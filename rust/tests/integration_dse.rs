//! Integration: the full DSE pipeline through the XLA artifact backend.

use std::sync::Arc;

use qappa::config::{PeType, ALL_PE_TYPES};
use qappa::coordinator::space::DesignSpace;
use qappa::coordinator::{
    run_dse, run_dse_multi, run_dse_with_store, DseOptions, ModelStore, NamedWorkload,
};
use qappa::dataflow::Layer;
use qappa::model::native::NativeBackend;
use qappa::model::CvConfig;
use qappa::runtime::{ArtifactRuntime, Engine, XlaBackend};

fn opts() -> DseOptions {
    DseOptions {
        space: DesignSpace::tiny(),
        train_per_type: 96,
        cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 4 },
        seed: 21,
        workers: 2,
        sigma: 0.03,
        chunk: 1024,
        topk: 8,
    }
}

fn layers() -> Vec<Layer> {
    vec![
        Layer::conv("c1", 8, 16, 28, 28, 3, 1, 1),
        Layer::conv("c2", 16, 32, 14, 14, 3, 1, 1),
        Layer::fc("fc", 512, 10),
    ]
}

#[test]
fn dse_through_artifacts_matches_native_shape() {
    let dir = ArtifactRuntime::artifacts_dir_default();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Arc::new(Engine::start(&dir).expect("engine"));
    let xla = XlaBackend::new(engine);
    let native = NativeBackend::new(7);

    let rx = run_dse(&xla, &layers(), "t", &opts()).expect("xla dse");
    let rn = run_dse(&native, &layers(), "t", &opts()).expect("native dse");

    // Same anchor config and closely matching ratios: the two backends see
    // the same oracle data and the same CV protocol.
    assert_eq!(rx.anchor.cfg, rn.anchor.cfg, "anchor config diverged");
    for ty in ALL_PE_TYPES {
        let (pax, ex) = rx.ratios[&ty];
        let (pan, en) = rn.ratios[&ty];
        assert!(
            (pax / pan - 1.0).abs() < 0.05,
            "{ty:?} perf/area ratio: xla {pax} vs native {pan}"
        );
        assert!(
            (ex / en - 1.0).abs() < 0.05,
            "{ty:?} energy ratio: xla {ex} vs native {en}"
        );
    }
}

#[test]
fn dse_points_cover_whole_grid_once() {
    let native = NativeBackend::new(7);
    let o = opts();
    let res = run_dse(&native, &layers(), "t", &o).expect("dse");
    for ty in ALL_PE_TYPES {
        let pts = &res.points[&ty];
        assert_eq!(pts.len(), o.space.len());
        let mut keys: Vec<String> = pts.iter().map(|p| p.cfg.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), pts.len(), "{ty:?}: duplicate configs");
        for p in pts {
            assert_eq!(p.cfg.pe_type, ty);
        }
    }
}

#[test]
fn frontier_members_are_undominated_within_type() {
    let native = NativeBackend::new(7);
    let res = run_dse(&native, &layers(), "t", &opts()).expect("dse");
    for ty in ALL_PE_TYPES {
        let pts = &res.points[&ty];
        for &i in &res.frontier[&ty] {
            for (j, q) in pts.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dominated = q.perf_per_area >= pts[i].perf_per_area
                    && q.energy_mj <= pts[i].energy_mj
                    && (q.perf_per_area > pts[i].perf_per_area
                        || q.energy_mj < pts[i].energy_mj);
                assert!(!dominated, "{ty:?}: frontier point {i} dominated by {j}");
            }
        }
    }
}

#[test]
fn int16_anchor_ratio_is_identity() {
    let native = NativeBackend::new(7);
    let res = run_dse(&native, &layers(), "t", &opts()).expect("dse");
    let (pa, _e) = res.ratios[&PeType::Int16];
    assert!((pa - 1.0).abs() < 1e-9);
}

#[test]
fn streaming_chunks_reproduce_eager_results_end_to_end() {
    // The streaming engine (small shards) and the eager shim (one
    // whole-grid shard) must agree bit-for-bit on anchor, frontier
    // membership and ratios.
    let native = NativeBackend::new(7);
    let mut eager = opts();
    eager.chunk = 0;
    let mut streaming = opts();
    streaming.chunk = 13;
    let a = run_dse(&native, &layers(), "t", &eager).expect("eager");
    let b = run_dse(&native, &layers(), "t", &streaming).expect("streaming");
    assert_eq!(a.anchor.cfg, b.anchor.cfg);
    for ty in ALL_PE_TYPES {
        assert_eq!(a.frontier[&ty], b.frontier[&ty], "{ty:?}");
        assert_eq!(a.ratios[&ty], b.ratios[&ty], "{ty:?}");
        assert_eq!(b.stats[&ty].evaluated, opts().space.len());
        assert_eq!(b.stats[&ty].shards, opts().space.len().div_ceil(13));
    }
}

#[test]
fn multi_workload_pass_trains_each_model_once() {
    // `qappa explore --workload a,b,c` semantics: one ModelStore, one
    // training pass per PE type, one streaming grid pass shared by all
    // workloads.
    let native = NativeBackend::new(7);
    let mut o = opts();
    o.chunk = 16;
    let store = ModelStore::new();
    let named = vec![
        NamedWorkload::new("a", layers()),
        NamedWorkload::new("b", vec![Layer::conv("x", 8, 16, 16, 16, 3, 1, 1)]),
    ];
    let summaries = run_dse_multi(&native, &store, &named, &o).expect("multi");
    assert_eq!(store.misses(), 4, "one training pass per PE type");
    assert_eq!(store.hits(), 0);
    assert_eq!(summaries.len(), 2);
    for s in &summaries {
        assert!((s.ratios[&PeType::Int16].0 - 1.0).abs() < 1e-9);
        for ty in ALL_PE_TYPES {
            assert!(!s.frontier[&ty].is_empty(), "{ty:?}");
            // streaming mode: the retained set is bounded by the shard in
            // flight plus frontier + reservoirs, never the grid
            let st = &s.stats[&ty];
            assert!(
                st.peak_resident <= 2 * (st.peak_frontier + st.reservoir_len),
                "{ty:?} peak {} frontier {} reservoirs {}",
                st.peak_resident,
                st.peak_frontier,
                st.reservoir_len
            );
        }
    }
    // a follow-up single-workload run reuses the same trained models
    run_dse_with_store(&native, &store, &layers(), "t", &o).expect("reuse");
    assert_eq!(store.misses(), 4);
    assert_eq!(store.hits(), 4);
}

#[test]
fn dse_runs_on_json_workload_file_end_to_end() {
    // The `qappa explore --workload model.json` path: write a small
    // depthwise-separable model to disk, load it through workloads::load,
    // and run the full DSE pipeline on it.
    let text = r#"{
        "name": "json-tiny",
        "layers": [
            {"name": "stem", "type": "conv", "c": 3, "k": 16, "hw": 32, "rs": 3, "stride": 2, "pad": 1},
            {"name": "dw", "type": "dw", "c": 16, "hw": 16, "rs": 3},
            {"name": "pw", "type": "pw", "c": 16, "k": 32, "hw": 16},
            {"name": "fc", "type": "fc", "c": 512, "k": 10}
        ]
    }"#;
    let path = std::env::temp_dir().join("qappa_test_workload.json");
    std::fs::write(&path, text).expect("write temp workload");
    let (name, layers) = qappa::workloads::load(path.to_str().unwrap()).expect("load json");
    assert_eq!(name, "json-tiny");
    assert_eq!(layers.len(), 4);
    assert!(layers[1].is_depthwise());

    let native = NativeBackend::new(7);
    let res = run_dse(&native, &layers, &name, &opts()).expect("dse over json workload");
    assert_eq!(res.workload, "json-tiny");
    for ty in ALL_PE_TYPES {
        assert!(!res.points[&ty].is_empty());
        for p in &res.points[&ty] {
            assert!(p.throughput > 0.0 && p.energy_mj > 0.0);
        }
    }
    std::fs::remove_file(&path).ok();
}
