//! Integration: the guided optimizer versus the exhaustive sweep.
//!
//! The acceptance experiment of the optimizer subsystem: on a mid-scale
//! hardware space x the four preset precision cells x MobileNetV1, seeded
//! NSGA-II with a budget under 5% of the uniform (hardware x precision)
//! grid must recover at least 90% of the exhaustive sweep's Pareto
//! hypervolume, beat the random baseline at equal budget, and reproduce
//! its frontier bit-for-bit under the same seed.  A second test pins the
//! serve/session identity: the `optimize` op over the wire and the typed
//! session call produce byte-identical frontier reports for the same seed.

use qappa::api::{handle_line, OptimizeRequest, PrecisionRequest, Qappa, ResponseBody};
use qappa::api::BackendChoice;
use qappa::config::{ALL_PE_TYPES, QUANT_NUM_FEATURES};
use qappa::coordinator::pareto::hypervolume;
use qappa::coordinator::report::{opt_convergence_table, opt_frontier_table};
use qappa::coordinator::sweep::{NamedWorkload, SweepEngine};
use qappa::coordinator::{DesignSpace, DseOptions, ModelStore};
use qappa::model::native::NativeBackend;
use qappa::model::CvConfig;
use qappa::opt::{
    run_optimize, Constraints, Objective, OptOptions, OptProblem, OptResult, SearchSpace,
    StrategyKind,
};
use qappa::workloads;

/// A mid-scale subset of the paper axes: 1280 hardware points, so the
/// uniform (hardware x 4 presets) grid has 5120 cells and the exhaustive
/// sweep stays test-sized.
fn mid_space() -> DesignSpace {
    DesignSpace {
        rows: vec![8, 12, 16, 24],
        cols: vec![8, 14, 20, 28],
        glb_kb: vec![32, 64, 108, 256, 512],
        spad_ifmap_b: vec![24, 96],
        spad_filter_b: vec![56, 224],
        spad_psum_b: vec![32, 128],
        bandwidth_gbps: vec![2.0, 8.0],
        quants: Vec::new(),
    }
}

fn mid_opts() -> DseOptions {
    DseOptions {
        space: mid_space(),
        train_per_type: 128,
        cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
        seed: 7,
        workers: 4,
        sigma: 0.02,
        chunk: 512,
        topk: 8,
    }
}

fn guided(
    backend: &NativeBackend,
    model: &qappa::model::PpaModel,
    opts: &DseOptions,
    layers: &[qappa::dataflow::Layer],
    strategy: StrategyKind,
    budget: usize,
    seed: u64,
) -> OptResult {
    let search =
        SearchSpace::new(&opts.space, ALL_PE_TYPES.to_vec(), layers, true).unwrap();
    let problem = OptProblem {
        search,
        objectives: vec![Objective::PerfPerArea, Objective::Energy],
        constraints: Constraints::default(),
        accuracy: None,
    };
    let oopts = OptOptions { strategy, budget, pop: 50, seed, ..Default::default() };
    run_optimize(backend, model, &problem, &oopts, opts.workers).unwrap()
}

fn frontier_pairs(res: &OptResult) -> Vec<(f64, f64)> {
    res.frontier
        .iter()
        .map(|f| (f.point.perf_per_area, f.point.energy_mj))
        .collect()
}

#[test]
fn nsga2_recovers_exhaustive_hypervolume_within_five_percent_budget() {
    let opts = mid_opts();
    let backend = NativeBackend::new(QUANT_NUM_FEATURES);
    let store = ModelStore::new();
    let palette = ALL_PE_TYPES.to_vec();
    let model = store.get_or_train_quant(&backend, &opts, &palette).unwrap();
    let layers = workloads::mobilenetv1();

    // Exhaustive baseline: one streaming pass over the precision-extended
    // grid (the quants axis makes precision the outermost grid digit).
    let mut ex_opts = opts.clone();
    ex_opts.space = mid_space().with_quants(palette.clone());
    let uniform_grid = ex_opts.space.len();
    assert_eq!(uniform_grid, 5120);
    let sweep = SweepEngine::new(&backend, &ex_opts)
        .sweep_type(
            &model,
            qappa::config::PeType::Int16, // ignored: the quants axis rules
            &[NamedWorkload::new("mobilenetv1", layers.clone())],
        )
        .unwrap()
        .remove(0);
    assert_eq!(sweep.stats.evaluated, uniform_grid);
    let exhaustive: Vec<(f64, f64)> = sweep
        .frontier
        .iter()
        .map(|e| (e.perf_per_area, e.energy))
        .collect();
    assert!(!exhaustive.is_empty());

    // Guided search: budget below 5% of the uniform grid.
    let budget = 250;
    assert!((budget as f64) < 0.05 * uniform_grid as f64);
    let nsga = guided(&backend, &model, &opts, &layers, StrategyKind::Nsga2, budget, 11);
    assert!(nsga.evaluated <= budget, "budget overrun: {}", nsga.evaluated);
    let rand = guided(&backend, &model, &opts, &layers, StrategyKind::Random, budget, 11);
    assert!(rand.evaluated <= budget);

    // One shared reference corner over every frontier involved.
    let g_pts = frontier_pairs(&nsga);
    let r_pts = frontier_pairs(&rand);
    let max_energy = exhaustive
        .iter()
        .chain(&g_pts)
        .chain(&r_pts)
        .map(|&(_, e)| e)
        .fold(f64::MIN, f64::max);
    let ref_point = (0.0, 1.25 * max_energy);
    let hv_ex = hypervolume(&exhaustive, ref_point);
    let hv_guided = hypervolume(&g_pts, ref_point);
    let hv_rand = hypervolume(&r_pts, ref_point);
    assert!(hv_ex > 0.0);

    // Acceptance: >= 90% of the exhaustive hypervolume at < 5% of the
    // evaluations (the per-layer search space the optimizer actually
    // roams — |hw| x |palette|^|layers| — is astronomically larger still).
    assert!(
        hv_guided >= 0.90 * hv_ex,
        "guided hypervolume {hv_guided:.6e} < 90% of exhaustive {hv_ex:.6e} \
         ({:.1}%)",
        100.0 * hv_guided / hv_ex
    );
    // The random baseline is strictly worse at equal budget.
    assert!(
        hv_rand < hv_guided,
        "random baseline {hv_rand:.6e} not beaten by nsga2 {hv_guided:.6e}"
    );

    // Same seed => bit-identical frontier (the byte-identical report is
    // pinned at the session/serve layer below).
    let again = guided(&backend, &model, &opts, &layers, StrategyKind::Nsga2, budget, 11);
    assert_eq!(nsga.evaluated, again.evaluated);
    assert_eq!(nsga.hypervolume, again.hypervolume);
    let render = |r: &OptResult| -> String {
        r.frontier
            .iter()
            .map(|f| {
                format!(
                    "{}|{:?}|{:?}|{}",
                    f.point.cfg.key(),
                    f.objs,
                    f.genome.hw,
                    f.precision.join(",")
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(render(&nsga), render(&again), "same seed must reproduce the frontier");
}

#[test]
fn optimize_over_serve_matches_the_typed_session_call() {
    let session = Qappa::builder()
        .backend(BackendChoice::Native)
        .space(DesignSpace::tiny())
        .train_per_type(64)
        .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
        .seed(7)
        .workers(4)
        .sigma(0.02)
        .chunk(32)
        .topk(8)
        .build();
    let req = OptimizeRequest {
        workload: "mobilenetv2".into(),
        objectives: vec!["latency".into(), "energy".into()],
        budget: Some(60),
        pop: Some(16),
        seed: Some(9),
        precision: Some(PrecisionRequest {
            types: vec!["int16".into(), "a4w4p8-int".into()],
            ..Default::default()
        }),
        ..Default::default()
    };
    let typed = session.optimize(&req).unwrap();
    assert_eq!(typed.objectives, vec!["latency".to_string(), "energy".to_string()]);
    assert!(!typed.frontier.is_empty());

    // The same request over the serve wire, against the same session.
    let line = format!(
        r#"{{"id":5,"op":"optimize","params":{}}}"#,
        req.to_json()
    );
    let resp = handle_line(&session, &line);
    assert_eq!(resp.id, Some(5));
    let wire = match resp.result {
        Ok(ResponseBody::Optimize(r)) => r,
        other => panic!("expected an optimize response, got {other:?}"),
    };
    assert_eq!(wire, typed, "serve and session must agree for identical seeds");

    // Byte-identical frontier report, both layers.
    assert_eq!(
        opt_frontier_table(&wire).to_csv(),
        opt_frontier_table(&typed).to_csv()
    );
    assert_eq!(
        opt_convergence_table(&wire).to_csv(),
        opt_convergence_table(&typed).to_csv()
    );
    // and the unified model trained exactly once across both runs
    assert_eq!(session.store().misses(), 1);
}
