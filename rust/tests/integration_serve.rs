//! Integration: the `qappa serve` request loop against one warm session —
//! a mixed batch of `explore` / `synth` / `analyze` requests through one
//! session must train the PPA models exactly once (ModelStore counters),
//! sequentially and under concurrent dispatch.

use qappa::api::{
    serve, BackendChoice, OptimizeResponse, Qappa, ResponseBody, ServeOptions, ServeResponse,
    ServeStats, SessionInfo,
};
use qappa::config::PeType;
use qappa::coordinator::DesignSpace;
use qappa::coordinator::DseOptions;
use qappa::model::CvConfig;
use qappa::util::json::Json;

fn tiny_session() -> Qappa {
    Qappa::builder()
        .backend(BackendChoice::Native)
        .options(DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk: 32,
            topk: 8,
        })
        .build()
}

fn parse_lines(out: &[u8]) -> Vec<ServeResponse> {
    std::str::from_utf8(out)
        .expect("utf8 output")
        .lines()
        .map(|l| ServeResponse::from_json(&Json::parse(l).expect("response json")).expect("typed"))
        .collect()
}

#[test]
fn mixed_batch_through_one_session_trains_models_once() {
    let session = tiny_session();
    let input = concat!(
        r#"{"id":1,"op":"workloads"}"#, "\n",
        r#"{"id":2,"op":"synth","params":{"config":{"pe_type":"int16"}}}"#, "\n",
        r#"{"id":3,"op":"explore","params":{"workloads":["vgg16"]}}"#, "\n",
        r#"{"id":4,"op":"explore","params":{"workloads":["vgg16"]}}"#, "\n",
        r#"{"id":5,"op":"analyze","params":{"workload":"vgg16","config":{"pe_type":"lightpe1"}}}"#, "\n",
        r#"{"id":6,"op":"session"}"#, "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve(&session, input.as_bytes(), &mut out, &ServeOptions { concurrency: 1 }).unwrap();
    assert_eq!(stats, ServeStats { requests: 6, ok: 6, errors: 0 });

    let resps = parse_lines(&out);
    assert_eq!(resps.len(), 6);
    // sequential serving answers in request order, ids echoed
    let ids: Vec<u64> = resps.iter().map(|r| r.id.expect("id echoed")).collect();
    assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
    for r in &resps {
        assert!(r.result.is_ok(), "request {:?} failed: {:?}", r.id, r.result);
    }

    // models trained exactly once: the first explore misses 4 (one per PE
    // type), the repeat explore is 4 cache hits
    assert_eq!(session.store().misses(), 4, "one training pass per PE type");
    assert!(session.store().hits() >= 4, "repeat explore served warm");

    // the two explore responses are identical (same warm models)
    match (&resps[2].result, &resps[3].result) {
        (Ok(ResponseBody::Explore(a)), Ok(ResponseBody::Explore(b))) => {
            assert_eq!(a, b, "warm repeat explore must be deterministic");
            assert_eq!(a.summaries.len(), 1);
            assert_eq!(a.summaries[0].workload, "vgg16");
            assert_eq!(a.summaries[0].anchor.pe_type, PeType::Int16);
        }
        other => panic!("expected two explore responses, got {other:?}"),
    }

    // the session op reported the same counters over the wire
    match &resps[5].result {
        Ok(ResponseBody::Session(SessionInfo { backend, models_trained, cache_hits, .. })) => {
            assert_eq!(backend.as_deref(), Some("native"));
            assert_eq!(*models_trained, 4);
            assert!(*cache_hits >= 4);
        }
        other => panic!("expected a session response, got {other:?}"),
    }
}

#[test]
fn concurrent_dispatch_shares_one_warm_session() {
    let session = tiny_session();
    // Two cold explores racing plus cheap requests: in-flight training
    // dedup must still train each PE-type model exactly once.
    let input = concat!(
        r#"{"id":1,"op":"explore","params":{"workloads":["vgg16"]}}"#, "\n",
        r#"{"id":2,"op":"explore","params":{"workloads":["vgg16"]}}"#, "\n",
        r#"{"id":3,"op":"workloads"}"#, "\n",
        r#"{"id":4,"op":"synth","params":{"config":{"pe_type":"fp32"}}}"#, "\n",
        r#"{"id":5,"op":"analyze","params":{"workload":"mobilenetv2","config":{"pe_type":"int16"}}}"#, "\n",
        r#"{"id":6,"op":"workloads","params":{"workload":"resnet34"}}"#, "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve(&session, input.as_bytes(), &mut out, &ServeOptions { concurrency: 4 }).unwrap();
    assert_eq!(stats, ServeStats { requests: 6, ok: 6, errors: 0 });

    let resps = parse_lines(&out);
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id.expect("id echoed")).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2, 3, 4, 5, 6], "every request answered exactly once");
    for r in &resps {
        assert!(r.result.is_ok(), "request {:?} failed: {:?}", r.id, r.result);
    }
    assert_eq!(
        session.store().misses(),
        4,
        "concurrent cold explores must not retrain (in-flight dedup)"
    );
    assert!(session.store().hits() >= 4);
}

#[test]
fn concurrent_optimize_and_explore_share_one_session() {
    // Long-running optimize requests dispatched concurrently with explore
    // on one session: every id answered exactly once, identical optimize
    // requests agree (determinism under concurrent dispatch), and the
    // model caches dedupe — 4 per-type models for explore + 1 unified
    // model for the optimize palette, no matter the interleaving.
    let session = tiny_session();
    let opt_params = r#"{"workload":"vgg16","budget":50,"pop":16,"seed":3,"precision":{"types":["int16","a4w4p8-int"]}}"#;
    let input = format!(
        concat!(
            r#"{{"id":1,"op":"optimize","params":{p}}}"#, "\n",
            r#"{{"id":2,"op":"explore","params":{{"workloads":["vgg16"]}}}}"#, "\n",
            r#"{{"id":3,"op":"optimize","params":{p}}}"#, "\n",
            r#"{{"id":4,"op":"workloads"}}"#, "\n",
            r#"{{"id":5,"op":"session"}}"#, "\n",
        ),
        p = opt_params
    );
    let mut out = Vec::new();
    let stats =
        serve(&session, input.as_bytes(), &mut out, &ServeOptions { concurrency: 4 }).unwrap();
    assert_eq!(stats, ServeStats { requests: 5, ok: 5, errors: 0 });

    let resps = parse_lines(&out);
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id.expect("id echoed")).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2, 3, 4, 5], "id correlation preserved out of order");

    let opt_of = |id: u64| -> &OptimizeResponse {
        match &resps.iter().find(|r| r.id == Some(id)).unwrap().result {
            Ok(ResponseBody::Optimize(r)) => r,
            other => panic!("request {id}: expected optimize, got {other:?}"),
        }
    };
    let a = opt_of(1);
    let b = opt_of(3);
    assert_eq!(a, b, "identical optimize requests must agree under concurrency");
    assert!(!a.frontier.is_empty());
    assert!(a.evaluated <= 50);
    // explore answered too
    assert!(matches!(
        resps.iter().find(|r| r.id == Some(2)).unwrap().result,
        Ok(ResponseBody::Explore(_))
    ));
    // 4 per-type models (explore) + 1 unified palette model (optimize)
    assert_eq!(session.store().misses(), 5, "in-flight dedup across op kinds");
}

#[test]
fn optimize_error_paths_classify_and_keep_the_loop_alive() {
    let session = tiny_session();
    let input = concat!(
        // malformed params: budget is not an integer -> protocol
        r#"{"id":20,"op":"optimize","params":{"workload":"vgg16","budget":"many"}}"#, "\n",
        // missing workload -> protocol
        r#"{"id":21,"op":"optimize","params":{"objectives":["lat","energy"]}}"#, "\n",
        // unknown objective -> config (request parsed, semantics rejected)
        r#"{"id":22,"op":"optimize","params":{"workload":"vgg16","objectives":["speed","energy"]}}"#, "\n",
        // unknown strategy -> config
        r#"{"id":23,"op":"optimize","params":{"workload":"vgg16","strategy":"annealing"}}"#, "\n",
        // cancelled-by-budget: a zero budget is rejected up front -> config
        r#"{"id":24,"op":"optimize","params":{"workload":"vgg16","budget":0}}"#, "\n",
        // impossible min_bits floor -> config naming the constraint
        r#"{"id":25,"op":"optimize","params":{"workload":"vgg16","constraints":{"min_bits":99}}}"#, "\n",
        // the loop survives to answer a healthy request
        r#"{"id":26,"op":"workloads"}"#, "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve(&session, input.as_bytes(), &mut out, &ServeOptions { concurrency: 1 }).unwrap();
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.errors, 6);

    let resps = parse_lines(&out);
    let err_of = |i: usize| resps[i].result.as_ref().unwrap_err();
    assert_eq!(resps[0].id, Some(20));
    assert_eq!(err_of(0).kind, "protocol");
    assert!(err_of(0).message.contains("budget"), "{}", err_of(0).message);
    assert_eq!(err_of(1).kind, "protocol");
    assert!(err_of(1).message.contains("workload"), "{}", err_of(1).message);
    assert_eq!(err_of(2).kind, "config");
    assert!(err_of(2).message.contains("speed"), "{}", err_of(2).message);
    assert_eq!(err_of(3).kind, "config");
    assert!(err_of(3).message.contains("annealing"), "{}", err_of(3).message);
    assert_eq!(err_of(4).kind, "config");
    assert!(err_of(4).message.contains("budget"), "{}", err_of(4).message);
    assert_eq!(err_of(5).kind, "config");
    assert!(err_of(5).message.contains("min_bits"), "{}", err_of(5).message);
    assert!(resps[6].result.is_ok(), "loop must survive optimize errors");
    // nothing trained, backend never started
    assert_eq!(session.store().misses(), 0);
    assert_eq!(session.session_info().backend, None);
}

#[test]
fn malformed_requests_answer_errors_and_never_train() {
    let session = tiny_session();
    let input = concat!(
        "this is not json\n",
        r#"{"id":9,"op":"nope"}"#, "\n",
        r#"{"id":10,"op":"explore","params":{"workloads":["alexnet"]}}"#, "\n",
        r#"{"id":11,"op":"synth","params":{"config":{"pe_type":"int16","pe_rows":0}}}"#, "\n",
        r#"{"id":12,"op":"session"}"#, "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve(&session, input.as_bytes(), &mut out, &ServeOptions { concurrency: 1 }).unwrap();
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.errors, 4);

    let resps = parse_lines(&out);
    // unparseable line: protocol error, id unknown
    assert_eq!(resps[0].id, None);
    assert_eq!(resps[0].result.as_ref().unwrap_err().kind, "protocol");
    // unknown op: id echoed, protocol error names the op
    assert_eq!(resps[1].id, Some(9));
    let e = resps[1].result.as_ref().unwrap_err();
    assert_eq!(e.kind, "protocol");
    assert!(e.message.contains("nope"), "{}", e.message);
    // unknown workload: classified, lists the built-ins
    let e = resps[2].result.as_ref().unwrap_err();
    assert_eq!(e.kind, "workload");
    assert!(e.message.contains("vgg16"), "{}", e.message);
    // invalid config: classified
    assert_eq!(resps[3].result.as_ref().unwrap_err().kind, "config");
    // the loop survived, nothing trained, backend never started
    match &resps[4].result {
        Ok(ResponseBody::Session(info)) => {
            assert_eq!(info.models_trained, 0);
            assert_eq!(info.backend, None, "bad requests must not start the backend");
        }
        other => panic!("expected session response, got {other:?}"),
    }
}
