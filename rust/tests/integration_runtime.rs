//! Integration: the PJRT artifact path against python-generated goldens.
//!
//! `artifacts/golden.json` is produced by `python -m compile.aot` and holds
//! deterministic inputs + the L2 model functions' outputs.  The rust engine
//! must reproduce them bit-closely through the HLO-text artifacts — this is
//! the end-to-end proof that L1 (pallas) == L2 (jax) == L3 (rust/PJRT).

use std::path::Path;
use std::sync::Arc;

use qappa::model::{Backend, M};
use qappa::runtime::{Engine, XlaBackend};
use qappa::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = qappa::runtime::ArtifactRuntime::artifacts_dir_default();
    if dir.join("manifest.json").exists() && dir.join("golden.json").exists() {
        Some(dir)
    } else {
        None
    }
}

fn load_golden(dir: &Path) -> Json {
    let text = std::fs::read_to_string(dir.join("golden.json")).expect("golden.json");
    Json::parse(&text).expect("golden parses")
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        let denom = w.abs().max(1.0);
        worst = worst.max((g - w).abs() / denom);
    }
    assert!(worst <= tol, "{what}: worst rel err {worst} > {tol}");
}

#[test]
fn golden_predict_fit_loss_parity() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let golden = load_golden(&dir);
    let engine = Engine::start(&dir).expect("engine");
    let d = engine.d;

    for degree in [1usize, 2, 3] {
        let case = golden.get("cases").get(&degree.to_string());
        if case == &Json::Null {
            continue;
        }
        // ---- predict ----
        let p = case.get("predict");
        let x = p.get("x").as_f32_vec().unwrap();
        let w = p.get("w").as_f32_vec().unwrap();
        let want = p.get("yhat").as_f32_vec().unwrap();
        let n = x.len() / d;
        let got = engine
            .predict(degree, Arc::new(w), x, n)
            .expect("predict");
        assert_close(&got, &want, 2e-4, &format!("predict d{degree}"));

        // ---- fit + loss ----
        let f = case.get("fit");
        let n_real = f.get("n_real").as_usize().unwrap();
        let fx = f.get("x").as_f32_vec().unwrap();
        let fy = f.get("y").as_f32_vec().unwrap();
        let lam = f.get("lam").as_f64().unwrap() as f32;
        let want_coef = f.get("coef").as_f32_vec().unwrap();
        let want_mse = f.get("mse").as_f32_vec().unwrap();
        let w1 = vec![1.0f32; n_real];
        let coef = engine
            .fit(degree, fx.clone(), fy.clone(), w1.clone(), n_real, lam)
            .expect("fit");
        assert_close(&coef, &want_coef, 5e-3, &format!("fit d{degree}"));
        let mse = engine
            .loss(degree, fx, fy, w1, n_real, coef)
            .expect("loss");
        assert_close(&mse, &want_mse, 5e-3, &format!("loss d{degree}"));
    }
}

#[test]
fn xla_and_native_backends_agree() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Arc::new(Engine::start(&dir).expect("engine"));
    let xla = XlaBackend::new(engine);
    let native = qappa::model::native::NativeBackend::new(xla.d());

    let mut rng = qappa::util::prng::Rng::new(77);
    let n = 300usize;
    let d = xla.d();
    let x: Vec<f32> = (0..n * d).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect();
    let y: Vec<f32> = (0..n * M).map(|_| rng.gauss() as f32).collect();
    let w: Vec<f32> = (0..n).map(|_| if rng.f64() < 0.8 { 1.0 } else { 0.0 }).collect();

    for degree in [1usize, 2] {
        let cx = xla.fit(&x, &y, &w, n, 0.01, degree).expect("xla fit");
        let cn = native.fit(&x, &y, &w, n, 0.01, degree).expect("native fit");
        assert_close(&cx, &cn, 2e-2, &format!("fit parity d{degree}"));

        let px = xla.predict(&x, n, &cn, degree).expect("xla predict");
        let pn = native.predict(&x, n, &cn, degree).expect("native predict");
        assert_close(&px, &pn, 2e-4, &format!("predict parity d{degree}"));

        let lx = xla.loss(&x, &y, &w, n, &cn, degree).expect("xla loss");
        let ln = native.loss(&x, &y, &w, n, &cn, degree).expect("native loss");
        assert_close(&lx, &ln, 2e-3, &format!("loss parity d{degree}"));
    }
}

#[test]
fn batcher_answers_every_request_exactly_once() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Arc::new(Engine::start(&dir).expect("engine"));
    let d = engine.d;
    let degree = 2usize;
    let p = qappa::model::num_features(d, degree);
    let coef: Arc<Vec<f32>> = Arc::new((0..p * M).map(|i| (i as f32 * 0.01).sin()).collect());

    // Fire concurrent odd-sized requests; each must come back with its own
    // rows (identity checked through a per-request marker column).
    let native = qappa::model::native::NativeBackend::new(d);
    let mut handles = Vec::new();
    for t in 0..8u32 {
        let engine = engine.clone();
        let coef = coef.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = qappa::util::prng::Rng::new(1000 + t as u64);
            let n = 1 + rng.below(700);
            let x: Vec<f32> =
                (0..n * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            let out = engine
                .predict(degree, coef.clone(), x.clone(), n)
                .expect("predict");
            (n, x, out)
        }));
    }
    for h in handles {
        let (n, x, out) = h.join().unwrap();
        assert_eq!(out.len(), n * M);
        let want = native.predict(&x, n, &coef, degree).unwrap();
        assert_close(&out, &want, 2e-4, "scattered batch rows");
    }
    // batching actually occurred (requests > batches is not guaranteed
    // under races, but rows processed must match rows requested)
    use std::sync::atomic::Ordering::Relaxed;
    let rows = engine.stats.predict_rows.load(Relaxed);
    let batches = engine.stats.predict_batches.load(Relaxed);
    assert!(rows > 0 && batches > 0);
}

#[test]
fn manifest_monomials_match_rust_expansion() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let man = qappa::runtime::Manifest::load(&dir).expect("manifest");
    for (&degree, mons) in &man.monomials {
        let rust = qappa::model::features::monomial_indices(man.d, degree);
        assert_eq!(&rust, mons, "monomial order mismatch at degree {degree}");
    }
    assert_eq!(man.d, qappa::config::NUM_FEATURES);
    assert_eq!(man.m, M);
}
