//! Calibration regression: the headline ratios of the paper's §4 must stay
//! in their reproduction bands (EXPERIMENTS.md records the exact values).
//!
//! Paper: LightPE-1 4.9x perf/area and 4.9x energy vs the best INT16
//! config; LightPE-2 4.1x / 4.2x; INT16 1.7x / 1.4x vs the best FP32.
//! Reproduced (jitter-free oracle, full default space): LightPE-1
//! ~4.0-4.6x / ~4.3-5.0x, LightPE-2 ~3.1x / ~3.2-3.6x, INT16-vs-FP32
//! ~2.6-2.9x / ~2.7x — same ordering and factor scale; the bands below are
//! intentionally wider than the measured spread but tight enough to catch
//! a broken model.

use qappa::config::PeType;
use qappa::coordinator::{run_dse, DseOptions};
use qappa::model::native::NativeBackend;
use qappa::workloads;

fn ratios(workload: &str) -> std::collections::BTreeMap<PeType, (f64, f64)> {
    let mut opts = DseOptions::default();
    opts.sigma = 0.0; // oracle-direct: calibration without regression noise
    opts.train_per_type = 512;
    let backend = NativeBackend::new(7);
    let layers = workloads::by_name(workload).unwrap();
    run_dse(&backend, &layers, workload, &opts)
        .expect("dse")
        .ratios
        .clone()
}

fn assert_band(v: f64, lo: f64, hi: f64, what: &str) {
    assert!((lo..=hi).contains(&v), "{what} = {v:.2} outside [{lo}, {hi}]");
}

#[test]
fn headline_ratios_for_all_networks() {
    for wl in ["vgg16", "resnet34", "resnet50"] {
        let r = ratios(wl);
        let (pa1, e1) = r[&PeType::LightPe1];
        let (pa2, e2) = r[&PeType::LightPe2];
        let (paf, ef) = r[&PeType::Fp32];
        let (pai, ei) = r[&PeType::Int16];

        // ordering: LightPE-1 > LightPE-2 > INT16 > FP32 on both axes
        assert!(pa1 > pa2 && pa2 > pai && pai > paf, "{wl}: perf/area ordering {pa1} {pa2} {pai} {paf}");
        assert!(e1 > e2 && e2 > 1.0 && 1.0 > ef, "{wl}: energy ordering {e1} {e2} {ef}");

        // bands around the paper's factors (paper: 4.9/4.9, 4.1/4.2)
        assert_band(pa1, 3.0, 6.5, &format!("{wl} LightPE-1 perf/area"));
        assert_band(e1, 3.3, 6.5, &format!("{wl} LightPE-1 energy"));
        assert_band(pa2, 2.2, 5.5, &format!("{wl} LightPE-2 perf/area"));
        assert_band(e2, 2.4, 5.5, &format!("{wl} LightPE-2 energy"));
        // INT16 vs FP32 (paper 1.7/1.4; we land ~2.5-3 — same direction)
        assert_band(1.0 / paf, 1.3, 4.0, &format!("{wl} INT16-vs-FP32 perf/area"));
        assert_band(1.0 / ef, 1.2, 4.0, &format!("{wl} INT16-vs-FP32 energy"));
        // anchor self-ratio
        assert!((pai - 1.0).abs() < 1e-9);
        assert!(ei >= 1.0, "{wl}: INT16 best-energy ratio {ei} < 1");
    }
}
