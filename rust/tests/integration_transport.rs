//! Integration: the TCP transport (`qappa serve --listen`) end to end —
//! concurrent clients correlated by id over one shared `ModelStore`
//! (models train once per process), malformed and oversized frames
//! answered without killing the stream, client disconnect cancelling an
//! in-flight `optimize`, admission shedding at both the connection and
//! the in-flight caps, and wire purity (sockets carry only JSON response
//! lines).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use qappa::api::{
    BackendChoice, DispatchOptions, Qappa, ServeResponse, TcpServer, TransportOptions,
};
use qappa::coordinator::{DesignSpace, DseOptions};
use qappa::model::CvConfig;
use qappa::util::json::Json;

fn tiny_session() -> Qappa {
    Qappa::builder()
        .backend(BackendChoice::Native)
        .options(DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk: 32,
            topk: 8,
        })
        .build()
}

fn bind(session: Arc<Qappa>, opts: TransportOptions) -> TcpServer {
    TcpServer::bind(session, "127.0.0.1:0", opts).expect("bind ephemeral port")
}

/// Connect, returning a (writer, buffered reader) pair over one socket.
fn client(server: &TcpServer) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(server.local_addr()).expect("connect");
    let reader = BufReader::new(stream.try_clone().expect("clone"));
    (stream, reader)
}

/// Read one line and parse it as a typed response — every byte a server
/// socket carries must survive this (the wire-purity contract).
fn read_response(reader: &mut BufReader<TcpStream>) -> ServeResponse {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read response line");
    assert!(n > 0, "server closed the connection unexpectedly");
    ServeResponse::from_json(&Json::parse(&line).expect("socket line must be JSON"))
        .expect("socket line must be a typed response")
}

#[test]
fn concurrent_clients_correlate_by_id_and_train_once() {
    let session = Arc::new(tiny_session());
    let mut server = bind(session.clone(), TransportOptions::default());
    let addr = server.local_addr();

    let handles: Vec<_> = (0..3u64)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                let mut writer = stream.try_clone().expect("clone");
                let mut reader = BufReader::new(stream);
                for k in 0..4u64 {
                    let id = c * 100 + k;
                    let req = if k % 2 == 0 {
                        format!(
                            "{{\"id\":{id},\"op\":\"explore\",\
                             \"params\":{{\"workloads\":[\"vgg16\"]}}}}"
                        )
                    } else {
                        format!("{{\"id\":{id},\"op\":\"workloads\"}}")
                    };
                    writeln!(writer, "{req}").expect("write");
                    writer.flush().expect("flush");
                    let resp = read_response(&mut reader);
                    assert_eq!(resp.id, Some(id), "response echoes this client's id");
                    assert!(resp.result.is_ok(), "request {id} failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }

    server.shutdown();
    let st = server.stats();
    assert_eq!(st.connections, 3);
    assert_eq!(st.active, 0, "drain leaves no live connections");
    assert_eq!((st.dispatch.requests, st.dispatch.ok), (12, 12));
    // Three clients, six explores — one training pass (4 models) for the
    // whole process.
    assert_eq!(session.store().misses(), 4, "models train once per process");
    // Even with maximal coalescing the second explore round dispatches
    // once more: 4 warm lookups at minimum.
    assert!(session.store().hits() >= 4, "later explores hit the shared store");
}

#[test]
fn malformed_and_oversized_frames_answer_errors_and_the_stream_survives() {
    let session = Arc::new(Qappa::builder().backend(BackendChoice::Native).build());
    let mut server = bind(
        session,
        TransportOptions { max_line_bytes: 256, ..TransportOptions::default() },
    );
    let (mut writer, mut reader) = client(&server);

    // Malformed JSON: a protocol error with a null id.
    writeln!(writer, "this is not json").unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert_eq!(resp.id, None);
    assert_eq!(resp.result.unwrap_err().kind, "protocol");

    // Oversized frame: consumed, reported with the byte count, stream alive.
    let huge = "x".repeat(400);
    writeln!(writer, "{huge}").unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert_eq!(resp.id, None);
    let e = resp.result.unwrap_err();
    assert_eq!(e.kind, "protocol");
    assert!(e.message.contains("oversized"), "{}", e.message);
    assert!(e.message.contains("max 256"), "{}", e.message);

    // The same connection still answers real requests afterwards.
    writeln!(writer, "{{\"id\":7,\"op\":\"workloads\"}}").unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert_eq!(resp.id, Some(7));
    assert!(resp.result.is_ok());

    // Wire purity: exactly one response line per request, nothing else,
    // then EOF once the server drains.
    server.shutdown();
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "no extra bytes after the responses: {rest:?}");
    let st = server.stats();
    assert_eq!(st.dispatch.requests, 3);
    assert_eq!((st.dispatch.ok, st.dispatch.errors), (1, 2));
}

#[test]
fn client_disconnect_cancels_an_inflight_optimize() {
    let session = Arc::new(tiny_session());
    let mut server = bind(session.clone(), TransportOptions::default());

    // Warm the store first so the optimize below is in its search loop
    // (the cancellable region) rather than still training.
    {
        let (mut writer, mut reader) = client(&server);
        writeln!(writer, "{{\"id\":1,\"op\":\"explore\",\"params\":{{\"workloads\":[\"vgg16\"]}}}}")
            .unwrap();
        writer.flush().unwrap();
        assert!(read_response(&mut reader).result.is_ok());
    }

    // A budget far past what the test should ever evaluate: only
    // cancellation can end this run promptly.
    let (mut writer, reader) = client(&server);
    writeln!(
        writer,
        "{{\"id\":2,\"op\":\"optimize\",\"params\":{{\"workload\":\"mobilenetv1\",\
         \"budget\":200000,\"pop\":32}}}}"
    )
    .unwrap();
    writer.flush().unwrap();
    std::thread::sleep(Duration::from_millis(300)); // let the run start
    drop(writer);
    drop(reader); // full disconnect: the connection reader sees EOF

    let deadline = Instant::now() + Duration::from_secs(120);
    while server.stats().dispatch.cancelled < 1 {
        assert!(
            Instant::now() < deadline,
            "optimize was not cancelled after disconnect: {:?}",
            server.stats().dispatch
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // The server survives and keeps answering fresh connections.
    let (mut writer, mut reader) = client(&server);
    writeln!(writer, "{{\"id\":3,\"op\":\"workloads\"}}").unwrap();
    writer.flush().unwrap();
    let resp = read_response(&mut reader);
    assert_eq!(resp.id, Some(3));
    assert!(resp.result.is_ok());
    server.shutdown();
}

#[test]
fn connection_cap_sheds_excess_clients_with_a_structured_error() {
    let session = Arc::new(Qappa::builder().backend(BackendChoice::Native).build());
    let mut server = bind(
        session,
        TransportOptions { max_connections: 1, ..TransportOptions::default() },
    );

    // First client occupies the only slot (a completed round trip proves
    // its registration happened before the second connect).
    let (mut writer, mut reader) = client(&server);
    writeln!(writer, "{{\"id\":1,\"op\":\"workloads\"}}").unwrap();
    writer.flush().unwrap();
    assert!(read_response(&mut reader).result.is_ok());

    // Second client is shed with one protocol error line, then EOF.
    let (_w2, mut r2) = client(&server);
    let resp = read_response(&mut r2);
    assert_eq!(resp.id, None);
    let e = resp.result.unwrap_err();
    assert_eq!(e.kind, "protocol");
    assert!(e.message.contains("connection capacity"), "{}", e.message);
    let mut rest = String::new();
    r2.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "shed socket closes after the error line");

    // The occupant is unaffected.
    writeln!(writer, "{{\"id\":2,\"op\":\"session\"}}").unwrap();
    writer.flush().unwrap();
    assert!(read_response(&mut reader).result.is_ok());

    server.shutdown();
    let st = server.stats();
    assert_eq!(st.connections, 1, "sheds are not counted as served connections");
    assert_eq!(st.shed_connections, 1);
}

#[test]
fn inflight_cap_sheds_requests_but_keeps_the_connection() {
    let session = Arc::new(Qappa::builder().backend(BackendChoice::Native).build());
    let opts = TransportOptions {
        dispatch: DispatchOptions { max_inflight: 0, coalesce: true },
        ..TransportOptions::default()
    };
    let mut server = bind(session, opts);
    let (mut writer, mut reader) = client(&server);

    for id in 1..=3u64 {
        writeln!(writer, "{{\"id\":{id},\"op\":\"workloads\"}}").unwrap();
        writer.flush().unwrap();
        let resp = read_response(&mut reader);
        assert_eq!(resp.id, Some(id), "shed responses still correlate by id");
        let e = resp.result.unwrap_err();
        assert_eq!(e.kind, "protocol");
        assert!(e.message.contains("at capacity"), "{}", e.message);
    }

    server.shutdown();
    let st = server.stats();
    assert_eq!(st.dispatch.shed, 3);
    assert_eq!(st.dispatch.ok, 0);
    assert_eq!(st.connections, 1, "request shedding never drops the connection");
}
