//! Golden snapshot tests for the four legacy PE presets.
//!
//! The parameterized-`QuantSpec` refactor must reproduce the closed-enum
//! era bit-for-bit.  Two golden layers pin that:
//!
//! * `golden/presets_expected.json` — **checked in**, integer-exact
//!   expectations (preset spec table, MAC datapath gate counts / critical
//!   paths / pipeline depths, built-in workload MAC totals) independently
//!   derived from the documented model, so a drift in either the spec
//!   table or the generic datapath builders fails loudly;
//! * `golden/ppa_presets.json` and `golden/dse_tiny_summary.csv` —
//!   **blessed snapshots** of the full floating-point PPA / DSE report
//!   surface.  Missing files are written from the current build (and the
//!   test passes with a notice); present files must match byte-for-byte.
//!   Set `QAPPA_BLESS=1` to re-bless after a deliberate model change.

use std::path::PathBuf;

use qappa::config::{AcceleratorConfig, ALL_PE_TYPES};
use qappa::coordinator::report::dse_summary_table;
use qappa::coordinator::{run_dse, DseOptions};
use qappa::dataflow::Layer;
use qappa::model::native::NativeBackend;
use qappa::model::CvConfig;
use qappa::synth::gates::GateLib;
use qappa::synth::mac::mac_unit;
use qappa::synth::{synthesize, synthesize_clean};
use qappa::util::json::{obj, Json};
use qappa::workloads;

/// Locate the golden directory relative to the crate manifest (the repo
/// layout keeps integration tests under `rust/tests/`).
fn golden_dir() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for candidate in [manifest.join("rust/tests/golden"), manifest.join("tests/golden")] {
        if candidate.exists() {
            return candidate;
        }
    }
    // First run in a layout without the checked-in dir: create next to the
    // manifest so blessed snapshots have a stable home.
    let dir = manifest.join("rust/tests/golden");
    std::fs::create_dir_all(&dir).expect("create golden dir");
    dir
}

fn load_golden(name: &str) -> Option<Json> {
    let path = golden_dir().join(name);
    let text = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&text).unwrap_or_else(|e| panic!("golden {name}: {e}")))
}

/// Bless-or-compare a text snapshot: write when absent (or QAPPA_BLESS=1),
/// byte-compare otherwise.
fn bless_or_compare(name: &str, current: &str) {
    let path = golden_dir().join(name);
    let bless = std::env::var_os("QAPPA_BLESS").is_some() || !path.exists();
    if bless {
        std::fs::write(&path, current).expect("write golden snapshot");
        eprintln!("[golden] blessed {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        current,
        expected,
        "golden snapshot {name} drifted; rerun with QAPPA_BLESS=1 only for a deliberate model change"
    );
}

#[test]
fn preset_spec_table_and_mac_datapaths_match_checked_in_golden() {
    let golden = load_golden("presets_expected.json")
        .expect("checked-in golden presets_expected.json must exist");
    let lib = GateLib::freepdk45();
    let gate_fields = [
        "inv", "nand2", "nor2", "and2", "or2", "xor2", "mux2", "fa", "ha", "dff",
    ];
    for ty in ALL_PE_TYPES {
        let label = ty.label();
        let want = golden.get("presets").get(&label);
        assert!(want.as_obj().is_some(), "golden entry for {label}");
        let q = ty.spec();
        assert_eq!(q.act_bits as usize, want.get("act_bits").as_usize().unwrap(), "{label} act");
        assert_eq!(q.wt_bits as usize, want.get("wt_bits").as_usize().unwrap(), "{label} wt");
        assert_eq!(q.psum_bits as usize, want.get("psum_bits").as_usize().unwrap(), "{label} psum");
        assert_eq!(
            q.shift_terms() as usize,
            want.get("shift_terms").as_usize().unwrap(),
            "{label} terms"
        );

        let mac = mac_unit(&lib, ty);
        // Critical paths are integer-valued (sums of integer cell delays),
        // so exact equality is the right assertion.
        assert_eq!(
            mac.crit_path_ps,
            want.get("crit_path_ps").as_usize().unwrap() as f64,
            "{label} critical path"
        );
        assert_eq!(
            mac.pipeline_stages as usize,
            want.get("pipeline_stages").as_usize().unwrap(),
            "{label} pipeline depth"
        );
        let got = [
            mac.counts.inv,
            mac.counts.nand2,
            mac.counts.nor2,
            mac.counts.and2,
            mac.counts.or2,
            mac.counts.xor2,
            mac.counts.mux2,
            mac.counts.fa,
            mac.counts.ha,
            mac.counts.dff,
        ];
        for (field, g) in gate_fields.iter().zip(got) {
            let w = want.get("gates").get(field).as_usize().unwrap_or(0) as u64;
            assert_eq!(g, w, "{label} gate count '{field}'");
        }
    }
}

#[test]
fn builtin_workload_mac_totals_match_checked_in_golden() {
    let golden = load_golden("presets_expected.json")
        .expect("checked-in golden presets_expected.json must exist");
    for name in workloads::WORKLOAD_NAMES {
        let macs: u64 = workloads::by_name(name).unwrap().iter().map(|l| l.macs()).sum();
        let want = golden.get("workload_macs").get(name).as_usize().unwrap() as u64;
        assert_eq!(macs, want, "{name} MAC total drifted");
    }
}

#[test]
fn golden_preset_ppa_snapshot_is_stable() {
    // Full floating-point PPA surface of `qappa synth` for each preset at
    // the default config: jittered and jitter-free triples, serialized
    // with shortest-round-trip f64 formatting so byte equality == bit
    // equality.
    let mut entries = Vec::new();
    for ty in ALL_PE_TYPES {
        let cfg = AcceleratorConfig::default_with(ty);
        let noisy = synthesize(&cfg);
        let clean = synthesize_clean(&cfg);
        entries.push((
            ty.label(),
            obj(vec![
                ("config", Json::Str(cfg.key())),
                (
                    "synthesized",
                    obj(vec![
                        ("power_mw", Json::Num(noisy.power_mw)),
                        ("fmax_mhz", Json::Num(noisy.fmax_mhz)),
                        ("area_mm2", Json::Num(noisy.area_mm2)),
                    ]),
                ),
                (
                    "jitter_free",
                    obj(vec![
                        ("power_mw", Json::Num(clean.power_mw)),
                        ("fmax_mhz", Json::Num(clean.fmax_mhz)),
                        ("area_mm2", Json::Num(clean.area_mm2)),
                    ]),
                ),
            ]),
        ));
    }
    let snapshot = obj(entries.iter().map(|(k, v)| (k.as_str(), v.clone())).collect()).to_string();
    bless_or_compare("ppa_presets.json", &snapshot);
}

#[test]
fn golden_tiny_dse_summary_snapshot_is_stable() {
    // End-to-end pipeline golden: train -> sweep -> ratios -> report for a
    // small deterministic run; pins the whole `explore` surface (model
    // selection, sweep order, tie-breaks, anchor choice, table rendering).
    let backend = NativeBackend::new(7);
    let opts = DseOptions {
        space: qappa::coordinator::DesignSpace::tiny(),
        train_per_type: 64,
        cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
        seed: 7,
        workers: 4,
        sigma: 0.02,
        chunk: 16,
        topk: 8,
    };
    let layers = vec![
        Layer::conv("c1", 3, 16, 32, 32, 3, 1, 1),
        Layer::conv("c2", 16, 32, 16, 16, 3, 1, 1),
        Layer::fc("fc", 512, 10),
    ];
    let res = run_dse(&backend, &layers, "golden-tiny", &opts).expect("tiny dse");
    bless_or_compare("dse_tiny_summary.csv", &dse_summary_table(&res).to_csv());
}
