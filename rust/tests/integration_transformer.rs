//! Integration: the transformer/LLM workload subsystem.
//!
//! Pins the serving-phase physics end to end: decode is bandwidth-bound
//! (arithmetic intensity far below prefill's), KV-cache DRAM traffic grows
//! linearly in context length, and the composed `both` phase equals
//! prefill plus `ctx` decode steps at the session layer.  The wire tests
//! pin serve/session identity for phased `analyze` and `optimize`
//! requests, including a seeded decode-phase NSGA-II smoke whose frontier
//! report must be byte-identical across the typed call, a repeat run, and
//! the serve path — all off one trained model.

use qappa::api::{
    handle_line, AnalyzeRequest, BackendChoice, OptimizeRequest, Qappa, ResponseBody,
};
use qappa::config::{AcceleratorConfig, PeType, QuantSpec};
use qappa::coordinator::report::opt_frontier_table;
use qappa::coordinator::DesignSpace;
use qappa::dataflow::{evaluate_network, NetworkCost};
use qappa::model::CvConfig;
use qappa::synth::oracle::energy_params;
use qappa::workloads::{self, shape_for_phase, Phase};

#[test]
fn decode_is_bandwidth_bound_and_kv_traffic_is_linear_in_context() {
    let cfg = AcceleratorConfig::default_with(PeType::Int16);
    let ep = energy_params(&cfg);
    let base = workloads::opt_1p3b();
    let ai = |c: &NetworkCost| c.macs as f64 / c.dram_bytes.max(1) as f64;

    let pre = evaluate_network(&cfg, &ep, &shape_for_phase(&base, Phase::Prefill, 1024));
    let dec = evaluate_network(&cfg, &ep, &shape_for_phase(&base, Phase::Decode, 1024));
    assert!(
        ai(&dec) * 8.0 < ai(&pre),
        "decode AI {:.3} not well below prefill AI {:.3}",
        ai(&dec),
        ai(&pre)
    );
    assert!(dec.dram_kv_bytes > 0, "decode must stream the KV cache");
    assert!(pre.dram_kv_bytes > 0, "prefill attention reads the cache it builds");
    assert!(
        dec.dram_kv_bytes <= dec.dram_bytes,
        "KV traffic is a subset of total DRAM traffic"
    );

    // One decode step streams the whole cache, so KV bytes are exactly
    // proportional to context length.
    let kv = |ctx: u32| {
        evaluate_network(&cfg, &ep, &shape_for_phase(&base, Phase::Decode, ctx)).dram_kv_bytes
    };
    let base_kv = kv(512);
    assert!(base_kv > 0);
    assert_eq!(kv(1024), 2 * base_kv, "KV bytes must double with context");
    assert_eq!(kv(2048), 4 * base_kv, "KV bytes must scale linearly with context");
}

#[test]
fn transformer_workloads_roundtrip_through_workload_json() {
    for name in ["opt-1.3b", "llama2-7b"] {
        let (canon, layers) = workloads::load(name).unwrap();
        let text = workloads::to_json(&canon, &layers).to_string();
        let (name2, parsed) = workloads::from_json(&text).unwrap();
        assert_eq!(name2, canon);
        assert_eq!(parsed, layers, "{name} JSON round trip");
    }

    // per-layer precision overrides survive the round trip on
    // matmul/attention layers exactly as on conv layers
    let tagged: Vec<qappa::dataflow::Layer> = workloads::opt_1p3b()
        .into_iter()
        .map(|l| l.with_precision(QuantSpec::int(4, 4)))
        .collect();
    let text = workloads::to_json("tagged", &tagged).to_string();
    let (_, parsed) = workloads::from_json(&text).unwrap();
    assert_eq!(parsed, tagged);
}

#[test]
fn phased_analyze_composes_and_matches_over_the_serve_wire() {
    let session = Qappa::builder().build();
    let req = |phase: &str| AnalyzeRequest {
        workload: "opt-1.3b".into(),
        config: AcceleratorConfig::default_with(PeType::Int16),
        phase: Some(phase.into()),
        ctx: Some(512),
        accuracy: None,
    };

    let both = session.analyze(&req("both")).unwrap();
    let p = both.phase.as_ref().expect("phased request must return a phase summary");
    assert_eq!((p.phase.as_str(), p.ctx), ("both", 512));
    assert!(p.kv_dram_bytes > 0);
    let lat = p.prefill_latency_s + 512.0 * p.decode_latency_s;
    let en = p.prefill_energy_mj + 512.0 * p.decode_energy_mj;
    assert!(
        (p.total_latency_s - lat).abs() <= 1e-12 * lat,
        "both latency {} != prefill + ctx*decode {lat}",
        p.total_latency_s
    );
    assert!(
        (p.total_energy_mj - en).abs() <= 1e-12 * en,
        "both energy {} != prefill + ctx*decode {en}",
        p.total_energy_mj
    );
    // decode rows carry KV bytes on the wire type
    let dec = session.analyze(&req("decode")).unwrap();
    assert!(dec.layers.iter().any(|l| l.kv_bytes.is_some()));

    // the identical request over the serve wire, same session
    let line = format!(r#"{{"id":3,"op":"analyze","params":{}}}"#, req("both").to_json());
    let resp = handle_line(&session, &line);
    assert_eq!(resp.id, Some(3));
    match resp.result {
        Ok(ResponseBody::Analyze(wire)) => {
            assert_eq!(wire, both, "serve and session must agree")
        }
        other => panic!("expected an analyze response, got {other:?}"),
    }
}

#[test]
fn seeded_decode_optimize_is_deterministic_across_session_and_serve() {
    let session = Qappa::builder()
        .backend(BackendChoice::Native)
        .space(DesignSpace::tiny())
        .train_per_type(64)
        .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
        .seed(7)
        .workers(4)
        .sigma(0.02)
        .chunk(32)
        .topk(8)
        .build();
    let req = OptimizeRequest {
        workload: "opt-1.3b".into(),
        objectives: vec!["latency".into(), "energy".into()],
        budget: Some(40),
        pop: Some(12),
        seed: Some(9),
        phase: Some("decode".into()),
        ctx: Some(256),
        ..Default::default()
    };
    let typed = session.optimize(&req).unwrap();
    assert!(!typed.frontier.is_empty());

    // same seed, same session: bit-identical response
    let again = session.optimize(&req).unwrap();
    assert_eq!(again, typed, "same seed must reproduce the decode frontier");

    // the same request over the serve wire
    let line = format!(r#"{{"id":8,"op":"optimize","params":{}}}"#, req.to_json());
    let resp = handle_line(&session, &line);
    assert_eq!(resp.id, Some(8));
    let wire = match resp.result {
        Ok(ResponseBody::Optimize(r)) => r,
        other => panic!("expected an optimize response, got {other:?}"),
    };
    assert_eq!(wire, typed, "serve and session must agree for identical seeds");
    assert_eq!(
        opt_frontier_table(&wire).to_csv(),
        opt_frontier_table(&typed).to_csv(),
        "frontier report must be byte-identical either way"
    );
    // one unified model across all three runs
    assert_eq!(session.store().misses(), 1);

    // gating: `both` has no single evaluable shape; CNNs take no phase
    let both = OptimizeRequest { phase: Some("both".into()), ..req.clone() };
    assert_eq!(session.optimize(&both).unwrap_err().kind(), "config");
    let cnn = OptimizeRequest { workload: "mobilenetv1".into(), ..req.clone() };
    assert_eq!(session.optimize(&cnn).unwrap_err().kind(), "workload");
    assert_eq!(session.store().misses(), 1, "rejected requests never train");
}
