//! Integration: the unified telemetry subsystem — registry concurrency,
//! histogram quantiles against the exact oracle, and the `metrics` wire op
//! round-tripping over both transports (stdio serve loop and TCP).
//!
//! The metrics registry is process-wide and tests in one binary share it,
//! so every assertion here is presence / monotonicity / `>=`, never exact
//! equality against a global counter.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use qappa::api::{
    serve, BackendChoice, MetricsSnapshot, Qappa, ResponseBody, ServeOptions, ServeRequest,
    ServeResponse, TcpServer, TransportOptions,
};
use qappa::coordinator::{DesignSpace, DseOptions};
use qappa::model::CvConfig;
use qappa::obs::{registry, MetricsRegistry};
use qappa::util::json::Json;
use qappa::util::stats::percentile;

fn tiny_session() -> Qappa {
    Qappa::builder()
        .backend(BackendChoice::Native)
        .options(DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk: 32,
            topk: 8,
        })
        .build()
}

// ---------------------------------------------------------------- registry

#[test]
fn parallel_increments_land_exactly_once_each() {
    let reg = MetricsRegistry::new();
    const THREADS: usize = 8;
    const PER: usize = 10_000;
    std::thread::scope(|scope| {
        for _ in 0..THREADS {
            let c = reg.counter("t.parallel");
            let g = reg.gauge("t.updown");
            let h = reg.histogram("t.lat");
            scope.spawn(move || {
                for i in 0..PER {
                    c.inc();
                    g.add(1.0);
                    g.add(-1.0);
                    h.record_ms(0.5 + (i % 100) as f64 * 0.01);
                }
            });
        }
    });
    let snap = reg.snapshot();
    assert_eq!(snap.counters["t.parallel"], (THREADS * PER) as u64);
    assert_eq!(snap.gauges["t.updown"], 0.0, "balanced up/down nets to zero");
    let h = &snap.histograms["t.lat"];
    assert_eq!(h.count, (THREADS * PER) as u64);
    assert!(h.p50_ms > 0.0 && h.p50_ms <= h.max_ms);
}

#[test]
fn concurrent_snapshots_stay_consistent_and_monotone() {
    let reg = MetricsRegistry::new();
    let c = reg.counter("t.mono");
    let h = reg.histogram("t.mono_ms");
    std::thread::scope(|scope| {
        let writer = {
            let c = c.clone();
            let h = h.clone();
            scope.spawn(move || {
                for i in 0..50_000u64 {
                    c.inc();
                    h.record_ms(1.0 + (i % 7) as f64);
                }
            })
        };
        let mut last = 0u64;
        let mut last_h = 0u64;
        while !writer.is_finished() {
            let snap = reg.snapshot();
            let now = snap.counters["t.mono"];
            assert!(now >= last, "counter snapshots must be monotone ({now} < {last})");
            last = now;
            let hs = &snap.histograms["t.mono_ms"];
            assert!(hs.count >= last_h, "histogram counts must be monotone");
            last_h = hs.count;
            // Internal consistency under concurrent recording: quantiles
            // are computed from the same bucket copy as the count, so an
            // in-range count implies in-range quantiles.
            if hs.count > 0 {
                assert!(hs.p50_ms <= hs.p95_ms && hs.p95_ms <= hs.p99_ms);
                assert!(hs.p99_ms <= hs.max_ms + 1e-9);
            }
        }
    });
    let snap = reg.snapshot();
    assert_eq!(snap.counters["t.mono"], 50_000);
    assert_eq!(snap.histograms["t.mono_ms"].count, 50_000);
}

#[test]
fn histogram_quantiles_match_the_sorted_oracle_on_known_shapes() {
    // Three distributions: uniform, geometric-ish spread, heavy tail.
    let shapes: Vec<Vec<f64>> = vec![
        (1..=500).map(|i| i as f64 * 0.2).collect(),
        (0..400).map(|i| 0.05 * 1.02f64.powi(i)).collect(),
        // Heavy tail: 2% of samples 17x above the body.  The tail mass is
        // deliberately below 1-p for every pinned quantile: a rank that
        // falls *in the gap* between body and tail is interpolated across
        // the cliff by the exact oracle, which no bucketed histogram can
        // reproduce (p50/p95 land in the body, p99 inside the tail).
        {
            let mut xs: Vec<f64> = (1..=980).map(|i| 1.0 + i as f64 * 0.002).collect();
            xs.extend((1..=20).map(|i| 50.0 + i as f64));
            xs
        },
    ];
    let reg = MetricsRegistry::new();
    for (n, xs) in shapes.iter().enumerate() {
        let h = reg.histogram(&format!("t.shape{n}"));
        for &x in xs {
            h.record_ms(x);
        }
        let s = h.summary();
        assert_eq!(s.count, xs.len() as u64);
        for (est, p) in [(s.p50_ms, 50.0), (s.p95_ms, 95.0), (s.p99_ms, 99.0)] {
            let exact = percentile(xs, p);
            assert!(
                (est - exact).abs() / exact < 0.10,
                "shape {n} p{p}: histogram {est} vs exact {exact}"
            );
        }
        let exact_max = xs.iter().cloned().fold(0.0, f64::max);
        assert_eq!(s.max_ms, exact_max, "shape {n}: max is exact");
    }
}

// ----------------------------------------------------------- wire op: stdio

/// The stable snapshot JSON shape: `counters` / `gauges` / `histograms`
/// objects, each histogram carrying
/// `count`/`mean_ms`/`p50_ms`/`p95_ms`/`p99_ms`/`max_ms`.
fn assert_snapshot_shape(v: &Json) {
    for section in ["counters", "gauges", "histograms"] {
        assert!(v.get(section).as_obj().is_some(), "snapshot must carry \"{section}\"");
    }
    for (name, h) in v.get("histograms").as_obj().unwrap() {
        for field in ["count", "mean_ms", "p50_ms", "p95_ms", "p99_ms", "max_ms"] {
            assert!(
                h.get(field).as_f64().is_some(),
                "histogram {name} must carry \"{field}\""
            );
        }
    }
}

#[test]
fn metrics_op_round_trips_over_the_stdio_loop() {
    let session = tiny_session();
    let input = concat!(
        r#"{"id":1,"op":"explore","params":{"workloads":["vgg16"]}}"#, "\n",
        r#"{"id":2,"op":"metrics"}"#, "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve(&session, input.as_bytes(), &mut out, &ServeOptions { concurrency: 1 }).unwrap();
    assert_eq!((stats.requests, stats.ok, stats.errors), (2, 2, 0));

    // Zero stdout pollution: the output stream is exactly two JSON lines.
    let text = std::str::from_utf8(&out).unwrap();
    assert_eq!(text.lines().count(), 2);
    for line in text.lines() {
        assert!(line.starts_with('{'), "serve output must be pure JSON lines: {line:?}");
        Json::parse(line).expect("every output line parses as JSON");
    }

    let metrics_line = text.lines().nth(1).unwrap();
    let v = Json::parse(metrics_line).unwrap();
    assert_eq!(v.get("ok").as_bool(), Some(true));
    assert_eq!(v.get("op").as_str(), Some("metrics"));
    assert_snapshot_shape(v.get("result"));

    // Typed round-trip, and the explore that just ran is visible.
    let resp = ServeResponse::from_json(&v).unwrap();
    let snap = match resp.result {
        Ok(ResponseBody::Metrics(s)) => s,
        other => panic!("expected a metrics response, got {other:?}"),
    };
    assert!(snap.counters.get("session.ops.explore").copied().unwrap_or(0) >= 1);
    assert!(snap.counters.get("session.ops.metrics").copied().unwrap_or(0) >= 1);
    assert!(snap.counters.get("sweep.shards").copied().unwrap_or(0) >= 1);
    assert!(snap.counters.get("store.models_trained").copied().unwrap_or(0) >= 1);
    assert!(snap.histograms.contains_key("sweep.shard_ms"));
    assert!(snap.histograms.contains_key("store.train_ms"));

    // The snapshot also survives a full JSON round-trip byte-for-byte.
    let rt = MetricsSnapshot::from_json(&Json::parse(&snap.to_json().to_string()).unwrap());
    assert_eq!(rt.unwrap(), snap);
}

// ------------------------------------------------------------- wire op: TCP

#[test]
fn metrics_op_round_trips_over_tcp() {
    let session = Arc::new(tiny_session());
    let mut server =
        TcpServer::bind(session, "127.0.0.1:0", TransportOptions::default()).unwrap();
    let mut client = TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(client.try_clone().unwrap());

    let mut round_trip = |line: &str| -> ServeResponse {
        writeln!(client, "{line}").unwrap();
        client.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        ServeResponse::from_json(&Json::parse(&resp).unwrap()).unwrap()
    };

    // Drive one real request first so serve.* instruments exist.
    let r = round_trip(r#"{"id":1,"op":"workloads"}"#);
    assert!(r.result.is_ok());

    let r = round_trip(r#"{"id":2,"op":"metrics"}"#);
    assert_eq!(r.id, Some(2));
    let snap = match r.result {
        Ok(ResponseBody::Metrics(s)) => s,
        other => panic!("expected a metrics response, got {other:?}"),
    };
    assert!(snap.counters.get("serve.requests").copied().unwrap_or(0) >= 1);
    assert!(snap.counters.get("serve.ok").copied().unwrap_or(0) >= 1);
    assert!(snap.counters.get("serve.connections").copied().unwrap_or(0) >= 1);
    assert!(snap.gauges.contains_key("serve.inflight"));
    let lat = snap.histograms.get("serve.request_ms").expect("request latency histogram");
    assert!(lat.count >= 1 && lat.p50_ms <= lat.max_ms);

    // A second scrape is monotone in the request counter.
    let before = snap.counters["serve.requests"];
    let r = round_trip(r#"{"id":3,"op":"metrics"}"#);
    match r.result {
        Ok(ResponseBody::Metrics(s)) => {
            assert!(s.counters["serve.requests"] > before, "scrapes see newer requests")
        }
        other => panic!("expected a metrics response, got {other:?}"),
    }

    drop(client);
    drop(reader);
    server.shutdown();
}

// ------------------------------------------------------- request round-trip

#[test]
fn metrics_request_json_round_trips() {
    let line = r#"{"id":9,"op":"metrics"}"#;
    let req = ServeRequest::from_json(&Json::parse(line).unwrap()).unwrap();
    assert_eq!(req.id, Some(9));
    assert_eq!(req.body.op(), "metrics");
    let re = ServeRequest::from_json(&Json::parse(&req.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(re.body.op(), "metrics");

    // The registry handle the op reads is the process-wide singleton.
    let before = registry().snapshot();
    registry().counter("t.wire_probe").inc();
    let after = registry().snapshot();
    assert_eq!(
        after.counters["t.wire_probe"],
        before.counters.get("t.wire_probe").copied().unwrap_or(0) + 1
    );
}
