//! Equivalence suite: the SoA fast path versus the per-point oracle.
//!
//! The evaluation hot path was restructured (structure-of-arrays batch
//! prediction, memoized synthesis, sweep-wide layer-cost memo) with one
//! invariant: **bit-identical results**.  The legacy per-point path is kept
//! precisely so these tests can compare against it — via the programmatic
//! `SweepEngine::legacy` / `OptOptions::legacy_eval` switches (the
//! `QAPPA_LEGACY_EVAL` env serves the same role at the process boundary,
//! pinned in `tests/integration_cli.rs`).
//!
//! Coverage, per the refactor's acceptance list:
//! * batch predict: SoA recipe-grouped vs the legacy flat slab, on
//!   mixed-recipe config lists (all presets + random `QuantSpec`s);
//! * the sweep engine across chunk sizes {1, 7, 256, 4096}, over a
//!   precision-extended grid and mixed per-layer precision workloads;
//! * the guided optimizer under every strategy's default entry point.

use qappa::config::{PeType, QuantSpec, ALL_PE_TYPES, QUANT_NUM_FEATURES};
use qappa::coordinator::sweep::{
    predict_configs_legacy, predict_configs_soa, NamedWorkload, SweepEngine, TypeSweep,
};
use qappa::coordinator::{DesignSpace, DseOptions, ModelStore};
use qappa::dataflow::Layer;
use qappa::model::native::NativeBackend;
use qappa::model::CvConfig;
use qappa::opt::{
    run_optimize, Constraints, Objective, OptOptions, OptProblem, SearchSpace, StrategyKind,
};
use qappa::testkit::{gen_config, gen_quant_spec};
use qappa::util::prng::Rng;

fn opts_with(chunk: usize) -> DseOptions {
    DseOptions {
        space: DesignSpace::tiny(),
        train_per_type: 64,
        cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
        seed: 7,
        workers: 4,
        sigma: 0.02,
        chunk,
        topk: 8,
    }
}

/// A small net with mixed per-layer precision: one full-precision conv, a
/// repeat of its shape pinned to int4/8 (exercising the mixed-precision
/// override branch of the prepared evaluator), and a depthwise layer.
fn mixed_net() -> Vec<Layer> {
    vec![
        Layer::conv("c0", 8, 16, 16, 16, 3, 1, 1),
        Layer::conv("c1", 8, 16, 16, 16, 3, 1, 1).with_precision(QuantSpec::int(4, 8)),
        Layer::dw("dw", 16, 16, 3, 1, 1),
    ]
}

#[test]
fn soa_predict_is_bit_identical_to_the_legacy_slab_on_mixed_recipes() {
    let backend = NativeBackend::new(QUANT_NUM_FEATURES);
    let opts = opts_with(64);
    let store = ModelStore::new();
    let palette = ALL_PE_TYPES.to_vec();
    let model = store.get_or_train_quant(&backend, &opts, &palette).unwrap();

    // Interleave preset-recipe configs with arbitrary-precision ones so the
    // SoA grouping actually has to gather and scatter across recipes.
    let mut rng = Rng::new(33);
    let mut cfgs = Vec::new();
    for i in 0..96usize {
        let mut c = gen_config(&mut rng);
        if i % 3 == 0 {
            c.pe_type = PeType::from_spec(gen_quant_spec(&mut rng));
        }
        cfgs.push(c);
    }

    let soa = predict_configs_soa(&backend, &model, &cfgs).unwrap();
    let legacy = predict_configs_legacy(&backend, &model, &cfgs).unwrap();
    assert_eq!(soa.len(), legacy.len());
    for (i, (a, b)) in soa.iter().zip(&legacy).enumerate() {
        assert_eq!(a.power_mw.to_bits(), b.power_mw.to_bits(), "power_mw row {i}");
        assert_eq!(a.fmax_mhz.to_bits(), b.fmax_mhz.to_bits(), "fmax_mhz row {i}");
        assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits(), "area_mm2 row {i}");
    }
}

/// Canonical bit-level rendering of a sweep result, used both to compare
/// fast-vs-oracle and to pin chunk-size invariance.
fn render(sweeps: &[TypeSweep]) -> String {
    let mut s = String::new();
    for ts in sweeps {
        s.push_str(&format!("== {} ==\n", ts.workload));
        for (i, p) in ts.points.as_ref().expect("retain_all").iter().enumerate() {
            s.push_str(&format!(
                "{i} {} {:016x} {:016x} {:016x} {:016x} {:016x} {:016x} {:016x}\n",
                p.cfg.key(),
                p.ppa.power_mw.to_bits(),
                p.ppa.fmax_mhz.to_bits(),
                p.ppa.area_mm2.to_bits(),
                p.throughput.to_bits(),
                p.perf_per_area.to_bits(),
                p.energy_mj.to_bits(),
                p.utilization.to_bits(),
            ));
        }
        s.push_str(&format!("frontier {:?}\n", ts.frontier_indices()));
        for p in ts.top_perf_per_area.iter().chain(&ts.top_energy) {
            s.push_str(&format!("top {}\n", p.cfg.key()));
        }
    }
    s
}

#[test]
fn sweep_fast_path_matches_the_per_point_oracle_across_chunk_sizes() {
    let backend = NativeBackend::new(QUANT_NUM_FEATURES);
    let palette = ALL_PE_TYPES.to_vec();
    let store = ModelStore::new();
    let model = store.get_or_train_quant(&backend, &opts_with(64), &palette).unwrap();

    // Precision-extended grid: the four presets plus two random (but
    // seed-fixed) arbitrary-precision recipes.
    let mut rng = Rng::new(7);
    let mut quants = palette.clone();
    quants.push(PeType::from_spec(gen_quant_spec(&mut rng)));
    quants.push(PeType::from_spec(gen_quant_spec(&mut rng)));

    // Two workloads sharing a layer shape, so the sweep-wide layer-cost
    // memo crosses workload boundaries; the first mixes per-layer precision.
    let wls = vec![
        NamedWorkload::new("mixed", mixed_net()),
        NamedWorkload::new("plain", vec![Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)]),
    ];

    let mut canonical: Option<String> = None;
    for chunk in [1usize, 7, 256, 4096] {
        let mut opts = opts_with(chunk);
        opts.space = DesignSpace::tiny().with_quants(quants.clone());

        let fast_engine = SweepEngine::new(&backend, &opts).retain_all(true);
        let fast = fast_engine.sweep_type(&model, PeType::Int16, &wls).unwrap();
        let memo = fast_engine.memo_stats();
        let slow_engine =
            SweepEngine::new(&backend, &opts).retain_all(true).legacy(true);
        let slow = slow_engine.sweep_type(&model, PeType::Int16, &wls).unwrap();

        assert_eq!(
            render(&fast),
            render(&slow),
            "fast path diverged from the per-point oracle at chunk={chunk}"
        );
        // The fast path actually ran memoized (and the oracle did not).
        assert!(memo.synth_hits + memo.synth_misses > 0, "synth memo never consulted");
        assert!(memo.cost_hits > 0, "layer-cost memo never hit across workloads");
        assert_eq!(slow_engine.memo_stats(), Default::default());

        // Chunking is a performance knob only: every chunk size must
        // produce the same bits.
        match &canonical {
            None => canonical = Some(render(&fast)),
            Some(c) => assert_eq!(c, &render(&fast), "results changed at chunk={chunk}"),
        }
    }
}

#[test]
fn optimizer_fast_path_matches_the_per_point_oracle_for_every_strategy() {
    let backend = NativeBackend::new(QUANT_NUM_FEATURES);
    let opts = opts_with(64);
    let store = ModelStore::new();
    let palette = ALL_PE_TYPES.to_vec();
    let model = store.get_or_train_quant(&backend, &opts, &palette).unwrap();
    let layers = mixed_net();

    for kind in [StrategyKind::Nsga2, StrategyKind::Random, StrategyKind::HillClimb] {
        let run = |legacy_eval: bool| {
            let search =
                SearchSpace::new(&opts.space, palette.clone(), &layers, true).unwrap();
            let problem = OptProblem {
                search,
                objectives: vec![Objective::PerfPerArea, Objective::Energy],
                constraints: Constraints::default(),
                accuracy: None,
            };
            let oopts = OptOptions {
                strategy: kind,
                budget: 60,
                pop: 16,
                seed: 5,
                legacy_eval,
                ..Default::default()
            };
            run_optimize(&backend, &model, &problem, &oopts, opts.workers).unwrap()
        };
        let fast = run(false);
        let slow = run(true);
        assert_eq!(fast.evaluated, slow.evaluated, "{kind:?}");
        assert_eq!(
            fast.hypervolume.to_bits(),
            slow.hypervolume.to_bits(),
            "{kind:?} hypervolume diverged"
        );
        let sig = |r: &qappa::opt::OptResult| -> Vec<String> {
            r.frontier
                .iter()
                .map(|f| {
                    format!(
                        "{}|{:x}|{:x}|{:?}|{}",
                        f.point.cfg.key(),
                        f.objs[0].to_bits(),
                        f.objs[1].to_bits(),
                        f.genome.hw,
                        f.precision.join(",")
                    )
                })
                .collect()
        };
        assert_eq!(sig(&fast), sig(&slow), "{kind:?} frontier diverged");
        assert!(
            fast.memo.synth_hits + fast.memo.synth_misses > 0,
            "{kind:?}: fast path never consulted the synth memo"
        );
        assert_eq!(slow.memo, Default::default(), "{kind:?}: oracle must not memoize");
    }
}
