//! Precision-axis property and round-trip tests.
//!
//! Pins the quantization-parameterization invariants across subsystems:
//! monotonicity of synthesized cost in every bit-width axis, accumulator
//! validity enforcement at each boundary, streaming/serial equivalence of
//! precision-grid sweeps at any chunk size, and per-layer precision
//! overrides surviving the workload-JSON -> API -> report path.

use qappa::api::{config_from_json, Qappa, WorkloadsRequest, WorkloadsResponse};
use qappa::config::{
    AcceleratorConfig, MacKind, PeType, QuantSpec, QUANT_NUM_FEATURES,
};
use qappa::coordinator::precision::train_quant_model;
use qappa::coordinator::report::workload_table;
use qappa::coordinator::sweep::{NamedWorkload, SweepEngine};
use qappa::coordinator::{DesignSpace, DseOptions};
use qappa::dataflow::Layer;
use qappa::model::native::NativeBackend;
use qappa::model::CvConfig;
use qappa::synth::gates::GateLib;
use qappa::synth::pe::synthesize_pe;
use qappa::testkit::{forall, gen_quant_spec, gen_u32};
use qappa::util::json::Json;
use qappa::util::prng::Rng;
use qappa::workloads;

/// PE-level cost of a spec at a fixed mid-range geometry: (area um2,
/// energy/MAC fJ, power mW at a fixed 500 MHz reference clock).  Power is
/// compared at a *fixed* clock because each design's own fmax moves with
/// pipeline-stage quantization; the physical monotonicity claim is about
/// hardware cost per operation, not the free-running operating point.
fn pe_cost(spec: QuantSpec) -> (f64, f64, f64) {
    let lib = GateLib::freepdk45();
    let cfg = AcceleratorConfig::default_with(PeType::from_spec(spec));
    let pe = synthesize_pe(&lib, &cfg);
    let area = pe.area_um2(&lib);
    let energy = pe.energy_per_mac_fj(&lib);
    // fJ * MHz = nW; 500 MHz reference.
    let power_mw = (energy * 500.0 + pe.leakage_nw(&lib)) / 1e6;
    (area, energy, power_mw)
}

#[test]
fn prop_area_and_power_monotone_in_every_bit_width_axis() {
    forall(
        "PE area/energy/power non-decreasing per bit-width axis",
        150,
        31,
        |rng: &mut Rng| {
            let spec = gen_quant_spec(rng);
            let axis = rng.below(3);
            let delta = gen_u32(rng, 1, 4);
            (spec, axis, delta)
        },
        |&(spec, axis, delta)| {
            let mut wider = spec;
            match axis {
                0 => wider.act_bits += delta,
                1 => wider.wt_bits += delta,
                _ => wider.psum_bits += delta,
            }
            if wider.validate().is_err() {
                return Ok(()); // stepped out of the valid region; vacuous
            }
            let (a0, e0, p0) = pe_cost(spec);
            let (a1, e1, p1) = pe_cost(wider);
            if a1 < a0 {
                return Err(format!("area {a1} < {a0} ({spec:?} axis {axis} +{delta})"));
            }
            if e1 < e0 {
                return Err(format!("energy {e1} < {e0} ({spec:?} axis {axis} +{delta})"));
            }
            if p1 < p0 {
                return Err(format!("power {p1} < {p0} ({spec:?} axis {axis} +{delta})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_generated_specs_always_satisfy_psum_invariant() {
    forall(
        "generator respects psum >= operands; violations reject",
        200,
        33,
        gen_quant_spec,
        |&spec| {
            spec.validate().map_err(|e| e.to_string())?;
            if spec.psum_bits < spec.act_bits.max(spec.wt_bits) {
                return Err(format!("generator emitted narrow psum: {spec:?}"));
            }
            // shrinking the accumulator below either operand must reject,
            // naming psum_bits
            let mut narrow = spec;
            narrow.psum_bits = spec.act_bits.max(spec.wt_bits).saturating_sub(1);
            if narrow.psum_bits > 0 {
                match narrow.validate() {
                    Ok(()) => return Err(format!("narrow psum accepted: {narrow:?}")),
                    Err(e) => {
                        if !e.to_string().contains("psum_bits") {
                            return Err(format!("error must name psum_bits: {e}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

fn quant_opts(chunk: usize) -> DseOptions {
    DseOptions {
        space: DesignSpace::tiny(),
        train_per_type: 96,
        cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
        seed: 7,
        workers: 4,
        sigma: 0.02,
        chunk,
        topk: 8,
    }
}

#[test]
fn precision_grid_parallel_sweep_matches_serial_at_any_chunk_size() {
    // One combined pass over the quants-axis grid must be bit-identical at
    // every chunk size, and identical to sweeping the cells serially one
    // at a time — the streaming==eager guarantee extended to the
    // precision axis.
    let specs = vec![
        PeType::parse("a4w4p8-int").unwrap(),
        PeType::Int16,
        PeType::parse("a8w8p16-int").unwrap(),
    ];
    let backend = NativeBackend::new(QUANT_NUM_FEATURES);
    let base = quant_opts(0);
    let model = train_quant_model(&backend, &base, &specs).unwrap();
    let wl = vec![NamedWorkload::new("t", vec![Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)])];

    let combined = |chunk: usize| {
        let mut opts = quant_opts(chunk);
        opts.space = DesignSpace::tiny().with_quants(specs.clone());
        SweepEngine::new(&backend, &opts)
            .retain_all(true)
            // the passed type is ignored when the quants axis is set
            .sweep_type(&model, PeType::Fp32, &wl)
            .unwrap()
            .remove(0)
    };
    let reference = combined(0);
    let ref_pa: Vec<f64> = reference
        .points
        .as_ref()
        .unwrap()
        .iter()
        .map(|p| p.perf_per_area)
        .collect();
    assert_eq!(ref_pa.len(), 3 * DesignSpace::tiny().len());
    for chunk in [1usize, 7, 64, 1000] {
        let ts = combined(chunk);
        let pa: Vec<f64> =
            ts.points.as_ref().unwrap().iter().map(|p| p.perf_per_area).collect();
        assert_eq!(pa, ref_pa, "chunk={chunk} point stream diverged");
        assert_eq!(
            ts.frontier_indices(),
            reference.frontier_indices(),
            "chunk={chunk} frontier diverged"
        );
        assert_eq!(
            ts.best_perf_per_area().unwrap().cfg,
            reference.best_perf_per_area().unwrap().cfg
        );
        assert_eq!(ts.best_energy().unwrap().cfg, reference.best_energy().unwrap().cfg);
    }

    // serial: one plain-space sweep per cell, concatenated in grid order
    let serial_opts = quant_opts(16);
    let engine = SweepEngine::new(&backend, &serial_opts).retain_all(true);
    let mut serial_pa = Vec::new();
    for spec in &specs {
        let ts = engine.sweep_type(&model, *spec, &wl).unwrap().remove(0);
        serial_pa.extend(ts.points.as_ref().unwrap().iter().map(|p| p.perf_per_area));
    }
    assert_eq!(serial_pa, ref_pa, "serial per-cell sweep diverged from the combined pass");
}

#[test]
fn pe_type_parse_and_preset_round_trips() {
    // presets: label round trip + case-insensitive aliases
    for ty in qappa::config::ALL_PE_TYPES {
        assert_eq!(PeType::parse(&ty.label()), Some(ty));
        assert_eq!(PeType::parse(&ty.label().to_ascii_lowercase()), Some(ty));
        assert_eq!(PeType::parse(&ty.label().to_ascii_uppercase()), Some(ty));
    }
    for (alias, ty) in [
        ("LIGHTPE-1", PeType::LightPe1),
        ("LightPe2", PeType::LightPe2),
        ("Fp32", PeType::Fp32),
        ("INT16", PeType::Int16),
        ("A16W16P32-INT", PeType::Int16),
        ("a8w4p20-light1", PeType::LightPe1),
    ] {
        assert_eq!(PeType::parse(alias), Some(ty), "{alias}");
    }
    // generic specs round trip through label -> parse -> label
    let q = PeType::parse("a10w6p22-light2").unwrap();
    assert_eq!(q.label(), "a10w6p22-light2");
    assert!(!q.is_preset());

    // unknown names reject at the JSON config boundary with an
    // actionable error naming the value and the accepted grammar
    let bad = Json::parse(r#"{"pe_type": "int99x"}"#).unwrap();
    let e = config_from_json(&bad).unwrap_err();
    assert_eq!(e.kind(), "protocol");
    let msg = e.to_string();
    assert!(msg.contains("int99x"), "{msg}");
    assert!(msg.contains("fp32|int16|lightpe1|lightpe2"), "{msg}");
    assert!(msg.contains("a<act>w<wt>p<psum>"), "{msg}");

    // syntactically-valid but out-of-range specs reject via validate with
    // the offending field named
    let zero = Json::parse(r#"{"pe_type": "a0w4p8-int"}"#).unwrap();
    let e = config_from_json(&zero).unwrap_err();
    assert_eq!(e.kind(), "config");
    assert!(e.to_string().contains("act_bits"), "{e}");
}

#[test]
fn per_layer_overrides_survive_json_api_and_report_round_trips() {
    // Build a mixed-precision model file: INT4 depthwise + LightPE-1 head.
    let mut layers = workloads::mobilenetv2();
    let int4 = QuantSpec::new(4, 4, 12, MacKind::IntExact).unwrap();
    for l in layers.iter_mut().filter(|l| l.is_depthwise()) {
        l.quant = Some(int4);
    }
    let head = layers.len() - 2;
    layers[head].quant = Some(PeType::LightPe1.spec());
    let text = workloads::to_json("mixed-mnv2", &layers).to_string();

    // JSON ingestion preserves every override
    let (name, parsed) = workloads::from_json(&text).unwrap();
    assert_eq!(name, "mixed-mnv2");
    assert_eq!(parsed, layers);

    // API round trip: workloads detail -> wire JSON -> parse -> equal
    let dir = std::env::temp_dir().join(format!("qappa_prec_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mixed-mnv2.json");
    std::fs::write(&path, &text).unwrap();
    let session = Qappa::builder().build();
    let req = WorkloadsRequest { workload: Some(path.to_string_lossy().to_string()) };
    let resp = session.workloads(&req).unwrap();
    let wire = resp.to_json().to_string();
    let back = WorkloadsResponse::from_json(&Json::parse(&wire).unwrap()).unwrap();
    match (&resp, &back) {
        (
            WorkloadsResponse::Detail { layers: a, .. },
            WorkloadsResponse::Detail { layers: b, .. },
        ) => {
            assert_eq!(a, b, "overrides must survive the wire round trip");
            assert_eq!(a, &layers);
        }
        other => panic!("expected detail responses, got {other:?}"),
    }

    // report: the layer table grows a precision column naming the
    // overrides, with '-' for inherit-from-config rows
    let table = workload_table(&parsed).to_csv();
    let header = table.lines().next().unwrap().to_string();
    assert!(header.ends_with("precision"), "{header}");
    assert!(table.contains("a4w4p12-int"), "{table}");
    assert!(table.contains("LightPE-1"), "{table}");
    assert!(table.contains(",-"), "{table}");
    std::fs::remove_file(&path).ok();
}
