//! Integration: the `qappa` binary's stream discipline and CLI/API parity.
//!
//! * Progress/stats lines (`[store]`, `[engine]`, `[trace]`) must go to
//!   stderr so piped stdout stays a parseable report — pinned here by
//!   running `explore` with `QAPPA_TRACE=1` and asserting stdout carries
//!   only report content.
//! * `qappa optimize` is a thin client of the session facade: its stdout
//!   must contain the exact frontier table an equivalent typed
//!   [`Qappa::optimize`] call renders.
//!
//! The binary path comes from `CARGO_BIN_EXE_qappa` (set by cargo for
//! integration tests of a crate with the `qappa` bin target); the tests
//! skip with a notice if the harness doesn't provide it.

use std::process::Command;

use qappa::api::{BackendChoice, OptimizeRequest, Qappa};
use qappa::coordinator::report::opt_frontier_table;
use qappa::coordinator::DesignSpace;

fn qappa_bin() -> Option<&'static str> {
    let bin = option_env!("CARGO_BIN_EXE_qappa");
    if bin.is_none() {
        eprintln!("[skip] CARGO_BIN_EXE_qappa not set; CLI smoke tests need the bin target");
    }
    bin
}

#[test]
fn explore_stdout_stays_parseable_with_progress_on_stderr() {
    let Some(bin) = qappa_bin() else { return };
    // Multi-workload explore on the tiny space: exercises the [store] and
    // [engine] progress lines, with tracing forced on.
    let out = Command::new(bin)
        .args([
            "explore",
            "--workload",
            "examples/tiny_mobilenet.json,mobilenetv1",
            "--space",
            "tiny",
            "--train",
            "48",
            "--backend",
            "native",
        ])
        .env("QAPPA_TRACE", "1")
        .output()
        .expect("run qappa explore");
    assert!(out.status.success(), "explore failed: {out:?}");
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    let stderr = String::from_utf8(out.stderr).expect("utf8 stderr");
    // stdout: report content only — no progress/stats/trace lines
    for marker in ["[store]", "[engine]", "[trace]", "[qappa]"] {
        assert!(
            !stdout.contains(marker),
            "progress marker {marker} leaked into stdout:\n{stdout}"
        );
    }
    // the report itself did land on stdout
    assert!(stdout.contains("perf/area_pred"), "summary table missing:\n{stdout}");
    assert!(stdout.contains("tiny-mobilenet"), "workload rows missing:\n{stdout}");
    // and the progress/tracing went to stderr
    assert!(stderr.contains("[store]"), "stderr lost the store counters:\n{stderr}");
    assert!(stderr.contains("[trace]"), "QAPPA_TRACE output missing from stderr:\n{stderr}");
}

/// Runs `explore` on the tiny space with the given chunk size, optionally
/// forcing the legacy per-point evaluation path, and returns raw stdout.
fn explore_stdout(bin: &str, chunk: &str, legacy: bool) -> Vec<u8> {
    let mut cmd = Command::new(bin);
    cmd.args([
        "explore",
        "--workload",
        "examples/tiny_mobilenet.json,mobilenetv1",
        "--space",
        "tiny",
        "--train",
        "48",
        "--backend",
        "native",
        "--chunk",
        chunk,
    ]);
    if legacy {
        cmd.env("QAPPA_LEGACY_EVAL", "1");
    }
    let out = cmd.output().expect("run qappa explore");
    assert!(out.status.success(), "explore (chunk={chunk}) failed: {out:?}");
    out.stdout
}

#[test]
fn explore_stdout_is_byte_identical_across_chunk_sizes_and_eval_paths() {
    let Some(bin) = qappa_bin() else { return };
    // The stdout report is a function of (workloads, space, seed) only:
    // chunk size and the SoA-vs-legacy evaluation path are performance
    // knobs, and wall-time/chunk diagnostics live on stderr.  Same seed
    // must mean byte-identical stdout.
    let base = explore_stdout(bin, "7", false);
    let chunked = explore_stdout(bin, "256", false);
    assert_eq!(
        base, chunked,
        "explore stdout diverged between --chunk 7 and --chunk 256"
    );
    let legacy = explore_stdout(bin, "7", true);
    assert_eq!(
        base, legacy,
        "explore stdout diverged between the SoA and legacy evaluation paths"
    );
}

#[test]
fn optimize_stdout_is_byte_identical_with_legacy_eval() {
    let Some(bin) = qappa_bin() else { return };
    let run = |legacy: bool| -> Vec<u8> {
        let mut cmd = Command::new(bin);
        cmd.args([
            "optimize",
            "--workload",
            "examples/tiny_mobilenet.json",
            "--space",
            "tiny",
            "--train",
            "48",
            "--budget",
            "60",
            "--pop",
            "16",
            "--backend",
            "native",
            "--precision",
            "int16,a4w4p8-int",
        ]);
        if legacy {
            cmd.env("QAPPA_LEGACY_EVAL", "1");
        }
        let out = cmd.output().expect("run qappa optimize");
        assert!(out.status.success(), "optimize (legacy={legacy}) failed: {out:?}");
        out.stdout
    };
    // The memoized fast path is pinned bit-exact against the per-point
    // oracle at the engine layer (opt::engine tests, tests/integration_soa);
    // this pins the same guarantee end-to-end at the process boundary.
    assert_eq!(
        run(false),
        run(true),
        "optimize stdout diverged between the SoA and legacy evaluation paths"
    );
}

#[test]
fn loadgen_stdout_is_exactly_one_json_report_line() {
    let Some(bin) = qappa_bin() else { return };
    // Self-spawn mode: loadgen binds its own ephemeral TCP server, drives
    // it, and must print exactly one machine-readable report line on
    // stdout — every `[serve]`/`[qappa]` diagnostic belongs to stderr.
    let out = Command::new(bin)
        .args([
            "loadgen",
            "--backend",
            "native",
            "--space",
            "tiny",
            "--train",
            "48",
            "--connections",
            "2",
            "--requests",
            "3",
            "--mix",
            "mixed",
        ])
        .output()
        .expect("run qappa loadgen");
    assert!(out.status.success(), "loadgen failed: {out:?}");
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    let stderr = String::from_utf8(out.stderr).expect("utf8 stderr");
    for marker in ["[serve]", "[store]", "[engine]", "[trace]", "[qappa]"] {
        assert!(
            !stdout.contains(marker),
            "diagnostic marker {marker} leaked into stdout:\n{stdout}"
        );
    }
    let lines: Vec<&str> = stdout.lines().collect();
    assert_eq!(lines.len(), 1, "stdout must be exactly one report line:\n{stdout}");
    let report = qappa::util::json::Json::parse(lines[0]).expect("report line must be JSON");
    assert_eq!(report.get("requests").as_usize(), Some(6));
    assert_eq!(report.get("errors").as_usize(), Some(0));
    assert!(report.get("throughput_per_s").as_f64().unwrap_or(0.0) > 0.0);
    // The transport's lifecycle diagnostics did land on stderr.
    assert!(stderr.contains("[serve] listening"), "serve banner missing from stderr:\n{stderr}");
}

#[test]
fn optimize_cli_renders_the_session_frontier_byte_for_byte() {
    let Some(bin) = qappa_bin() else { return };
    let out = Command::new(bin)
        .args([
            "optimize",
            "--workload",
            "examples/tiny_mobilenet.json",
            "--space",
            "tiny",
            "--train",
            "48",
            "--budget",
            "60",
            "--pop",
            "16",
            "--backend",
            "native",
            "--precision",
            "int16,a4w4p8-int",
        ])
        .output()
        .expect("run qappa optimize");
    assert!(out.status.success(), "optimize failed: {out:?}");
    let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
    let stderr = String::from_utf8(out.stderr).expect("utf8 stderr");
    assert!(stdout.contains("hypervolume"), "header missing:\n{stdout}");
    assert!(!stdout.contains("[store]"), "progress leaked into stdout:\n{stdout}");
    assert!(stderr.contains("[store]"), "store counters missing from stderr");

    // An equivalent typed session call must render the exact same
    // frontier table the CLI printed (identical seeds => identical
    // frontiers across entry points).
    let session = Qappa::builder()
        .backend(BackendChoice::Native)
        .space(DesignSpace::tiny())
        .train_per_type(48)
        .build();
    let req = OptimizeRequest {
        workload: "examples/tiny_mobilenet.json".into(),
        budget: Some(60),
        pop: Some(16),
        precision: Some(qappa::api::PrecisionRequest {
            types: vec!["int16".into(), "a4w4p8-int".into()],
            ..Default::default()
        }),
        ..Default::default()
    };
    let resp = session.optimize(&req).unwrap();
    let table = opt_frontier_table(&resp).render();
    assert!(
        stdout.contains(&table),
        "CLI frontier table diverged from the session render.\nexpected:\n{table}\nstdout:\n{stdout}"
    );
}
