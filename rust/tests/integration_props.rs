//! Cross-module property tests (testkit = in-repo proptest stand-in).
//!
//! These pin the invariants that hold *between* subsystems: the oracle,
//! the dataflow model, the RTL simulator and the regression stack must
//! stay mutually consistent for any generated configuration.

use qappa::config::{AcceleratorConfig, PeType};
use qappa::dataflow::{evaluate_network, layer_traffic, map_layer, Layer};
use qappa::model::features::Standardizer;
use qappa::synth::oracle::{energy_params, synthesize, synthesize_clean};
use qappa::testkit::{forall, gen_config, gen_layer, gen_u32};
use qappa::util::json::Json;
use qappa::util::prng::Rng;

#[test]
fn prop_oracle_deterministic_and_positive() {
    forall("oracle determinism", 150, 1, gen_config, |cfg| {
        let a = synthesize(cfg);
        let b = synthesize(cfg);
        if a != b {
            return Err("oracle not deterministic".into());
        }
        for v in a.as_array() {
            if !(v > 0.0) || !v.is_finite() {
                return Err(format!("non-positive metric {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_oracle_monotone_in_array_size() {
    forall("area/power monotone in PEs", 100, 2, gen_config, |cfg| {
        let mut bigger = *cfg;
        bigger.pe_rows += 4;
        bigger.pe_cols += 4;
        let a = synthesize_clean(cfg);
        let b = synthesize_clean(&bigger);
        if b.area_mm2 <= a.area_mm2 {
            return Err(format!("area not monotone: {} vs {}", b.area_mm2, a.area_mm2));
        }
        if b.power_mw <= a.power_mw {
            return Err(format!("power not monotone: {} vs {}", b.power_mw, a.power_mw));
        }
        Ok(())
    });
}

#[test]
fn prop_dataflow_work_conserved() {
    forall(
        "array cannot do more MACs than capacity",
        120,
        3,
        |rng: &mut Rng| (gen_config(rng), gen_layer(rng)),
        |(cfg, layer)| {
            let ep = energy_params(cfg);
            let perf = map_layer(cfg, &ep, layer);
            let capacity = perf.cycles as f64 * cfg.num_pes() as f64;
            if capacity + 0.5 < layer.macs() as f64 {
                return Err(format!(
                    "capacity {capacity} < macs {} (cycles {})",
                    layer.macs(),
                    perf.cycles
                ));
            }
            if !(perf.utilization > 0.0 && perf.utilization <= 1.0) {
                return Err(format!("utilization {}", perf.utilization));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_grouped_macs_are_dense_over_groups() {
    // The grouped-conv invariant: a layer's MAC and filter volume are
    // exactly 1/groups of the dense layer with the same shape (so
    // depthwise = dense / Cin).
    forall("grouped macs = dense / groups", 200, 12, gen_layer, |layer| {
        let mut dense = layer.clone();
        dense.groups = 1;
        if layer.macs() * layer.groups as u64 != dense.macs() {
            return Err(format!(
                "macs {} * groups {} != dense {}",
                layer.macs(),
                layer.groups,
                dense.macs()
            ));
        }
        if layer.filter_elems() * layer.groups as u64 != dense.filter_elems() {
            return Err("filter volume not 1/groups of dense".into());
        }
        Ok(())
    });
}

#[test]
fn prop_traffic_at_least_compulsory() {
    forall(
        "dram >= compulsory",
        120,
        4,
        |rng: &mut Rng| (gen_config(rng), gen_layer(rng)),
        |(cfg, layer)| {
            let ep = energy_params(cfg);
            let perf = map_layer(cfg, &ep, layer);
            let t = layer_traffic(cfg, layer, &perf);
            let act = cfg.pe_type.act_bits() as u64;
            let wt = cfg.pe_type.wt_bits() as u64;
            let compulsory = (layer.ifmap_elems() * act
                + layer.filter_elems() * wt
                + layer.ofmap_elems() * act)
                / 8;
            if t.dram_bytes < compulsory {
                return Err(format!("dram {} < compulsory {compulsory}", t.dram_bytes));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_network_energy_and_latency_positive() {
    forall(
        "network eval sane",
        60,
        5,
        |rng: &mut Rng| {
            let cfg = gen_config(rng);
            let layers: Vec<Layer> = (0..1 + rng.below(5)).map(|_| gen_layer(rng)).collect();
            (cfg, layers)
        },
        |(cfg, layers)| {
            let ep = energy_params(cfg);
            let cost = evaluate_network(cfg, &ep, layers);
            if !(cost.latency_s > 0.0 && cost.latency_s.is_finite()) {
                return Err(format!("latency {}", cost.latency_s));
            }
            if !(cost.energy_mj > 0.0 && cost.energy_mj.is_finite()) {
                return Err(format!("energy {}", cost.energy_mj));
            }
            if cost.macs != layers.iter().map(|l| l.macs()).sum::<u64>() {
                return Err("mac accounting".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lightpe_never_worse_ppa_than_int16_same_config() {
    // Same geometry => LightPE-1 must synthesize to no more area/power
    // than INT16 (the paper's hardware-efficiency claim at config parity).
    forall("lightpe <= int16 at parity", 80, 6, gen_config, |cfg| {
        let mut a = *cfg;
        a.pe_type = PeType::Int16;
        let mut b = *cfg;
        b.pe_type = PeType::LightPe1;
        let pa = synthesize_clean(&a);
        let pb = synthesize_clean(&b);
        if pb.area_mm2 > pa.area_mm2 * 1.0001 {
            return Err(format!("area {} > {}", pb.area_mm2, pa.area_mm2));
        }
        if pb.power_mw > pa.power_mw * 1.0001 {
            return Err(format!("power {} > {}", pb.power_mw, pa.power_mw));
        }
        Ok(())
    });
}

#[test]
fn prop_rtl_light_term_verifies_for_any_width() {
    forall(
        "light term netlist == arithmetic",
        12,
        7,
        |rng: &mut Rng| gen_u32(rng, 12, 32),
        |&w| {
            qappa::rtl::sim::verify_light_term(w, 60, w as u64)
                .map(|_| ())
                .map_err(|e| e.to_string())
        },
    );
}

#[test]
fn prop_config_json_roundtrip() {
    forall("config json roundtrip", 150, 8, gen_config, |cfg| {
        let j = cfg.to_json().to_string();
        let parsed = Json::parse(&j).map_err(|e| e.to_string())?;
        let back = AcceleratorConfig::from_json(&parsed).ok_or("from_json")?;
        if &back != cfg {
            return Err(format!("{back:?} != {cfg:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_standardizer_inverts() {
    forall(
        "standardizer roundtrip",
        100,
        9,
        |rng: &mut Rng| {
            let n = 2 + rng.below(50);
            let rows: Vec<f64> = (0..n * 3).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            rows
        },
        |rows| {
            let s = Standardizer::fit(rows, 3);
            for row in rows.chunks(3) {
                let z = s.apply_row(row);
                let back = s.invert_row(&z);
                for (a, b) in back.iter().zip(row) {
                    if (a - b).abs() > 1e-8 * b.abs().max(1.0) {
                        return Err(format!("{a} != {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_native_fit_interpolates_planted_targets() {
    use qappa::model::native::{predict_f64, ridge_fit_f64};
    forall(
        "planted polynomial recovered",
        25,
        10,
        |rng: &mut Rng| {
            let d = 2 + rng.below(4);
            let degree = 1 + rng.below(2);
            (d, degree, rng.next_u64())
        },
        |&(d, degree, seed)| {
            let idx = qappa::model::features::monomial_indices(d, degree);
            let p = 1 + idx.len();
            let mut rng = Rng::new(seed);
            let n = 40 * p;
            let coef: Vec<f64> = (0..p * 3).map(|_| rng.gauss()).collect();
            let x: Vec<f64> = (0..n * d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let y = predict_f64(&x, n, d, &coef, degree);
            let w = vec![1.0; n];
            let fitted = ridge_fit_f64(&x, &y, &w, n, d, 0.0, degree).map_err(|e| e.to_string())?;
            let yhat = predict_f64(&x, n, d, &fitted, degree);
            for (a, b) in yhat.iter().zip(&y) {
                if (a - b).abs() > 1e-4 * b.abs().max(1.0) {
                    return Err(format!("pred {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}
