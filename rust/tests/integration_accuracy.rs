//! Integration: the accuracy axis of the co-exploration space.
//!
//! Three layers of pinning on top of `accuracy/`'s unit tests:
//!
//! * the noise-model **properties** the optimizer relies on — more bits
//!   never decreases the estimate (strict when a layer does real work),
//!   layer order is irrelevant, and a table exported from the proxy
//!   reproduces the proxy bit-for-bit (measured tables are drop-in);
//! * **strict ingestion at the session boundary** — malformed or
//!   mismatched sensitivity tables, out-of-range model knobs and
//!   non-scalable workloads are each rejected with an error naming the
//!   offending field, before any model trains;
//! * the **acceptance experiment** — a seeded three-objective
//!   latency/energy/accuracy NSGA-II run on MobileNetV1 whose mixed
//!   frontier strictly beats the best uniform-precision configuration on
//!   at least two objectives at equal evaluation budget, plus a
//!   `min-accuracy` floor run whose returned frontier never violates the
//!   floor — and byte-identical determinism for the same seed across the
//!   typed session call, the serve dispatch line, a TCP round trip, and
//!   the CLI's frontier/convergence report rendering.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use qappa::accuracy::AccuracyModel;
use qappa::api::{
    handle_line, BackendChoice, OptimizeRequest, OptimizeResponse, PrecisionRequest, Qappa,
    ResponseBody, ServeResponse, TcpServer, TransportOptions,
};
use qappa::config::{PeType, QuantSpec, ALL_PE_TYPES};
use qappa::coordinator::report::{opt_convergence_table, opt_frontier_table};
use qappa::coordinator::DesignSpace;
use qappa::dataflow::Layer;
use qappa::model::CvConfig;
use qappa::opt::Constraints;
use qappa::util::json::Json;
use qappa::workloads;

fn tiny_session() -> Qappa {
    Qappa::builder()
        .backend(BackendChoice::Native)
        .space(DesignSpace::tiny())
        .train_per_type(64)
        .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
        .seed(7)
        .workers(4)
        .sigma(0.02)
        .chunk(32)
        .topk(8)
        .build()
}

fn uniform_specs(layers: &[Layer], spec: QuantSpec) -> Vec<QuantSpec> {
    vec![spec; layers.len()]
}

fn three_objectives() -> Vec<String> {
    vec!["latency".into(), "energy".into(), "accuracy".into()]
}

// ---------------------------------------------------------------- properties

#[test]
fn accuracy_estimate_is_monotone_in_operand_bits() {
    let net = workloads::mobilenetv1();
    let proxy = AccuracyModel::proxy();
    let mut table = proxy.to_table(&net);
    table.baseline = 0.7;
    let measured = AccuracyModel::from_table(table, &net).unwrap();

    for m in [&proxy, &measured] {
        // Uniform bit ladder: strictly more accurate at every step, never
        // above the unquantized baseline.
        let ladder: Vec<f64> = [2u32, 4, 6, 8, 12, 16]
            .iter()
            .map(|&b| m.estimate(&net, &uniform_specs(&net, QuantSpec::int(b, b))))
            .collect();
        for w in ladder.windows(2) {
            assert!(w[0] < w[1], "more bits must strictly help: {ladder:?}");
        }
        for &a in &ladder {
            assert!(a <= m.baseline(), "estimate {a} above baseline {}", m.baseline());
        }

        // Per-layer monotonicity: bumping any single layer (weights alone,
        // or both operands) never decreases the estimate — strictly
        // increases it, since every MobileNetV1 layer does real MACs.
        let base_specs = uniform_specs(&net, QuantSpec::int(4, 4));
        let base = m.estimate(&net, &base_specs);
        for i in 0..net.len() {
            for bumped_spec in [QuantSpec::int(4, 8), QuantSpec::int(8, 8)] {
                let mut specs = base_specs.clone();
                specs[i] = bumped_spec;
                let bumped = m.estimate(&net, &specs);
                assert!(
                    bumped > base,
                    "bumping layer {} ({}) to {:?} did not help: {bumped} vs {base}",
                    i,
                    net[i].name,
                    bumped_spec
                );
            }
        }
    }

    // The float datapath is the zero-noise reference: exactly the baseline.
    let fp = proxy.estimate(&net, &uniform_specs(&net, PeType::Fp32.spec()));
    assert_eq!(fp, proxy.baseline());
}

#[test]
fn accuracy_estimate_is_permutation_invariant_over_layer_order() {
    let net = workloads::mobilenetv1();
    let m = AccuracyModel::proxy();
    // A deliberately non-uniform assignment so reordering actually moves
    // different (layer, spec) pairs around.
    let specs: Vec<QuantSpec> = (0..net.len())
        .map(|i| ALL_PE_TYPES[i % ALL_PE_TYPES.len()].spec())
        .collect();
    let base = m.estimate(&net, &specs);
    assert!(base > 0.0 && base < 1.0);

    let permute = |order: Vec<usize>| {
        let layers: Vec<Layer> = order.iter().map(|&i| net[i].clone()).collect();
        let sp: Vec<QuantSpec> = order.iter().map(|&i| specs[i]).collect();
        m.estimate(&layers, &sp)
    };
    let reversed = permute((0..net.len()).rev().collect());
    let interleaved = permute(
        (0..net.len()).step_by(2).chain((1..net.len()).step_by(2)).collect(),
    );
    for (what, acc) in [("reversed", reversed), ("interleaved", interleaved)] {
        let rel = (acc - base).abs() / base;
        assert!(rel < 1e-12, "{what} order moved the estimate: {acc} vs {base}");
    }
}

#[test]
fn table_exported_from_the_proxy_reproduces_the_proxy_exactly() {
    let net = workloads::mobilenetv1();
    let proxy = AccuracyModel::proxy();

    // Export -> JSON text -> strict re-parse -> wrap: the full round trip a
    // user's measured table would take.
    let table = proxy.to_table(&net);
    let text = table.to_json().to_string();
    let reparsed = qappa::accuracy::SensitivityTable::parse(&text).unwrap();
    assert_eq!(reparsed, table, "sensitivity-table JSON must round-trip");
    let wrapped = AccuracyModel::from_table(reparsed, &net).unwrap();
    assert!(wrapped.is_measured() && !proxy.is_measured());

    // Agreement must be exact (bit-identical), not approximate: uniform
    // presets, a mixed cycle, and single-layer bumps.
    let mut assignments: Vec<Vec<QuantSpec>> = ALL_PE_TYPES
        .iter()
        .map(|&t| uniform_specs(&net, t.spec()))
        .collect();
    assignments.push(
        (0..net.len()).map(|i| ALL_PE_TYPES[i % ALL_PE_TYPES.len()].spec()).collect(),
    );
    for i in [0, net.len() / 2, net.len() - 1] {
        let mut specs = uniform_specs(&net, QuantSpec::int(4, 4));
        specs[i] = QuantSpec::int(16, 16);
        assignments.push(specs);
    }
    for specs in &assignments {
        let a = proxy.estimate(&net, specs);
        let b = wrapped.estimate(&net, specs);
        assert_eq!(a.to_bits(), b.to_bits(), "proxy {a} != table {b}");
    }
    // Baseline scales the whole curve linearly.
    let mut scaled = proxy.to_table(&net);
    scaled.baseline = 0.7;
    let scaled = AccuracyModel::from_table(scaled, &net).unwrap();
    let specs = uniform_specs(&net, QuantSpec::int(8, 8));
    let ratio = scaled.estimate(&net, &specs) / proxy.estimate(&net, &specs);
    assert!((ratio - 0.7).abs() < 1e-12, "{ratio}");
}

// ------------------------------------------------------- strict ingestion

#[test]
fn session_rejects_bad_tables_and_knobs_naming_the_field_before_training() {
    let session = Qappa::builder().backend(BackendChoice::Native).build();
    let net = workloads::mobilenetv1();
    let base = AccuracyModel::proxy().to_table(&net);
    let req = |sensitivity: Option<Json>| OptimizeRequest {
        workload: "mobilenetv1".into(),
        objectives: three_objectives(),
        sensitivity,
        budget: Some(10),
        pop: Some(8),
        seed: Some(1),
        ..Default::default()
    };
    let expect = |r: &OptimizeRequest, kind: &str, needle: &str| {
        let e = session.optimize(r).unwrap_err();
        assert_eq!(e.kind(), kind, "{e}");
        let msg = e.to_string();
        assert!(msg.contains(needle), "expected {needle:?} in: {msg}");
    };

    // Unknown top-level field.
    let mut extra = base.to_json();
    if let Json::Obj(m) = &mut extra {
        m.insert("extra".into(), Json::Num(1.0));
    }
    expect(&req(Some(extra)), "workload", "\"extra\"");

    // An entry naming no workload layer.
    let mut ghost = base.clone();
    ghost.sensitivity.insert("ghost".into(), 1.0);
    expect(&req(Some(ghost.to_json())), "workload", "sensitivity.ghost");

    // A workload layer with no entry.
    let mut missing = base.clone();
    missing.sensitivity.remove("stem");
    expect(&req(Some(missing.to_json())), "workload", "'stem'");

    // Non-positive sensitivity names the per-layer field.
    let mut negative = base.clone();
    negative.sensitivity.insert("stem".into(), -1.0);
    expect(&req(Some(negative.to_json())), "workload", "sensitivity.stem");

    // The table must be an object at all.
    expect(&req(Some(Json::Num(5.0))), "workload", "object");

    // A table without anything consuming it is a configuration error, not
    // a silent no-op.
    let mut classic = req(Some(base.to_json()));
    classic.objectives = vec!["latency".into(), "energy".into()];
    expect(&classic, "config", "requires an accuracy objective");

    // Model knobs: multipliers live in (0, 1]; only scalable workloads
    // accept them.
    let mut wide = req(None);
    wide.width_mults = vec![1.5];
    expect(&wide, "config", "width_mults");
    let mut unscalable = req(None);
    unscalable.workload = "resnet34".into();
    unscalable.depth_mults = vec![0.5];
    expect(&unscalable, "workload", "no scalable builder");

    assert_eq!(session.store().misses(), 0, "rejected requests must never train");
}

// ---------------------------------------------------------- determinism

#[test]
fn seeded_three_objective_optimize_is_deterministic_across_transports() {
    let net = workloads::mobilenetv1();
    let table = AccuracyModel::proxy().to_table(&net);
    let req = OptimizeRequest {
        workload: "mobilenetv1".into(),
        objectives: three_objectives(),
        constraints: Constraints { min_accuracy: Some(0.85), ..Default::default() },
        sensitivity: Some(table.to_json()),
        width_mults: vec![1.0, 0.75],
        budget: Some(60),
        pop: Some(16),
        seed: Some(9),
        ..Default::default()
    };

    let session = tiny_session();
    let typed = session.optimize(&req).unwrap();
    assert_eq!(typed.objectives, vec!["latency", "energy", "accuracy"]);
    assert!(!typed.frontier.is_empty());
    for p in &typed.frontier {
        assert_eq!(p.objectives.len(), 3);
        let a = p.accuracy.expect("accuracy runs must report per-point accuracy");
        assert_eq!(p.objectives[2], 1.0 - a, "third slot is the minimized 1 - accuracy");
        assert!(a >= 0.85, "floor violated in the returned frontier: {a}");
    }

    // Same seed, same session.
    let again = session.optimize(&req).unwrap();
    assert_eq!(again, typed, "same seed must reproduce the 3-objective frontier");

    // The serve dispatch line (stdio transport), same session.
    let line = format!(r#"{{"id":5,"op":"optimize","params":{}}}"#, req.to_json());
    let resp = handle_line(&session, &line);
    assert_eq!(resp.id, Some(5));
    let wire = match resp.result {
        Ok(ResponseBody::Optimize(r)) => r,
        other => panic!("expected an optimize response, got {other:?}"),
    };
    assert_eq!(wire, typed, "serve and session must agree for identical seeds");
    assert_eq!(session.store().misses(), 1, "one trained model across all three runs");

    // A full TCP round trip against a *fresh* session built from the same
    // recipe: determinism across processes, not just calls.
    let remote = Arc::new(tiny_session());
    let mut server =
        TcpServer::bind(remote, "127.0.0.1:0", TransportOptions::default()).expect("bind");
    let stream = TcpStream::connect(server.local_addr()).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{line}").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read response line");
    let resp = ServeResponse::from_json(&Json::parse(&reply).expect("JSON line"))
        .expect("typed response");
    assert_eq!(resp.id, Some(5));
    let tcp = match resp.result {
        Ok(ResponseBody::Optimize(r)) => r,
        other => panic!("expected an optimize response over TCP, got {other:?}"),
    };
    server.shutdown();
    assert_eq!(tcp, typed, "TCP transport must agree with the typed call");

    // The CLI layer renders these tables: byte-identical reports, with the
    // accuracy column and third-objective convergence present.
    let frontier_csv = opt_frontier_table(&typed).to_csv();
    assert_eq!(opt_frontier_table(&tcp).to_csv(), frontier_csv);
    assert_eq!(opt_convergence_table(&tcp).to_csv(), opt_convergence_table(&typed).to_csv());
    assert!(frontier_csv.contains("accuracy"), "report must carry the accuracy column");
    assert!(opt_convergence_table(&typed).to_csv().contains("best_obj2"));
}

// ----------------------------------------------------------- acceptance

/// Equal-weight best-compromise point: minimized objectives normalized by
/// the per-axis maximum over `points`, then the row with the smallest sum.
fn best_compromise(points: &[&qappa::api::OptPoint]) -> Vec<f64> {
    let mut maxs = [0.0f64; 3];
    for p in points {
        for k in 0..3 {
            maxs[k] = maxs[k].max(p.objectives[k]);
        }
    }
    for m in &mut maxs {
        if *m <= 0.0 {
            *m = 1.0;
        }
    }
    points
        .iter()
        .map(|p| {
            let score: f64 = (0..3).map(|k| p.objectives[k] / maxs[k]).sum();
            (score, p.objectives.clone())
        })
        .min_by(|a, b| a.0.total_cmp(&b.0))
        .expect("non-empty frontier")
        .1
}

#[test]
fn three_objective_frontier_beats_uniform_precision_baselines_at_equal_budget() {
    const BUDGET: usize = 240;
    let session = tiny_session();
    let base = |precision: Option<PrecisionRequest>, per_layer: Option<bool>| OptimizeRequest {
        workload: "mobilenetv1".into(),
        objectives: three_objectives(),
        budget: Some(BUDGET),
        pop: Some(24),
        seed: Some(11),
        per_layer,
        precision,
        ..Default::default()
    };

    // The co-exploration run: hardware x per-layer precision over the four
    // preset cells.
    let mixed = session.optimize(&base(None, None)).unwrap();
    assert!(mixed.evaluated <= BUDGET);
    assert!(!mixed.frontier.is_empty());

    // Uniform-precision baselines: one run per preset, hardware-only
    // search, the same seed and the same evaluation budget.
    let mut uniform: Vec<OptimizeResponse> = Vec::new();
    for label in ["fp32", "int16", "lightpe-1", "lightpe-2"] {
        let req = base(
            Some(PrecisionRequest { types: vec![label.into()], ..Default::default() }),
            Some(false),
        );
        let resp = session.optimize(&req).unwrap();
        assert!(resp.evaluated <= BUDGET, "{label} overran the shared budget");
        assert!(!resp.frontier.is_empty(), "{label} produced no frontier");
        // A uniform palette has exactly one accuracy level: hardware knobs
        // cannot move the quantization noise.
        let acc0 = resp.frontier[0].accuracy.expect("accuracy present").to_bits();
        for p in &resp.frontier {
            assert_eq!(p.accuracy.unwrap().to_bits(), acc0, "{label} accuracy drifted");
        }
        uniform.push(resp);
    }

    // The best uniform configuration across all presets: the equal-weight
    // compromise over every uniform frontier point (normalized per axis).
    let pool: Vec<&qappa::api::OptPoint> =
        uniform.iter().flat_map(|r| r.frontier.iter()).collect();
    let best_uniform = best_compromise(&pool);

    // Acceptance: some mixed-frontier point is strictly better on at least
    // two of the three minimized objectives.
    let beaten = mixed.frontier.iter().any(|p| {
        (0..3).filter(|&k| p.objectives[k] < best_uniform[k]).count() >= 2
    });
    assert!(
        beaten,
        "no mixed point beat the best uniform config {best_uniform:?} on >= 2 \
         objectives; mixed frontier: {:?}",
        mixed.frontier.iter().map(|p| p.objectives.clone()).collect::<Vec<_>>()
    );

    // The hard floor: a constrained run never returns a violating point.
    let floor = 0.93;
    let mut floored = base(None, None);
    floored.constraints = Constraints { min_accuracy: Some(floor), ..Default::default() };
    floored.budget = Some(80);
    floored.pop = Some(16);
    floored.seed = Some(5);
    let resp = session.optimize(&floored).unwrap();
    assert!(!resp.frontier.is_empty(), "feasible designs exist above the floor");
    for p in &resp.frontier {
        let a = p.accuracy.expect("constrained runs must report accuracy");
        assert!(a >= floor, "returned point violates min-accuracy {floor}: {a}");
    }
}
