//! Artifact loading: manifest parse + HLO text -> PJRT executables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/manifest.json` (shapes contract with aot.py).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub d: usize,
    pub m: usize,
    pub n_fit: usize,
    pub b_predict: usize,
    /// Gram accumulation tile (gram artifacts take b_gram rows; the engine
    /// chunks larger row counts and sums the additive accumulators).
    pub b_gram: usize,
    pub degrees: Vec<usize>,
    /// P per degree.
    pub p: HashMap<usize, usize>,
    pub feature_order: Vec<String>,
    pub target_order: Vec<String>,
    /// Monomial index tuples per degree (for cross-checking the rust
    /// feature expansion against the kernels').
    pub monomials: HashMap<usize, Vec<Vec<usize>>>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let need = |k: &str| -> Result<usize> {
            v.get(k).as_usize().ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let degrees: Vec<usize> = v
            .get("degrees")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest degrees"))?
            .iter()
            .filter_map(|x| x.as_usize())
            .collect();
        let mut p = HashMap::new();
        let mut monomials = HashMap::new();
        for &d in &degrees {
            let art = v.get("artifacts").get(&format!("predict_d{d}"));
            p.insert(d, art.get("p").as_usize().ok_or_else(|| anyhow!("p for d{d}"))?);
            let mons = v
                .get("monomials")
                .get(&d.to_string())
                .as_arr()
                .ok_or_else(|| anyhow!("monomials d{d}"))?
                .iter()
                .map(|t| {
                    t.as_arr()
                        .map(|xs| xs.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default()
                })
                .collect();
            monomials.insert(d, mons);
        }
        let strings = |k: &str| -> Vec<String> {
            v.get(k)
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        Ok(Manifest {
            d: need("d")?,
            m: need("m")?,
            n_fit: need("n_fit")?,
            b_predict: need("b_predict")?,
            b_gram: v.get("b_gram").as_usize().unwrap_or(need("n_fit")?),
            degrees,
            p,
            feature_order: strings("feature_order"),
            target_order: strings("target_order"),
            monomials,
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }
}

/// The PJRT client + compiled executables (owned by the engine thread; the
/// underlying handles are not Sync).
pub struct ArtifactRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ArtifactRuntime {
    /// Load every artifact named by the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for &d in &manifest.degrees {
            for kind in ["predict", "fit", "loss", "gram", "solve"] {
                let name = format!("{kind}_d{d}");
                let path = dir.join(format!("{name}.hlo.txt"));
                if !path.exists() {
                    bail!("artifact {} missing — run `make artifacts`", path.display());
                }
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                exes.insert(name, exe);
            }
        }
        Ok(ArtifactRuntime { manifest, client, exes })
    }

    pub fn artifacts_dir_default() -> PathBuf {
        std::env::var("QAPPA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run1(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name}"))?;
        let out = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?)
    }

    fn run3(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name}"))?;
        let out = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple3()?)
    }

    fn mat(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// Predict one full B-tile: x is `b_predict x d`, coef `p x m`.
    pub fn predict_tile(&self, degree: usize, x: &[f32], coef: &[f32]) -> Result<Vec<f32>> {
        let man = &self.manifest;
        let p = man.p[&degree];
        let xl = Self::mat(x, man.b_predict, man.d)?;
        let wl = Self::mat(coef, p, man.m)?;
        let out = self.run1(&format!("predict_d{degree}"), &[xl, wl])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Fit on padded `n_fit` rows (weights mask padding).
    pub fn fit(
        &self,
        degree: usize,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        lam: f32,
    ) -> Result<Vec<f32>> {
        let man = &self.manifest;
        let xl = Self::mat(x, man.n_fit, man.d)?;
        let yl = Self::mat(y, man.n_fit, man.m)?;
        let wl = xla::Literal::vec1(w).reshape(&[man.n_fit as i64])?;
        let ll = xla::Literal::scalar(lam);
        let out = self.run1(&format!("fit_d{degree}"), &[xl, yl, wl, ll])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Un-normalized Gram accumulators for one b_gram tile: `(G, C, n_eff)`.
    pub fn gram_tile(
        &self,
        degree: usize,
        x: &[f32],
        y: &[f32],
        w: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let man = &self.manifest;
        let xl = Self::mat(x, man.b_gram, man.d)?;
        let yl = Self::mat(y, man.b_gram, man.m)?;
        let wl = xla::Literal::vec1(w).reshape(&[man.b_gram as i64])?;
        let (g, c, n) = self.run3(&format!("gram_d{degree}"), &[xl, yl, wl])?;
        Ok((
            g.to_vec::<f32>()?,
            c.to_vec::<f32>()?,
            n.to_vec::<f32>()?[0],
        ))
    }

    /// Ridge solve from accumulators.
    pub fn solve(
        &self,
        degree: usize,
        g: &[f32],
        c: &[f32],
        n_eff: f32,
        lam: f32,
    ) -> Result<Vec<f32>> {
        let man = &self.manifest;
        let p = man.p[&degree];
        let gl = Self::mat(g, p, p)?;
        let cl = Self::mat(c, p, man.m)?;
        let nl = xla::Literal::scalar(n_eff);
        let ll = xla::Literal::scalar(lam);
        let out = self.run1(&format!("solve_d{degree}"), &[gl, cl, nl, ll])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Weighted MSE of `coef` on padded rows.
    pub fn loss(
        &self,
        degree: usize,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        coef: &[f32],
    ) -> Result<Vec<f32>> {
        let man = &self.manifest;
        let p = man.p[&degree];
        let xl = Self::mat(x, man.n_fit, man.d)?;
        let yl = Self::mat(y, man.n_fit, man.m)?;
        let wl = xla::Literal::vec1(w).reshape(&[man.n_fit as i64])?;
        let cl = Self::mat(coef, p, man.m)?;
        let out = self.run1(&format!("loss_d{degree}"), &[xl, yl, wl, cl])?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST_SNIPPET: &str = r#"{
      "d": 7, "m": 3, "n_fit": 2048, "b_predict": 256,
      "degrees": [1, 2],
      "feature_order": ["pe_rows","pe_cols","glb_kb","spad_ifmap_b","spad_filter_b","spad_psum_b","bandwidth_gbps"],
      "target_order": ["power_mw","fmax_mhz","area_mm2"],
      "monomials": {"1": [[0],[1],[2],[3],[4],[5],[6]], "2": [[0],[0,0]]},
      "artifacts": {
        "predict_d1": {"p": 8}, "fit_d1": {"p": 8}, "loss_d1": {"p": 8},
        "predict_d2": {"p": 36}, "fit_d2": {"p": 36}, "loss_d2": {"p": 36}
      }
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST_SNIPPET).unwrap();
        assert_eq!(m.d, 7);
        assert_eq!(m.b_predict, 256);
        assert_eq!(m.degrees, vec![1, 2]);
        assert_eq!(m.p[&1], 8);
        assert_eq!(m.p[&2], 36);
        assert_eq!(m.feature_order.len(), 7);
        assert_eq!(m.monomials[&1].len(), 7);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn manifest_feature_order_matches_config() {
        // The rust feature vector is pinned to this order in
        // config::AcceleratorConfig::features().
        let m = Manifest::parse(MANIFEST_SNIPPET).unwrap();
        assert_eq!(
            m.feature_order,
            vec![
                "pe_rows", "pe_cols", "glb_kb", "spad_ifmap_b", "spad_filter_b",
                "spad_psum_b", "bandwidth_gbps"
            ]
        );
    }
}
