//! PJRT runtime: load the AOT HLO-text artifacts and serve fit / loss /
//! predict requests from the rust hot path.
//!
//! * [`client`] — manifest-driven artifact loading: HLO text ->
//!   `HloModuleProto` -> PJRT compile, one executable per (kind, degree);
//! * [`engine`] — a dedicated runtime thread owning the PJRT client plus a
//!   dynamic batcher: concurrent predict requests are coalesced into the
//!   artifact's fixed `B = 256` tile (padding masked out), the vLLM-router
//!   pattern scaled down to this paper's workload.
//!
//! Python never runs here: after `make artifacts`, the rust binary is
//! self-contained.

pub mod client;
pub mod engine;

pub use client::{ArtifactRuntime, Manifest};
pub use engine::{Engine, EngineStats, XlaBackend};
