//! The runtime engine: a dedicated thread owning the PJRT client, fed by a
//! request channel with **dynamic batching** of predict traffic.
//!
//! PJRT handles are not `Sync`, so a single engine thread owns them and the
//! rest of the coordinator talks to it through an mpsc channel.  Predict
//! requests carry arbitrary row counts; the engine coalesces whatever is
//! queued into the artifact's fixed `B`-row tile (padding the tail), which
//! amortizes dispatch overhead exactly like a serving router's batcher.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::api::error::QappaError;
use crate::model::{Backend, M};
use crate::runtime::client::ArtifactRuntime;

enum Request {
    Predict {
        degree: usize,
        coef: Arc<Vec<f32>>,
        x: Vec<f32>, // n x d
        n: usize,
        reply: Sender<Result<Vec<f32>, QappaError>>,
    },
    Fit {
        degree: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        w: Vec<f32>,
        n: usize,
        lam: f32,
        reply: Sender<Result<Vec<f32>, QappaError>>,
    },
    Loss {
        degree: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        w: Vec<f32>,
        n: usize,
        coef: Vec<f32>,
        reply: Sender<Result<Vec<f32>, QappaError>>,
    },
    Gram {
        degree: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        w: Vec<f32>,
        n: usize,
        reply: Sender<Result<(Vec<f32>, Vec<f32>, f32), QappaError>>,
    },
    Solve {
        degree: usize,
        g: Vec<f32>,
        c: Vec<f32>,
        n_eff: f32,
        lam: f32,
        reply: Sender<Result<Vec<f32>, QappaError>>,
    },
    Shutdown,
}

/// Counters exposed for benches and the perf log.
#[derive(Debug, Default)]
pub struct EngineStats {
    pub predict_requests: AtomicU64,
    pub predict_rows: AtomicU64,
    pub predict_batches: AtomicU64,
    pub predict_padded_rows: AtomicU64,
    pub fit_calls: AtomicU64,
    pub loss_calls: AtomicU64,
    pub gram_calls: AtomicU64,
    pub solve_calls: AtomicU64,
}

/// Handle to the engine thread.
///
/// `Engine` is `Sync` (the request sender sits behind a `Mutex`), so one
/// engine can be shared by reference across a serving session's worker
/// threads — concurrent predict requests land in the same queue and get
/// coalesced by the dynamic batcher.
pub struct Engine {
    tx: Mutex<Sender<Request>>,
    pub stats: Arc<EngineStats>,
    pub d: usize,
    pub n_fit: usize,
    pub b_predict: usize,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Start the engine by loading artifacts from `dir`.
    pub fn start(dir: &Path) -> Result<Engine, QappaError> {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(EngineStats::default());
        let stats2 = stats.clone();
        // Load inside the engine thread (handles are not Send), but fail
        // fast: the thread reports readiness over a oneshot.
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize, usize), QappaError>>();
        let dir = dir.to_path_buf();
        let join = std::thread::Builder::new()
            .name("qappa-runtime".into())
            .spawn(move || {
                let rt = match ArtifactRuntime::load(&dir) {
                    Ok(rt) => {
                        let m = &rt.manifest;
                        let _ = ready_tx.send(Ok((m.d, m.n_fit, m.b_predict)));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(QappaError::Backend(format!("{e:#}"))));
                        return;
                    }
                };
                engine_loop(rt, rx, stats2);
            })
            .map_err(|e| QappaError::io("spawning qappa-runtime thread", e))?;
        let (d, n_fit, b_predict) = ready_rx.recv().map_err(|_| {
            QappaError::Backend("engine thread died during artifact load".into())
        })??;
        Ok(Engine { tx: Mutex::new(tx), stats, d, n_fit, b_predict, join: Some(join) })
    }

    /// Queue one request (lock scope is just the send, so concurrent
    /// callers only serialize on the enqueue).
    fn send(&self, req: Request) -> Result<(), QappaError> {
        self.tx
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .send(req)
            .map_err(|_| QappaError::Backend("engine gone".into()))
    }

    fn rpc(
        &self,
        req: Request,
        rx: Receiver<Result<Vec<f32>, QappaError>>,
    ) -> Result<Vec<f32>, QappaError> {
        self.send(req)?;
        rx.recv()
            .map_err(|_| QappaError::Backend("engine dropped reply".into()))?
    }

    pub fn predict(
        &self,
        degree: usize,
        coef: Arc<Vec<f32>>,
        x: Vec<f32>,
        n: usize,
    ) -> Result<Vec<f32>, QappaError> {
        let (reply, rx) = channel();
        self.stats.predict_requests.fetch_add(1, Ordering::Relaxed);
        self.stats.predict_rows.fetch_add(n as u64, Ordering::Relaxed);
        self.rpc(Request::Predict { degree, coef, x, n, reply }, rx)
    }

    pub fn fit(
        &self,
        degree: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        w: Vec<f32>,
        n: usize,
        lam: f32,
    ) -> Result<Vec<f32>, QappaError> {
        let (reply, rx) = channel();
        self.stats.fit_calls.fetch_add(1, Ordering::Relaxed);
        self.rpc(Request::Fit { degree, x, y, w, n, lam, reply }, rx)
    }

    pub fn loss(
        &self,
        degree: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        w: Vec<f32>,
        n: usize,
        coef: Vec<f32>,
    ) -> Result<Vec<f32>, QappaError> {
        let (reply, rx) = channel();
        self.stats.loss_calls.fetch_add(1, Ordering::Relaxed);
        self.rpc(Request::Loss { degree, x, y, w, n, coef, reply }, rx)
    }

    pub fn gram(
        &self,
        degree: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        w: Vec<f32>,
        n: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32), QappaError> {
        let (reply, rx) = channel();
        self.stats.gram_calls.fetch_add(1, Ordering::Relaxed);
        self.send(Request::Gram { degree, x, y, w, n, reply })?;
        rx.recv()
            .map_err(|_| QappaError::Backend("engine dropped reply".into()))?
    }

    pub fn solve(
        &self,
        degree: usize,
        g: Vec<f32>,
        c: Vec<f32>,
        n_eff: f32,
        lam: f32,
    ) -> Result<Vec<f32>, QappaError> {
        let (reply, rx) = channel();
        self.stats.solve_calls.fetch_add(1, Ordering::Relaxed);
        self.rpc(Request::Solve { degree, g, c, n_eff, lam, reply }, rx)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Pad an `n x cols` slab to `rows_total` rows with zeros.
fn pad_rows(data: &[f32], n: usize, cols: usize, rows_total: usize) -> Vec<f32> {
    debug_assert!(n <= rows_total, "{n} > {rows_total}");
    let mut out = Vec::with_capacity(rows_total * cols);
    out.extend_from_slice(&data[..n * cols]);
    out.resize(rows_total * cols, 0.0);
    out
}

fn engine_loop(rt: ArtifactRuntime, rx: Receiver<Request>, stats: Arc<EngineStats>) {
    let d = rt.manifest.d;
    let m = rt.manifest.m;
    let b = rt.manifest.b_predict;
    // Pending predict rows grouped by (degree, coef identity).
    struct Pending {
        degree: usize,
        coef: Arc<Vec<f32>>,
        x: Vec<f32>,
        n: usize,
        reply: Sender<Result<Vec<f32>, QappaError>>,
    }

    let mut queue: Vec<Pending> = Vec::new();

    let flush = |queue: &mut Vec<Pending>, stats: &EngineStats| {
        while !queue.is_empty() {
            // Take the head request's (degree, coef) group and coalesce all
            // compatible requests into B-row tiles.
            let degree = queue[0].degree;
            let coef = queue[0].coef.clone();
            let mut group: Vec<Pending> = Vec::new();
            let mut rest: Vec<Pending> = Vec::new();
            for p in queue.drain(..) {
                if p.degree == degree && Arc::ptr_eq(&p.coef, &coef) {
                    group.push(p);
                } else {
                    rest.push(p);
                }
            }
            *queue = rest;

            // Concatenate group rows, execute tile by tile, scatter back.
            let total: usize = group.iter().map(|p| p.n).sum();
            let mut all_x = Vec::with_capacity(total * d);
            for p in &group {
                all_x.extend_from_slice(&p.x[..p.n * d]);
            }
            let mut all_out: Vec<f32> = Vec::with_capacity(total * m);
            let mut ok: Result<(), QappaError> = Ok(());
            let mut off = 0usize;
            while off < total {
                let take = (total - off).min(b);
                let tile = pad_rows(&all_x[off * d..], take, d, b);
                stats.predict_batches.fetch_add(1, Ordering::Relaxed);
                stats
                    .predict_padded_rows
                    .fetch_add((b - take) as u64, Ordering::Relaxed);
                match rt.predict_tile(degree, &tile, &coef) {
                    Ok(out) => all_out.extend_from_slice(&out[..take * m]),
                    Err(e) => {
                        ok = Err(QappaError::Backend(format!("{e:#}")));
                        break;
                    }
                }
                off += take;
            }
            // scatter
            let mut row = 0usize;
            for p in group {
                let res = match &ok {
                    Ok(()) => Ok(all_out[row * m..(row + p.n) * m].to_vec()),
                    Err(e) => Err(e.clone()),
                };
                row += p.n;
                let _ = p.reply.send(res);
            }
        }
    };

    loop {
        // Block for one request, then drain whatever else is queued so the
        // batcher sees the full backlog.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut batchable = Vec::new();
        let mut others = Vec::new();
        let mut shutdown = false;
        let mut stash = |req: Request, batchable: &mut Vec<Pending>, others: &mut Vec<Request>| {
            match req {
                Request::Predict { degree, coef, x, n, reply } => {
                    batchable.push(Pending { degree, coef, x, n, reply })
                }
                other => others.push(other),
            }
        };
        match first {
            Request::Shutdown => break,
            r => stash(r, &mut batchable, &mut others),
        }
        while let Ok(r) = rx.try_recv() {
            match r {
                Request::Shutdown => {
                    shutdown = true;
                    break;
                }
                r => stash(r, &mut batchable, &mut others),
            }
        }
        queue.extend(batchable);
        flush(&mut queue, &stats);
        for req in others {
            match req {
                Request::Fit { degree, x, y, w, n, lam, reply } => {
                    let n_fit = rt.manifest.n_fit;
                    let res = if n > n_fit {
                        Err(QappaError::Backend(format!(
                            "fit rows {n} exceed artifact capacity {n_fit}"
                        )))
                    } else {
                        let xp = pad_rows(&x, n, d, n_fit);
                        let yp = pad_rows(&y, n, m, n_fit);
                        let wp = pad_rows(&w, n, 1, n_fit);
                        rt.fit(degree, &xp, &yp, &wp, lam)
                            .map_err(|e| QappaError::Backend(format!("{e:#}")))
                    };
                    let _ = reply.send(res);
                }
                Request::Loss { degree, x, y, w, n, coef, reply } => {
                    let n_fit = rt.manifest.n_fit;
                    let res = if n > n_fit {
                        Err(QappaError::Backend(format!(
                            "loss rows {n} exceed artifact capacity {n_fit}"
                        )))
                    } else {
                        let xp = pad_rows(&x, n, d, n_fit);
                        let yp = pad_rows(&y, n, m, n_fit);
                        let wp = pad_rows(&w, n, 1, n_fit);
                        rt.loss(degree, &xp, &yp, &wp, &coef)
                            .map_err(|e| QappaError::Backend(format!("{e:#}")))
                    };
                    let _ = reply.send(res);
                }
                Request::Gram { degree, x, y, w, n, reply } => {
                    // Grams are additive: chunk the rows through the
                    // b_gram tile and sum the accumulators.
                    let bg = rt.manifest.b_gram;
                    let mut acc: Option<(Vec<f32>, Vec<f32>, f32)> = None;
                    let mut err: Option<QappaError> = None;
                    let mut off = 0usize;
                    while off < n {
                        let take = (n - off).min(bg);
                        let xp = pad_rows(&x[off * d..], take, d, bg);
                        let yp = pad_rows(&y[off * m..], take, m, bg);
                        let wp = pad_rows(&w[off..], take, 1, bg);
                        match rt.gram_tile(degree, &xp, &yp, &wp) {
                            Ok((g, c, ne)) => match &mut acc {
                                None => acc = Some((g, c, ne)),
                                Some((ga, ca, na)) => {
                                    for (a, b) in ga.iter_mut().zip(&g) {
                                        *a += b;
                                    }
                                    for (a, b) in ca.iter_mut().zip(&c) {
                                        *a += b;
                                    }
                                    *na += ne;
                                }
                            },
                            Err(e) => {
                                err = Some(QappaError::Backend(format!("{e:#}")));
                                break;
                            }
                        }
                        off += take;
                    }
                    let res = match (err, acc) {
                        (Some(e), _) => Err(e),
                        (None, Some(a)) => Ok(a),
                        (None, None) => {
                            Err(QappaError::Backend("gram with zero rows".into()))
                        }
                    };
                    let _ = reply.send(res);
                }
                Request::Solve { degree, g, c, n_eff, lam, reply } => {
                    let res = rt
                        .solve(degree, &g, &c, n_eff, lam)
                        .map_err(|e| QappaError::Backend(format!("{e:#}")));
                    let _ = reply.send(res);
                }
                Request::Predict { .. } | Request::Shutdown => unreachable!(),
            }
        }
        if shutdown {
            break;
        }
    }
}

/// `model::Backend` implementation over the engine (standardized f32
/// matrices in, coefficients out — same contract as `NativeBackend`).
pub struct XlaBackend {
    engine: Arc<Engine>,
}

impl XlaBackend {
    pub fn new(engine: Arc<Engine>) -> XlaBackend {
        XlaBackend { engine }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Backend for XlaBackend {
    fn d(&self) -> usize {
        self.engine.d
    }

    fn fit(
        &self,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        n: usize,
        lam: f32,
        degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        self.engine
            .fit(degree, x.to_vec(), y.to_vec(), w.to_vec(), n, lam)
    }

    fn loss(
        &self,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        n: usize,
        coef: &[f32],
        degree: usize,
    ) -> Result<[f32; M], QappaError> {
        let v = self
            .engine
            .loss(degree, x.to_vec(), y.to_vec(), w.to_vec(), n, coef.to_vec())?;
        if v.len() != M {
            return Err(QappaError::Backend(format!("loss returned {} values", v.len())));
        }
        Ok([v[0], v[1], v[2]])
    }

    fn predict(
        &self,
        x: &[f32],
        n: usize,
        coef: &[f32],
        degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        self.engine
            .predict(degree, Arc::new(coef.to_vec()), x.to_vec(), n)
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn has_gram_solve(&self) -> bool {
        true
    }

    fn gram(
        &self,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        n: usize,
        degree: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32), QappaError> {
        self.engine
            .gram(degree, x.to_vec(), y.to_vec(), w.to_vec(), n)
    }

    fn solve(
        &self,
        g: &[f32],
        c: &[f32],
        n_eff: f32,
        lam: f32,
        degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        self.engine
            .solve(degree, g.to_vec(), c.to_vec(), n_eff, lam)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rows_pads_and_preserves() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = pad_rows(&data, 2, 3, 4);
        assert_eq!(out.len(), 12);
        assert_eq!(&out[..6], &data[..]);
        assert!(out[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)] // debug_assert! is compiled out in release
    fn pad_rows_rejects_overflow_in_debug() {
        let data = [0.0f32; 12];
        let _ = pad_rows(&data, 4, 3, 2);
    }
}
