//! The `qappa serve` request loop: JSON-lines in, JSON-lines out, one warm
//! [`Qappa`] session behind every request.
//!
//! Protocol (documented with worked examples in `docs/API.md`):
//!
//! * one request per line: `{"id": 7, "op": "explore", "params": {...}}`;
//! * one response per line: `{"id": 7, "ok": true, "op": "explore",
//!   "result": {...}}` or `{"id": 7, "ok": false, "error": {"kind": "...",
//!   "message": "..."}}`;
//! * `id` is echoed verbatim; with `concurrency > 1` responses may arrive
//!   out of order, so clients correlate by it;
//! * a malformed line answers with a `protocol` error (id `null` if the
//!   line didn't parse far enough to carry one) — the loop never dies on
//!   bad input, only on I/O failure.
//!
//! Requests are dispatched by a small scoped-thread worker pool against one
//! shared session: models train once (`ModelStore` serializes in-flight
//! training), every worker answers from the warm cache, and the engine's
//! dynamic batcher coalesces concurrent predict traffic.

use std::io::{BufRead, Write};
use std::sync::Mutex;

use crate::api::error::QappaError;
use crate::api::session::Qappa;
use crate::api::types::{ErrorBody, RequestBody, ResponseBody, ServeRequest, ServeResponse};
use crate::util::json::Json;
use crate::util::pool::default_workers;
use crate::util::queue::BoundedQueue;

/// Options for one serve loop.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads dispatching requests (1 = sequential, in-order
    /// responses).
    pub concurrency: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { concurrency: default_workers().min(4) }
    }
}

/// Counters of one serve loop.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
}

/// Dispatch one typed request against the session.  Every op bumps its
/// `session.ops.<op>` counter in the process-wide metrics registry.
pub fn dispatch(session: &Qappa, body: &RequestBody) -> Result<ResponseBody, QappaError> {
    crate::obs::registry().counter(&format!("session.ops.{}", body.op())).inc();
    match body {
        RequestBody::Synth(r) => session.synth(r).map(ResponseBody::Synth),
        RequestBody::Fit(r) => session.fit(r).map(ResponseBody::Fit),
        RequestBody::Explore(r) => session.explore(r).map(ResponseBody::Explore),
        RequestBody::Optimize(r) => session.optimize(r).map(ResponseBody::Optimize),
        RequestBody::Analyze(r) => session.analyze(r).map(ResponseBody::Analyze),
        RequestBody::Workloads(r) => session.workloads(r).map(ResponseBody::Workloads),
        RequestBody::Session => Ok(ResponseBody::Session(session.session_info())),
        RequestBody::Metrics => Ok(ResponseBody::Metrics(crate::obs::registry().snapshot())),
    }
}

/// Parse and answer one request line; never panics on bad input.  The
/// request id is extracted best-effort before typed parsing, so even an
/// unknown op or a bad parameter payload answers with the caller's id.
pub fn handle_line(session: &Qappa, line: &str) -> ServeResponse {
    let v = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            let e = QappaError::from(e);
            return ServeResponse { id: None, result: Err(ErrorBody::from(&e)) };
        }
    };
    let id = v.get("id").as_usize().map(|x| x as u64);
    let req = match ServeRequest::from_json(&v) {
        Ok(req) => req,
        Err(e) => return ServeResponse { id, result: Err(ErrorBody::from(&e)) },
    };
    match dispatch(session, &req.body) {
        Ok(body) => ServeResponse { id: req.id, result: Ok(body) },
        Err(e) => ServeResponse { id: req.id, result: Err(ErrorBody::from(&e)) },
    }
}

/// Run the request loop: read JSON-lines requests from `reader`, answer on
/// `writer` from one shared warm session.  Returns the loop counters.
pub fn serve<R: BufRead, W: Write + Send>(
    session: &Qappa,
    reader: R,
    writer: W,
    opts: &ServeOptions,
) -> Result<ServeStats, QappaError> {
    let workers = opts.concurrency.max(1);
    let out = Mutex::new(writer);
    let stats = Mutex::new(ServeStats::default());

    let emit = |resp: &ServeResponse| -> Result<(), QappaError> {
        {
            let mut s = stats.lock().unwrap_or_else(|p| p.into_inner());
            s.requests += 1;
            if resp.result.is_ok() {
                s.ok += 1;
            } else {
                s.errors += 1;
            }
        }
        let mut w = out.lock().unwrap_or_else(|p| p.into_inner());
        writeln!(w, "{}", resp.to_json())
            .and_then(|_| w.flush())
            .map_err(|e| QappaError::io("writing response", e))
    };

    if workers == 1 {
        for line in reader.lines() {
            let line = line.map_err(|e| QappaError::io("reading request", e))?;
            if line.trim().is_empty() {
                continue;
            }
            emit(&handle_line(session, &line))?;
        }
    } else {
        // Bounded queue: the producer reads at most O(workers) lines ahead
        // of the dispatchers, so a huge piped batch never balloons memory.
        // A worker that dies on a write failure (downstream closed the
        // pipe) closes the queue, which wakes a producer blocked on the
        // full queue — the explicit shutdown signal that used to be a 1 ms
        // `try_send`/sleep poll loop.
        let queue: BoundedQueue<String> = BoundedQueue::new(workers * 2);
        let worker_err: Mutex<Option<QappaError>> = Mutex::new(None);
        std::thread::scope(|scope| -> Result<(), QappaError> {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let Some(line) = queue.pop() else { break };
                    if let Err(e) = emit(&handle_line(session, &line)) {
                        let mut slot = worker_err.lock().unwrap_or_else(|p| p.into_inner());
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        queue.close(); // dead-worker abort: wake the producer
                        break;
                    }
                });
            }
            let produced = (|| -> Result<(), QappaError> {
                for line in reader.lines() {
                    let line = line.map_err(|e| QappaError::io("reading request", e))?;
                    if line.trim().is_empty() {
                        continue;
                    }
                    if queue.push(line).is_err() {
                        break; // a worker died and closed the queue
                    }
                }
                Ok(())
            })();
            // Close unconditionally (also on a read error), so blocked
            // workers drain the tail and the scope can join.
            queue.close();
            produced
        })?;
        if let Some(e) = worker_err.into_inner().unwrap_or_else(|p| p.into_inner()) {
            return Err(e);
        }
    }
    Ok(stats.into_inner().unwrap_or_else(|p| p.into_inner()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::session::BackendChoice;
    use crate::api::types::{SessionInfo, WorkloadsResponse};
    use crate::util::json::Json;

    fn session() -> Qappa {
        Qappa::builder().backend(BackendChoice::Native).build()
    }

    fn parse_lines(out: &[u8]) -> Vec<ServeResponse> {
        std::str::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| ServeResponse::from_json(&Json::parse(l).unwrap()).unwrap())
            .collect()
    }

    #[test]
    fn sequential_loop_answers_in_order() {
        let s = session();
        let input = "\
{\"id\":1,\"op\":\"workloads\"}\n\
\n\
{\"id\":2,\"op\":\"session\"}\n";
        let mut out = Vec::new();
        let stats = serve(&s, input.as_bytes(), &mut out, &ServeOptions { concurrency: 1 }).unwrap();
        assert_eq!(stats, ServeStats { requests: 2, ok: 2, errors: 0 });
        let resps = parse_lines(&out);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].id, Some(1));
        assert!(matches!(resps[0].result, Ok(ResponseBody::Workloads(WorkloadsResponse::List(_)))));
        assert_eq!(resps[1].id, Some(2));
        match &resps[1].result {
            Ok(ResponseBody::Session(SessionInfo { backend: None, models_trained: 0, .. })) => {}
            other => panic!("unexpected session response: {other:?}"),
        }
    }

    #[test]
    fn bad_lines_answer_protocol_errors_without_killing_the_loop() {
        let s = session();
        let input = "\
not json\n\
{\"id\":9,\"op\":\"nope\"}\n\
{\"id\":10,\"op\":\"synth\",\"params\":{\"config\":{\"pe_type\":\"bogus\"}}}\n\
{\"id\":11,\"op\":\"workloads\"}\n";
        let mut out = Vec::new();
        let stats = serve(&s, input.as_bytes(), &mut out, &ServeOptions { concurrency: 1 }).unwrap();
        assert_eq!(stats.requests, 4);
        assert_eq!(stats.errors, 3);
        let resps = parse_lines(&out);
        // unparseable line: id unknown
        assert_eq!(resps[0].id, None);
        let e = resps[0].result.as_ref().unwrap_err();
        assert_eq!(e.kind, "protocol");
        // unknown op: id echoed
        assert_eq!(resps[1].id, Some(9));
        assert!(resps[1].result.as_ref().unwrap_err().message.contains("nope"));
        // typed param error
        assert_eq!(resps[2].id, Some(10));
        assert!(resps[2].result.as_ref().unwrap_err().message.contains("pe_type"));
        // the loop survived to answer the good request
        assert_eq!(resps[3].id, Some(11));
        assert!(resps[3].result.is_ok());
    }

    /// A writer whose every write fails — the downstream-closed-the-pipe
    /// case that kills every worker.
    struct FailWriter;

    impl std::io::Write for FailWriter {
        fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "sink closed"))
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn dead_workers_unblock_a_full_queue() {
        let s = session();
        // Far more requests than the bounded queue holds, against a writer
        // that fails every write: all workers die on their first response
        // while the producer is blocked on the full queue.  The close()
        // signal must wake it so serve() terminates with the worker's
        // error instead of hanging (the old poll loop's job, minus the
        // busy-wait).
        let mut input = String::new();
        for id in 0..64u64 {
            input.push_str(&format!("{{\"id\":{id},\"op\":\"session\"}}\n"));
        }
        let err = serve(&s, input.as_bytes(), FailWriter, &ServeOptions { concurrency: 2 })
            .unwrap_err();
        assert_eq!(err.kind(), "io");
    }

    #[test]
    fn concurrent_loop_answers_every_request() {
        let s = session();
        let mut input = String::new();
        for id in 1..=12u64 {
            input.push_str(&format!("{{\"id\":{id},\"op\":\"workloads\"}}\n"));
        }
        let mut out = Vec::new();
        let stats = serve(&s, input.as_bytes(), &mut out, &ServeOptions { concurrency: 4 }).unwrap();
        assert_eq!(stats, ServeStats { requests: 12, ok: 12, errors: 0 });
        let mut ids: Vec<u64> = parse_lines(&out).iter().map(|r| r.id.unwrap()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (1..=12).collect::<Vec<_>>());
    }
}
