//! `QappaError` — the crate-wide structured error type.
//!
//! Every fallible public API in the crate returns `Result<_, QappaError>`:
//! the variants classify *where* in the stack a request died (configuration,
//! workload ingestion, regression backend, model math, I/O, wire protocol),
//! which is exactly what a service client needs to decide between "fix the
//! request" and "retry / page the operator".  [`QappaError::kind`] is the
//! stable lowercase tag carried by `qappa serve` error payloads
//! (`api::types::ErrorBody`).
//!
//! `Display` prints the bare message (no variant prefix) so CLI error lines
//! read exactly as they did when the crate returned `Result<_, String>`;
//! the classification travels out-of-band via [`QappaError::kind`].

use std::fmt;
use std::sync::Arc;

use crate::util::cli::CliError;
use crate::util::json::ParseError;

/// Structured error for every fallible public API in the crate.
///
/// The `Io` variant keeps the failing path / operation as `context` and the
/// underlying [`std::io::Error`] as `source` (shared through an `Arc` so
/// the error stays `Clone`-able across the engine's reply channels).
#[derive(Debug, Clone)]
pub enum QappaError {
    /// Invalid accelerator configuration, design space, backend selection
    /// or CLI/builder parameters.
    Config(String),
    /// Workload resolution or ingestion failure (unknown name, malformed
    /// JSON model, invalid layer shape).
    Workload(String),
    /// Regression-backend failure: engine startup, artifact execution,
    /// channel breakdown, capacity overflow.
    Backend(String),
    /// Model-math failure: CV grid problems, non-SPD normal equations,
    /// golden-model verification mismatches.
    Model(String),
    /// I/O failure with the path or operation preserved as context.
    Io {
        context: String,
        source: Arc<std::io::Error>,
    },
    /// Malformed service request (the `qappa serve` wire protocol).
    Protocol(String),
}

impl QappaError {
    /// Build an [`QappaError::Io`] with the failing path / operation kept
    /// as context (a bare `From<io::Error>` would flatten it away, which is
    /// exactly the context loss this type exists to prevent).
    pub fn io(context: impl Into<String>, source: std::io::Error) -> QappaError {
        QappaError::Io { context: context.into(), source: Arc::new(source) }
    }

    /// Stable lowercase tag for wire payloads and logs.
    pub fn kind(&self) -> &'static str {
        match self {
            QappaError::Config(_) => "config",
            QappaError::Workload(_) => "workload",
            QappaError::Backend(_) => "backend",
            QappaError::Model(_) => "model",
            QappaError::Io { .. } => "io",
            QappaError::Protocol(_) => "protocol",
        }
    }

    /// Prefix the message with extra context, keeping the variant — the
    /// `QappaError` analogue of `format!("{ctx}: {e}")` on strings.
    pub fn context(self, prefix: impl fmt::Display) -> QappaError {
        match self {
            QappaError::Config(m) => QappaError::Config(format!("{prefix}: {m}")),
            QappaError::Workload(m) => QappaError::Workload(format!("{prefix}: {m}")),
            QappaError::Backend(m) => QappaError::Backend(format!("{prefix}: {m}")),
            QappaError::Model(m) => QappaError::Model(format!("{prefix}: {m}")),
            QappaError::Io { context, source } => QappaError::Io {
                context: format!("{prefix}: {context}"),
                source,
            },
            QappaError::Protocol(m) => QappaError::Protocol(format!("{prefix}: {m}")),
        }
    }
}

impl fmt::Display for QappaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QappaError::Config(m)
            | QappaError::Workload(m)
            | QappaError::Backend(m)
            | QappaError::Model(m)
            | QappaError::Protocol(m) => write!(f, "{m}"),
            QappaError::Io { context, source } => write!(f, "{context}: {source}"),
        }
    }
}

impl std::error::Error for QappaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            QappaError::Io { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

/// CLI flag errors carry the flag name in their message
/// (`--train: cannot parse 'abc'`), so the conversion preserves context.
impl From<CliError> for QappaError {
    fn from(e: CliError) -> QappaError {
        QappaError::Config(e.0)
    }
}

/// JSON syntax errors surface as protocol errors (byte offset preserved);
/// semantic workload errors are classified at the ingestion site instead.
impl From<ParseError> for QappaError {
    fn from(e: ParseError) -> QappaError {
        QappaError::Protocol(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_the_bare_message() {
        let e = QappaError::Workload("unknown workload 'x'".into());
        assert_eq!(e.to_string(), "unknown workload 'x'");
        assert_eq!(e.kind(), "workload");
    }

    #[test]
    fn io_preserves_context_and_source() {
        let inner = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = QappaError::io("reading workload file 'm.json'", inner);
        assert_eq!(e.kind(), "io");
        let msg = e.to_string();
        assert!(msg.starts_with("reading workload file 'm.json': "), "{msg}");
        assert!(msg.contains("gone"), "{msg}");
        use std::error::Error;
        assert!(e.source().is_some());
    }

    #[test]
    fn context_keeps_the_variant() {
        let e = QappaError::Model("empty CV grid".into()).context("INT16");
        assert_eq!(e.kind(), "model");
        assert_eq!(e.to_string(), "INT16: empty CV grid");
        let io = QappaError::io("writing x.csv", std::io::Error::new(std::io::ErrorKind::Other, "disk"))
            .context("figures");
        assert_eq!(io.kind(), "io");
        assert!(io.to_string().starts_with("figures: writing x.csv: "));
    }

    #[test]
    fn cli_and_json_conversions_classify() {
        let c: QappaError = CliError("--train: cannot parse 'x'".into()).into();
        assert_eq!(c.kind(), "config");
        assert_eq!(c.to_string(), "--train: cannot parse 'x'");
        let p: QappaError = crate::util::json::Json::parse("{").unwrap_err().into();
        assert_eq!(p.kind(), "protocol");
        assert!(p.to_string().contains("json parse error"), "{p}");
    }

    #[test]
    fn errors_are_cloneable_for_reply_fanout() {
        let e = QappaError::io("ctx", std::io::Error::new(std::io::ErrorKind::Other, "x"));
        let c = e.clone();
        assert_eq!(c.to_string(), e.to_string());
    }
}
