//! Admission control, request coalescing and cancellation — the dispatch
//! layer between a transport (TCP connections, `api::transport`) and the
//! shared [`Qappa`] session.
//!
//! Three concerns live here, none of which the stdio loop needs:
//!
//! * **Bounded admission.**  `max_inflight` caps the requests being worked
//!   at once across every connection; past the cap a request is *shed*
//!   with a structured `protocol` error instead of queueing without bound
//!   (the client sees the error immediately and may retry, instead of a
//!   timeout it can't attribute).
//! * **Coalescing.**  Identical in-flight read-only requests (`explore`,
//!   `fit`, `analyze` with byte-identical params) are collapsed into one
//!   evaluation: the first caller becomes the *leader* and runs the query,
//!   followers block on the flight and share the leader's answer.  Sound
//!   because these ops are deterministic functions of (params, session
//!   recipe) — the repo's bit-for-bit reproducibility guarantee — and it
//!   amortizes one batched `predict_configs_soa` pass across clients.
//! * **Cancellation.**  Each connection hands its [`CancelToken`] down so
//!   a client that vanishes mid-`optimize` stops burning evaluation budget
//!   (the engine exits at the next batch boundary).
//!
//! Shed diagnostics go to stderr (`[serve]` prefix); the wire carries only
//! JSON responses — the stdout/wire-purity convention of `docs/SERVE.md`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::api::error::QappaError;
use crate::api::serve;
use crate::api::session::Qappa;
use crate::api::types::{ErrorBody, RequestBody, ResponseBody, ServeRequest, ServeResponse};
use crate::obs;
use crate::opt::CancelToken;
use crate::util::json::Json;

/// Knobs of the dispatch layer.
#[derive(Debug, Clone, Copy)]
pub struct DispatchOptions {
    /// Requests being worked at once, across all connections; past this
    /// the dispatcher sheds with a `protocol` error.
    pub max_inflight: usize,
    /// Collapse identical in-flight read-only requests into one pass.
    pub coalesce: bool,
}

impl Default for DispatchOptions {
    fn default() -> DispatchOptions {
        DispatchOptions { max_inflight: 64, coalesce: true }
    }
}

/// Counter snapshot of one dispatcher (see [`Dispatcher::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DispatchStats {
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    /// Requests refused at the admission gate.
    pub shed: usize,
    /// Followers answered from a leader's in-flight evaluation.
    pub coalesced: usize,
    /// Optimize runs stopped by a fired [`CancelToken`].
    pub cancelled: usize,
}

/// One in-flight coalescable evaluation: followers wait on `cv` until the
/// leader publishes into `done`.
struct Flight {
    done: Mutex<Option<Result<ResponseBody, ErrorBody>>>,
    cv: Condvar,
}

#[derive(Default)]
struct Counters {
    requests: AtomicUsize,
    ok: AtomicUsize,
    errors: AtomicUsize,
    shed: AtomicUsize,
    coalesced: AtomicUsize,
    cancelled: AtomicUsize,
}

/// The shared dispatch layer: every connection calls
/// [`Dispatcher::handle_line`] with its own [`CancelToken`].
pub struct Dispatcher {
    session: Arc<Qappa>,
    opts: DispatchOptions,
    inflight: AtomicUsize,
    flights: Mutex<HashMap<String, Arc<Flight>>>,
    counters: Counters,
}

/// Decrements the in-flight gauges (the dispatcher's own and the
/// registry's `serve.inflight`) on every exit path.
struct Admitted<'a> {
    inflight: &'a AtomicUsize,
    gauge: obs::Gauge,
}

impl Drop for Admitted<'_> {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        self.gauge.add(-1.0);
    }
}

impl Dispatcher {
    pub fn new(session: Arc<Qappa>, opts: DispatchOptions) -> Dispatcher {
        Dispatcher {
            session,
            opts,
            inflight: AtomicUsize::new(0),
            flights: Mutex::new(HashMap::new()),
            counters: Counters::default(),
        }
    }

    pub fn options(&self) -> DispatchOptions {
        self.opts
    }

    pub fn session(&self) -> &Qappa {
        &self.session
    }

    pub fn stats(&self) -> DispatchStats {
        DispatchStats {
            requests: self.counters.requests.load(Ordering::SeqCst),
            ok: self.counters.ok.load(Ordering::SeqCst),
            errors: self.counters.errors.load(Ordering::SeqCst),
            shed: self.counters.shed.load(Ordering::SeqCst),
            coalesced: self.counters.coalesced.load(Ordering::SeqCst),
            cancelled: self.counters.cancelled.load(Ordering::SeqCst),
        }
    }

    /// Count a request a transport rejected before dispatch (oversized
    /// frame): it still shows up in `requests`/`errors` totals.
    pub(crate) fn note_rejected(&self) {
        self.counters.requests.fetch_add(1, Ordering::SeqCst);
        self.counters.errors.fetch_add(1, Ordering::SeqCst);
        let reg = obs::registry();
        reg.counter("serve.requests").inc();
        reg.counter("serve.errors").inc();
    }

    /// Parse and answer one request line against the admission gate, the
    /// coalescing map and the caller's cancel token.  Mirrors
    /// [`serve::handle_line`]'s never-panic contract: every input answers
    /// with a response carrying the caller's id when one was parseable.
    /// Every request feeds the registry: `serve.requests`/`ok`/`errors`
    /// counters and the `serve.request_ms` latency histogram.
    pub fn handle_line(&self, line: &str, cancel: &CancelToken) -> ServeResponse {
        let t0 = std::time::Instant::now();
        let resp = self.handle_line_inner(line, cancel);
        let reg = obs::registry();
        reg.histogram("serve.request_ms").record_ms(t0.elapsed().as_secs_f64() * 1e3);
        reg.counter("serve.requests").inc();
        reg.counter(if resp.result.is_ok() { "serve.ok" } else { "serve.errors" }).inc();
        resp
    }

    fn handle_line_inner(&self, line: &str, cancel: &CancelToken) -> ServeResponse {
        self.counters.requests.fetch_add(1, Ordering::SeqCst);
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                self.counters.errors.fetch_add(1, Ordering::SeqCst);
                let e = QappaError::from(e);
                return ServeResponse { id: None, result: Err(ErrorBody::from(&e)) };
            }
        };
        let id = v.get("id").as_usize().map(|x| x as u64);
        let req = match ServeRequest::from_json(&v) {
            Ok(req) => req,
            Err(e) => {
                self.counters.errors.fetch_add(1, Ordering::SeqCst);
                return ServeResponse { id, result: Err(ErrorBody::from(&e)) };
            }
        };

        // Admission gate: admit-then-check keeps the gauge race-free
        // without a lock on the hot path.
        let prev = self.inflight.fetch_add(1, Ordering::SeqCst);
        let inflight_gauge = obs::registry().gauge("serve.inflight");
        inflight_gauge.add(1.0);
        let guard = Admitted { inflight: &self.inflight, gauge: inflight_gauge };
        if prev >= self.opts.max_inflight {
            drop(guard);
            self.counters.shed.fetch_add(1, Ordering::SeqCst);
            self.counters.errors.fetch_add(1, Ordering::SeqCst);
            obs::registry().counter("serve.shed").inc();
            obs::diag(
                "serve",
                format_args!(
                    "shed {} request: {} in flight (max {})",
                    req.body.op(),
                    prev,
                    self.opts.max_inflight
                ),
            );
            let e = QappaError::Protocol(format!(
                "admission: server at capacity ({} requests in flight, max {}); retry later",
                prev, self.opts.max_inflight
            ));
            return ServeResponse { id: req.id, result: Err(ErrorBody::from(&e)) };
        }

        let result = self.handle_body(&req.body, cancel);
        if result.is_ok() {
            self.counters.ok.fetch_add(1, Ordering::SeqCst);
        } else {
            self.counters.errors.fetch_add(1, Ordering::SeqCst);
        }
        drop(guard);
        ServeResponse { id: req.id, result }
    }

    fn handle_body(
        &self,
        body: &RequestBody,
        cancel: &CancelToken,
    ) -> Result<ResponseBody, ErrorBody> {
        match body {
            RequestBody::Optimize(r) => {
                // Bypasses `serve::dispatch` (cancellable path), so count
                // the op here to keep `session.ops.*` complete.
                obs::registry().counter("session.ops.optimize").inc();
                match self.session.optimize_cancellable(r, cancel) {
                    Ok(resp) => Ok(ResponseBody::Optimize(resp)),
                    Err(e) => {
                        if cancel.is_cancelled() {
                            self.counters.cancelled.fetch_add(1, Ordering::SeqCst);
                            obs::registry().counter("serve.cancelled").inc();
                        }
                        Err(ErrorBody::from(&e))
                    }
                }
            }
            RequestBody::Explore(_) | RequestBody::Fit(_) | RequestBody::Analyze(_)
                if self.opts.coalesce =>
            {
                self.coalesced_dispatch(body)
            }
            other => serve::dispatch(&self.session, other).map_err(|e| ErrorBody::from(&e)),
        }
    }

    /// Single-flight: one leader evaluates per distinct (op, params) key;
    /// followers arriving while the flight is open share its result.
    fn coalesced_dispatch(&self, body: &RequestBody) -> Result<ResponseBody, ErrorBody> {
        let key = format!("{}|{}", body.op(), body.params_to_json());
        let (flight, leader) = {
            let mut flights = self.flights.lock().unwrap_or_else(|p| p.into_inner());
            match flights.get(&key) {
                Some(f) => (f.clone(), false),
                None => {
                    let f = Arc::new(Flight { done: Mutex::new(None), cv: Condvar::new() });
                    flights.insert(key.clone(), f.clone());
                    (f, true)
                }
            }
        };
        if leader {
            let result =
                serve::dispatch(&self.session, body).map_err(|e| ErrorBody::from(&e));
            // Unregister before publishing: a request arriving after this
            // point starts a fresh flight instead of reading a settled one.
            self.flights.lock().unwrap_or_else(|p| p.into_inner()).remove(&key);
            let mut done = flight.done.lock().unwrap_or_else(|p| p.into_inner());
            *done = Some(result.clone());
            flight.cv.notify_all();
            result
        } else {
            self.counters.coalesced.fetch_add(1, Ordering::SeqCst);
            obs::registry().counter("serve.coalesced").inc();
            let mut done = flight.done.lock().unwrap_or_else(|p| p.into_inner());
            while done.is_none() {
                done = flight.cv.wait(done).unwrap_or_else(|p| p.into_inner());
            }
            done.clone().expect("flight published")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::session::BackendChoice;
    use crate::api::types::WorkloadsResponse;

    fn dispatcher(opts: DispatchOptions) -> Dispatcher {
        let s = Arc::new(Qappa::builder().backend(BackendChoice::Native).build());
        Dispatcher::new(s, opts)
    }

    #[test]
    fn plain_request_round_trips_and_counts() {
        let d = dispatcher(DispatchOptions::default());
        let cancel = CancelToken::new();
        let resp = d.handle_line("{\"id\":3,\"op\":\"workloads\"}", &cancel);
        assert_eq!(resp.id, Some(3));
        assert!(matches!(resp.result, Ok(ResponseBody::Workloads(WorkloadsResponse::List(_)))));
        let st = d.stats();
        assert_eq!((st.requests, st.ok, st.errors, st.shed), (1, 1, 0, 0));
    }

    #[test]
    fn malformed_lines_answer_protocol_errors() {
        let d = dispatcher(DispatchOptions::default());
        let cancel = CancelToken::new();
        let resp = d.handle_line("not json", &cancel);
        assert_eq!(resp.id, None);
        assert_eq!(resp.result.unwrap_err().kind, "protocol");
        let resp = d.handle_line("{\"id\":9,\"op\":\"nope\"}", &cancel);
        assert_eq!(resp.id, Some(9), "id echoed even for an unknown op");
        assert_eq!(d.stats().errors, 2);
    }

    #[test]
    fn zero_capacity_sheds_everything() {
        let d = dispatcher(DispatchOptions { max_inflight: 0, coalesce: true });
        let cancel = CancelToken::new();
        let resp = d.handle_line("{\"id\":1,\"op\":\"session\"}", &cancel);
        assert_eq!(resp.id, Some(1), "shed responses still correlate by id");
        let e = resp.result.unwrap_err();
        assert_eq!(e.kind, "protocol");
        assert!(e.message.contains("at capacity"), "{}", e.message);
        let st = d.stats();
        assert_eq!((st.shed, st.errors, st.ok), (1, 1, 0));
        assert_eq!(d.inflight.load(Ordering::SeqCst), 0, "shed must release the gauge");
    }

    #[test]
    fn followers_share_a_leaders_flight() {
        let d = Arc::new(dispatcher(DispatchOptions::default()));
        let line = "{\"id\":5,\"op\":\"analyze\",\"params\":{\"workload\":\"mobilenetv2\",\
                    \"config\":{\"pe_type\":\"int16\"}}}";
        // Pre-register the flight under the same key the dispatcher would
        // compute, so the thread below is deterministically a follower.
        let req = ServeRequest::parse_line(line).unwrap();
        let key = format!("{}|{}", req.body.op(), req.body.params_to_json());
        let flight = Arc::new(Flight { done: Mutex::new(None), cv: Condvar::new() });
        d.flights
            .lock()
            .unwrap()
            .insert(key.clone(), flight.clone());

        let follower = {
            let d = d.clone();
            let line = line.to_string();
            std::thread::spawn(move || d.handle_line(&line, &CancelToken::new()))
        };
        // Publish a sentinel error as the "leader's" answer.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let sentinel = ErrorBody { kind: "model".into(), message: "sentinel".into() };
        {
            let mut done = flight.done.lock().unwrap();
            *done = Some(Err(sentinel.clone()));
            flight.cv.notify_all();
        }
        let resp = follower.join().unwrap();
        assert_eq!(resp.id, Some(5));
        assert_eq!(resp.result.unwrap_err(), sentinel, "follower got the flight's answer");
        assert_eq!(d.stats().coalesced, 1);
    }

    #[test]
    fn coalescing_off_bypasses_the_flight_map() {
        let d = dispatcher(DispatchOptions { max_inflight: 64, coalesce: false });
        let cancel = CancelToken::new();
        // An invalid analyze config answers a typed error straight from the
        // session — no flight is ever registered.
        let resp = d.handle_line(
            "{\"id\":2,\"op\":\"analyze\",\"params\":{\"workload\":\"mobilenetv2\",\
             \"config\":{\"pe_type\":\"bogus\"}}}",
            &cancel,
        );
        assert!(resp.result.is_err());
        assert!(d.flights.lock().unwrap().is_empty());
        assert_eq!(d.stats().coalesced, 0);
    }
}
