//! `qappa loadgen` — the built-in load generator that pins serve
//! throughput: N connections × M lockstep requests against a TCP server,
//! reporting latency percentiles and saturation throughput.
//!
//! Each connection is one thread speaking the JSON-lines protocol in
//! request/response lockstep (send, wait for the echo-correlated reply,
//! repeat), so per-request latency is exact and the concurrency level is
//! precisely the connection count.  The aggregate report feeds
//! `BENCH_serve.json` (via `benches/serve_throughput.rs`) and the CI
//! load-smoke step; thresholds live in `tools/bench_baseline.json`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::api::error::QappaError;
use crate::api::types::{
    AnalyzeRequest, ExploreRequest, RequestBody, ServeRequest, ServeResponse,
};
use crate::config::{AcceleratorConfig, PeType};
use crate::obs::Histogram;
use crate::util::json::{obj, Json};

/// Which request stream each connection sends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestMix {
    /// Warm-cache `explore` queries (the serve hot path).
    Explore,
    /// Config-only `analyze` queries (no model, no backend).
    Analyze,
    /// Rotate explore / analyze / session.
    Mixed,
}

impl RequestMix {
    pub fn parse(s: &str) -> Result<RequestMix, QappaError> {
        match s.to_ascii_lowercase().as_str() {
            "explore" => Ok(RequestMix::Explore),
            "analyze" => Ok(RequestMix::Analyze),
            "mixed" => Ok(RequestMix::Mixed),
            other => Err(QappaError::Config(format!(
                "loadgen: unknown mix '{other}' (expected explore|analyze|mixed)"
            ))),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            RequestMix::Explore => "explore",
            RequestMix::Analyze => "analyze",
            RequestMix::Mixed => "mixed",
        }
    }

    /// The k-th request body of this mix (every body is deterministic, so
    /// a server run under loadgen is reproducible).
    fn body(self, k: usize) -> RequestBody {
        let explore = || {
            RequestBody::Explore(ExploreRequest {
                workloads: vec!["vgg16".into()],
                precision: None,
            })
        };
        let analyze = || {
            RequestBody::Analyze(AnalyzeRequest::new(
                "mobilenetv2",
                AcceleratorConfig::default_with(PeType::Int16),
            ))
        };
        match self {
            RequestMix::Explore => explore(),
            RequestMix::Analyze => analyze(),
            RequestMix::Mixed => match k % 3 {
                0 => explore(),
                1 => analyze(),
                _ => RequestBody::Session,
            },
        }
    }
}

/// Knobs of one loadgen run.
#[derive(Debug, Clone, Copy)]
pub struct LoadgenOptions {
    pub connections: usize,
    /// Requests per connection.
    pub requests: usize,
    pub mix: RequestMix,
    /// Issue one untimed `explore` first so training happens outside the
    /// measured window (off = cold measurement).
    pub warmup: bool,
    /// How long to keep retrying the initial connect (the server may
    /// still be binding).
    pub connect_timeout_ms: u64,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            connections: 4,
            requests: 25,
            mix: RequestMix::Explore,
            warmup: true,
            connect_timeout_ms: 5000,
        }
    }
}

/// Aggregate result of one run (JSON shape: [`LoadgenReport::to_json`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LoadgenReport {
    pub connections: usize,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub elapsed_s: f64,
    /// Completed requests per wall-clock second across all connections.
    pub throughput_per_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LoadgenReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("connections", Json::Num(self.connections as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("throughput_per_s", Json::Num(self.throughput_per_s)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("max_ms", Json::Num(self.max_ms)),
        ])
    }
}

fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream, QappaError> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                return Err(QappaError::io(format!("connecting to {addr}"), e));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// One lockstep exchange: send, wait for the reply, verify the id echo.
/// Returns whether the reply was `ok`.
fn round_trip(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    id: u64,
    body: RequestBody,
) -> Result<bool, QappaError> {
    let req = ServeRequest { id: Some(id), body };
    writeln!(writer, "{}", req.to_json())
        .and_then(|_| writer.flush())
        .map_err(|e| QappaError::io("writing request", e))?;
    line.clear();
    let n = reader
        .read_line(line)
        .map_err(|e| QappaError::io("reading response", e))?;
    if n == 0 {
        return Err(QappaError::Protocol("server closed the connection".into()));
    }
    let resp = ServeResponse::from_json(&Json::parse(line)?)?;
    if resp.id != Some(id) {
        return Err(QappaError::Protocol(format!(
            "response id {:?} does not echo request id {id}",
            resp.id
        )));
    }
    Ok(resp.result.is_ok())
}

/// One connection's lockstep loop: returns (latencies in ms, ok, errors).
fn run_connection(
    addr: &str,
    conn: usize,
    opts: &LoadgenOptions,
    start: &Barrier,
) -> Result<(Vec<f64>, usize, usize), QappaError> {
    let mut line = String::new();
    // Connect and warm up *before* the barrier, but reach the barrier on
    // every path — a connection that fails setup must not deadlock the
    // stopwatch and its peers.
    let ready = (|| -> Result<(TcpStream, BufReader<TcpStream>), QappaError> {
        let stream =
            connect_with_retry(addr, Duration::from_millis(opts.connect_timeout_ms))?;
        let mut writer = stream
            .try_clone()
            .map_err(|e| QappaError::io("cloning loadgen socket", e))?;
        let mut reader = BufReader::new(stream);
        if opts.warmup {
            // Untimed: absorbs training (one connection pays it, the rest
            // hit the in-flight dedup / warm store) before timing starts.
            round_trip(
                &mut writer,
                &mut reader,
                &mut line,
                (conn as u64 + 1) * 1_000_000_000,
                RequestBody::Explore(ExploreRequest {
                    workloads: vec!["vgg16".into()],
                    precision: None,
                }),
            )?;
        }
        Ok((writer, reader))
    })();
    start.wait();
    let (mut writer, mut reader) = ready?;

    let mut latencies = Vec::with_capacity(opts.requests);
    let (mut ok, mut errors) = (0usize, 0usize);
    for k in 0..opts.requests {
        let id = (conn as u64) * 1_000_000 + k as u64;
        let t0 = Instant::now();
        if round_trip(&mut writer, &mut reader, &mut line, id, opts.mix.body(k))? {
            ok += 1;
        } else {
            errors += 1;
        }
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok((latencies, ok, errors))
}

/// Run the generator against a listening server and aggregate the report.
pub fn run_loadgen(addr: &str, opts: &LoadgenOptions) -> Result<LoadgenReport, QappaError> {
    let connections = opts.connections.max(1);
    let requests = opts.requests.max(1);
    let opts = LoadgenOptions { connections, requests, ..*opts };
    // +1: the aggregator thread holds the stopwatch, started only once
    // every connection is connected and warmed.
    let start = Arc::new(Barrier::new(connections + 1));
    let mut handles = Vec::with_capacity(connections);
    for conn in 0..connections {
        let addr = addr.to_string();
        let start = start.clone();
        handles.push(std::thread::spawn(move || {
            run_connection(&addr, conn, &opts, &start)
        }));
    }
    start.wait();
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(connections * requests);
    let (mut ok, mut errors) = (0usize, 0usize);
    for h in handles {
        let (l, o, e) = h
            .join()
            .map_err(|_| QappaError::Protocol("loadgen connection thread panicked".into()))??;
        latencies.extend(l);
        ok += o;
        errors += e;
    }
    let elapsed_s = t0.elapsed().as_secs_f64().max(1e-9);
    let total = latencies.len();
    // One quantile implementation for the whole codebase: the shared
    // log-bucket histogram from `obs` (max is exact; p50/p95/p99 are
    // rank-interpolated within ≤~4.4%-wide buckets — see obs::metrics).
    let (p50_ms, p95_ms, p99_ms, max_ms) = latency_quantiles(&latencies);
    Ok(LoadgenReport {
        connections,
        requests: total,
        ok,
        errors,
        elapsed_s,
        throughput_per_s: total as f64 / elapsed_s,
        p50_ms,
        p95_ms,
        p99_ms,
        max_ms,
    })
}

/// (p50, p95, p99, max) of a latency sample in ms, via the shared obs
/// histogram so loadgen and the serve-side `serve.request_ms` metric agree
/// on one quantile definition.
fn latency_quantiles(latencies: &[f64]) -> (f64, f64, f64, f64) {
    let h = Histogram::new();
    for &ms in latencies {
        h.record_ms(ms);
    }
    (h.quantile(50.0), h.quantile(95.0), h.quantile(99.0), h.max_ms())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::session::{BackendChoice, Qappa};
    use crate::api::transport::{TcpServer, TransportOptions};
    use crate::coordinator::space::DesignSpace;
    use crate::model::CvConfig;

    #[test]
    fn mix_parses_and_rotates() {
        assert_eq!(RequestMix::parse("Mixed").unwrap(), RequestMix::Mixed);
        assert!(RequestMix::parse("nope").is_err());
        let ops: Vec<&str> =
            (0..4).map(|k| RequestMix::Mixed.body(k).op()).collect();
        assert_eq!(ops, ["explore", "analyze", "session", "explore"]);
    }

    #[test]
    fn latency_quantiles_pin_to_the_exact_sorted_oracle() {
        use crate::util::stats::percentile;
        // A skewed latency-like sample: mostly fast, a heavy tail.
        let mut xs: Vec<f64> = (1..=900).map(|i| 0.5 + i as f64 * 0.01).collect();
        xs.extend((1..=100).map(|i| 20.0 + i as f64 * 0.5));
        let (p50, p95, p99, max) = latency_quantiles(&xs);
        for (est, p) in [(p50, 50.0), (p95, 95.0), (p99, 99.0)] {
            let exact = percentile(&xs, p);
            assert!(
                (est - exact).abs() / exact < 0.10,
                "p{p}: histogram {est} vs exact {exact}"
            );
        }
        assert_eq!(max, 70.0, "max is exact");
        assert!(p50 <= p95 && p95 <= p99 && p99 <= max);
    }

    #[test]
    fn report_round_trips_to_json() {
        let r = LoadgenReport {
            connections: 4,
            requests: 100,
            ok: 100,
            errors: 0,
            elapsed_s: 0.5,
            throughput_per_s: 200.0,
            p50_ms: 1.5,
            p95_ms: 3.0,
            p99_ms: 4.0,
            max_ms: 9.0,
        };
        let v = r.to_json();
        assert_eq!(v.get("throughput_per_s").as_f64(), Some(200.0));
        assert_eq!(v.get("p99_ms").as_f64(), Some(4.0));
        assert_eq!(v.get("errors").as_f64(), Some(0.0));
    }

    #[test]
    fn loadgen_drives_a_live_server_error_free() {
        let session = Arc::new(
            Qappa::builder()
                .backend(BackendChoice::Native)
                .space(DesignSpace::tiny())
                .train_per_type(64)
                .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
                .seed(7)
                .workers(4)
                .sigma(0.02)
                .chunk(32)
                .topk(8)
                .build(),
        );
        let mut server =
            TcpServer::bind(session.clone(), "127.0.0.1:0", TransportOptions::default())
                .unwrap();
        let addr = server.local_addr().to_string();
        let report = run_loadgen(
            &addr,
            &LoadgenOptions {
                connections: 3,
                requests: 5,
                mix: RequestMix::Mixed,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.requests, 15);
        assert_eq!(report.errors, 0);
        assert!(report.throughput_per_s > 0.0);
        assert!(report.p50_ms <= report.p99_ms && report.p99_ms <= report.max_ms);
        // warm-up plus every explore in the mix: exactly one training pass
        // (4 models) for the whole process.
        assert_eq!(session.store().misses(), 4, "models trained once across connections");
        server.shutdown();
    }
}
