//! Typed request / response structs for the `qappa::api` facade and the
//! `qappa serve` wire protocol.
//!
//! Every type round-trips losslessly through [`crate::util::json`]
//! (`to_json` → serialize → parse → `from_json` yields an equal value; the
//! JSON writer prints `f64` with Rust's shortest-round-trip formatting).
//! The schemas are documented in `docs/API.md`; `main.rs` builds requests
//! from CLI flags and renders responses, `api::serve` speaks them over
//! JSON-lines.
//!
//! Conventions:
//!
//! * configs serialize via [`AcceleratorConfig::to_json`]; request-side
//!   parsing ([`config_from_json`]) accepts partial objects — `pe_type` is
//!   required, every other field defaults from
//!   [`AcceleratorConfig::default_with`] — and validates the result;
//! * PE types serialize as their display labels (`"INT16"`,
//!   `"LightPE-1"`, …) and parse through [`PeType::parse`] (case- and
//!   alias-insensitive);
//! * malformed request payloads are [`QappaError::Protocol`] errors that
//!   name the offending field.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, MacKind, PeType};
use crate::coordinator::explorer::WorkloadSummary;
use crate::coordinator::precision::PrecisionGrid;
use crate::dataflow::{Layer, MemoStats};
use crate::obs::metrics::MetricsSnapshot;
use crate::opt::engine::GenStat;
use crate::opt::objective::Constraints;
use crate::synth::oracle::Ppa;
use crate::util::json::{obj, Json};
use crate::workloads;

// ---------------------------------------------------------------------------
// field helpers
// ---------------------------------------------------------------------------

fn proto(msg: impl Into<String>) -> QappaError {
    QappaError::Protocol(msg.into())
}

fn num_u(x: u64) -> Json {
    Json::Num(x as f64)
}

fn req_str<'a>(v: &'a Json, key: &str, what: &str) -> Result<&'a str, QappaError> {
    v.get(key)
        .as_str()
        .ok_or_else(|| proto(format!("{what}: missing or non-string field \"{key}\"")))
}

fn req_usize(v: &Json, key: &str, what: &str) -> Result<usize, QappaError> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| proto(format!("{what}: missing or non-integer field \"{key}\"")))
}

fn req_u64(v: &Json, key: &str, what: &str) -> Result<u64, QappaError> {
    Ok(req_usize(v, key, what)? as u64)
}

fn req_f64(v: &Json, key: &str, what: &str) -> Result<f64, QappaError> {
    v.get(key)
        .as_f64()
        .ok_or_else(|| proto(format!("{what}: missing or non-number field \"{key}\"")))
}

/// Optional u32: absent -> default, present-but-malformed (including
/// values past u32::MAX, which `as` would silently wrap) -> error.
fn opt_u32(v: &Json, key: &str, default: u32, what: &str) -> Result<u32, QappaError> {
    match v.get(key) {
        Json::Null => Ok(default),
        other => other
            .as_usize()
            .and_then(|x| u32::try_from(x).ok())
            .ok_or_else(|| proto(format!("{what}: field \"{key}\" must be a u32 integer"))),
    }
}

/// Optional string field: absent -> `None`, present-but-non-string -> error.
fn opt_str(v: &Json, key: &str, what: &str) -> Result<Option<String>, QappaError> {
    match v.get(key) {
        Json::Null => Ok(None),
        other => Ok(Some(
            other
                .as_str()
                .ok_or_else(|| proto(format!("{what}: \"{key}\" must be a string")))?
                .to_string(),
        )),
    }
}

/// Optional u32 field: absent -> `None`, present-but-malformed -> error.
fn opt_u32_nullable(v: &Json, key: &str, what: &str) -> Result<Option<u32>, QappaError> {
    match v.get(key) {
        Json::Null => Ok(None),
        other => other
            .as_usize()
            .and_then(|x| u32::try_from(x).ok())
            .map(Some)
            .ok_or_else(|| proto(format!("{what}: field \"{key}\" must be a u32 integer"))),
    }
}

fn pe_type_to_json(ty: PeType) -> Json {
    Json::Str(ty.label().into())
}

fn pe_type_from_json(v: &Json, what: &str) -> Result<PeType, QappaError> {
    let s = v
        .as_str()
        .ok_or_else(|| proto(format!("{what}: \"pe_type\" must be a string")))?;
    PeType::parse(s).ok_or_else(|| {
        proto(format!(
            "{what}: unknown pe_type '{s}' (expected fp32|int16|lightpe1|lightpe2 or a<act>w<wt>p<psum>[-mac])"
        ))
    })
}

/// Parse an accelerator config from a (possibly partial) request object:
/// `pe_type` is required, everything else defaults from
/// [`AcceleratorConfig::default_with`].  The result is validated.
pub fn config_from_json(v: &Json) -> Result<AcceleratorConfig, QappaError> {
    let what = "config";
    let ty = pe_type_from_json(v.get("pe_type"), what)?;
    let mut cfg = AcceleratorConfig::default_with(ty);
    cfg.pe_rows = opt_u32(v, "pe_rows", cfg.pe_rows, what)?;
    cfg.pe_cols = opt_u32(v, "pe_cols", cfg.pe_cols, what)?;
    cfg.glb_kb = opt_u32(v, "glb_kb", cfg.glb_kb, what)?;
    cfg.spad_ifmap_b = opt_u32(v, "spad_ifmap_b", cfg.spad_ifmap_b, what)?;
    cfg.spad_filter_b = opt_u32(v, "spad_filter_b", cfg.spad_filter_b, what)?;
    cfg.spad_psum_b = opt_u32(v, "spad_psum_b", cfg.spad_psum_b, what)?;
    cfg.bandwidth_gbps = match v.get("bandwidth_gbps") {
        Json::Null => cfg.bandwidth_gbps,
        other => other
            .as_f64()
            .ok_or_else(|| proto(format!("{what}: field \"bandwidth_gbps\" must be a number")))?,
    };
    cfg.validate()?;
    Ok(cfg)
}

fn ppa_to_json(p: &Ppa) -> Json {
    obj(vec![
        ("power_mw", Json::Num(p.power_mw)),
        ("fmax_mhz", Json::Num(p.fmax_mhz)),
        ("area_mm2", Json::Num(p.area_mm2)),
    ])
}

fn ppa_from_json(v: &Json, what: &str) -> Result<Ppa, QappaError> {
    Ok(Ppa {
        power_mw: req_f64(v, "power_mw", what)?,
        fmax_mhz: req_f64(v, "fmax_mhz", what)?,
        area_mm2: req_f64(v, "area_mm2", what)?,
    })
}

// ---------------------------------------------------------------------------
// synth
// ---------------------------------------------------------------------------

/// `synth`: ground-truth PPA for one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthRequest {
    pub config: AcceleratorConfig,
}

impl SynthRequest {
    pub fn to_json(&self) -> Json {
        obj(vec![("config", self.config.to_json())])
    }

    pub fn from_json(v: &Json) -> Result<SynthRequest, QappaError> {
        Ok(SynthRequest { config: config_from_json(v.get("config"))? })
    }
}

/// `synth` result: the jittered (tool-realistic) and jitter-free PPA.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthResponse {
    pub config: AcceleratorConfig,
    pub synthesized: Ppa,
    pub jitter_free: Ppa,
}

impl SynthResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("config", self.config.to_json()),
            ("synthesized", ppa_to_json(&self.synthesized)),
            ("jitter_free", ppa_to_json(&self.jitter_free)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SynthResponse, QappaError> {
        Ok(SynthResponse {
            config: config_from_json(v.get("config"))?,
            synthesized: ppa_from_json(v.get("synthesized"), "synth.synthesized")?,
            jitter_free: ppa_from_json(v.get("jitter_free"), "synth.jitter_free")?,
        })
    }
}

// ---------------------------------------------------------------------------
// fit
// ---------------------------------------------------------------------------

/// `fit`: train (or fetch from the session's `ModelStore`) the PPA models.
/// An empty `pe_types` list means all four types.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FitRequest {
    pub pe_types: Vec<PeType>,
}

impl FitRequest {
    pub fn to_json(&self) -> Json {
        if self.pe_types.is_empty() {
            return obj(vec![]);
        }
        obj(vec![(
            "pe_types",
            Json::Arr(self.pe_types.iter().map(|&t| pe_type_to_json(t)).collect()),
        )])
    }

    pub fn from_json(v: &Json) -> Result<FitRequest, QappaError> {
        let mut pe_types = Vec::new();
        match v.get("pe_types") {
            Json::Null => {}
            Json::Arr(items) => {
                for item in items {
                    pe_types.push(pe_type_from_json(item, "fit.pe_types")?);
                }
            }
            _ => return Err(proto("fit: \"pe_types\" must be an array of PE-type names")),
        }
        Ok(FitRequest { pe_types })
    }
}

/// One (degree, lambda) CV grid entry.
#[derive(Debug, Clone, PartialEq)]
pub struct CvPoint {
    pub degree: usize,
    pub lambda: f64,
    pub mse: f64,
}

/// The selected model for one PE type, with its CV table.
#[derive(Debug, Clone, PartialEq)]
pub struct FitModelReport {
    pub pe_type: PeType,
    pub degree: usize,
    pub lambda: f64,
    pub n_train: usize,
    pub cv: Vec<CvPoint>,
}

impl FitModelReport {
    fn to_json(&self) -> Json {
        obj(vec![
            ("pe_type", pe_type_to_json(self.pe_type)),
            ("degree", num_u(self.degree as u64)),
            ("lambda", Json::Num(self.lambda)),
            ("n_train", num_u(self.n_train as u64)),
            (
                "cv",
                Json::Arr(
                    self.cv
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("degree", num_u(e.degree as u64)),
                                ("lambda", Json::Num(e.lambda)),
                                ("mse", Json::Num(e.mse)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<FitModelReport, QappaError> {
        let what = "fit.models[]";
        let cv_arr = v
            .get("cv")
            .as_arr()
            .ok_or_else(|| proto(format!("{what}: missing \"cv\" array")))?;
        let mut cv = Vec::with_capacity(cv_arr.len());
        for e in cv_arr {
            cv.push(CvPoint {
                degree: req_usize(e, "degree", "fit.cv[]")?,
                lambda: req_f64(e, "lambda", "fit.cv[]")?,
                mse: req_f64(e, "mse", "fit.cv[]")?,
            });
        }
        Ok(FitModelReport {
            pe_type: pe_type_from_json(v.get("pe_type"), what)?,
            degree: req_usize(v, "degree", what)?,
            lambda: req_f64(v, "lambda", what)?,
            n_train: req_usize(v, "n_train", what)?,
            cv,
        })
    }
}

/// `fit` result: the backend that trained and one report per PE type.
#[derive(Debug, Clone, PartialEq)]
pub struct FitResponse {
    pub backend: String,
    pub models: Vec<FitModelReport>,
}

impl FitResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("backend", Json::Str(self.backend.clone())),
            ("models", Json::Arr(self.models.iter().map(|m| m.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FitResponse, QappaError> {
        let arr = v
            .get("models")
            .as_arr()
            .ok_or_else(|| proto("fit: missing \"models\" array"))?;
        let mut models = Vec::with_capacity(arr.len());
        for m in arr {
            models.push(FitModelReport::from_json(m)?);
        }
        Ok(FitResponse { backend: req_str(v, "backend", "fit")?.to_string(), models })
    }
}

// ---------------------------------------------------------------------------
// explore
// ---------------------------------------------------------------------------

/// Precision axes of an `explore` request: explicit bit-width lists per
/// operand (`psum_bits` empty = automatic accumulator widths), a MAC
/// datapath kind, and/or explicit precision selectors by label
/// (`"a8w4p20-light1"`, `"int16"`).  Resolves to a validated
/// [`PrecisionGrid`] — width violations are config errors naming the
/// offending field.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionRequest {
    pub act_bits: Vec<u32>,
    pub wt_bits: Vec<u32>,
    /// Empty = automatic accumulator widths ([`crate::config::auto_psum`]).
    pub psum_bits: Vec<u32>,
    pub mac: MacKind,
    /// Explicit precision cells by label, appended after the range cross
    /// product (either source may be empty, not both).
    pub types: Vec<String>,
}

impl Default for PrecisionRequest {
    fn default() -> PrecisionRequest {
        PrecisionRequest {
            act_bits: Vec::new(),
            wt_bits: Vec::new(),
            psum_bits: Vec::new(),
            mac: MacKind::IntExact,
            types: Vec::new(),
        }
    }
}

impl PrecisionRequest {
    /// Resolve into the validated precision grid the DSE sweeps.
    pub fn resolve(&self) -> Result<PrecisionGrid, QappaError> {
        let mut cells = Vec::new();
        if !self.act_bits.is_empty() || !self.wt_bits.is_empty() {
            if self.act_bits.is_empty() || self.wt_bits.is_empty() {
                return Err(QappaError::Config(
                    "precision: act_bits and wt_bits must both be given for a range grid".into(),
                ));
            }
            cells.extend(
                PrecisionGrid::from_ranges(&self.act_bits, &self.wt_bits, &self.psum_bits, self.mac)?
                    .types,
            );
        }
        for label in &self.types {
            let ty = PeType::parse(label).ok_or_else(|| {
                QappaError::Config(format!(
                    "precision: unknown precision '{label}' (expected a preset name or a<act>w<wt>p<psum>[-mac])"
                ))
            })?;
            cells.push(ty);
        }
        PrecisionGrid::new(cells)
    }

    pub fn to_json(&self) -> Json {
        let bits = |v: &Vec<u32>| Json::Arr(v.iter().map(|&b| num_u(b as u64)).collect());
        let mut pairs = Vec::new();
        if !self.act_bits.is_empty() {
            pairs.push(("act_bits", bits(&self.act_bits)));
        }
        if !self.wt_bits.is_empty() {
            pairs.push(("wt_bits", bits(&self.wt_bits)));
        }
        if !self.psum_bits.is_empty() {
            pairs.push(("psum_bits", bits(&self.psum_bits)));
        }
        pairs.push(("mac", Json::Str(self.mac.suffix())));
        if !self.types.is_empty() {
            pairs.push((
                "types",
                Json::Arr(self.types.iter().map(|t| Json::Str(t.clone())).collect()),
            ));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<PrecisionRequest, QappaError> {
        let what = "explore.precision";
        if v.as_obj().is_none() {
            return Err(proto(format!("{what} must be an object")));
        }
        let bits_field = |key: &str| -> Result<Vec<u32>, QappaError> {
            match v.get(key) {
                Json::Null => Ok(Vec::new()),
                Json::Arr(items) => {
                    let mut out = Vec::with_capacity(items.len());
                    for item in items {
                        out.push(item.as_usize().and_then(|x| u32::try_from(x).ok()).ok_or_else(
                            || proto(format!("{what}: \"{key}\" entries must be u32 bit widths")),
                        )?);
                    }
                    Ok(out)
                }
                _ => Err(proto(format!("{what}: \"{key}\" must be an array of bit widths"))),
            }
        };
        let mac = match v.get("mac") {
            Json::Null => MacKind::IntExact,
            other => {
                let s = other
                    .as_str()
                    .ok_or_else(|| proto(format!("{what}: \"mac\" must be a string")))?;
                MacKind::parse(&s.to_ascii_lowercase()).ok_or_else(|| {
                    proto(format!("{what}: unknown mac '{s}' (expected fp|int|light<n>)"))
                })?
            }
        };
        let mut types = Vec::new();
        match v.get("types") {
            Json::Null => {}
            Json::Arr(items) => {
                for item in items {
                    types.push(
                        item.as_str()
                            .ok_or_else(|| proto(format!("{what}: \"types\" entries must be strings")))?
                            .to_string(),
                    );
                }
            }
            _ => return Err(proto(format!("{what}: \"types\" must be an array of labels"))),
        }
        Ok(PrecisionRequest {
            act_bits: bits_field("act_bits")?,
            wt_bits: bits_field("wt_bits")?,
            psum_bits: bits_field("psum_bits")?,
            mac,
            types,
        })
    }
}

/// `explore`: design-space exploration over one or more workloads (built-in
/// names or JSON model file paths) in a single streaming pass.  With a
/// `precision` block the sweep runs over the requested precision grid
/// (unified cross-precision model, one row per precision cell) instead of
/// the four preset PE types.
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreRequest {
    pub workloads: Vec<String>,
    pub precision: Option<PrecisionRequest>,
}

impl ExploreRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![(
            "workloads",
            Json::Arr(self.workloads.iter().map(|w| Json::Str(w.clone())).collect()),
        )];
        if let Some(p) = &self.precision {
            pairs.push(("precision", p.to_json()));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<ExploreRequest, QappaError> {
        let arr = v
            .get("workloads")
            .as_arr()
            .ok_or_else(|| proto("explore: missing \"workloads\" array"))?;
        let mut workloads = Vec::with_capacity(arr.len());
        for w in arr {
            workloads.push(
                w.as_str()
                    .ok_or_else(|| proto("explore: \"workloads\" entries must be strings"))?
                    .to_string(),
            );
        }
        if workloads.is_empty() {
            return Err(proto("explore: \"workloads\" must not be empty"));
        }
        let precision = match v.get("precision") {
            Json::Null => None,
            other => Some(PrecisionRequest::from_json(other)?),
        };
        Ok(ExploreRequest { workloads, precision })
    }
}

/// Per-PE-type exploration result: anchor-normalized ratios (predicted and
/// winner-validated), frontier size, engine counters and the best config.
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreEntry {
    pub pe_type: PeType,
    /// Best perf/area relative to the INT16 anchor (model-predicted).
    pub perf_per_area: f64,
    /// The same ratio with the winning configs re-synthesized (honest
    /// post-selection numbers).
    pub perf_per_area_validated: f64,
    /// Energy-improvement ratio vs the anchor (model-predicted).
    pub energy: f64,
    pub energy_validated: f64,
    /// Pareto-frontier size.
    pub frontier: usize,
    /// Evaluated grid points.
    pub evaluated: usize,
    /// Streaming shards processed.
    pub shards: usize,
    /// Peak resident point count (the streaming-memory guarantee).
    pub peak_resident: usize,
    /// Best perf/area configuration.
    pub best: AcceleratorConfig,
}

impl ExploreEntry {
    fn to_json(&self) -> Json {
        obj(vec![
            ("pe_type", pe_type_to_json(self.pe_type)),
            ("perf_per_area", Json::Num(self.perf_per_area)),
            ("perf_per_area_validated", Json::Num(self.perf_per_area_validated)),
            ("energy", Json::Num(self.energy)),
            ("energy_validated", Json::Num(self.energy_validated)),
            ("frontier", num_u(self.frontier as u64)),
            ("evaluated", num_u(self.evaluated as u64)),
            ("shards", num_u(self.shards as u64)),
            ("peak_resident", num_u(self.peak_resident as u64)),
            ("best", self.best.to_json()),
        ])
    }

    fn from_json(v: &Json) -> Result<ExploreEntry, QappaError> {
        let what = "explore.entries[]";
        Ok(ExploreEntry {
            pe_type: pe_type_from_json(v.get("pe_type"), what)?,
            perf_per_area: req_f64(v, "perf_per_area", what)?,
            perf_per_area_validated: req_f64(v, "perf_per_area_validated", what)?,
            energy: req_f64(v, "energy", what)?,
            energy_validated: req_f64(v, "energy_validated", what)?,
            frontier: req_usize(v, "frontier", what)?,
            evaluated: req_usize(v, "evaluated", what)?,
            shards: req_usize(v, "shards", what)?,
            peak_resident: req_usize(v, "peak_resident", what)?,
            best: config_from_json(v.get("best"))?,
        })
    }
}

/// One workload's exploration summary.
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreSummary {
    pub workload: String,
    /// The INT16 anchor config (best predicted perf/area).
    pub anchor: AcceleratorConfig,
    pub entries: Vec<ExploreEntry>,
}

impl ExploreSummary {
    fn to_json(&self) -> Json {
        obj(vec![
            ("workload", Json::Str(self.workload.clone())),
            ("anchor", self.anchor.to_json()),
            ("entries", Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())),
        ])
    }

    fn from_json(v: &Json) -> Result<ExploreSummary, QappaError> {
        let arr = v
            .get("entries")
            .as_arr()
            .ok_or_else(|| proto("explore.summaries[]: missing \"entries\" array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            entries.push(ExploreEntry::from_json(e)?);
        }
        Ok(ExploreSummary {
            workload: req_str(v, "workload", "explore.summaries[]")?.to_string(),
            anchor: config_from_json(v.get("anchor"))?,
            entries,
        })
    }
}

/// `explore` result: one summary per requested workload, input order.
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreResponse {
    pub summaries: Vec<ExploreSummary>,
}

impl ExploreResponse {
    /// Condense streaming [`WorkloadSummary`]s into the wire shape.  The
    /// entry set follows the summaries' own precision keys (the four
    /// presets for a classic run — `BTreeMap` order equals the historical
    /// `ALL_PE_TYPES` order — or the precision grid's cells for a
    /// precision-grid run).
    pub fn from_summaries(summaries: &[WorkloadSummary]) -> Result<ExploreResponse, QappaError> {
        let mut out = Vec::with_capacity(summaries.len());
        for s in summaries {
            let mut entries = Vec::with_capacity(s.ratios.len());
            for (&ty, &(pa, e)) in &s.ratios {
                let (pav, ev) = s.ratios_validated[&ty];
                let st = &s.stats[&ty];
                let best = s.top_perf_per_area[&ty].first().ok_or_else(|| {
                    QappaError::Model(format!("empty {} reservoir for '{}'", ty.label(), s.workload))
                })?;
                entries.push(ExploreEntry {
                    pe_type: ty,
                    perf_per_area: pa,
                    perf_per_area_validated: pav,
                    energy: e,
                    energy_validated: ev,
                    frontier: s.frontier[&ty].len(),
                    evaluated: st.evaluated,
                    shards: st.shards,
                    peak_resident: st.peak_resident,
                    best: best.cfg,
                });
            }
            out.push(ExploreSummary {
                workload: s.workload.clone(),
                anchor: s.anchor.cfg,
                entries,
            });
        }
        Ok(ExploreResponse { summaries: out })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![(
            "summaries",
            Json::Arr(self.summaries.iter().map(|s| s.to_json()).collect()),
        )])
    }

    pub fn from_json(v: &Json) -> Result<ExploreResponse, QappaError> {
        let arr = v
            .get("summaries")
            .as_arr()
            .ok_or_else(|| proto("explore: missing \"summaries\" array"))?;
        let mut summaries = Vec::with_capacity(arr.len());
        for s in arr {
            summaries.push(ExploreSummary::from_json(s)?);
        }
        Ok(ExploreResponse { summaries })
    }
}

// ---------------------------------------------------------------------------
// optimize
// ---------------------------------------------------------------------------

fn opt_usize(v: &Json, key: &str, what: &str) -> Result<Option<usize>, QappaError> {
    match v.get(key) {
        Json::Null => Ok(None),
        other => other.as_usize().map(Some).ok_or_else(|| {
            proto(format!("{what}: field \"{key}\" must be a non-negative integer"))
        }),
    }
}

fn opt_f64(v: &Json, key: &str, what: &str) -> Result<Option<f64>, QappaError> {
    match v.get(key) {
        Json::Null => Ok(None),
        other => other
            .as_f64()
            .map(Some)
            .ok_or_else(|| proto(format!("{what}: field \"{key}\" must be a number"))),
    }
}

fn opt_bool(v: &Json, key: &str, what: &str) -> Result<Option<bool>, QappaError> {
    match v.get(key) {
        Json::Null => Ok(None),
        other => other
            .as_bool()
            .map(Some)
            .ok_or_else(|| proto(format!("{what}: field \"{key}\" must be a boolean"))),
    }
}

fn str_list(v: &Json, key: &str, what: &str) -> Result<Vec<String>, QappaError> {
    match v.get(key) {
        Json::Null => Ok(Vec::new()),
        Json::Arr(items) => {
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                out.push(
                    item.as_str()
                        .ok_or_else(|| {
                            proto(format!("{what}: \"{key}\" entries must be strings"))
                        })?
                        .to_string(),
                );
            }
            Ok(out)
        }
        _ => Err(proto(format!("{what}: \"{key}\" must be an array of strings"))),
    }
}

fn constraints_to_json(c: &Constraints) -> Json {
    let mut pairs = Vec::new();
    if let Some(x) = c.max_area_mm2 {
        pairs.push(("max_area_mm2", Json::Num(x)));
    }
    if let Some(x) = c.max_power_mw {
        pairs.push(("max_power_mw", Json::Num(x)));
    }
    if let Some(x) = c.max_latency_ms {
        pairs.push(("max_latency_ms", Json::Num(x)));
    }
    if let Some(b) = c.min_bits {
        pairs.push(("min_bits", num_u(b as u64)));
    }
    if let Some(a) = c.min_accuracy {
        pairs.push(("min_accuracy", Json::Num(a)));
    }
    obj(pairs)
}

fn constraints_from_json(v: &Json, what: &str) -> Result<Constraints, QappaError> {
    if matches!(v, Json::Null) {
        return Ok(Constraints::default());
    }
    if v.as_obj().is_none() {
        return Err(proto(format!("{what}: \"constraints\" must be an object")));
    }
    let min_bits = match v.get("min_bits") {
        Json::Null => None,
        other => Some(
            other
                .as_usize()
                .and_then(|x| u32::try_from(x).ok())
                .ok_or_else(|| proto(format!("{what}: \"min_bits\" must be a u32 integer")))?,
        ),
    };
    Ok(Constraints {
        max_area_mm2: opt_f64(v, "max_area_mm2", what)?,
        max_power_mw: opt_f64(v, "max_power_mw", what)?,
        max_latency_ms: opt_f64(v, "max_latency_ms", what)?,
        min_bits,
        min_accuracy: opt_f64(v, "min_accuracy", what)?,
    })
}

/// `optimize`: guided multi-objective search over (hardware config,
/// per-layer precision) for one workload, under hard constraints and an
/// evaluation budget (`docs/OPTIMIZER.md`).  Empty `objectives` means the
/// classic pair `["perf/area", "energy"]`; absent knobs default in the
/// session (strategy `nsga2`, budget 20000, population 64, seed = the
/// session seed, per-layer assignment on when the palette offers a
/// choice).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OptimizeRequest {
    pub workload: String,
    /// Two or three objective names once resolved (empty = the default
    /// pair; a third slot is typically `accuracy`).
    pub objectives: Vec<String>,
    pub constraints: Constraints,
    /// Measured per-layer quantization-sensitivity table (the JSON schema
    /// of `docs/ACCURACY.md`), embedded verbatim.  Absent = the built-in
    /// noise-model proxy whenever accuracy is requested.  Serialized only
    /// when set, keeping classic requests byte-identical.
    pub sensitivity: Option<Json>,
    /// Model width-multiplier axis (channel scaling, each in `(0, 1]`).
    /// Non-empty adds model-side knobs to the genome; serialized only
    /// when non-empty.
    pub width_mults: Vec<f64>,
    /// Model depth-multiplier axis (block/layer scaling, each in
    /// `(0, 1]`); same rules as `width_mults`.
    pub depth_mults: Vec<f64>,
    /// `nsga2` (default) | `random` | `hillclimb`.
    pub strategy: Option<String>,
    /// Distinct-evaluation budget.
    pub budget: Option<usize>,
    /// Population / batch size.
    pub pop: Option<usize>,
    /// Search seed (default: the session's DSE seed).
    pub seed: Option<u64>,
    /// Per-layer precision assignment (default: on when the palette has
    /// more than one cell).
    pub per_layer: Option<bool>,
    /// Precision palette (same schema as `explore`); absent = the four
    /// preset PE types.
    pub precision: Option<PrecisionRequest>,
    /// Inference phase for transformer workloads (`prefill` or `decode`;
    /// `both` is rejected — pick the phase to optimize for).  Absent =
    /// the workload's built-in shape; an error on pure-CNN workloads.
    pub phase: Option<String>,
    /// Context length for phase shaping (default
    /// [`workloads::transformer::DEFAULT_CTX`]).
    pub ctx: Option<u32>,
}

impl OptimizeRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("workload", Json::Str(self.workload.clone()))];
        if !self.objectives.is_empty() {
            pairs.push((
                "objectives",
                Json::Arr(self.objectives.iter().map(|o| Json::Str(o.clone())).collect()),
            ));
        }
        if !self.constraints.is_empty() {
            pairs.push(("constraints", constraints_to_json(&self.constraints)));
        }
        if let Some(t) = &self.sensitivity {
            pairs.push(("sensitivity", t.clone()));
        }
        if !self.width_mults.is_empty() {
            pairs.push((
                "width_mults",
                Json::Arr(self.width_mults.iter().map(|&x| Json::Num(x)).collect()),
            ));
        }
        if !self.depth_mults.is_empty() {
            pairs.push((
                "depth_mults",
                Json::Arr(self.depth_mults.iter().map(|&x| Json::Num(x)).collect()),
            ));
        }
        if let Some(s) = &self.strategy {
            pairs.push(("strategy", Json::Str(s.clone())));
        }
        if let Some(b) = self.budget {
            pairs.push(("budget", num_u(b as u64)));
        }
        if let Some(p) = self.pop {
            pairs.push(("pop", num_u(p as u64)));
        }
        if let Some(s) = self.seed {
            pairs.push(("seed", num_u(s)));
        }
        if let Some(p) = self.per_layer {
            pairs.push(("per_layer", Json::Bool(p)));
        }
        if let Some(p) = &self.precision {
            pairs.push(("precision", p.to_json()));
        }
        if let Some(p) = &self.phase {
            pairs.push(("phase", Json::Str(p.clone())));
        }
        if let Some(c) = self.ctx {
            pairs.push(("ctx", num_u(c as u64)));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<OptimizeRequest, QappaError> {
        let what = "optimize";
        let strategy = match v.get("strategy") {
            Json::Null => None,
            other => Some(
                other
                    .as_str()
                    .ok_or_else(|| proto(format!("{what}: \"strategy\" must be a string")))?
                    .to_string(),
            ),
        };
        let precision = match v.get("precision") {
            Json::Null => None,
            other => Some(PrecisionRequest::from_json(other)?),
        };
        let sensitivity = match v.get("sensitivity") {
            Json::Null => None,
            other if other.as_obj().is_some() => Some(other.clone()),
            _ => {
                return Err(proto(format!(
                    "{what}: \"sensitivity\" must be a sensitivity-table object"
                )))
            }
        };
        let mult_axis = |key: &str| -> Result<Vec<f64>, QappaError> {
            match v.get(key) {
                Json::Null => Ok(Vec::new()),
                other => other
                    .as_f64_vec()
                    .ok_or_else(|| proto(format!("{what}: \"{key}\" must be a number array"))),
            }
        };
        Ok(OptimizeRequest {
            workload: req_str(v, "workload", what)?.to_string(),
            objectives: str_list(v, "objectives", what)?,
            constraints: constraints_from_json(v.get("constraints"), what)?,
            sensitivity,
            width_mults: mult_axis("width_mults")?,
            depth_mults: mult_axis("depth_mults")?,
            strategy,
            budget: opt_usize(v, "budget", what)?,
            pop: opt_usize(v, "pop", what)?,
            seed: opt_usize(v, "seed", what)?.map(|x| x as u64),
            per_layer: opt_bool(v, "per_layer", what)?,
            precision,
            phase: opt_str(v, "phase", what)?,
            ctx: opt_u32_nullable(v, "ctx", what)?,
        })
    }
}

/// One frontier member of an [`OptimizeResponse`].
#[derive(Debug, Clone, PartialEq)]
pub struct OptPoint {
    pub config: AcceleratorConfig,
    /// Minimized objective values, request order.
    pub objectives: Vec<f64>,
    /// Inferences/s on the workload.
    pub throughput: f64,
    /// Energy per inference, mJ.
    pub energy_mj: f64,
    /// Predicted array PPA.
    pub ppa: Ppa,
    /// Precision labels: one per layer (mixed designs), or a single
    /// uniform label.
    pub precision: Vec<String>,
    /// Estimated top-1 accuracy (fraction of the fp32 baseline); present
    /// iff the run carried an accuracy objective or constraint.  Absent
    /// on the wire otherwise, keeping classic responses byte-identical.
    pub accuracy: Option<f64>,
}

impl OptPoint {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("config", self.config.to_json()),
            (
                "objectives",
                Json::Arr(self.objectives.iter().map(|&x| Json::Num(x)).collect()),
            ),
            ("throughput", Json::Num(self.throughput)),
            ("energy_mj", Json::Num(self.energy_mj)),
            ("ppa", ppa_to_json(&self.ppa)),
            (
                "precision",
                Json::Arr(self.precision.iter().map(|p| Json::Str(p.clone())).collect()),
            ),
        ];
        if let Some(a) = self.accuracy {
            pairs.push(("accuracy", Json::Num(a)));
        }
        obj(pairs)
    }

    fn from_json(v: &Json) -> Result<OptPoint, QappaError> {
        let what = "optimize.frontier[]";
        let objectives = v
            .get("objectives")
            .as_f64_vec()
            .ok_or_else(|| proto(format!("{what}: missing \"objectives\" number array")))?;
        Ok(OptPoint {
            config: config_from_json(v.get("config"))?,
            objectives,
            throughput: req_f64(v, "throughput", what)?,
            energy_mj: req_f64(v, "energy_mj", what)?,
            ppa: ppa_from_json(v.get("ppa"), "optimize.ppa")?,
            precision: str_list(v, "precision", what)?,
            accuracy: opt_f64(v, "accuracy", what)?,
        })
    }
}

fn gen_stat_to_json(g: &GenStat) -> Json {
    obj(vec![
        ("generation", num_u(g.generation as u64)),
        ("evaluated", num_u(g.evaluated as u64)),
        ("frontier", num_u(g.frontier as u64)),
        ("hypervolume", Json::Num(g.hypervolume)),
        ("best", Json::Arr(g.best.iter().map(|&x| Json::Num(x)).collect())),
    ])
}

fn gen_stat_from_json(v: &Json) -> Result<GenStat, QappaError> {
    let what = "optimize.generations[]";
    let best = v
        .get("best")
        .as_f64_vec()
        .filter(|b| (2..=3).contains(&b.len()))
        .ok_or_else(|| proto(format!("{what}: \"best\" must be a 2- or 3-number array")))?;
    Ok(GenStat {
        generation: req_usize(v, "generation", what)?,
        evaluated: req_usize(v, "evaluated", what)?,
        frontier: req_usize(v, "frontier", what)?,
        hypervolume: req_f64(v, "hypervolume", what)?,
        best,
    })
}

/// `optimize` result: the feasible Pareto frontier found within budget,
/// generation-by-generation convergence stats and the run's hypervolume
/// (w.r.t. `ref_point`, the reference corner fixed after the first batch).
#[derive(Debug, Clone, PartialEq)]
pub struct OptimizeResponse {
    pub workload: String,
    pub strategy: String,
    /// Canonical objective names, request order.
    pub objectives: Vec<String>,
    /// Distinct evaluations spent.
    pub evaluated: usize,
    /// The requested budget (spend cap).
    pub budget: usize,
    /// Reference corner in minimized-objective space.
    pub ref_point: Vec<f64>,
    /// Final archive hypervolume w.r.t. `ref_point`.
    pub hypervolume: f64,
    /// Frontier sorted by the first objective ascending.
    pub frontier: Vec<OptPoint>,
    pub generations: Vec<GenStat>,
    /// Evaluation-memo counters (layer-cost + synthesis caches).  Optional
    /// on the wire for compatibility: absent means all-zero (a legacy-path
    /// run, or a peer predating the field).
    pub memo: MemoStats,
}

impl OptimizeResponse {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("workload", Json::Str(self.workload.clone())),
            ("strategy", Json::Str(self.strategy.clone())),
            (
                "objectives",
                Json::Arr(self.objectives.iter().map(|o| Json::Str(o.clone())).collect()),
            ),
            ("evaluated", num_u(self.evaluated as u64)),
            ("budget", num_u(self.budget as u64)),
            (
                "ref_point",
                Json::Arr(self.ref_point.iter().map(|&x| Json::Num(x)).collect()),
            ),
            ("hypervolume", Json::Num(self.hypervolume)),
            (
                "frontier",
                Json::Arr(self.frontier.iter().map(|p| p.to_json()).collect()),
            ),
            (
                "generations",
                Json::Arr(self.generations.iter().map(gen_stat_to_json).collect()),
            ),
            (
                "memo",
                obj(vec![
                    ("cost_hits", num_u(self.memo.cost_hits)),
                    ("cost_misses", num_u(self.memo.cost_misses)),
                    ("synth_hits", num_u(self.memo.synth_hits)),
                    ("synth_misses", num_u(self.memo.synth_misses)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<OptimizeResponse, QappaError> {
        let what = "optimize";
        let frontier_arr = v
            .get("frontier")
            .as_arr()
            .ok_or_else(|| proto(format!("{what}: missing \"frontier\" array")))?;
        let mut frontier = Vec::with_capacity(frontier_arr.len());
        for p in frontier_arr {
            frontier.push(OptPoint::from_json(p)?);
        }
        let gen_arr = v
            .get("generations")
            .as_arr()
            .ok_or_else(|| proto(format!("{what}: missing \"generations\" array")))?;
        let mut generations = Vec::with_capacity(gen_arr.len());
        for g in gen_arr {
            generations.push(gen_stat_from_json(g)?);
        }
        let ref_point = v
            .get("ref_point")
            .as_f64_vec()
            .ok_or_else(|| proto(format!("{what}: missing \"ref_point\" number array")))?;
        // Optional for wire compatibility: absent → all-zero counters.
        let m = v.get("memo");
        let count = |key: &str| m.get(key).as_f64().unwrap_or(0.0) as u64;
        let memo = MemoStats {
            cost_hits: count("cost_hits"),
            cost_misses: count("cost_misses"),
            synth_hits: count("synth_hits"),
            synth_misses: count("synth_misses"),
        };
        Ok(OptimizeResponse {
            workload: req_str(v, "workload", what)?.to_string(),
            strategy: req_str(v, "strategy", what)?.to_string(),
            objectives: str_list(v, "objectives", what)?,
            evaluated: req_usize(v, "evaluated", what)?,
            budget: req_usize(v, "budget", what)?,
            ref_point,
            hypervolume: req_f64(v, "hypervolume", what)?,
            frontier,
            generations,
            memo,
        })
    }
}

// ---------------------------------------------------------------------------
// analyze
// ---------------------------------------------------------------------------

/// `analyze`: per-layer latency/energy breakdown of one workload on one
/// configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalyzeRequest {
    pub workload: String,
    pub config: AcceleratorConfig,
    /// Inference phase for transformer workloads (`prefill|decode|both`);
    /// absent keeps the workload's built-in shape and is required to stay
    /// absent for pure-CNN workloads.  Serialized only when set, so plain
    /// `analyze` requests stay byte-identical on the wire.
    pub phase: Option<String>,
    /// Context length for phase shaping (default
    /// [`workloads::transformer::DEFAULT_CTX`]).
    pub ctx: Option<u32>,
    /// Opt-in accuracy estimate: `true` attaches the noise-model proxy's
    /// accuracy prediction to the response.  Serialized only when set, so
    /// classic requests stay byte-identical on the wire.
    pub accuracy: Option<bool>,
}

impl AnalyzeRequest {
    /// Phase-less request (the CNN-era constructor shape).
    pub fn new(workload: impl Into<String>, config: AcceleratorConfig) -> AnalyzeRequest {
        AnalyzeRequest { workload: workload.into(), config, phase: None, ctx: None, accuracy: None }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("workload", Json::Str(self.workload.clone())),
            ("config", self.config.to_json()),
        ];
        if let Some(p) = &self.phase {
            pairs.push(("phase", Json::Str(p.clone())));
        }
        if let Some(c) = self.ctx {
            pairs.push(("ctx", num_u(c as u64)));
        }
        if let Some(a) = self.accuracy {
            pairs.push(("accuracy", Json::Bool(a)));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<AnalyzeRequest, QappaError> {
        Ok(AnalyzeRequest {
            workload: req_str(v, "workload", "analyze")?.to_string(),
            config: config_from_json(v.get("config"))?,
            phase: opt_str(v, "phase", "analyze")?,
            ctx: opt_u32_nullable(v, "ctx", "analyze")?,
            accuracy: opt_bool(v, "accuracy", "analyze")?,
        })
    }
}

/// Per-layer cost row of an [`AnalyzeResponse`].
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCost {
    pub name: String,
    pub macs: u64,
    pub cycles: u64,
    pub stall_cycles: u64,
    pub utilization: f64,
    pub dram_bytes: u64,
    pub compute_mj: f64,
    pub dram_mj: f64,
    /// GLB + NoC + leakage energy.
    pub other_mj: f64,
    pub total_mj: f64,
    /// Precision label when the layer carried a per-layer override
    /// (mixed-precision networks); absent on the wire otherwise, keeping
    /// plain `analyze` responses byte-identical.
    pub precision: Option<String>,
    /// KV-cache DRAM bytes (attention layers); absent on the wire when
    /// zero, keeping CNN responses byte-identical.
    pub kv_bytes: Option<u64>,
}

impl LayerCost {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("macs", num_u(self.macs)),
            ("cycles", num_u(self.cycles)),
            ("stall_cycles", num_u(self.stall_cycles)),
            ("utilization", Json::Num(self.utilization)),
            ("dram_bytes", num_u(self.dram_bytes)),
            ("compute_mj", Json::Num(self.compute_mj)),
            ("dram_mj", Json::Num(self.dram_mj)),
            ("other_mj", Json::Num(self.other_mj)),
            ("total_mj", Json::Num(self.total_mj)),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        if let Some(kv) = self.kv_bytes {
            pairs.push(("kv_bytes", num_u(kv)));
        }
        obj(pairs)
    }

    fn from_json(v: &Json) -> Result<LayerCost, QappaError> {
        let what = "analyze.layers[]";
        let precision = opt_str(v, "precision", what)?;
        let kv_bytes = match v.get("kv_bytes") {
            Json::Null => None,
            other => Some(other.as_usize().ok_or_else(|| {
                proto(format!("{what}: \"kv_bytes\" must be a non-negative integer"))
            })? as u64),
        };
        Ok(LayerCost {
            name: req_str(v, "name", what)?.to_string(),
            macs: req_u64(v, "macs", what)?,
            cycles: req_u64(v, "cycles", what)?,
            stall_cycles: req_u64(v, "stall_cycles", what)?,
            utilization: req_f64(v, "utilization", what)?,
            dram_bytes: req_u64(v, "dram_bytes", what)?,
            compute_mj: req_f64(v, "compute_mj", what)?,
            dram_mj: req_f64(v, "dram_mj", what)?,
            other_mj: req_f64(v, "other_mj", what)?,
            total_mj: req_f64(v, "total_mj", what)?,
            precision,
            kv_bytes,
        })
    }
}

/// Per-phase latency/energy summary attached to transformer `analyze`
/// responses; absent for CNN workloads (and on the wire), keeping those
/// responses byte-identical.  `decode_*` fields are per decode step;
/// `total_*` compose the requested phase (`both` = prefill + `ctx` decode
/// steps).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSummary {
    /// Requested phase label (`prefill|decode|both`).
    pub phase: String,
    /// Context length the workload was shaped at.
    pub ctx: u32,
    /// Whole-prompt prefill latency, seconds.
    pub prefill_latency_s: f64,
    pub prefill_energy_mj: f64,
    /// Single-token decode-step latency, seconds.
    pub decode_latency_s: f64,
    pub decode_energy_mj: f64,
    /// KV-cache DRAM bytes streamed per decode step.
    pub kv_dram_bytes: u64,
    /// Latency of the requested phase (both = prefill + ctx decode steps).
    pub total_latency_s: f64,
    pub total_energy_mj: f64,
}

impl PhaseSummary {
    fn to_json(&self) -> Json {
        obj(vec![
            ("phase", Json::Str(self.phase.clone())),
            ("ctx", num_u(self.ctx as u64)),
            ("prefill_latency_s", Json::Num(self.prefill_latency_s)),
            ("prefill_energy_mj", Json::Num(self.prefill_energy_mj)),
            ("decode_latency_s", Json::Num(self.decode_latency_s)),
            ("decode_energy_mj", Json::Num(self.decode_energy_mj)),
            ("kv_dram_bytes", num_u(self.kv_dram_bytes)),
            ("total_latency_s", Json::Num(self.total_latency_s)),
            ("total_energy_mj", Json::Num(self.total_energy_mj)),
        ])
    }

    fn from_json(v: &Json) -> Result<PhaseSummary, QappaError> {
        let what = "analyze.phase";
        Ok(PhaseSummary {
            phase: req_str(v, "phase", what)?.to_string(),
            ctx: opt_u32_nullable(v, "ctx", what)?
                .ok_or_else(|| proto(format!("{what}: missing field \"ctx\"")))?,
            prefill_latency_s: req_f64(v, "prefill_latency_s", what)?,
            prefill_energy_mj: req_f64(v, "prefill_energy_mj", what)?,
            decode_latency_s: req_f64(v, "decode_latency_s", what)?,
            decode_energy_mj: req_f64(v, "decode_energy_mj", what)?,
            kv_dram_bytes: req_u64(v, "kv_dram_bytes", what)?,
            total_latency_s: req_f64(v, "total_latency_s", what)?,
            total_energy_mj: req_f64(v, "total_energy_mj", what)?,
        })
    }
}

/// `analyze` result: the jitter-free PPA of the config plus per-layer and
/// whole-network costs.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalyzeResponse {
    pub workload: String,
    pub config: AcceleratorConfig,
    pub ppa: Ppa,
    pub layers: Vec<LayerCost>,
    /// End-to-end latency, seconds per inference.  For phased transformer
    /// analyses this is the *displayed* shape's latency (prefill for
    /// `both`); see `phase` for the per-phase composition.
    pub latency_s: f64,
    /// End-to-end energy, mJ per inference.
    pub energy_mj: f64,
    /// Per-phase summary; present iff the request carried a `phase`.
    pub phase: Option<PhaseSummary>,
    /// Noise-model accuracy estimate (fraction of the fp32 baseline);
    /// present iff the request opted in with `accuracy: true`.  Absent on
    /// the wire otherwise, keeping classic responses byte-identical.
    pub accuracy: Option<f64>,
}

impl AnalyzeResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("workload", Json::Str(self.workload.clone())),
            ("config", self.config.to_json()),
            ("ppa", ppa_to_json(&self.ppa)),
            ("layers", Json::Arr(self.layers.iter().map(|l| l.to_json()).collect())),
            ("latency_s", Json::Num(self.latency_s)),
            ("energy_mj", Json::Num(self.energy_mj)),
        ];
        if let Some(p) = &self.phase {
            pairs.push(("phase", p.to_json()));
        }
        if let Some(a) = self.accuracy {
            pairs.push(("accuracy", Json::Num(a)));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<AnalyzeResponse, QappaError> {
        let arr = v
            .get("layers")
            .as_arr()
            .ok_or_else(|| proto("analyze: missing \"layers\" array"))?;
        let mut layers = Vec::with_capacity(arr.len());
        for l in arr {
            layers.push(LayerCost::from_json(l)?);
        }
        let phase = match v.get("phase") {
            Json::Null => None,
            other => Some(PhaseSummary::from_json(other)?),
        };
        Ok(AnalyzeResponse {
            workload: req_str(v, "workload", "analyze")?.to_string(),
            config: config_from_json(v.get("config"))?,
            ppa: ppa_from_json(v.get("ppa"), "analyze.ppa")?,
            layers,
            latency_s: req_f64(v, "latency_s", "analyze")?,
            energy_mj: req_f64(v, "energy_mj", "analyze")?,
            phase,
            accuracy: opt_f64(v, "accuracy", "analyze")?,
        })
    }
}

// ---------------------------------------------------------------------------
// workloads
// ---------------------------------------------------------------------------

/// `workloads`: list the built-in networks, or detail one workload
/// (built-in name or JSON model path).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WorkloadsRequest {
    pub workload: Option<String>,
}

impl WorkloadsRequest {
    pub fn to_json(&self) -> Json {
        match &self.workload {
            Some(w) => obj(vec![("workload", Json::Str(w.clone()))]),
            None => obj(vec![]),
        }
    }

    pub fn from_json(v: &Json) -> Result<WorkloadsRequest, QappaError> {
        let workload = match v.get("workload") {
            Json::Null => None,
            other => Some(
                other
                    .as_str()
                    .ok_or_else(|| proto("workloads: \"workload\" must be a string"))?
                    .to_string(),
            ),
        };
        Ok(WorkloadsRequest { workload })
    }
}

/// Listing row for one built-in network.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadInfo {
    pub name: String,
    pub layers: usize,
    pub depthwise: usize,
    pub macs: u64,
}

/// `workloads` result: a listing, or one workload's full layer table
/// (layers travel in the `docs/WORKLOADS.md` JSON schema, so the detail
/// payload is itself a loadable model file).
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadsResponse {
    List(Vec<WorkloadInfo>),
    Detail { name: String, layers: Vec<Layer> },
}

impl WorkloadsResponse {
    pub fn to_json(&self) -> Json {
        match self {
            WorkloadsResponse::List(infos) => obj(vec![(
                "list",
                Json::Arr(
                    infos
                        .iter()
                        .map(|i| {
                            obj(vec![
                                ("name", Json::Str(i.name.clone())),
                                ("layers", num_u(i.layers as u64)),
                                ("depthwise", num_u(i.depthwise as u64)),
                                ("macs", num_u(i.macs)),
                            ])
                        })
                        .collect(),
                ),
            )]),
            WorkloadsResponse::Detail { name, layers } => {
                obj(vec![("detail", workloads::to_json(name, layers))])
            }
        }
    }

    pub fn from_json(v: &Json) -> Result<WorkloadsResponse, QappaError> {
        match v.get("list") {
            Json::Null => {}
            Json::Arr(items) => {
                let mut infos = Vec::with_capacity(items.len());
                for i in items {
                    infos.push(WorkloadInfo {
                        name: req_str(i, "name", "workloads.list[]")?.to_string(),
                        layers: req_usize(i, "layers", "workloads.list[]")?,
                        depthwise: req_usize(i, "depthwise", "workloads.list[]")?,
                        macs: req_u64(i, "macs", "workloads.list[]")?,
                    });
                }
                return Ok(WorkloadsResponse::List(infos));
            }
            _ => return Err(proto("workloads: \"list\" must be an array")),
        }
        match v.get("detail") {
            Json::Null => Err(proto("workloads: expected a \"list\" or \"detail\" field")),
            detail => {
                let (name, layers) = workloads::from_json_value(detail)?;
                Ok(WorkloadsResponse::Detail { name, layers })
            }
        }
    }
}

// ---------------------------------------------------------------------------
// session introspection
// ---------------------------------------------------------------------------

/// `session`: counters of the serving session — which backend is warm and
/// how many model-training passes ran vs were served from the
/// `ModelStore` cache.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionInfo {
    /// Backend name, once lazily initialized (`None` before the first
    /// model-needing request).
    pub backend: Option<String>,
    /// Training passes actually run (`ModelStore` misses).
    pub models_trained: usize,
    /// Avoided training passes (`ModelStore` hits).
    pub cache_hits: usize,
    /// Built-in workload names.
    pub workloads: Vec<String>,
}

impl SessionInfo {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("models_trained", num_u(self.models_trained as u64)),
            ("cache_hits", num_u(self.cache_hits as u64)),
            (
                "workloads",
                Json::Arr(self.workloads.iter().map(|w| Json::Str(w.clone())).collect()),
            ),
        ];
        if let Some(b) = &self.backend {
            pairs.push(("backend", Json::Str(b.clone())));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<SessionInfo, QappaError> {
        let backend = match v.get("backend") {
            Json::Null => None,
            other => Some(
                other
                    .as_str()
                    .ok_or_else(|| proto("session: \"backend\" must be a string"))?
                    .to_string(),
            ),
        };
        let arr = v
            .get("workloads")
            .as_arr()
            .ok_or_else(|| proto("session: missing \"workloads\" array"))?;
        let mut names = Vec::with_capacity(arr.len());
        for w in arr {
            names.push(
                w.as_str()
                    .ok_or_else(|| proto("session: \"workloads\" entries must be strings"))?
                    .to_string(),
            );
        }
        Ok(SessionInfo {
            backend,
            models_trained: req_usize(v, "models_trained", "session")?,
            cache_hits: req_usize(v, "cache_hits", "session")?,
            workloads: names,
        })
    }
}

// ---------------------------------------------------------------------------
// error payload
// ---------------------------------------------------------------------------

/// Wire shape of a failed request: the stable [`QappaError::kind`] tag plus
/// the human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorBody {
    pub kind: String,
    pub message: String,
}

impl From<&QappaError> for ErrorBody {
    fn from(e: &QappaError) -> ErrorBody {
        ErrorBody { kind: e.kind().to_string(), message: e.to_string() }
    }
}

impl ErrorBody {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(self.kind.clone())),
            ("message", Json::Str(self.message.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ErrorBody, QappaError> {
        Ok(ErrorBody {
            kind: req_str(v, "kind", "error")?.to_string(),
            message: req_str(v, "message", "error")?.to_string(),
        })
    }
}

// ---------------------------------------------------------------------------
// serve envelope
// ---------------------------------------------------------------------------

/// The ops the serve loop understands, with their typed parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestBody {
    Synth(SynthRequest),
    Fit(FitRequest),
    Explore(ExploreRequest),
    Optimize(OptimizeRequest),
    Analyze(AnalyzeRequest),
    Workloads(WorkloadsRequest),
    Session,
    /// Process-wide metrics registry snapshot (`docs/OBSERVABILITY.md`).
    Metrics,
}

/// Every op name, in help/docs order.
pub const OPS: [&str; 8] =
    ["synth", "fit", "explore", "optimize", "analyze", "workloads", "session", "metrics"];

impl RequestBody {
    pub fn op(&self) -> &'static str {
        match self {
            RequestBody::Synth(_) => "synth",
            RequestBody::Fit(_) => "fit",
            RequestBody::Explore(_) => "explore",
            RequestBody::Optimize(_) => "optimize",
            RequestBody::Analyze(_) => "analyze",
            RequestBody::Workloads(_) => "workloads",
            RequestBody::Session => "session",
            RequestBody::Metrics => "metrics",
        }
    }

    pub fn from_op_params(op: &str, params: &Json) -> Result<RequestBody, QappaError> {
        match op {
            "synth" => Ok(RequestBody::Synth(SynthRequest::from_json(params)?)),
            "fit" => Ok(RequestBody::Fit(FitRequest::from_json(params)?)),
            "explore" => Ok(RequestBody::Explore(ExploreRequest::from_json(params)?)),
            "optimize" => Ok(RequestBody::Optimize(OptimizeRequest::from_json(params)?)),
            "analyze" => Ok(RequestBody::Analyze(AnalyzeRequest::from_json(params)?)),
            "workloads" => Ok(RequestBody::Workloads(WorkloadsRequest::from_json(params)?)),
            "session" => Ok(RequestBody::Session),
            "metrics" => Ok(RequestBody::Metrics),
            other => Err(proto(format!(
                "unknown op '{other}' (expected {})",
                OPS.join("|")
            ))),
        }
    }

    pub fn params_to_json(&self) -> Json {
        match self {
            RequestBody::Synth(r) => r.to_json(),
            RequestBody::Fit(r) => r.to_json(),
            RequestBody::Explore(r) => r.to_json(),
            RequestBody::Optimize(r) => r.to_json(),
            RequestBody::Analyze(r) => r.to_json(),
            RequestBody::Workloads(r) => r.to_json(),
            RequestBody::Session => obj(vec![]),
            RequestBody::Metrics => obj(vec![]),
        }
    }
}

/// One JSON-lines request: `{"id": 7, "op": "explore", "params": {...}}`.
/// `id` is optional and echoed verbatim in the response — clients that
/// pipeline concurrent requests correlate by it.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRequest {
    pub id: Option<u64>,
    pub body: RequestBody,
}

impl ServeRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = Vec::new();
        if let Some(id) = self.id {
            pairs.push(("id", num_u(id)));
        }
        pairs.push(("op", Json::Str(self.body.op().into())));
        pairs.push(("params", self.body.params_to_json()));
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<ServeRequest, QappaError> {
        if v.as_obj().is_none() {
            return Err(proto("request must be a JSON object"));
        }
        let id = match v.get("id") {
            Json::Null => None,
            other => Some(
                other
                    .as_usize()
                    .ok_or_else(|| proto("\"id\" must be a non-negative integer"))?
                    as u64,
            ),
        };
        let op = req_str(v, "op", "request")?;
        let body = RequestBody::from_op_params(op, v.get("params"))?;
        Ok(ServeRequest { id, body })
    }

    /// Parse one request line (JSON syntax errors become protocol errors).
    pub fn parse_line(line: &str) -> Result<ServeRequest, QappaError> {
        let v = Json::parse(line)?;
        ServeRequest::from_json(&v)
    }
}

/// Typed results, one variant per op.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseBody {
    Synth(SynthResponse),
    Fit(FitResponse),
    Explore(ExploreResponse),
    Optimize(OptimizeResponse),
    Analyze(AnalyzeResponse),
    Workloads(WorkloadsResponse),
    Session(SessionInfo),
    Metrics(MetricsSnapshot),
}

impl ResponseBody {
    pub fn op(&self) -> &'static str {
        match self {
            ResponseBody::Synth(_) => "synth",
            ResponseBody::Fit(_) => "fit",
            ResponseBody::Explore(_) => "explore",
            ResponseBody::Optimize(_) => "optimize",
            ResponseBody::Analyze(_) => "analyze",
            ResponseBody::Workloads(_) => "workloads",
            ResponseBody::Session(_) => "session",
            ResponseBody::Metrics(_) => "metrics",
        }
    }

    fn result_to_json(&self) -> Json {
        match self {
            ResponseBody::Synth(r) => r.to_json(),
            ResponseBody::Fit(r) => r.to_json(),
            ResponseBody::Explore(r) => r.to_json(),
            ResponseBody::Optimize(r) => r.to_json(),
            ResponseBody::Analyze(r) => r.to_json(),
            ResponseBody::Workloads(r) => r.to_json(),
            ResponseBody::Session(r) => r.to_json(),
            ResponseBody::Metrics(r) => r.to_json(),
        }
    }

    fn from_op_result(op: &str, result: &Json) -> Result<ResponseBody, QappaError> {
        match op {
            "synth" => Ok(ResponseBody::Synth(SynthResponse::from_json(result)?)),
            "fit" => Ok(ResponseBody::Fit(FitResponse::from_json(result)?)),
            "explore" => Ok(ResponseBody::Explore(ExploreResponse::from_json(result)?)),
            "optimize" => Ok(ResponseBody::Optimize(OptimizeResponse::from_json(result)?)),
            "analyze" => Ok(ResponseBody::Analyze(AnalyzeResponse::from_json(result)?)),
            "workloads" => Ok(ResponseBody::Workloads(WorkloadsResponse::from_json(result)?)),
            "session" => Ok(ResponseBody::Session(SessionInfo::from_json(result)?)),
            "metrics" => Ok(ResponseBody::Metrics(MetricsSnapshot::from_json(result)?)),
            other => Err(proto(format!("unknown response op '{other}'"))),
        }
    }
}

/// One JSON-lines response:
/// `{"id": 7, "ok": true, "op": "explore", "result": {...}}` or
/// `{"id": 7, "ok": false, "error": {"kind": "...", "message": "..."}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    pub id: Option<u64>,
    pub result: Result<ResponseBody, ErrorBody>,
}

impl ServeResponse {
    pub fn to_json(&self) -> Json {
        // Responses always carry an explicit `id` (`null` when the request
        // line didn't parse far enough to supply one) — the documented
        // wire contract, so strict clients can key on the field.
        let mut pairs = vec![("id", match self.id {
            Some(id) => num_u(id),
            None => Json::Null,
        })];
        match &self.result {
            Ok(body) => {
                pairs.push(("ok", Json::Bool(true)));
                pairs.push(("op", Json::Str(body.op().into())));
                pairs.push(("result", body.result_to_json()));
            }
            Err(e) => {
                pairs.push(("ok", Json::Bool(false)));
                pairs.push(("error", e.to_json()));
            }
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<ServeResponse, QappaError> {
        let id = match v.get("id") {
            Json::Null => None,
            other => Some(
                other
                    .as_usize()
                    .ok_or_else(|| proto("response \"id\" must be a non-negative integer"))?
                    as u64,
            ),
        };
        match v.get("ok").as_bool() {
            Some(true) => {
                let op = req_str(v, "op", "response")?;
                let body = ResponseBody::from_op_result(op, v.get("result"))?;
                Ok(ServeResponse { id, result: Ok(body) })
            }
            Some(false) => Ok(ServeResponse {
                id,
                result: Err(ErrorBody::from_json(v.get("error"))?),
            }),
            None => Err(proto("response needs a boolean \"ok\" field")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// parse(serialize(x)) == x, through actual JSON text.
    fn roundtrip_json(v: &Json) -> Json {
        Json::parse(&v.to_string()).expect("round-trip parse")
    }

    fn cfg(ty: PeType) -> AcceleratorConfig {
        let mut c = AcceleratorConfig::default_with(ty);
        c.pe_rows = 24;
        c.bandwidth_gbps = 6.5;
        c
    }

    #[test]
    fn config_roundtrip_and_partial_defaults() {
        let c = cfg(PeType::LightPe2);
        let back = config_from_json(&roundtrip_json(&c.to_json())).unwrap();
        assert_eq!(back, c);
        // partial: only pe_type -> full default config
        let partial = Json::parse(r#"{"pe_type": "int16", "pe_rows": 16}"#).unwrap();
        let got = config_from_json(&partial).unwrap();
        let mut want = AcceleratorConfig::default_with(PeType::Int16);
        want.pe_rows = 16;
        assert_eq!(got, want);
        // present-but-malformed must error, not silently default
        let bad = Json::parse(r#"{"pe_type": "int16", "glb_kb": "big"}"#).unwrap();
        assert!(config_from_json(&bad).is_err());
        // values past u32::MAX must error, not wrap modulo 2^32
        let wrap = Json::parse(r#"{"pe_type": "int16", "glb_kb": 4294967404}"#).unwrap();
        assert!(config_from_json(&wrap).is_err());
        // invalid configs are rejected at the boundary
        let zero = Json::parse(r#"{"pe_type": "int16", "pe_rows": 0}"#).unwrap();
        assert_eq!(config_from_json(&zero).unwrap_err().kind(), "config");
    }

    #[test]
    fn synth_types_roundtrip() {
        let req = SynthRequest { config: cfg(PeType::Fp32) };
        assert_eq!(SynthRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);
        let resp = SynthResponse {
            config: cfg(PeType::Fp32),
            synthesized: Ppa { power_mw: 123.456, fmax_mhz: 987.5, area_mm2: 1.2345 },
            jitter_free: Ppa { power_mw: 120.0, fmax_mhz: 990.25, area_mm2: 1.25 },
        };
        assert_eq!(SynthResponse::from_json(&roundtrip_json(&resp.to_json())).unwrap(), resp);
    }

    #[test]
    fn fit_types_roundtrip() {
        let empty = FitRequest::default();
        assert_eq!(FitRequest::from_json(&roundtrip_json(&empty.to_json())).unwrap(), empty);
        let req = FitRequest { pe_types: vec![PeType::Int16, PeType::LightPe1] };
        assert_eq!(FitRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);
        let resp = FitResponse {
            backend: "native".into(),
            models: vec![FitModelReport {
                pe_type: PeType::LightPe1,
                degree: 2,
                lambda: 1e-3,
                n_train: 384,
                cv: vec![
                    CvPoint { degree: 1, lambda: 1e-4, mse: 0.0123 },
                    CvPoint { degree: 2, lambda: 1e-3, mse: 0.0045 },
                ],
            }],
        };
        assert_eq!(FitResponse::from_json(&roundtrip_json(&resp.to_json())).unwrap(), resp);
    }

    #[test]
    fn explore_types_roundtrip() {
        let req = ExploreRequest {
            workloads: vec!["vgg16".into(), "m.json".into()],
            precision: None,
        };
        assert_eq!(ExploreRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);
        assert!(ExploreRequest::from_json(&Json::parse(r#"{"workloads": []}"#).unwrap()).is_err());
        // a plain request serializes without a "precision" key (wire-stable)
        assert!(!req.to_json().to_string().contains("precision"));

        let resp = ExploreResponse {
            summaries: vec![ExploreSummary {
                workload: "vgg16".into(),
                anchor: cfg(PeType::Int16),
                entries: vec![ExploreEntry {
                    pe_type: PeType::LightPe1,
                    perf_per_area: 4.87,
                    perf_per_area_validated: 4.12,
                    energy: 3.3,
                    energy_validated: 3.05,
                    frontier: 17,
                    evaluated: 19200,
                    shards: 19,
                    peak_resident: 1200,
                    best: cfg(PeType::LightPe1),
                }],
            }],
        };
        assert_eq!(
            ExploreResponse::from_json(&roundtrip_json(&resp.to_json())).unwrap(),
            resp
        );
    }

    #[test]
    fn precision_request_roundtrip_and_resolution() {
        use crate::config::MacKind;
        let req = ExploreRequest {
            workloads: vec!["mobilenetv2".into()],
            precision: Some(PrecisionRequest {
                act_bits: vec![4, 8],
                wt_bits: vec![4, 8],
                psum_bits: vec![],
                mac: MacKind::IntExact,
                types: vec!["lightpe1".into()],
            }),
        };
        let back = ExploreRequest::from_json(&roundtrip_json(&req.to_json())).unwrap();
        assert_eq!(back, req);
        // resolves to the 2x2 cross product plus the explicit preset
        let grid = back.precision.as_ref().unwrap().resolve().unwrap();
        assert_eq!(grid.len(), 5);
        assert!(grid.types.contains(&PeType::LightPe1));
        // quant pe_types survive the entry wire format
        let q = PeType::parse("a4w4p8-int").unwrap();
        assert_eq!(pe_type_from_json(&pe_type_to_json(q), "t").unwrap(), q);

        // validation failures carry the offending field
        let bad = PrecisionRequest { act_bits: vec![0], wt_bits: vec![8], ..Default::default() };
        let e = bad.resolve().unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("act_bits"), "{e}");
        // one-sided range grids are rejected
        let half = PrecisionRequest { act_bits: vec![8], ..Default::default() };
        assert!(half.resolve().unwrap_err().to_string().contains("wt_bits"));
        // unknown labels are rejected by name
        let unk = PrecisionRequest { types: vec!["int99x".into()], ..Default::default() };
        assert!(unk.resolve().unwrap_err().to_string().contains("int99x"));
        // malformed JSON payloads classify as protocol errors
        let e = PrecisionRequest::from_json(&Json::parse(r#"{"act_bits": ["x"]}"#).unwrap())
            .unwrap_err();
        assert_eq!(e.kind(), "protocol");
        assert!(PrecisionRequest::from_json(&Json::parse("5").unwrap()).is_err());
    }

    #[test]
    fn optimize_types_roundtrip() {
        // minimal request: only the workload travels
        let bare = OptimizeRequest { workload: "mobilenetv1".into(), ..Default::default() };
        let line = bare.to_json().to_string();
        assert_eq!(OptimizeRequest::from_json(&roundtrip_json(&bare.to_json())).unwrap(), bare);
        for absent in ["objectives", "constraints", "strategy", "budget", "precision"] {
            assert!(!line.contains(absent), "bare request leaked \"{absent}\": {line}");
        }

        // fully-specified request
        let full = OptimizeRequest {
            workload: "m.json".into(),
            objectives: vec!["latency".into(), "energy".into()],
            constraints: Constraints {
                max_area_mm2: Some(2.5),
                max_power_mw: Some(300.0),
                max_latency_ms: None,
                min_bits: Some(4),
                min_accuracy: Some(0.95),
            },
            sensitivity: None,
            width_mults: vec![],
            depth_mults: vec![],
            strategy: Some("nsga2".into()),
            budget: Some(20_000),
            pop: Some(64),
            seed: Some(7),
            per_layer: Some(true),
            precision: Some(PrecisionRequest {
                act_bits: vec![4, 8],
                wt_bits: vec![4, 8],
                ..Default::default()
            }),
            phase: None,
            ctx: None,
        };
        assert_eq!(OptimizeRequest::from_json(&roundtrip_json(&full.to_json())).unwrap(), full);

        // malformed payloads are protocol errors naming the field
        let e = OptimizeRequest::from_json(&Json::parse(r#"{"objectives": []}"#).unwrap())
            .unwrap_err();
        assert_eq!(e.kind(), "protocol");
        assert!(e.to_string().contains("workload"), "{e}");
        let e = OptimizeRequest::from_json(
            &Json::parse(r#"{"workload": "vgg16", "budget": "many"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("budget"), "{e}");
        let e = OptimizeRequest::from_json(
            &Json::parse(r#"{"workload": "vgg16", "constraints": {"min_bits": "four"}}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("min_bits"), "{e}");
        let e = OptimizeRequest::from_json(
            &Json::parse(r#"{"workload": "vgg16", "objectives": 5}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("objectives"), "{e}");

        // response round-trip
        let resp = OptimizeResponse {
            workload: "mobilenetv1".into(),
            strategy: "nsga2".into(),
            objectives: vec!["perf/area".into(), "energy".into()],
            evaluated: 480,
            budget: 500,
            ref_point: vec![0.125, 7.5],
            hypervolume: 0.8125,
            frontier: vec![OptPoint {
                config: cfg(PeType::LightPe1),
                objectives: vec![0.0625, 3.25],
                throughput: 812.5,
                energy_mj: 3.25,
                ppa: Ppa { power_mw: 212.5, fmax_mhz: 900.0, area_mm2: 1.75 },
                precision: vec!["a4w4p8-int".into(), "LightPE-1".into()],
                accuracy: None,
            }],
            generations: vec![crate::opt::engine::GenStat {
                generation: 0,
                evaluated: 64,
                frontier: 9,
                hypervolume: 0.5,
                best: vec![0.0625, 3.25],
            }],
            memo: MemoStats {
                cost_hits: 1200,
                cost_misses: 340,
                synth_hits: 470,
                synth_misses: 10,
            },
        };
        assert_eq!(
            OptimizeResponse::from_json(&roundtrip_json(&resp.to_json())).unwrap(),
            resp
        );
        // a memo-less payload (older peer) parses to all-zero counters
        let mut legacy = resp.to_json();
        if let Json::Obj(o) = &mut legacy {
            o.remove("memo");
        }
        let parsed = OptimizeResponse::from_json(&legacy).unwrap();
        assert_eq!(parsed.memo, MemoStats::default());
        assert_eq!(parsed.frontier, resp.frontier);
    }

    #[test]
    fn analyze_types_roundtrip() {
        let req = AnalyzeRequest::new("resnet50", cfg(PeType::Int16));
        assert_eq!(AnalyzeRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);
        // phase-less requests stay byte-identical to the CNN-era wire shape
        let line = req.to_json().to_string();
        assert!(!line.contains("phase") && !line.contains("ctx"), "{line}");
        let resp = AnalyzeResponse {
            workload: "resnet50".into(),
            config: cfg(PeType::Int16),
            ppa: Ppa { power_mw: 250.5, fmax_mhz: 800.0, area_mm2: 2.75 },
            layers: vec![LayerCost {
                name: "stem".into(),
                macs: 118_013_952,
                cycles: 1_234_567,
                stall_cycles: 4321,
                utilization: 0.87,
                dram_bytes: 1_500_000,
                compute_mj: 0.125,
                dram_mj: 0.5,
                other_mj: 0.0625,
                total_mj: 0.6875,
                precision: Some("a4w4p8-int".into()),
                kv_bytes: None,
            }],
            latency_s: 0.0123,
            energy_mj: 12.5,
            phase: None,
            accuracy: None,
        };
        assert_eq!(
            AnalyzeResponse::from_json(&roundtrip_json(&resp.to_json())).unwrap(),
            resp
        );
        let out = resp.to_json().to_string();
        assert!(!out.contains("kv_bytes") && !out.contains("\"phase\""), "{out}");
        assert!(!out.contains("accuracy"), "{out}");
    }

    #[test]
    fn analyze_phase_fields_roundtrip() {
        let req = AnalyzeRequest {
            workload: "llama2-7b".into(),
            config: cfg(PeType::Int16),
            phase: Some("decode".into()),
            ctx: Some(2048),
            accuracy: None,
        };
        assert_eq!(AnalyzeRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);
        // malformed phase/ctx are protocol errors naming the field
        let e = AnalyzeRequest::from_json(
            &Json::parse(r#"{"workload": "llama2-7b", "config": {"pe_type": "int16"}, "ctx": -3}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("\"ctx\""), "{e}");
        let e = AnalyzeRequest::from_json(
            &Json::parse(r#"{"workload": "llama2-7b", "config": {"pe_type": "int16"}, "phase": 7}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("\"phase\""), "{e}");

        let resp = AnalyzeResponse {
            workload: "llama2-7b".into(),
            config: cfg(PeType::Int16),
            ppa: Ppa { power_mw: 250.5, fmax_mhz: 800.0, area_mm2: 2.75 },
            layers: vec![LayerCost {
                name: "blk0.attn".into(),
                macs: 536_870_912,
                cycles: 98_304,
                stall_cycles: 1_024,
                utilization: 0.25,
                dram_bytes: 4_194_304,
                compute_mj: 0.125,
                dram_mj: 0.5,
                other_mj: 0.0625,
                total_mj: 0.6875,
                precision: None,
                kv_bytes: Some(2_097_152),
            }],
            latency_s: 0.0123,
            energy_mj: 12.5,
            phase: Some(PhaseSummary {
                phase: "both".into(),
                ctx: 2048,
                prefill_latency_s: 0.75,
                prefill_energy_mj: 640.0,
                decode_latency_s: 0.0015,
                decode_energy_mj: 1.25,
                kv_dram_bytes: 2_097_152,
                total_latency_s: 3.822,
                total_energy_mj: 3200.0,
            }),
            accuracy: None,
        };
        assert_eq!(
            AnalyzeResponse::from_json(&roundtrip_json(&resp.to_json())).unwrap(),
            resp
        );
    }

    #[test]
    fn optimize_phase_fields_roundtrip() {
        let bare = OptimizeRequest { workload: "opt-1.3b".into(), ..Default::default() };
        let line = bare.to_json().to_string();
        assert!(!line.contains("phase") && !line.contains("ctx"), "{line}");
        let phased = OptimizeRequest {
            workload: "opt-1.3b".into(),
            phase: Some("decode".into()),
            ctx: Some(1024),
            ..Default::default()
        };
        assert_eq!(
            OptimizeRequest::from_json(&roundtrip_json(&phased.to_json())).unwrap(),
            phased
        );
    }

    #[test]
    fn optimize_accuracy_fields_roundtrip() {
        // classic requests never leak the accuracy-era keys
        let bare = OptimizeRequest { workload: "mobilenetv1".into(), ..Default::default() };
        let line = bare.to_json().to_string();
        for absent in ["sensitivity", "width_mults", "depth_mults", "min_accuracy"] {
            assert!(!line.contains(absent), "bare request leaked \"{absent}\": {line}");
        }

        // embedded sensitivity table + model knobs + floor travel together
        let table = Json::parse(
            r#"{"baseline": 0.7089, "noise_scale": 12.0, "sensitivity": {"conv1": 1.5, "fc": 2.0}}"#,
        )
        .unwrap();
        let req = OptimizeRequest {
            workload: "mobilenetv1".into(),
            objectives: vec!["latency".into(), "energy".into(), "accuracy".into()],
            constraints: Constraints { min_accuracy: Some(0.97), ..Default::default() },
            sensitivity: Some(table),
            width_mults: vec![1.0, 0.75],
            depth_mults: vec![1.0, 0.5],
            seed: Some(11),
            ..Default::default()
        };
        assert_eq!(OptimizeRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);

        // malformed accuracy-era fields are protocol errors naming the field
        let e = OptimizeRequest::from_json(
            &Json::parse(r#"{"workload": "vgg16", "sensitivity": 5}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("sensitivity"), "{e}");
        let e = OptimizeRequest::from_json(
            &Json::parse(r#"{"workload": "vgg16", "width_mults": "half"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("width_mults"), "{e}");
        let e = OptimizeRequest::from_json(
            &Json::parse(r#"{"workload": "vgg16", "constraints": {"min_accuracy": "hi"}}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("min_accuracy"), "{e}");

        // frontier points carry the estimate; generation stats grow a slot
        let point = OptPoint {
            config: cfg(PeType::Int16),
            objectives: vec![0.0625, 3.25, 0.03],
            throughput: 812.5,
            energy_mj: 3.25,
            ppa: Ppa { power_mw: 212.5, fmax_mhz: 900.0, area_mm2: 1.75 },
            precision: vec!["a8w8p16-int".into()],
            accuracy: Some(0.97),
        };
        assert_eq!(OptPoint::from_json(&roundtrip_json(&point.to_json())).unwrap(), point);
        let g = crate::opt::engine::GenStat {
            generation: 2,
            evaluated: 128,
            frontier: 12,
            hypervolume: 0.75,
            best: vec![0.0625, 3.25, 0.025],
        };
        assert_eq!(gen_stat_from_json(&roundtrip_json(&gen_stat_to_json(&g))).unwrap(), g);
        let e = gen_stat_from_json(
            &Json::parse(
                r#"{"generation": 0, "evaluated": 1, "frontier": 1, "hypervolume": 0.5, "best": [1.0]}"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("best"), "{e}");

        // analyze opt-in flag and the response estimate round-trip
        let mut areq = AnalyzeRequest::new("mobilenetv1", cfg(PeType::Int16));
        areq.accuracy = Some(true);
        assert_eq!(AnalyzeRequest::from_json(&roundtrip_json(&areq.to_json())).unwrap(), areq);
        let aresp = AnalyzeResponse {
            workload: "mobilenetv1".into(),
            config: cfg(PeType::Int16),
            ppa: Ppa { power_mw: 250.5, fmax_mhz: 800.0, area_mm2: 2.75 },
            layers: vec![],
            latency_s: 0.0123,
            energy_mj: 12.5,
            phase: None,
            accuracy: Some(0.9991),
        };
        assert_eq!(
            AnalyzeResponse::from_json(&roundtrip_json(&aresp.to_json())).unwrap(),
            aresp
        );
    }

    #[test]
    fn workloads_types_roundtrip() {
        let req = WorkloadsRequest::default();
        assert_eq!(WorkloadsRequest::from_json(&roundtrip_json(&req.to_json())).unwrap(), req);
        let req2 = WorkloadsRequest { workload: Some("mobilenetv2".into()) };
        assert_eq!(WorkloadsRequest::from_json(&roundtrip_json(&req2.to_json())).unwrap(), req2);

        let list = WorkloadsResponse::List(vec![WorkloadInfo {
            name: "vgg16".into(),
            layers: 16,
            depthwise: 0,
            macs: 15_470_264_320,
        }]);
        assert_eq!(WorkloadsResponse::from_json(&roundtrip_json(&list.to_json())).unwrap(), list);

        // detail carries real layers through the docs/WORKLOADS.md schema
        let detail = WorkloadsResponse::Detail {
            name: "mobilenetv2".into(),
            layers: workloads::mobilenetv2(),
        };
        assert_eq!(
            WorkloadsResponse::from_json(&roundtrip_json(&detail.to_json())).unwrap(),
            detail
        );
    }

    #[test]
    fn session_and_error_payloads_roundtrip() {
        for backend in [None, Some("xla".to_string())] {
            let info = SessionInfo {
                backend,
                models_trained: 4,
                cache_hits: 12,
                workloads: workloads::WORKLOAD_NAMES.iter().map(|s| s.to_string()).collect(),
            };
            assert_eq!(SessionInfo::from_json(&roundtrip_json(&info.to_json())).unwrap(), info);
        }
        let err = ErrorBody::from(&QappaError::Workload("unknown workload 'x'".into()));
        assert_eq!(err.kind, "workload");
        assert_eq!(ErrorBody::from_json(&roundtrip_json(&err.to_json())).unwrap(), err);
    }

    #[test]
    fn serve_envelope_roundtrip() {
        let reqs = vec![
            ServeRequest { id: Some(7), body: RequestBody::Session },
            ServeRequest {
                id: None,
                body: RequestBody::Explore(ExploreRequest {
                    workloads: vec!["vgg16".into()],
                    precision: None,
                }),
            },
            ServeRequest {
                id: Some(9),
                body: RequestBody::Explore(ExploreRequest {
                    workloads: vec!["vgg16".into()],
                    precision: Some(PrecisionRequest {
                        act_bits: vec![4, 8],
                        wt_bits: vec![4],
                        ..Default::default()
                    }),
                }),
            },
            ServeRequest {
                id: Some(12),
                body: RequestBody::Optimize(OptimizeRequest {
                    workload: "mobilenetv1".into(),
                    objectives: vec!["lat".into(), "energy".into()],
                    constraints: Constraints {
                        max_area_mm2: Some(2.5),
                        ..Default::default()
                    },
                    budget: Some(500),
                    seed: Some(3),
                    ..Default::default()
                }),
            },
            ServeRequest {
                id: Some(1),
                body: RequestBody::Synth(SynthRequest { config: cfg(PeType::Int16) }),
            },
            ServeRequest { id: Some(2), body: RequestBody::Fit(FitRequest::default()) },
            ServeRequest {
                id: Some(3),
                body: RequestBody::Workloads(WorkloadsRequest { workload: Some("vgg16".into()) }),
            },
            ServeRequest {
                id: Some(4),
                body: RequestBody::Analyze(AnalyzeRequest::new("vgg16", cfg(PeType::LightPe1))),
            },
        ];
        for req in reqs {
            let line = req.to_json().to_string();
            assert_eq!(ServeRequest::parse_line(&line).unwrap(), req, "{line}");
        }

        let ok = ServeResponse {
            id: Some(7),
            result: Ok(ResponseBody::Session(SessionInfo {
                backend: Some("native".into()),
                models_trained: 4,
                cache_hits: 8,
                workloads: vec!["vgg16".into()],
            })),
        };
        assert_eq!(ServeResponse::from_json(&roundtrip_json(&ok.to_json())).unwrap(), ok);

        let err = ServeResponse {
            id: None,
            result: Err(ErrorBody { kind: "protocol".into(), message: "bad".into() }),
        };
        assert_eq!(ServeResponse::from_json(&roundtrip_json(&err.to_json())).unwrap(), err);
    }

    #[test]
    fn request_parsing_rejects_malformed() {
        assert_eq!(ServeRequest::parse_line("not json").unwrap_err().kind(), "protocol");
        assert_eq!(ServeRequest::parse_line("[1,2]").unwrap_err().kind(), "protocol");
        let e = ServeRequest::parse_line(r#"{"op": "nope"}"#).unwrap_err();
        assert!(e.to_string().contains("unknown op 'nope'"), "{e}");
        let e = ServeRequest::parse_line(r#"{"id": 1.5, "op": "session"}"#).unwrap_err();
        assert!(e.to_string().contains("\"id\""), "{e}");
        // op params are validated by the typed parsers
        let e = ServeRequest::parse_line(r#"{"op": "synth"}"#).unwrap_err();
        assert!(e.to_string().contains("pe_type"), "{e}");
    }
}
