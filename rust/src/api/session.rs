//! The `Qappa` session facade: one warm handle over backend + engine +
//! `ModelStore`, serving typed requests.
//!
//! A session owns everything a query needs — the regression backend (lazily
//! started, so config-only requests never spin up the XLA engine), the DSE
//! options (training recipe, design space, sharding) and a shared
//! [`ModelStore`] — which is what makes QAPPA's economics work as a
//! service: models train **once per store** and every subsequent
//! `explore`/`fit` query is answered from the warm cache in the time of a
//! sweep, not a training pass.  All methods take `&self` and the session is
//! `Sync`, so one session can serve concurrent requests (`api::serve`).
//!
//! The store is an `Arc`: by default each session gets a fresh one, but
//! [`QappaBuilder::store`] injects a shared handle so several sessions —
//! e.g. one per TCP connection — reuse each other's training passes, and
//! [`process_store`] is the process-wide instance the network server uses
//! so models train once per *process* (`docs/SERVE.md`).  Store keys cover
//! the full training recipe, so sessions with different recipes can share
//! one store without collisions.
//!
//! ```no_run
//! use qappa::api::{ExploreRequest, Qappa};
//!
//! let session = Qappa::builder().build();
//! let req = ExploreRequest { workloads: vec!["mobilenetv2".into()], precision: None };
//! let resp = session.explore(&req).unwrap(); // trains models on first use
//! let again = session.explore(&req).unwrap(); // warm: zero training passes
//! assert_eq!(session.store().misses(), 4);
//! # let _ = (resp, again);
//! ```

use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::accuracy::{AccuracyModel, SensitivityTable};
use crate::api::error::QappaError;
use crate::api::types::{
    AnalyzeRequest, AnalyzeResponse, ExploreRequest, ExploreResponse, FitRequest, FitResponse,
    CvPoint, FitModelReport, LayerCost, OptPoint, OptimizeRequest, OptimizeResponse,
    PhaseSummary, PrecisionRequest, SessionInfo, SynthRequest, SynthResponse, WorkloadInfo,
    WorkloadsRequest, WorkloadsResponse,
};
use crate::config::{PeType, ALL_PE_TYPES, NUM_FEATURES, QUANT_NUM_FEATURES};
use crate::coordinator::explorer::{
    run_dse_multi, run_dse_with_store, DseOptions, DseResult, ModelStore, WorkloadSummary,
};
use crate::coordinator::precision::{run_dse_precision, PrecisionGrid};
use crate::coordinator::report::{fig2_accuracy, AccuracyRow};
use crate::coordinator::space::DesignSpace;
use crate::coordinator::sweep::NamedWorkload;
use crate::dataflow::Layer;
use crate::model::native::NativeBackend;
use crate::model::{Backend, CvConfig};
use crate::opt::{
    resolve_objectives, run_optimize_cancellable, CancelToken, Objective, OptOptions,
    OptProblem, SearchSpace, StrategyKind,
};
use crate::runtime::{ArtifactRuntime, Engine, XlaBackend};
use crate::workloads;
use crate::workloads::{has_transformer_ops, shape_for_phase, Phase, DEFAULT_CTX};

/// Resolve a request's `phase`/`ctx` pair against a loaded workload.
///
/// Either flag on a pure-CNN workload is a workload error (phase shaping
/// is meaningless there, and silently ignoring it would misreport costs).
/// `ctx` without `phase` shapes prefill at that context; `phase` without
/// `ctx` uses [`DEFAULT_CTX`].  Returns the layers shaped for display
/// (`both` displays prefill — the evaluable half; the decode half travels
/// in the phase summary) plus the parsed pair when either flag was set.
fn resolve_phase(
    what: &str,
    name: &str,
    layers: Vec<Layer>,
    phase: &Option<String>,
    ctx: Option<u32>,
) -> Result<(Vec<Layer>, Option<(Phase, u32)>), QappaError> {
    if phase.is_none() && ctx.is_none() {
        return Ok((layers, None));
    }
    if !has_transformer_ops(&layers) {
        return Err(QappaError::Workload(format!(
            "{what}: \"phase\"/\"ctx\" apply to transformer workloads only \
             ('{name}' has no matmul/attention layers)"
        )));
    }
    let phase = match phase {
        Some(p) => Phase::parse(p)?,
        None => Phase::Prefill,
    };
    let ctx = ctx.unwrap_or(DEFAULT_CTX);
    if ctx == 0 {
        return Err(QappaError::Workload(format!("{what}: \"ctx\" must be > 0")));
    }
    Ok((shape_for_phase(&layers, phase, ctx), Some((phase, ctx))))
}

/// Which regression backend a session drives.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// XLA artifacts when `artifacts/manifest.json` exists, native
    /// otherwise (the historical CLI default).
    #[default]
    Auto,
    /// The pure-Rust fallback; needs no artifacts.
    Native,
    /// The PJRT artifact engine, from the given directory (or the default
    /// artifact location when `None`).
    Xla(Option<PathBuf>),
}

impl BackendChoice {
    /// Parse the CLI `--backend` value.
    pub fn parse(s: &str) -> Result<BackendChoice, QappaError> {
        match s {
            "native" => Ok(BackendChoice::Native),
            "xla" => Ok(BackendChoice::Xla(None)),
            "auto" => Ok(BackendChoice::Auto),
            other => Err(QappaError::Config(format!("unknown backend '{other}'"))),
        }
    }
}

/// Owned backend (native or XLA-with-engine).
enum AnyBackend {
    Native(NativeBackend),
    Xla(XlaBackend, Arc<Engine>),
}

impl AnyBackend {
    fn get(&self) -> &dyn Backend {
        match self {
            AnyBackend::Native(b) => b,
            AnyBackend::Xla(b, _) => b,
        }
    }

    fn engine(&self) -> Option<&Engine> {
        match self {
            AnyBackend::Native(_) => None,
            AnyBackend::Xla(_, e) => Some(e),
        }
    }
}

/// Builder for a [`Qappa`] session: backend choice, training recipe and
/// design-space overrides.  Everything defaults to the paper-scale
/// [`DseOptions::default`].
#[derive(Default)]
pub struct QappaBuilder {
    choice: BackendChoice,
    opts: DseOptions,
    store: Option<Arc<ModelStore>>,
}

/// The process-wide shared [`ModelStore`]: sessions built with
/// `.store(process_store())` train each model exactly once per process no
/// matter how many sessions come and go (the TCP serve path,
/// `docs/SERVE.md`).  Keys cover the whole training recipe, so mixing
/// recipes is safe.
pub fn process_store() -> Arc<ModelStore> {
    static STORE: OnceLock<Arc<ModelStore>> = OnceLock::new();
    STORE.get_or_init(|| Arc::new(ModelStore::new())).clone()
}

impl QappaBuilder {
    pub fn backend(mut self, choice: BackendChoice) -> QappaBuilder {
        self.choice = choice;
        self
    }

    /// Replace the whole option block (training recipe + space + sharding).
    pub fn options(mut self, opts: DseOptions) -> QappaBuilder {
        self.opts = opts;
        self
    }

    pub fn space(mut self, space: DesignSpace) -> QappaBuilder {
        self.opts.space = space;
        self
    }

    pub fn cv(mut self, cv: CvConfig) -> QappaBuilder {
        self.opts.cv = cv;
        self
    }

    /// k of the k-fold CV (keeps the rest of the CV grid).
    pub fn cv_k(mut self, k: usize) -> QappaBuilder {
        self.opts.cv.k = k;
        self
    }

    pub fn train_per_type(mut self, n: usize) -> QappaBuilder {
        self.opts.train_per_type = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> QappaBuilder {
        self.opts.seed = seed;
        self
    }

    pub fn workers(mut self, workers: usize) -> QappaBuilder {
        self.opts.workers = workers;
        self
    }

    pub fn sigma(mut self, sigma: f64) -> QappaBuilder {
        self.opts.sigma = sigma;
        self
    }

    pub fn chunk(mut self, chunk: usize) -> QappaBuilder {
        self.opts.chunk = chunk;
        self
    }

    pub fn topk(mut self, topk: usize) -> QappaBuilder {
        self.opts.topk = topk;
        self
    }

    /// Share a model store with other sessions (e.g. [`process_store`]):
    /// training passes done by any holder are warm hits for all of them.
    pub fn store(mut self, store: Arc<ModelStore>) -> QappaBuilder {
        self.store = Some(store);
        self
    }

    pub fn build(self) -> Qappa {
        Qappa {
            choice: self.choice,
            opts: self.opts,
            store: self.store.unwrap_or_default(),
            backend: OnceLock::new(),
            quant_backend: OnceLock::new(),
            init: Mutex::new(()),
        }
    }
}

/// A warm QAPPA session (see the module docs).
pub struct Qappa {
    choice: BackendChoice,
    opts: DseOptions,
    store: Arc<ModelStore>,
    /// Lazily-initialized backend: config-only requests (`synth`,
    /// `analyze`, `workloads`) never pay engine startup.
    backend: OnceLock<AnyBackend>,
    /// Lazily-initialized extended-feature backend for precision-grid
    /// sweeps (always native: the AOT artifacts are lowered for the
    /// 7-feature per-type protocol).
    quant_backend: OnceLock<NativeBackend>,
    /// Serializes backend initialization (double-checked around the
    /// `OnceLock`), so concurrent first requests start one engine.
    init: Mutex<()>,
}

impl Qappa {
    pub fn builder() -> QappaBuilder {
        QappaBuilder::default()
    }

    /// The session's DSE options (training recipe, space, sharding).
    pub fn options(&self) -> &DseOptions {
        &self.opts
    }

    /// The session's model cache; `misses()` counts training passes run,
    /// `hits()` the passes avoided.
    pub fn store(&self) -> &ModelStore {
        &self.store
    }

    /// A shareable handle on the session's model cache (what
    /// [`QappaBuilder::store`] accepts).
    pub fn store_handle(&self) -> Arc<ModelStore> {
        self.store.clone()
    }

    /// The XLA engine, if the session runs one and it has started.
    pub fn engine(&self) -> Option<&Engine> {
        self.backend.get().and_then(|b| b.engine())
    }

    /// Backend name, forcing lazy initialization.
    pub fn backend_name(&self) -> Result<&'static str, QappaError> {
        Ok(self.backend()?.name())
    }

    fn backend(&self) -> Result<&dyn Backend, QappaError> {
        if self.backend.get().is_none() {
            let _guard = self.init.lock().unwrap_or_else(|p| p.into_inner());
            if self.backend.get().is_none() {
                let b = Self::start_backend(&self.choice)?;
                let _ = self.backend.set(b);
            }
        }
        Ok(self.backend.get().expect("backend initialized").get())
    }

    fn start_backend(choice: &BackendChoice) -> Result<AnyBackend, QappaError> {
        let default_dir = ArtifactRuntime::artifacts_dir_default();
        let dir = match choice {
            BackendChoice::Native => return Ok(AnyBackend::Native(NativeBackend::new(NUM_FEATURES))),
            BackendChoice::Auto => {
                if !default_dir.join("manifest.json").exists() {
                    return Ok(AnyBackend::Native(NativeBackend::new(NUM_FEATURES)));
                }
                default_dir
            }
            BackendChoice::Xla(Some(dir)) => dir.clone(),
            BackendChoice::Xla(None) => default_dir,
        };
        let engine = Arc::new(Engine::start(&dir).map_err(|e| {
            e.context(format!("starting XLA engine from {}", dir.display()))
        })?);
        crate::obs::diag(
            "qappa",
            format_args!(
                "XLA engine up (d={}, B={}, N_fit={}) from {}",
                engine.d,
                engine.b_predict,
                engine.n_fit,
                dir.display()
            ),
        );
        Ok(AnyBackend::Xla(XlaBackend::new(engine.clone()), engine))
    }

    // ------------------------------------------------------------ queries

    /// Ground-truth synthesis of one configuration (no models involved).
    pub fn synth(&self, req: &SynthRequest) -> Result<SynthResponse, QappaError> {
        req.config.validate()?;
        Ok(SynthResponse {
            config: req.config,
            synthesized: crate::synth::synthesize(&req.config),
            jitter_free: crate::synth::synthesize_clean(&req.config),
        })
    }

    /// Train (or fetch warm) PPA models; empty `pe_types` means all four.
    pub fn fit(&self, req: &FitRequest) -> Result<FitResponse, QappaError> {
        let types: &[PeType] =
            if req.pe_types.is_empty() { &ALL_PE_TYPES } else { &req.pe_types };
        let backend = self.backend()?;
        let mut models = Vec::with_capacity(types.len());
        for &ty in types {
            let m = self.store.get_or_train(backend, &self.opts, ty)?;
            models.push(FitModelReport {
                pe_type: ty,
                degree: m.degree,
                lambda: m.lambda,
                n_train: m.n_train,
                cv: m
                    .cv_table
                    .iter()
                    .map(|e| CvPoint { degree: e.degree, lambda: e.lambda, mse: e.mse })
                    .collect(),
            });
        }
        Ok(FitResponse { backend: backend.name().to_string(), models })
    }

    /// Full DSE over already-loaded layers, retaining every evaluated
    /// point (the CLI / figure path; models come from the warm store).
    pub fn dse(&self, workload: &str, layers: &[Layer]) -> Result<DseResult, QappaError> {
        run_dse_with_store(self.backend()?, &self.store, layers, workload, &self.opts)
    }

    /// Streaming DSE over one or more workload specs (built-in names or
    /// JSON model paths): one pass over the grid, O(frontier + k) memory
    /// per workload.  Workloads are resolved before the backend starts, so
    /// a bad spec never pays engine startup.
    pub fn explore_summaries(
        &self,
        req: &ExploreRequest,
    ) -> Result<Vec<WorkloadSummary>, QappaError> {
        let named = self.resolve_workloads(&req.workloads)?;
        match &req.precision {
            Some(p) => self.explore_precision(&named, p),
            None => self.explore_named(&named),
        }
    }

    /// [`Qappa::explore_summaries`] over already-loaded workloads (the CLI
    /// path, which resolves specs itself to report load errors early).
    pub fn explore_named(
        &self,
        named: &[NamedWorkload],
    ) -> Result<Vec<WorkloadSummary>, QappaError> {
        if named.is_empty() {
            return Err(QappaError::Workload("explore: empty workload list".into()));
        }
        run_dse_multi(self.backend()?, &self.store, named, &self.opts)
    }

    /// [`Qappa::explore_summaries`], condensed to the wire response.
    /// Requests carrying a `precision` block route to the precision-grid
    /// pipeline (one row per precision cell).
    pub fn explore(&self, req: &ExploreRequest) -> Result<ExploreResponse, QappaError> {
        ExploreResponse::from_summaries(&self.explore_summaries(req)?)
    }

    /// Precision-grid DSE over already-loaded workloads: resolve the
    /// requested grid, train (or fetch warm) the unified cross-precision
    /// model on the session's extended-feature native backend, and stream
    /// every precision cell through the chunked sweep engine.
    pub fn explore_precision(
        &self,
        named: &[NamedWorkload],
        precision: &PrecisionRequest,
    ) -> Result<Vec<WorkloadSummary>, QappaError> {
        if named.is_empty() {
            return Err(QappaError::Workload("explore: empty workload list".into()));
        }
        let grid = precision.resolve()?;
        let backend = self
            .quant_backend
            .get_or_init(|| NativeBackend::new(QUANT_NUM_FEATURES));
        run_dse_precision(backend, &self.store, named, &self.opts, &grid)
    }

    /// Guided multi-objective search over (hardware config, per-layer
    /// precision) for one workload — the `optimize` op / `qappa optimize`
    /// subcommand (`docs/OPTIMIZER.md`).
    ///
    /// The search space is the session's hardware [`DesignSpace`] crossed
    /// with a precision palette (the request's `precision` block, or the
    /// four presets), pruned by the `min_bits` constraint.  Evaluations
    /// run through the unified cross-precision model fetched from the
    /// session's `ModelStore` — guided search shares one training pass
    /// with `explore` runs over the same palette — and the same
    /// predict → dataflow pipeline as the streaming sweep.  Identical
    /// (request, session recipe, seed) inputs reproduce the frontier
    /// bit-for-bit, whether issued here, over `serve`, or via the CLI.
    pub fn optimize(&self, req: &OptimizeRequest) -> Result<OptimizeResponse, QappaError> {
        self.optimize_cancellable(req, &CancelToken::new())
    }

    /// [`Qappa::optimize`] with a cooperative cancellation handle: when
    /// `cancel` fires the search stops at the next batch boundary and the
    /// run answers a `protocol` error (the network server cancels this way
    /// when a client drops mid-optimize — see `docs/SERVE.md`).
    pub fn optimize_cancellable(
        &self,
        req: &OptimizeRequest,
        cancel: &CancelToken,
    ) -> Result<OptimizeResponse, QappaError> {
        // Cheap validation first: a bad request never pays workload
        // loading or training.
        let objectives = resolve_objectives(&req.objectives)?;
        req.constraints.validate()?;
        let strategy = match &req.strategy {
            Some(s) => StrategyKind::parse(s)?,
            None => StrategyKind::Nsga2,
        };
        let budget = req.budget.unwrap_or(20_000);
        if budget == 0 {
            return Err(QappaError::Config("optimize: budget must be >= 1".into()));
        }
        let (name, layers) = workloads::load(&req.workload)?;
        // Phase shaping: the optimizer needs one evaluable shape, so
        // `both` is rejected — pick the serving regime to optimize for.
        let (layers, phased) = resolve_phase("optimize", &name, layers, &req.phase, req.ctx)?;
        if matches!(phased, Some((Phase::Both, _))) {
            return Err(QappaError::Config(
                "optimize: phase must be 'prefill' or 'decode' (a composed 'both' \
                 workload has no single evaluable shape)"
                    .into(),
            ));
        }

        // Precision palette: requested grid or the four presets, pruned by
        // the min-bits accuracy floor.
        let grid = match &req.precision {
            Some(p) => p.resolve()?,
            None => PrecisionGrid::new(ALL_PE_TYPES.to_vec())?,
        };
        let mut palette = grid.types;
        if let Some(b) = req.constraints.min_bits {
            palette.retain(|t| t.act_bits() >= b && t.wt_bits() >= b);
            if palette.is_empty() {
                return Err(QappaError::Config(format!(
                    "optimize: min_bits = {b} leaves no cell in the precision palette"
                )));
            }
        }
        let per_layer = req.per_layer.unwrap_or(palette.len() > 1);

        // Accuracy model: a measured sensitivity table when the request
        // embeds one (validated against this workload's layer names so
        // typos fail loudly), else the engine falls back to the structural
        // proxy whenever an objective or constraint prices accuracy.
        let needs_accuracy = objectives.contains(&Objective::Accuracy)
            || req.constraints.min_accuracy.is_some();
        let accuracy = match &req.sensitivity {
            Some(json) => {
                if !needs_accuracy {
                    return Err(QappaError::Config(
                        "optimize: \"sensitivity\" requires an accuracy objective or a \
                         min_accuracy constraint"
                            .into(),
                    ));
                }
                Some(AccuracyModel::from_table(SensitivityTable::from_json(json)?, &layers)?)
            }
            None => None,
        };

        // Build the search space (and validate any model knobs) before
        // training so malformed requests fail without paying a training
        // pass.
        let mut search = SearchSpace::new(&self.opts.space, palette.clone(), &layers, per_layer)?;
        // Model-side knobs: pre-build the scaled variant for every
        // (width, depth) cell so decode() is a table lookup.  Variants go
        // through the same phase shaping as the base workload, keeping
        // their layer lists directly comparable.
        if !(req.width_mults.is_empty() && req.depth_mults.is_empty()) {
            let width =
                if req.width_mults.is_empty() { vec![1.0] } else { req.width_mults.clone() };
            let depth =
                if req.depth_mults.is_empty() { vec![1.0] } else { req.depth_mults.clone() };
            let mut variants = Vec::with_capacity(width.len() * depth.len());
            for &w in &width {
                for &d in &depth {
                    let scaled = workloads::scaled(&name, w, d)?;
                    let (scaled, _) =
                        resolve_phase("optimize", &name, scaled, &req.phase, req.ctx)?;
                    variants.push(scaled);
                }
            }
            search = search.with_model_knobs(width, depth, variants)?;
        }
        let problem = OptProblem { search, objectives, constraints: req.constraints, accuracy };
        let backend = self
            .quant_backend
            .get_or_init(|| NativeBackend::new(QUANT_NUM_FEATURES));
        let model = self.store.get_or_train_quant(backend, &self.opts, &palette)?;
        let oopts = OptOptions {
            strategy,
            budget,
            pop: req.pop.unwrap_or(64),
            seed: req.seed.unwrap_or(self.opts.seed),
            ..Default::default()
        };
        let result =
            run_optimize_cancellable(backend, &model, &problem, &oopts, self.opts.workers, cancel)?;
        if cancel.is_cancelled() {
            return Err(QappaError::Protocol("optimize: run cancelled".into()));
        }

        let frontier = result
            .frontier
            .iter()
            .map(|f| OptPoint {
                config: f.point.cfg,
                objectives: f.objs.to_vec(),
                throughput: f.point.throughput,
                energy_mj: f.point.energy_mj,
                ppa: f.point.ppa,
                precision: f.precision.clone(),
                accuracy: f.accuracy,
            })
            .collect();
        Ok(OptimizeResponse {
            workload: name,
            strategy: result.strategy.to_string(),
            objectives: problem.objectives.iter().map(|o| o.label().to_string()).collect(),
            evaluated: result.evaluated,
            budget,
            ref_point: result.ref_point.to_vec(),
            hypervolume: result.hypervolume,
            frontier,
            generations: result.generations,
            memo: result.memo,
        })
    }

    /// Resolve workload specs (built-in names or JSON model paths) before
    /// any backend starts, so a bad spec never pays engine startup.
    fn resolve_workloads(&self, specs: &[String]) -> Result<Vec<NamedWorkload>, QappaError> {
        if specs.is_empty() {
            return Err(QappaError::Workload("explore: empty workload list".into()));
        }
        let mut named = Vec::with_capacity(specs.len());
        for spec in specs {
            let (name, layers) = workloads::load(spec)?;
            named.push(NamedWorkload::new(name, layers));
        }
        Ok(named)
    }

    /// Per-layer latency/energy breakdown of one workload on one config
    /// (analytical models only; no training).
    pub fn analyze(&self, req: &AnalyzeRequest) -> Result<AnalyzeResponse, QappaError> {
        let (name, layers) = workloads::load(&req.workload)?;
        let (layers, phased) = resolve_phase("analyze", &name, layers, &req.phase, req.ctx)?;
        req.config.validate()?;
        let cfg = req.config;
        let ep = crate::synth::oracle::energy_params(&cfg);
        let ppa = crate::synth::synthesize_clean(&cfg);
        let mut rows = Vec::with_capacity(layers.len());
        let mut latency_s = 0.0;
        let mut energy_mj = 0.0;
        // Per-layer precision overrides re-size the hardware; memoize the
        // derived (config, energy params) per spec so a mixed-precision
        // net re-synthesizes each override once, not once per layer.
        let mut override_hw: Vec<(
            crate::config::QuantSpec,
            crate::config::AcceleratorConfig,
            crate::synth::oracle::EnergyParams,
        )> = Vec::new();
        for l in &layers {
            let (cfg_l, ep_l) = match l.quant {
                Some(q) if q != cfg.quant() => {
                    match override_hw.iter().position(|(spec, _, _)| *spec == q) {
                        Some(i) => (override_hw[i].1, override_hw[i].2),
                        None => {
                            let (c, e) = crate::dataflow::layer_hw(&cfg, &ep, l);
                            override_hw.push((q, c, e));
                            (c, e)
                        }
                    }
                }
                _ => (cfg, ep),
            };
            let (perf, traffic, e) = crate::dataflow::layer_cost_at(&cfg_l, &ep_l, l);
            latency_s += perf.latency_s(ep.fmax_mhz);
            energy_mj += e.total_mj();
            rows.push(LayerCost {
                name: l.name.clone(),
                macs: l.macs(),
                cycles: perf.cycles,
                stall_cycles: perf.stall_cycles,
                utilization: perf.utilization,
                dram_bytes: traffic.dram_bytes,
                compute_mj: e.compute_mj,
                dram_mj: e.dram_mj,
                other_mj: e.glb_mj + e.noc_mj + e.leakage_mj,
                total_mj: e.total_mj(),
                precision: l.quant.map(|q| PeType::from_spec(q).label()),
                kv_bytes: (traffic.dram_kv_bytes > 0).then_some(traffic.dram_kv_bytes),
            });
        }
        // Per-phase summary: evaluate the prefill and decode shapes of the
        // *original* workload and compose per the requested phase.  Uses
        // the same override-aware network evaluator as the sweep path, so
        // the composition laws (`both` = prefill + ctx decode steps) hold
        // exactly at the NetworkCost level.
        let phase = phased.map(|(phase, ctx)| {
            let (_, base) = workloads::load(&req.workload).expect("already loaded");
            let pre_cost = crate::dataflow::evaluate_network(
                &cfg,
                &ep,
                &shape_for_phase(&base, Phase::Prefill, ctx),
            );
            let dec_cost = crate::dataflow::evaluate_network(
                &cfg,
                &ep,
                &shape_for_phase(&base, Phase::Decode, ctx),
            );
            let total = match phase {
                Phase::Prefill => pre_cost.clone(),
                Phase::Decode => dec_cost.clone(),
                Phase::Both => pre_cost.add(&dec_cost.scale(ctx as u64)),
            };
            PhaseSummary {
                phase: phase.label().to_string(),
                ctx,
                prefill_latency_s: pre_cost.latency_s,
                prefill_energy_mj: pre_cost.energy_mj,
                decode_latency_s: dec_cost.latency_s,
                decode_energy_mj: dec_cost.energy_mj,
                kv_dram_bytes: dec_cost.dram_kv_bytes,
                total_latency_s: total.latency_s,
                total_energy_mj: total.energy_mj,
            }
        });
        // Opt-in accuracy estimate: the structural proxy priced at each
        // layer's effective precision (per-layer override or the config's
        // uniform spec) — the same estimator the optimizer scores with.
        let accuracy = (req.accuracy == Some(true)).then(|| {
            let specs: Vec<crate::config::QuantSpec> =
                layers.iter().map(|l| l.effective_quant(&cfg)).collect();
            AccuracyModel::proxy().estimate(&layers, &specs)
        });
        Ok(AnalyzeResponse {
            workload: name,
            config: cfg,
            ppa,
            layers: rows,
            latency_s,
            energy_mj,
            phase,
            accuracy,
        })
    }

    /// List built-in workloads, or detail one spec.
    pub fn workloads(&self, req: &WorkloadsRequest) -> Result<WorkloadsResponse, QappaError> {
        match &req.workload {
            Some(spec) => {
                let (name, layers) = workloads::load(spec)?;
                Ok(WorkloadsResponse::Detail { name, layers })
            }
            None => {
                let mut list = Vec::with_capacity(workloads::WORKLOAD_NAMES.len());
                for name in workloads::WORKLOAD_NAMES {
                    let layers = workloads::by_name(name).expect("built-in workload");
                    list.push(WorkloadInfo {
                        name: name.to_string(),
                        layers: layers.len(),
                        depthwise: layers.iter().filter(|l| l.is_depthwise()).count(),
                        macs: layers.iter().map(|l| l.macs()).sum(),
                    });
                }
                Ok(WorkloadsResponse::List(list))
            }
        }
    }

    /// Figure-2 model accuracy (trains its own holdout models; the
    /// ModelStore cache is not involved, matching the figure protocol).
    pub fn accuracy(&self, holdout_per_type: usize) -> Result<Vec<AccuracyRow>, QappaError> {
        fig2_accuracy(self.backend()?, &self.opts, holdout_per_type)
    }

    /// Session counters for the `session` op (does not force backend
    /// initialization).
    pub fn session_info(&self) -> SessionInfo {
        SessionInfo {
            backend: self.backend.get().map(|b| b.get().name().to_string()),
            models_trained: self.store.misses(),
            cache_hits: self.store.hits(),
            workloads: workloads::WORKLOAD_NAMES.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::ExploreRequest;
    use crate::config::AcceleratorConfig;

    fn tiny_session() -> Qappa {
        Qappa::builder()
            .backend(BackendChoice::Native)
            .space(DesignSpace::tiny())
            .train_per_type(64)
            .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
            .seed(7)
            .workers(4)
            .sigma(0.02)
            .chunk(32)
            .topk(8)
            .build()
    }

    #[test]
    fn synth_needs_no_backend() {
        let s = tiny_session();
        let req = SynthRequest { config: AcceleratorConfig::default_with(PeType::Int16) };
        let resp = s.synth(&req).unwrap();
        assert!(resp.synthesized.power_mw > 0.0 && resp.jitter_free.area_mm2 > 0.0);
        // nothing forced the backend up
        assert_eq!(s.session_info().backend, None);
        assert_eq!(s.store().misses(), 0);
    }

    #[test]
    fn models_train_once_across_queries() {
        let s = tiny_session();
        let req = ExploreRequest { workloads: vec!["vgg16".into()], precision: None };
        // first explore trains all four models
        let r1 = s.explore(&req).unwrap();
        assert_eq!(s.store().misses(), 4);
        assert_eq!(s.store().hits(), 0);
        // fit and a repeat explore are pure cache hits
        let fit = s.fit(&FitRequest::default()).unwrap();
        assert_eq!(fit.models.len(), 4);
        let r2 = s.explore(&req).unwrap();
        assert_eq!(s.store().misses(), 4, "no retraining on a warm session");
        assert!(s.store().hits() >= 8);
        assert_eq!(r1, r2, "warm queries are deterministic");
        let info = s.session_info();
        assert_eq!(info.backend.as_deref(), Some("native"));
        assert_eq!(info.models_trained, 4);
    }

    #[test]
    fn sessions_sharing_a_store_train_once() {
        let shared = Arc::new(ModelStore::new());
        let a = Qappa::builder()
            .backend(BackendChoice::Native)
            .space(DesignSpace::tiny())
            .train_per_type(64)
            .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
            .seed(7)
            .workers(4)
            .sigma(0.02)
            .chunk(32)
            .topk(8)
            .store(shared.clone())
            .build();
        let b = Qappa::builder()
            .backend(BackendChoice::Native)
            .space(DesignSpace::tiny())
            .train_per_type(64)
            .cv(CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 })
            .seed(7)
            .workers(4)
            .sigma(0.02)
            .chunk(32)
            .topk(8)
            .store(shared.clone())
            .build();
        let req = ExploreRequest { workloads: vec!["vgg16".into()], precision: None };
        let r1 = a.explore(&req).unwrap();
        assert_eq!(shared.misses(), 4, "first session trains all four models");
        let r2 = b.explore(&req).unwrap();
        assert_eq!(shared.misses(), 4, "second session answers warm from the shared store");
        assert!(shared.hits() >= 4);
        assert_eq!(r1, r2, "same recipe + shared store -> identical answers");
    }

    #[test]
    fn cancelled_optimize_answers_protocol_error() {
        use crate::api::types::OptimizeRequest;
        let s = tiny_session();
        let cancel = CancelToken::new();
        cancel.cancel();
        let req = OptimizeRequest {
            workload: "mobilenetv1".into(),
            budget: Some(80),
            pop: Some(16),
            ..Default::default()
        };
        let err = s.optimize_cancellable(&req, &cancel).unwrap_err();
        assert_eq!(err.kind(), "protocol");
        assert!(err.to_string().contains("cancelled"));
    }

    #[test]
    fn explore_response_matches_dse_anchor() {
        let s = tiny_session();
        let (name, layers) = workloads::load("vgg16").unwrap();
        let resp = s.explore(&ExploreRequest { workloads: vec!["vgg16".into()], precision: None }).unwrap();
        let res = s.dse(&name, &layers).unwrap();
        assert_eq!(resp.summaries.len(), 1);
        let summary = &resp.summaries[0];
        assert_eq!(summary.workload, "vgg16");
        assert_eq!(summary.anchor, res.anchor.cfg);
        for entry in &summary.entries {
            let (pa, e) = res.ratios[&entry.pe_type];
            assert_eq!(entry.perf_per_area, pa, "{:?}", entry.pe_type);
            assert_eq!(entry.energy, e);
            assert_eq!(entry.evaluated, s.options().space.len());
        }
    }

    #[test]
    fn analyze_and_workloads_are_config_only() {
        let s = tiny_session();
        let resp = s
            .analyze(&AnalyzeRequest::new(
                "mobilenetv2",
                AcceleratorConfig::default_with(PeType::LightPe1),
            ))
            .unwrap();
        assert_eq!(resp.workload, "mobilenetv2");
        assert_eq!(resp.layers.len(), workloads::mobilenetv2().len());
        assert!(resp.latency_s > 0.0 && resp.energy_mj > 0.0);
        let total: f64 = resp.layers.iter().map(|l| l.total_mj).sum();
        assert!((total - resp.energy_mj).abs() < 1e-9);

        match s.workloads(&WorkloadsRequest::default()).unwrap() {
            WorkloadsResponse::List(list) => {
                assert_eq!(list.len(), workloads::WORKLOAD_NAMES.len());
                assert!(list.iter().any(|i| i.name == "mobilenetv1" && i.depthwise == 13));
            }
            other => panic!("expected a listing, got {other:?}"),
        }
        match s.workloads(&WorkloadsRequest { workload: Some("vgg-16".into()) }).unwrap() {
            WorkloadsResponse::Detail { name, layers } => {
                assert_eq!(name, "vgg16");
                assert_eq!(layers, workloads::vgg16());
            }
            other => panic!("expected detail, got {other:?}"),
        }
        assert_eq!(s.store().misses(), 0, "no training for analytical queries");
    }

    #[test]
    fn analyze_phases_compose_and_gate_on_transformer_workloads() {
        let s = tiny_session();
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let req = |phase: &str, ctx: u32| AnalyzeRequest {
            workload: "opt-1.3b".into(),
            config: cfg,
            phase: Some(phase.into()),
            ctx: Some(ctx),
            accuracy: None,
        };
        let pre = s.analyze(&req("prefill", 512)).unwrap();
        let dec = s.analyze(&req("decode", 512)).unwrap();
        let both = s.analyze(&req("both", 512)).unwrap();
        let p = pre.phase.as_ref().unwrap();
        let d = dec.phase.as_ref().unwrap();
        let b = both.phase.as_ref().unwrap();
        assert_eq!((p.phase.as_str(), p.ctx), ("prefill", 512));
        // the summary is phase-symmetric: prefill/decode halves agree
        // across requests, only the total picks the requested phase
        assert_eq!(p.prefill_latency_s.to_bits(), d.prefill_latency_s.to_bits());
        assert_eq!(p.kv_dram_bytes, d.kv_dram_bytes);
        assert_eq!(p.total_latency_s.to_bits(), p.prefill_latency_s.to_bits());
        assert_eq!(d.total_latency_s.to_bits(), d.decode_latency_s.to_bits());
        // a decode step is far cheaper than the whole prompt, but streams
        // the full KV cache
        assert!(d.total_latency_s < p.total_latency_s);
        assert!(d.kv_dram_bytes > 0);
        // composition law: both = prefill + ctx decode steps
        let want = p.total_latency_s + 512.0 * d.total_latency_s;
        assert!(
            (b.total_latency_s - want).abs() < 1e-12 * want,
            "{} != {want}",
            b.total_latency_s
        );
        let want_e = p.total_energy_mj + 512.0 * d.total_energy_mj;
        assert!((b.total_energy_mj - want_e).abs() < 1e-12 * want_e);
        // decode rows surface per-layer KV traffic; CNN rows never do
        assert!(dec.layers.iter().any(|l| l.kv_bytes.is_some()));
        let total: f64 = dec.layers.iter().map(|l| l.total_mj).sum();
        assert!((total - dec.energy_mj).abs() < 1e-9 * total.max(1.0));
        // phase flags are rejected on pure-CNN workloads
        let e = s
            .analyze(&AnalyzeRequest {
                workload: "vgg16".into(),
                config: cfg,
                phase: Some("decode".into()),
                ctx: None,
                accuracy: None,
            })
            .unwrap_err();
        assert!(e.to_string().contains("transformer"), "{e}");
        let e = s.analyze(&req("training", 64)).unwrap_err();
        assert!(e.to_string().contains("prefill|decode|both"), "{e}");
        assert_eq!(s.store().misses(), 0, "phased analyze stays analytical");
    }

    #[test]
    fn explore_with_precision_sweeps_the_grid() {
        let s = tiny_session();
        let req = ExploreRequest {
            workloads: vec!["vgg16".into()],
            precision: Some(PrecisionRequest {
                act_bits: vec![4, 8],
                wt_bits: vec![4],
                ..Default::default()
            }),
        };
        let resp = s.explore(&req).unwrap();
        // one unified model for the whole grid, not one per cell
        assert_eq!(s.store().misses(), 1);
        assert_eq!(resp.summaries.len(), 1);
        let summary = &resp.summaries[0];
        assert_eq!(summary.entries.len(), 2, "one row per precision cell");
        for entry in &summary.entries {
            assert!(!entry.pe_type.is_preset(), "{:?}", entry.pe_type);
            assert_eq!(entry.evaluated, s.options().space.len());
            assert!(entry.frontier > 0);
        }
        // warm repeat: zero extra training
        let again = s.explore(&req).unwrap();
        assert_eq!(s.store().misses(), 1);
        assert_eq!(again, resp);
        // the response round-trips the quant pe_type labels losslessly
        let j = resp.to_json().to_string();
        let back = ExploreResponse::from_json(&crate::util::json::Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, resp);
        // a bad precision request classifies as config without training
        let bad = ExploreRequest {
            workloads: vec!["vgg16".into()],
            precision: Some(PrecisionRequest {
                act_bits: vec![0],
                wt_bits: vec![4],
                ..Default::default()
            }),
        };
        assert_eq!(s.explore(&bad).unwrap_err().kind(), "config");
    }

    #[test]
    fn analyze_applies_per_layer_precision_overrides() {
        use crate::config::QuantSpec;
        let s = tiny_session();
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        // serialize a mixed-precision model to a temp file and analyze it
        let mut layers = workloads::by_name("mobilenetv1").unwrap();
        for l in layers.iter_mut().filter(|l| l.is_depthwise()) {
            l.quant = Some(QuantSpec::int(4, 4));
        }
        let dir = std::env::temp_dir().join(format!("qappa_mixed_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.json");
        std::fs::write(&path, workloads::to_json("mixed-mnv1", &layers).to_string()).unwrap();
        let spec = path.to_string_lossy().to_string();

        let mixed = s.analyze(&AnalyzeRequest::new(spec, cfg)).unwrap();
        let plain = s.analyze(&AnalyzeRequest::new("mobilenetv1", cfg)).unwrap();
        assert!(mixed.energy_mj < plain.energy_mj, "INT4 depthwise must cut energy");
        let dw_rows: Vec<_> =
            mixed.layers.iter().filter(|l| l.precision.is_some()).collect();
        assert_eq!(dw_rows.len(), 13, "all depthwise rows carry the override label");
        assert!(dw_rows.iter().all(|l| l.precision.as_deref() == Some("a4w4p8-int")));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn optimize_trains_once_and_is_deterministic_per_seed() {
        use crate::api::types::{OptimizeRequest, PrecisionRequest};
        let s = tiny_session();
        let req = OptimizeRequest {
            workload: "mobilenetv1".into(),
            budget: Some(80),
            pop: Some(16),
            seed: Some(5),
            precision: Some(PrecisionRequest {
                types: vec!["int16".into(), "a4w4p8-int".into()],
                ..Default::default()
            }),
            ..Default::default()
        };
        let a = s.optimize(&req).unwrap();
        assert_eq!(s.store().misses(), 1, "one unified model for the palette");
        assert_eq!(a.workload, "mobilenetv1");
        assert_eq!(a.strategy, "nsga2");
        assert_eq!(a.objectives, vec!["perf/area".to_string(), "energy".to_string()]);
        assert!(a.evaluated <= 80);
        assert!(!a.frontier.is_empty());
        assert!(a.hypervolume > 0.0);
        // frontier members carry per-layer precision labels
        let n_layers = workloads::mobilenetv1().len();
        for p in &a.frontier {
            assert_eq!(p.precision.len(), n_layers);
        }
        // warm repeat with the same seed: zero retraining, identical result
        let b = s.optimize(&req).unwrap();
        assert_eq!(s.store().misses(), 1);
        assert_eq!(a, b, "same seed must reproduce the frontier bit-for-bit");
        // responses round-trip the wire losslessly
        let j = a.to_json().to_string();
        let back = crate::api::types::OptimizeResponse::from_json(
            &crate::util::json::Json::parse(&j).unwrap(),
        )
        .unwrap();
        assert_eq!(back, a);
        // bad requests classify without touching the trained state
        let bad = OptimizeRequest {
            workload: "mobilenetv1".into(),
            objectives: vec!["bogus".into(), "energy".into()],
            ..Default::default()
        };
        assert_eq!(s.optimize(&bad).unwrap_err().kind(), "config");
        let zero = OptimizeRequest {
            workload: "mobilenetv1".into(),
            budget: Some(0),
            ..Default::default()
        };
        assert!(s.optimize(&zero).unwrap_err().to_string().contains("budget"));
        // min_bits prunes the palette; an impossible floor errors by name
        let floor = OptimizeRequest {
            workload: "mobilenetv1".into(),
            constraints: crate::opt::Constraints { min_bits: Some(99), ..Default::default() },
            ..Default::default()
        };
        assert!(s.optimize(&floor).unwrap_err().to_string().contains("min_bits"));
        assert_eq!(s.store().misses(), 1, "bad requests never train");
    }

    #[test]
    fn bad_requests_classify() {
        let s = tiny_session();
        let e = s
            .explore(&ExploreRequest { workloads: vec!["alexnet".into()], precision: None })
            .unwrap_err();
        assert_eq!(e.kind(), "workload");
        assert_eq!(s.session_info().backend, None, "bad spec never starts the backend");
        let mut cfg = AcceleratorConfig::default_with(PeType::Int16);
        cfg.pe_rows = 0;
        let e = s.synth(&SynthRequest { config: cfg }).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert_eq!(BackendChoice::parse("bogus").unwrap_err().to_string(), "unknown backend 'bogus'");
    }
}
