//! The TCP transport for `qappa serve --listen`: a std-only listener
//! multiplexing per-connection JSON-lines sessions over one shared
//! [`Dispatcher`] (and through it one shared session + `ModelStore`, so
//! models train once per *process* no matter how many clients connect).
//!
//! Lifecycle of a connection (full protocol: `docs/SERVE.md`):
//!
//! * accepted while under `max_connections`; past the cap the server
//!   writes one `protocol` error line and closes (connection shedding);
//! * framed as newline-delimited JSON with a `max_line_bytes` bound — an
//!   oversized line is *consumed* (through its newline), answered with a
//!   `protocol` error, and the stream keeps going;
//! * dispatched by a small per-connection worker pool over a
//!   [`BoundedQueue`], so one slow request doesn't stall the socket read
//!   and responses may arrive out of order (clients correlate by `id`);
//! * cancelled cooperatively when the client vanishes: reader EOF outside
//!   a server-initiated drain fires the connection's [`CancelToken`],
//!   stopping in-flight `optimize` runs at their next batch boundary;
//! * drained gracefully on [`TcpServer::shutdown`]: the listener stops,
//!   every live socket's read half is shut down (readers see EOF, the
//!   token does *not* fire), queued work completes and responses flush.
//!
//! Diagnostics go to stderr with a `[serve]` prefix; sockets carry only
//! JSON response lines.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::dispatch::{DispatchOptions, DispatchStats, Dispatcher};
use crate::api::error::QappaError;
use crate::api::session::Qappa;
use crate::api::types::{ErrorBody, ServeResponse};
use crate::obs;
use crate::opt::CancelToken;
use crate::util::queue::BoundedQueue;

/// Knobs of the TCP transport.
#[derive(Debug, Clone, Copy)]
pub struct TransportOptions {
    /// Concurrent connections; past this new sockets are shed with one
    /// `protocol` error line.
    pub max_connections: usize,
    /// Worker threads per connection (out-of-order responses when > 1).
    pub concurrency: usize,
    /// Longest accepted request line in bytes; longer frames answer a
    /// `protocol` error without buffering the payload.
    pub max_line_bytes: usize,
    /// The shared dispatch layer's knobs (admission, coalescing).
    pub dispatch: DispatchOptions,
}

impl Default for TransportOptions {
    fn default() -> TransportOptions {
        TransportOptions {
            max_connections: 64,
            concurrency: 2,
            max_line_bytes: 1 << 20,
            dispatch: DispatchOptions::default(),
        }
    }
}

/// Counter snapshot of one server (see [`TcpServer::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Connections accepted and served (sheds excluded).
    pub connections: usize,
    /// Connections live right now.
    pub active: usize,
    /// Sockets refused at the connection cap.
    pub shed_connections: usize,
    pub dispatch: DispatchStats,
}

struct Shared {
    shutdown: AtomicBool,
    accepted: AtomicUsize,
    active: AtomicUsize,
    shed: AtomicUsize,
    /// Read-half handles of live connections, for the drain broadcast.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    /// Joinable handles of live + finished connection threads.
    threads: Mutex<Vec<JoinHandle<()>>>,
}

/// One frame off the socket.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum Frame {
    Line(String),
    /// A line longer than the bound: consumed through its newline,
    /// carrying the byte count actually seen.
    Oversized(usize),
    Eof,
}

/// Read one newline-delimited frame without ever buffering more than
/// `max` payload bytes (an attacker can't balloon memory with one giant
/// line — the tail is counted and discarded, not stored).
pub(crate) fn read_bounded_line<R: BufRead>(r: &mut R, max: usize) -> io::Result<Frame> {
    let mut buf: Vec<u8> = Vec::new();
    let mut oversized: Option<usize> = None;
    loop {
        let (sep, used, grow) = {
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                // EOF: an unterminated tail still counts as a frame.
                return Ok(match oversized {
                    Some(n) => Frame::Oversized(n),
                    None if buf.is_empty() => Frame::Eof,
                    None => Frame::Line(String::from_utf8_lossy(&buf).into_owned()),
                });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => (true, pos + 1, chunk[..pos].to_vec()),
                None => (false, chunk.len(), chunk.to_vec()),
            }
        };
        match oversized {
            Some(ref mut n) => *n += grow.len(),
            None if buf.len() + grow.len() > max => {
                oversized = Some(buf.len() + grow.len());
                buf.clear();
            }
            None => buf.extend_from_slice(&grow),
        }
        r.consume(used);
        if sep {
            return Ok(match oversized {
                Some(n) => Frame::Oversized(n),
                None => Frame::Line(String::from_utf8_lossy(&buf).into_owned()),
            });
        }
    }
}

fn write_line(stream: &Mutex<TcpStream>, resp: &ServeResponse) -> io::Result<()> {
    let mut w = stream.lock().unwrap_or_else(|p| p.into_inner());
    writeln!(w, "{}", resp.to_json()).and_then(|_| w.flush())
}

/// The per-connection loop: frame, dispatch over a bounded queue, write.
fn handle_connection(
    conn_id: u64,
    stream: TcpStream,
    dispatcher: &Dispatcher,
    shared: &Shared,
    opts: &TransportOptions,
) {
    let cancel = CancelToken::new();
    let reader = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            obs::diag("serve", format_args!("conn #{conn_id}: clone failed: {e}"));
            return;
        }
    };
    let writer = Mutex::new(stream);
    let workers = opts.concurrency.max(1);
    let queue: BoundedQueue<String> = BoundedQueue::new(workers * 2);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let Some(line) = queue.pop() else { break };
                if cancel.is_cancelled() {
                    continue; // client abandoned the tail; drop it
                }
                let resp = dispatcher.handle_line(&line, &cancel);
                if write_line(&writer, &resp).is_err() {
                    // Client gone: abandon outstanding work on this
                    // connection and stop taking more.
                    cancel.cancel();
                    queue.close();
                    break;
                }
            });
        }

        let mut reader = BufReader::new(reader);
        loop {
            match read_bounded_line(&mut reader, opts.max_line_bytes) {
                Ok(Frame::Eof) | Err(_) => break,
                Ok(Frame::Line(l)) => {
                    if l.trim().is_empty() {
                        continue;
                    }
                    if queue.push(l).is_err() {
                        break; // workers died (write side closed)
                    }
                }
                Ok(Frame::Oversized(seen)) => {
                    dispatcher.note_rejected();
                    let e = QappaError::Protocol(format!(
                        "oversized request line: {seen} bytes (max {})",
                        opts.max_line_bytes
                    ));
                    let resp = ServeResponse { id: None, result: Err(ErrorBody::from(&e)) };
                    if write_line(&writer, &resp).is_err() {
                        break;
                    }
                }
            }
        }
        // EOF semantics: a client that goes away abandons its outstanding
        // requests; a server-initiated drain (shutdown flag set before the
        // forced EOF) lets them finish and flush.
        if !shared.shutdown.load(Ordering::SeqCst) {
            cancel.cancel();
        }
        queue.close();
    });
}

/// A running `qappa serve --listen` endpoint.  Dropping the server shuts
/// it down (drain semantics — see [`TcpServer::shutdown`]).
pub struct TcpServer {
    addr: SocketAddr,
    dispatcher: Arc<Dispatcher>,
    shared: Arc<Shared>,
    opts: TransportOptions,
    accept: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting in a background thread.
    pub fn bind(
        session: Arc<Qappa>,
        addr: impl ToSocketAddrs,
        opts: TransportOptions,
    ) -> Result<TcpServer, QappaError> {
        let listener =
            TcpListener::bind(addr).map_err(|e| QappaError::io("binding listener", e))?;
        let local = listener
            .local_addr()
            .map_err(|e| QappaError::io("resolving listener address", e))?;
        let dispatcher = Arc::new(Dispatcher::new(session, opts.dispatch));
        let shared = Arc::new(Shared {
            shutdown: AtomicBool::new(false),
            accepted: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            conns: Mutex::new(Vec::new()),
            threads: Mutex::new(Vec::new()),
        });
        let accept = {
            let dispatcher = dispatcher.clone();
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, dispatcher, shared, opts))
        };
        obs::diag("serve", format_args!("listening on {local}"));
        Ok(TcpServer { addr: local, dispatcher, shared, opts, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn options(&self) -> TransportOptions {
        self.opts
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            connections: self.shared.accepted.load(Ordering::SeqCst),
            active: self.shared.active.load(Ordering::SeqCst),
            shed_connections: self.shared.shed.load(Ordering::SeqCst),
            dispatch: self.dispatcher.stats(),
        }
    }

    /// Graceful drain: stop accepting, force EOF on every live reader
    /// (in-flight and queued requests still complete and flush — the
    /// cancel tokens do **not** fire), then join every thread.  Idempotent.
    pub fn shutdown(&mut self) {
        let Some(accept) = self.accept.take() else { return };
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway self-connection.
        let _ = TcpStream::connect(self.addr);
        let _ = accept.join();
        // Force EOF on live connections: readers stop, tails drain.
        for (_, conn) in self.shared.conns.lock().unwrap_or_else(|p| p.into_inner()).iter() {
            let _ = conn.shutdown(Shutdown::Read);
        }
        let threads: Vec<JoinHandle<()>> = {
            let mut t = self.shared.threads.lock().unwrap_or_else(|p| p.into_inner());
            t.drain(..).collect()
        };
        for t in threads {
            let _ = t.join();
        }
        obs::diag("serve", format_args!("drained: {:?}", self.stats().dispatch));
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    dispatcher: Arc<Dispatcher>,
    shared: Arc<Shared>,
    opts: TransportOptions,
) {
    let mut next_id: u64 = 0;
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                obs::diag("serve", format_args!("accept failed: {e}"));
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // the wake-up self-connection, or a straggler
        }
        if shared.active.load(Ordering::SeqCst) >= opts.max_connections {
            shed_connection(stream, &shared, opts.max_connections);
            continue;
        }
        let conn_id = next_id;
        next_id += 1;
        shared.accepted.fetch_add(1, Ordering::SeqCst);
        shared.active.fetch_add(1, Ordering::SeqCst);
        obs::registry().counter("serve.connections").inc();
        obs::registry().gauge("serve.connections_active").add(1.0);
        if let Ok(clone) = stream.try_clone() {
            shared
                .conns
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push((conn_id, clone));
        }
        let handle = {
            let dispatcher = dispatcher.clone();
            let shared = shared.clone();
            std::thread::spawn(move || {
                handle_connection(conn_id, stream, &dispatcher, &shared, &opts);
                shared.active.fetch_sub(1, Ordering::SeqCst);
                obs::registry().gauge("serve.connections_active").add(-1.0);
                shared
                    .conns
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .retain(|(id, _)| *id != conn_id);
            })
        };
        shared.threads.lock().unwrap_or_else(|p| p.into_inner()).push(handle);
    }
}

/// Refuse a socket at the connection cap: one structured error line, then
/// close — the client learns *why* instead of hanging in a backlog.
fn shed_connection(mut stream: TcpStream, shared: &Shared, max: usize) {
    shared.shed.fetch_add(1, Ordering::SeqCst);
    obs::registry().counter("serve.connections_shed").inc();
    obs::diag("serve", format_args!("shed connection: {max} already active"));
    let e = QappaError::Protocol(format!(
        "admission: server at connection capacity (max {max}); retry later"
    ));
    let resp = ServeResponse { id: None, result: Err(ErrorBody::from(&e)) };
    let _ = writeln!(stream, "{}", resp.to_json()).and_then(|_| stream.flush());
    let _ = stream.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::session::BackendChoice;
    use std::io::Cursor;

    #[test]
    fn bounded_reader_frames_lines_and_eof() {
        let mut r = Cursor::new(b"alpha\nbeta\n".to_vec());
        assert_eq!(read_bounded_line(&mut r, 64).unwrap(), Frame::Line("alpha".into()));
        assert_eq!(read_bounded_line(&mut r, 64).unwrap(), Frame::Line("beta".into()));
        assert_eq!(read_bounded_line(&mut r, 64).unwrap(), Frame::Eof);
    }

    #[test]
    fn bounded_reader_counts_and_skips_oversized_lines() {
        let mut input = vec![b'x'; 100];
        input.push(b'\n');
        input.extend_from_slice(b"ok\n");
        let mut r = Cursor::new(input);
        assert_eq!(read_bounded_line(&mut r, 10).unwrap(), Frame::Oversized(100));
        // the stream recovers at the next frame
        assert_eq!(read_bounded_line(&mut r, 10).unwrap(), Frame::Line("ok".into()));
        assert_eq!(read_bounded_line(&mut r, 10).unwrap(), Frame::Eof);
    }

    #[test]
    fn bounded_reader_takes_an_unterminated_tail() {
        let mut r = Cursor::new(b"tail".to_vec());
        assert_eq!(read_bounded_line(&mut r, 64).unwrap(), Frame::Line("tail".into()));
        assert_eq!(read_bounded_line(&mut r, 64).unwrap(), Frame::Eof);
    }

    #[test]
    fn server_answers_a_round_trip_and_drains() {
        let session = Arc::new(Qappa::builder().backend(BackendChoice::Native).build());
        let mut server =
            TcpServer::bind(session, "127.0.0.1:0", TransportOptions::default()).unwrap();
        let mut client = TcpStream::connect(server.local_addr()).unwrap();
        writeln!(client, "{{\"id\":42,\"op\":\"workloads\"}}").unwrap();
        client.flush().unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp =
            ServeResponse::from_json(&crate::util::json::Json::parse(&line).unwrap()).unwrap();
        assert_eq!(resp.id, Some(42));
        assert!(resp.result.is_ok());
        drop(client);
        server.shutdown();
        let st = server.stats();
        assert_eq!(st.connections, 1);
        assert_eq!(st.active, 0);
        assert_eq!((st.dispatch.requests, st.dispatch.ok), (1, 1));
    }
}
