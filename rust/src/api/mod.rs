//! The typed service facade — QAPPA as a queryable estimator.
//!
//! The paper's premise is that a trained PPA model answers design queries
//! in microseconds instead of milliseconds-per-config synthesis; this
//! module is the surface that makes those queries *programmable*.  Every
//! other entry point (the CLI in `main.rs`, the serve loop, tests,
//! benches) is a client of three pieces:
//!
//! * [`session::Qappa`] — a warm session built via [`Qappa::builder`]
//!   (backend choice, training recipe, design-space overrides) that owns
//!   the backend, the XLA engine and a shared
//!   [`crate::coordinator::ModelStore`].  Typed methods [`Qappa::synth`],
//!   [`Qappa::fit`], [`Qappa::explore`], [`Qappa::analyze`] and
//!   [`Qappa::workloads`]; models train once per session and stay warm
//!   across any number of queries.
//! * [`types`] — request/response structs with lossless JSON round-trips
//!   through [`crate::util::json`] (schemas in `docs/API.md`).
//! * [`serve`] — the `qappa serve` JSON-lines request loop: concurrent
//!   requests dispatched against one shared session.
//! * [`transport`] + [`dispatch`] — the network serve path
//!   (`qappa serve --listen`): a std-only TCP listener multiplexing
//!   per-connection JSON-lines sessions over one shared dispatcher with
//!   bounded admission, request coalescing and per-connection
//!   cancellation (`docs/SERVE.md`).
//! * [`loadgen`] — the built-in load generator (`qappa loadgen`) that
//!   pins serve throughput in `BENCH_serve.json`.
//!
//! [`error::QappaError`] is the crate-wide structured error every fallible
//! public API returns (re-exported at the crate root).

pub mod dispatch;
pub mod error;
pub mod loadgen;
pub mod serve;
pub mod session;
pub mod transport;
pub mod types;

pub use dispatch::{DispatchOptions, DispatchStats, Dispatcher};
pub use error::QappaError;
pub use loadgen::{run_loadgen, LoadgenOptions, LoadgenReport, RequestMix};
pub use serve::{dispatch, handle_line, serve, ServeOptions, ServeStats};
pub use session::{process_store, BackendChoice, Qappa, QappaBuilder};
pub use transport::{ServerStats, TcpServer, TransportOptions};
pub use crate::obs::{HistogramSummary, MetricsSnapshot};
pub use crate::opt::CancelToken;
pub use crate::opt::objective::Constraints;
pub use types::{
    config_from_json, AnalyzeRequest, AnalyzeResponse, CvPoint, ErrorBody, ExploreEntry,
    ExploreRequest, ExploreResponse, ExploreSummary, FitModelReport, FitRequest, FitResponse,
    LayerCost, OptPoint, OptimizeRequest, OptimizeResponse, PhaseSummary, PrecisionRequest,
    RequestBody,
    ResponseBody, ServeRequest, ServeResponse, SessionInfo, SynthRequest, SynthResponse,
    WorkloadInfo, WorkloadsRequest, WorkloadsResponse, OPS,
};
