//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! `forall` runs a property over `n` seeded random cases; on failure it
//! performs a bounded shrink (re-running with "smaller" generated values by
//! re-seeding towards simpler cases) and reports the smallest failing seed.
//! Generators are plain closures over [`Rng`], composed with ordinary Rust.

use crate::util::prng::Rng;

/// Property outcome: `Err(message)` describes the violation.  Properties
/// report plain test-expectation messages, not service failures, so this
/// stays a string (the crate's service APIs return
/// [`crate::QappaError`] instead).
pub type PropResult = Result<(), String>;

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub case: String,
    pub msg: String,
}

/// Run `prop` over `n` random cases. `gen` draws a case from the RNG;
/// `prop` returns `Err(msg)` on violation. Panics with the failing case
/// (smallest seed found during the retry sweep) so `cargo test` reports it.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    n: usize,
    base_seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> PropResult,
) {
    if let Some(f) = forall_result(n, base_seed, &gen, &prop) {
        panic!(
            "property '{name}' failed (seed {}):\n  case: {}\n  {}",
            f.seed, f.case, f.msg
        );
    }
}

/// Non-panicking variant (used by testkit's own tests).
pub fn forall_result<T: std::fmt::Debug>(
    n: usize,
    base_seed: u64,
    gen: &impl Fn(&mut Rng) -> T,
    prop: &impl Fn(&T) -> PropResult,
) -> Option<Failure> {
    for i in 0..n {
        let seed = base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            // "Shrink": probe a handful of nearby seeds and keep the
            // lexicographically-smallest failing debug representation —
            // cheap, deterministic, and usually lands on a simpler case.
            let mut best = Failure { seed, case: format!("{case:?}"), msg };
            for probe in 0..32u64 {
                let s2 = seed ^ (probe + 1);
                let mut r2 = Rng::new(s2);
                let c2 = gen(&mut r2);
                if let Err(m2) = prop(&c2) {
                    let repr = format!("{c2:?}");
                    if repr.len() < best.case.len() {
                        best = Failure { seed: s2, case: repr, msg: m2 };
                    }
                }
            }
            return Some(best);
        }
    }
    None
}

/// Draw a u32 in [lo, hi] (inclusive).
pub fn gen_u32(rng: &mut Rng, lo: u32, hi: u32) -> u32 {
    lo + rng.below((hi - lo + 1) as usize) as u32
}

/// Draw an f64 in [lo, hi).
pub fn gen_f64(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    rng.range_f64(lo, hi)
}

/// Draw a random transformer layer — matmul projections (prefill `m`
/// spans many rows, decode `m = 1`) and attention over a KV cache with
/// `seq_q <= seq_kv` — always structurally valid.
pub fn gen_transformer_layer(rng: &mut Rng) -> crate::dataflow::Layer {
    use crate::dataflow::Layer;
    if rng.f64() < 0.5 {
        // ~1 in 4 matmuls are decode-shaped (single streamed row).
        let m = if rng.f64() < 0.25 { 1 } else { gen_u32(rng, 2, 512) };
        Layer::matmul("mm", m, gen_u32(rng, 8, 1024), gen_u32(rng, 8, 1024))
    } else {
        let heads = *rng.choice(&[1u32, 2, 4, 8, 16]);
        let head_dim = *rng.choice(&[16u32, 32, 64, 128]);
        let seq_kv = gen_u32(rng, 1, 1024);
        // Decode (seq_q = 1) or prefill-ish (any prefix of the cache).
        let seq_q = if rng.f64() < 0.5 { 1 } else { gen_u32(rng, 1, seq_kv) };
        Layer::attention("attn", heads, head_dim, seq_q, seq_kv)
    }
}

/// Draw a random DNN layer spanning the full taxonomy — fully-connected,
/// depthwise, grouped and dense convolutions plus the transformer kinds
/// (see [`crate::dataflow::Layer`]) — always structurally valid.
pub fn gen_layer(rng: &mut Rng) -> crate::dataflow::Layer {
    use crate::dataflow::Layer;
    let roll = rng.f64();
    if roll < 0.15 {
        gen_transformer_layer(rng)
    } else if roll < 0.3 {
        Layer::fc("fc", gen_u32(rng, 8, 4096), gen_u32(rng, 8, 4096))
    } else if roll < 0.45 {
        let rs = *rng.choice(&[3u32, 5]);
        let hw = gen_u32(rng, 7, 64).max(rs);
        let c = 4 * gen_u32(rng, 1, 64);
        Layer::dw("dw", c, hw, rs, *rng.choice(&[1u32, 2]), rs / 2)
    } else if roll < 0.6 {
        let rs = *rng.choice(&[1u32, 3]);
        let hw = gen_u32(rng, 7, 64).max(rs);
        let g = *rng.choice(&[2u32, 4, 8]);
        let c = g * gen_u32(rng, 1, 32);
        let k = g * gen_u32(rng, 1, 32);
        Layer::grouped("grouped", c, k, hw, rs, *rng.choice(&[1u32, 2]), rs / 2, g)
    } else {
        let rs = *rng.choice(&[1u32, 3, 5, 7]);
        let hw = gen_u32(rng, 7, 64).max(rs);
        Layer::conv(
            "conv",
            gen_u32(rng, 1, 256),
            gen_u32(rng, 1, 256),
            hw,
            hw,
            rs,
            *rng.choice(&[1u32, 2]),
            rs / 2,
        )
    }
}

/// Draw a random, always-valid quantization spec spanning every MAC kind:
/// operands in 2..=32 bits, accumulator at least as wide as both operands
/// (the [`crate::config::QuantSpec::validate`] invariants hold by
/// construction).
pub fn gen_quant_spec(rng: &mut Rng) -> crate::config::QuantSpec {
    use crate::config::{MacKind, QuantSpec};
    let mac = match rng.below(3) {
        0 => MacKind::IntExact,
        1 => MacKind::Lightweight(1 + gen_u32(rng, 0, 2)),
        _ => MacKind::Fp,
    };
    let act_bits = gen_u32(rng, 2, 32);
    let wt_bits = gen_u32(rng, 2, 32);
    let floor = act_bits.max(wt_bits);
    let psum_bits = gen_u32(rng, floor, (2 * floor + 8).min(64));
    QuantSpec { act_bits, wt_bits, psum_bits, mac }
}

/// Draw a random accelerator configuration from sane generator bounds.
pub fn gen_config(rng: &mut Rng) -> crate::config::AcceleratorConfig {
    use crate::config::{AcceleratorConfig, ALL_PE_TYPES};
    AcceleratorConfig {
        pe_type: *rng.choice(&ALL_PE_TYPES),
        pe_rows: gen_u32(rng, 2, 32),
        pe_cols: gen_u32(rng, 2, 32),
        glb_kb: gen_u32(rng, 16, 512),
        spad_ifmap_b: gen_u32(rng, 8, 128),
        spad_filter_b: gen_u32(rng, 32, 1024),
        spad_psum_b: gen_u32(rng, 8, 256),
        bandwidth_gbps: gen_f64(rng, 0.5, 16.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_returns_none() {
        let out = forall_result(
            100,
            1,
            &|rng| gen_u32(rng, 0, 100),
            &|&x| if x <= 100 { Ok(()) } else { Err("bound".into()) },
        );
        assert!(out.is_none());
    }

    #[test]
    fn failing_property_reports_case() {
        let out = forall_result(
            100,
            1,
            &|rng| gen_u32(rng, 0, 100),
            &|&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
        );
        let f = out.expect("must fail");
        assert!(f.msg.contains(">= 50"));
    }

    #[test]
    #[should_panic(expected = "property 'demo' failed")]
    fn forall_panics_with_name() {
        forall("demo", 50, 3, |rng| gen_u32(rng, 10, 20), |_| Err("always".into()));
    }

    #[test]
    fn gen_config_is_valid() {
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            gen_config(&mut rng).validate().expect("generated config valid");
        }
    }

    #[test]
    fn gen_quant_spec_is_valid_and_covers_kinds() {
        use crate::config::MacKind;
        let mut rng = Rng::new(21);
        let mut kinds = std::collections::BTreeSet::new();
        for _ in 0..400 {
            let q = gen_quant_spec(&mut rng);
            q.validate().expect("generated spec valid");
            assert!(q.psum_bits >= q.act_bits && q.psum_bits >= q.wt_bits);
            kinds.insert(match q.mac {
                MacKind::Fp => "fp",
                MacKind::IntExact => "int",
                MacKind::Lightweight(_) => "light",
            });
        }
        assert_eq!(kinds.len(), 3, "generator must cover all MAC kinds: {kinds:?}");
    }

    #[test]
    fn gen_layer_is_valid_and_covers_taxonomy() {
        let mut rng = Rng::new(11);
        let mut kinds = std::collections::BTreeSet::new();
        for _ in 0..500 {
            let l = gen_layer(&mut rng);
            l.validate().expect("generated layer valid");
            kinds.insert(l.kind());
        }
        for kind in ["fc", "dw", "grouped", "conv", "matmul", "attention"] {
            assert!(kinds.contains(kind), "generator never produced '{kind}'");
        }
    }

    #[test]
    fn gen_transformer_layer_is_valid_and_covers_both_phases() {
        use crate::dataflow::layer::Op;
        let mut rng = Rng::new(13);
        let (mut decode, mut prefill, mut matmuls) = (0, 0, 0);
        for _ in 0..400 {
            let l = gen_transformer_layer(&mut rng);
            l.validate().expect("generated transformer layer valid");
            match l.op {
                Op::Matmul { .. } => matmuls += 1,
                Op::Attention { seq_q: 1, .. } => decode += 1,
                Op::Attention { .. } => prefill += 1,
                Op::Conv => panic!("transformer generator produced conv"),
            }
        }
        assert!(matmuls > 0 && decode > 0 && prefill > 0, "{matmuls}/{decode}/{prefill}");
    }

    #[test]
    fn fuzz_malformed_transformer_shapes_name_the_offending_field() {
        use crate::dataflow::layer::{Layer, Op};
        forall(
            "malformed transformer shapes produce field-naming errors",
            240,
            31,
            |rng| {
                let mut l = gen_transformer_layer(rng);
                // Mutate one field into an invalid state; record which
                // field the error must name.
                let field = match (&mut l.op, rng.below(3) as u32) {
                    (Op::Matmul { m, .. }, 0) => {
                        *m = 0;
                        "m"
                    }
                    (Op::Matmul { n, .. }, 1) => {
                        *n = 0;
                        "n"
                    }
                    (Op::Matmul { .. }, _) => {
                        l.c += 1; // carried reduction dim out of sync
                        "k"
                    }
                    (Op::Attention { heads, .. }, 0) => {
                        *heads = 0;
                        "heads"
                    }
                    (Op::Attention { head_dim, .. }, 1) => {
                        *head_dim = 0;
                        "head_dim"
                    }
                    (Op::Attention { seq_q, seq_kv, .. }, _) => {
                        *seq_q = *seq_kv + 1; // cache misses query positions
                        "seq_kv"
                    }
                };
                (l, field)
            },
            |(l, field)| {
                let msg = match l.validate() {
                    Err(e) => e.to_string(),
                    Ok(()) => return Err(format!("{l:?} validated despite mutation")),
                };
                if msg.contains(&format!("\"{field}\"")) && msg.contains(&l.name) {
                    Ok(())
                } else {
                    Err(format!("error '{msg}' does not name \"{field}\""))
                }
            },
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        assert_eq!(gen_config(&mut a), gen_config(&mut b));
    }
}
