//! Tracing spans and the pluggable trace sink.
//!
//! The sink is resolved **once** per process from `QAPPA_TRACE`
//! ([`OnceLock`]), fixing the old hot-path cost of an `env::var_os` call
//! per phase event:
//!
//! * unset / empty / `0` — disabled; every instrumentation call reduces to
//!   one atomic load and an early return (no formatting, no clock read for
//!   spans entered after the check);
//! * `1` / `true` — human-readable stderr, the historical format:
//!   `[trace] sweep/int16/shard0/predict(1024): 1.2 ms`, nested spans
//!   indented two spaces per level;
//! * anything else — treated as a file path; every event is appended as
//!   one JSON object per line (`{"ev":"span","name":...,"ms":...,
//!   "depth":...}`), machine-consumable by benches and offline tooling.
//!
//! [`Span`] guards time a scope and record parent/child nesting via a
//! thread-local depth counter; `key=value` attributes ride along.
//! [`phase_with`] is the lazy phase-timing primitive the sweep/opt/store
//! hot paths call: the message closure only runs when the sink is live.
//! [`diag`] is the one door for human diagnostic lines (`[store] ...`,
//! `[engine] ...`): always stderr, never stdout, one prefix convention.

use std::cell::Cell;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{obj, Json};

enum Sink {
    Disabled,
    Stderr,
    /// JSON-lines trace file (append mode).
    File(Mutex<File>),
}

static SINK: OnceLock<Sink> = OnceLock::new();

thread_local! {
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

fn sink() -> &'static Sink {
    SINK.get_or_init(|| match std::env::var("QAPPA_TRACE") {
        Err(_) => Sink::Disabled,
        Ok(v) => match v.as_str() {
            "" | "0" => Sink::Disabled,
            "1" | "true" => Sink::Stderr,
            path => match OpenOptions::new().create(true).append(true).open(path) {
                Ok(f) => Sink::File(Mutex::new(f)),
                Err(e) => {
                    // A bad path must not kill the run: fall back to the
                    // human sink so the operator still sees the events.
                    eprintln!("[trace] cannot open trace file {path:?} ({e}); using stderr");
                    Sink::Stderr
                }
            },
        },
    })
}

/// Is any trace sink live?  One `OnceLock` load; callers may guard
/// expensive message construction on this (or use [`phase_with`], which
/// does it for them).
pub fn enabled() -> bool {
    !matches!(sink(), Sink::Disabled)
}

fn emit(ev: &str, name: &str, ms: f64, depth: usize, attrs: &[(&'static str, String)]) {
    match sink() {
        Sink::Disabled => {}
        Sink::Stderr => {
            let indent = "  ".repeat(depth);
            if attrs.is_empty() {
                eprintln!("[trace] {indent}{name}: {ms:.1} ms");
            } else {
                let kv: Vec<String> =
                    attrs.iter().map(|(k, v)| format!("{k}={v}")).collect();
                eprintln!("[trace] {indent}{name}: {ms:.1} ms ({})", kv.join(", "));
            }
        }
        Sink::File(f) => {
            let mut pairs = vec![
                ("ev", Json::Str(ev.into())),
                ("name", Json::Str(name.into())),
                ("ms", Json::Num(ms)),
                ("depth", Json::Num(depth as f64)),
            ];
            if !attrs.is_empty() {
                pairs.push((
                    "attrs",
                    obj(attrs.iter().map(|(k, v)| (*k, Json::Str(v.clone()))).collect()),
                ));
            }
            let line = obj(pairs).to_string();
            let mut f = f.lock().unwrap_or_else(|p| p.into_inner());
            // Trace loss is not worth killing a run over; ignore I/O errors.
            let _ = writeln!(f, "{line}");
        }
    }
}

/// A hierarchical timed span: created by [`span`], records wall time from
/// construction to drop, nests via a thread-local depth (children report
/// `depth = parent + 1`), and carries optional `key=value` attributes.
///
/// When tracing is disabled the guard is inert: no clock read, no
/// allocation, nothing on drop.
pub struct Span {
    name: String,
    t0: Instant,
    depth: usize,
    attrs: Vec<(&'static str, String)>,
    active: bool,
}

/// Enter a named span; time stops (and the event is emitted) when the
/// returned guard drops.
pub fn span(name: &str) -> Span {
    if !enabled() {
        return Span {
            name: String::new(),
            t0: Instant::now(),
            depth: 0,
            attrs: Vec::new(),
            active: false,
        };
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    Span { name: name.to_string(), t0: Instant::now(), depth, attrs: Vec::new(), active: true }
}

impl Span {
    /// Attach a `key=value` attribute (shown in parentheses on the human
    /// sink, as an `attrs` object on the JSON sink).  No-op when disabled.
    pub fn attr(&mut self, key: &'static str, value: impl std::fmt::Display) -> &mut Span {
        if self.active {
            self.attrs.push((key, value.to_string()));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let ms = self.t0.elapsed().as_secs_f64() * 1e3;
        emit("span", &self.name, ms, self.depth, &self.attrs);
    }
}

/// Record one phase timing (elapsed since `t0`) under a lazily-built name.
/// The closure only runs when a sink is live — hot loops pay one atomic
/// load on the disabled path, not a `format!`.
pub fn phase_with(name: impl FnOnce() -> String, t0: Instant) {
    if !enabled() {
        return;
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    emit("phase", &name(), ms, DEPTH.with(Cell::get), &[]);
}

/// Route one human diagnostic line to stderr with its subsystem prefix:
/// `diag("store", format_args!("dse wall time: {dt:.2}s"))` prints
/// `[store] dse wall time: 1.23s`.  Diagnostics never touch stdout (the
/// machine channel) — the purity convention `tests/integration_cli.rs`
/// pins.
pub fn diag(subsystem: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{subsystem}] {args}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        // The test process never sets QAPPA_TRACE, so the resolved sink is
        // Disabled (cargo test runs with a clean env here; suites that
        // exercise live sinks spawn subprocesses).
        if enabled() {
            return; // an outer harness set QAPPA_TRACE; nothing to assert
        }
        let before = DEPTH.with(Cell::get);
        {
            let mut s = span("test.noop");
            s.attr("k", 1);
        }
        assert_eq!(DEPTH.with(Cell::get), before, "inert span must not touch depth");
    }

    #[test]
    fn phase_with_skips_the_closure_when_disabled() {
        if enabled() {
            return;
        }
        let mut ran = false;
        phase_with(
            || {
                ran = true;
                String::new()
            },
            Instant::now(),
        );
        assert!(!ran, "disabled sink must not build the message");
    }
}
