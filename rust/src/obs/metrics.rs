//! The process-wide metrics registry: named counters, gauges and
//! log-scale latency histograms behind `Arc`-shared typed handles.
//!
//! One registry per process ([`registry`]) feeds every consumer the same
//! numbers: the `metrics` wire op, `qappa metrics`, `--stats-json`, the
//! bench harness.  Handles are cheap to clone and lock-free to update
//! (`Relaxed` atomics — these are statistics, not synchronization);
//! registering a name twice returns the same underlying cell, so
//! subsystems can re-acquire handles by name without coordination.
//!
//! Histograms record **milliseconds** into logarithmic buckets (16 per
//! octave starting at 1 µs → ≤ ~4.4% bucket width over a 1 µs..71 min
//! range) and estimate p50/p95/p99 by rank interpolation inside the
//! matching bucket — the one quantile implementation the codebase shares
//! (loadgen reports come from this type; `util::stats::percentile` is the
//! exact oracle its tests pin against).  `max` is exact (an atomic
//! f64-bits max, valid because non-negative IEEE-754 floats order like
//! their bit patterns).
//!
//! [`MetricsSnapshot`] is the stable wire shape:
//!
//! ```json
//! {"counters": {"serve.requests": 40},
//!  "gauges": {"serve.inflight": 0},
//!  "histograms": {"serve.request_ms": {"count": 40, "mean_ms": 1.9,
//!    "p50_ms": 1.7, "p95_ms": 4.1, "p99_ms": 6.0, "max_ms": 6.2}}}
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::api::error::QappaError;
use crate::util::json::{obj, Json};

/// Log-bucket geometry: 16 sub-buckets per octave (ratio 2^(1/16) ≈
/// 1.0443), bucket 0 anchored at 1 µs; 512 buckets span 32 octaves,
/// i.e. 1 µs .. ~71.6 minutes before the last bucket saturates.
const SUB_PER_OCTAVE: f64 = 16.0;
const NUM_BUCKETS: usize = 512;
const LO_MS: f64 = 1e-3;

/// A monotone event counter.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

struct GaugeCell {
    /// f64 bits; gauges may hold any finite value (hypervolume, in-flight
    /// depth).
    bits: AtomicU64,
}

/// A last-value / up-down instrument storing an `f64`.
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<GaugeCell>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.cell.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, d: f64) {
        let _ = self.cell.bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            Some((f64::from_bits(b) + d).to_bits())
        });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.cell.bits.load(Ordering::Relaxed))
    }
}

struct HistCore {
    buckets: Vec<AtomicU64>,
    /// Total microseconds recorded (mean's numerator).
    sum_us: AtomicU64,
    /// Exact max as f64 bits (non-negative floats order like u64 bits).
    max_bits: AtomicU64,
}

/// A log-scale histogram of millisecond samples.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistCore>,
}

fn bucket_index(ms: f64) -> usize {
    // Callers normalize NaN/negatives to 0.0 first (`record_ms`), so a
    // plain comparison is total here.
    if ms <= LO_MS {
        return 0;
    }
    let idx = ((ms / LO_MS).log2() * SUB_PER_OCTAVE).floor() as usize;
    idx.min(NUM_BUCKETS - 1)
}

/// `[lo, hi)` bounds of bucket `i` in milliseconds (bucket 0 reaches down
/// to 0).
fn bucket_bounds(i: usize) -> (f64, f64) {
    let lo = if i == 0 { 0.0 } else { LO_MS * (i as f64 / SUB_PER_OCTAVE).exp2() };
    let hi = LO_MS * ((i + 1) as f64 / SUB_PER_OCTAVE).exp2();
    (lo, hi)
}

/// Rank-interpolated quantile over a bucket snapshot: mirrors
/// `util::stats::percentile`'s rank convention (`(p/100)·(n-1)`, linear),
/// resolved to the matching log bucket.  `max_ms` caps the estimate so
/// p100 returns the exact observed maximum.
fn quantile_from(buckets: &[u64], total: u64, p: f64, max_ms: f64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (total - 1) as f64;
    let mut seen = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        // This bucket holds sample ranks [seen, seen + c - 1].
        if (seen + c - 1) as f64 >= rank {
            let (lo, hi) = bucket_bounds(i);
            let frac = ((rank - seen as f64 + 0.5) / c as f64).clamp(0.0, 1.0);
            return (lo + frac * (hi - lo)).min(max_ms);
        }
        seen += c;
    }
    max_ms
}

fn new_hist_core() -> Arc<HistCore> {
    Arc::new(HistCore {
        buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        sum_us: AtomicU64::new(0),
        max_bits: AtomicU64::new(0f64.to_bits()),
    })
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// A standalone histogram detached from any registry — for local
    /// aggregation (loadgen's latency report); process-wide instruments
    /// come from [`MetricsRegistry::histogram`] instead.
    pub fn new() -> Histogram {
        Histogram { core: new_hist_core() }
    }

    /// Record one sample, in milliseconds (negatives clamp to 0).
    pub fn record_ms(&self, ms: f64) {
        let ms = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        self.core.buckets[bucket_index(ms)].fetch_add(1, Ordering::Relaxed);
        self.core.sum_us.fetch_add((ms * 1e3).round() as u64, Ordering::Relaxed);
        self.core.max_bits.fetch_max(ms.to_bits(), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn max_ms(&self) -> f64 {
        f64::from_bits(self.core.max_bits.load(Ordering::Relaxed))
    }

    /// Estimate the p-th percentile (0..=100) in milliseconds.
    pub fn quantile(&self, p: f64) -> f64 {
        let buckets: Vec<u64> =
            self.core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = buckets.iter().sum();
        quantile_from(&buckets, count, p, self.max_ms())
    }

    /// One internally-consistent summary: the buckets are copied once, so
    /// the count and every quantile describe the same sample set even
    /// while other threads keep recording.
    pub fn summary(&self) -> HistogramSummary {
        let buckets: Vec<u64> =
            self.core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = buckets.iter().sum();
        let max_ms = self.max_ms();
        let mean_ms = if count == 0 {
            0.0
        } else {
            self.core.sum_us.load(Ordering::Relaxed) as f64 / 1e3 / count as f64
        };
        HistogramSummary {
            count,
            mean_ms,
            p50_ms: quantile_from(&buckets, count, 50.0, max_ms),
            p95_ms: quantile_from(&buckets, count, 95.0, max_ms),
            p99_ms: quantile_from(&buckets, count, 99.0, max_ms),
            max_ms,
        }
    }
}

/// Wire shape of one histogram: stable field names
/// `count`/`mean_ms`/`p50_ms`/`p95_ms`/`p99_ms`/`max_ms`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl HistogramSummary {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("max_ms", Json::Num(self.max_ms)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<HistogramSummary, QappaError> {
        let f = |k: &str| -> Result<f64, QappaError> {
            v.get(k).as_f64().ok_or_else(|| {
                QappaError::Protocol(format!("metrics histogram: missing \"{k}\""))
            })
        };
        Ok(HistogramSummary {
            count: f("count")? as u64,
            mean_ms: f("mean_ms")?,
            p50_ms: f("p50_ms")?,
            p95_ms: f("p95_ms")?,
            p99_ms: f("p99_ms")?,
            max_ms: f("max_ms")?,
        })
    }
}

/// One consistent point-in-time view of the whole registry — the payload
/// of the `metrics` wire op and the `--stats-json` flag.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistogramSummary>,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let map = |m: &BTreeMap<String, f64>| {
            Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
        };
        obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                        .collect(),
                ),
            ),
            ("gauges", map(&self.gauges)),
            (
                "histograms",
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|(k, v)| (k.clone(), v.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<MetricsSnapshot, QappaError> {
        let section = |k: &str| -> Result<&BTreeMap<String, Json>, QappaError> {
            v.get(k)
                .as_obj()
                .ok_or_else(|| QappaError::Protocol(format!("metrics: missing \"{k}\" object")))
        };
        let mut counters = BTreeMap::new();
        for (k, val) in section("counters")? {
            let n = val.as_f64().ok_or_else(|| {
                QappaError::Protocol(format!("metrics: counter \"{k}\" must be a number"))
            })?;
            counters.insert(k.clone(), n as u64);
        }
        let mut gauges = BTreeMap::new();
        for (k, val) in section("gauges")? {
            let n = val.as_f64().ok_or_else(|| {
                QappaError::Protocol(format!("metrics: gauge \"{k}\" must be a number"))
            })?;
            gauges.insert(k.clone(), n);
        }
        let mut histograms = BTreeMap::new();
        for (k, val) in section("histograms")? {
            histograms.insert(k.clone(), HistogramSummary::from_json(val)?);
        }
        Ok(MetricsSnapshot { counters, gauges, histograms })
    }
}

/// The registry: three name → cell maps behind short-lived locks (handle
/// acquisition and snapshots lock; updates through handles never do).
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<GaugeCell>>>,
    hists: Mutex<BTreeMap<String, Arc<HistCore>>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Counter handle for `name`, creating it at zero on first use.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap_or_else(|p| p.into_inner());
        let cell = m
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone();
        Counter { cell }
    }

    /// Gauge handle for `name`, creating it at 0.0 on first use.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap_or_else(|p| p.into_inner());
        let cell = m
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(GaugeCell { bits: AtomicU64::new(0f64.to_bits()) }))
            .clone();
        Gauge { cell }
    }

    /// Histogram handle for `name`, creating it empty on first use.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.hists.lock().unwrap_or_else(|p| p.into_inner());
        let core = m.entry(name.to_string()).or_insert_with(new_hist_core).clone();
        Histogram { core }
    }

    /// Snapshot every registered instrument.  Counter reads are atomic and
    /// monotone; each histogram summary is computed from one bucket copy,
    /// so its count and quantiles are mutually consistent even under
    /// concurrent recording.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters: Vec<(String, Arc<AtomicU64>)> = {
            let m = self.counters.lock().unwrap_or_else(|p| p.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let gauges: Vec<(String, Arc<GaugeCell>)> = {
            let m = self.gauges.lock().unwrap_or_else(|p| p.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let hists: Vec<(String, Histogram)> = {
            let m = self.hists.lock().unwrap_or_else(|p| p.into_inner());
            m.iter().map(|(k, v)| (k.clone(), Histogram { core: v.clone() })).collect()
        };
        MetricsSnapshot {
            counters: counters
                .into_iter()
                .map(|(k, c)| (k, c.load(Ordering::Relaxed)))
                .collect(),
            gauges: gauges
                .into_iter()
                .map(|(k, g)| (k, f64::from_bits(g.bits.load(Ordering::Relaxed))))
                .collect(),
            histograms: hists.into_iter().map(|(k, h)| (k, h.summary())).collect(),
        }
    }
}

/// The process-wide registry every subsystem feeds.
pub fn registry() -> &'static MetricsRegistry {
    static REG: OnceLock<MetricsRegistry> = OnceLock::new();
    REG.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;

    #[test]
    fn counters_accumulate_and_share_cells_by_name() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("t.count");
        let b = reg.counter("t.count");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5, "same name must alias the same cell");
        assert_eq!(reg.snapshot().counters["t.count"], 5);
    }

    #[test]
    fn gauges_hold_floats_and_support_up_down() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("t.gauge");
        g.set(2.5);
        g.add(1.0);
        g.add(-3.0);
        assert!((g.get() - 0.5).abs() < 1e-12);
        assert!((reg.snapshot().gauges["t.gauge"] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_track_the_exact_oracle() {
        // Uniform 0.1..100 ms: log buckets are ≤4.43% wide, interpolation
        // across a bucket seam at most doubles that — pin 10% relative.
        let reg = MetricsRegistry::new();
        let h = reg.histogram("t.lat");
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.1).collect();
        for &x in &xs {
            h.record_ms(x);
        }
        let s = h.summary();
        assert_eq!(s.count, 1000);
        for (est, p) in [(s.p50_ms, 50.0), (s.p95_ms, 95.0), (s.p99_ms, 99.0)] {
            let exact = percentile(&xs, p);
            assert!(
                (est - exact).abs() / exact < 0.10,
                "p{p}: est {est} vs exact {exact}"
            );
        }
        assert_eq!(s.max_ms, 100.0, "max is exact, not bucketed");
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms && s.p99_ms <= s.max_ms);
    }

    #[test]
    fn empty_and_degenerate_histograms_are_safe() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("t.empty");
        let s = h.summary();
        assert_eq!((s.count, s.p50_ms, s.max_ms), (0, 0.0, 0.0));
        h.record_ms(f64::NAN); // clamps to 0, must not poison anything
        h.record_ms(-3.0);
        let s = h.summary();
        assert_eq!(s.count, 2);
        assert_eq!(s.max_ms, 0.0);
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let reg = MetricsRegistry::new();
        reg.counter("a.b").add(7);
        reg.gauge("c.d").set(1.25);
        let h = reg.histogram("e.f_ms");
        h.record_ms(3.0);
        let snap = reg.snapshot();
        let parsed = Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(MetricsSnapshot::from_json(&parsed).unwrap(), snap);
    }
}
