//! Unified observability: structured tracing spans and a process-wide
//! metrics registry, wired through sweep / optimizer / store / serve.
//!
//! Two halves, one subsystem:
//!
//! * [`trace`] — hierarchical named spans ([`span`]) and phase timings
//!   ([`trace::phase_with`]) routed to one pluggable sink resolved **once**
//!   from `QAPPA_TRACE`: unset → disabled (near-zero overhead: one
//!   `OnceLock` load per call), `1`/`true` → the human stderr format the
//!   repo has always printed (`[trace] phase: 1.2 ms`), any other value →
//!   a JSON-lines trace file at that path.  Human diagnostics
//!   (`[store]`/`[engine]`/`[serve]` progress lines) flow through
//!   [`trace::diag`] so every subsystem shares one prefix convention and
//!   stdout stays machine-parseable.
//! * [`metrics`] — a process-wide registry of named counters, gauges and
//!   log-scale latency histograms (p50/p95/p99/max), `Arc`-shared typed
//!   handles, and one stable `snapshot()` JSON shape served by the
//!   `metrics` wire op and the `--stats-json` CLI flag.
//!
//! Metric naming: `subsystem.metric` with dots, e.g. `sweep.shards`,
//! `opt.evaluations`, `store.cache_hits`, `serve.request_ms`.  The full
//! scheme, the span model and the wire format live in
//! `docs/OBSERVABILITY.md`.

pub mod metrics;
pub mod trace;

pub use metrics::{
    registry, Counter, Gauge, Histogram, HistogramSummary, MetricsRegistry, MetricsSnapshot,
};
pub use trace::{diag, span, Span};
