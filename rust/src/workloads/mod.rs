//! DNN workload definitions and ingestion.
//!
//! Two sources of workloads, both producing a plain `Vec<Layer>`:
//!
//! 1. **Built-in builders** — the paper's three classic CNNs (VGG-16,
//!    ResNet-34/50) plus the depthwise-separable MobileNetV1/V2 family,
//!    all at 224x224 inference, and the transformer decoder stacks
//!    (`opt-1.3b`, `llama2-7b` — see [`transformer`]). Resolve by name
//!    with [`by_name`] or [`load`].
//! 2. **User-supplied JSON** — [`from_json`] ingests an arbitrary network
//!    from the schema documented in `docs/WORKLOADS.md`, so
//!    `qappa explore --workload path/to/model.json` evaluates models the
//!    repo has never heard of. [`to_json`] writes the same schema back
//!    (round-trip tested).
//!
//! [`load`] is the CLI entry point: it tries built-in names first, then
//! treats the spec as a JSON file path, and otherwise fails with the full
//! list of known names.

pub mod transformer;

use crate::api::error::QappaError;
use crate::dataflow::layer::{Layer, Op};
use crate::util::json::{obj, Json};

pub use transformer::{
    has_transformer_ops, llama2_7b, opt_1p3b, shape_for_phase, Phase, DEFAULT_CTX,
};

/// Canonical names of the built-in workloads, in CLI/help order.
pub const WORKLOAD_NAMES: [&str; 7] = [
    "vgg16",
    "resnet34",
    "resnet50",
    "mobilenetv1",
    "mobilenetv2",
    "opt-1.3b",
    "llama2-7b",
];

/// Canonical name + builder for a workload alias, if known.
fn builder(name: &str) -> Option<(&'static str, fn() -> Vec<Layer>)> {
    match name.to_ascii_lowercase().as_str() {
        "vgg16" | "vgg-16" => Some(("vgg16", vgg16)),
        "resnet34" | "resnet-34" => Some(("resnet34", resnet34)),
        "resnet50" | "resnet-50" => Some(("resnet50", resnet50)),
        "mobilenetv1" | "mobilenet-v1" | "mobilenet" => Some(("mobilenetv1", mobilenetv1)),
        "mobilenetv2" | "mobilenet-v2" => Some(("mobilenetv2", mobilenetv2)),
        "opt-1.3b" | "opt1.3b" | "opt-1p3b" => Some(("opt-1.3b", transformer::opt_1p3b)),
        "llama2-7b" | "llama-2-7b" | "llama2_7b" => Some(("llama2-7b", transformer::llama2_7b)),
        _ => None,
    }
}

/// Named workload for CLI selection (accepts aliases like `vgg-16`).
pub fn by_name(name: &str) -> Option<Vec<Layer>> {
    builder(name).map(|(_, f)| f())
}

/// Resolve a CLI workload spec: a built-in name (see [`WORKLOAD_NAMES`]),
/// or a path to a JSON model file. Returns `(canonical_name, layers)`.
///
/// The error message lists every built-in name and points at the JSON
/// schema docs, so an unknown `--workload` is always actionable.
pub fn load(spec: &str) -> Result<(String, Vec<Layer>), QappaError> {
    if let Some((canonical, f)) = builder(spec) {
        return Ok((canonical.to_string(), f()));
    }
    let looks_like_path =
        spec.ends_with(".json") || spec.contains('/') || spec.contains('\\');
    if looks_like_path {
        let text = std::fs::read_to_string(spec)
            .map_err(|e| QappaError::io(format!("reading workload file '{spec}'"), e))?;
        return from_json(&text).map_err(|e| e.context(format!("workload file '{spec}'")));
    }
    Err(QappaError::Workload(format!(
        "unknown workload '{spec}'. Built-in workloads: {}. \
         Or pass a path to a .json model file (schema: docs/WORKLOADS.md).",
        WORKLOAD_NAMES.join(", ")
    )))
}

// ---------------------------------------------------------------------------
// JSON ingestion (docs/WORKLOADS.md documents the schema)
// ---------------------------------------------------------------------------

/// Parse a workload from JSON text. Returns `(name, layers)`.
///
/// Top level: `{"name": "...", "layers": [ ... ]}`. Each layer object has a
/// `"type"` of `conv` (default), `grouped`, `dw`, `pw`, `fc`, `matmul` or
/// `attention`; see `docs/WORKLOADS.md` for the per-type fields and
/// defaults. Every layer is
/// validated ([`Layer::validate`]) so malformed models fail with the layer
/// name in the error, not deep inside the dataflow model.
pub fn from_json(text: &str) -> Result<(String, Vec<Layer>), QappaError> {
    let v = Json::parse(text).map_err(|e| QappaError::Workload(e.to_string()))?;
    from_json_value(&v)
}

/// [`from_json`] over an already-parsed [`Json`] value (used by the
/// service layer, whose payloads embed workloads inside larger objects).
pub fn from_json_value(v: &Json) -> Result<(String, Vec<Layer>), QappaError> {
    let name = v.get("name").as_str().unwrap_or("custom").to_string();
    let arr = v
        .get("layers")
        .as_arr()
        .ok_or_else(|| {
            QappaError::Workload("workload JSON needs a top-level \"layers\" array".into())
        })?;
    if arr.is_empty() {
        return Err(QappaError::Workload(
            "workload JSON has an empty \"layers\" array".into(),
        ));
    }
    let mut layers = Vec::with_capacity(arr.len());
    for (i, lj) in arr.iter().enumerate() {
        let layer = layer_from_json(lj, i)?;
        layer.validate()?;
        layers.push(layer);
    }
    Ok((name, layers))
}

/// Serialize a workload into the same JSON schema [`from_json`] reads
/// (round-trip tested). Useful for exporting the built-ins as templates.
/// Layers carrying a per-layer precision override serialize it as a
/// `"precision"` label; plain layers omit the field, keeping the schema
/// byte-identical for single-precision models.
pub fn to_json(name: &str, layers: &[Layer]) -> Json {
    let num = |x: u32| Json::Num(x as f64);
    let arr = layers
        .iter()
        .map(|l| {
            let mut pairs = vec![
                ("name", Json::Str(l.name.clone())),
                ("type", Json::Str(l.kind().into())),
            ];
            // Transformer kinds carry their geometry in `op`, not the conv
            // fields, so they skip "c" entirely; every conv kind keeps the
            // original field order (c first) byte-for-byte.
            match l.op {
                Op::Matmul { m, k, n } => {
                    pairs.push(("m", num(m)));
                    pairs.push(("k", num(k)));
                    pairs.push(("n", num(n)));
                }
                Op::Attention { heads, head_dim, seq_q, seq_kv } => {
                    pairs.push(("heads", num(heads)));
                    pairs.push(("head_dim", num(head_dim)));
                    pairs.push(("seq_q", num(seq_q)));
                    pairs.push(("seq_kv", num(seq_kv)));
                }
                Op::Conv => {
                    pairs.push(("c", num(l.c)));
                    match l.kind() {
                        "fc" => pairs.push(("k", num(l.k))),
                        "pw" => {
                            pairs.push(("k", num(l.k)));
                            pairs.push(("hw", num(l.hw)));
                        }
                        "dw" => {
                            pairs.push(("hw", num(l.hw)));
                            pairs.push(("rs", num(l.rs)));
                            pairs.push(("stride", num(l.stride)));
                            pairs.push(("pad", num(l.pad)));
                        }
                        _ => {
                            pairs.push(("k", num(l.k)));
                            pairs.push(("hw", num(l.hw)));
                            pairs.push(("rs", num(l.rs)));
                            pairs.push(("stride", num(l.stride)));
                            pairs.push(("pad", num(l.pad)));
                            pairs.push(("groups", num(l.groups)));
                        }
                    }
                }
            }
            if let Some(q) = l.quant {
                pairs.push(("precision", Json::Str(crate::config::PeType::from_spec(q).label())));
            }
            obj(pairs)
        })
        .collect();
    obj(vec![("name", Json::Str(name.into())), ("layers", Json::Arr(arr))])
}

fn req_u32(v: &Json, key: &str, what: &str) -> Result<u32, QappaError> {
    v.get(key)
        .as_usize()
        .and_then(|x| u32::try_from(x).ok())
        .ok_or_else(|| {
            QappaError::Workload(format!("{what}: missing or non-integer field \"{key}\""))
        })
}

/// Optional field: absent -> default, present-but-malformed -> error (a
/// string or fractional `stride` must not silently load as the default).
fn opt_u32(v: &Json, key: &str, default: u32, what: &str) -> Result<u32, QappaError> {
    match v.get(key) {
        Json::Null => Ok(default),
        other => other.as_usize().and_then(|x| u32::try_from(x).ok()).ok_or_else(|| {
            QappaError::Workload(format!(
                "{what}: field \"{key}\" must be a non-negative integer"
            ))
        }),
    }
}

fn layer_from_json(v: &Json, idx: usize) -> Result<Layer, QappaError> {
    let name = v
        .get("name")
        .as_str()
        .map(str::to_string)
        .unwrap_or_else(|| format!("layer{idx}"));
    let kind = v.get("type").as_str().unwrap_or("conv");
    let what = format!("layer {idx} ('{name}')");
    // Optional per-layer precision override: a preset name or a generic
    // spec label. Width-range violations surface through Layer::validate
    // (called by the loader) with the offending field named.
    let quant = match v.get("precision") {
        Json::Null => None,
        other => {
            let s = other.as_str().ok_or_else(|| {
                QappaError::Workload(format!("{what}: field \"precision\" must be a string"))
            })?;
            Some(
                crate::config::PeType::parse(s)
                    .ok_or_else(|| {
                        QappaError::Workload(format!(
                            "{what}: unknown precision '{s}' (expected a preset name or a<act>w<wt>p<psum>[-mac])"
                        ))
                    })?
                    .spec(),
            )
        }
    };
    let layer = layer_shape_from_json(v, kind, &name, &what)?;
    Ok(match quant {
        Some(q) => layer.with_precision(q),
        None => layer,
    })
}

fn layer_shape_from_json(
    v: &Json,
    kind: &str,
    name: &str,
    what: &str,
) -> Result<Layer, QappaError> {
    let name = name.to_string();
    match kind {
        "fc" => Ok(Layer::fc(&name, req_u32(v, "c", &what)?, req_u32(v, "k", &what)?)),
        "pw" => {
            // pw is dense 1x1 stride 1 by definition: reject fields that
            // would be silently ignored.
            if opt_u32(v, "stride", 1, &what)? != 1
                || opt_u32(v, "pad", 0, &what)? != 0
                || opt_u32(v, "groups", 1, &what)? != 1
                || opt_u32(v, "rs", 1, &what)? != 1
            {
                return Err(QappaError::Workload(format!(
                    "{what}: \"pw\" is a dense 1x1 stride-1 conv; use type \"conv\" \
                     for other strides/kernels/groups"
                )));
            }
            Ok(Layer::pw(
                &name,
                req_u32(v, "c", &what)?,
                req_u32(v, "k", &what)?,
                req_u32(v, "hw", &what)?,
            ))
        }
        "dw" => {
            let c = req_u32(v, "c", &what)?;
            let rs = req_u32(v, "rs", &what)?;
            // Depthwise pins k = groups = c; an explicit contradicting
            // value must not be silently overridden.
            if opt_u32(v, "k", c, &what)? != c || opt_u32(v, "groups", c, &what)? != c {
                return Err(QappaError::Workload(format!(
                    "{what}: \"dw\" layers have k = groups = c; use type \"grouped\" \
                     for other channel connectivities"
                )));
            }
            Ok(Layer::dw(
                &name,
                c,
                req_u32(v, "hw", &what)?,
                rs,
                opt_u32(v, "stride", 1, &what)?,
                opt_u32(v, "pad", rs / 2, &what)?,
            ))
        }
        "conv" | "grouped" => {
            let rs = req_u32(v, "rs", &what)?;
            let groups = opt_u32(v, "groups", 1, &what)?;
            // An explicit "grouped" layer with groups <= 1 is almost
            // certainly a dropped field — exactly the dense-costing error
            // this loader exists to prevent. Fail loudly.
            if kind == "grouped" && groups < 2 {
                return Err(QappaError::Workload(format!(
                    "{what}: type \"grouped\" requires \"groups\" >= 2 \
                     (got {groups}); use type \"conv\" for dense layers"
                )));
            }
            // Built as a struct literal (not Layer::grouped) so bad
            // divisibility reaches validate() as an error, not a
            // debug_assert panic.
            Ok(Layer {
                name,
                c: req_u32(v, "c", &what)?,
                k: req_u32(v, "k", &what)?,
                hw: req_u32(v, "hw", &what)?,
                rs,
                stride: opt_u32(v, "stride", 1, &what)?,
                pad: opt_u32(v, "pad", rs / 2, &what)?,
                groups,
                quant: None,
                op: Op::Conv,
            })
        }
        "matmul" => {
            // Transformer matmul carries m/k/n only; conv-shape fields
            // would be silently ignored, so their presence is an error.
            for f in ["c", "hw", "rs", "stride", "pad", "groups"] {
                if !matches!(v.get(f), Json::Null) {
                    return Err(QappaError::Workload(format!(
                        "{what}: field \"{f}\" is not a \"matmul\" field \
                         (matmul layers take m/k/n)"
                    )));
                }
            }
            Ok(Layer::matmul(
                &name,
                req_u32(v, "m", what)?,
                req_u32(v, "k", what)?,
                req_u32(v, "n", what)?,
            ))
        }
        "attention" => {
            for f in ["c", "k", "hw", "rs", "stride", "pad", "groups", "m", "n"] {
                if !matches!(v.get(f), Json::Null) {
                    return Err(QappaError::Workload(format!(
                        "{what}: field \"{f}\" is not an \"attention\" field \
                         (attention layers take heads/head_dim/seq_q/seq_kv)"
                    )));
                }
            }
            Ok(Layer::attention(
                &name,
                req_u32(v, "heads", what)?,
                req_u32(v, "head_dim", what)?,
                req_u32(v, "seq_q", what)?,
                req_u32(v, "seq_kv", what)?,
            ))
        }
        other => Err(QappaError::Workload(format!(
            "{what}: unknown layer type '{other}' \
             (expected conv|grouped|dw|pw|fc|matmul|attention)"
        ))),
    }
}

// ---------------------------------------------------------------------------
// Built-in networks
// ---------------------------------------------------------------------------

/// VGG-16 (Simonyan & Zisserman 2014): 13 conv + 3 FC, ~15.5 GMACs.
pub fn vgg16() -> Vec<Layer> {
    let c = |name: &str, cin, cout, hw| Layer::conv(name, cin, cout, hw, hw, 3, 1, 1);
    vec![
        c("conv1_1", 3, 64, 224),
        c("conv1_2", 64, 64, 224),
        c("conv2_1", 64, 128, 112),
        c("conv2_2", 128, 128, 112),
        c("conv3_1", 128, 256, 56),
        c("conv3_2", 256, 256, 56),
        c("conv3_3", 256, 256, 56),
        c("conv4_1", 256, 512, 28),
        c("conv4_2", 512, 512, 28),
        c("conv4_3", 512, 512, 28),
        c("conv5_1", 512, 512, 14),
        c("conv5_2", 512, 512, 14),
        c("conv5_3", 512, 512, 14),
        Layer::fc("fc6", 512 * 7 * 7, 4096),
        Layer::fc("fc7", 4096, 4096),
        Layer::fc("fc8", 4096, 1000),
    ]
}

/// Basic residual block: two 3x3 convs (the projection shortcut conv is
/// included where the stage downsamples).
fn basic_block(layers: &mut Vec<Layer>, name: &str, cin: u32, cout: u32, hw_in: u32, downsample: bool) {
    let stride = if downsample { 2 } else { 1 };
    let hw_out = if downsample { hw_in / 2 } else { hw_in };
    layers.push(Layer::conv(&format!("{name}.conv1"), cin, cout, hw_in, hw_in, 3, stride, 1));
    layers.push(Layer::conv(&format!("{name}.conv2"), cout, cout, hw_out, hw_out, 3, 1, 1));
    if downsample || cin != cout {
        layers.push(Layer::conv(&format!("{name}.proj"), cin, cout, hw_in, hw_in, 1, stride, 0));
    }
}

/// Bottleneck block: 1x1 reduce, 3x3, 1x1 expand (x4).
fn bottleneck(layers: &mut Vec<Layer>, name: &str, cin: u32, mid: u32, hw_in: u32, downsample: bool, first: bool) {
    let cout = mid * 4;
    let stride = if downsample { 2 } else { 1 };
    let hw_out = if downsample { hw_in / 2 } else { hw_in };
    layers.push(Layer::conv(&format!("{name}.conv1"), cin, mid, hw_in, hw_in, 1, 1, 0));
    layers.push(Layer::conv(&format!("{name}.conv2"), mid, mid, hw_in, hw_in, 3, stride, 1));
    layers.push(Layer::conv(&format!("{name}.conv3"), mid, cout, hw_out, hw_out, 1, 1, 0));
    if first {
        layers.push(Layer::conv(&format!("{name}.proj"), cin, cout, hw_in, hw_in, 1, stride, 0));
    }
}

/// ResNet-34 (He et al. 2016): stem + [3,4,6,3] basic blocks + FC,
/// ~3.6 GMACs.
pub fn resnet34() -> Vec<Layer> {
    let mut l = vec![Layer::conv("stem", 3, 64, 224, 224, 7, 2, 3)];
    // maxpool 3x3/2 -> 56x56 (pooling costs no MACs)
    let stages: [(u32, u32, u32, usize); 4] = [
        (64, 64, 56, 3),
        (64, 128, 56, 4),
        (128, 256, 28, 6),
        (256, 512, 14, 3),
    ];
    for (si, &(cin, cout, hw, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let first = b == 0;
            let down = first && si > 0;
            let (bc_in, bhw) = if first {
                (cin, hw)
            } else {
                (cout, if si > 0 { hw / 2 } else { hw })
            };
            basic_block(&mut l, &format!("s{}b{}", si + 1, b + 1), bc_in, cout, bhw, down);
        }
    }
    l.push(Layer::fc("fc", 512, 1000));
    l
}

/// ResNet-50 (He et al. 2016): stem + [3,4,6,3] bottleneck blocks + FC,
/// ~4.1 GMACs.
pub fn resnet50() -> Vec<Layer> {
    let mut l = vec![Layer::conv("stem", 3, 64, 224, 224, 7, 2, 3)];
    let stages: [(u32, u32, u32, usize); 4] = [
        (64, 64, 56, 3),
        (256, 128, 56, 4),
        (512, 256, 28, 6),
        (1024, 512, 14, 3),
    ];
    for (si, &(cin, mid, hw, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let first = b == 0;
            let down = first && si > 0;
            let (bc_in, bhw) = if first {
                (cin, hw)
            } else {
                (mid * 4, if si > 0 { hw / 2 } else { hw })
            };
            bottleneck(&mut l, &format!("s{}b{}", si + 1, b + 1), bc_in, mid, bhw, down, first);
        }
    }
    l.push(Layer::fc("fc", 2048, 1000));
    l
}

/// MobileNetV1 (Howard et al. 2017), width 1.0 at 224x224: conv stem +
/// 13 depthwise-separable blocks (3x3 dw + 1x1 pw) + FC. ~0.57 GMACs —
/// the depthwise layers are 13 of 28 layers but only ~3% of the MACs,
/// which is exactly why costing them as dense convs would be badly wrong.
pub fn mobilenetv1() -> Vec<Layer> {
    let mut l = vec![Layer::conv("stem", 3, 32, 224, 224, 3, 2, 1)];
    // (cin, cout, input hw, dw stride) per separable block.
    let blocks: [(u32, u32, u32, u32); 13] = [
        (32, 64, 112, 1),
        (64, 128, 112, 2),
        (128, 128, 56, 1),
        (128, 256, 56, 2),
        (256, 256, 28, 1),
        (256, 512, 28, 2),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 1024, 14, 2),
        (1024, 1024, 7, 1),
    ];
    for (i, &(cin, cout, hw, stride)) in blocks.iter().enumerate() {
        let hw_out = if stride == 2 { hw / 2 } else { hw };
        l.push(Layer::dw(&format!("b{}.dw", i + 1), cin, hw, 3, stride, 1));
        l.push(Layer::pw(&format!("b{}.pw", i + 1), cin, cout, hw_out));
    }
    // global average pool costs no MACs
    l.push(Layer::fc("fc", 1024, 1000));
    l
}

/// One MobileNetV2 inverted-residual block: 1x1 expand (skipped when the
/// expansion factor is 1), 3x3 depthwise, 1x1 linear projection.
fn inverted_residual(
    layers: &mut Vec<Layer>,
    name: &str,
    cin: u32,
    cout: u32,
    hw: u32,
    stride: u32,
    expand: u32,
) {
    let mid = cin * expand;
    let hw_out = if stride == 2 { hw / 2 } else { hw };
    if expand != 1 {
        layers.push(Layer::pw(&format!("{name}.expand"), cin, mid, hw));
    }
    layers.push(Layer::dw(&format!("{name}.dw"), mid, hw, 3, stride, 1));
    layers.push(Layer::pw(&format!("{name}.project"), mid, cout, hw_out));
}

/// MobileNetV2 (Sandler et al. 2018), width 1.0 at 224x224: conv stem +
/// 17 inverted-residual blocks + 1x1 head + FC. ~0.30 GMACs, matching the
/// paper's "300M MAdds" (Table 4).
pub fn mobilenetv2() -> Vec<Layer> {
    let mut l = vec![Layer::conv("stem", 3, 32, 224, 224, 3, 2, 1)];
    // (expansion t, output channels c, repeats n, first-block stride s),
    // straight from the paper's Table 2.
    let stages: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32u32;
    let mut hw = 112u32;
    for (si, &(t, cout, n, s)) in stages.iter().enumerate() {
        for b in 0..n {
            let stride = if b == 0 { s } else { 1 };
            inverted_residual(&mut l, &format!("s{}b{}", si + 1, b + 1), cin, cout, hw, stride, t);
            if stride == 2 {
                hw /= 2;
            }
            cin = cout;
        }
    }
    l.push(Layer::pw("head", 320, 1280, 7));
    l.push(Layer::fc("fc", 1280, 1000));
    l
}

// ---------------------------------------------------------------------------
// scaled variants (model-knob search)
// ---------------------------------------------------------------------------

/// A channel count scaled by `mult` and rounded to the nearest multiple
/// of 8 (never below 8) — MobileNet's width-multiplier convention, which
/// keeps every scaled tensor array-friendly.  Identity at `mult = 1.0`
/// for the builders' channel counts (all multiples of 8).
fn scale_ch(c: u32, mult: f64) -> u32 {
    ((c as f64 * mult / 8.0).round() as u32).max(1) * 8
}

/// [`mobilenetv1`] under (width, depth) multipliers in (0, 1]: channels
/// shrink via [`scale_ch`], depth keeps the first
/// `max(1, round(13 * depth_mult))` separable blocks (trailing blocks
/// drop, so every scaled layer name exists in the full model).
/// `(1.0, 1.0)` reproduces [`mobilenetv1`] exactly.
pub fn mobilenetv1_scaled(width_mult: f64, depth_mult: f64) -> Vec<Layer> {
    let blocks: [(u32, u32, u32, u32); 13] = [
        (32, 64, 112, 1),
        (64, 128, 112, 2),
        (128, 128, 56, 1),
        (128, 256, 56, 2),
        (256, 256, 28, 1),
        (256, 512, 28, 2),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 1024, 14, 2),
        (1024, 1024, 7, 1),
    ];
    let keep = ((13.0 * depth_mult).round() as usize).clamp(1, 13);
    let mut l = vec![Layer::conv("stem", 3, scale_ch(32, width_mult), 224, 224, 3, 2, 1)];
    let mut last = scale_ch(32, width_mult);
    for (i, &(cin, cout, hw, stride)) in blocks.iter().take(keep).enumerate() {
        let hw_out = if stride == 2 { hw / 2 } else { hw };
        let (cin, cout) = (scale_ch(cin, width_mult), scale_ch(cout, width_mult));
        l.push(Layer::dw(&format!("b{}.dw", i + 1), cin, hw, 3, stride, 1));
        l.push(Layer::pw(&format!("b{}.pw", i + 1), cin, cout, hw_out));
        last = cout;
    }
    l.push(Layer::fc("fc", last, 1000));
    l
}

/// [`mobilenetv2`] under (width, depth) multipliers in (0, 1]: channels
/// shrink via [`scale_ch`], each stage keeps `max(1, round(n *
/// depth_mult))` of its `n` inverted-residual repeats.  `(1.0, 1.0)`
/// reproduces [`mobilenetv2`] exactly.
pub fn mobilenetv2_scaled(width_mult: f64, depth_mult: f64) -> Vec<Layer> {
    let sc = |c: u32| scale_ch(c, width_mult);
    let mut l = vec![Layer::conv("stem", 3, sc(32), 224, 224, 3, 2, 1)];
    let stages: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = sc(32);
    let mut hw = 112u32;
    for (si, &(t, cout, n, s)) in stages.iter().enumerate() {
        let reps = ((n as f64 * depth_mult).round() as u32).clamp(1, n);
        for b in 0..reps {
            let stride = if b == 0 { s } else { 1 };
            inverted_residual(&mut l, &format!("s{}b{}", si + 1, b + 1), cin, sc(cout), hw, stride, t);
            if stride == 2 {
                hw /= 2;
            }
            cin = sc(cout);
        }
    }
    l.push(Layer::pw("head", sc(320), sc(1280), 7));
    l.push(Layer::fc("fc", sc(1280), 1000));
    l
}

/// The scaled variant of a built-in workload for model-knob search, with
/// width and depth multipliers in (0, 1].  Scalable families: the
/// MobileNets (channel/block scaling) and the transformer decoder stacks
/// (d_model/FFN/block scaling).  Accepts the same aliases as [`by_name`];
/// non-scalable workloads are a structured error, not a silent identity.
pub fn scaled(name: &str, width_mult: f64, depth_mult: f64) -> Result<Vec<Layer>, QappaError> {
    let canonical = builder(name).map(|(c, _)| c).unwrap_or(name);
    match canonical {
        "mobilenetv1" => Ok(mobilenetv1_scaled(width_mult, depth_mult)),
        "mobilenetv2" => Ok(mobilenetv2_scaled(width_mult, depth_mult)),
        "opt-1.3b" => Ok(transformer::opt_1p3b_scaled(width_mult, depth_mult)),
        "llama2-7b" => Ok(transformer::llama2_7b_scaled(width_mult, depth_mult)),
        other => Err(QappaError::Workload(format!(
            "workload '{other}' has no scalable builder — width/depth \
             multipliers are supported for: mobilenetv1, mobilenetv2, \
             opt-1.3b, llama2-7b"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gmacs(layers: &[Layer]) -> f64 {
        layers.iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9
    }

    #[test]
    fn scaled_mobilenets_are_identity_at_one_and_shrink_below() {
        assert_eq!(mobilenetv1_scaled(1.0, 1.0), mobilenetv1());
        assert_eq!(mobilenetv2_scaled(1.0, 1.0), mobilenetv2());
        let half = mobilenetv1_scaled(0.5, 0.5);
        // depth 0.5 keeps round(13 * 0.5) = 7 blocks: stem + 7x(dw,pw) + fc
        assert_eq!(half.len(), 1 + 7 * 2 + 1);
        assert_eq!(half[0].k, 16, "stem channels halved");
        assert_eq!(half.last().unwrap().c, 256, "fc follows the last kept block");
        let base = mobilenetv1();
        for l in &half {
            assert!(base.iter().any(|b| b.name == l.name), "{} not in base", l.name);
            l.validate().unwrap_or_else(|e| panic!("{e}"));
        }
        assert!(gmacs(&half) < 0.5 * gmacs(&base), "width+depth halving cuts MACs");
        // v2 keeps >= 1 repeat per stage and all channels multiples of 8
        let thin = mobilenetv2_scaled(0.25, 0.1);
        for l in &thin {
            if l.name != "fc" {
                // classifier output stays 1000-way; everything else 8-aligned
                assert!(l.k >= 8 && l.k % 8 == 0, "{}: k={}", l.name, l.k);
            }
            l.validate().unwrap_or_else(|e| panic!("{e}"));
            assert!(mobilenetv2().iter().any(|b| b.name == l.name), "{}", l.name);
        }
        // dispatch: aliases resolve, non-scalable names are loud errors
        assert_eq!(scaled("mobilenet-v1", 0.5, 0.5).unwrap(), half);
        let e = scaled("vgg16", 0.5, 0.5).unwrap_err();
        assert!(e.to_string().contains("no scalable builder"), "{e}");
    }

    #[test]
    fn vgg16_macs_match_published() {
        // VGG-16: ~15.5 GMACs (publications quote 15.3-15.5 G).
        let g = gmacs(&vgg16());
        assert!((14.5..16.5).contains(&g), "VGG-16 {g} GMACs");
        assert_eq!(vgg16().len(), 16);
    }

    #[test]
    fn resnet34_macs_match_published() {
        // ResNet-34: ~3.6 GMACs.
        let g = gmacs(&resnet34());
        assert!((3.2..4.2).contains(&g), "ResNet-34 {g} GMACs");
    }

    #[test]
    fn resnet50_macs_match_published() {
        // ResNet-50: ~4.1 GMACs.
        let g = gmacs(&resnet50());
        assert!((3.6..4.6).contains(&g), "ResNet-50 {g} GMACs");
    }

    #[test]
    fn mobilenetv1_macs_match_published() {
        // MobileNetV1 1.0/224: ~569M MAdds (paper Table 8).
        let net = mobilenetv1();
        let g = gmacs(&net);
        assert!((0.52..0.62).contains(&g), "MobileNetV1 {g} GMACs");
        // stem + 13 x (dw + pw) + fc
        assert_eq!(net.len(), 1 + 13 * 2 + 1);
        assert_eq!(net.iter().filter(|l| l.is_depthwise()).count(), 13);
    }

    #[test]
    fn mobilenetv2_macs_match_published() {
        // MobileNetV2 1.0/224: ~300M MAdds (paper Table 4); per-layer
        // accounting with stem/head/FC lands ~0.301 G.
        let net = mobilenetv2();
        let g = gmacs(&net);
        assert!((0.27..0.34).contains(&g), "MobileNetV2 {g} GMACs");
        // stem + (2 + 16*3 block layers) + head + fc
        assert_eq!(net.len(), 1 + 2 + 16 * 3 + 1 + 1);
        assert_eq!(net.iter().filter(|l| l.is_depthwise()).count(), 17);
    }

    #[test]
    fn mobilenet_depthwise_is_tiny_mac_fraction() {
        // The MobileNet point: depthwise layers carry almost none of the
        // MACs. Dense-costing them would inflate the dw share ~c-fold.
        for net in [mobilenetv1(), mobilenetv2()] {
            let total: u64 = net.iter().map(|l| l.macs()).sum();
            let dw: u64 = net.iter().filter(|l| l.is_depthwise()).map(|l| l.macs()).sum();
            let frac = dw as f64 / total as f64;
            assert!(frac > 0.0 && frac < 0.10, "dw MAC fraction {frac}");
        }
    }

    #[test]
    fn resnet_block_counts() {
        // ResNet-34: stem + (3+4+6+3) blocks x 2 convs + 3 projections + fc
        let n34 = resnet34().len();
        assert_eq!(n34, 1 + 16 * 2 + 3 + 1, "resnet34 layer count {n34}");
        // ResNet-50: stem + 16 blocks x 3 convs + 4 projections + fc
        let n50 = resnet50().len();
        assert_eq!(n50, 1 + 16 * 3 + 4 + 1, "resnet50 layer count {n50}");
    }

    #[test]
    fn spatial_dims_consistent() {
        for name in WORKLOAD_NAMES {
            for l in &by_name(name).unwrap() {
                assert!(l.out_hw() > 0, "{} out_hw=0", l.name);
                assert!(l.macs() > 0, "{} macs=0", l.name);
                l.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            }
        }
    }

    #[test]
    fn by_name_resolves() {
        for n in WORKLOAD_NAMES {
            assert!(by_name(n).is_some());
        }
        assert!(by_name("mobilenet-v2").is_some());
        assert!(by_name("alexnet").is_none());
    }

    #[test]
    fn load_resolves_names_and_rejects_unknown_with_listing() {
        let (name, layers) = load("mobilenetv2").unwrap();
        assert_eq!(name, "mobilenetv2");
        assert_eq!(layers.len(), mobilenetv2().len());
        // alias maps to the canonical name
        assert_eq!(load("vgg-16").unwrap().0, "vgg16");
        let err = load("alexnet").unwrap_err().to_string();
        for n in WORKLOAD_NAMES {
            assert!(err.contains(n), "error should list '{n}': {err}");
        }
        assert!(err.contains(".json"), "error should mention JSON: {err}");
    }

    #[test]
    fn json_roundtrip_all_builtins() {
        for name in WORKLOAD_NAMES {
            let layers = by_name(name).unwrap();
            let text = to_json(name, &layers).to_string();
            let (back_name, back) = from_json(&text).unwrap();
            assert_eq!(back_name, name);
            assert_eq!(back, layers, "round-trip mismatch for {name}");
        }
    }

    #[test]
    fn from_json_parses_schema_with_defaults() {
        let text = r#"{
            "name": "tiny",
            "layers": [
                {"name": "stem", "type": "conv", "c": 3, "k": 16, "hw": 32, "rs": 3, "stride": 2},
                {"type": "dw", "c": 16, "hw": 16, "rs": 3},
                {"type": "pw", "c": 16, "k": 32, "hw": 16},
                {"type": "grouped", "c": 32, "k": 32, "hw": 16, "rs": 3, "groups": 4},
                {"type": "fc", "c": 512, "k": 10}
            ]
        }"#;
        let (name, layers) = from_json(text).unwrap();
        assert_eq!(name, "tiny");
        assert_eq!(layers.len(), 5);
        // conv: pad defaults to rs/2 = 1
        assert_eq!(layers[0].pad, 1);
        assert_eq!(layers[0].out_hw(), 16);
        // dw: groups = c, stride defaults 1, pad defaults rs/2
        assert!(layers[1].is_depthwise());
        assert_eq!(layers[1].groups, 16);
        // unnamed layers get positional names
        assert_eq!(layers[1].name, "layer1");
        assert_eq!(layers[3].groups, 4);
        assert!(layers[4].is_fc());
    }

    #[test]
    fn per_layer_precision_round_trips_through_json() {
        use crate::config::{PeType, QuantSpec};
        // overrides on every layer kind survive serialize -> parse
        let layers = vec![
            Layer::conv("c", 3, 16, 32, 32, 3, 2, 1).with_precision(QuantSpec::int(8, 8)),
            Layer::dw("d", 16, 16, 3, 1, 1).with_precision(QuantSpec::int(4, 4)),
            Layer::pw("p", 16, 32, 16).with_precision(PeType::LightPe1.spec()),
            Layer::fc("f", 512, 10), // no override
        ];
        let text = to_json("mixed", &layers).to_string();
        assert!(text.contains("\"precision\""));
        assert!(text.contains("LightPE-1"), "preset-matching specs use preset labels: {text}");
        let (name, back) = from_json(&text).unwrap();
        assert_eq!(name, "mixed");
        assert_eq!(back, layers, "override values must survive the round trip");
        assert_eq!(back[3].quant, None, "absent field stays None");

        // parse side: preset names and generic labels both load
        let (_, parsed) = from_json(
            r#"{"layers": [
                {"type": "dw", "c": 16, "hw": 16, "rs": 3, "precision": "int16"},
                {"type": "fc", "c": 64, "k": 10, "precision": "a6w3p12-light1"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(parsed[0].quant, Some(PeType::Int16.spec()));
        assert_eq!(parsed[1].quant.unwrap().label(), "a6w3p12-light1");
    }

    #[test]
    fn precision_field_is_validated_at_the_json_boundary() {
        // unknown label -> error naming the value
        let e = from_json(
            r#"{"layers": [{"type": "fc", "c": 8, "k": 8, "precision": "int99x"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("int99x"), "{e}");
        // non-string -> error naming the field
        let e = from_json(r#"{"layers": [{"type": "fc", "c": 8, "k": 8, "precision": 8}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("precision"), "{e}");
        // syntactically valid spec with bad widths -> rejected by
        // Layer::validate with the offending field named
        for (label, field) in [
            ("a0w8p16-int", "act_bits"),
            ("a70w8p70-int", "act_bits"),
            ("a16w8p8-int", "psum_bits"),
        ] {
            let text = format!(
                r#"{{"layers": [{{"type": "fc", "c": 8, "k": 8, "precision": "{label}"}}]}}"#
            );
            let e = from_json(&text).unwrap_err().to_string();
            assert!(e.contains(field), "{label}: {e}");
        }
    }

    #[test]
    fn from_json_rejects_malformed() {
        // not JSON at all
        assert!(from_json("nope").is_err());
        // no layers array
        assert!(from_json(r#"{"name": "x"}"#).is_err());
        // empty layers
        assert!(from_json(r#"{"layers": []}"#).is_err());
        // unknown type
        let e = from_json(r#"{"layers": [{"type": "pool", "c": 3}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("pool"), "{e}");
        // missing required field
        let e = from_json(r#"{"layers": [{"type": "conv", "c": 3, "hw": 8, "rs": 3}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("\"k\""), "{e}");
        // groups not dividing channels
        let e = from_json(
            r#"{"layers": [{"type": "grouped", "c": 10, "k": 8, "hw": 8, "rs": 3, "groups": 3}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("divisible"), "{e}");
    }

    #[test]
    fn from_json_is_strict_about_present_fields() {
        // present-but-malformed optional field must error, not silently
        // fall back to the default (a string stride would otherwise load
        // as stride=1 and overstate MACs 4x)
        let e = from_json(
            r#"{"layers": [{"type": "conv", "c": 3, "k": 16, "hw": 32, "rs": 3, "stride": "2"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("\"stride\""), "{e}");
        // fractional values are not integers
        assert!(from_json(
            r#"{"layers": [{"type": "conv", "c": 3, "k": 16, "hw": 32, "rs": 3, "pad": 1.5}]}"#
        )
        .is_err());
        // "grouped" with groups omitted (or 1) is a dropped-field error,
        // not a silent dense conv
        let e = from_json(r#"{"layers": [{"type": "grouped", "c": 64, "k": 64, "hw": 8, "rs": 3}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("groups"), "{e}");
        // dw with a contradicting k must not be silently overridden
        let e = from_json(r#"{"layers": [{"type": "dw", "c": 16, "k": 32, "hw": 8, "rs": 3}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("dw"), "{e}");
        // pw with a stride would be silently ignored -> error
        assert!(from_json(
            r#"{"layers": [{"type": "pw", "c": 16, "k": 32, "hw": 8, "stride": 2}]}"#
        )
        .is_err());
        // values past u32::MAX must error, not wrap modulo 2^32
        // (4294967299 = 2^32 + 3 would otherwise load as c = 3)
        assert!(from_json(
            r#"{"layers": [{"type": "conv", "c": 4294967299, "k": 64, "hw": 8, "rs": 3}]}"#
        )
        .is_err());
    }

    #[test]
    fn transformer_workloads_register_and_alias() {
        assert_eq!(load("opt-1.3b").unwrap().0, "opt-1.3b");
        assert_eq!(load("opt1.3b").unwrap().0, "opt-1.3b");
        assert_eq!(load("llama-2-7b").unwrap().0, "llama2-7b");
        let (_, layers) = load("llama2-7b").unwrap();
        assert!(has_transformer_ops(&layers));
        assert!(!has_transformer_ops(&vgg16()));
    }

    #[test]
    fn transformer_layers_parse_from_json() {
        let text = r#"{
            "name": "block",
            "layers": [
                {"name": "qkv", "type": "matmul", "m": 128, "k": 256, "n": 768},
                {"name": "attn", "type": "attention", "heads": 4, "head_dim": 64,
                 "seq_q": 128, "seq_kv": 128, "precision": "int16"},
                {"type": "fc", "c": 256, "k": 10}
            ]
        }"#;
        let (name, layers) = from_json(text).unwrap();
        assert_eq!(name, "block");
        assert_eq!(layers[0], Layer::matmul("qkv", 128, 256, 768));
        assert_eq!(
            layers[1],
            Layer::attention("attn", 4, 64, 128, 128)
                .with_precision(crate::config::PeType::Int16.spec())
        );
        assert!(layers[2].is_fc());
    }

    #[test]
    fn transformer_json_is_strict() {
        // conv-shape fields on a matmul are an error, not ignored
        let e = from_json(r#"{"layers": [{"type": "matmul", "m": 4, "k": 8, "n": 8, "hw": 32}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("\"hw\""), "{e}");
        // missing required fields name the field
        let e = from_json(r#"{"layers": [{"type": "matmul", "m": 4, "k": 8}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("\"n\""), "{e}");
        let e = from_json(
            r#"{"layers": [{"type": "attention", "heads": 4, "head_dim": 64, "seq_q": 8}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("\"seq_kv\""), "{e}");
        // malformed shapes reach Layer::validate with the field named
        let e = from_json(
            r#"{"layers": [{"type": "attention", "heads": 0, "head_dim": 64,
                 "seq_q": 8, "seq_kv": 8}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("\"heads\""), "{e}");
        let e = from_json(
            r#"{"layers": [{"type": "attention", "heads": 4, "head_dim": 64,
                 "seq_q": 16, "seq_kv": 8}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("\"seq_kv\""), "{e}");
    }

    #[test]
    fn grouped_1x1_at_unit_hw_round_trips_with_groups() {
        // kind() must classify grouped layers before fc, or a grouped 1x1
        // layer at hw=1 would serialize as dense fc and round-trip to a
        // model with groups-times the MACs.
        let l = Layer::grouped("g", 64, 64, 1, 1, 1, 0, 64);
        assert_eq!(l.kind(), "dw");
        let text = to_json("t", std::slice::from_ref(&l)).to_string();
        let (_, back) = from_json(&text).unwrap();
        assert_eq!(back[0].groups, 64);
        assert_eq!(back[0].macs(), l.macs());
    }
}
