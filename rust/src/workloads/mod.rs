//! DNN workload definitions: the three networks the paper's design-space
//! exploration uses (VGG-16, ResNet-34, ResNet-50), at 224x224 inference.

use crate::dataflow::layer::Layer;

/// Named workload for CLI selection.
pub fn by_name(name: &str) -> Option<Vec<Layer>> {
    match name.to_ascii_lowercase().as_str() {
        "vgg16" | "vgg-16" => Some(vgg16()),
        "resnet34" | "resnet-34" => Some(resnet34()),
        "resnet50" | "resnet-50" => Some(resnet50()),
        _ => None,
    }
}

pub const WORKLOAD_NAMES: [&str; 3] = ["vgg16", "resnet34", "resnet50"];

/// VGG-16 (Simonyan & Zisserman 2014): 13 conv + 3 FC.
pub fn vgg16() -> Vec<Layer> {
    let c = |name: &str, cin, cout, hw| Layer::conv(name, cin, cout, hw, hw, 3, 1, 1);
    vec![
        c("conv1_1", 3, 64, 224),
        c("conv1_2", 64, 64, 224),
        c("conv2_1", 64, 128, 112),
        c("conv2_2", 128, 128, 112),
        c("conv3_1", 128, 256, 56),
        c("conv3_2", 256, 256, 56),
        c("conv3_3", 256, 256, 56),
        c("conv4_1", 256, 512, 28),
        c("conv4_2", 512, 512, 28),
        c("conv4_3", 512, 512, 28),
        c("conv5_1", 512, 512, 14),
        c("conv5_2", 512, 512, 14),
        c("conv5_3", 512, 512, 14),
        Layer::fc("fc6", 512 * 7 * 7, 4096),
        Layer::fc("fc7", 4096, 4096),
        Layer::fc("fc8", 4096, 1000),
    ]
}

/// Basic residual block: two 3x3 convs (the projection shortcut conv is
/// included where the stage downsamples).
fn basic_block(layers: &mut Vec<Layer>, name: &str, cin: u32, cout: u32, hw_in: u32, downsample: bool) {
    let stride = if downsample { 2 } else { 1 };
    let hw_out = if downsample { hw_in / 2 } else { hw_in };
    layers.push(Layer::conv(&format!("{name}.conv1"), cin, cout, hw_in, hw_in, 3, stride, 1));
    layers.push(Layer::conv(&format!("{name}.conv2"), cout, cout, hw_out, hw_out, 3, 1, 1));
    if downsample || cin != cout {
        layers.push(Layer::conv(&format!("{name}.proj"), cin, cout, hw_in, hw_in, 1, stride, 0));
    }
}

/// Bottleneck block: 1x1 reduce, 3x3, 1x1 expand (x4).
fn bottleneck(layers: &mut Vec<Layer>, name: &str, cin: u32, mid: u32, hw_in: u32, downsample: bool, first: bool) {
    let cout = mid * 4;
    let stride = if downsample { 2 } else { 1 };
    let hw_out = if downsample { hw_in / 2 } else { hw_in };
    layers.push(Layer::conv(&format!("{name}.conv1"), cin, mid, hw_in, hw_in, 1, 1, 0));
    layers.push(Layer::conv(&format!("{name}.conv2"), mid, mid, hw_in, hw_in, 3, stride, 1));
    layers.push(Layer::conv(&format!("{name}.conv3"), mid, cout, hw_out, hw_out, 1, 1, 0));
    if first {
        layers.push(Layer::conv(&format!("{name}.proj"), cin, cout, hw_in, hw_in, 1, stride, 0));
    }
}

/// ResNet-34 (He et al. 2016): stem + [3,4,6,3] basic blocks + FC.
pub fn resnet34() -> Vec<Layer> {
    let mut l = vec![Layer::conv("stem", 3, 64, 224, 224, 7, 2, 3)];
    // maxpool 3x3/2 -> 56x56 (pooling costs no MACs)
    let stages: [(u32, u32, u32, usize); 4] = [
        (64, 64, 56, 3),
        (64, 128, 56, 4),
        (128, 256, 28, 6),
        (256, 512, 14, 3),
    ];
    for (si, &(cin, cout, hw, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let first = b == 0;
            let down = first && si > 0;
            let (bc_in, bhw) = if first {
                (cin, hw)
            } else {
                (cout, if si > 0 { hw / 2 } else { hw })
            };
            basic_block(&mut l, &format!("s{}b{}", si + 1, b + 1), bc_in, cout, bhw, down);
        }
    }
    l.push(Layer::fc("fc", 512, 1000));
    l
}

/// ResNet-50 (He et al. 2016): stem + [3,4,6,3] bottleneck blocks + FC.
pub fn resnet50() -> Vec<Layer> {
    let mut l = vec![Layer::conv("stem", 3, 64, 224, 224, 7, 2, 3)];
    let stages: [(u32, u32, u32, usize); 4] = [
        (64, 64, 56, 3),
        (256, 128, 56, 4),
        (512, 256, 28, 6),
        (1024, 512, 14, 3),
    ];
    for (si, &(cin, mid, hw, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let first = b == 0;
            let down = first && si > 0;
            let (bc_in, bhw) = if first {
                (cin, hw)
            } else {
                (mid * 4, if si > 0 { hw / 2 } else { hw })
            };
            bottleneck(&mut l, &format!("s{}b{}", si + 1, b + 1), bc_in, mid, bhw, down, first);
        }
    }
    l.push(Layer::fc("fc", 2048, 1000));
    l
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gmacs(layers: &[Layer]) -> f64 {
        layers.iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9
    }

    #[test]
    fn vgg16_macs_match_published() {
        // VGG-16: ~15.5 GMACs (publications quote 15.3-15.5 G).
        let g = gmacs(&vgg16());
        assert!((14.5..16.5).contains(&g), "VGG-16 {g} GMACs");
        assert_eq!(vgg16().len(), 16);
    }

    #[test]
    fn resnet34_macs_match_published() {
        // ResNet-34: ~3.6 GMACs.
        let g = gmacs(&resnet34());
        assert!((3.2..4.2).contains(&g), "ResNet-34 {g} GMACs");
    }

    #[test]
    fn resnet50_macs_match_published() {
        // ResNet-50: ~4.1 GMACs.
        let g = gmacs(&resnet50());
        assert!((3.6..4.6).contains(&g), "ResNet-50 {g} GMACs");
    }

    #[test]
    fn resnet_block_counts() {
        // ResNet-34: stem + (3+4+6+3) blocks x 2 convs + 3 projections + fc
        let n34 = resnet34().len();
        assert_eq!(n34, 1 + 16 * 2 + 3 + 1, "resnet34 layer count {n34}");
        // ResNet-50: stem + 16 blocks x 3 convs + 4 projections + fc
        let n50 = resnet50().len();
        assert_eq!(n50, 1 + 16 * 3 + 4 + 1, "resnet50 layer count {n50}");
    }

    #[test]
    fn spatial_dims_consistent() {
        for net in [vgg16(), resnet34(), resnet50()] {
            for l in &net {
                assert!(l.out_hw() > 0, "{} out_hw=0", l.name);
                assert!(l.macs() > 0, "{} macs=0", l.name);
            }
        }
    }

    #[test]
    fn by_name_resolves() {
        for n in WORKLOAD_NAMES {
            assert!(by_name(n).is_some());
        }
        assert!(by_name("alexnet").is_none());
    }
}
