//! Transformer/LLM decoder workloads: phase shaping (prefill vs. decode)
//! and parameterized decoder-block builders.
//!
//! A decoder block is expressed with the two transformer layer kinds from
//! [`crate::dataflow::layer::Op`]:
//!
//! * `matmul` — the QKV / output / FFN projections (`[m x k] . [k x n]`,
//!   weights resident, activations streamed);
//! * `attention` — scaled-dot-product attention over the KV cache.
//!
//! The **phase** model re-shapes the same block for the two serving
//! regimes:
//!
//! * **Prefill** processes the whole prompt at once: matmul `m = ctx`,
//!   attention `seq_q = seq_kv = ctx`. Lots of MACs per weight/KV byte —
//!   compute-bound.
//! * **Decode** emits one token per step: matmul `m = 1`, attention
//!   `seq_q = 1` against the full `seq_kv = ctx` cache. Every weight and
//!   KV byte is streamed for a single row of MACs — bandwidth-bound, with
//!   KV traffic growing linearly in context length.
//! * **Both** is prefill plus `ctx` decode steps, composed at the
//!   [`crate::dataflow::NetworkCost`] level (`add`/`scale`) rather than by
//!   materializing `ctx`-many layer lists.
//!
//! Builders ([`opt_1p3b`], [`llama2_7b`], [`transformer`]) emit decoder
//! blocks only (no embedding/LM-head) in prefill shape at
//! [`DEFAULT_CTX`]; phase/context shaping is applied downstream by
//! [`shape_for_phase`]. Per-layer [`crate::config::QuantSpec`] overrides
//! attach to transformer layers exactly as to conv layers, so the
//! optimizer can mix precision across QKV/FFN/attention.

use crate::api::error::QappaError;
use crate::dataflow::layer::{Layer, Op};

/// Default context length for the builders and the `--ctx` flag.
pub const DEFAULT_CTX: u32 = 2048;

/// Inference phase of a transformer workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Process the whole prompt at once (compute-bound).
    Prefill,
    /// One token per step against the full KV cache (bandwidth-bound).
    /// Costs reported per step.
    Decode,
    /// Prefill plus `ctx` decode steps, composed additively.
    Both,
}

impl Phase {
    /// Parse a CLI/wire phase label.
    pub fn parse(s: &str) -> Result<Phase, QappaError> {
        match s.to_ascii_lowercase().as_str() {
            "prefill" => Ok(Phase::Prefill),
            "decode" => Ok(Phase::Decode),
            "both" => Ok(Phase::Both),
            other => Err(QappaError::Workload(format!(
                "unknown phase '{other}' (expected prefill|decode|both)"
            ))),
        }
    }

    /// The canonical label, inverse of [`Phase::parse`].
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::Both => "both",
        }
    }
}

/// True when the layer list contains any transformer operator — the gate
/// for `--phase`/`--ctx` (phase shaping is meaningless for pure CNNs).
pub fn has_transformer_ops(layers: &[Layer]) -> bool {
    layers.iter().any(Layer::is_transformer)
}

/// Re-shape a workload for one evaluable phase at context length `ctx`:
/// matmul `m` becomes the streamed row count (prefill: `ctx`, decode: 1),
/// attention gets `seq_q` per phase against a `seq_kv = ctx` cache.
/// Conv-family layers pass through untouched, so hybrid workloads keep
/// their CNN portion identical across phases.
///
/// `Phase::Both` is not an evaluable shape — evaluate prefill and decode
/// separately and compose with `NetworkCost::add`/`scale` (the session
/// layer does this); passing it here shapes prefill.
pub fn shape_for_phase(layers: &[Layer], phase: Phase, ctx: u32) -> Vec<Layer> {
    let seq_q = match phase {
        Phase::Decode => 1,
        Phase::Prefill | Phase::Both => ctx,
    };
    layers
        .iter()
        .map(|l| {
            let mut l = l.clone();
            match l.op {
                Op::Matmul { k, n, .. } => l.op = Op::Matmul { m: seq_q, k, n },
                Op::Attention { heads, head_dim, .. } => {
                    l.op = Op::Attention { heads, head_dim, seq_q, seq_kv: ctx }
                }
                Op::Conv => {}
            }
            l
        })
        .collect()
}

/// Emit `n_layers` decoder blocks in prefill shape at context `ctx`.
/// Gated FFNs (Llama-style SwiGLU) add a third FFN projection.
fn decoder_blocks(
    d_model: u32,
    heads: u32,
    ffn_hidden: u32,
    n_layers: u32,
    ctx: u32,
    gated_ffn: bool,
) -> Vec<Layer> {
    debug_assert!(heads > 0 && d_model % heads == 0);
    let head_dim = d_model / heads;
    let mut layers = Vec::with_capacity(n_layers as usize * if gated_ffn { 6 } else { 5 });
    for i in 0..n_layers {
        let p = format!("blk{i}");
        layers.push(Layer::matmul(&format!("{p}.attn.qkv"), ctx, d_model, 3 * d_model));
        layers.push(Layer::attention(&format!("{p}.attn"), heads, head_dim, ctx, ctx));
        layers.push(Layer::matmul(&format!("{p}.attn.out"), ctx, d_model, d_model));
        if gated_ffn {
            layers.push(Layer::matmul(&format!("{p}.ffn.gate"), ctx, d_model, ffn_hidden));
        }
        layers.push(Layer::matmul(&format!("{p}.ffn.up"), ctx, d_model, ffn_hidden));
        layers.push(Layer::matmul(&format!("{p}.ffn.down"), ctx, ffn_hidden, d_model));
    }
    layers
}

/// Generic decoder stack: `n_layers` blocks of width `d_model` with
/// `heads` attention heads and a non-gated FFN of `d_model * ffn_mult`,
/// in prefill shape at context `ctx`.
pub fn transformer(d_model: u32, heads: u32, ffn_mult: u32, n_layers: u32, ctx: u32) -> Vec<Layer> {
    decoder_blocks(d_model, heads, d_model * ffn_mult, n_layers, ctx, false)
}

/// OPT-1.3B decoder stack (Zhang et al. 2022): 24 blocks, d_model 2048,
/// 32 heads, FFN 8192 — ~2.89 TMACs prefill at the default context.
pub fn opt_1p3b() -> Vec<Layer> {
    decoder_blocks(2048, 32, 8192, 24, DEFAULT_CTX, false)
}

/// Llama-2-7B decoder stack (Touvron et al. 2023): 32 blocks, d_model
/// 4096, 32 heads, gated FFN 11008 — ~14.4 TMACs prefill at the default
/// context.
pub fn llama2_7b() -> Vec<Layer> {
    decoder_blocks(4096, 32, 11008, 32, DEFAULT_CTX, true)
}

/// A dimension scaled by `mult` and rounded to the nearest multiple of
/// `step` (never below one step, never above the original): the width
/// multiplier convention for model-knob search.  `mult = 1.0` is exact
/// identity for any `v` divisible by `step`.
fn scale_dim(v: u32, mult: f64, step: u32) -> u32 {
    let steps = (v as f64 * mult / step as f64).round() as u32;
    (steps.max(1) * step).min(v.max(step))
}

/// Decoder stack scaled by (width, depth) multipliers in (0, 1]:
/// `d_model` shrinks in steps of `heads` (head count fixed, head_dim
/// shrinks), the FFN in steps of 8, and the block count to
/// `max(1, round(n_layers * depth_mult))` — trailing blocks drop, so
/// every scaled layer name exists in the full stack.
fn decoder_blocks_scaled(
    d_model: u32,
    heads: u32,
    ffn_hidden: u32,
    n_layers: u32,
    ctx: u32,
    gated_ffn: bool,
    width_mult: f64,
    depth_mult: f64,
) -> Vec<Layer> {
    let dm = scale_dim(d_model, width_mult, heads);
    let ffn = scale_dim(ffn_hidden, width_mult, 8);
    let n = ((n_layers as f64 * depth_mult).round() as u32).clamp(1, n_layers);
    decoder_blocks(dm, heads, ffn, n, ctx, gated_ffn)
}

/// [`opt_1p3b`] under (width, depth) multipliers; `(1.0, 1.0)` is the
/// exact full stack.
pub fn opt_1p3b_scaled(width_mult: f64, depth_mult: f64) -> Vec<Layer> {
    decoder_blocks_scaled(2048, 32, 8192, 24, DEFAULT_CTX, false, width_mult, depth_mult)
}

/// [`llama2_7b`] under (width, depth) multipliers; `(1.0, 1.0)` is the
/// exact full stack.
pub fn llama2_7b_scaled(width_mult: f64, depth_mult: f64) -> Vec<Layer> {
    decoder_blocks_scaled(4096, 32, 11008, 32, DEFAULT_CTX, true, width_mult, depth_mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_labels_round_trip() {
        for p in [Phase::Prefill, Phase::Decode, Phase::Both] {
            assert_eq!(Phase::parse(p.label()).unwrap(), p);
        }
        assert_eq!(Phase::parse("PREFILL").unwrap(), Phase::Prefill);
        let e = Phase::parse("train").unwrap_err().to_string();
        assert!(e.contains("train") && e.contains("prefill|decode|both"), "{e}");
    }

    #[test]
    fn builders_validate_and_have_expected_structure() {
        let opt = opt_1p3b();
        assert_eq!(opt.len(), 24 * 5);
        let llama = llama2_7b();
        assert_eq!(llama.len(), 32 * 6);
        for l in opt.iter().chain(&llama) {
            l.validate().unwrap_or_else(|e| panic!("{e}"));
            assert!(l.is_transformer(), "{}", l.name);
        }
        assert!(has_transformer_ops(&opt) && has_transformer_ops(&llama));
        assert_eq!(opt.iter().filter(|l| l.kind() == "attention").count(), 24);
        assert_eq!(llama.iter().filter(|l| l.kind() == "attention").count(), 32);
        // generic builder: width/heads/mult knobs flow through
        let tiny = transformer(256, 4, 4, 2, 128);
        assert_eq!(tiny.len(), 2 * 5);
        assert!(matches!(tiny[0].op, Op::Matmul { m: 128, k: 256, n: 768 }));
        assert!(
            matches!(tiny[1].op, Op::Attention { heads: 4, head_dim: 64, seq_q: 128, seq_kv: 128 })
        );
    }

    #[test]
    fn scaled_builders_shrink_cleanly_and_are_identity_at_one() {
        assert_eq!(opt_1p3b_scaled(1.0, 1.0), opt_1p3b());
        assert_eq!(llama2_7b_scaled(1.0, 1.0), llama2_7b());
        let half = opt_1p3b_scaled(0.5, 0.5);
        assert_eq!(half.len(), 12 * 5, "half depth keeps 12 of 24 blocks");
        // d_model 2048 * 0.5 = 1024, still a multiple of 32 heads
        assert!(matches!(half[0].op, Op::Matmul { m: DEFAULT_CTX, k: 1024, n: 3072 }));
        assert!(matches!(half[1].op, Op::Attention { heads: 32, head_dim: 32, .. }));
        // every scaled name is a full-stack name, and everything validates
        let base = opt_1p3b();
        for l in &half {
            assert!(base.iter().any(|b| b.name == l.name), "{}", l.name);
            l.validate().unwrap_or_else(|e| panic!("{e}"));
        }
        // extreme multipliers stay positive and head-divisible
        let tiny = llama2_7b_scaled(0.01, 0.01);
        assert!(!tiny.is_empty());
        for l in &tiny {
            if let Op::Attention { heads, head_dim, .. } = l.op {
                assert!(heads == 32 && head_dim >= 1);
            }
            l.validate().unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn builder_mac_totals_match_hand_computation() {
        // Per block at ctx=2048: qkv 3d^2*ctx + attn 2*d*ctx^2 + out
        // d^2*ctx + ffn 2*d*ffn*ctx (+ gate d*ffn*ctx when gated).
        let total = |ls: &[Layer]| ls.iter().map(Layer::macs).sum::<u64>();
        assert_eq!(total(&opt_1p3b()), 2_886_218_022_912);
        assert_eq!(total(&llama2_7b()), 14_362_370_637_824);
    }

    #[test]
    fn shape_for_phase_rewrites_only_transformer_ops() {
        let mut layers = transformer(256, 4, 4, 1, 512);
        layers.push(Layer::fc("head", 256, 32000));
        let dec = shape_for_phase(&layers, Phase::Decode, 512);
        assert!(matches!(dec[0].op, Op::Matmul { m: 1, k: 256, n: 768 }));
        assert!(matches!(
            dec[1].op,
            Op::Attention { heads: 4, head_dim: 64, seq_q: 1, seq_kv: 512 }
        ));
        assert_eq!(dec.last().unwrap(), layers.last().unwrap(), "conv layers untouched");
        // prefill at a longer context stretches both m and the cache
        let pre = shape_for_phase(&layers, Phase::Prefill, 1024);
        assert!(matches!(pre[0].op, Op::Matmul { m: 1024, .. }));
        assert!(matches!(pre[1].op, Op::Attention { seq_q: 1024, seq_kv: 1024, .. }));
        // every reshaped layer still validates (carried fields intact)
        for l in dec.iter().chain(&pre) {
            l.validate().unwrap_or_else(|e| panic!("{e}"));
        }
        // Both shapes as prefill (the evaluable half of the composition)
        assert_eq!(shape_for_phase(&layers, Phase::Both, 512), layers);
    }

    #[test]
    fn decode_has_fewer_macs_same_kv() {
        let pre = shape_for_phase(&opt_1p3b(), Phase::Prefill, 1024);
        let dec = shape_for_phase(&opt_1p3b(), Phase::Decode, 1024);
        let macs = |ls: &[Layer]| ls.iter().map(Layer::macs).sum::<u64>();
        let kv = |ls: &[Layer]| ls.iter().map(Layer::kv_elems).sum::<u64>();
        assert!(macs(&dec) * 512 < macs(&pre), "decode step must be ~1/ctx the MACs");
        assert_eq!(kv(&dec), kv(&pre), "same cache streamed either phase");
        // precision overrides survive shaping
        use crate::config::QuantSpec;
        let tagged: Vec<Layer> =
            opt_1p3b().into_iter().map(|l| l.with_precision(QuantSpec::int(4, 4))).collect();
        let shaped = shape_for_phase(&tagged, Phase::Decode, 256);
        assert!(shaped.iter().all(|l| l.quant == Some(QuantSpec::int(4, 4))));
    }
}
