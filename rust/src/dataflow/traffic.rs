//! Memory-hierarchy traffic model for row-stationary execution.
//!
//! Traffic at each level is the *compulsory* volume times a reload factor
//! determined by what fits in the level below:
//!
//! * **GLB -> spads**: in RS, ifmap rows are multicast to the PEs that need
//!   them; each ifmap element leaves the GLB once per *filter group* (the
//!   set of output channels processed concurrently), and each filter
//!   element once per *ifmap strip* resident in the spads.
//! * **DRAM -> GLB**: compulsory ifmap/filter/ofmap volume times a reload
//!   factor = how many passes over the data the GLB capacity forces.
//!
//! All factors are >= 1 and shrink monotonically as capacities grow — the
//! property tests pin this.
//!
//! Grouped/depthwise layers inherit the reduced filter volume from
//! [`Layer::filter_elems`] (each filter spans only `c / groups` input
//! channels), so a depthwise layer moves `1/c` of the dense filter bytes
//! while its ifmap/ofmap volumes stay unchanged.
//!
//! Attention layers add a fourth DRAM class: the KV cache
//! ([`Layer::kv_elems`], keys + values for every cached position), streamed
//! once per step flash-attention-style — it never fits a reload schedule,
//! so it bypasses the resident-schedule choice and lands directly in
//! `dram_kv_bytes` (and, doubled for write+read, in the GLB count). Zero
//! for every non-attention layer, keeping CNN traffic byte-identical.

use crate::config::AcceleratorConfig;
use crate::dataflow::layer::Layer;
use crate::dataflow::rs::LayerPerf;

/// Per-level access counts for one layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    /// GLB reads+writes, in words of the PE operand width.
    pub glb_accesses: u64,
    /// Bits moved over the GLB<->PE interconnect.
    pub noc_bits: u64,
    /// DRAM traffic in bytes (ifmap in + filters in + ofmap out, with
    /// reloads).
    pub dram_bytes: u64,
    /// DRAM ifmap bytes (breakdown for reports).
    pub dram_ifmap_bytes: u64,
    /// DRAM filter bytes (breakdown for reports).
    pub dram_filter_bytes: u64,
    /// DRAM ofmap bytes (breakdown for reports).
    pub dram_ofmap_bytes: u64,
    /// DRAM KV-cache bytes (attention layers only; grows with context
    /// length — the decode-phase bandwidth term).
    pub dram_kv_bytes: u64,
}

/// Fraction of the GLB the scheduler allots to ifmaps (rest: filters +
/// psums) — matches Eyeriss's static partitioning.
const GLB_IFMAP_FRACTION: f64 = 0.5;
const GLB_FILTER_FRACTION: f64 = 0.35;

fn ceil_div_f(a: f64, b: f64) -> u64 {
    (a / b).ceil().max(1.0) as u64
}

/// Compute the traffic for one mapped layer.
pub fn layer_traffic(cfg: &AcceleratorConfig, layer: &Layer, perf: &LayerPerf) -> Traffic {
    let q = cfg.quant();
    let act_bits = q.act_bits as u64;
    let wt_bits = q.wt_bits as u64;
    let glb_bits = cfg.glb_kb as u64 * 1024 * 8;

    let ifmap_bits = layer.ifmap_elems() * act_bits;
    let filter_bits = layer.filter_elems() * wt_bits;
    let ofmap_bits = layer.ofmap_elems() * act_bits;

    // ---- DRAM level -------------------------------------------------
    // Two classic schedules; the mapper picks the cheaper one per layer:
    //
    //  A. filter-resident: filters stay in the GLB in chunks; the ifmap is
    //     re-streamed once per chunk (weights read once);
    //  B. ifmap-resident: the ifmap stays in strips (with an rs-row halo
    //     re-read per extra strip); filters are re-streamed per strip.
    let filter_cap = (glb_bits as f64 * GLB_FILTER_FRACTION).max(1.0);
    let filter_chunks = ceil_div_f(filter_bits as f64, filter_cap);
    let ifmap_cap = (glb_bits as f64 * GLB_IFMAP_FRACTION).max(1.0);
    let ifmap_strips = ceil_div_f(ifmap_bits as f64, ifmap_cap);
    let halo = (1.0
        + (layer.rs.saturating_sub(1) as f64 / layer.hw.max(1) as f64)
            * (ifmap_strips.saturating_sub(1)) as f64)
        .min(2.0);

    let cost_a_if = ifmap_bits as f64 * filter_chunks as f64;
    let cost_a_wt = filter_bits as f64;
    let cost_b_if = ifmap_bits as f64 * halo;
    let cost_b_wt = filter_bits as f64 * ifmap_strips as f64;
    let (dram_ifmap_bits, dram_filter_bits) =
        if cost_a_if + cost_a_wt <= cost_b_if + cost_b_wt {
            (cost_a_if as u64, cost_a_wt as u64)
        } else {
            (cost_b_if as u64, cost_b_wt as u64)
        };
    let dram_ofmap_bits = ofmap_bits; // written once (psums stay on-chip)
    // KV cache: streamed once per step (flash-attention style), at
    // activation precision; zero for non-attention layers.
    let dram_kv_bits = layer.kv_elems() * act_bits;
    let dram_ifmap_bytes = dram_ifmap_bits.div_ceil(8);
    let dram_filter_bytes = dram_filter_bits.div_ceil(8);
    let dram_ofmap_bytes = dram_ofmap_bits.div_ceil(8);
    let dram_kv_bytes = dram_kv_bits.div_ceil(8);

    // ---- GLB level ---------------------------------------------------
    // Every DRAM bit passes through the GLB (write + read), plus RS reuse
    // traffic: each pass re-reads its working set from the GLB into spads.
    let spad_refill_bits = perf.passes
        * (cfg.spad_ifmap_b as u64 * 8 + cfg.spad_filter_b as u64 * 8) / 2;

    // Psum spill: the psum spad must hold one output-row segment
    // (out_hw-wide at psum precision). If it can't, partial sums spill to
    // the GLB once per missing segment (read + write).
    let psum_bits = q.psum_bits as u64;
    let seg_need = layer.out_hw().min(cfg.pe_cols) as u64 * psum_bits;
    let seg_have = (cfg.spad_psum_b as u64 * 8).max(1);
    let psum_segments = seg_need.div_ceil(seg_have);
    let psum_spill_bits = ofmap_bits * 2 * psum_segments.saturating_sub(1);

    // Ifmap window: the ifmap spad must hold a sliding window of rs
    // activations (double-buffered). Undersized spads re-read from GLB.
    let win_need = 2 * layer.rs as u64 * act_bits;
    let win_have = (cfg.spad_ifmap_b as u64 * 8).max(1);
    let ifmap_rereads = if win_have < win_need {
        // every pass re-touches its ifmap share from the GLB
        dram_ifmap_bits / 2
    } else {
        0
    };

    let glb_word = q.act_bits.max(8) as u64;
    let glb_bits_moved = 2 * (dram_ifmap_bits + dram_filter_bits + dram_ofmap_bits + dram_kv_bits)
        + spad_refill_bits
        + psum_spill_bits
        + ifmap_rereads;
    let glb_accesses = glb_bits_moved.div_ceil(glb_word);

    // ---- NoC ----------------------------------------------------------
    // Multicast amortizes ifmap delivery; filters and psums move
    // point-to-point. Approximation: everything read from the GLB crosses
    // the interconnect once.
    let noc_bits = glb_bits_moved / 2 + spad_refill_bits;

    Traffic {
        glb_accesses,
        noc_bits,
        dram_bytes: dram_ifmap_bytes + dram_filter_bytes + dram_ofmap_bytes + dram_kv_bytes,
        dram_ifmap_bytes,
        dram_filter_bytes,
        dram_ofmap_bytes,
        dram_kv_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::synth::oracle::energy_params;

    fn traffic_for(cfg: &AcceleratorConfig, layer: &Layer) -> Traffic {
        let ep = energy_params(cfg);
        let perf = crate::dataflow::rs::map_layer(cfg, &ep, layer);
        layer_traffic(cfg, layer, &perf)
    }

    #[test]
    fn dram_traffic_at_least_compulsory() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let l = Layer::conv("c", 64, 128, 28, 28, 3, 1, 1);
        let t = traffic_for(&cfg, &l);
        let compulsory = (l.ifmap_elems() * 16 + l.filter_elems() * 16
            + l.ofmap_elems() * 16)
            / 8;
        assert!(t.dram_bytes >= compulsory, "{} < {compulsory}", t.dram_bytes);
    }

    #[test]
    fn bigger_glb_never_more_dram_traffic() {
        let mut cfg = AcceleratorConfig::default_with(PeType::Fp32);
        let l = Layer::conv("c", 256, 256, 28, 28, 3, 1, 1);
        let mut last = u64::MAX;
        for g in [32u32, 64, 128, 256, 1024] {
            cfg.glb_kb = g;
            let t = traffic_for(&cfg, &l);
            assert!(t.dram_bytes <= last, "glb {g}: {} > {last}", t.dram_bytes);
            last = t.dram_bytes;
        }
    }

    #[test]
    fn lower_precision_less_traffic() {
        let l = Layer::conv("c", 128, 128, 28, 28, 3, 1, 1);
        let t32 = traffic_for(&AcceleratorConfig::default_with(PeType::Fp32), &l);
        let t16 = traffic_for(&AcceleratorConfig::default_with(PeType::Int16), &l);
        let t8 = traffic_for(&AcceleratorConfig::default_with(PeType::LightPe1), &l);
        assert!(t32.dram_bytes > t16.dram_bytes);
        assert!(t16.dram_bytes > t8.dram_bytes);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        for l in [
            Layer::conv("c", 64, 64, 56, 56, 3, 1, 1),
            Layer::matmul("mm", 64, 512, 512),
            Layer::attention("at", 8, 64, 1, 512),
        ] {
            let t = traffic_for(&cfg, &l);
            assert_eq!(
                t.dram_bytes,
                t.dram_ifmap_bytes + t.dram_filter_bytes + t.dram_ofmap_bytes + t.dram_kv_bytes,
                "{}",
                l.name
            );
        }
    }

    #[test]
    fn kv_bytes_zero_for_conv_and_matmul() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        assert_eq!(traffic_for(&cfg, &Layer::conv("c", 64, 64, 28, 28, 3, 1, 1)).dram_kv_bytes, 0);
        assert_eq!(traffic_for(&cfg, &Layer::fc("f", 512, 512)).dram_kv_bytes, 0);
        assert_eq!(traffic_for(&cfg, &Layer::matmul("m", 16, 512, 512)).dram_kv_bytes, 0);
    }

    #[test]
    fn kv_traffic_grows_linearly_with_context() {
        // Per decode step the whole cache is streamed once: KV bytes are
        // exactly (2 * heads * seq_kv * head_dim) * act_bits / 8.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let at = |ctx: u32| Layer::attention("a", 16, 64, 1, ctx);
        let base = traffic_for(&cfg, &at(256)).dram_kv_bytes;
        assert_eq!(base, 2 * 16 * 256 * 64 * 16 / 8);
        for mult in [2u32, 4, 8] {
            let t = traffic_for(&cfg, &at(256 * mult));
            assert_eq!(t.dram_kv_bytes, base * mult as u64, "ctx x{mult}");
        }
        // and narrower activations shrink the cache proportionally
        let t8 = traffic_for(&AcceleratorConfig::default_with(PeType::LightPe1), &at(256));
        assert!(t8.dram_kv_bytes < base);
    }

    #[test]
    fn tiny_psum_spad_spills_to_glb() {
        let mut cfg = AcceleratorConfig::default_with(PeType::Int16);
        cfg.spad_psum_b = 4; // far below an output-row segment
        let l = Layer::conv("c", 64, 64, 28, 28, 3, 1, 1);
        let tight = traffic_for(&cfg, &l);
        cfg.spad_psum_b = 256;
        let roomy = traffic_for(&cfg, &l);
        assert!(tight.glb_accesses > roomy.glb_accesses);
    }

    #[test]
    fn tiny_ifmap_spad_rereads_from_glb() {
        let mut cfg = AcceleratorConfig::default_with(PeType::Fp32);
        cfg.spad_ifmap_b = 2; // below the 2*rs*act window
        let l = Layer::conv("c", 64, 64, 28, 28, 3, 1, 1);
        let tight = traffic_for(&cfg, &l);
        cfg.spad_ifmap_b = 64;
        let roomy = traffic_for(&cfg, &l);
        assert!(tight.glb_accesses > roomy.glb_accesses);
    }

    #[test]
    fn depthwise_moves_fewer_filter_bytes_than_dense() {
        // Same (c, k, hw, rs) shape: the depthwise layer's filter traffic
        // must shrink by ~c while ifmap/ofmap volumes stay comparable, and
        // its compulsory floor must still hold.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let dense = Layer::conv("d", 64, 64, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 64, 28, 3, 1, 1);
        let td = traffic_for(&cfg, &dense);
        let tdw = traffic_for(&cfg, &dw);
        assert!(
            tdw.dram_filter_bytes < td.dram_filter_bytes,
            "dw filters {} >= dense {}",
            tdw.dram_filter_bytes,
            td.dram_filter_bytes
        );
        assert!(tdw.dram_bytes < td.dram_bytes);
        let compulsory = (dw.ifmap_elems() * 16 + dw.filter_elems() * 16
            + dw.ofmap_elems() * 16)
            / 8;
        assert!(tdw.dram_bytes >= compulsory);
    }

    #[test]
    fn glb_and_noc_positive() {
        let cfg = AcceleratorConfig::default_with(PeType::LightPe2);
        let l = Layer::fc("fc", 512, 512);
        let t = traffic_for(&cfg, &l);
        assert!(t.glb_accesses > 0);
        assert!(t.noc_bits > 0);
    }
}
