//! DNN layer descriptors (convolution and fully-connected).

/// One layer of a network, in inference shape (batch = 1, as in the
/// paper's edge-deployment setting).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    /// Input channels.
    pub c: u32,
    /// Output channels (filters).
    pub k: u32,
    /// Input spatial size (square h = w; VGG/ResNet are square throughout).
    pub hw: u32,
    /// Filter spatial size (square r = s).
    pub rs: u32,
    pub stride: u32,
    pub pad: u32,
}

impl Layer {
    pub fn conv(
        name: &str,
        c: u32,
        k: u32,
        hw: u32,
        _unused_w: u32,
        rs: u32,
        stride: u32,
        pad: u32,
    ) -> Layer {
        Layer { name: name.into(), c, k, hw, rs, stride, pad }
    }

    /// Fully-connected layer as a 1x1 conv over a 1x1 "image".
    pub fn fc(name: &str, c_in: u32, c_out: u32) -> Layer {
        Layer { name: name.into(), c: c_in, k: c_out, hw: 1, rs: 1, stride: 1, pad: 0 }
    }

    pub fn is_fc(&self) -> bool {
        self.hw == 1 && self.rs == 1
    }

    /// Output spatial size (square).
    pub fn out_hw(&self) -> u32 {
        debug_assert!(self.stride > 0);
        (self.hw + 2 * self.pad - self.rs) / self.stride + 1
    }

    /// Total multiply-accumulates.
    pub fn macs(&self) -> u64 {
        let e = self.out_hw() as u64;
        self.c as u64 * self.k as u64 * e * e * (self.rs as u64 * self.rs as u64)
    }

    /// Elements in the input feature map.
    pub fn ifmap_elems(&self) -> u64 {
        self.c as u64 * self.hw as u64 * self.hw as u64
    }

    /// Elements in all filters.
    pub fn filter_elems(&self) -> u64 {
        self.c as u64 * self.k as u64 * self.rs as u64 * self.rs as u64
    }

    /// Elements in the output feature map.
    pub fn ofmap_elems(&self) -> u64 {
        let e = self.out_hw() as u64;
        self.k as u64 * e * e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_size() {
        // 224x224, 3x3 stride 1 pad 1 -> 224
        let l = Layer::conv("x", 3, 64, 224, 224, 3, 1, 1);
        assert_eq!(l.out_hw(), 224);
        // 224x224, 7x7 stride 2 pad 3 -> 112 (ResNet stem)
        let s = Layer::conv("stem", 3, 64, 224, 224, 7, 2, 3);
        assert_eq!(s.out_hw(), 112);
    }

    #[test]
    fn macs_formula() {
        let l = Layer::conv("x", 3, 64, 224, 224, 3, 1, 1);
        // 3*64*224*224*9
        assert_eq!(l.macs(), 3 * 64 * 224 * 224 * 9);
    }

    #[test]
    fn fc_macs() {
        let f = Layer::fc("fc", 4096, 1000);
        assert!(f.is_fc());
        assert_eq!(f.macs(), 4096 * 1000);
        assert_eq!(f.out_hw(), 1);
    }

    #[test]
    fn element_counts() {
        let l = Layer::conv("x", 16, 32, 8, 8, 3, 1, 1);
        assert_eq!(l.ifmap_elems(), 16 * 64);
        assert_eq!(l.filter_elems(), 16 * 32 * 9);
        assert_eq!(l.ofmap_elems(), 32 * 64);
    }
}
