//! DNN layer descriptors: dense, grouped and depthwise convolutions,
//! fully-connected layers, and transformer matmul/attention operators.
//!
//! The taxonomy (see `docs/WORKLOADS.md`):
//!
//! | kind        | constructor           | `groups`     | shape notes |
//! |-------------|-----------------------|--------------|-------------|
//! | `conv`      | [`Layer::conv`]       | 1            | dense convolution |
//! | `grouped`   | [`Layer::grouped`]    | `1 < g < c`  | channels split into `g` independent groups |
//! | `dw`        | [`Layer::dw`]         | `g == c == k`| depthwise: one filter per channel |
//! | `pw`        | [`Layer::pw`]         | 1            | pointwise: dense 1x1 convolution |
//! | `fc`        | [`Layer::fc`]         | 1            | 1x1 conv over a 1x1 "image" |
//! | `matmul`    | [`Layer::matmul`]     | 1            | dense `[m x k] . [k x n]` (QKV/FFN projections) |
//! | `attention` | [`Layer::attention`]  | 1            | scaled-dot-product attention over a KV cache |
//!
//! A grouped convolution connects each output channel to only `c / groups`
//! input channels, so MACs and filter volume shrink by `groups` relative to
//! a dense layer of the same (c, k, hw, rs) shape — a depthwise layer costs
//! exactly `dense / c`. Costing it as dense would overstate MobileNet-class
//! networks by ~8-9x, which is why every accounting method here is
//! `groups`-aware.
//!
//! Transformer operators extend the taxonomy through the [`Op`] field:
//! `matmul` streams `m` activation rows through a resident `[k x n]` weight
//! matrix (decode evaluates `m = 1`), while `attention` carries no weights
//! at all — its "filter" is the KV cache, accounted separately through
//! [`Layer::kv_elems`] so the traffic model can price KV reads as their own
//! DRAM class. Phase shaping (prefill vs. decode) lives in
//! `workloads::transformer`.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, QuantSpec};

/// Operator family of a [`Layer`]. `Conv` covers the whole convolution
/// taxonomy (dense/grouped/dw/pw/fc — discriminated by the shape fields);
/// the transformer operators carry their own geometry so decode/prefill
/// re-shaping never has to reverse-engineer it from conv fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Convolution family; the layer's shape lives in `c/k/hw/rs/...`.
    Conv,
    /// Dense matrix multiply `[m x k] . [k x n]`: transformer QKV/output
    /// projections and FFN layers. Prefill runs `m = seq` rows; decode
    /// streams a single row (`m = 1`).
    Matmul {
        /// Activation rows (sequence positions evaluated this step).
        m: u32,
        /// Reduction width (input features).
        k: u32,
        /// Output features.
        n: u32,
    },
    /// Scaled-dot-product attention: per head, `Q.K^T` then `A.V` against
    /// a KV cache of `seq_kv` positions. The cache itself is priced via
    /// [`Layer::kv_elems`] as a dedicated traffic class.
    Attention {
        /// Attention heads.
        heads: u32,
        /// Feature width per head (`d_model = heads * head_dim`).
        head_dim: u32,
        /// Query positions evaluated this step (prefill: seq; decode: 1).
        seq_q: u32,
        /// Cached key/value positions attended over (the context length).
        seq_kv: u32,
    },
}

/// One layer of a network, in inference shape (batch = 1, as in the
/// paper's edge-deployment setting).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// Human-readable layer name (report/table key; not part of identity
    /// for cost purposes).
    pub name: String,
    /// Input channels.
    pub c: u32,
    /// Output channels (filters).
    pub k: u32,
    /// Input spatial size (square h = w; the supported nets are square
    /// throughout).
    pub hw: u32,
    /// Filter spatial size (square r = s).
    pub rs: u32,
    /// Convolution stride (same in both spatial dims).
    pub stride: u32,
    /// Zero padding on each spatial border.
    pub pad: u32,
    /// Channel groups: 1 = dense, `c` = depthwise. Each output channel
    /// reads only `c / groups` input channels; `c` and `k` must both be
    /// divisible by `groups`.
    pub groups: u32,
    /// Optional per-layer precision override (mixed-precision networks):
    /// when set, this layer is costed as if the PEs ran at this spec —
    /// e.g. INT4 depthwise layers mixed with INT8 pointwise layers.
    /// `None` means the accelerator configuration's own precision.
    pub quant: Option<QuantSpec>,
    /// Operator family: [`Op::Conv`] for the whole convolution taxonomy
    /// (the default for every conv-family constructor), or a transformer
    /// operator carrying its own geometry. The conv fields of a
    /// transformer layer are derived by its constructor (`hw = rs = 1`,
    /// `groups = 1`) so generic shape code stays well-defined.
    pub op: Op,
}

impl Layer {
    /// Dense convolution (`groups = 1`).
    pub fn conv(
        name: &str,
        c: u32,
        k: u32,
        hw: u32,
        _unused_w: u32,
        rs: u32,
        stride: u32,
        pad: u32,
    ) -> Layer {
        Layer { name: name.into(), c, k, hw, rs, stride, pad, groups: 1, quant: None, op: Op::Conv }
    }

    /// Grouped convolution: input/output channels split into `groups`
    /// independent slices (AlexNet-style groups, ResNeXt cardinality).
    pub fn grouped(
        name: &str,
        c: u32,
        k: u32,
        hw: u32,
        rs: u32,
        stride: u32,
        pad: u32,
        groups: u32,
    ) -> Layer {
        debug_assert!(groups > 0 && c % groups == 0 && k % groups == 0);
        Layer { name: name.into(), c, k, hw, rs, stride, pad, groups, quant: None, op: Op::Conv }
    }

    /// Depthwise convolution: one spatial filter per channel
    /// (`groups = c = k`), the MobileNet workhorse.
    pub fn dw(name: &str, c: u32, hw: u32, rs: u32, stride: u32, pad: u32) -> Layer {
        Layer { name: name.into(), c, k: c, hw, rs, stride, pad, groups: c, quant: None, op: Op::Conv }
    }

    /// Pointwise convolution: dense 1x1, stride 1, no padding — the channel
    /// mixer paired with depthwise layers in separable blocks.
    pub fn pw(name: &str, c: u32, k: u32, hw: u32) -> Layer {
        Layer { name: name.into(), c, k, hw, rs: 1, stride: 1, pad: 0, groups: 1, quant: None, op: Op::Conv }
    }

    /// Fully-connected layer as a 1x1 conv over a 1x1 "image".
    pub fn fc(name: &str, c_in: u32, c_out: u32) -> Layer {
        Layer {
            name: name.into(),
            c: c_in,
            k: c_out,
            hw: 1,
            rs: 1,
            stride: 1,
            pad: 0,
            groups: 1,
            quant: None,
            op: Op::Conv,
        }
    }

    /// Dense matrix multiply `[m x k] . [k x n]` — transformer projections
    /// and FFN layers. The carried conv fields mirror the reduction
    /// (`c = k`, `k = n`) so generic per-channel code stays meaningful.
    pub fn matmul(name: &str, m: u32, k: u32, n: u32) -> Layer {
        Layer {
            name: name.into(),
            c: k,
            k: n,
            hw: 1,
            rs: 1,
            stride: 1,
            pad: 0,
            groups: 1,
            quant: None,
            op: Op::Matmul { m, k, n },
        }
    }

    /// Scaled-dot-product attention over a KV cache. Carries
    /// `c = k = heads * head_dim` (the model width) in the conv fields.
    pub fn attention(name: &str, heads: u32, head_dim: u32, seq_q: u32, seq_kv: u32) -> Layer {
        let d_model = heads.saturating_mul(head_dim);
        Layer {
            name: name.into(),
            c: d_model,
            k: d_model,
            hw: 1,
            rs: 1,
            stride: 1,
            pad: 0,
            groups: 1,
            quant: None,
            op: Op::Attention { heads, head_dim, seq_q, seq_kv },
        }
    }

    /// Attach a per-layer precision override (builder style).
    pub fn with_precision(mut self, quant: QuantSpec) -> Layer {
        self.quant = Some(quant);
        self
    }

    /// The precision this layer runs at on `cfg`: its own override, or the
    /// configuration's spec.
    pub fn effective_quant(&self, cfg: &AcceleratorConfig) -> QuantSpec {
        self.quant.unwrap_or_else(|| cfg.quant())
    }

    /// True for layers built by [`Layer::fc`] (1x1 conv over a 1x1 image).
    /// Transformer layers also carry `hw = rs = 1`, so fc is conv-only.
    pub fn is_fc(&self) -> bool {
        matches!(self.op, Op::Conv) && self.hw == 1 && self.rs == 1
    }

    /// True for the transformer operators (`matmul` / `attention`).
    pub fn is_transformer(&self) -> bool {
        !matches!(self.op, Op::Conv)
    }

    /// True when every channel has its own filter (`groups = c = k`).
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.groups == self.c && self.groups == self.k
    }

    /// True for any non-dense channel connectivity (`groups > 1`).
    pub fn is_grouped(&self) -> bool {
        self.groups > 1
    }

    /// Taxonomy label used by reports and the JSON schema:
    /// `fc` / `dw` / `grouped` / `pw` / `conv`.
    pub fn kind(&self) -> &'static str {
        // Transformer ops are discriminated by `op`, not shape, so they
        // come first; a matmul's carried hw = rs = 1 must not read as fc.
        match self.op {
            Op::Matmul { .. } => return "matmul",
            Op::Attention { .. } => return "attention",
            Op::Conv => {}
        }
        // Grouped checks come first: a grouped 1x1 layer at hw = 1 must
        // not be mistaken for (dense) fc, or serialization would drop its
        // `groups` and round-trip to a model with groups-times the MACs.
        if self.is_depthwise() {
            "dw"
        } else if self.is_grouped() {
            "grouped"
        } else if self.is_fc() {
            "fc"
        } else if self.rs == 1 && self.stride == 1 && self.pad == 0 {
            // Stride-2 1x1 projections (ResNet shortcuts) stay "conv":
            // [`Layer::pw`] pins stride 1, so only exact matches round-trip.
            "pw"
        } else {
            "conv"
        }
    }

    /// Structural validity: positive dims, kernel fits the padded input,
    /// and channel counts divisible by `groups`. The JSON loader calls this
    /// on every ingested layer.
    pub fn validate(&self) -> Result<(), QappaError> {
        let err = |m: String| Err(QappaError::Workload(m));
        match self.op {
            Op::Matmul { m, k, n } => {
                for (field, v) in [("m", m), ("k", k), ("n", n)] {
                    if v == 0 {
                        return err(format!(
                            "layer '{}': matmul field \"{field}\" must be > 0",
                            self.name
                        ));
                    }
                }
                if self.c != k || self.k != n {
                    // Hand-built layers must go through `Layer::matmul` so
                    // the carried channel fields track the op geometry.
                    return err(format!(
                        "layer '{}': matmul field \"k\"/\"n\" mismatch the carried channels \
                         (c={} vs k={}, k={} vs n={}); build with Layer::matmul",
                        self.name, self.c, k, self.k, n
                    ));
                }
            }
            Op::Attention { heads, head_dim, seq_q, seq_kv } => {
                for (field, v) in
                    [("heads", heads), ("head_dim", head_dim), ("seq_q", seq_q), ("seq_kv", seq_kv)]
                {
                    if v == 0 {
                        return err(format!(
                            "layer '{}': attention field \"{field}\" must be > 0",
                            self.name
                        ));
                    }
                }
                if seq_kv < seq_q {
                    return err(format!(
                        "layer '{}': attention field \"seq_kv\" ({seq_kv}) must cover every \
                         query position (seq_q={seq_q}); prefill keeps seq_kv = seq_q, \
                         decode evaluates seq_q = 1",
                        self.name
                    ));
                }
                let d_model = heads as u64 * head_dim as u64;
                if self.c as u64 != d_model || self.k as u64 != d_model {
                    return err(format!(
                        "layer '{}': attention field \"heads\"*\"head_dim\" ({d_model}) \
                         mismatches the carried channels (c={}, k={}); build with \
                         Layer::attention",
                        self.name, self.c, self.k
                    ));
                }
            }
            Op::Conv => {}
        }
        if self.is_transformer() {
            // Conv-shape checks below don't apply; the constructors pin
            // hw = rs = stride = 1, pad = 0, groups = 1.
            if let Some(q) = self.quant {
                q.validate().map_err(|e| e.context(format!("layer '{}'", self.name)))?;
            }
            return Ok(());
        }
        if self.c == 0 || self.k == 0 || self.hw == 0 || self.rs == 0 || self.stride == 0 {
            return err(format!("layer '{}': all of c/k/hw/rs/stride must be > 0", self.name));
        }
        if self.groups == 0 {
            return err(format!("layer '{}': groups must be > 0", self.name));
        }
        if self.c % self.groups != 0 || self.k % self.groups != 0 {
            return err(format!(
                "layer '{}': c={} and k={} must be divisible by groups={}",
                self.name, self.c, self.k, self.groups
            ));
        }
        if self.hw + 2 * self.pad < self.rs {
            return err(format!(
                "layer '{}': kernel {} exceeds padded input {}",
                self.name,
                self.rs,
                self.hw + 2 * self.pad
            ));
        }
        if let Some(q) = self.quant {
            // Per-layer precision overrides obey the same bit-width rules
            // as configurations; keep the layer name as context.
            q.validate().map_err(|e| e.context(format!("layer '{}'", self.name)))?;
        }
        Ok(())
    }

    /// Output spatial size (square).
    pub fn out_hw(&self) -> u32 {
        debug_assert!(self.stride > 0);
        (self.hw + 2 * self.pad - self.rs) / self.stride + 1
    }

    /// Total multiply-accumulates. Each output channel reduces over
    /// `c / groups` input channels, so a depthwise layer (`groups = c`)
    /// costs `1/c` of its dense counterpart. Attention counts both
    /// chained matmuls (`Q.K^T` and `A.V`) per head.
    pub fn macs(&self) -> u64 {
        match self.op {
            Op::Matmul { m, k, n } => m as u64 * k as u64 * n as u64,
            Op::Attention { heads, head_dim, seq_q, seq_kv } => {
                2 * heads as u64 * head_dim as u64 * seq_q as u64 * seq_kv as u64
            }
            Op::Conv => {
                let e = self.out_hw() as u64;
                let cin_per_group = (self.c / self.groups.max(1)) as u64;
                cin_per_group * self.k as u64 * e * e * (self.rs as u64 * self.rs as u64)
            }
        }
    }

    /// Elements in the input feature map (matmul: the `m` activation rows;
    /// attention: the query block).
    pub fn ifmap_elems(&self) -> u64 {
        match self.op {
            Op::Matmul { m, k, .. } => m as u64 * k as u64,
            Op::Attention { heads, head_dim, seq_q, .. } => {
                seq_q as u64 * heads as u64 * head_dim as u64
            }
            Op::Conv => self.c as u64 * self.hw as u64 * self.hw as u64,
        }
    }

    /// Elements in all filters: each of the `k` filters spans only its
    /// group's `c / groups` input channels. Attention carries no weights —
    /// its operand is the KV cache, accounted via [`Layer::kv_elems`].
    pub fn filter_elems(&self) -> u64 {
        match self.op {
            Op::Matmul { k, n, .. } => k as u64 * n as u64,
            Op::Attention { .. } => 0,
            Op::Conv => {
                let cin_per_group = (self.c / self.groups.max(1)) as u64;
                cin_per_group * self.k as u64 * self.rs as u64 * self.rs as u64
            }
        }
    }

    /// Elements in the output feature map.
    pub fn ofmap_elems(&self) -> u64 {
        match self.op {
            Op::Matmul { m, n, .. } => m as u64 * n as u64,
            Op::Attention { heads, head_dim, seq_q, .. } => {
                seq_q as u64 * heads as u64 * head_dim as u64
            }
            Op::Conv => {
                let e = self.out_hw() as u64;
                self.k as u64 * e * e
            }
        }
    }

    /// KV-cache elements this layer streams per evaluation: keys + values
    /// for every cached position (`2 * heads * seq_kv * head_dim`), read
    /// exactly once per step in a flash-attention-style schedule. Zero for
    /// every non-attention operator, so folding it into traffic totals is
    /// identity-safe for CNN workloads. Grows linearly with context
    /// length — the term that makes decode bandwidth-bound.
    pub fn kv_elems(&self) -> u64 {
        match self.op {
            Op::Attention { heads, head_dim, seq_kv, .. } => {
                2 * heads as u64 * seq_kv as u64 * head_dim as u64
            }
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_size() {
        // 224x224, 3x3 stride 1 pad 1 -> 224
        let l = Layer::conv("x", 3, 64, 224, 224, 3, 1, 1);
        assert_eq!(l.out_hw(), 224);
        // 224x224, 7x7 stride 2 pad 3 -> 112 (ResNet stem)
        let s = Layer::conv("stem", 3, 64, 224, 224, 7, 2, 3);
        assert_eq!(s.out_hw(), 112);
    }

    #[test]
    fn macs_formula() {
        let l = Layer::conv("x", 3, 64, 224, 224, 3, 1, 1);
        // 3*64*224*224*9
        assert_eq!(l.macs(), 3 * 64 * 224 * 224 * 9);
    }

    #[test]
    fn fc_macs() {
        let f = Layer::fc("fc", 4096, 1000);
        assert!(f.is_fc());
        assert_eq!(f.macs(), 4096 * 1000);
        assert_eq!(f.out_hw(), 1);
    }

    #[test]
    fn element_counts() {
        let l = Layer::conv("x", 16, 32, 8, 8, 3, 1, 1);
        assert_eq!(l.ifmap_elems(), 16 * 64);
        assert_eq!(l.filter_elems(), 16 * 32 * 9);
        assert_eq!(l.ofmap_elems(), 32 * 64);
    }

    #[test]
    fn depthwise_macs_are_dense_over_cin() {
        // The ISSUE invariant: depthwise MACs = dense MACs / Cin.
        let dense = Layer::conv("d", 64, 64, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 64, 28, 3, 1, 1);
        assert!(dw.is_depthwise());
        assert_eq!(dw.kind(), "dw");
        assert_eq!(dw.macs() * 64, dense.macs());
        assert_eq!(dw.filter_elems() * 64, dense.filter_elems());
        // Same feature-map volumes either way.
        assert_eq!(dw.ifmap_elems(), dense.ifmap_elems());
        assert_eq!(dw.ofmap_elems(), dense.ofmap_elems());
    }

    #[test]
    fn grouped_macs_scale_with_groups() {
        let dense = Layer::conv("d", 128, 256, 14, 14, 3, 1, 1);
        for g in [2u32, 4, 8] {
            let grp = Layer::grouped("g", 128, 256, 14, 3, 1, 1, g);
            assert!(grp.is_grouped() && !grp.is_depthwise());
            assert_eq!(grp.kind(), "grouped");
            assert_eq!(grp.macs() * g as u64, dense.macs());
            assert_eq!(grp.filter_elems() * g as u64, dense.filter_elems());
        }
    }

    #[test]
    fn pointwise_is_dense_1x1() {
        let pw = Layer::pw("pw", 32, 64, 56);
        assert_eq!(pw.kind(), "pw");
        assert_eq!(pw.out_hw(), 56);
        assert_eq!(pw.macs(), 32 * 64 * 56 * 56);
        assert!(!pw.is_fc());
    }

    #[test]
    fn precision_override_builds_and_validates() {
        use crate::config::{MacKind, PeType};
        let q = QuantSpec::new(4, 4, 12, MacKind::IntExact).unwrap();
        let l = Layer::dw("dw4", 64, 28, 3, 1, 1).with_precision(q);
        l.validate().unwrap();
        assert_eq!(l.quant, Some(q));
        // effective precision: override wins, else the config's spec
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        assert_eq!(l.effective_quant(&cfg), q);
        assert_eq!(Layer::dw("dw", 64, 28, 3, 1, 1).effective_quant(&cfg), PeType::Int16.spec());
        // an invalid override is rejected with the layer named and the
        // offending field in the message
        let bad = Layer::pw("pw0", 16, 32, 14)
            .with_precision(QuantSpec { act_bits: 0, wt_bits: 8, psum_bits: 16, mac: MacKind::IntExact });
        let e = bad.validate().unwrap_err();
        assert!(e.to_string().contains("pw0"), "{e}");
        assert!(e.to_string().contains("act_bits"), "{e}");
        let narrow = Layer::pw("pwn", 16, 32, 14)
            .with_precision(QuantSpec { act_bits: 8, wt_bits: 8, psum_bits: 4, mac: MacKind::IntExact });
        assert!(narrow.validate().unwrap_err().to_string().contains("psum_bits"));
    }

    #[test]
    fn matmul_accounting() {
        let l = Layer::matmul("blk0.attn.qkv", 128, 2048, 6144);
        assert_eq!(l.kind(), "matmul");
        assert!(l.is_transformer() && !l.is_fc());
        assert_eq!(l.macs(), 128 * 2048 * 6144);
        assert_eq!(l.ifmap_elems(), 128 * 2048);
        assert_eq!(l.filter_elems(), 2048 * 6144);
        assert_eq!(l.ofmap_elems(), 128 * 6144);
        assert_eq!(l.kv_elems(), 0);
        l.validate().unwrap();
        // decode shape: a single streamed row
        let d = Layer::matmul("d", 1, 2048, 6144);
        assert_eq!(d.macs(), 2048 * 6144);
        assert_eq!(d.ifmap_elems(), 2048);
    }

    #[test]
    fn attention_accounting() {
        // 32 heads x 64 dims, prefill over 2048 positions
        let a = Layer::attention("blk0.attn", 32, 64, 2048, 2048);
        assert_eq!(a.kind(), "attention");
        assert!(a.is_transformer());
        assert_eq!(a.macs(), 2 * 32 * 64 * 2048 * 2048);
        assert_eq!(a.ifmap_elems(), 2048 * 32 * 64);
        assert_eq!(a.filter_elems(), 0);
        assert_eq!(a.ofmap_elems(), 2048 * 32 * 64);
        assert_eq!(a.kv_elems(), 2 * 32 * 2048 * 64);
        a.validate().unwrap();
        // decode: one query over the full cache — same KV bytes per step,
        // 1/seq the MACs, so arithmetic intensity collapses
        let d = Layer::attention("d", 32, 64, 1, 2048);
        assert_eq!(d.kv_elems(), a.kv_elems());
        assert_eq!(d.macs() * 2048, a.macs());
        d.validate().unwrap();
    }

    #[test]
    fn transformer_validate_names_the_offending_field() {
        let cases: Vec<(Layer, &str)> = vec![
            (Layer::matmul("z", 0, 64, 64), "\"m\""),
            (Layer::matmul("z", 4, 0, 64), "\"k\""),
            (Layer::matmul("z", 4, 64, 0), "\"n\""),
            (Layer::attention("z", 0, 64, 4, 4), "\"heads\""),
            (Layer::attention("z", 4, 0, 4, 4), "\"head_dim\""),
            (Layer::attention("z", 4, 64, 0, 4), "\"seq_q\""),
            (Layer::attention("z", 4, 64, 4, 0), "\"seq_kv\""),
            // KV cache shorter than the query block
            (Layer::attention("z", 4, 64, 8, 4), "\"seq_kv\""),
        ];
        for (l, field) in cases {
            let e = l.validate().unwrap_err().to_string();
            assert!(e.contains(field), "expected {field} in: {e}");
            assert!(e.contains("'z'"), "layer name missing: {e}");
        }
        // carried channel fields drifting from the op geometry (k mismatch)
        let skewed = Layer { c: 65, ..Layer::matmul("skew", 4, 64, 64) };
        let e = skewed.validate().unwrap_err().to_string();
        assert!(e.contains("\"k\""), "{e}");
        let skewed_a = Layer { k: 100, ..Layer::attention("skew", 4, 64, 4, 4) };
        assert!(skewed_a.validate().is_err());
        // quant overrides are still validated on transformer ops
        use crate::config::MacKind;
        let bad_q = Layer::matmul("q", 4, 64, 64).with_precision(QuantSpec {
            act_bits: 0,
            wt_bits: 8,
            psum_bits: 16,
            mac: MacKind::IntExact,
        });
        assert!(bad_q.validate().unwrap_err().to_string().contains("act_bits"));
    }

    #[test]
    fn validate_catches_bad_shapes() {
        assert!(Layer::conv("ok", 16, 32, 28, 28, 3, 1, 1).validate().is_ok());
        assert!(Layer::dw("ok", 64, 28, 3, 2, 1).validate().is_ok());
        // c not divisible by groups
        let bad = Layer { groups: 3, ..Layer::conv("bad", 16, 32, 28, 28, 3, 1, 1) };
        assert!(bad.validate().is_err());
        // kernel larger than padded input
        let big = Layer::conv("big", 3, 8, 2, 2, 7, 1, 0);
        assert!(big.validate().is_err());
        // zero stride
        let z = Layer { stride: 0, ..Layer::conv("z", 3, 8, 8, 8, 3, 1, 1) };
        assert!(z.validate().is_err());
    }
}
