//! Layer energy model: traffic x per-access energy + compute + leakage.
//!
//! Every coefficient comes from the synthesis oracle's `EnergyParams`, so
//! the workload-level energy is consistent with the synthesized hardware.

use crate::config::AcceleratorConfig;
use crate::dataflow::layer::Layer;
use crate::dataflow::rs::LayerPerf;
use crate::dataflow::traffic::Traffic;
use crate::synth::oracle::EnergyParams;

/// Energy breakdown for one layer, millijoules.
///
/// Compute energy scales with [`Layer::macs`], which is `groups`-aware: a
/// depthwise layer pays `1/c` of the dense MAC energy of the same shape.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    /// MAC datapath + scratchpad energy.
    pub compute_mj: f64,
    /// Global-buffer access energy.
    pub glb_mj: f64,
    /// GLB<->PE interconnect energy.
    pub noc_mj: f64,
    /// Off-chip DRAM transfer energy.
    pub dram_mj: f64,
    /// Static leakage over the layer's wall-clock latency.
    pub leakage_mj: f64,
}

impl EnergyBreakdown {
    /// Sum of all components, millijoules.
    pub fn total_mj(&self) -> f64 {
        self.compute_mj + self.glb_mj + self.noc_mj + self.dram_mj + self.leakage_mj
    }
}

const FJ_TO_MJ: f64 = 1e-12;

/// Energy of one mapped layer.
pub fn layer_energy(
    _cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
    perf: &LayerPerf,
    traffic: &Traffic,
) -> EnergyBreakdown {
    let compute_mj = layer.macs() as f64 * ep.mac_with_spads_fj * FJ_TO_MJ;
    let glb_mj = traffic.glb_accesses as f64 * ep.glb_access_fj * FJ_TO_MJ;
    let noc_mj = traffic.noc_bits as f64 * ep.wire_fj_per_bit * FJ_TO_MJ;
    // `dram_bytes` already folds in the KV-cache class (attention layers),
    // so decode-phase energy prices KV reads at the DRAM rate for free.
    let dram_mj = traffic.dram_bytes as f64 * 8.0 * ep.dram_fj_per_bit * FJ_TO_MJ;
    // mW x s = mJ.
    let leakage_mj = ep.leakage_mw * perf.latency_s(ep.fmax_mhz);
    EnergyBreakdown { compute_mj, glb_mj, noc_mj, dram_mj, leakage_mj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::dataflow::rs::map_layer;
    use crate::dataflow::traffic::layer_traffic;
    use crate::synth::oracle::energy_params;

    fn energy_for(cfg: &AcceleratorConfig, l: &Layer) -> EnergyBreakdown {
        let ep = energy_params(cfg);
        let perf = map_layer(cfg, &ep, l);
        let traffic = layer_traffic(cfg, l, &perf);
        layer_energy(cfg, &ep, l, &perf, &traffic)
    }

    #[test]
    fn total_is_sum_of_parts() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let l = Layer::conv("c", 64, 64, 28, 28, 3, 1, 1);
        let e = energy_for(&cfg, &l);
        let sum = e.compute_mj + e.glb_mj + e.noc_mj + e.dram_mj + e.leakage_mj;
        assert!((e.total_mj() - sum).abs() < 1e-15);
        assert!(e.total_mj() > 0.0);
    }

    #[test]
    fn lightpe_cheaper_than_int16_cheaper_than_fp32() {
        let l = Layer::conv("c", 128, 128, 28, 28, 3, 1, 1);
        let e32 = energy_for(&AcceleratorConfig::default_with(PeType::Fp32), &l).total_mj();
        let e16 = energy_for(&AcceleratorConfig::default_with(PeType::Int16), &l).total_mj();
        let e8 = energy_for(&AcceleratorConfig::default_with(PeType::LightPe1), &l).total_mj();
        assert!(e32 > e16, "{e32} <= {e16}");
        assert!(e16 > e8, "{e16} <= {e8}");
    }

    #[test]
    fn compute_energy_matches_hand_formula() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let l = Layer::fc("fc", 64, 64);
        let e = energy_for(&cfg, &l);
        let expect = l.macs() as f64 * ep.mac_with_spads_fj * 1e-12;
        assert!((e.compute_mj - expect).abs() < 1e-12 * expect.max(1.0));
    }

    #[test]
    fn depthwise_much_cheaper_than_dense_same_shape() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let dense = Layer::conv("d", 64, 64, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 64, 28, 3, 1, 1);
        let ed = energy_for(&cfg, &dense);
        let edw = energy_for(&cfg, &dw);
        // Compute energy is proportional to MACs: exactly c=64x less.
        assert!((edw.compute_mj * 64.0 - ed.compute_mj).abs() < 1e-9 * ed.compute_mj.max(1.0));
        assert!(edw.total_mj() < ed.total_mj());
    }

    #[test]
    fn kv_cache_traffic_priced_at_dram_rate() {
        // Two decode-shaped attention layers differing only in context
        // length: the DRAM energy delta must equal the KV byte delta at
        // the DRAM per-bit rate (everything else about the layers' DRAM
        // volume is identical).
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let short = Layer::attention("a", 8, 64, 1, 512);
        let long = Layer::attention("a", 8, 64, 1, 2048);
        let es = energy_for(&cfg, &short);
        let el = energy_for(&cfg, &long);
        let perf_s = map_layer(&cfg, &ep, &short);
        let perf_l = map_layer(&cfg, &ep, &long);
        let ts = layer_traffic(&cfg, &short, &perf_s);
        let tl = layer_traffic(&cfg, &long, &perf_l);
        assert!(tl.dram_kv_bytes > ts.dram_kv_bytes);
        let expect_delta =
            (tl.dram_bytes - ts.dram_bytes) as f64 * 8.0 * ep.dram_fj_per_bit * 1e-12;
        let got_delta = el.dram_mj - es.dram_mj;
        assert!(
            (got_delta - expect_delta).abs() < 1e-9 * expect_delta.max(1e-12),
            "kv energy delta {got_delta} != {expect_delta}"
        );
        assert!(el.total_mj() > es.total_mj());
    }

    #[test]
    fn bigger_layer_more_energy() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let small = Layer::conv("s", 16, 16, 14, 14, 3, 1, 1);
        let big = Layer::conv("b", 64, 64, 28, 28, 3, 1, 1);
        assert!(energy_for(&cfg, &big).total_mj() > energy_for(&cfg, &small).total_mj());
    }
}
