//! Row-stationary dataflow model (Eyeriss-style) — the stand-in for the
//! paper's Synopsys VCS timing runs over DNN testbenches.
//!
//! Given a layer and an accelerator configuration it produces cycle counts,
//! PE utilization, per-level memory traffic and energy.  The model is
//! analytical (closed-form reuse factors) and is documented per-equation in
//! the submodules; its invariants (work conservation, compulsory-traffic
//! lower bounds, utilization <= 1) are enforced by unit + property tests.
//!
//! All cost accounting is `groups`-aware: dense, grouped and depthwise
//! convolutions (see [`Layer`]) are costed at their connected-plane MAC and
//! filter-traffic counts, never at the dense rate.

pub mod energy;
pub mod layer;
pub mod rs;
pub mod traffic;

pub use energy::{layer_energy, EnergyBreakdown};
pub use layer::Layer;
pub use rs::{map_layer, LayerPerf};
pub use traffic::{layer_traffic, Traffic};

use crate::config::AcceleratorConfig;
use crate::synth::oracle::EnergyParams;

/// Aggregate cost of running a whole network once.
#[derive(Debug, Clone, Copy, Default)]
pub struct NetworkCost {
    /// Total multiply-accumulates (groups-aware, see [`Layer::macs`]).
    pub macs: u64,
    /// Total cycles across all layers.
    pub cycles: u64,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Total energy, mJ.
    pub energy_mj: f64,
    /// MAC-weighted average PE-array utilization.
    pub avg_utilization: f64,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: u64,
}

/// Evaluate a network (list of layers) on a configuration.
///
/// Residual networks repeat identical layer shapes many times (ResNet-34
/// has 37 layers but only ~24 distinct shapes); since every per-layer cost
/// is additive, identical layers are evaluated once and scaled by their
/// multiplicity — exact, and ~1.5-2x faster in the DSE inner loop. The
/// shape key includes `groups`, so a depthwise layer never aliases a dense
/// layer of the same (c, k, hw, rs) dimensions.
pub fn evaluate_network(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layers: &[Layer],
) -> NetworkCost {
    // Group identical shapes preserving first-seen order.
    let mut unique: Vec<(&Layer, u64)> = Vec::with_capacity(layers.len());
    'outer: for layer in layers {
        for (l, count) in unique.iter_mut() {
            if l.c == layer.c
                && l.k == layer.k
                && l.hw == layer.hw
                && l.rs == layer.rs
                && l.stride == layer.stride
                && l.pad == layer.pad
                && l.groups == layer.groups
            {
                *count += 1;
                continue 'outer;
            }
        }
        unique.push((layer, 1));
    }

    let mut total = NetworkCost::default();
    let mut util_weighted = 0.0;
    for (layer, count) in unique {
        let mapped = map_layer(cfg, ep, layer);
        let traffic = layer_traffic(cfg, layer, &mapped);
        // Re-tighten the bandwidth roofline with the scheduled traffic.
        let perf = rs::apply_bandwidth(cfg, ep, layer, &mapped, traffic.dram_bytes);
        let energy = layer_energy(cfg, ep, layer, &perf, &traffic);
        let n = count as f64;
        total.macs += layer.macs() * count;
        total.cycles += perf.cycles * count;
        total.latency_s += perf.latency_s(ep.fmax_mhz) * n;
        total.energy_mj += energy.total_mj() * n;
        total.dram_bytes += traffic.dram_bytes * count;
        util_weighted += perf.utilization * (layer.macs() * count) as f64;
    }
    total.avg_utilization = if total.macs > 0 {
        util_weighted / total.macs as f64
    } else {
        0.0
    };
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::synth::oracle::energy_params;

    #[test]
    fn network_cost_accumulates() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let layers = vec![
            Layer::conv("a", 3, 16, 32, 32, 3, 1, 1),
            Layer::conv("b", 16, 32, 16, 16, 3, 1, 1),
            Layer::fc("c", 256, 10),
        ];
        let cost = evaluate_network(&cfg, &ep, &layers);
        let macs: u64 = layers.iter().map(|l| l.macs()).sum();
        assert_eq!(cost.macs, macs);
        assert!(cost.cycles > 0);
        assert!(cost.latency_s > 0.0);
        assert!(cost.energy_mj > 0.0);
        assert!(cost.avg_utilization > 0.0 && cost.avg_utilization <= 1.0);
    }

    #[test]
    fn dedup_never_aliases_depthwise_with_dense() {
        // Same (c, k, hw, rs, stride, pad) but different groups: the
        // shape-dedup in evaluate_network must keep them distinct, so the
        // pair costs strictly more than two copies of the depthwise layer.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let dense = Layer::conv("d", 32, 32, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 32, 28, 3, 1, 1);
        let mixed = evaluate_network(&cfg, &ep, &[dense.clone(), dw.clone()]);
        let twice_dw = evaluate_network(&cfg, &ep, &[dw.clone(), dw.clone()]);
        assert_eq!(mixed.macs, dense.macs() + dw.macs());
        assert!(mixed.cycles > twice_dw.cycles);
    }
}
