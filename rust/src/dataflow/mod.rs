//! Row-stationary dataflow model (Eyeriss-style) — the stand-in for the
//! paper's Synopsys VCS timing runs over DNN testbenches.
//!
//! Given a layer and an accelerator configuration it produces cycle counts,
//! PE utilization, per-level memory traffic and energy.  The model is
//! analytical (closed-form reuse factors) and is documented per-equation in
//! the submodules; its invariants (work conservation, compulsory-traffic
//! lower bounds, utilization <= 1) are enforced by unit + property tests.
//!
//! All cost accounting is `groups`-aware: dense, grouped and depthwise
//! convolutions (see [`Layer`]) are costed at their connected-plane MAC and
//! filter-traffic counts, never at the dense rate.

pub mod energy;
pub mod layer;
pub mod rs;
pub mod traffic;

pub use energy::{layer_energy, EnergyBreakdown};
pub use layer::{Layer, Op};
pub use rs::{map_layer, LayerPerf};
pub use traffic::{layer_traffic, Traffic};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::{AcceleratorConfig, PeType, QuantSpec};
use crate::synth::cache::SynthMemo;
use crate::synth::oracle::{energy_params, EnergyParams};

/// Aggregate cost of running a whole network once.
#[derive(Debug, Clone, Copy, Default)]
pub struct NetworkCost {
    /// Total multiply-accumulates (groups-aware, see [`Layer::macs`]).
    pub macs: u64,
    /// Total cycles across all layers.
    pub cycles: u64,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Total energy, mJ.
    pub energy_mj: f64,
    /// MAC-weighted average PE-array utilization.
    pub avg_utilization: f64,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: u64,
    /// KV-cache DRAM traffic, bytes (subset of `dram_bytes`; zero for
    /// CNN workloads).
    pub dram_kv_bytes: u64,
}

impl NetworkCost {
    /// Sum of two evaluations — e.g. prefill plus the decode phase of a
    /// transformer workload. Utilization recombines MAC-weighted, matching
    /// how `evaluate_network` averages across layers.
    pub fn add(&self, other: &NetworkCost) -> NetworkCost {
        let macs = self.macs + other.macs;
        let avg_utilization = if macs > 0 {
            (self.avg_utilization * self.macs as f64
                + other.avg_utilization * other.macs as f64)
                / macs as f64
        } else {
            0.0
        };
        NetworkCost {
            macs,
            cycles: self.cycles + other.cycles,
            latency_s: self.latency_s + other.latency_s,
            energy_mj: self.energy_mj + other.energy_mj,
            avg_utilization,
            dram_bytes: self.dram_bytes + other.dram_bytes,
            dram_kv_bytes: self.dram_kv_bytes + other.dram_kv_bytes,
        }
    }

    /// Cost of running this network `n` times back-to-back — e.g. `ctx`
    /// decode steps. Utilization is per-step and unchanged by repetition.
    pub fn scale(&self, n: u64) -> NetworkCost {
        NetworkCost {
            macs: self.macs * n,
            cycles: self.cycles * n,
            latency_s: self.latency_s * n as f64,
            energy_mj: self.energy_mj * n as f64,
            avg_utilization: self.avg_utilization,
            dram_bytes: self.dram_bytes * n,
            dram_kv_bytes: self.dram_kv_bytes * n,
        }
    }
}

/// Resolve the (config, energy params) a layer actually runs with: its own
/// precision override applied to the accelerator (hardware re-sized at the
/// override spec, clock kept at the array's), or the inputs unchanged.
/// Derives full array energy parameters on an override — callers looping
/// over many layers should memoize per spec (as `evaluate_network` and the
/// session's analyze path do) and feed [`layer_cost_at`].
pub fn layer_hw(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
) -> (AcceleratorConfig, EnergyParams) {
    match layer.quant {
        Some(q) if q != cfg.quant() => {
            let cfg_l = cfg.with_pe_type(PeType::from_spec(q));
            let mut ep_l = energy_params(&cfg_l);
            // One chip, one clock: the override re-sizes datapaths and
            // word widths but runs at the array's (possibly predicted)
            // clock, so latency stays comparable across layers.
            ep_l.fmax_mhz = ep.fmax_mhz;
            (cfg_l, ep_l)
        }
        _ => (*cfg, *ep),
    }
}

/// Cost one layer end-to-end: map, schedule traffic, re-tighten the
/// bandwidth roofline, price energy.  Applies the layer's precision
/// override (if any), so `analyze` and the network evaluator agree on
/// mixed-precision accounting.
pub fn layer_cost(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
) -> (LayerPerf, Traffic, EnergyBreakdown) {
    let (cfg_l, ep_l) = layer_hw(cfg, ep, layer);
    layer_cost_at(&cfg_l, &ep_l, layer)
}

/// [`layer_cost`] after override resolution ([`layer_hw`]); callers that
/// memoize the per-spec hardware skip the re-derivation.
pub fn layer_cost_at(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
) -> (LayerPerf, Traffic, EnergyBreakdown) {
    let mapped = map_layer(cfg, ep, layer);
    let traffic = layer_traffic(cfg, layer, &mapped);
    // Re-tighten the bandwidth roofline with the scheduled traffic.
    let perf = rs::apply_bandwidth(cfg, ep, layer, &mapped, traffic.dram_bytes);
    let energy = layer_energy(cfg, ep, layer, &perf, &traffic);
    (perf, traffic, energy)
}

/// Evaluate a network (list of layers) on a configuration.
///
/// Residual networks repeat identical layer shapes many times (ResNet-34
/// has 37 layers but only ~24 distinct shapes); since every per-layer cost
/// is additive, identical layers are evaluated once and scaled by their
/// multiplicity — exact, and ~1.5-2x faster in the DSE inner loop. The
/// shape key includes `groups` and the per-layer precision override, so a
/// depthwise layer never aliases a dense layer of the same (c, k, hw, rs)
/// dimensions and an INT4 layer never aliases its INT8 twin.  Override
/// hardware (energy params per distinct spec) is derived once per spec,
/// not once per layer.
pub fn evaluate_network(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layers: &[Layer],
) -> NetworkCost {
    // Group identical shapes preserving first-seen order.
    let mut unique: Vec<(&Layer, u64)> = Vec::with_capacity(layers.len());
    'outer: for layer in layers {
        for (l, count) in unique.iter_mut() {
            if l.c == layer.c
                && l.k == layer.k
                && l.hw == layer.hw
                && l.rs == layer.rs
                && l.stride == layer.stride
                && l.pad == layer.pad
                && l.groups == layer.groups
                && l.quant == layer.quant
                && l.op == layer.op
            {
                *count += 1;
                continue 'outer;
            }
        }
        unique.push((layer, 1));
    }

    // Per-override hardware memo: mixed-precision nets reuse a handful of
    // specs across many layers, and energy_params re-synthesizes the array.
    let mut override_hw: Vec<(crate::config::QuantSpec, AcceleratorConfig, EnergyParams)> =
        Vec::new();

    let mut total = NetworkCost::default();
    let mut util_weighted = 0.0;
    for (layer, count) in unique {
        let (cfg_l, ep_l) = match layer.quant {
            Some(q) if q != cfg.quant() => {
                match override_hw.iter().position(|(spec, _, _)| *spec == q) {
                    Some(i) => (override_hw[i].1, override_hw[i].2),
                    None => {
                        let (c, e) = layer_hw(cfg, ep, layer);
                        override_hw.push((q, c, e));
                        (c, e)
                    }
                }
            }
            _ => (*cfg, *ep),
        };
        let (perf, traffic, energy) = layer_cost_at(&cfg_l, &ep_l, layer);
        let n = count as f64;
        total.macs += layer.macs() * count;
        total.cycles += perf.cycles * count;
        total.latency_s += perf.latency_s(ep_l.fmax_mhz) * n;
        total.energy_mj += energy.total_mj() * n;
        total.dram_bytes += traffic.dram_bytes * count;
        total.dram_kv_bytes += traffic.dram_kv_bytes * count;
        util_weighted += perf.utilization * (layer.macs() * count) as f64;
    }
    total.avg_utilization = if total.macs > 0 {
        util_weighted / total.macs as f64
    } else {
        0.0
    };
    total
}

// ---------------------------------------------------------------------------
// Hot path: prepared workloads + the sweep-wide layer-cost memo
// ---------------------------------------------------------------------------

/// A workload with the shape-dedup of [`evaluate_network`] hoisted out of
/// the per-config inner loop.  A sweep evaluates the same layer list for
/// tens of thousands of configs; the first-seen grouping is identical
/// every time, so the engine builds it once per (workload, sweep) and
/// streams configs through [`evaluate_network_prepared`].
#[derive(Debug, Clone)]
pub struct PreparedWorkload {
    /// (layer, multiplicity) in first-seen order — exactly the grouping
    /// `evaluate_network` derives, so the accumulation order (and every
    /// float) matches the unprepared path bit-for-bit.
    unique: Vec<(Layer, u64)>,
}

impl PreparedWorkload {
    pub fn new(layers: &[Layer]) -> PreparedWorkload {
        let mut unique: Vec<(Layer, u64)> = Vec::with_capacity(layers.len());
        'outer: for layer in layers {
            for (l, count) in unique.iter_mut() {
                if l.c == layer.c
                    && l.k == layer.k
                    && l.hw == layer.hw
                    && l.rs == layer.rs
                    && l.stride == layer.stride
                    && l.pad == layer.pad
                    && l.groups == layer.groups
                    && l.quant == layer.quant
                    && l.op == layer.op
                {
                    *count += 1;
                    continue 'outer;
                }
            }
            unique.push((layer.clone(), 1));
        }
        PreparedWorkload { unique }
    }

    /// Distinct layer shapes after dedup.
    pub fn distinct(&self) -> usize {
        self.unique.len()
    }
}

/// Memo key: every input [`layer_cost_at`] reads.  The config fields plus
/// the clock pin the energy params exactly — callers derive `ep` from the
/// config via `energy_params` (possibly with the predicted `fmax_mhz`
/// substituted), so (config fields, fmax) determines every other `ep`
/// field.  The layer key is the full cost-relevant shape (`name` is
/// excluded: it never enters the cost model); `quant` is included even
/// though callers resolve overrides first, keeping the key conservative.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CostKey {
    pe_type: PeType,
    pe_rows: u32,
    pe_cols: u32,
    glb_kb: u32,
    spad_ifmap_b: u32,
    spad_filter_b: u32,
    spad_psum_b: u32,
    bandwidth_bits: u64,
    fmax_bits: u64,
    c: u32,
    k: u32,
    hw: u32,
    rs: u32,
    stride: u32,
    pad: u32,
    groups: u32,
    quant: Option<QuantSpec>,
    op: Op,
}

impl CostKey {
    fn new(cfg: &AcceleratorConfig, ep: &EnergyParams, layer: &Layer) -> CostKey {
        CostKey {
            pe_type: cfg.pe_type,
            pe_rows: cfg.pe_rows,
            pe_cols: cfg.pe_cols,
            glb_kb: cfg.glb_kb,
            spad_ifmap_b: cfg.spad_ifmap_b,
            spad_filter_b: cfg.spad_filter_b,
            spad_psum_b: cfg.spad_psum_b,
            bandwidth_bits: cfg.bandwidth_gbps.to_bits(),
            fmax_bits: ep.fmax_mhz.to_bits(),
            c: layer.c,
            k: layer.k,
            hw: layer.hw,
            rs: layer.rs,
            stride: layer.stride,
            pad: layer.pad,
            groups: layer.groups,
            quant: layer.quant,
            op: layer.op,
        }
    }
}

/// Insertion cap: a runaway sweep (every key distinct) stops growing the
/// map here and keeps computing cold — correctness never depends on a hit.
const COST_MEMO_MAX_ENTRIES: usize = 262_144;

/// Sweep-wide layer-cost memo keyed by (resolved config, clock, layer
/// shape).  Thread-safe: the sweep's dataflow phase runs on a thread pool.
#[derive(Default)]
pub struct CostMemo {
    map: Mutex<HashMap<CostKey, (LayerPerf, Traffic, EnergyBreakdown)>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CostMemo {
    pub fn new() -> CostMemo {
        CostMemo::default()
    }

    /// (hits, misses); their sum equals the number of
    /// [`CostMemo::layer_cost_cached`] calls.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// [`layer_cost_at`] through the memo: a hit returns the cached
    /// triple (bit-identical — the cached value *is* a previous cold
    /// result for an identical key), a miss computes and caches.
    pub fn layer_cost_cached(
        &self,
        cfg: &AcceleratorConfig,
        ep: &EnergyParams,
        layer: &Layer,
    ) -> (LayerPerf, Traffic, EnergyBreakdown) {
        let key = CostKey::new(cfg, ep, layer);
        if let Some(v) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *v;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Compute outside the lock; a racing double-insert writes the
        // identical value.
        let v = layer_cost_at(cfg, ep, layer);
        let mut map = self.map.lock().unwrap();
        if map.len() < COST_MEMO_MAX_ENTRIES {
            map.insert(key, v);
        }
        v
    }
}

/// Hit/miss counters of both hot-path memos, as surfaced through
/// `SweepStats` and the optimizer's `[engine]` stderr line.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    pub cost_hits: u64,
    pub cost_misses: u64,
    pub synth_hits: u64,
    pub synth_misses: u64,
}

/// Shared evaluation context: the synthesis memo feeding `energy_params`
/// and the layer-cost memo.  One context spans a whole sweep (the
/// `SweepEngine` owns one) or a whole optimizer run.
#[derive(Default)]
pub struct EvalContext {
    pub synth: SynthMemo,
    pub costs: CostMemo,
}

impl EvalContext {
    pub fn new() -> EvalContext {
        EvalContext::default()
    }

    pub fn stats(&self) -> MemoStats {
        let (cost_hits, cost_misses) = self.costs.counters();
        let (synth_hits, synth_misses) = self.synth.counters();
        MemoStats { cost_hits, cost_misses, synth_hits, synth_misses }
    }
}

/// [`evaluate_network`] over a [`PreparedWorkload`] with both memos
/// applied.  The accumulation replicates `evaluate_network` operation-for-
/// operation (same first-seen order, same per-layer arithmetic), and the
/// memos return bit-identical values to cold computation, so this is
/// bit-exact against the legacy path — pinned by tests here and by
/// `tests/integration_soa.rs`.
pub fn evaluate_network_prepared(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    prep: &PreparedWorkload,
    ctx: &EvalContext,
) -> NetworkCost {
    let mut override_hw: Vec<(QuantSpec, AcceleratorConfig, EnergyParams)> = Vec::new();
    let mut total = NetworkCost::default();
    let mut util_weighted = 0.0;
    for (layer, count) in &prep.unique {
        let (cfg_l, ep_l) = match layer.quant {
            Some(q) if q != cfg.quant() => {
                match override_hw.iter().position(|(spec, _, _)| *spec == q) {
                    Some(i) => (override_hw[i].1, override_hw[i].2),
                    None => {
                        let cfg_q = cfg.with_pe_type(PeType::from_spec(q));
                        let mut ep_q = ctx.synth.energy_params_with(&cfg_q);
                        ep_q.fmax_mhz = ep.fmax_mhz;
                        override_hw.push((q, cfg_q, ep_q));
                        (cfg_q, ep_q)
                    }
                }
            }
            _ => (*cfg, *ep),
        };
        let (perf, traffic, energy) = ctx.costs.layer_cost_cached(&cfg_l, &ep_l, layer);
        let count = *count;
        let n = count as f64;
        total.macs += layer.macs() * count;
        total.cycles += perf.cycles * count;
        total.latency_s += perf.latency_s(ep_l.fmax_mhz) * n;
        total.energy_mj += energy.total_mj() * n;
        total.dram_bytes += traffic.dram_bytes * count;
        total.dram_kv_bytes += traffic.dram_kv_bytes * count;
        util_weighted += perf.utilization * (layer.macs() * count) as f64;
    }
    total.avg_utilization = if total.macs > 0 {
        util_weighted / total.macs as f64
    } else {
        0.0
    };
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::synth::oracle::energy_params;

    #[test]
    fn network_cost_accumulates() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let layers = vec![
            Layer::conv("a", 3, 16, 32, 32, 3, 1, 1),
            Layer::conv("b", 16, 32, 16, 16, 3, 1, 1),
            Layer::fc("c", 256, 10),
        ];
        let cost = evaluate_network(&cfg, &ep, &layers);
        let macs: u64 = layers.iter().map(|l| l.macs()).sum();
        assert_eq!(cost.macs, macs);
        assert!(cost.cycles > 0);
        assert!(cost.latency_s > 0.0);
        assert!(cost.energy_mj > 0.0);
        assert!(cost.avg_utilization > 0.0 && cost.avg_utilization <= 1.0);
    }

    #[test]
    fn per_layer_precision_override_changes_cost() {
        use crate::config::QuantSpec;
        // An INT4 override on an INT16 array must cut the layer's compute
        // and DRAM cost; a no-op override (same spec as the config) must be
        // bit-identical to no override at all.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let base = Layer::conv("c", 64, 64, 28, 28, 3, 1, 1);
        let int4 = base.clone().with_precision(QuantSpec::int(4, 4));
        let noop = base.clone().with_precision(PeType::Int16.spec());

        let (pb, tb, eb) = layer_cost(&cfg, &ep, &base);
        let (p4, t4, e4) = layer_cost(&cfg, &ep, &int4);
        let (pn, tn, en) = layer_cost(&cfg, &ep, &noop);
        assert!(t4.dram_bytes < tb.dram_bytes, "{} >= {}", t4.dram_bytes, tb.dram_bytes);
        assert!(e4.total_mj() < eb.total_mj());
        assert_eq!(pn.cycles, pb.cycles);
        assert_eq!(tn.dram_bytes, tb.dram_bytes);
        assert_eq!(en.total_mj(), eb.total_mj());
        assert!(p4.cycles > 0);

        // evaluate_network applies the same overrides (and keeps MACs
        // precision-independent)
        let mixed = evaluate_network(&cfg, &ep, &[base.clone(), int4.clone()]);
        let plain = evaluate_network(&cfg, &ep, &[base.clone(), base.clone()]);
        assert_eq!(mixed.macs, plain.macs);
        assert!(mixed.energy_mj < plain.energy_mj);
        assert!(mixed.dram_bytes < plain.dram_bytes);
    }

    #[test]
    fn dedup_keeps_precision_overrides_distinct() {
        use crate::config::QuantSpec;
        // Same shape, different precision: the shape-dedup must keep them
        // apart, or the INT4 copy would be costed at INT16.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let l16 = Layer::conv("a", 32, 32, 14, 14, 3, 1, 1);
        let l4 = l16.clone().with_precision(QuantSpec::int(4, 4));
        let mixed = evaluate_network(&cfg, &ep, &[l16.clone(), l4.clone()]);
        let twice4 = evaluate_network(&cfg, &ep, &[l4.clone(), l4]);
        assert!(mixed.energy_mj > twice4.energy_mj);
    }

    #[test]
    fn dedup_never_aliases_depthwise_with_dense() {
        // Same (c, k, hw, rs, stride, pad) but different groups: the
        // shape-dedup in evaluate_network must keep them distinct, so the
        // pair costs strictly more than two copies of the depthwise layer.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let dense = Layer::conv("d", 32, 32, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 32, 28, 3, 1, 1);
        let mixed = evaluate_network(&cfg, &ep, &[dense.clone(), dw.clone()]);
        let twice_dw = evaluate_network(&cfg, &ep, &[dw.clone(), dw.clone()]);
        assert_eq!(mixed.macs, dense.macs() + dw.macs());
        assert!(mixed.cycles > twice_dw.cycles);
    }

    fn assert_cost_bits_equal(a: &NetworkCost, b: &NetworkCost) {
        assert_eq!(a.macs, b.macs);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.dram_bytes, b.dram_bytes);
        assert_eq!(a.dram_kv_bytes, b.dram_kv_bytes);
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "latency drifted");
        assert_eq!(a.energy_mj.to_bits(), b.energy_mj.to_bits(), "energy drifted");
        assert_eq!(
            a.avg_utilization.to_bits(),
            b.avg_utilization.to_bits(),
            "utilization drifted"
        );
    }

    #[test]
    fn prepared_evaluation_bit_identical_to_legacy_including_mixed_precision() {
        use crate::config::QuantSpec;
        let ctx = EvalContext::new();
        let layers = vec![
            Layer::conv("a", 3, 16, 32, 32, 3, 1, 1),
            Layer::conv("b", 16, 32, 16, 16, 3, 1, 1),
            Layer::conv("b2", 16, 32, 16, 16, 3, 1, 1), // repeated shape
            Layer::dw("dw", 32, 16, 3, 1, 1).with_precision(QuantSpec::int(4, 8)),
            Layer::fc("fc", 256, 10),
        ];
        let prep = PreparedWorkload::new(&layers);
        assert_eq!(prep.distinct(), 4);
        for ty in crate::config::ALL_PE_TYPES {
            let cfg = AcceleratorConfig::default_with(ty);
            let mut ep = energy_params(&cfg);
            ep.fmax_mhz = 917.0; // a predicted clock, as the sweep substitutes
            let legacy = evaluate_network(&cfg, &ep, &layers);
            // Run twice: the second pass is all memo hits and must not drift.
            let cold = evaluate_network_prepared(&cfg, &ep, &prep, &ctx);
            let warm = evaluate_network_prepared(&cfg, &ep, &prep, &ctx);
            assert_cost_bits_equal(&legacy, &cold);
            assert_cost_bits_equal(&legacy, &warm);
        }
        let s = ctx.stats();
        assert!(s.cost_hits > 0, "second pass must hit the layer-cost memo");
        assert!(s.synth_hits > 0, "override hardware must hit the synth memo");
    }

    #[test]
    fn network_cost_add_and_scale_compose_phases() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let prefill = vec![
            Layer::matmul("qkv", 512, 512, 1536),
            Layer::attention("attn", 8, 64, 512, 512),
        ];
        let decode = vec![
            Layer::matmul("qkv", 1, 512, 1536),
            Layer::attention("attn", 8, 64, 1, 512),
        ];
        let pre = evaluate_network(&cfg, &ep, &prefill);
        let dec = evaluate_network(&cfg, &ep, &decode);
        assert!(pre.dram_kv_bytes > 0 && dec.dram_kv_bytes > 0);
        // Both = prefill + ctx decode steps, exactly.
        let ctx = 512u64;
        let both = pre.add(&dec.scale(ctx));
        assert_eq!(both.macs, pre.macs + dec.macs * ctx);
        assert_eq!(both.cycles, pre.cycles + dec.cycles * ctx);
        assert_eq!(both.dram_kv_bytes, pre.dram_kv_bytes + dec.dram_kv_bytes * ctx);
        assert!((both.latency_s - (pre.latency_s + dec.latency_s * ctx as f64)).abs() < 1e-12);
        assert!(both.avg_utilization > 0.0 && both.avg_utilization <= 1.0);
        // Identity cases (utilization recombination tolerates one ulp of
        // x * n / n rounding, so compare approximately).
        let zero = NetworkCost::default();
        let same = pre.add(&zero);
        assert_eq!(same.macs, pre.macs);
        assert_eq!(same.cycles, pre.cycles);
        assert!((same.avg_utilization - pre.avg_utilization).abs() < 1e-12);
        assert_eq!(dec.scale(1).cycles, dec.cycles);
        assert_eq!(dec.scale(0).macs, 0);
    }

    #[test]
    fn dedup_never_aliases_phases_or_transformer_ops() {
        // A decode matmul (m = 1) carries the same conv fields as the fc
        // layer of identical width and as its prefill twin — the dedup key
        // must keep all of them distinct via `op`.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let fc = Layer::fc("fc", 512, 512);
        let mm1 = Layer::matmul("mm1", 1, 512, 512);
        let mm128 = Layer::matmul("mm128", 128, 512, 512);
        let prep = PreparedWorkload::new(&[fc.clone(), mm1.clone(), mm128.clone()]);
        assert_eq!(prep.distinct(), 3);
        let cost = evaluate_network(&cfg, &ep, &[fc.clone(), mm1.clone(), mm128.clone()]);
        assert_eq!(cost.macs, fc.macs() + mm1.macs() + mm128.macs());
        // Attention decode vs prefill at the same width likewise.
        let a_pre = Layer::attention("a", 8, 64, 256, 256);
        let a_dec = Layer::attention("a", 8, 64, 1, 256);
        assert_eq!(PreparedWorkload::new(&[a_pre, a_dec]).distinct(), 2);
    }

    #[test]
    fn cost_memo_hit_equals_cold_compute_for_random_spec_layer_pairs() {
        use crate::testkit::{forall, gen_config, gen_layer, gen_quant_spec};
        let memo = CostMemo::new();
        forall(
            "layer-cost memo hit == cold compute",
            60,
            93,
            |rng| {
                let mut cfg = gen_config(rng);
                if rng.f64() < 0.5 {
                    cfg.pe_type = PeType::from_spec(gen_quant_spec(rng));
                }
                (cfg, gen_layer(rng))
            },
            |(cfg, layer)| {
                let ep = energy_params(cfg);
                let cold = layer_cost_at(cfg, &ep, layer);
                let first = memo.layer_cost_cached(cfg, &ep, layer);
                let second = memo.layer_cost_cached(cfg, &ep, layer);
                for (tag, got) in [("miss", &first), ("hit", &second)] {
                    if got.0.cycles != cold.0.cycles
                        || got.1.dram_bytes != cold.1.dram_bytes
                        || got.2.total_mj().to_bits() != cold.2.total_mj().to_bits()
                        || got.0.utilization.to_bits() != cold.0.utilization.to_bits()
                    {
                        return Err(format!("memo {tag} diverged from cold compute"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cost_memo_distinct_keys_never_collide() {
        // A depthwise layer and a grouped layer engineered to share the
        // exact MAC count must still occupy distinct memo entries.
        let dw = Layer::dw("dw", 64, 28, 3, 1, 1);
        let grp = Layer::grouped("g", 64, 8, 28, 3, 1, 1, 8);
        assert_eq!(dw.macs(), grp.macs(), "test premise: equal flop counts");
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let memo = CostMemo::new();
        let a = memo.layer_cost_cached(&cfg, &ep, &dw);
        let b = memo.layer_cost_cached(&cfg, &ep, &grp);
        assert_eq!(memo.counters(), (0, 2), "both shapes must miss separately");
        assert!(
            a.0.cycles != b.0.cycles || a.1.dram_bytes != b.1.dram_bytes,
            "distinct shapes must cost differently"
        );
        // Repeat lookups hit their own entries, never each other's.
        let a2 = memo.layer_cost_cached(&cfg, &ep, &dw);
        let b2 = memo.layer_cost_cached(&cfg, &ep, &grp);
        assert_eq!(memo.counters(), (2, 2));
        assert_eq!(a.0.cycles, a2.0.cycles);
        assert_eq!(b.0.cycles, b2.0.cycles);
        assert_eq!(a.2.total_mj().to_bits(), a2.2.total_mj().to_bits());
        assert_eq!(b.2.total_mj().to_bits(), b2.2.total_mj().to_bits());
    }

    #[test]
    fn cost_memo_counters_sum_to_total_lookups() {
        use crate::testkit::{gen_config, gen_layer};
        use crate::util::prng::Rng;
        let memo = CostMemo::new();
        let mut rng = Rng::new(17);
        let mut lookups = 0u64;
        for _ in 0..40 {
            let cfg = gen_config(&mut rng);
            let ep = energy_params(&cfg);
            let layer = gen_layer(&mut rng);
            // 1-3 lookups per pair so repeats generate genuine hits.
            for _ in 0..(1 + rng.below(3)) {
                memo.layer_cost_cached(&cfg, &ep, &layer);
                lookups += 1;
            }
        }
        let (hits, misses) = memo.counters();
        assert_eq!(hits + misses, lookups, "hits + misses must equal lookups");
        assert!(hits > 0 && misses > 0, "exercise both paths: {hits}/{misses}");
    }
}
