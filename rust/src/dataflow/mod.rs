//! Row-stationary dataflow model (Eyeriss-style) — the stand-in for the
//! paper's Synopsys VCS timing runs over DNN testbenches.
//!
//! Given a layer and an accelerator configuration it produces cycle counts,
//! PE utilization, per-level memory traffic and energy.  The model is
//! analytical (closed-form reuse factors) and is documented per-equation in
//! the submodules; its invariants (work conservation, compulsory-traffic
//! lower bounds, utilization <= 1) are enforced by unit + property tests.
//!
//! All cost accounting is `groups`-aware: dense, grouped and depthwise
//! convolutions (see [`Layer`]) are costed at their connected-plane MAC and
//! filter-traffic counts, never at the dense rate.

pub mod energy;
pub mod layer;
pub mod rs;
pub mod traffic;

pub use energy::{layer_energy, EnergyBreakdown};
pub use layer::Layer;
pub use rs::{map_layer, LayerPerf};
pub use traffic::{layer_traffic, Traffic};

use crate::config::{AcceleratorConfig, PeType};
use crate::synth::oracle::{energy_params, EnergyParams};

/// Aggregate cost of running a whole network once.
#[derive(Debug, Clone, Copy, Default)]
pub struct NetworkCost {
    /// Total multiply-accumulates (groups-aware, see [`Layer::macs`]).
    pub macs: u64,
    /// Total cycles across all layers.
    pub cycles: u64,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Total energy, mJ.
    pub energy_mj: f64,
    /// MAC-weighted average PE-array utilization.
    pub avg_utilization: f64,
    /// Total DRAM traffic, bytes.
    pub dram_bytes: u64,
}

/// Resolve the (config, energy params) a layer actually runs with: its own
/// precision override applied to the accelerator (hardware re-sized at the
/// override spec, clock kept at the array's), or the inputs unchanged.
/// Derives full array energy parameters on an override — callers looping
/// over many layers should memoize per spec (as `evaluate_network` and the
/// session's analyze path do) and feed [`layer_cost_at`].
pub fn layer_hw(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
) -> (AcceleratorConfig, EnergyParams) {
    match layer.quant {
        Some(q) if q != cfg.quant() => {
            let cfg_l = cfg.with_pe_type(PeType::from_spec(q));
            let mut ep_l = energy_params(&cfg_l);
            // One chip, one clock: the override re-sizes datapaths and
            // word widths but runs at the array's (possibly predicted)
            // clock, so latency stays comparable across layers.
            ep_l.fmax_mhz = ep.fmax_mhz;
            (cfg_l, ep_l)
        }
        _ => (*cfg, *ep),
    }
}

/// Cost one layer end-to-end: map, schedule traffic, re-tighten the
/// bandwidth roofline, price energy.  Applies the layer's precision
/// override (if any), so `analyze` and the network evaluator agree on
/// mixed-precision accounting.
pub fn layer_cost(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
) -> (LayerPerf, Traffic, EnergyBreakdown) {
    let (cfg_l, ep_l) = layer_hw(cfg, ep, layer);
    layer_cost_at(&cfg_l, &ep_l, layer)
}

/// [`layer_cost`] after override resolution ([`layer_hw`]); callers that
/// memoize the per-spec hardware skip the re-derivation.
pub fn layer_cost_at(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
) -> (LayerPerf, Traffic, EnergyBreakdown) {
    let mapped = map_layer(cfg, ep, layer);
    let traffic = layer_traffic(cfg, layer, &mapped);
    // Re-tighten the bandwidth roofline with the scheduled traffic.
    let perf = rs::apply_bandwidth(cfg, ep, layer, &mapped, traffic.dram_bytes);
    let energy = layer_energy(cfg, ep, layer, &perf, &traffic);
    (perf, traffic, energy)
}

/// Evaluate a network (list of layers) on a configuration.
///
/// Residual networks repeat identical layer shapes many times (ResNet-34
/// has 37 layers but only ~24 distinct shapes); since every per-layer cost
/// is additive, identical layers are evaluated once and scaled by their
/// multiplicity — exact, and ~1.5-2x faster in the DSE inner loop. The
/// shape key includes `groups` and the per-layer precision override, so a
/// depthwise layer never aliases a dense layer of the same (c, k, hw, rs)
/// dimensions and an INT4 layer never aliases its INT8 twin.  Override
/// hardware (energy params per distinct spec) is derived once per spec,
/// not once per layer.
pub fn evaluate_network(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layers: &[Layer],
) -> NetworkCost {
    // Group identical shapes preserving first-seen order.
    let mut unique: Vec<(&Layer, u64)> = Vec::with_capacity(layers.len());
    'outer: for layer in layers {
        for (l, count) in unique.iter_mut() {
            if l.c == layer.c
                && l.k == layer.k
                && l.hw == layer.hw
                && l.rs == layer.rs
                && l.stride == layer.stride
                && l.pad == layer.pad
                && l.groups == layer.groups
                && l.quant == layer.quant
            {
                *count += 1;
                continue 'outer;
            }
        }
        unique.push((layer, 1));
    }

    // Per-override hardware memo: mixed-precision nets reuse a handful of
    // specs across many layers, and energy_params re-synthesizes the array.
    let mut override_hw: Vec<(crate::config::QuantSpec, AcceleratorConfig, EnergyParams)> =
        Vec::new();

    let mut total = NetworkCost::default();
    let mut util_weighted = 0.0;
    for (layer, count) in unique {
        let (cfg_l, ep_l) = match layer.quant {
            Some(q) if q != cfg.quant() => {
                match override_hw.iter().position(|(spec, _, _)| *spec == q) {
                    Some(i) => (override_hw[i].1, override_hw[i].2),
                    None => {
                        let (c, e) = layer_hw(cfg, ep, layer);
                        override_hw.push((q, c, e));
                        (c, e)
                    }
                }
            }
            _ => (*cfg, *ep),
        };
        let (perf, traffic, energy) = layer_cost_at(&cfg_l, &ep_l, layer);
        let n = count as f64;
        total.macs += layer.macs() * count;
        total.cycles += perf.cycles * count;
        total.latency_s += perf.latency_s(ep_l.fmax_mhz) * n;
        total.energy_mj += energy.total_mj() * n;
        total.dram_bytes += traffic.dram_bytes * count;
        util_weighted += perf.utilization * (layer.macs() * count) as f64;
    }
    total.avg_utilization = if total.macs > 0 {
        util_weighted / total.macs as f64
    } else {
        0.0
    };
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::synth::oracle::energy_params;

    #[test]
    fn network_cost_accumulates() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let layers = vec![
            Layer::conv("a", 3, 16, 32, 32, 3, 1, 1),
            Layer::conv("b", 16, 32, 16, 16, 3, 1, 1),
            Layer::fc("c", 256, 10),
        ];
        let cost = evaluate_network(&cfg, &ep, &layers);
        let macs: u64 = layers.iter().map(|l| l.macs()).sum();
        assert_eq!(cost.macs, macs);
        assert!(cost.cycles > 0);
        assert!(cost.latency_s > 0.0);
        assert!(cost.energy_mj > 0.0);
        assert!(cost.avg_utilization > 0.0 && cost.avg_utilization <= 1.0);
    }

    #[test]
    fn per_layer_precision_override_changes_cost() {
        use crate::config::QuantSpec;
        // An INT4 override on an INT16 array must cut the layer's compute
        // and DRAM cost; a no-op override (same spec as the config) must be
        // bit-identical to no override at all.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let base = Layer::conv("c", 64, 64, 28, 28, 3, 1, 1);
        let int4 = base.clone().with_precision(QuantSpec::int(4, 4));
        let noop = base.clone().with_precision(PeType::Int16.spec());

        let (pb, tb, eb) = layer_cost(&cfg, &ep, &base);
        let (p4, t4, e4) = layer_cost(&cfg, &ep, &int4);
        let (pn, tn, en) = layer_cost(&cfg, &ep, &noop);
        assert!(t4.dram_bytes < tb.dram_bytes, "{} >= {}", t4.dram_bytes, tb.dram_bytes);
        assert!(e4.total_mj() < eb.total_mj());
        assert_eq!(pn.cycles, pb.cycles);
        assert_eq!(tn.dram_bytes, tb.dram_bytes);
        assert_eq!(en.total_mj(), eb.total_mj());
        assert!(p4.cycles > 0);

        // evaluate_network applies the same overrides (and keeps MACs
        // precision-independent)
        let mixed = evaluate_network(&cfg, &ep, &[base.clone(), int4.clone()]);
        let plain = evaluate_network(&cfg, &ep, &[base.clone(), base.clone()]);
        assert_eq!(mixed.macs, plain.macs);
        assert!(mixed.energy_mj < plain.energy_mj);
        assert!(mixed.dram_bytes < plain.dram_bytes);
    }

    #[test]
    fn dedup_keeps_precision_overrides_distinct() {
        use crate::config::QuantSpec;
        // Same shape, different precision: the shape-dedup must keep them
        // apart, or the INT4 copy would be costed at INT16.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let l16 = Layer::conv("a", 32, 32, 14, 14, 3, 1, 1);
        let l4 = l16.clone().with_precision(QuantSpec::int(4, 4));
        let mixed = evaluate_network(&cfg, &ep, &[l16.clone(), l4.clone()]);
        let twice4 = evaluate_network(&cfg, &ep, &[l4.clone(), l4]);
        assert!(mixed.energy_mj > twice4.energy_mj);
    }

    #[test]
    fn dedup_never_aliases_depthwise_with_dense() {
        // Same (c, k, hw, rs, stride, pad) but different groups: the
        // shape-dedup in evaluate_network must keep them distinct, so the
        // pair costs strictly more than two copies of the depthwise layer.
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        let dense = Layer::conv("d", 32, 32, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 32, 28, 3, 1, 1);
        let mixed = evaluate_network(&cfg, &ep, &[dense.clone(), dw.clone()]);
        let twice_dw = evaluate_network(&cfg, &ep, &[dw.clone(), dw.clone()]);
        assert_eq!(mixed.macs, dense.macs() + dw.macs());
        assert!(mixed.cycles > twice_dw.cycles);
    }
}
