//! Row-stationary mapping and cycle model.
//!
//! Eyeriss's RS dataflow assigns PE(i, j) the 1-D convolution of filter row
//! `i` against the ifmap rows needed for output row `j`: a logical PE set
//! of `rs x out_hw` per (input-channel, filter) plane.  Folding/replication
//! onto the physical `rows x cols` array:
//!
//! * vertical: filter rows fold if `rs > rows` (`v_folds` passes), and if
//!   `rs <= rows` the array stacks `v_stack = rows / rs` independent
//!   (c, k) planes on top of each other;
//! * horizontal: output rows strip-mine across `cols` (`h_strips` passes);
//! * the `c*k` planes not covered by vertical stacking become sequential
//!   plane passes.
//!
//! Each pass keeps a PE busy for `rs * out_hw` MACs (one 1-D conv per
//! output row: `out_hw` outputs x `rs` taps), plus an array-fill overhead.
//! FC layers degenerate (out_hw = rs = 1), so they map as a `rows x cols`
//! dot-product tile: K across columns, C across rows.
//!
//! Grouped/depthwise convolutions schedule only the (input-channel, filter)
//! plane pairs that are actually connected: `(c / groups) * k` planes
//! instead of the dense `c * k`, so a depthwise layer runs `1/c` of the
//! dense plane passes (and its MAC count shrinks to match — see
//! [`Layer::macs`]).
//!
//! Transformer operators map as dot-product tiles rather than RS planes:
//! a `matmul` keeps a `[k x n]` weight tile stationary (K across rows, N
//! across columns, like FC) and streams its `m` activation rows through
//! it; `attention` runs two chained matmul tilings per head (`Q.K^T` with
//! the `[head_dim x seq_kv]` key block stationary, then `A.V`), with the
//! KV-cache bytes joining the compulsory-traffic roofline so a decode
//! step (`seq_q = 1` against a long cache) lands bandwidth-bound.

use crate::config::AcceleratorConfig;
use crate::dataflow::layer::{Layer, Op};
use crate::synth::oracle::EnergyParams;

/// Per-layer mapping/performance result.
#[derive(Debug, Clone, Copy)]
pub struct LayerPerf {
    /// Total cycles including fill and bandwidth stalls.
    pub cycles: u64,
    /// Pure compute cycles (no stalls).
    pub compute_cycles: u64,
    /// Bandwidth stall cycles.
    pub stall_cycles: u64,
    /// Number of array passes.
    pub passes: u64,
    /// Active PEs per pass (average).
    pub active_pes: f64,
    /// MAC-level utilization of the whole array over the layer.
    pub utilization: f64,
}

impl LayerPerf {
    /// Wall-clock latency at the given clock, seconds.
    pub fn latency_s(&self, fmax_mhz: f64) -> f64 {
        self.cycles as f64 / (fmax_mhz * 1e6)
    }
}

/// Pipeline fill cycles per pass (operands ripple down the array).
const FILL_PER_PASS: u64 = 8;

/// Map one layer onto the array and derive cycles.
pub fn map_layer(cfg: &AcceleratorConfig, ep: &EnergyParams, layer: &Layer) -> LayerPerf {
    let rows = cfg.pe_rows as u64;
    let cols = cfg.pe_cols as u64;
    let total_pes = rows * cols;
    let macs = layer.macs();

    let (passes, active_pes) = if let Op::Matmul { k, n, .. } = layer.op {
        // Weight-stationary: the [k x n] weight matrix tiles K across rows
        // and N across columns; the m activation rows stream through each
        // resident tile (m shows up in `macs`, so work conservation below
        // carries it into cycles).
        let kd = k as u64;
        let nd = n as u64;
        let tile_k = rows.min(kd);
        let tile_n = cols.min(nd);
        let passes = kd.div_ceil(tile_k) * nd.div_ceil(tile_n);
        (passes, (tile_k * tile_n) as f64)
    } else if let Op::Attention { heads, head_dim, seq_kv, .. } = layer.op {
        // Two chained matmul tilings per head: Q.K^T keeps the
        // [head_dim x seq_kv] key block stationary, A.V the
        // [seq_kv x head_dim] value block; seq_q streams through both
        // (decode: a single query row).
        let d = head_dim as u64;
        let kv = seq_kv as u64;
        let p_qk = d.div_ceil(rows.min(d)) * kv.div_ceil(cols.min(kv));
        let a_qk = (rows.min(d) * cols.min(kv)) as f64;
        let p_av = kv.div_ceil(rows.min(kv)) * d.div_ceil(cols.min(d));
        let a_av = (rows.min(kv) * cols.min(d)) as f64;
        let passes = heads as u64 * (p_qk + p_av);
        // Pass-weighted average occupancy across the two tilings.
        let active = (p_qk as f64 * a_qk + p_av as f64 * a_av) / (p_qk + p_av) as f64;
        (passes, active.min(total_pes as f64))
    } else if layer.is_fc() {
        // K across cols, C across rows; each active PE does one MAC per
        // pass; partial sums reduce down the column.
        let tile_c = rows.min(layer.c as u64);
        let tile_k = cols.min(layer.k as u64);
        let passes = (layer.c as u64).div_ceil(tile_c) * (layer.k as u64).div_ceil(tile_k);
        (passes, (tile_c * tile_k) as f64)
    } else {
        let rs = layer.rs as u64;
        let e = layer.out_hw() as u64;
        // vertical: fold large filters (rs > rows), stack small ones
        let v_folds = rs.div_ceil(rows);
        let rs_phys = rs.min(rows); // filter rows resident per pass
        // Quantization-aware capacity limit: stacking a (c,k) plane keeps
        // one filter row (rs weights) resident per PE, so the filter spad
        // bounds how many planes can stack — narrower weights stack more.
        let wt_bits = cfg.quant().wt_bits as u64;
        let spad_planes = (cfg.spad_filter_b as u64 * 8 / (rs * wt_bits)).max(1);
        let v_stack = (rows / rs_phys).max(1).min(spad_planes); // (c,k) planes stacked
        // horizontal strips of output rows
        let h_strips = e.div_ceil(cols);
        let e_phys = e.min(cols);
        // sequential (c,k) plane groups — only connected pairs: each of the
        // k filters reduces over c/groups input channels
        let planes = (layer.c / layer.groups.max(1)) as u64 * layer.k as u64;
        let plane_passes = planes.div_ceil(v_stack);
        let passes = v_folds * h_strips * plane_passes;
        let active = (rs_phys * e_phys * v_stack.min(planes)) as f64;
        (passes, active.min(total_pes as f64))
    };

    // Compute cycles: work conservation — the active PEs must execute all
    // MACs, at per-pass granularity (>= 1 cycle per pass), plus the
    // array-fill overhead of each pass.
    let ideal = (macs as f64 / active_pes.max(1.0)).ceil() as u64;
    let compute_cycles = ideal.max(passes) + passes * FILL_PER_PASS;

    // Bandwidth roofline against *compulsory* traffic (a lower bound);
    // `apply_bandwidth` re-tightens it with the scheduled traffic.
    let act_bits = cfg.quant().act_bits as u64;
    let wt_bits = cfg.quant().wt_bits as u64;
    // KV-cache reads are compulsory too (keys + values once per step);
    // zero for every non-attention layer.
    let compulsory_bits = layer.ifmap_elems() * act_bits
        + layer.filter_elems() * wt_bits
        + layer.ofmap_elems() * act_bits
        + layer.kv_elems() * act_bits;
    let bytes = compulsory_bits.div_ceil(8);
    with_mem_roofline(cfg, ep, layer, compute_cycles, passes, active_pes, bytes)
}

fn with_mem_roofline(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
    compute_cycles: u64,
    passes: u64,
    active_pes: f64,
    dram_bytes: u64,
) -> LayerPerf {
    let bytes_per_cycle = cfg.bandwidth_gbps * 1e9 / (ep.fmax_mhz * 1e6);
    let mem_cycles = (dram_bytes as f64 / bytes_per_cycle).ceil() as u64;
    let cycles = compute_cycles.max(mem_cycles);
    let stall_cycles = cycles - compute_cycles;
    let total_pes = (cfg.pe_rows * cfg.pe_cols) as f64;
    let utilization = layer.macs() as f64 / (cycles as f64 * total_pes);
    LayerPerf {
        cycles,
        compute_cycles,
        stall_cycles,
        passes,
        active_pes,
        utilization: utilization.min(1.0),
    }
}

/// Tighten the bandwidth roofline with the *scheduled* DRAM traffic (which
/// includes GLB-capacity reloads); returns an updated perf.
pub fn apply_bandwidth(
    cfg: &AcceleratorConfig,
    ep: &EnergyParams,
    layer: &Layer,
    perf: &LayerPerf,
    dram_bytes: u64,
) -> LayerPerf {
    with_mem_roofline(
        cfg,
        ep,
        layer,
        perf.compute_cycles,
        perf.passes,
        perf.active_pes,
        dram_bytes,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::synth::oracle::energy_params;

    fn setup(t: PeType) -> (AcceleratorConfig, crate::synth::oracle::EnergyParams) {
        let cfg = AcceleratorConfig::default_with(t);
        let ep = energy_params(&cfg);
        (cfg, ep)
    }

    #[test]
    fn work_is_conserved() {
        let (cfg, ep) = setup(PeType::Int16);
        let l = Layer::conv("c", 32, 64, 28, 28, 3, 1, 1);
        let p = map_layer(&cfg, &ep, &l);
        // cycles * total_pes >= macs (can't do more work than the array has)
        let capacity = p.cycles as f64 * cfg.num_pes() as f64;
        assert!(capacity >= l.macs() as f64, "{capacity} < {}", l.macs());
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
    }

    #[test]
    fn utilization_bounded_for_many_shapes() {
        let (cfg, ep) = setup(PeType::Int16);
        let shapes = [
            Layer::conv("a", 3, 64, 224, 224, 3, 1, 1),
            Layer::conv("b", 512, 512, 7, 7, 3, 1, 1),
            Layer::conv("c", 64, 64, 56, 56, 1, 1, 0),
            Layer::conv("d", 3, 64, 224, 224, 7, 2, 3),
            Layer::fc("e", 4096, 1000),
            Layer::fc("f", 25088, 4096),
        ];
        for l in shapes {
            let p = map_layer(&cfg, &ep, &l);
            assert!(p.utilization <= 1.0, "{}: util {}", l.name, p.utilization);
            assert_eq!(p.cycles, p.compute_cycles + p.stall_cycles);
            assert!(p.cycles > 0);
        }
    }

    #[test]
    fn more_pes_never_slower() {
        let (mut small, ep_s) = setup(PeType::Int16);
        small.pe_rows = 8;
        small.pe_cols = 8;
        let mut big = small;
        big.pe_rows = 16;
        big.pe_cols = 16;
        let ep_b = energy_params(&big);
        let l = Layer::conv("c", 64, 128, 28, 28, 3, 1, 1);
        let ps = map_layer(&small, &ep_s, &l);
        let pb = map_layer(&big, &ep_b, &l);
        assert!(pb.compute_cycles <= ps.compute_cycles);
    }

    #[test]
    fn low_bandwidth_stalls() {
        let (mut cfg, _) = setup(PeType::Fp32);
        cfg.bandwidth_gbps = 0.05; // starved
        let ep = energy_params(&cfg);
        let l = Layer::conv("c", 64, 64, 56, 56, 1, 1, 0); // traffic heavy, compute light
        let p = map_layer(&cfg, &ep, &l);
        assert!(p.stall_cycles > 0, "expected stalls at 0.05 GB/s");
        cfg.bandwidth_gbps = 50.0;
        let ep2 = energy_params(&cfg);
        let p2 = map_layer(&cfg, &ep2, &l);
        assert!(p2.stall_cycles < p.stall_cycles);
    }

    #[test]
    fn lower_precision_moves_fewer_bytes() {
        let (cfg16, ep16) = setup(PeType::Int16);
        let (cfg8, ep8) = setup(PeType::LightPe1);
        let mut cfg16 = cfg16;
        let mut cfg8 = cfg8;
        cfg16.bandwidth_gbps = 0.2;
        cfg8.bandwidth_gbps = 0.2;
        let l = Layer::conv("c", 128, 128, 28, 28, 1, 1, 0);
        let p16 = map_layer(&cfg16, &ep16, &l);
        let p8 = map_layer(&cfg8, &ep8, &l);
        // same compute shape, less traffic -> fewer stalls
        assert!(p8.stall_cycles <= p16.stall_cycles);
    }

    #[test]
    fn filter_spad_capacity_limits_stacking() {
        // Tiny filter spads prevent plane stacking -> more passes, worse
        // utilization; the narrow-weight LightPE stacks more planes into
        // the same bytes than INT16 (the quantization-aware effect).
        let l = Layer::conv("c", 64, 64, 28, 28, 3, 1, 1);
        let mut cfg16 = AcceleratorConfig::default_with(PeType::Int16);
        cfg16.spad_filter_b = 12; // 2 planes of 3x16b
        let ep16 = energy_params(&cfg16);
        let tight = map_layer(&cfg16, &ep16, &l);
        cfg16.spad_filter_b = 448;
        let ep16b = energy_params(&cfg16);
        let roomy = map_layer(&cfg16, &ep16b, &l);
        assert!(tight.passes > roomy.passes, "{} <= {}", tight.passes, roomy.passes);
        assert!(tight.utilization < roomy.utilization);

        let mut cfg4 = AcceleratorConfig::default_with(PeType::LightPe1);
        cfg4.spad_filter_b = 12; // same bytes, 4b weights -> 8 planes
        let ep4 = energy_params(&cfg4);
        let light = map_layer(&cfg4, &ep4, &l);
        assert!(light.passes < tight.passes);
    }

    #[test]
    fn depthwise_costed_at_grouped_not_dense_rate() {
        // A depthwise layer must schedule c plane passes, not c*c: same
        // spatial shape as the dense layer but 1/c the MACs, so compute
        // cycles and passes must both shrink.
        let (cfg, ep) = setup(PeType::Int16);
        let dense = Layer::conv("d", 64, 64, 28, 28, 3, 1, 1);
        let dw = Layer::dw("dw", 64, 28, 3, 1, 1);
        assert_eq!(dw.macs() * 64, dense.macs());
        let pd = map_layer(&cfg, &ep, &dense);
        let pdw = map_layer(&cfg, &ep, &dw);
        assert!(pdw.passes < pd.passes, "dw {} >= dense {}", pdw.passes, pd.passes);
        assert!(
            pdw.compute_cycles < pd.compute_cycles,
            "dw {} >= dense {}",
            pdw.compute_cycles,
            pd.compute_cycles
        );
        // Work conservation still holds for the grouped layer.
        let capacity = pdw.cycles as f64 * cfg.num_pes() as f64;
        assert!(capacity >= dw.macs() as f64);
    }

    #[test]
    fn grouped_conv_fewer_cycles_than_dense() {
        let (cfg, ep) = setup(PeType::Int16);
        let dense = Layer::conv("d", 128, 128, 14, 14, 3, 1, 1);
        let grp = Layer::grouped("g", 128, 128, 14, 3, 1, 1, 8);
        let pd = map_layer(&cfg, &ep, &dense);
        let pg = map_layer(&cfg, &ep, &grp);
        assert!(pg.compute_cycles < pd.compute_cycles);
        assert!(pg.utilization > 0.0 && pg.utilization <= 1.0);
    }

    #[test]
    fn matmul_mapping_tiles_like_weight_stationary() {
        let (cfg, ep) = setup(PeType::Int16);
        let l = Layer::matmul("mm", 128, 512, 512);
        let p = map_layer(&cfg, &ep, &l);
        // passes = ceil(k/rows)*ceil(n/cols), independent of m
        let expect = (512u64.div_ceil(cfg.pe_rows as u64))
            * (512u64.div_ceil(cfg.pe_cols as u64));
        assert_eq!(p.passes, expect);
        // work conservation carries the streamed m rows into cycles
        let capacity = p.cycles as f64 * cfg.num_pes() as f64;
        assert!(capacity >= l.macs() as f64);
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        // a decode-shaped matmul (m = 1) does strictly less compute
        let d = map_layer(&cfg, &ep, &Layer::matmul("mm1", 1, 512, 512));
        assert!(d.compute_cycles < p.compute_cycles);
    }

    #[test]
    fn attention_decode_is_bandwidth_bound_prefill_compute_bound() {
        let (cfg, ep) = setup(PeType::Int16);
        let prefill = Layer::attention("a", 16, 64, 1024, 1024);
        let decode = Layer::attention("a", 16, 64, 1, 1024);
        let pp = map_layer(&cfg, &ep, &prefill);
        let pd = map_layer(&cfg, &ep, &decode);
        for (l, p) in [(&prefill, &pp), (&decode, &pd)] {
            let capacity = p.cycles as f64 * cfg.num_pes() as f64;
            assert!(capacity >= l.macs() as f64, "{}", l.name);
            assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        }
        // One query against the full KV cache: the same compulsory KV
        // bytes buy 1/seq the MACs, so decode stalls on memory while
        // prefill does not (at the default bandwidth).
        assert!(pd.stall_cycles > 0, "decode should be bandwidth-bound");
        assert!(
            pp.stall_cycles == 0,
            "prefill should be compute-bound, got {} stall cycles",
            pp.stall_cycles
        );
    }

    #[test]
    fn fc_mapping_tiles() {
        let (cfg, ep) = setup(PeType::Int16);
        let l = Layer::fc("fc", 512, 512);
        let p = map_layer(&cfg, &ep, &l);
        // passes = ceil(512/rows)*ceil(512/cols)
        let expect = (512u64.div_ceil(cfg.pe_rows as u64))
            * (512u64.div_ceil(cfg.pe_cols as u64));
        assert_eq!(p.passes, expect);
    }
}
