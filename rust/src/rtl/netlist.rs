//! Gate-level netlist builder.
//!
//! Nets are created in topological order (every gate only references
//! already-built nets), so simulation is a single forward sweep and
//! elaboration doubles as a cycle-free proof.  The arithmetic generators
//! mirror `synth::mac`'s structural recipes; a cross-check test asserts the
//! gate counts agree with the cell counts the oracle prices.

use crate::synth::gates::GateCounts;

pub type NetId = u32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateKind {
    /// Primary input (value injected by the simulator).
    Input,
    Const0,
    Const1,
    Not(NetId),
    And(NetId, NetId),
    Or(NetId, NetId),
    Xor(NetId, NetId),
    Nand(NetId, NetId),
    Nor(NetId, NetId),
    /// Mux(sel, a, b) = sel ? b : a.
    Mux(NetId, NetId, NetId),
}

#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub gates: Vec<GateKind>,
    pub inputs: Vec<NetId>,
    pub outputs: Vec<(String, Vec<NetId>)>,
}

/// A little-endian bus of nets (bit 0 first).
pub type Bus = Vec<NetId>;

impl Netlist {
    pub fn new() -> Netlist {
        Netlist::default()
    }

    fn push(&mut self, g: GateKind) -> NetId {
        let id = self.gates.len() as NetId;
        if let Some(&n) = [match g {
            GateKind::Not(a) => a,
            GateKind::And(a, _)
            | GateKind::Or(a, _)
            | GateKind::Xor(a, _)
            | GateKind::Nand(a, _)
            | GateKind::Nor(a, _) => a,
            GateKind::Mux(s, _, _) => s,
            _ => 0,
        }]
        .iter()
        .max()
        {
            debug_assert!(
                matches!(g, GateKind::Input | GateKind::Const0 | GateKind::Const1)
                    || n < id,
                "netlist must be topological"
            );
        }
        self.gates.push(g);
        id
    }

    // ------------------------------------------------------------ primitives

    pub fn input(&mut self) -> NetId {
        let id = self.push(GateKind::Input);
        self.inputs.push(id);
        id
    }

    pub fn input_bus(&mut self, width: u32) -> Bus {
        (0..width).map(|_| self.input()).collect()
    }

    pub fn zero(&mut self) -> NetId {
        self.push(GateKind::Const0)
    }

    pub fn one(&mut self) -> NetId {
        self.push(GateKind::Const1)
    }

    pub fn not(&mut self, a: NetId) -> NetId {
        self.push(GateKind::Not(a))
    }

    pub fn and(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::And(a, b))
    }

    pub fn or(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Or(a, b))
    }

    pub fn xor(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Xor(a, b))
    }

    pub fn mux(&mut self, sel: NetId, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Mux(sel, a, b))
    }

    pub fn mark_output(&mut self, name: &str, bus: &Bus) {
        self.outputs.push((name.to_string(), bus.clone()));
    }

    // ------------------------------------------------------------ arithmetic

    /// Full adder; returns (sum, carry).
    pub fn full_adder(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        let axb = self.xor(a, b);
        let sum = self.xor(axb, c);
        let t1 = self.and(axb, c);
        let t2 = self.and(a, b);
        let carry = self.or(t1, t2);
        (sum, carry)
    }

    /// Ripple-carry adder over equal-width buses; returns (sum, carry_out).
    pub fn adder_c(&mut self, a: &Bus, b: &Bus, carry_in: Option<NetId>) -> (Bus, NetId) {
        assert_eq!(a.len(), b.len(), "adder width mismatch");
        let mut carry = carry_in.unwrap_or_else(|| self.zero());
        let mut sum = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let (s, c) = self.full_adder(a[i], b[i], carry);
            sum.push(s);
            carry = c;
        }
        (sum, carry)
    }

    /// Ripple-carry adder, wrap-around (two's-complement modular sum).
    pub fn adder(&mut self, a: &Bus, b: &Bus, carry_in: Option<NetId>) -> Bus {
        self.adder_c(a, b, carry_in).0
    }

    /// Two's-complement negate.
    pub fn negate(&mut self, a: &Bus) -> Bus {
        let inv: Bus = a.iter().map(|&n| self.not(n)).collect();
        let zero = self.zero();
        let zeros: Bus = (0..a.len()).map(|_| zero).collect();
        let one = self.one();
        self.adder(&inv, &zeros, Some(one))
    }

    /// Conditional negate: `neg ? -a : a`.
    pub fn cond_negate(&mut self, a: &Bus, neg: NetId) -> Bus {
        let negated = self.negate(a);
        a.iter()
            .zip(&negated)
            .map(|(&orig, &n)| self.mux(neg, orig, n))
            .collect()
    }

    /// Zero-extend a bus to `width`.
    pub fn zext(&mut self, a: &Bus, width: u32) -> Bus {
        let mut out = a.clone();
        let z = self.zero();
        while (out.len() as u32) < width {
            out.push(z);
        }
        out
    }

    /// Logical left barrel shifter: shift `a` by the binary amount in
    /// `shamt` (little-endian select bus). Width preserved (bits shift out).
    pub fn barrel_shift_left(&mut self, a: &Bus, shamt: &Bus) -> Bus {
        let mut cur = a.clone();
        let zero = self.zero();
        for (stage, &sel) in shamt.iter().enumerate() {
            let dist = 1usize << stage;
            let mut next = Vec::with_capacity(cur.len());
            for i in 0..cur.len() {
                let shifted = if i >= dist { cur[i - dist] } else { zero };
                next.push(self.mux(sel, cur[i], shifted));
            }
            cur = next;
        }
        cur
    }

    /// Unsigned array multiplier: m x n -> m + n bits.
    ///
    /// Classic array structure: each row adds its partial products into the
    /// running accumulator shifted one position — m FAs per row, ~m*n total
    /// (the same structure `synth::mac::array_multiplier` prices).
    pub fn multiplier(&mut self, a: &Bus, b: &Bus) -> Bus {
        let (m, n) = (a.len(), b.len());
        let zero = self.zero();
        // row 0 partial products seed the accumulator
        let mut acc: Bus = (0..m).map(|i| self.and(a[i], b[0])).collect();
        let mut carry_prev = zero;
        let mut out: Bus = vec![acc[0]];
        for j in 1..n {
            let row: Bus = (0..m).map(|i| self.and(a[i], b[j])).collect();
            // add row to (acc >> 1 with previous carry as MSB); the low
            // bit of acc is already a final product bit
            let mut hi: Bus = acc[1..].to_vec();
            hi.push(carry_prev);
            let (sum, c) = self.adder_c(&hi, &row, None);
            acc = sum;
            carry_prev = c;
            out.push(acc[0]);
        }
        out.extend_from_slice(&acc[1..]);
        out.push(carry_prev);
        debug_assert_eq!(out.len(), m + n);
        out
    }

    /// Cell-count view compatible with the synthesis library.
    pub fn gate_counts(&self) -> GateCounts {
        let mut c = GateCounts::default();
        for g in &self.gates {
            match g {
                GateKind::Not(_) => c.inv += 1,
                GateKind::And(..) => c.and2 += 1,
                GateKind::Or(..) => c.or2 += 1,
                GateKind::Xor(..) => c.xor2 += 1,
                GateKind::Nand(..) => c.nand2 += 1,
                GateKind::Nor(..) => c.nor2 += 1,
                GateKind::Mux(..) => c.mux2 += 1,
                GateKind::Input | GateKind::Const0 | GateKind::Const1 => {}
            }
        }
        c
    }

    pub fn num_gates(&self) -> usize {
        self.gates
            .iter()
            .filter(|g| {
                !matches!(g, GateKind::Input | GateKind::Const0 | GateKind::Const1)
            })
            .count()
    }
}

// ---------------------------------------------------------------------------
// Ready-made datapaths (the verification targets)
// ---------------------------------------------------------------------------

/// INT16 multiplier core: 16x16 unsigned -> 32-bit product.
pub fn int16_multiplier() -> Netlist {
    let mut nl = Netlist::new();
    let a = nl.input_bus(16);
    let b = nl.input_bus(16);
    let p = nl.multiplier(&a, &b);
    nl.mark_output("product", &p);
    nl
}

/// LightPE shift-add term: out = acc + (sign ? -(act << shamt) : act << shamt)
/// over `acc_w`-bit two's-complement arithmetic.
/// Inputs (in order): act[8], shamt[3], sign, acc[acc_w].
pub fn light_term(acc_w: u32) -> Netlist {
    let mut nl = Netlist::new();
    let act = nl.input_bus(8);
    let shamt = nl.input_bus(3);
    let sign = nl.input();
    let acc = nl.input_bus(acc_w);
    let wide = nl.zext(&act, acc_w);
    let shifted = nl.barrel_shift_left(&wide, &shamt);
    let signed = nl.cond_negate(&shifted, sign);
    let sum = nl.adder(&acc, &signed, None);
    nl.mark_output("acc_next", &sum);
    nl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topological_by_construction() {
        let nl = int16_multiplier();
        for (id, g) in nl.gates.iter().enumerate() {
            let ok = match *g {
                GateKind::Not(a) => (a as usize) < id,
                GateKind::And(a, b)
                | GateKind::Or(a, b)
                | GateKind::Xor(a, b)
                | GateKind::Nand(a, b)
                | GateKind::Nor(a, b) => (a as usize) < id && (b as usize) < id,
                GateKind::Mux(s, a, b) => {
                    (s as usize) < id && (a as usize) < id && (b as usize) < id
                }
                _ => true,
            };
            assert!(ok, "gate {id} references later net");
        }
    }

    #[test]
    fn multiplier_gate_count_tracks_synth_model() {
        // synth::mac prices an m x n multiplier at ~m*n ANDs + ~m*n FAs;
        // the netlist decomposes each FA into 5 gates. Require agreement
        // within 35% (edge effects differ).
        let nl = int16_multiplier();
        let counts = nl.gate_counts();
        let lib = crate::synth::gates::GateLib::freepdk45();
        let synth = crate::synth::mac::array_multiplier(&lib, 16, 16);
        let synth_flat = synth.counts.and2 as f64
            + synth.counts.inv as f64
            + 5.0 * synth.counts.fa as f64
            + 3.0 * synth.counts.ha as f64;
        let netlist_flat = counts.total() as f64;
        let ratio = netlist_flat / synth_flat;
        assert!((0.65..1.35).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn light_term_is_small() {
        // The whole LightPE shift-add term must be far smaller than the
        // INT16 multiplier — the paper's core hardware claim.
        let mult = int16_multiplier().num_gates();
        let light = light_term(20).num_gates();
        assert!(light * 3 < mult, "light {light} vs mult {mult}");
    }

    #[test]
    fn io_bookkeeping() {
        let nl = light_term(20);
        assert_eq!(nl.inputs.len(), 8 + 3 + 1 + 20);
        assert_eq!(nl.outputs.len(), 1);
        assert_eq!(nl.outputs[0].1.len(), 20);
    }
}
