//! RTL generation + functional verification — the stand-in for the paper's
//! "automatically generated RTL" and its Synopsys VCS verification flow.
//!
//! * [`netlist`] elaborates the same structural recipes the synthesis
//!   oracle prices (`synth::mac`) into real gate-level netlists;
//! * [`sim`] is a levelized gate simulator that verifies the netlists
//!   against arithmetic golden models and measures toggle activity (the
//!   activity factors the power model assumes);
//! * [`verilog`] emits synthesizable Verilog: structural gate netlists for
//!   the MAC cores plus behavioral PE/array wrappers.

pub mod netlist;
pub mod sim;
pub mod verilog;
