//! Levelized gate-level simulator with toggle counting.
//!
//! Because netlists are topological by construction, evaluation is one
//! forward sweep.  `Simulator` keeps the previous net values and counts
//! toggles, yielding the switching-activity factors the power model
//! assumes (`synth::mac` activity constants) — the same loop the paper
//! closes with VCS + SAIF.

use crate::api::error::QappaError;
use crate::rtl::netlist::{GateKind, Netlist};
use crate::util::prng::Rng;

pub struct Simulator<'a> {
    nl: &'a Netlist,
    values: Vec<bool>,
    prev: Option<Vec<bool>>,
    toggles: u64,
    evals: u64,
}

impl<'a> Simulator<'a> {
    pub fn new(nl: &'a Netlist) -> Simulator<'a> {
        Simulator {
            nl,
            values: vec![false; nl.gates.len()],
            prev: None,
            toggles: 0,
            evals: 0,
        }
    }

    /// Evaluate the netlist for one input assignment (bits per primary
    /// input, in `nl.inputs` order).
    pub fn eval(&mut self, input_bits: &[bool]) {
        assert_eq!(input_bits.len(), self.nl.inputs.len(), "input width");
        let mut it = input_bits.iter();
        for (id, gate) in self.nl.gates.iter().enumerate() {
            let v = match *gate {
                GateKind::Input => *it.next().unwrap(),
                GateKind::Const0 => false,
                GateKind::Const1 => true,
                GateKind::Not(a) => !self.values[a as usize],
                GateKind::And(a, b) => self.values[a as usize] & self.values[b as usize],
                GateKind::Or(a, b) => self.values[a as usize] | self.values[b as usize],
                GateKind::Xor(a, b) => self.values[a as usize] ^ self.values[b as usize],
                GateKind::Nand(a, b) => !(self.values[a as usize] & self.values[b as usize]),
                GateKind::Nor(a, b) => !(self.values[a as usize] | self.values[b as usize]),
                GateKind::Mux(s, a, b) => {
                    if self.values[s as usize] {
                        self.values[b as usize]
                    } else {
                        self.values[a as usize]
                    }
                }
            };
            self.values[id] = v;
        }
        if let Some(prev) = &self.prev {
            self.toggles += prev
                .iter()
                .zip(&self.values)
                .filter(|(p, v)| p != v)
                .count() as u64;
        }
        self.prev = Some(self.values.clone());
        self.evals += 1;
    }

    /// Read an output bus as u64 (little-endian; bus must be <= 64 bits).
    pub fn output_u64(&self, name: &str) -> u64 {
        let (_, bus) = self
            .nl
            .outputs
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("no output {name}"));
        assert!(bus.len() <= 64);
        bus.iter()
            .enumerate()
            .fold(0u64, |acc, (i, &net)| acc | ((self.values[net as usize] as u64) << i))
    }

    /// Average per-gate toggle rate across all eval pairs.
    pub fn activity(&self) -> f64 {
        let gates = self.nl.num_gates().max(1) as u64;
        let pairs = self.evals.saturating_sub(1).max(1);
        self.toggles as f64 / (gates * pairs) as f64
    }
}

/// Pack a u64 into a little-endian bit vector of `width` bits.
pub fn to_bits(value: u64, width: u32) -> Vec<bool> {
    (0..width).map(|i| (value >> i) & 1 == 1).collect()
}

/// Run `n` random vectors through the INT16 multiplier netlist and verify
/// against host arithmetic; returns measured activity.
pub fn verify_int16_multiplier(n: usize, seed: u64) -> Result<f64, QappaError> {
    let nl = crate::rtl::netlist::int16_multiplier();
    let mut sim = Simulator::new(&nl);
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let a = (rng.next_u64() & 0xffff) as u64;
        let b = (rng.next_u64() & 0xffff) as u64;
        let mut bits = to_bits(a, 16);
        bits.extend(to_bits(b, 16));
        sim.eval(&bits);
        let got = sim.output_u64("product");
        let want = a * b;
        if got != want {
            return Err(QappaError::Model(format!(
                "vector {i}: {a} * {b} = {want}, netlist says {got}"
            )));
        }
    }
    Ok(sim.activity())
}

/// Verify the LightPE shift-add term netlist against host arithmetic.
pub fn verify_light_term(acc_w: u32, n: usize, seed: u64) -> Result<f64, QappaError> {
    let nl = crate::rtl::netlist::light_term(acc_w);
    let mut sim = Simulator::new(&nl);
    let mut rng = Rng::new(seed);
    let mask: u64 = (1u64 << acc_w) - 1;
    for i in 0..n {
        let act = rng.next_u64() & 0xff;
        let shamt = rng.next_u64() & 0x7;
        let sign = rng.next_u64() & 1;
        let acc = rng.next_u64() & mask;
        let mut bits = to_bits(act, 8);
        bits.extend(to_bits(shamt, 3));
        bits.push(sign == 1);
        bits.extend(to_bits(acc, acc_w));
        sim.eval(&bits);
        let got = sim.output_u64("acc_next");
        let term = (act << shamt) & mask;
        let want = if sign == 1 {
            acc.wrapping_sub(term) & mask
        } else {
            acc.wrapping_add(term) & mask
        };
        if got != want {
            return Err(QappaError::Model(format!(
                "vector {i}: acc={acc} act={act} shamt={shamt} sign={sign}: want {want}, got {got}"
            )));
        }
    }
    Ok(sim.activity())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::netlist::Netlist;

    #[test]
    fn primitive_gates_evaluate() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let and = nl.and(a, b);
        let xor = nl.xor(a, b);
        let not = nl.not(a);
        nl.mark_output("o", &vec![and, xor, not]);
        let mut sim = Simulator::new(&nl);
        sim.eval(&[true, false]);
        // little-endian: bit0 = and = 0, bit1 = xor = 1, bit2 = not(a) = 0
        assert_eq!(sim.output_u64("o"), 0b010);
    }

    #[test]
    fn adder_exhaustive_4bit() {
        let mut nl = Netlist::new();
        let a = nl.input_bus(4);
        let b = nl.input_bus(4);
        let s = nl.adder(&a, &b, None);
        nl.mark_output("sum", &s);
        let mut sim = Simulator::new(&nl);
        for x in 0u64..16 {
            for y in 0u64..16 {
                let mut bits = to_bits(x, 4);
                bits.extend(to_bits(y, 4));
                sim.eval(&bits);
                assert_eq!(sim.output_u64("sum"), (x + y) & 0xf, "{x}+{y}");
            }
        }
    }

    #[test]
    fn negate_exhaustive_5bit() {
        let mut nl = Netlist::new();
        let a = nl.input_bus(5);
        let n = nl.negate(&a);
        nl.mark_output("neg", &n);
        let mut sim = Simulator::new(&nl);
        for x in 0u64..32 {
            sim.eval(&to_bits(x, 5));
            assert_eq!(sim.output_u64("neg"), x.wrapping_neg() & 0x1f, "x={x}");
        }
    }

    #[test]
    fn barrel_shifter_exhaustive_8bit() {
        let mut nl = Netlist::new();
        let a = nl.input_bus(8);
        let sh = nl.input_bus(3);
        let out = nl.barrel_shift_left(&a, &sh);
        nl.mark_output("out", &out);
        let mut sim = Simulator::new(&nl);
        for x in [0u64, 1, 0x80, 0xff, 0xa5] {
            for s in 0u64..8 {
                let mut bits = to_bits(x, 8);
                bits.extend(to_bits(s, 3));
                sim.eval(&bits);
                assert_eq!(sim.output_u64("out"), (x << s) & 0xff, "{x} << {s}");
            }
        }
    }

    #[test]
    fn multiplier_small_exhaustive() {
        let mut nl = Netlist::new();
        let a = nl.input_bus(4);
        let b = nl.input_bus(4);
        let p = nl.multiplier(&a, &b);
        nl.mark_output("p", &p);
        let mut sim = Simulator::new(&nl);
        for x in 0u64..16 {
            for y in 0u64..16 {
                let mut bits = to_bits(x, 4);
                bits.extend(to_bits(y, 4));
                sim.eval(&bits);
                assert_eq!(sim.output_u64("p"), x * y, "{x}*{y}");
            }
        }
    }

    #[test]
    fn int16_multiplier_verifies() {
        let act = verify_int16_multiplier(200, 42).expect("int16 multiplier");
        assert!(act > 0.05 && act < 0.9, "activity {act}");
    }

    #[test]
    fn light_term_verifies() {
        for w in [16u32, 20, 24] {
            let act = verify_light_term(w, 200, 7).expect("light term");
            assert!(act > 0.02 && act < 0.9, "activity {act}");
        }
    }

    #[test]
    fn measured_activity_matches_power_model_assumptions() {
        // The synthesis power model assumes ~0.28 for multiplier-centric
        // datapaths and ~0.18 for shift-add; the measured toggle rates
        // must be in the same regime (within 2.5x).
        let mult = verify_int16_multiplier(500, 1).unwrap();
        assert!((0.28 / mult - 1.0).abs() < 1.5, "int16 activity {mult}");
        let light = verify_light_term(20, 500, 2).unwrap();
        assert!((0.18 / light - 1.0).abs() < 1.5, "light activity {light}");
    }
}
