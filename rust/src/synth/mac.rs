//! Gate-level MAC datapath generators, parameterized by [`QuantSpec`].
//!
//! Each generator composes the standard-cell library into the arithmetic
//! structure the paper's RTL generator would emit, returning gate counts and
//! the combinational critical path.  The datapaths are sized entirely from
//! the quantization spec — multiplier dimensions from the operand widths,
//! accumulators and shifters from the psum width, FP mantissa/exponent
//! split from the format width — so *any* `a<act>w<wt>p<psum>-<mac>`
//! precision synthesizes, not just the four presets.  The LightNN shift-add
//! datapaths (Ding et al. 2018) encode the weight as `n` signed powers of
//! two, so the multiplier collapses into `n` barrel shifts (+ carry-save
//! merges for the extra terms).
//!
//! For the preset specs the generic builders emit exactly the gate
//! structure (and therefore bit-identical PPA) of the historical
//! hand-written FP32/INT16/LightPE generators — pinned by
//! `tests/golden_presets.rs`.
//!
//! The same structural recipes are elaborated into real gate netlists by
//! `crate::rtl::netlist`; a cross-check test there asserts the counts agree.

use crate::config::{MacKind, PeType, QuantSpec};
use crate::synth::gates::{GateCounts, GateLib};

/// A synthesized combinational/pipelined block.
#[derive(Debug, Clone, Copy)]
pub struct Block {
    pub counts: GateCounts,
    /// Combinational critical path before pipelining, ps.
    pub crit_path_ps: f64,
}

impl Block {
    pub fn empty() -> Block {
        Block { counts: GateCounts::default(), crit_path_ps: 0.0 }
    }

    /// Series composition: counts add, critical paths add.
    pub fn then(mut self, other: &Block) -> Block {
        self.counts.add(&other.counts);
        self.crit_path_ps += other.crit_path_ps;
        self
    }

    /// Parallel composition: counts add, critical path is the max.
    pub fn beside(mut self, other: &Block) -> Block {
        self.counts.add(&other.counts);
        self.crit_path_ps = self.crit_path_ps.max(other.crit_path_ps);
        self
    }
}

/// n-bit ripple-carry adder.
pub fn ripple_adder(lib: &GateLib, n: u32) -> Block {
    Block {
        counts: GateCounts { fa: n as u64, ..Default::default() },
        crit_path_ps: n as f64 * lib.fa.delay_ps,
    }
}

/// n-bit carry-lookahead adder (4-bit groups, two lookahead levels).
pub fn cla_adder(lib: &GateLib, n: u32) -> Block {
    let groups = n.div_ceil(4) as u64;
    let counts = GateCounts {
        fa: n as u64,
        // generate/propagate + group lookahead logic
        and2: 3 * n as u64,
        or2: 2 * n as u64,
        nand2: 4 * groups,
        ..Default::default()
    };
    // log-depth carry tree: one FA stage + lookahead levels
    let levels = (n as f64).log2().ceil().max(1.0);
    Block {
        counts,
        crit_path_ps: lib.fa.delay_ps + levels * (lib.and2.delay_ps + lib.or2.delay_ps),
    }
}

/// m x n signed array multiplier (Baugh-Wooley).
pub fn array_multiplier(lib: &GateLib, m: u32, n: u32) -> Block {
    let (m, n) = (m as u64, n as u64);
    let counts = GateCounts {
        and2: m * n,                   // partial products
        fa: (m - 1) * n,               // carry-save reduction rows
        ha: m + n,                     // row edges
        inv: m + n,                    // Baugh-Wooley sign complements
        ..Default::default()
    };
    Block {
        counts,
        // diagonal through the carry-save array plus the final row
        crit_path_ps: lib.and2.delay_ps + (m + n - 2) as f64 * lib.fa.delay_ps,
    }
}

/// w-bit barrel shifter with `stages` mux levels (shift range 2^stages).
pub fn barrel_shifter(lib: &GateLib, w: u32, stages: u32) -> Block {
    Block {
        counts: GateCounts {
            mux2: (w * stages) as u64,
            ..Default::default()
        },
        crit_path_ps: stages as f64 * lib.mux2.delay_ps,
    }
}

/// Conditional two's-complement negate (xor mask + carry-in absorbed by the
/// downstream adder).
pub fn cond_negate(lib: &GateLib, w: u32) -> Block {
    Block {
        counts: GateCounts { xor2: w as u64, ..Default::default() },
        crit_path_ps: lib.xor2.delay_ps,
    }
}

/// Leading-zero counter for FP normalization (w-bit).
pub fn leading_zero_count(lib: &GateLib, w: u32) -> Block {
    let levels = (w as f64).log2().ceil() as u64;
    Block {
        counts: GateCounts {
            nor2: w as u64,
            mux2: w as u64,
            or2: (w as u64) / 2 * levels,
            ..Default::default()
        },
        crit_path_ps: levels as f64 * (lib.or2.delay_ps + lib.mux2.delay_ps),
    }
}

/// A complete pipelined MAC unit.
#[derive(Debug, Clone, Copy)]
pub struct MacUnit {
    pub pe_type: PeType,
    pub counts: GateCounts,
    pub crit_path_ps: f64,
    pub pipeline_stages: u32,
    /// Average datapath node activity per MAC (structure-dependent;
    /// cross-checked against the rtl toggle simulator).
    pub activity: f64,
}

/// Pipeline-stage timing target (ps). One MAC issues per cycle; deeper
/// datapaths get more stages instead of a slower clock.
const STAGE_TARGET_PS: f64 = 900.0;
/// Clock overhead per stage: DFF clk->q + setup + margin (ps).
const CLK_OVERHEAD_PS: f64 = 150.0;

impl MacUnit {
    /// Achievable clock, MHz (1e6 ps per µs).
    ///
    /// Deeper pipelines do not cut the stage time perfectly: register
    /// placement imbalance adds ~6% per extra stage, and clock skew /
    /// margin accumulates with depth — so a 5-stage FP32 pipe cannot
    /// out-clock a 2-stage INT16 pipe just by rounding.
    pub fn fmax_mhz(&self) -> f64 {
        let stages = self.pipeline_stages as f64;
        let imbalance = 1.0 + 0.06 * (stages - 1.0);
        let overhead = CLK_OVERHEAD_PS + 14.0 * stages;
        let stage = self.crit_path_ps / stages * imbalance + overhead;
        1.0e6 / stage
    }

    pub fn area_um2(&self, lib: &GateLib) -> f64 {
        lib.area_um2(&self.counts)
    }

    /// Dynamic energy per MAC operation, fJ.
    pub fn energy_per_mac_fj(&self, lib: &GateLib) -> f64 {
        lib.energy_per_op_fj(&self.counts, self.activity)
    }

    pub fn leakage_nw(&self, lib: &GateLib) -> f64 {
        lib.leakage_nw(&self.counts)
    }
}

fn pipelined(pe_type: PeType, datapath: Block, out_width: u32, activity: f64) -> MacUnit {
    let stages = (datapath.crit_path_ps / STAGE_TARGET_PS).ceil().max(1.0) as u32;
    let mut counts = datapath.counts;
    // Pipeline registers: roughly 1.5x the output width per internal cut,
    // plus the architectural output register.
    let regs = out_width as u64 * 3 / 2 * (stages as u64 - 1) + out_width as u64;
    counts.dff += regs;
    MacUnit {
        pe_type,
        counts,
        crit_path_ps: datapath.crit_path_ps,
        pipeline_stages: stages,
        activity,
    }
}

/// Build the MAC unit for a precision selector (preset or arbitrary spec).
pub fn mac_unit(lib: &GateLib, pe_type: PeType) -> MacUnit {
    mac_unit_spec(lib, pe_type, pe_type.spec())
}

/// Build the MAC unit directly from a quantization spec.
pub fn mac_unit_spec(lib: &GateLib, pe_type: PeType, q: QuantSpec) -> MacUnit {
    match q.mac {
        MacKind::Fp => fp_mac(lib, pe_type, q),
        MacKind::IntExact => int_mac(lib, pe_type, q),
        MacKind::Lightweight(_) => light_mac(lib, pe_type, q),
    }
}

/// ceil(log2(n)) for shifter/lookahead staging (n >= 1 -> >= 1 stage).
fn log2_stages(n: u32) -> u32 {
    let mut stages = 0u32;
    while (1u64 << stages) < n as u64 {
        stages += 1;
    }
    stages.max(1)
}

/// Floating-point fused multiply-add, sized from the format width
/// (`max(act, wt)`): IEEE-style exponent split, mantissa multiplier with
/// hidden bit, double-width align/normalize shifters.  At `a32w32p32-fp`
/// this is exactly the historical FP32 FMA datapath.
fn fp_mac(lib: &GateLib, pe_type: PeType, q: QuantSpec) -> MacUnit {
    let w = q.act_bits.max(q.wt_bits);
    // IEEE-style exponent widths: 5 (half) / 8 (single) / 11 (double).
    let exp = if w <= 16 {
        5
    } else if w <= 32 {
        8
    } else {
        11
    };
    // Mantissa including the hidden bit (w=32 -> 24).  The exponent field
    // widens in steps at the format boundaries, so the raw `w - exp` dips
    // there; flooring at the previous format's mantissa keeps datapath
    // cost monotone in the operand width (pinned by the precision
    // property tests) without moving any of the standard formats.
    let mant_at = |w: u32, exp: u32| (w - w.min(exp)).max(2);
    let mant = if w <= 16 {
        mant_at(w, 5)
    } else if w <= 32 {
        mant_at(w, 8).max(mant_at(16, 5))
    } else {
        mant_at(w, 11).max(mant_at(32, 8))
    };
    let wide = 2 * mant; // product / alignment width
    let mant_mult = array_multiplier(lib, mant, mant);
    let exp_add = ripple_adder(lib, exp);
    let align = barrel_shifter(lib, wide, log2_stages(wide));
    let mant_add = cla_adder(lib, wide);
    let lzc = leading_zero_count(lib, wide);
    let norm = barrel_shifter(lib, wide, log2_stages(wide));
    let round = ripple_adder(lib, mant / 2);
    // Exception/sign/flag logic.
    let misc = Block {
        counts: GateCounts { nand2: 220, inv: 90, or2: 60, ..Default::default() },
        crit_path_ps: 2.0 * lib.nand2.delay_ps,
    };
    let datapath = mant_mult
        .beside(&exp_add) // exponent path runs in parallel with the multiply
        .then(&align)
        .then(&mant_add)
        .then(&lzc)
        .then(&norm)
        .then(&round)
        .then(&misc);
    // Multiplier arrays toggle heavily; FP datapath average ~0.25.
    pipelined(pe_type, datapath, q.psum_bits, 0.25)
}

/// Exact integer MAC: `act x wt` Baugh-Wooley array multiplier feeding a
/// psum-wide carry-lookahead accumulator (INT16 = `a16w16p32-int`).
fn int_mac(lib: &GateLib, pe_type: PeType, q: QuantSpec) -> MacUnit {
    let mult = array_multiplier(lib, q.act_bits, q.wt_bits);
    let acc = cla_adder(lib, q.psum_bits);
    let datapath = mult.then(&acc);
    pipelined(pe_type, datapath, q.psum_bits, 0.28)
}

/// LightNN shift-add MAC: the weight is encoded as `shift_terms` signed
/// powers of two; shift range covers the activation width, accumulator
/// width from the spec.
fn light_mac(lib: &GateLib, pe_type: PeType, q: QuantSpec) -> MacUnit {
    debug_assert!(q.is_light());
    let acc_w = q.psum_bits;
    let terms = q.shift_terms();
    // Barrel stages cover shifts 0..act_bits-1 (8b act -> 3 stages).
    let shift_stages = log2_stages(q.act_bits);
    // Weight decode: split the packed weight into per-term (sign, shift).
    let decode = Block {
        counts: GateCounts { nand2: 12, inv: 6, ..Default::default() },
        crit_path_ps: 2.0 * lib.nand2.delay_ps,
    };
    // One shifted term: barrel shift widened to the accumulator, then a
    // conditional negate for the sign.
    let term = barrel_shifter(lib, acc_w, shift_stages).then(&cond_negate(lib, acc_w));
    let mut datapath = decode.then(&term);
    for _ in 1..terms {
        // Extra terms are generated in parallel; each merges with the
        // running partial through a 3:2 carry-save stage (one FA row)
        // before the single carry-propagate accumulator below — so extra
        // terms cost area but almost no latency.
        let term_n = barrel_shifter(lib, acc_w, shift_stages).then(&cond_negate(lib, acc_w));
        let csa = Block {
            counts: GateCounts { fa: acc_w as u64, ..Default::default() },
            crit_path_ps: lib.fa.delay_ps,
        };
        datapath = datapath.beside(&term_n).then(&csa);
    }
    // Accumulate into the partial sum.
    let datapath = datapath.then(&cla_adder(lib, acc_w));
    // Shift networks toggle sparsely compared to multiplier arrays; with
    // more terms the extra shifters are gated off for the weights that
    // fewer powers of two already represent, lowering the average node
    // activity (LightPE-1 = 0.18, LightPE-2 = 0.15).
    let activity = match terms {
        1 => 0.18,
        2 => 0.15,
        n => (0.15 - 0.01 * (n as f64 - 2.0)).max(0.05),
    };
    pipelined(pe_type, datapath, acc_w, activity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PE_TYPES;

    fn lib() -> GateLib {
        GateLib::freepdk45()
    }

    #[test]
    fn adder_counts_and_paths() {
        let l = lib();
        let r8 = ripple_adder(&l, 8);
        let r32 = ripple_adder(&l, 32);
        assert_eq!(r8.counts.fa, 8);
        assert_eq!(r32.counts.fa, 32);
        assert!(r32.crit_path_ps > r8.crit_path_ps);
        let c32 = cla_adder(&l, 32);
        // CLA trades area for delay
        assert!(c32.counts.total() > r32.counts.total());
        assert!(c32.crit_path_ps < r32.crit_path_ps);
    }

    #[test]
    fn multiplier_scales_quadratically() {
        let l = lib();
        let m8 = array_multiplier(&l, 8, 8);
        let m16 = array_multiplier(&l, 16, 16);
        let ratio = m16.counts.total() as f64 / m8.counts.total() as f64;
        assert!((3.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn compose_then_beside() {
        let l = lib();
        let a = ripple_adder(&l, 8);
        let b = ripple_adder(&l, 16);
        let s = a.then(&b);
        assert_eq!(s.counts.fa, 24);
        assert!((s.crit_path_ps - (a.crit_path_ps + b.crit_path_ps)).abs() < 1e-9);
        let p = a.beside(&b);
        assert_eq!(p.counts.fa, 24);
        assert_eq!(p.crit_path_ps, b.crit_path_ps);
    }

    #[test]
    fn mac_area_ordering_matches_paper() {
        // Fig. 2: FP32 costliest, LightPEs cheapest (per PE).
        let l = lib();
        let area = |t| mac_unit(&l, t).area_um2(&l);
        assert!(area(PeType::Fp32) > 2.0 * area(PeType::Int16));
        assert!(area(PeType::Int16) > 2.0 * area(PeType::LightPe2));
        assert!(area(PeType::LightPe2) > area(PeType::LightPe1));
    }

    #[test]
    fn mac_energy_ordering_matches_paper() {
        let l = lib();
        let e = |t| mac_unit(&l, t).energy_per_mac_fj(&l);
        assert!(e(PeType::Fp32) > e(PeType::Int16));
        assert!(e(PeType::Int16) > 3.0 * e(PeType::LightPe2));
        assert!(e(PeType::LightPe2) > e(PeType::LightPe1));
    }

    #[test]
    fn mac_energy_in_horowitz_ballpark() {
        // 45nm: FP32 FMA ~4.6 pJ, INT16 MAC ~1 pJ (order of magnitude;
        // our activity-scaled average sits at the low end).
        let l = lib();
        let fp = mac_unit(&l, PeType::Fp32).energy_per_mac_fj(&l) / 1000.0;
        assert!((0.5..12.0).contains(&fp), "fp32 mac {fp} pJ");
        let i16 = mac_unit(&l, PeType::Int16).energy_per_mac_fj(&l) / 1000.0;
        assert!((0.2..3.0).contains(&i16), "int16 mac {i16} pJ");
        let lp1 = mac_unit(&l, PeType::LightPe1).energy_per_mac_fj(&l) / 1000.0;
        assert!((0.01..0.4).contains(&lp1), "lightpe1 mac {lp1} pJ");
    }

    #[test]
    fn lighter_datapaths_clock_no_slower() {
        let l = lib();
        let f = |t| mac_unit(&l, t).fmax_mhz();
        // Shift-add datapaths are shallow and clock fastest; FP32 and
        // INT16 may land close to each other because deeper pipelining
        // compensates for the longer FP path.
        assert!(f(PeType::LightPe1) > f(PeType::Int16));
        assert!(f(PeType::LightPe1) > f(PeType::Fp32));
        for t in ALL_PE_TYPES {
            let mhz = f(t);
            assert!((200.0..2500.0).contains(&mhz), "{t:?} fmax {mhz} MHz");
        }
    }

    #[test]
    fn pipeline_depth_reflects_path() {
        let l = lib();
        let fp = mac_unit(&l, PeType::Fp32);
        let lp = mac_unit(&l, PeType::LightPe1);
        assert!(fp.pipeline_stages > lp.pipeline_stages);
        assert!(lp.pipeline_stages >= 1);
    }

    #[test]
    fn preset_specs_reproduce_legacy_datapaths_exactly() {
        // The tentpole identity: building each preset through the generic
        // spec-driven path must give bit-identical gate counts, critical
        // paths, stages and activity to the historical hand-written
        // generators (reconstructed here from the public combinators).
        let l = lib();

        // legacy INT16: 16x16 multiplier + 32b CLA, out 32, activity 0.28
        let legacy_i16 = array_multiplier(&l, 16, 16).then(&cla_adder(&l, 32));
        let i16 = mac_unit(&l, PeType::Int16);
        assert_eq!(i16.crit_path_ps, legacy_i16.crit_path_ps);
        assert_eq!(i16.pipeline_stages, (legacy_i16.crit_path_ps / 900.0).ceil() as u32);
        let mut want = legacy_i16.counts;
        want.dff += 32 * 3 / 2 * (i16.pipeline_stages as u64 - 1) + 32;
        assert_eq!(i16.counts, want);
        assert_eq!(i16.activity, 0.28);

        // legacy FP32: 24x24 mantissa mult || 8b exp add, 48b align/add/
        // lzc/norm, 12b round, misc block
        let misc = Block {
            counts: GateCounts { nand2: 220, inv: 90, or2: 60, ..Default::default() },
            crit_path_ps: 2.0 * l.nand2.delay_ps,
        };
        let legacy_fp = array_multiplier(&l, 24, 24)
            .beside(&ripple_adder(&l, 8))
            .then(&barrel_shifter(&l, 48, 6))
            .then(&cla_adder(&l, 48))
            .then(&leading_zero_count(&l, 48))
            .then(&barrel_shifter(&l, 48, 6))
            .then(&ripple_adder(&l, 12))
            .then(&misc);
        let fp = mac_unit(&l, PeType::Fp32);
        assert_eq!(fp.crit_path_ps, legacy_fp.crit_path_ps);
        assert_eq!(fp.activity, 0.25);

        // legacy LightPE-1/2: decode + 3-stage barrel terms + CSA merge +
        // CLA accumulate at the preset accumulator width
        for (t, acc_w, terms, activity) in
            [(PeType::LightPe1, 20u32, 1u32, 0.18), (PeType::LightPe2, 24, 2, 0.15)]
        {
            let decode = Block {
                counts: GateCounts { nand2: 12, inv: 6, ..Default::default() },
                crit_path_ps: 2.0 * l.nand2.delay_ps,
            };
            let term = barrel_shifter(&l, acc_w, 3).then(&cond_negate(&l, acc_w));
            let mut legacy = decode.then(&term);
            if terms == 2 {
                let term2 = barrel_shifter(&l, acc_w, 3).then(&cond_negate(&l, acc_w));
                let csa = Block {
                    counts: GateCounts { fa: acc_w as u64, ..Default::default() },
                    crit_path_ps: l.fa.delay_ps,
                };
                legacy = legacy.beside(&term2).then(&csa);
            }
            let legacy = legacy.then(&cla_adder(&l, acc_w));
            let got = mac_unit(&l, t);
            assert_eq!(got.crit_path_ps, legacy.crit_path_ps, "{t:?}");
            assert_eq!(got.activity, activity, "{t:?}");
            let mut want = legacy.counts;
            want.dff += acc_w as u64 * 3 / 2 * (got.pipeline_stages as u64 - 1) + acc_w as u64;
            assert_eq!(got.counts, want, "{t:?}");
        }
    }

    #[test]
    fn arbitrary_precision_macs_synthesize_and_scale() {
        let l = lib();
        // 4-bit int MAC must be far cheaper than INT16
        let q4 = crate::config::QuantSpec::new(4, 4, 12, crate::config::MacKind::IntExact).unwrap();
        let m4 = mac_unit_spec(&l, PeType::from_spec(q4), q4);
        let m16 = mac_unit(&l, PeType::Int16);
        assert!(m4.area_um2(&l) < m16.area_um2(&l) / 3.0);
        assert!(m4.energy_per_mac_fj(&l) < m16.energy_per_mac_fj(&l) / 3.0);
        // a 3-term lightweight MAC costs more than the 2-term preset at the
        // same widths
        let q3 = crate::config::QuantSpec::new(8, 12, 24, crate::config::MacKind::Lightweight(3)).unwrap();
        let m3 = mac_unit_spec(&l, PeType::from_spec(q3), q3);
        let m2 = mac_unit(&l, PeType::LightPe2);
        assert!(m3.area_um2(&l) > m2.area_um2(&l));
        // fp16 sits well below fp32
        let qh = crate::config::QuantSpec::new(16, 16, 16, crate::config::MacKind::Fp).unwrap();
        let mh = mac_unit_spec(&l, PeType::from_spec(qh), qh);
        let mf = mac_unit(&l, PeType::Fp32);
        assert!(mh.area_um2(&l) < mf.area_um2(&l));
        for m in [&m4, &m3, &mh] {
            assert!(m.fmax_mhz() > 100.0 && m.pipeline_stages >= 1);
        }
    }
}
