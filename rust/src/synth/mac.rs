//! Gate-level MAC datapath generators — one per PE type.
//!
//! Each generator composes the standard-cell library into the arithmetic
//! structure the paper's RTL generator would emit, returning gate counts and
//! the combinational critical path.  The LightPE datapaths follow LightNN
//! (Ding et al. 2018): the weight is encoded as one (LightPE-1) or two
//! (LightPE-2) signed powers of two, so the multiplier collapses into a
//! barrel shifter (+ an extra adder for the second term).
//!
//! The same structural recipes are elaborated into real gate netlists by
//! `crate::rtl::netlist`; a cross-check test there asserts the counts agree.

use crate::config::PeType;
use crate::synth::gates::{GateCounts, GateLib};

/// A synthesized combinational/pipelined block.
#[derive(Debug, Clone, Copy)]
pub struct Block {
    pub counts: GateCounts,
    /// Combinational critical path before pipelining, ps.
    pub crit_path_ps: f64,
}

impl Block {
    pub fn empty() -> Block {
        Block { counts: GateCounts::default(), crit_path_ps: 0.0 }
    }

    /// Series composition: counts add, critical paths add.
    pub fn then(mut self, other: &Block) -> Block {
        self.counts.add(&other.counts);
        self.crit_path_ps += other.crit_path_ps;
        self
    }

    /// Parallel composition: counts add, critical path is the max.
    pub fn beside(mut self, other: &Block) -> Block {
        self.counts.add(&other.counts);
        self.crit_path_ps = self.crit_path_ps.max(other.crit_path_ps);
        self
    }
}

/// n-bit ripple-carry adder.
pub fn ripple_adder(lib: &GateLib, n: u32) -> Block {
    Block {
        counts: GateCounts { fa: n as u64, ..Default::default() },
        crit_path_ps: n as f64 * lib.fa.delay_ps,
    }
}

/// n-bit carry-lookahead adder (4-bit groups, two lookahead levels).
pub fn cla_adder(lib: &GateLib, n: u32) -> Block {
    let groups = n.div_ceil(4) as u64;
    let counts = GateCounts {
        fa: n as u64,
        // generate/propagate + group lookahead logic
        and2: 3 * n as u64,
        or2: 2 * n as u64,
        nand2: 4 * groups,
        ..Default::default()
    };
    // log-depth carry tree: one FA stage + lookahead levels
    let levels = (n as f64).log2().ceil().max(1.0);
    Block {
        counts,
        crit_path_ps: lib.fa.delay_ps + levels * (lib.and2.delay_ps + lib.or2.delay_ps),
    }
}

/// m x n signed array multiplier (Baugh-Wooley).
pub fn array_multiplier(lib: &GateLib, m: u32, n: u32) -> Block {
    let (m, n) = (m as u64, n as u64);
    let counts = GateCounts {
        and2: m * n,                   // partial products
        fa: (m - 1) * n,               // carry-save reduction rows
        ha: m + n,                     // row edges
        inv: m + n,                    // Baugh-Wooley sign complements
        ..Default::default()
    };
    Block {
        counts,
        // diagonal through the carry-save array plus the final row
        crit_path_ps: lib.and2.delay_ps + (m + n - 2) as f64 * lib.fa.delay_ps,
    }
}

/// w-bit barrel shifter with `stages` mux levels (shift range 2^stages).
pub fn barrel_shifter(lib: &GateLib, w: u32, stages: u32) -> Block {
    Block {
        counts: GateCounts {
            mux2: (w * stages) as u64,
            ..Default::default()
        },
        crit_path_ps: stages as f64 * lib.mux2.delay_ps,
    }
}

/// Conditional two's-complement negate (xor mask + carry-in absorbed by the
/// downstream adder).
pub fn cond_negate(lib: &GateLib, w: u32) -> Block {
    Block {
        counts: GateCounts { xor2: w as u64, ..Default::default() },
        crit_path_ps: lib.xor2.delay_ps,
    }
}

/// Leading-zero counter for FP normalization (w-bit).
pub fn leading_zero_count(lib: &GateLib, w: u32) -> Block {
    let levels = (w as f64).log2().ceil() as u64;
    Block {
        counts: GateCounts {
            nor2: w as u64,
            mux2: w as u64,
            or2: (w as u64) / 2 * levels,
            ..Default::default()
        },
        crit_path_ps: levels as f64 * (lib.or2.delay_ps + lib.mux2.delay_ps),
    }
}

/// A complete pipelined MAC unit.
#[derive(Debug, Clone, Copy)]
pub struct MacUnit {
    pub pe_type: PeType,
    pub counts: GateCounts,
    pub crit_path_ps: f64,
    pub pipeline_stages: u32,
    /// Average datapath node activity per MAC (structure-dependent;
    /// cross-checked against the rtl toggle simulator).
    pub activity: f64,
}

/// Pipeline-stage timing target (ps). One MAC issues per cycle; deeper
/// datapaths get more stages instead of a slower clock.
const STAGE_TARGET_PS: f64 = 900.0;
/// Clock overhead per stage: DFF clk->q + setup + margin (ps).
const CLK_OVERHEAD_PS: f64 = 150.0;

impl MacUnit {
    /// Achievable clock, MHz (1e6 ps per µs).
    ///
    /// Deeper pipelines do not cut the stage time perfectly: register
    /// placement imbalance adds ~6% per extra stage, and clock skew /
    /// margin accumulates with depth — so a 5-stage FP32 pipe cannot
    /// out-clock a 2-stage INT16 pipe just by rounding.
    pub fn fmax_mhz(&self) -> f64 {
        let stages = self.pipeline_stages as f64;
        let imbalance = 1.0 + 0.06 * (stages - 1.0);
        let overhead = CLK_OVERHEAD_PS + 14.0 * stages;
        let stage = self.crit_path_ps / stages * imbalance + overhead;
        1.0e6 / stage
    }

    pub fn area_um2(&self, lib: &GateLib) -> f64 {
        lib.area_um2(&self.counts)
    }

    /// Dynamic energy per MAC operation, fJ.
    pub fn energy_per_mac_fj(&self, lib: &GateLib) -> f64 {
        lib.energy_per_op_fj(&self.counts, self.activity)
    }

    pub fn leakage_nw(&self, lib: &GateLib) -> f64 {
        lib.leakage_nw(&self.counts)
    }
}

fn pipelined(pe_type: PeType, datapath: Block, out_width: u32, activity: f64) -> MacUnit {
    let stages = (datapath.crit_path_ps / STAGE_TARGET_PS).ceil().max(1.0) as u32;
    let mut counts = datapath.counts;
    // Pipeline registers: roughly 1.5x the output width per internal cut,
    // plus the architectural output register.
    let regs = out_width as u64 * 3 / 2 * (stages as u64 - 1) + out_width as u64;
    counts.dff += regs;
    MacUnit {
        pe_type,
        counts,
        crit_path_ps: datapath.crit_path_ps,
        pipeline_stages: stages,
        activity,
    }
}

/// Build the MAC unit for a PE type.
pub fn mac_unit(lib: &GateLib, pe_type: PeType) -> MacUnit {
    match pe_type {
        PeType::Fp32 => fp32_mac(lib),
        PeType::Int16 => int16_mac(lib),
        PeType::LightPe1 => light_mac(lib, PeType::LightPe1),
        PeType::LightPe2 => light_mac(lib, PeType::LightPe2),
    }
}

/// IEEE-754 single-precision fused multiply-add.
fn fp32_mac(lib: &GateLib) -> MacUnit {
    let mant_mult = array_multiplier(lib, 24, 24);
    let exp_add = ripple_adder(lib, 8);
    let align = barrel_shifter(lib, 48, 6);
    let mant_add = cla_adder(lib, 48);
    let lzc = leading_zero_count(lib, 48);
    let norm = barrel_shifter(lib, 48, 6);
    let round = ripple_adder(lib, 12);
    // Exception/sign/flag logic.
    let misc = Block {
        counts: GateCounts { nand2: 220, inv: 90, or2: 60, ..Default::default() },
        crit_path_ps: 2.0 * lib.nand2.delay_ps,
    };
    let datapath = mant_mult
        .beside(&exp_add) // exponent path runs in parallel with the multiply
        .then(&align)
        .then(&mant_add)
        .then(&lzc)
        .then(&norm)
        .then(&round)
        .then(&misc);
    // Multiplier arrays toggle heavily; FP datapath average ~0.25.
    pipelined(PeType::Fp32, datapath, 32, 0.25)
}

/// 16-bit integer MAC with a 32-bit accumulator.
fn int16_mac(lib: &GateLib) -> MacUnit {
    let mult = array_multiplier(lib, 16, 16);
    let acc = cla_adder(lib, 32);
    let datapath = mult.then(&acc);
    pipelined(PeType::Int16, datapath, 32, 0.28)
}

/// LightNN shift-add MAC: 8-bit activation, weight encoded as
/// `shift_terms` signed powers of two; accumulator width from the PE type.
fn light_mac(lib: &GateLib, pe_type: PeType) -> MacUnit {
    debug_assert!(pe_type.is_light());
    let acc_w = pe_type.psum_bits();
    // Weight decode: split the packed weight into per-term (sign, shift).
    let decode = Block {
        counts: GateCounts { nand2: 12, inv: 6, ..Default::default() },
        crit_path_ps: 2.0 * lib.nand2.delay_ps,
    };
    // One shifted term: 3-stage barrel shift (range 0..7) widened to the
    // accumulator, then a conditional negate for the sign.
    let term = barrel_shifter(lib, acc_w, 3).then(&cond_negate(lib, acc_w));
    let mut datapath = decode.then(&term);
    if pe_type.shift_terms() == 2 {
        // Second term is generated in parallel; the two terms and the
        // incoming psum merge through a 3:2 carry-save stage (one FA row)
        // before the single carry-propagate accumulator below — so the
        // second term costs area but almost no latency.
        let term2 = barrel_shifter(lib, acc_w, 3).then(&cond_negate(lib, acc_w));
        let csa = Block {
            counts: GateCounts { fa: acc_w as u64, ..Default::default() },
            crit_path_ps: lib.fa.delay_ps,
        };
        datapath = datapath.beside(&term2).then(&csa);
    }
    // Accumulate into the partial sum.
    let datapath = datapath.then(&cla_adder(lib, acc_w));
    // Shift networks toggle sparsely compared to multiplier arrays; in
    // LightPE-2 the second term is gated off for the ~40% of LightNN
    // weights that one power-of-two already represents, lowering the
    // average node activity further.
    let activity = if pe_type.shift_terms() == 2 { 0.15 } else { 0.18 };
    pipelined(pe_type, datapath, acc_w, activity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PE_TYPES;

    fn lib() -> GateLib {
        GateLib::freepdk45()
    }

    #[test]
    fn adder_counts_and_paths() {
        let l = lib();
        let r8 = ripple_adder(&l, 8);
        let r32 = ripple_adder(&l, 32);
        assert_eq!(r8.counts.fa, 8);
        assert_eq!(r32.counts.fa, 32);
        assert!(r32.crit_path_ps > r8.crit_path_ps);
        let c32 = cla_adder(&l, 32);
        // CLA trades area for delay
        assert!(c32.counts.total() > r32.counts.total());
        assert!(c32.crit_path_ps < r32.crit_path_ps);
    }

    #[test]
    fn multiplier_scales_quadratically() {
        let l = lib();
        let m8 = array_multiplier(&l, 8, 8);
        let m16 = array_multiplier(&l, 16, 16);
        let ratio = m16.counts.total() as f64 / m8.counts.total() as f64;
        assert!((3.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn compose_then_beside() {
        let l = lib();
        let a = ripple_adder(&l, 8);
        let b = ripple_adder(&l, 16);
        let s = a.then(&b);
        assert_eq!(s.counts.fa, 24);
        assert!((s.crit_path_ps - (a.crit_path_ps + b.crit_path_ps)).abs() < 1e-9);
        let p = a.beside(&b);
        assert_eq!(p.counts.fa, 24);
        assert_eq!(p.crit_path_ps, b.crit_path_ps);
    }

    #[test]
    fn mac_area_ordering_matches_paper() {
        // Fig. 2: FP32 costliest, LightPEs cheapest (per PE).
        let l = lib();
        let area = |t| mac_unit(&l, t).area_um2(&l);
        assert!(area(PeType::Fp32) > 2.0 * area(PeType::Int16));
        assert!(area(PeType::Int16) > 2.0 * area(PeType::LightPe2));
        assert!(area(PeType::LightPe2) > area(PeType::LightPe1));
    }

    #[test]
    fn mac_energy_ordering_matches_paper() {
        let l = lib();
        let e = |t| mac_unit(&l, t).energy_per_mac_fj(&l);
        assert!(e(PeType::Fp32) > e(PeType::Int16));
        assert!(e(PeType::Int16) > 3.0 * e(PeType::LightPe2));
        assert!(e(PeType::LightPe2) > e(PeType::LightPe1));
    }

    #[test]
    fn mac_energy_in_horowitz_ballpark() {
        // 45nm: FP32 FMA ~4.6 pJ, INT16 MAC ~1 pJ (order of magnitude;
        // our activity-scaled average sits at the low end).
        let l = lib();
        let fp = mac_unit(&l, PeType::Fp32).energy_per_mac_fj(&l) / 1000.0;
        assert!((0.5..12.0).contains(&fp), "fp32 mac {fp} pJ");
        let i16 = mac_unit(&l, PeType::Int16).energy_per_mac_fj(&l) / 1000.0;
        assert!((0.2..3.0).contains(&i16), "int16 mac {i16} pJ");
        let lp1 = mac_unit(&l, PeType::LightPe1).energy_per_mac_fj(&l) / 1000.0;
        assert!((0.01..0.4).contains(&lp1), "lightpe1 mac {lp1} pJ");
    }

    #[test]
    fn lighter_datapaths_clock_no_slower() {
        let l = lib();
        let f = |t| mac_unit(&l, t).fmax_mhz();
        // Shift-add datapaths are shallow and clock fastest; FP32 and
        // INT16 may land close to each other because deeper pipelining
        // compensates for the longer FP path.
        assert!(f(PeType::LightPe1) > f(PeType::Int16));
        assert!(f(PeType::LightPe1) > f(PeType::Fp32));
        for t in ALL_PE_TYPES {
            let mhz = f(t);
            assert!((200.0..2500.0).contains(&mhz), "{t:?} fmax {mhz} MHz");
        }
    }

    #[test]
    fn pipeline_depth_reflects_path() {
        let l = lib();
        let fp = mac_unit(&l, PeType::Fp32);
        let lp = mac_unit(&l, PeType::LightPe1);
        assert!(fp.pipeline_stages > lp.pipeline_stages);
        assert!(lp.pipeline_stages >= 1);
    }
}
