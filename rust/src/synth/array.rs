//! Full-accelerator assembly: PE array + NoC + global buffer + DMA.
//!
//! Matches the paper's spatial architecture (Fig. 1): a `rows x cols` PE
//! array fed by a global buffer over row/column buses (Eyeriss-style
//! X/Y-bus NoC), plus a DMA engine to the device interface.

use crate::config::AcceleratorConfig;
use crate::synth::gates::{GateCounts, GateLib};
use crate::synth::pe::{synthesize_pe, PeSynth};
use crate::synth::sram::{storage, SramMacro};

/// Synthesized whole-chip view.
#[derive(Debug, Clone, Copy)]
pub struct ArraySynth {
    pub pe: PeSynth,
    pub num_pes: u32,
    pub glb: SramMacro,
    /// NoC interface logic (all PEs) + DMA + top-level control.
    pub infra: GateCounts,
    /// Average GLB->PE interconnect length, mm.
    pub avg_wire_mm: f64,
    /// Array clock after clock-distribution margin, MHz.
    pub fmax_mhz: f64,
}

/// On-chip wire energy, fJ per bit per mm (repeated minimum-pitch wire at
/// 1.1 V, ~0.2 fF/µm).
pub const WIRE_FJ_PER_BIT_MM: f64 = 180.0;

/// Floorplan overhead on top of summed macro area.
const FLOORPLAN_OVERHEAD: f64 = 1.10;

/// Fraction of MACs that touch the GLB in row-stationary operation (used
/// only for the reference-activity power report; the dataflow model
/// computes real per-layer traffic).
pub const GLB_ACCESS_PER_MAC: f64 = 0.05;

/// Reference utilization at which the oracle reports power (the paper
/// reports synthesis power at a nominal testbench activity).
pub const REF_UTILIZATION: f64 = 0.85;

pub(crate) fn noc_interface(cfg: &AcceleratorConfig) -> GateCounts {
    // Per-PE bus interface: tag match + FIFO slot + drivers, scaled by
    // operand width.
    let w = cfg.quant().act_bits as u64;
    let per_pe = GateCounts {
        dff: 2 * w,
        mux2: 2 * w,
        nand2: 48,
        inv: 24,
        ..Default::default()
    };
    per_pe.scaled(cfg.num_pes() as u64)
}

pub(crate) fn dma_engine(cfg: &AcceleratorConfig) -> GateCounts {
    // Descriptor FSM + burst counters + bus width registers; modestly
    // scaled by bandwidth (wider interfaces for higher BW).
    let lanes = (cfg.bandwidth_gbps / 2.0).ceil().max(1.0) as u64;
    GateCounts {
        dff: 500 + 64 * lanes,
        nand2: 1200 + 100 * lanes,
        inv: 500,
        mux2: 200 + 32 * lanes,
        ..Default::default()
    }
}

pub(crate) fn top_control(cfg: &AcceleratorConfig) -> GateCounts {
    // Layer sequencer + config registers; grows slowly with array size.
    let pes = cfg.num_pes() as u64;
    GateCounts {
        dff: 800 + pes / 4,
        nand2: 2600 + pes,
        inv: 900,
        ..Default::default()
    }
}

/// Assemble the whole accelerator.
pub fn synthesize_array(lib: &GateLib, cfg: &AcceleratorConfig) -> ArraySynth {
    let pe = synthesize_pe(lib, cfg);
    let num_pes = cfg.num_pes();
    let glb = storage(cfg.glb_kb as u64 * 1024, 64);

    let mut infra = noc_interface(cfg);
    infra.add(&dma_engine(cfg));
    infra.add(&top_control(cfg));

    // Geometry: PEs tile a grid with pitch sqrt(pe_area); the average
    // GLB->PE Manhattan distance is half the array span.
    let pe_mm = (pe.area_um2(lib) / 1e6).sqrt();
    let span_mm = pe_mm * (cfg.pe_rows as f64 + cfg.pe_cols as f64) / 2.0;
    let avg_wire_mm = (span_mm / 2.0).max(0.05);

    // Clock distribution slows large arrays (skew across the H-tree).
    let margin = 1.0 - 0.003 * (cfg.pe_rows + cfg.pe_cols) as f64;
    let fmax_mhz = pe.fmax_mhz() * margin.max(0.7);

    ArraySynth { pe, num_pes, glb, infra, avg_wire_mm, fmax_mhz }
}

impl ArraySynth {
    /// Total die area, mm².
    pub fn area_mm2(&self, lib: &GateLib) -> f64 {
        let um2 = self.pe.area_um2(lib) * self.num_pes as f64
            + self.glb.area_um2
            + lib.area_um2(&self.infra);
        um2 * FLOORPLAN_OVERHEAD / 1e6
    }

    /// Power at the reference operating point (all PEs at REF_UTILIZATION,
    /// clocked at fmax), mW. This is the "synthesis tool power report" the
    /// regression models learn.
    pub fn power_mw(&self, lib: &GateLib) -> f64 {
        let f_mhz = self.fmax_mhz;
        // fJ * MHz = nW.
        let mac_nw = self.pe.energy_per_mac_fj(lib)
            * self.num_pes as f64
            * f_mhz
            * REF_UTILIZATION;
        // GLB + interconnect traffic per MAC: the bits fetched per MAC
        // scale with the operand precision (act + weight), so quantized
        // PEs draw proportionally less buffer/NoC power — the
        // quantization-aware part of the power report.
        let q = self.pe.pe_type.spec();
        let word_bits = q.act_bits as f64;
        let op_bits = (q.act_bits + q.wt_bits) as f64;
        let glb_nw = (self.glb.access_energy_fj
            + WIRE_FJ_PER_BIT_MM * self.avg_wire_mm * word_bits)
            * GLB_ACCESS_PER_MAC
            * (op_bits / 32.0)
            * self.num_pes as f64
            * f_mhz
            * REF_UTILIZATION;
        let infra_nw = lib.energy_per_op_fj(&self.infra, 0.08) * f_mhz;
        let leak_nw = self.pe.leakage_nw(lib) * self.num_pes as f64
            + self.glb.leak_nw
            + lib.leakage_nw(&self.infra);
        (mac_nw + glb_nw + infra_nw + leak_nw) / 1e6
    }

    /// Peak throughput at the reference point, GMAC/s.
    pub fn peak_gmacs(&self) -> f64 {
        self.num_pes as f64 * self.fmax_mhz / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType, ALL_PE_TYPES};

    fn lib() -> GateLib {
        GateLib::freepdk45()
    }

    #[test]
    fn area_scales_with_array_size() {
        let l = lib();
        let mut small = AcceleratorConfig::default_with(PeType::Int16);
        small.pe_rows = 8;
        small.pe_cols = 8;
        let mut big = small;
        big.pe_rows = 16;
        big.pe_cols = 16;
        let a_small = synthesize_array(&l, &small).area_mm2(&l);
        let a_big = synthesize_array(&l, &big).area_mm2(&l);
        // 4x the PEs: area should grow 2-4x (GLB amortizes)
        assert!(a_big / a_small > 1.8, "{a_big} / {a_small}");
        assert!(a_big / a_small < 4.5);
    }

    #[test]
    fn power_scales_with_array_size() {
        let l = lib();
        let mut small = AcceleratorConfig::default_with(PeType::Int16);
        small.pe_rows = 8;
        small.pe_cols = 8;
        let mut big = small;
        big.pe_rows = 16;
        big.pe_cols = 16;
        let p_small = synthesize_array(&l, &small).power_mw(&l);
        let p_big = synthesize_array(&l, &big).power_mw(&l);
        assert!(p_big > 2.0 * p_small);
    }

    #[test]
    fn glb_contributes_area() {
        let l = lib();
        let mut a = AcceleratorConfig::default_with(PeType::Int16);
        a.glb_kb = 64;
        let mut b = a;
        b.glb_kb = 512;
        assert!(
            synthesize_array(&l, &b).area_mm2(&l) > synthesize_array(&l, &a).area_mm2(&l)
        );
    }

    #[test]
    fn chip_numbers_in_eyeriss_ballpark() {
        // Eyeriss: 168 PEs, 108KB GLB, 12.25 mm² @65nm, ~280 mW.
        // At 45nm with INT16 we expect a few mm² and O(100 mW - 1 W).
        let l = lib();
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let arr = synthesize_array(&l, &cfg);
        let area = arr.area_mm2(&l);
        let power = arr.power_mw(&l);
        assert!((0.5..20.0).contains(&area), "area {area} mm²");
        assert!((30.0..3000.0).contains(&power), "power {power} mW");
    }

    #[test]
    fn fp32_chip_costs_most_lightpe_least() {
        let l = lib();
        let get = |t| {
            let cfg = AcceleratorConfig::default_with(t);
            let arr = synthesize_array(&l, &cfg);
            (arr.area_mm2(&l), arr.power_mw(&l))
        };
        let (a_fp, p_fp) = get(PeType::Fp32);
        let (a_i16, p_i16) = get(PeType::Int16);
        let (a_l1, p_l1) = get(PeType::LightPe1);
        let (a_l2, p_l2) = get(PeType::LightPe2);
        assert!(a_fp > a_i16 && a_i16 > a_l2 && a_l2 >= a_l1);
        // Power is reported at each design's own fmax; LightPE-1 clocks
        // much faster than LightPE-2, so their *power* ordering may cross
        // even though LightPE-1 energy/op is lower.
        assert!(p_fp > p_i16 && p_i16 > p_l1.max(p_l2));
    }

    #[test]
    fn peak_throughput_positive_for_all_types() {
        let l = lib();
        for t in ALL_PE_TYPES {
            let arr = synthesize_array(&l, &AcceleratorConfig::default_with(t));
            assert!(arr.peak_gmacs() > 10.0, "{t:?}");
        }
    }
}
