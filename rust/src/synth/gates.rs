//! FreePDK45-calibrated standard-cell library.
//!
//! Per-cell area comes from the FreePDK45 / Nangate 45 nm Open Cell Library
//! (X1 drive strengths); switching energy and delay are representative
//! typical-corner values at VDD = 1.1 V consistent with the Horowitz
//! ISSCC-2014 energy table (e.g. a 32-bit ripple add built from these FA
//! cells lands at ~0.1 pJ, an 8-bit add at ~0.03 pJ).  Absolute numbers only
//! need to be *plausible*; the paper's claims are ratios between PE types,
//! which are determined by gate-count structure, not by the exact pJ scale.

/// One standard cell (or cell-sized macro) in the library.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    /// Layout area, µm².
    pub area_um2: f64,
    /// Average switching energy per output toggle, fJ.
    pub energy_fj: f64,
    /// Leakage power, nW.
    pub leak_nw: f64,
    /// Propagation delay, ps (typical corner, FO4-ish load).
    pub delay_ps: f64,
}

/// Aggregate gate counts of a synthesized block.
///
/// The fields mirror the cells the structural generators instantiate; a
/// block's PPA is the dot product of its counts with the library.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GateCounts {
    pub inv: u64,
    pub nand2: u64,
    pub nor2: u64,
    pub and2: u64,
    pub or2: u64,
    pub xor2: u64,
    pub mux2: u64,
    pub fa: u64,
    pub ha: u64,
    pub dff: u64,
}

impl GateCounts {
    pub fn total(&self) -> u64 {
        self.inv + self.nand2 + self.nor2 + self.and2 + self.or2 + self.xor2
            + self.mux2 + self.fa + self.ha + self.dff
    }

    pub fn add(&mut self, other: &GateCounts) {
        self.inv += other.inv;
        self.nand2 += other.nand2;
        self.nor2 += other.nor2;
        self.and2 += other.and2;
        self.or2 += other.or2;
        self.xor2 += other.xor2;
        self.mux2 += other.mux2;
        self.fa += other.fa;
        self.ha += other.ha;
        self.dff += other.dff;
    }

    pub fn scaled(&self, k: u64) -> GateCounts {
        GateCounts {
            inv: self.inv * k,
            nand2: self.nand2 * k,
            nor2: self.nor2 * k,
            and2: self.and2 * k,
            or2: self.or2 * k,
            xor2: self.xor2 * k,
            mux2: self.mux2 * k,
            fa: self.fa * k,
            ha: self.ha * k,
            dff: self.dff * k,
        }
    }
}

/// The cell library.
#[derive(Debug, Clone, Copy)]
pub struct GateLib {
    pub inv: Cell,
    pub nand2: Cell,
    pub nor2: Cell,
    pub and2: Cell,
    pub or2: Cell,
    pub xor2: Cell,
    pub mux2: Cell,
    pub fa: Cell,
    pub ha: Cell,
    pub dff: Cell,
}

impl GateLib {
    /// FreePDK45 / Nangate45-flavoured typical-corner library.
    pub const fn freepdk45() -> GateLib {
        GateLib {
            //                 area    energy  leak   delay
            inv: Cell { area_um2: 0.53, energy_fj: 0.35, leak_nw: 8.0, delay_ps: 12.0 },
            nand2: Cell { area_um2: 0.80, energy_fj: 0.45, leak_nw: 11.0, delay_ps: 16.0 },
            nor2: Cell { area_um2: 0.80, energy_fj: 0.50, leak_nw: 12.0, delay_ps: 20.0 },
            and2: Cell { area_um2: 1.06, energy_fj: 0.55, leak_nw: 13.0, delay_ps: 22.0 },
            or2: Cell { area_um2: 1.06, energy_fj: 0.60, leak_nw: 13.0, delay_ps: 24.0 },
            xor2: Cell { area_um2: 1.60, energy_fj: 1.10, leak_nw: 19.0, delay_ps: 30.0 },
            mux2: Cell { area_um2: 1.33, energy_fj: 0.80, leak_nw: 16.0, delay_ps: 26.0 },
            // Full adder as a complex cell (sum + carry).
            fa: Cell { area_um2: 4.26, energy_fj: 2.90, leak_nw: 46.0, delay_ps: 48.0 },
            ha: Cell { area_um2: 2.13, energy_fj: 1.60, leak_nw: 26.0, delay_ps: 34.0 },
            // Positive-edge D flip-flop.
            dff: Cell { area_um2: 4.52, energy_fj: 2.10, leak_nw: 58.0, delay_ps: 60.0 },
        }
    }

    fn cells(&self) -> [(&Cell, u64); 10] {
        [
            (&self.inv, 0),
            (&self.nand2, 0),
            (&self.nor2, 0),
            (&self.and2, 0),
            (&self.or2, 0),
            (&self.xor2, 0),
            (&self.mux2, 0),
            (&self.fa, 0),
            (&self.ha, 0),
            (&self.dff, 0),
        ]
    }

    fn paired<'a>(&'a self, c: &GateCounts) -> [(&'a Cell, u64); 10] {
        let mut p = self.cells();
        let counts = [
            c.inv, c.nand2, c.nor2, c.and2, c.or2, c.xor2, c.mux2, c.fa, c.ha, c.dff,
        ];
        for (slot, n) in p.iter_mut().zip(counts) {
            slot.1 = n;
        }
        p
    }

    /// Total layout area, µm² (plus a placement/routing utilization factor).
    pub fn area_um2(&self, counts: &GateCounts) -> f64 {
        const UTILIZATION: f64 = 0.75; // typical placeable-area utilization
        let raw: f64 = self
            .paired(counts)
            .iter()
            .map(|(cell, n)| cell.area_um2 * *n as f64)
            .sum();
        raw / UTILIZATION
    }

    /// Switching energy for one *operation* of the block, fJ, at the given
    /// average node activity (fraction of gates toggling per op).
    pub fn energy_per_op_fj(&self, counts: &GateCounts, activity: f64) -> f64 {
        self.paired(counts)
            .iter()
            .map(|(cell, n)| cell.energy_fj * *n as f64)
            .sum::<f64>()
            * activity
    }

    /// Total leakage power, nW.
    pub fn leakage_nw(&self, counts: &GateCounts) -> f64 {
        self.paired(counts)
            .iter()
            .map(|(cell, n)| cell.leak_nw * *n as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn library_constants_are_positive_and_ordered() {
        let lib = GateLib::freepdk45();
        for (cell, _) in lib.cells() {
            assert!(cell.area_um2 > 0.0);
            assert!(cell.energy_fj > 0.0);
            assert!(cell.leak_nw > 0.0);
            assert!(cell.delay_ps > 0.0);
        }
        // complex cells cost more than simple ones
        assert!(lib.fa.area_um2 > lib.xor2.area_um2);
        assert!(lib.xor2.area_um2 > lib.nand2.area_um2);
        assert!(lib.dff.energy_fj > lib.inv.energy_fj);
    }

    #[test]
    fn counts_add_and_scale() {
        let a = GateCounts { fa: 2, dff: 1, ..Default::default() };
        let mut b = a;
        b.add(&a);
        assert_eq!(b.fa, 4);
        assert_eq!(b.dff, 2);
        assert_eq!(a.scaled(3).fa, 6);
        assert_eq!(a.total(), 3);
    }

    #[test]
    fn aggregate_ppa_monotone_in_counts() {
        let lib = GateLib::freepdk45();
        let small = GateCounts { fa: 16, ..Default::default() };
        let big = GateCounts { fa: 64, ..Default::default() };
        assert!(lib.area_um2(&big) > lib.area_um2(&small));
        assert!(lib.energy_per_op_fj(&big, 0.2) > lib.energy_per_op_fj(&small, 0.2));
        assert!(lib.leakage_nw(&big) > lib.leakage_nw(&small));
    }

    #[test]
    fn ripple_add_energy_in_horowitz_ballpark() {
        // Horowitz ISSCC'14 @45nm: 32-bit int add ~0.1 pJ, 8-bit ~0.03 pJ.
        // A ripple adder toggles most of its cells per op -> activity ~0.5.
        let lib = GateLib::freepdk45();
        let add32 = GateCounts { fa: 32, ..Default::default() };
        let e32_pj = lib.energy_per_op_fj(&add32, 0.5) / 1000.0;
        assert!((0.02..0.3).contains(&e32_pj), "32b add = {e32_pj} pJ");
        let add8 = GateCounts { fa: 8, ..Default::default() };
        let e8_pj = lib.energy_per_op_fj(&add8, 0.5) / 1000.0;
        assert!((0.005..0.08).contains(&e8_pj), "8b add = {e8_pj} pJ");
    }
}
