//! Synthesis memo for the evaluation hot path.
//!
//! `energy_params` re-runs full PE gate synthesis and the GLB macro model
//! per call, but both are pure functions of a handful of config fields:
//! the PE side depends only on (resolved `QuantSpec`, scratchpad bytes)
//! and the GLB macro only on `glb_kb`.  [`SynthMemo`] caches those two
//! components — the expensive parts — and recomposes the remaining
//! arithmetic in exactly the order `energy_params` uses, so the memoized
//! result is bit-identical to a cold `energy_params` call (pinned by
//! tests here and by the SoA equivalence suite).
//!
//! Hit/miss counters feed `SweepStats` and the optimizer's `[engine]`
//! stderr line; one lookup is counted per [`SynthMemo::energy_params_with`]
//! call, a hit meaning every cached component was already present.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::{AcceleratorConfig, PeType};
use crate::synth::array::{dma_engine, noc_interface, top_control, WIRE_FJ_PER_BIT_MM};
use crate::synth::gates::GateLib;
use crate::synth::oracle::EnergyParams;
use crate::synth::pe::synthesize_pe;
use crate::synth::sram::{storage, SramMacro, DRAM_FJ_PER_BIT};

/// The four scalars the energy model needs from one synthesized PE.
/// Caching these (rather than the full `PeSynth`) keeps the entries tiny
/// and forces every derived value through the same method calls
/// `energy_params` makes, so the floats agree bit-for-bit.
#[derive(Debug, Clone, Copy)]
struct PeDerived {
    area_um2: f64,
    energy_per_mac_fj: f64,
    leakage_nw: f64,
    fmax_mhz: f64,
}

/// PE synthesis key: everything `synthesize_pe` reads from the config.
type PeKey = (PeType, u32, u32, u32);

/// Thread-safe cache over the synthesis-derived inputs of `energy_params`.
pub struct SynthMemo {
    lib: GateLib,
    pe: Mutex<HashMap<PeKey, PeDerived>>,
    glb: Mutex<HashMap<u32, SramMacro>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for SynthMemo {
    fn default() -> Self {
        SynthMemo::new()
    }
}

impl SynthMemo {
    pub fn new() -> SynthMemo {
        SynthMemo {
            lib: GateLib::freepdk45(),
            pe: Mutex::new(HashMap::new()),
            glb: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// (hits, misses) so far; `hits + misses` equals the number of
    /// `energy_params_with` calls.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    fn pe_derived(&self, cfg: &AcceleratorConfig) -> (PeDerived, bool) {
        let key: PeKey =
            (cfg.pe_type, cfg.spad_ifmap_b, cfg.spad_filter_b, cfg.spad_psum_b);
        if let Some(d) = self.pe.lock().unwrap().get(&key) {
            return (*d, true);
        }
        // Synthesize outside the lock; a racing double-insert writes the
        // identical value (pure function of the key).
        let pe = synthesize_pe(&self.lib, cfg);
        let d = PeDerived {
            area_um2: pe.area_um2(&self.lib),
            energy_per_mac_fj: pe.energy_per_mac_fj(&self.lib),
            leakage_nw: pe.leakage_nw(&self.lib),
            fmax_mhz: pe.fmax_mhz(),
        };
        self.pe.lock().unwrap().insert(key, d);
        (d, false)
    }

    fn glb_macro(&self, glb_kb: u32) -> (SramMacro, bool) {
        if let Some(m) = self.glb.lock().unwrap().get(&glb_kb) {
            return (*m, true);
        }
        let m = storage(glb_kb as u64 * 1024, 64);
        self.glb.lock().unwrap().insert(glb_kb, m);
        (m, false)
    }

    /// Memoized `energy_params`: bit-identical to
    /// [`crate::synth::oracle::energy_params`] on every field.
    pub fn energy_params_with(&self, cfg: &AcceleratorConfig) -> EnergyParams {
        let (pe, pe_hit) = self.pe_derived(cfg);
        let (glb, glb_hit) = self.glb_macro(cfg.glb_kb);
        if pe_hit && glb_hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }

        // Recomposition mirrors `synthesize_array` + `energy_params`
        // operation-for-operation so the floats cannot drift.
        let mut infra = noc_interface(cfg);
        infra.add(&dma_engine(cfg));
        infra.add(&top_control(cfg));
        let leak_nw = pe.leakage_nw * cfg.num_pes() as f64
            + glb.leak_nw
            + self.lib.leakage_nw(&infra);

        let pe_mm = (pe.area_um2 / 1e6).sqrt();
        let span_mm = pe_mm * (cfg.pe_rows as f64 + cfg.pe_cols as f64) / 2.0;
        let avg_wire_mm = (span_mm / 2.0).max(0.05);
        let margin = 1.0 - 0.003 * (cfg.pe_rows + cfg.pe_cols) as f64;
        let fmax_mhz = pe.fmax_mhz * margin.max(0.7);

        EnergyParams {
            mac_with_spads_fj: pe.energy_per_mac_fj,
            glb_access_fj: glb.access_energy_fj,
            glb_word_bits: 64,
            wire_fj_per_bit: WIRE_FJ_PER_BIT_MM * avg_wire_mm,
            dram_fj_per_bit: DRAM_FJ_PER_BIT,
            leakage_mw: leak_nw / 1e6,
            fmax_mhz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PeType;
    use crate::synth::oracle::energy_params;
    use crate::testkit::{forall, gen_config, gen_quant_spec};

    fn assert_bit_identical(a: &EnergyParams, b: &EnergyParams) -> Result<(), String> {
        let pairs = [
            ("mac_with_spads_fj", a.mac_with_spads_fj, b.mac_with_spads_fj),
            ("glb_access_fj", a.glb_access_fj, b.glb_access_fj),
            ("wire_fj_per_bit", a.wire_fj_per_bit, b.wire_fj_per_bit),
            ("dram_fj_per_bit", a.dram_fj_per_bit, b.dram_fj_per_bit),
            ("leakage_mw", a.leakage_mw, b.leakage_mw),
            ("fmax_mhz", a.fmax_mhz, b.fmax_mhz),
        ];
        for (name, x, y) in pairs {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{name}: {x} != {y}"));
            }
        }
        if a.glb_word_bits != b.glb_word_bits {
            return Err("glb_word_bits differ".into());
        }
        Ok(())
    }

    #[test]
    fn memoized_params_bit_identical_to_cold_for_presets_and_random_specs() {
        let memo = SynthMemo::new();
        forall(
            "memoized energy_params == cold energy_params",
            150,
            41,
            |rng| {
                let mut cfg = gen_config(rng);
                // Half the cases swap in an arbitrary-precision spec so the
                // memo is exercised beyond the 4 presets.
                if rng.f64() < 0.5 {
                    cfg.pe_type = PeType::from_spec(gen_quant_spec(rng));
                }
                cfg
            },
            |cfg| assert_bit_identical(&memo.energy_params_with(cfg), &energy_params(cfg)),
        );
    }

    #[test]
    fn repeat_lookups_hit_and_counters_sum_to_lookups() {
        let memo = SynthMemo::new();
        let cfg = crate::config::AcceleratorConfig::default_with(PeType::Int16);
        let a = memo.energy_params_with(&cfg);
        assert_eq!(memo.counters(), (0, 1), "cold call must miss");
        let b = memo.energy_params_with(&cfg);
        assert_eq!(memo.counters(), (1, 1), "warm call must hit");
        assert_bit_identical(&a, &b).unwrap();

        // Same PE recipe, different GLB: the GLB component misses.
        let mut bigger = cfg;
        bigger.glb_kb += 64;
        memo.energy_params_with(&bigger);
        let (h, m) = memo.counters();
        assert_eq!((h, m), (1, 2));
        assert_eq!(h + m, 3, "hits + misses must equal total lookups");
    }

    #[test]
    fn distinct_pe_recipes_do_not_collide() {
        // Same spad bytes, different resolved spec — and vice versa — must
        // produce distinct cached results.
        let memo = SynthMemo::new();
        let a = crate::config::AcceleratorConfig::default_with(PeType::Int16);
        let mut b = a;
        b.pe_type = PeType::LightPe1;
        let ea = memo.energy_params_with(&a);
        let eb = memo.energy_params_with(&b);
        assert!(ea.mac_with_spads_fj != eb.mac_with_spads_fj);
        let mut c = a;
        c.spad_filter_b *= 2;
        let ec = memo.energy_params_with(&c);
        assert!(ea.mac_with_spads_fj != ec.mac_with_spads_fj);
    }
}
