//! The synthesis oracle: `synthesize(cfg) -> Ppa` ground truth.
//!
//! This is the stand-in for the paper's Synopsys Design Compiler +
//! FreePDK45 flow.  `synthesize_clean` is the pure analytical model;
//! `synthesize` adds deterministic per-config multiplicative jitter that
//! mimics synthesis-tool non-determinism (placement seeds, mapping
//! heuristics), which is what makes the regression fit a statistics
//! problem rather than table interpolation.  Jitter is keyed off the
//! config identity, so the "tool" is reproducible run-to-run.

use crate::config::AcceleratorConfig;
use crate::synth::array::{synthesize_array, ArraySynth};
use crate::synth::gates::GateLib;
use crate::util::prng::{hash64, Rng};

/// Ground-truth (or predicted) power / performance / area triple.
///
/// Field order matches the artifact target order
/// (`manifest.json: target_order` = [power_mw, fmax_mhz, area_mm2]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ppa {
    pub power_mw: f64,
    pub fmax_mhz: f64,
    pub area_mm2: f64,
}

impl Ppa {
    pub fn as_array(&self) -> [f64; 3] {
        [self.power_mw, self.fmax_mhz, self.area_mm2]
    }

    pub fn from_array(a: [f64; 3]) -> Ppa {
        Ppa { power_mw: a[0], fmax_mhz: a[1], area_mm2: a[2] }
    }
}

/// Relative sigma of the synthesis jitter (power — the noisiest report).
pub const JITTER_SIGMA: f64 = 0.03;
/// Timing reports are far more repeatable than power estimates.
pub const JITTER_SIGMA_FMAX_SCALE: f64 = 0.25;
/// Area sits in between.
pub const JITTER_SIGMA_AREA_SCALE: f64 = 0.5;

/// Jitter-free analytical synthesis.
pub fn synthesize_clean(cfg: &AcceleratorConfig) -> Ppa {
    let lib = GateLib::freepdk45();
    let arr = synthesize_array(&lib, cfg);
    Ppa {
        power_mw: arr.power_mw(&lib),
        fmax_mhz: arr.fmax_mhz,
        area_mm2: arr.area_mm2(&lib),
    }
}

/// Synthesis with tool jitter — the data source for model training.
pub fn synthesize(cfg: &AcceleratorConfig) -> Ppa {
    synthesize_with_sigma(cfg, JITTER_SIGMA)
}

/// Jitter amplitude exposed for the `ablation_noise` bench.
pub fn synthesize_with_sigma(cfg: &AcceleratorConfig, sigma: f64) -> Ppa {
    let clean = synthesize_clean(cfg);
    let mut rng = Rng::new(hash64(cfg.key().as_bytes()));
    let mut jitter = |scale: f64| (sigma * scale * rng.gauss()).exp();
    Ppa {
        power_mw: clean.power_mw * jitter(1.0),
        fmax_mhz: clean.fmax_mhz * jitter(JITTER_SIGMA_FMAX_SCALE),
        area_mm2: clean.area_mm2 * jitter(JITTER_SIGMA_AREA_SCALE),
    }
}

/// Energy/time coefficients the dataflow model needs, derived from the same
/// synthesized design (so the oracle and the workload-level energy model
/// can never disagree about the hardware).
#[derive(Debug, Clone, Copy)]
pub struct EnergyParams {
    /// Dynamic energy of one MAC including spad traffic, fJ.
    pub mac_with_spads_fj: f64,
    /// GLB access energy per word, fJ.
    pub glb_access_fj: f64,
    /// Word width for GLB accounting, bits.
    pub glb_word_bits: u32,
    /// Interconnect energy per bit moved GLB<->PE, fJ.
    pub wire_fj_per_bit: f64,
    /// DRAM energy per bit, fJ.
    pub dram_fj_per_bit: f64,
    /// Total chip leakage, mW.
    pub leakage_mw: f64,
    /// Array clock, MHz.
    pub fmax_mhz: f64,
}

/// Derive the energy parameters for a configuration.
pub fn energy_params(cfg: &AcceleratorConfig) -> EnergyParams {
    let lib = GateLib::freepdk45();
    let arr: ArraySynth = synthesize_array(&lib, cfg);
    let leak_nw = arr.pe.leakage_nw(&lib) * arr.num_pes as f64
        + arr.glb.leak_nw
        + lib.leakage_nw(&arr.infra);
    EnergyParams {
        mac_with_spads_fj: arr.pe.energy_per_mac_fj(&lib),
        glb_access_fj: arr.glb.access_energy_fj,
        glb_word_bits: 64,
        wire_fj_per_bit: crate::synth::array::WIRE_FJ_PER_BIT_MM * arr.avg_wire_mm,
        dram_fj_per_bit: crate::synth::sram::DRAM_FJ_PER_BIT,
        leakage_mw: leak_nw / 1e6,
        fmax_mhz: arr.fmax_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType, ALL_PE_TYPES};

    #[test]
    fn jitter_is_deterministic_per_config() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        assert_eq!(synthesize(&cfg), synthesize(&cfg));
    }

    #[test]
    fn jitter_differs_between_configs() {
        let a = AcceleratorConfig::default_with(PeType::Int16);
        let mut b = a;
        b.glb_kb += 4;
        let ra = synthesize(&a);
        let rb = synthesize(&b);
        assert_ne!(ra, rb);
    }

    #[test]
    fn jitter_stays_within_a_few_sigma() {
        for t in ALL_PE_TYPES {
            let mut cfg = AcceleratorConfig::default_with(t);
            for g in [64u32, 128, 256] {
                cfg.glb_kb = g;
                let clean = synthesize_clean(&cfg);
                let noisy = synthesize(&cfg);
                for (c, n) in clean.as_array().iter().zip(noisy.as_array()) {
                    let rel = (n / c - 1.0).abs();
                    assert!(rel < 6.0 * JITTER_SIGMA, "rel dev {rel}");
                }
            }
        }
    }

    #[test]
    fn zero_sigma_equals_clean() {
        let cfg = AcceleratorConfig::default_with(PeType::LightPe1);
        assert_eq!(synthesize_with_sigma(&cfg, 0.0), synthesize_clean(&cfg));
    }

    #[test]
    fn clean_model_monotone_in_array_size() {
        let mut cfg = AcceleratorConfig::default_with(PeType::Int16);
        let mut last_area = 0.0;
        let mut last_power = 0.0;
        for n in [8u32, 12, 16, 24] {
            cfg.pe_rows = n;
            cfg.pe_cols = n;
            let p = synthesize_clean(&cfg);
            assert!(p.area_mm2 > last_area);
            assert!(p.power_mw > last_power);
            last_area = p.area_mm2;
            last_power = p.power_mw;
        }
    }

    #[test]
    fn energy_params_sane() {
        let cfg = AcceleratorConfig::default_with(PeType::Int16);
        let ep = energy_params(&cfg);
        assert!(ep.mac_with_spads_fj > 0.0);
        assert!(ep.glb_access_fj > ep.mac_with_spads_fj / 100.0);
        assert!(ep.dram_fj_per_bit > ep.glb_access_fj / 64.0);
        assert!(ep.leakage_mw > 0.0);
        assert!(ep.fmax_mhz > 100.0);
    }

    #[test]
    fn ppa_array_roundtrip() {
        let p = Ppa { power_mw: 1.0, fmax_mhz: 2.0, area_mm2: 3.0 };
        assert_eq!(Ppa::from_array(p.as_array()), p);
    }
}
