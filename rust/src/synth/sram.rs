//! CACTI-style SRAM / register-file macro model.
//!
//! Two regimes, matching how the paper's RTL maps storage:
//!
//! * small per-PE scratchpads (tens to hundreds of bytes) — flop/latch
//!   register files, whose cost comes from the standard-cell library;
//! * the global buffer (tens to hundreds of KiB) — 6T SRAM macros with
//!   peripheral overhead that amortizes with capacity and access energy
//!   that grows ~sqrt(bits) (wordline/bitline length), the classic CACTI
//!   shape at 45 nm.

/// Cost summary of one storage macro.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramMacro {
    pub bits: u64,
    pub area_um2: f64,
    /// Energy per read or write access of one word, fJ.
    pub access_energy_fj: f64,
    /// Leakage, nW.
    pub leak_nw: f64,
}

/// 6T bitcell area at 45 nm, µm² (FreePDK45-era bitcells: 0.25-0.4).
const BITCELL_UM2: f64 = 0.30;
/// Register-file storage cost per bit (latch + mux), µm².
const RF_BIT_UM2: f64 = 1.3;
/// SRAM leakage per bit, nW.
const SRAM_LEAK_NW_PER_BIT: f64 = 0.012;
/// RF leakage per bit, nW.
const RF_LEAK_NW_PER_BIT: f64 = 0.05;

/// Threshold below which storage synthesizes to a register file.
pub const RF_THRESHOLD_BITS: u64 = 8 * 1024;

/// Model a scratchpad / buffer of `bytes` capacity with `word_bits` access
/// width.
pub fn storage(bytes: u64, word_bits: u32) -> SramMacro {
    let bits = (bytes * 8).max(1);
    let word = word_bits.max(1) as f64;
    if bits <= RF_THRESHOLD_BITS {
        // Register file: linear area, access energy ~ word width with a
        // shallow size term (read mux depth).
        let area = bits as f64 * RF_BIT_UM2;
        let depth = ((bits as f64 / word).max(1.0)).log2().max(1.0);
        let access = 0.55 * word * (1.0 + 0.15 * depth);
        SramMacro {
            bits,
            area_um2: area,
            access_energy_fj: access,
            leak_nw: bits as f64 * RF_LEAK_NW_PER_BIT,
        }
    } else {
        // SRAM macro: bitcell array + peripheral overhead that shrinks
        // relatively as capacity grows; access energy ~ word * sqrt(bits).
        let periph = 1.0 + 4.0 / (bits as f64 / 8192.0).sqrt().max(1.0);
        let area = bits as f64 * BITCELL_UM2 * periph.min(4.0);
        let access = 0.35 * word * (bits as f64).sqrt() / 16.0;
        SramMacro {
            bits,
            area_um2: area,
            access_energy_fj: access,
            leak_nw: bits as f64 * SRAM_LEAK_NW_PER_BIT,
        }
    }
}

/// DRAM access energy per bit, fJ (LPDDR-class, ~20 pJ/bit at 45 nm-era
/// systems; used by the dataflow energy model, not by chip area/power).
pub const DRAM_FJ_PER_BIT: f64 = 20_000.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_monotone_in_capacity() {
        let sizes = [16u64, 64, 256, 1024, 16 * 1024, 128 * 1024, 512 * 1024];
        let mut last = 0.0;
        for s in sizes {
            let m = storage(s, 16);
            assert!(m.area_um2 > last, "area not monotone at {s} B");
            last = m.area_um2;
        }
    }

    #[test]
    fn access_energy_monotone_in_capacity_within_regime() {
        let small = storage(64, 16);
        let bigger = storage(512, 16);
        assert!(bigger.access_energy_fj >= small.access_energy_fj);
        let glb_small = storage(32 * 1024, 64);
        let glb_big = storage(512 * 1024, 64);
        assert!(glb_big.access_energy_fj > glb_small.access_energy_fj);
    }

    #[test]
    fn wider_words_cost_more_per_access() {
        let narrow = storage(64 * 1024, 16);
        let wide = storage(64 * 1024, 64);
        assert!(wide.access_energy_fj > narrow.access_energy_fj);
    }

    #[test]
    fn sram_beats_rf_per_bit_at_scale() {
        // per-bit area must be much cheaper in the SRAM regime
        let rf = storage(512, 16); // register file
        let sram = storage(256 * 1024, 16); // SRAM macro
        let rf_per_bit = rf.area_um2 / rf.bits as f64;
        let sram_per_bit = sram.area_um2 / sram.bits as f64;
        assert!(rf_per_bit > 3.0 * sram_per_bit);
    }

    #[test]
    fn glb_access_energy_in_cacti_ballpark() {
        // ~100 KiB buffer, 64-bit word: expect O(1-20 pJ) per access.
        let glb = storage(108 * 1024, 64);
        let pj = glb.access_energy_fj / 1000.0;
        assert!((0.5..50.0).contains(&pj), "GLB access {pj} pJ");
    }

    #[test]
    fn spad_access_energy_below_glb() {
        let spad = storage(448, 16);
        let glb = storage(108 * 1024, 64);
        assert!(spad.access_energy_fj < glb.access_energy_fj / 5.0);
    }
}
