//! Processing-element assembly: MAC + scratchpads + control.
//!
//! Mirrors the paper's PE microarchitecture (Fig. 1): each PE holds an
//! ifmap scratchpad, a filter scratchpad, a partial-sum scratchpad and a
//! precision-configurable MAC, plus a small control FSM and operand/result
//! registers.

use crate::config::{AcceleratorConfig, PeType, QuantSpec};
use crate::synth::gates::{GateCounts, GateLib};
use crate::synth::mac::{mac_unit_spec, MacUnit};
use crate::synth::sram::{storage, SramMacro};

/// Synthesized view of one PE.
#[derive(Debug, Clone, Copy)]
pub struct PeSynth {
    pub pe_type: PeType,
    pub mac: MacUnit,
    pub spad_ifmap: SramMacro,
    pub spad_filter: SramMacro,
    pub spad_psum: SramMacro,
    /// Control FSM + operand register gate counts.
    pub ctrl: GateCounts,
}

/// Control overhead: address counters, FSM, handshake — roughly constant
/// per PE in the paper's generator, with operand steering sized by the
/// activation width.
fn control_block(q: QuantSpec) -> GateCounts {
    GateCounts {
        dff: 55,
        nand2: 150,
        inv: 70,
        mux2: 32 + q.act_bits as u64, // operand steering
        ..Default::default()
    }
}

/// Assemble (and "synthesize") one PE for a configuration.  Every width —
/// MAC datapath, scratchpad word granularity, operand steering — is sized
/// from the config's resolved [`QuantSpec`].
pub fn synthesize_pe(lib: &GateLib, cfg: &AcceleratorConfig) -> PeSynth {
    let q = cfg.quant();
    PeSynth {
        pe_type: cfg.pe_type,
        mac: mac_unit_spec(lib, cfg.pe_type, q),
        // Scratchpad capacities are *bytes of storage hardware*; the word
        // width (= access granularity) follows the spec's operand widths.
        spad_ifmap: storage(cfg.spad_ifmap_b as u64, q.act_bits),
        spad_filter: storage(cfg.spad_filter_b as u64, q.wt_bits),
        spad_psum: storage(cfg.spad_psum_b as u64, q.psum_bits),
        ctrl: control_block(q),
    }
}

impl PeSynth {
    pub fn area_um2(&self, lib: &GateLib) -> f64 {
        self.mac.area_um2(lib)
            + self.spad_ifmap.area_um2
            + self.spad_filter.area_um2
            + self.spad_psum.area_um2
            + lib.area_um2(&self.ctrl)
    }

    /// Dynamic energy of one MAC *including* its spad traffic, fJ.
    ///
    /// Row-stationary inner loop: each MAC reads act + weight, reads and
    /// writes the partial sum.
    pub fn energy_per_mac_fj(&self, lib: &GateLib) -> f64 {
        self.mac.energy_per_mac_fj(lib)
            + self.spad_ifmap.access_energy_fj
            + self.spad_filter.access_energy_fj
            + 2.0 * self.spad_psum.access_energy_fj
            // address counters / FSM toggle sparsely relative to the datapath
            + lib.energy_per_op_fj(&self.ctrl, 0.05)
    }

    pub fn leakage_nw(&self, lib: &GateLib) -> f64 {
        self.mac.leakage_nw(lib)
            + self.spad_ifmap.leak_nw
            + self.spad_filter.leak_nw
            + self.spad_psum.leak_nw
            + lib.leakage_nw(&self.ctrl)
    }

    /// PE clock: MAC pipeline stage time plus the scratchpad read that
    /// feeds it — larger register files have deeper read muxes, so spad
    /// sizing genuinely moves fmax (and the regression can learn it).
    pub fn fmax_mhz(&self) -> f64 {
        let mac_period_ps = 1.0e6 / self.mac.fmax_mhz();
        let max_bits = self
            .spad_ifmap
            .bits
            .max(self.spad_filter.bits)
            .max(self.spad_psum.bits) as f64;
        let spad_delay_ps = 11.0 * (max_bits / 128.0 + 2.0).log2();
        1.0e6 / (mac_period_ps + spad_delay_ps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, ALL_PE_TYPES};

    fn lib() -> GateLib {
        GateLib::freepdk45()
    }

    #[test]
    fn pe_area_ordering_across_types() {
        let l = lib();
        let area = |t| {
            let cfg = AcceleratorConfig::default_with(t);
            synthesize_pe(&l, &cfg).area_um2(&l)
        };
        assert!(area(PeType::Fp32) > area(PeType::Int16));
        assert!(area(PeType::Int16) > area(PeType::LightPe2));
        assert!(area(PeType::LightPe2) >= area(PeType::LightPe1));
    }

    #[test]
    fn pe_energy_ordering_across_types() {
        let l = lib();
        let e = |t| {
            let cfg = AcceleratorConfig::default_with(t);
            synthesize_pe(&l, &cfg).energy_per_mac_fj(&l)
        };
        assert!(e(PeType::Fp32) > e(PeType::Int16));
        assert!(e(PeType::Int16) > 2.0 * e(PeType::LightPe2));
    }

    #[test]
    fn bigger_spads_cost_area_and_energy() {
        let l = lib();
        let mut small = AcceleratorConfig::default_with(PeType::Int16);
        small.spad_filter_b = 128;
        let mut big = small;
        big.spad_filter_b = 1024;
        let ps = synthesize_pe(&l, &small);
        let pb = synthesize_pe(&l, &big);
        assert!(pb.area_um2(&l) > ps.area_um2(&l));
        assert!(pb.energy_per_mac_fj(&l) > ps.energy_per_mac_fj(&l));
        assert!(pb.leakage_nw(&l) > ps.leakage_nw(&l));
    }

    #[test]
    fn pe_area_in_eyeriss_ballpark() {
        // Eyeriss (65nm) PE ~0.01 mm²; at 45nm expect 0.002-0.02 mm².
        let l = lib();
        for t in ALL_PE_TYPES {
            let cfg = AcceleratorConfig::default_with(t);
            let mm2 = synthesize_pe(&l, &cfg).area_um2(&l) / 1e6;
            assert!((0.0003..0.05).contains(&mm2), "{t:?} PE = {mm2} mm²");
        }
    }

    #[test]
    fn spad_word_width_follows_precision() {
        let l = lib();
        let cfg16 = AcceleratorConfig::default_with(PeType::Int16);
        let cfg8 = AcceleratorConfig::default_with(PeType::LightPe1);
        let p16 = synthesize_pe(&l, &cfg16);
        let p8 = synthesize_pe(&l, &cfg8);
        // same byte capacity but narrower words -> cheaper accesses
        assert!(p8.spad_ifmap.access_energy_fj < p16.spad_ifmap.access_energy_fj);
        assert!(p8.spad_filter.access_energy_fj < p16.spad_filter.access_energy_fj);
    }
}
