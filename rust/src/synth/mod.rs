//! Synthesis oracle — the stand-in for Synopsys Design Compiler + FreePDK45.
//!
//! The paper extracts ground-truth PPA by synthesizing every accelerator
//! configuration; this module reproduces that data source analytically:
//! every datapath is composed from a FreePDK45-calibrated standard-cell
//! library ([`gates`]), SRAM macros come from a CACTI-style model
//! ([`sram`]), and the full design is assembled bottom-up
//! (MAC -> PE -> array, [`mac`]/[`pe`]/[`array`]).  [`oracle`] adds the
//! deterministic per-config "tool jitter" that makes the regression problem
//! realistic and exposes the `synthesize()` entry point the coordinator's
//! training-set sweep calls.
//!
//! The same structural generators drive the RTL netlist builder
//! (`crate::rtl`), so the gate counts the oracle prices and the netlists the
//! logic simulator verifies cannot drift apart.

pub mod array;
pub mod cache;
pub mod gates;
pub mod mac;
pub mod oracle;
pub mod pe;
pub mod sram;

pub use oracle::{synthesize, synthesize_clean, Ppa};
