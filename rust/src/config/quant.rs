//! Parameterized quantization specs — bit precision as a first-class axis.
//!
//! QAPPA's premise is that precision is a *design parameter*, not a menu:
//! a [`QuantSpec`] fixes the activation / weight / partial-sum operand
//! widths and the MAC datapath style ([`MacKind`]), and every layer of the
//! stack — gate-level synthesis ([`crate::synth::mac`]), scratchpad word
//! widths ([`crate::synth::pe`]), traffic and energy accounting
//! ([`crate::dataflow`]), regression features and the DSE grid
//! ([`crate::coordinator::precision`]) — is sized from it.  The four
//! historical PE types (`FP32`, `INT16`, `LightPE-1/2`) are named presets
//! resolving to `QuantSpec`s (see [`crate::config::PeType::spec`]); any
//! other width combination is written `a<act>w<wt>p<psum>-<mac>`, e.g.
//! `a8w4p20-light1` or `a4w4p8-int`.
//!
//! Validation is strict at every boundary (builder, config JSON, workload
//! JSON, precision-grid requests): operand widths must lie in 1..=64 bits
//! and the partial-sum accumulator may never be narrower than either
//! operand — violations are [`QappaError::Config`] errors naming the
//! offending field.

use crate::api::error::QappaError;

/// MAC datapath style of a processing element.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MacKind {
    /// Floating-point fused multiply-add (mantissa/exponent split derived
    /// from the operand width; `a32w32p32-fp` is IEEE-754 single).
    Fp,
    /// Exact integer multiply-accumulate (Baugh-Wooley array multiplier +
    /// carry-lookahead accumulator).
    IntExact,
    /// LightNN-style shift-add datapath: the weight is encoded as `n`
    /// signed powers of two, so the multiplier collapses into `n` barrel
    /// shifts (`LightPE-1` = 1 term, `LightPE-2` = 2 terms).
    Lightweight(u32),
}

impl MacKind {
    /// Canonical label suffix: `fp`, `int`, `light<n>`.
    pub fn suffix(self) -> String {
        match self {
            MacKind::Fp => "fp".to_string(),
            MacKind::IntExact => "int".to_string(),
            MacKind::Lightweight(n) => format!("light{n}"),
        }
    }

    /// Parse a label suffix (case already lowered by the caller).
    pub fn parse(s: &str) -> Option<MacKind> {
        match s {
            "fp" => Some(MacKind::Fp),
            "int" => Some(MacKind::IntExact),
            _ => {
                let n = s.strip_prefix("light")?;
                n.parse::<u32>().ok().map(MacKind::Lightweight)
            }
        }
    }

    /// Numeric code for regression features (constant within a single-kind
    /// precision grid; the standardizer centres constant columns away).
    pub fn code(self) -> f64 {
        match self {
            MacKind::IntExact => 0.0,
            MacKind::Lightweight(_) => 1.0,
            MacKind::Fp => 2.0,
        }
    }

    /// Shift-add terms replacing the multiplier (0 = real multiply).
    pub fn shift_terms(self) -> u32 {
        match self {
            MacKind::Lightweight(n) => n,
            _ => 0,
        }
    }
}

/// A fully parameterized PE precision: operand widths + datapath style.
///
/// This is the quantization axis of the design space. Construct validated
/// specs with [`QuantSpec::new`] (the builder boundary); deserialized specs
/// are re-validated by [`crate::config::AcceleratorConfig::validate`] and
/// the workload loader.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QuantSpec {
    /// Activation operand width, bits.
    pub act_bits: u32,
    /// Weight operand width, bits (for lightweight MACs this is the packed
    /// sign+shift encoding width).
    pub wt_bits: u32,
    /// Partial-sum accumulator width, bits (>= both operand widths).
    pub psum_bits: u32,
    /// Datapath style.
    pub mac: MacKind,
}

/// Generator limit on operand widths, bits.
pub const MAX_BITS: u32 = 64;
/// Generator limit on lightweight shift-add terms.
pub const MAX_SHIFT_TERMS: u32 = 8;

impl QuantSpec {
    /// Validated constructor — the builder-side boundary check.
    pub fn new(act_bits: u32, wt_bits: u32, psum_bits: u32, mac: MacKind) -> Result<QuantSpec, QappaError> {
        let spec = QuantSpec { act_bits, wt_bits, psum_bits, mac };
        spec.validate()?;
        Ok(spec)
    }

    /// Integer spec with an automatic accumulator width.
    pub fn int(act_bits: u32, wt_bits: u32) -> QuantSpec {
        QuantSpec {
            act_bits,
            wt_bits,
            psum_bits: auto_psum(act_bits, wt_bits, MacKind::IntExact),
            mac: MacKind::IntExact,
        }
    }

    /// Lightweight (shift-add) spec with an automatic accumulator width.
    pub fn light(act_bits: u32, wt_bits: u32, terms: u32) -> QuantSpec {
        let mac = MacKind::Lightweight(terms);
        QuantSpec { act_bits, wt_bits, psum_bits: auto_psum(act_bits, wt_bits, mac), mac }
    }

    /// Shift-add terms (0 for multiply datapaths).
    pub fn shift_terms(&self) -> u32 {
        self.mac.shift_terms()
    }

    pub fn is_light(&self) -> bool {
        self.shift_terms() > 0
    }

    /// Canonical label: `a<act>w<wt>p<psum>-<mac>`, e.g. `a8w4p20-light1`.
    pub fn label(&self) -> String {
        format!("a{}w{}p{}-{}", self.act_bits, self.wt_bits, self.psum_bits, self.mac.suffix())
    }

    /// Parse the canonical label (case-insensitive). Returns `None` on
    /// syntax errors; width-range violations are deferred to
    /// [`QuantSpec::validate`] so boundaries can report the field.
    pub fn parse(s: &str) -> Option<QuantSpec> {
        let s = s.to_ascii_lowercase();
        let rest = s.strip_prefix('a')?;
        let (act, rest) = split_digits(rest)?;
        let rest = rest.strip_prefix('w')?;
        let (wt, rest) = split_digits(rest)?;
        let rest = rest.strip_prefix('p')?;
        let (psum, rest) = split_digits(rest)?;
        let mac = if rest.is_empty() {
            MacKind::IntExact
        } else {
            MacKind::parse(rest.strip_prefix('-')?)?
        };
        Some(QuantSpec { act_bits: act, wt_bits: wt, psum_bits: psum, mac })
    }

    /// Bit-width sanity: operands in 1..=[`MAX_BITS`], accumulator at least
    /// as wide as both operands, lightweight term count in range. Errors
    /// name the offending field.
    pub fn validate(&self) -> Result<(), QappaError> {
        let err = |m: String| Err(QappaError::Config(m));
        for (field, bits) in [
            ("act_bits", self.act_bits),
            ("wt_bits", self.wt_bits),
            ("psum_bits", self.psum_bits),
        ] {
            if bits == 0 {
                return err(format!("quant spec: {field} must be >= 1 bit"));
            }
            if bits > MAX_BITS {
                return err(format!("quant spec: {field} = {bits} exceeds the generator limit of {MAX_BITS} bits"));
            }
        }
        if self.psum_bits < self.act_bits {
            return err(format!(
                "quant spec: psum_bits = {} narrower than act_bits = {}",
                self.psum_bits, self.act_bits
            ));
        }
        if self.psum_bits < self.wt_bits {
            return err(format!(
                "quant spec: psum_bits = {} narrower than wt_bits = {}",
                self.psum_bits, self.wt_bits
            ));
        }
        if let MacKind::Lightweight(n) = self.mac {
            if n == 0 {
                return err("quant spec: mac = light0 needs at least 1 shift-add term".into());
            }
            if n > MAX_SHIFT_TERMS {
                return err(format!(
                    "quant spec: mac = light{n} exceeds the generator limit of {MAX_SHIFT_TERMS} shift-add terms"
                ));
            }
        }
        Ok(())
    }
}

/// Automatic accumulator width for a grid cell without an explicit psum
/// axis: wide enough for the product plus accumulation margin, monotone in
/// both operand widths, capped at [`MAX_BITS`].
pub fn auto_psum(act_bits: u32, wt_bits: u32, mac: MacKind) -> u32 {
    let raw = match mac {
        // Full-precision product + headroom.
        MacKind::IntExact => act_bits + wt_bits,
        // Shifted activation (range ~act-1) + term/accumulation margin.
        MacKind::Lightweight(n) => 2 * act_bits + 4 + 2 * n.min(MAX_SHIFT_TERMS),
        // FP accumulates at the operand format's own width.
        MacKind::Fp => act_bits.max(wt_bits),
    };
    raw.max(act_bits.max(wt_bits)).min(MAX_BITS)
}

/// Split a leading run of ASCII digits; `None` if empty or unparseable.
fn split_digits(s: &str) -> Option<(u32, &str)> {
    let end = s.bytes().position(|b| !b.is_ascii_digit()).unwrap_or(s.len());
    if end == 0 {
        return None;
    }
    s[..end].parse::<u32>().ok().map(|v| (v, &s[end..]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_parse_roundtrip() {
        for spec in [
            QuantSpec { act_bits: 8, wt_bits: 4, psum_bits: 20, mac: MacKind::Lightweight(1) },
            QuantSpec { act_bits: 16, wt_bits: 16, psum_bits: 32, mac: MacKind::IntExact },
            QuantSpec { act_bits: 32, wt_bits: 32, psum_bits: 32, mac: MacKind::Fp },
            QuantSpec::int(4, 4),
            QuantSpec::light(6, 3, 2),
        ] {
            let label = spec.label();
            assert_eq!(QuantSpec::parse(&label), Some(spec), "{label}");
            // case-insensitive
            assert_eq!(QuantSpec::parse(&label.to_ascii_uppercase()), Some(spec));
        }
        // default mac is int
        assert_eq!(
            QuantSpec::parse("a8w8p16"),
            Some(QuantSpec { act_bits: 8, wt_bits: 8, psum_bits: 16, mac: MacKind::IntExact })
        );
        for bad in ["", "a8", "a8w4", "a8w4p", "w4p8a8", "a8w4p20-lightx", "a8w4p20+int", "bogus"] {
            assert_eq!(QuantSpec::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn validate_rejects_zero_and_oversized_and_narrow_psum() {
        // builder boundary: QuantSpec::new rejects with the field named
        let e = QuantSpec::new(0, 8, 16, MacKind::IntExact).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("act_bits"), "{e}");
        let e = QuantSpec::new(8, 0, 16, MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("wt_bits"), "{e}");
        let e = QuantSpec::new(8, 8, 0, MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("psum_bits"), "{e}");
        let e = QuantSpec::new(65, 8, 70, MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("act_bits") && e.to_string().contains("64"), "{e}");
        let e = QuantSpec::new(16, 8, 12, MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("psum_bits") && e.to_string().contains("act_bits"), "{e}");
        let e = QuantSpec::new(4, 8, 6, MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("wt_bits"), "{e}");
        let e = QuantSpec::new(8, 4, 20, MacKind::Lightweight(0)).unwrap_err();
        assert!(e.to_string().contains("light0"), "{e}");
        assert!(QuantSpec::new(8, 4, 20, MacKind::Lightweight(1)).is_ok());
        assert!(QuantSpec::new(64, 64, 64, MacKind::IntExact).is_ok());
    }

    #[test]
    fn auto_psum_monotone_and_covers_presets_shape() {
        // int: act+wt (INT16-compatible: 16+16 = 32)
        assert_eq!(auto_psum(16, 16, MacKind::IntExact), 32);
        // monotone in each operand axis
        for w in [2u32, 4, 8, 16, 32] {
            assert!(auto_psum(w + 1, 8, MacKind::IntExact) >= auto_psum(w, 8, MacKind::IntExact));
            assert!(auto_psum(8, w + 1, MacKind::IntExact) >= auto_psum(8, w, MacKind::IntExact));
            assert!(
                auto_psum(w + 1, 4, MacKind::Lightweight(2)) >= auto_psum(w, 4, MacKind::Lightweight(2))
            );
        }
        // never below the operands, never above the cap
        for a in [1u32, 7, 33, 64] {
            for mac in [MacKind::Fp, MacKind::IntExact, MacKind::Lightweight(1)] {
                let p = auto_psum(a, a, mac);
                assert!(p >= a && p <= MAX_BITS, "a{a} {mac:?} -> {p}");
                QuantSpec { act_bits: a, wt_bits: a, psum_bits: p, mac }.validate().unwrap();
            }
        }
    }

    #[test]
    fn mac_kind_suffix_roundtrip() {
        for mac in [MacKind::Fp, MacKind::IntExact, MacKind::Lightweight(1), MacKind::Lightweight(3)] {
            assert_eq!(MacKind::parse(&mac.suffix()), Some(mac));
        }
        assert_eq!(MacKind::parse("nope"), None);
        assert_eq!(MacKind::Lightweight(2).shift_terms(), 2);
        assert_eq!(MacKind::Fp.shift_terms(), 0);
    }
}
