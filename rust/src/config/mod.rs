//! Accelerator configuration types — the axes of QAPPA's design space.
//!
//! A configuration fixes the spatial-array accelerator the paper's RTL
//! generator would emit: PE type (bit precision + datapath style), PE array
//! geometry, per-PE scratchpad capacities, global buffer size and device
//! bandwidth.  `features()` produces the 7-vector consumed by the regression
//! models, in the exact order pinned by `artifacts/manifest.json`.

use crate::api::error::QappaError;
use crate::util::json::{obj, Json};

/// Processing-element type: precision + datapath style.
///
/// * `Fp32`     — IEEE-754 single-precision multiply-accumulate.
/// * `Int16`    — 16-bit integer MAC (the paper's normalization baseline).
/// * `LightPe1` — 8-bit activations x 4-bit weights; the multiply is
///   replaced by **one** shift (LightNN-style sign + power-of-two weight).
/// * `LightPe2` — 8-bit activations x 8-bit weights; **two** shift-add
///   terms (sum of two signed powers of two).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PeType {
    Fp32,
    Int16,
    LightPe1,
    LightPe2,
}

pub const ALL_PE_TYPES: [PeType; 4] =
    [PeType::Fp32, PeType::Int16, PeType::LightPe1, PeType::LightPe2];

impl PeType {
    pub fn label(self) -> &'static str {
        match self {
            PeType::Fp32 => "FP32",
            PeType::Int16 => "INT16",
            PeType::LightPe1 => "LightPE-1",
            PeType::LightPe2 => "LightPE-2",
        }
    }

    pub fn parse(s: &str) -> Option<PeType> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(PeType::Fp32),
            "int16" => Some(PeType::Int16),
            "lightpe1" | "lightpe-1" | "light1" => Some(PeType::LightPe1),
            "lightpe2" | "lightpe-2" | "light2" => Some(PeType::LightPe2),
            _ => None,
        }
    }

    /// Activation operand width in bits.
    pub fn act_bits(self) -> u32 {
        match self {
            PeType::Fp32 => 32,
            PeType::Int16 => 16,
            PeType::LightPe1 | PeType::LightPe2 => 8,
        }
    }

    /// Weight operand width in bits.
    pub fn wt_bits(self) -> u32 {
        match self {
            PeType::Fp32 => 32,
            PeType::Int16 => 16,
            PeType::LightPe1 => 4,
            PeType::LightPe2 => 8,
        }
    }

    /// Partial-sum (accumulator) width in bits.
    pub fn psum_bits(self) -> u32 {
        match self {
            PeType::Fp32 => 32,
            PeType::Int16 => 32,
            // 8b act shifted by up to 7 (1 or 2 terms) + accumulation margin.
            PeType::LightPe1 => 20,
            PeType::LightPe2 => 24,
        }
    }

    /// Number of shift-add terms replacing the multiplier (0 = real multiply).
    pub fn shift_terms(self) -> u32 {
        match self {
            PeType::Fp32 | PeType::Int16 => 0,
            PeType::LightPe1 => 1,
            PeType::LightPe2 => 2,
        }
    }

    pub fn is_light(self) -> bool {
        self.shift_terms() > 0
    }
}

/// One point in the accelerator design space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AcceleratorConfig {
    pub pe_type: PeType,
    /// PE array geometry.
    pub pe_rows: u32,
    pub pe_cols: u32,
    /// Global buffer capacity in KiB.
    pub glb_kb: u32,
    /// Per-PE scratchpad capacities in **bytes**.
    pub spad_ifmap_b: u32,
    pub spad_filter_b: u32,
    pub spad_psum_b: u32,
    /// Device (DRAM) bandwidth in GB/s.
    pub bandwidth_gbps: f64,
}

/// Number of regression features (must match `manifest.json: d`).
pub const NUM_FEATURES: usize = 7;

impl AcceleratorConfig {
    /// A mid-range Eyeriss-like default used by examples and tests.
    pub fn default_with(pe_type: PeType) -> AcceleratorConfig {
        AcceleratorConfig {
            pe_type,
            pe_rows: 12,
            pe_cols: 14,
            glb_kb: 108,
            spad_ifmap_b: 48,
            spad_filter_b: 448,
            spad_psum_b: 64,
            bandwidth_gbps: 4.0,
        }
    }

    pub fn num_pes(&self) -> u32 {
        self.pe_rows * self.pe_cols
    }

    /// Regression feature vector (order pinned by `manifest.json:
    /// feature_order` = [pe_rows, pe_cols, glb_kb, spad_ifmap_b,
    /// spad_filter_b, spad_psum_b, bandwidth_gbps]).
    pub fn features(&self) -> [f64; NUM_FEATURES] {
        [
            self.pe_rows as f64,
            self.pe_cols as f64,
            self.glb_kb as f64,
            self.spad_ifmap_b as f64,
            self.spad_filter_b as f64,
            self.spad_psum_b as f64,
            self.bandwidth_gbps,
        ]
    }

    /// Validity constraints of the RTL generator.
    pub fn validate(&self) -> Result<(), QappaError> {
        let err = |m: String| Err(QappaError::Config(m));
        if self.pe_rows == 0 || self.pe_cols == 0 {
            return err(format!("PE array must be non-empty: {}x{}", self.pe_rows, self.pe_cols));
        }
        if self.pe_rows > 256 || self.pe_cols > 256 {
            return err(format!("PE array {}x{} exceeds generator limit 256", self.pe_rows, self.pe_cols));
        }
        if self.glb_kb == 0 {
            return err("global buffer must be > 0 KiB".into());
        }
        if self.spad_ifmap_b == 0 || self.spad_filter_b == 0 || self.spad_psum_b == 0 {
            return err("scratchpads must be > 0 bytes".into());
        }
        if !(self.bandwidth_gbps > 0.0) {
            return err("bandwidth must be positive".into());
        }
        Ok(())
    }

    /// Stable identity string (used to key synthesis jitter and caches).
    pub fn key(&self) -> String {
        format!(
            "{}:r{}c{}:g{}:s{}/{}/{}:bw{:.3}",
            self.pe_type.label(),
            self.pe_rows,
            self.pe_cols,
            self.glb_kb,
            self.spad_ifmap_b,
            self.spad_filter_b,
            self.spad_psum_b,
            self.bandwidth_gbps
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("pe_type", Json::Str(self.pe_type.label().into())),
            ("pe_rows", Json::Num(self.pe_rows as f64)),
            ("pe_cols", Json::Num(self.pe_cols as f64)),
            ("glb_kb", Json::Num(self.glb_kb as f64)),
            ("spad_ifmap_b", Json::Num(self.spad_ifmap_b as f64)),
            ("spad_filter_b", Json::Num(self.spad_filter_b as f64)),
            ("spad_psum_b", Json::Num(self.spad_psum_b as f64)),
            ("bandwidth_gbps", Json::Num(self.bandwidth_gbps)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<AcceleratorConfig> {
        Some(AcceleratorConfig {
            pe_type: PeType::parse(v.get("pe_type").as_str()?)?,
            pe_rows: v.get("pe_rows").as_usize()? as u32,
            pe_cols: v.get("pe_cols").as_usize()? as u32,
            glb_kb: v.get("glb_kb").as_usize()? as u32,
            spad_ifmap_b: v.get("spad_ifmap_b").as_usize()? as u32,
            spad_filter_b: v.get("spad_filter_b").as_usize()? as u32,
            spad_psum_b: v.get("spad_psum_b").as_usize()? as u32,
            bandwidth_gbps: v.get("bandwidth_gbps").as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_type_parse_roundtrip() {
        for t in ALL_PE_TYPES {
            assert_eq!(PeType::parse(t.label()), Some(t));
        }
        assert_eq!(PeType::parse("lightpe-2"), Some(PeType::LightPe2));
        assert_eq!(PeType::parse("bogus"), None);
    }

    #[test]
    fn precision_ladder() {
        // storage footprint must strictly shrink FP32 -> INT16 -> LightPE
        assert!(PeType::Fp32.act_bits() > PeType::Int16.act_bits());
        assert!(PeType::Int16.wt_bits() > PeType::LightPe2.wt_bits());
        assert!(PeType::LightPe2.wt_bits() > PeType::LightPe1.wt_bits());
        assert!(PeType::LightPe1.is_light() && PeType::LightPe2.is_light());
        assert!(!PeType::Int16.is_light());
    }

    #[test]
    fn features_order_matches_manifest_contract() {
        let c = AcceleratorConfig::default_with(PeType::Int16);
        let f = c.features();
        assert_eq!(f[0], c.pe_rows as f64);
        assert_eq!(f[1], c.pe_cols as f64);
        assert_eq!(f[2], c.glb_kb as f64);
        assert_eq!(f[3], c.spad_ifmap_b as f64);
        assert_eq!(f[4], c.spad_filter_b as f64);
        assert_eq!(f[5], c.spad_psum_b as f64);
        assert_eq!(f[6], c.bandwidth_gbps);
    }

    #[test]
    fn validate_rejects_degenerate() {
        let mut c = AcceleratorConfig::default_with(PeType::Fp32);
        c.validate().unwrap();
        c.pe_rows = 0;
        assert!(c.validate().is_err());
        let mut c2 = AcceleratorConfig::default_with(PeType::Fp32);
        c2.bandwidth_gbps = -1.0;
        assert!(c2.validate().is_err());
        let mut c3 = AcceleratorConfig::default_with(PeType::Fp32);
        c3.glb_kb = 0;
        assert!(c3.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = AcceleratorConfig::default_with(PeType::LightPe1);
        let j = c.to_json().to_string();
        let back = AcceleratorConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn key_distinguishes_configs() {
        let a = AcceleratorConfig::default_with(PeType::Int16);
        let mut b = a;
        b.glb_kb += 1;
        assert_ne!(a.key(), b.key());
    }
}
