//! Accelerator configuration types — the axes of QAPPA's design space.
//!
//! A configuration fixes the spatial-array accelerator the paper's RTL
//! generator would emit: PE precision ([`QuantSpec`]: operand bit widths +
//! datapath style, selected through [`PeType`]), PE array geometry, per-PE
//! scratchpad capacities, global buffer size and device bandwidth.
//! `features()` produces the 7-vector consumed by the per-type regression
//! models, in the exact order pinned by `artifacts/manifest.json`;
//! `features_quant()` appends the precision axes for the unified
//! cross-precision model (`docs/PRECISION.md`).

pub mod quant;

pub use quant::{auto_psum, MacKind, QuantSpec};

use crate::api::error::QappaError;
use crate::util::json::{obj, Json};
use crate::util::prng::hash64;

/// Processing-element precision selector: a named preset or an arbitrary
/// [`QuantSpec`].
///
/// The presets are the paper's four PE types, each resolving to a
/// [`QuantSpec`] via [`PeType::spec`]:
///
/// * `Fp32`     — IEEE-754 single-precision FMA (`a32w32p32-fp`).
/// * `Int16`    — 16-bit integer MAC, the normalization baseline
///   (`a16w16p32-int`).
/// * `LightPe1` — 8-bit activations x 4-bit weights; the multiply is
///   replaced by **one** shift (LightNN-style sign + power-of-two weight;
///   `a8w4p20-light1`).
/// * `LightPe2` — 8-bit activations x 8-bit weights; **two** shift-add
///   terms (`a8w8p24-light2`).
///
/// `Quant` carries any other width/datapath combination — every consumer
/// in the crate sizes hardware from the resolved spec, so arbitrary
/// precisions flow through synthesis, dataflow and the DSE unchanged.
/// [`PeType::parse`] accepts preset aliases *and* generic spec labels
/// (`a8w4p20-light1`); [`PeType::from_spec`] canonicalizes specs that
/// exactly match a preset back to the preset name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PeType {
    Fp32,
    Int16,
    LightPe1,
    LightPe2,
    Quant(QuantSpec),
}

pub const ALL_PE_TYPES: [PeType; 4] =
    [PeType::Fp32, PeType::Int16, PeType::LightPe1, PeType::LightPe2];

impl PeType {
    /// Resolve to the underlying quantization spec — the single source of
    /// truth every bit-width consumer reads.
    pub fn spec(self) -> QuantSpec {
        match self {
            PeType::Fp32 => QuantSpec { act_bits: 32, wt_bits: 32, psum_bits: 32, mac: MacKind::Fp },
            PeType::Int16 => {
                QuantSpec { act_bits: 16, wt_bits: 16, psum_bits: 32, mac: MacKind::IntExact }
            }
            // 8b act shifted by up to 7 (1 or 2 terms) + accumulation margin.
            PeType::LightPe1 => {
                QuantSpec { act_bits: 8, wt_bits: 4, psum_bits: 20, mac: MacKind::Lightweight(1) }
            }
            PeType::LightPe2 => {
                QuantSpec { act_bits: 8, wt_bits: 8, psum_bits: 24, mac: MacKind::Lightweight(2) }
            }
            PeType::Quant(q) => q,
        }
    }

    /// Wrap a spec, canonicalizing exact preset matches back to the preset
    /// (so `a16w16p32-int` displays — and hashes — as `INT16`).
    pub fn from_spec(q: QuantSpec) -> PeType {
        for t in ALL_PE_TYPES {
            if t.spec() == q {
                return t;
            }
        }
        PeType::Quant(q)
    }

    /// True for the four named presets.
    pub fn is_preset(self) -> bool {
        !matches!(self, PeType::Quant(_))
    }

    pub fn label(self) -> String {
        match self {
            PeType::Fp32 => "FP32".to_string(),
            PeType::Int16 => "INT16".to_string(),
            PeType::LightPe1 => "LightPE-1".to_string(),
            PeType::LightPe2 => "LightPE-2".to_string(),
            PeType::Quant(q) => q.label(),
        }
    }

    /// Parse a preset alias (`fp32`, `int16`, `lightpe-1`, …) or a generic
    /// spec label (`a8w4p20-light1`), case-insensitively.  Width-range
    /// violations in generic labels parse successfully and are rejected by
    /// [`QuantSpec::validate`] at the consuming boundary, which names the
    /// offending field.
    pub fn parse(s: &str) -> Option<PeType> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(PeType::Fp32),
            "int16" => Some(PeType::Int16),
            "lightpe1" | "lightpe-1" | "light1" => Some(PeType::LightPe1),
            "lightpe2" | "lightpe-2" | "light2" => Some(PeType::LightPe2),
            other => QuantSpec::parse(other).map(PeType::from_spec),
        }
    }

    /// Activation operand width in bits.
    pub fn act_bits(self) -> u32 {
        self.spec().act_bits
    }

    /// Weight operand width in bits.
    pub fn wt_bits(self) -> u32 {
        self.spec().wt_bits
    }

    /// Partial-sum (accumulator) width in bits.
    pub fn psum_bits(self) -> u32 {
        self.spec().psum_bits
    }

    /// Number of shift-add terms replacing the multiplier (0 = real multiply).
    pub fn shift_terms(self) -> u32 {
        self.spec().shift_terms()
    }

    pub fn is_light(self) -> bool {
        self.shift_terms() > 0
    }

    /// Stable per-type stream id for seeded sampling.  Presets keep their
    /// historical discriminant values (0..=3) so every sampled training set
    /// — and therefore every trained model and DSE report — stays
    /// bit-identical to the closed-enum era; arbitrary specs hash their
    /// canonical label.
    pub(crate) fn stream_id(self) -> u64 {
        match self {
            PeType::Fp32 => 0,
            PeType::Int16 => 1,
            PeType::LightPe1 => 2,
            PeType::LightPe2 => 3,
            PeType::Quant(q) => hash64(q.label().as_bytes()),
        }
    }
}

/// One point in the accelerator design space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AcceleratorConfig {
    pub pe_type: PeType,
    /// PE array geometry.
    pub pe_rows: u32,
    pub pe_cols: u32,
    /// Global buffer capacity in KiB.
    pub glb_kb: u32,
    /// Per-PE scratchpad capacities in **bytes**.
    pub spad_ifmap_b: u32,
    pub spad_filter_b: u32,
    pub spad_psum_b: u32,
    /// Device (DRAM) bandwidth in GB/s.
    pub bandwidth_gbps: f64,
}

/// Number of regression features (must match `manifest.json: d`).
pub const NUM_FEATURES: usize = 7;

/// Feature count of the unified cross-precision model: the 7 base axes
/// plus [act_bits, wt_bits, psum_bits, shift_terms, mac-kind code].  The
/// AOT XLA artifacts are lowered for `d = NUM_FEATURES`, so precision-grid
/// sweeps always run the native backend (see `docs/PRECISION.md`).
pub const QUANT_NUM_FEATURES: usize = NUM_FEATURES + 5;

impl AcceleratorConfig {
    /// A mid-range Eyeriss-like default used by examples and tests.
    pub fn default_with(pe_type: PeType) -> AcceleratorConfig {
        AcceleratorConfig {
            pe_type,
            pe_rows: 12,
            pe_cols: 14,
            glb_kb: 108,
            spad_ifmap_b: 48,
            spad_filter_b: 448,
            spad_psum_b: 64,
            bandwidth_gbps: 4.0,
        }
    }

    pub fn num_pes(&self) -> u32 {
        self.pe_rows * self.pe_cols
    }

    /// The configuration's resolved quantization spec — the hot-path read
    /// every synthesis/dataflow consumer sizes hardware from.
    pub fn quant(&self) -> QuantSpec {
        self.pe_type.spec()
    }

    /// Copy of this configuration with a different precision (used to
    /// apply per-layer precision overrides and to walk precision axes).
    pub fn with_pe_type(mut self, pe_type: PeType) -> AcceleratorConfig {
        self.pe_type = pe_type;
        self
    }

    /// Regression feature vector (order pinned by `manifest.json:
    /// feature_order` = [pe_rows, pe_cols, glb_kb, spad_ifmap_b,
    /// spad_filter_b, spad_psum_b, bandwidth_gbps]).
    pub fn features(&self) -> [f64; NUM_FEATURES] {
        [
            self.pe_rows as f64,
            self.pe_cols as f64,
            self.glb_kb as f64,
            self.spad_ifmap_b as f64,
            self.spad_filter_b as f64,
            self.spad_psum_b as f64,
            self.bandwidth_gbps,
        ]
    }

    /// Extended feature vector for the unified cross-precision model: the
    /// 7 base features followed by [act_bits, wt_bits, psum_bits,
    /// shift_terms, mac-kind code].  One model fitted on these generalizes
    /// across bit widths instead of training once per PE type.
    pub fn features_quant(&self) -> [f64; QUANT_NUM_FEATURES] {
        let base = self.features();
        let q = self.quant();
        [
            base[0],
            base[1],
            base[2],
            base[3],
            base[4],
            base[5],
            base[6],
            q.act_bits as f64,
            q.wt_bits as f64,
            q.psum_bits as f64,
            q.shift_terms() as f64,
            q.mac.code(),
        ]
    }

    /// Validity constraints of the RTL generator.
    pub fn validate(&self) -> Result<(), QappaError> {
        // Precision first: bit-width violations (0-bit / >64-bit operands,
        // psum narrower than an operand) are rejected with the offending
        // field named, at every boundary that calls validate().
        self.quant().validate()?;
        let err = |m: String| Err(QappaError::Config(m));
        if self.pe_rows == 0 || self.pe_cols == 0 {
            return err(format!("PE array must be non-empty: {}x{}", self.pe_rows, self.pe_cols));
        }
        if self.pe_rows > 256 || self.pe_cols > 256 {
            return err(format!("PE array {}x{} exceeds generator limit 256", self.pe_rows, self.pe_cols));
        }
        if self.glb_kb == 0 {
            return err("global buffer must be > 0 KiB".into());
        }
        if self.spad_ifmap_b == 0 || self.spad_filter_b == 0 || self.spad_psum_b == 0 {
            return err("scratchpads must be > 0 bytes".into());
        }
        if !(self.bandwidth_gbps > 0.0) {
            return err("bandwidth must be positive".into());
        }
        Ok(())
    }

    /// Stable identity string (used to key synthesis jitter and caches).
    pub fn key(&self) -> String {
        format!(
            "{}:r{}c{}:g{}:s{}/{}/{}:bw{:.3}",
            self.pe_type.label(),
            self.pe_rows,
            self.pe_cols,
            self.glb_kb,
            self.spad_ifmap_b,
            self.spad_filter_b,
            self.spad_psum_b,
            self.bandwidth_gbps
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("pe_type", Json::Str(self.pe_type.label())),
            ("pe_rows", Json::Num(self.pe_rows as f64)),
            ("pe_cols", Json::Num(self.pe_cols as f64)),
            ("glb_kb", Json::Num(self.glb_kb as f64)),
            ("spad_ifmap_b", Json::Num(self.spad_ifmap_b as f64)),
            ("spad_filter_b", Json::Num(self.spad_filter_b as f64)),
            ("spad_psum_b", Json::Num(self.spad_psum_b as f64)),
            ("bandwidth_gbps", Json::Num(self.bandwidth_gbps)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<AcceleratorConfig> {
        Some(AcceleratorConfig {
            pe_type: PeType::parse(v.get("pe_type").as_str()?)?,
            pe_rows: v.get("pe_rows").as_usize()? as u32,
            pe_cols: v.get("pe_cols").as_usize()? as u32,
            glb_kb: v.get("glb_kb").as_usize()? as u32,
            spad_ifmap_b: v.get("spad_ifmap_b").as_usize()? as u32,
            spad_filter_b: v.get("spad_filter_b").as_usize()? as u32,
            spad_psum_b: v.get("spad_psum_b").as_usize()? as u32,
            bandwidth_gbps: v.get("bandwidth_gbps").as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_type_parse_roundtrip() {
        for t in ALL_PE_TYPES {
            assert_eq!(PeType::parse(&t.label()), Some(t));
        }
        assert_eq!(PeType::parse("lightpe-2"), Some(PeType::LightPe2));
        assert_eq!(PeType::parse("bogus"), None);
    }

    #[test]
    fn presets_resolve_to_their_specs_and_back() {
        // The preset spec table is the single source of truth: the legacy
        // accessor values are pinned here, and canonicalization maps each
        // spec back to its preset name.
        for (t, a, w, p, terms) in [
            (PeType::Fp32, 32, 32, 32, 0),
            (PeType::Int16, 16, 16, 32, 0),
            (PeType::LightPe1, 8, 4, 20, 1),
            (PeType::LightPe2, 8, 8, 24, 2),
        ] {
            let q = t.spec();
            assert_eq!((q.act_bits, q.wt_bits, q.psum_bits, q.shift_terms()), (a, w, p, terms));
            assert_eq!((t.act_bits(), t.wt_bits(), t.psum_bits(), t.shift_terms()), (a, w, p, terms));
            assert_eq!(PeType::from_spec(q), t, "canonicalize {t:?}");
            assert_eq!(PeType::parse(&q.label()), Some(t), "generic label -> preset");
            q.validate().unwrap();
        }
        // preset stream ids keep the closed-enum discriminants
        assert_eq!(
            ALL_PE_TYPES.map(|t| t.stream_id()),
            [0, 1, 2, 3],
            "preset sampling streams must stay bit-identical"
        );
    }

    #[test]
    fn quant_pe_types_parse_label_and_json_roundtrip() {
        let q = QuantSpec::new(6, 3, 14, MacKind::Lightweight(1)).unwrap();
        let t = PeType::from_spec(q);
        assert!(!t.is_preset());
        assert_eq!(t.label(), "a6w3p14-light1");
        assert_eq!(PeType::parse("A6W3P14-LIGHT1"), Some(t), "case-insensitive");
        let c = AcceleratorConfig::default_with(t);
        c.validate().unwrap();
        let j = c.to_json().to_string();
        let back = AcceleratorConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, c);
        assert!(c.key().starts_with("a6w3p14-light1:"), "{}", c.key());
    }

    #[test]
    fn validate_rejects_bad_bit_widths_at_the_config_boundary() {
        for (label, field) in [
            ("a0w8p16-int", "act_bits"),
            ("a8w0p16-int", "wt_bits"),
            ("a8w8p0-int", "psum_bits"),
            ("a65w8p65-int", "act_bits"),
            ("a16w8p12-int", "psum_bits"),
        ] {
            let t = PeType::parse(label).expect(label);
            let e = AcceleratorConfig::default_with(t).validate().unwrap_err();
            assert_eq!(e.kind(), "config", "{label}");
            assert!(e.to_string().contains(field), "{label}: {e}");
        }
    }

    #[test]
    fn features_quant_extends_base_features() {
        let c = AcceleratorConfig::default_with(PeType::LightPe2);
        let f = c.features();
        let fq = c.features_quant();
        assert_eq!(&fq[..NUM_FEATURES], &f[..]);
        assert_eq!(fq[7], 8.0); // act
        assert_eq!(fq[8], 8.0); // wt
        assert_eq!(fq[9], 24.0); // psum
        assert_eq!(fq[10], 2.0); // shift terms
        assert_eq!(fq[11], MacKind::Lightweight(2).code());
        assert_eq!(QUANT_NUM_FEATURES, 12);
    }

    #[test]
    fn precision_ladder() {
        // storage footprint must strictly shrink FP32 -> INT16 -> LightPE
        assert!(PeType::Fp32.act_bits() > PeType::Int16.act_bits());
        assert!(PeType::Int16.wt_bits() > PeType::LightPe2.wt_bits());
        assert!(PeType::LightPe2.wt_bits() > PeType::LightPe1.wt_bits());
        assert!(PeType::LightPe1.is_light() && PeType::LightPe2.is_light());
        assert!(!PeType::Int16.is_light());
    }

    #[test]
    fn features_order_matches_manifest_contract() {
        let c = AcceleratorConfig::default_with(PeType::Int16);
        let f = c.features();
        assert_eq!(f[0], c.pe_rows as f64);
        assert_eq!(f[1], c.pe_cols as f64);
        assert_eq!(f[2], c.glb_kb as f64);
        assert_eq!(f[3], c.spad_ifmap_b as f64);
        assert_eq!(f[4], c.spad_filter_b as f64);
        assert_eq!(f[5], c.spad_psum_b as f64);
        assert_eq!(f[6], c.bandwidth_gbps);
    }

    #[test]
    fn validate_rejects_degenerate() {
        let mut c = AcceleratorConfig::default_with(PeType::Fp32);
        c.validate().unwrap();
        c.pe_rows = 0;
        assert!(c.validate().is_err());
        let mut c2 = AcceleratorConfig::default_with(PeType::Fp32);
        c2.bandwidth_gbps = -1.0;
        assert!(c2.validate().is_err());
        let mut c3 = AcceleratorConfig::default_with(PeType::Fp32);
        c3.glb_kb = 0;
        assert!(c3.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = AcceleratorConfig::default_with(PeType::LightPe1);
        let j = c.to_json().to_string();
        let back = AcceleratorConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn key_distinguishes_configs() {
        let a = AcceleratorConfig::default_with(PeType::Int16);
        let mut b = a;
        b.glb_kb += 1;
        assert_ne!(a.key(), b.key());
    }
}
