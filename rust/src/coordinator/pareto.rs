//! Pareto-frontier extraction for (maximize perf/area, minimize energy).

/// Return the indices of the Pareto-optimal points among
/// `(perf_per_area, energy)` pairs: no other point has >= perf/area AND
/// <= energy with at least one strict.
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // sort by perf/area descending, energy ascending as tiebreak
    idx.sort_by(|&a, &b| {
        points[b]
            .0
            .partial_cmp(&points[a].0)
            .unwrap()
            .then(points[a].1.partial_cmp(&points[b].1).unwrap())
    });
    let mut out = Vec::new();
    let mut best_energy = f64::INFINITY;
    let mut last_pa = f64::INFINITY;
    for &i in &idx {
        let (pa, e) = points[i];
        if e < best_energy {
            // strictly better energy than everything with >= perf/area
            out.push(i);
            best_energy = e;
            last_pa = pa;
        } else if e == best_energy && pa == last_pa {
            // exact duplicates of a frontier point are dominated (keep one)
        }
    }
    out.sort();
    out
}

/// True iff `a` dominates `b` (>= perf/area, <= energy, one strict).
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::prng::Rng;

    #[test]
    fn simple_frontier() {
        // (pa, energy): point 1 dominates 0; 2 is incomparable to 1.
        let pts = vec![(1.0, 5.0), (2.0, 3.0), (1.5, 1.0)];
        let f = pareto_frontier(&pts);
        assert_eq!(f, vec![1, 2]);
    }

    #[test]
    fn single_and_empty() {
        assert_eq!(pareto_frontier(&[]), Vec::<usize>::new());
        assert_eq!(pareto_frontier(&[(1.0, 1.0)]), vec![0]);
    }

    #[test]
    fn dominated_duplicates_removed() {
        let pts = vec![(2.0, 3.0), (2.0, 3.0), (2.0, 3.0)];
        let f = pareto_frontier(&pts);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn property_frontier_has_no_dominated_member() {
        testkit::forall(
            "no dominated member",
            200,
            11,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 10.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = pareto_frontier(pts);
                for &i in &f {
                    for (j, &q) in pts.iter().enumerate() {
                        if i != j && dominates(q, pts[i]) {
                            return Err(format!("frontier member {i} dominated by {j}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_every_point_dominated_by_some_frontier_member() {
        testkit::forall(
            "coverage",
            200,
            13,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 10.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = pareto_frontier(pts);
                for (j, &q) in pts.iter().enumerate() {
                    let covered = f.iter().any(|&i| i == j || dominates(pts[i], q))
                        // equal points count as covered
                        || f.iter().any(|&i| pts[i] == q);
                    if !covered {
                        return Err(format!("point {j} not covered by frontier"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_idempotent() {
        testkit::forall(
            "idempotent",
            100,
            17,
            |rng: &mut Rng| {
                let n = 1 + rng.below(30);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 4.0), rng.range_f64(0.0, 4.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = pareto_frontier(pts);
                let sub: Vec<(f64, f64)> = f.iter().map(|&i| pts[i]).collect();
                let f2 = pareto_frontier(&sub);
                if f2.len() != sub.len() {
                    return Err(format!("re-running dropped {} points", sub.len() - f2.len()));
                }
                Ok(())
            },
        );
    }
}
