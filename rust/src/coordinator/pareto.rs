//! Pareto-frontier extraction for (maximize perf/area, minimize energy),
//! plus an N-objective minimized-space variant ([`IncrementalFrontierNd`],
//! [`hypervolume_min`]) for the optimizer's 3-objective runs.

/// Return the indices of the Pareto-optimal points among
/// `(perf_per_area, energy)` pairs: no other point has >= perf/area AND
/// <= energy with at least one strict.
///
/// Points with a NaN coordinate are excluded outright: a degenerate
/// prediction must neither panic the sweep nor (since NaN sorts above
/// every finite value under `total_cmp`) shadow genuine frontier members.
/// This mirrors [`IncrementalFrontier`], which rejects NaN on push.
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len())
        .filter(|&i| !points[i].0.is_nan() && !points[i].1.is_nan())
        .collect();
    // sort by perf/area descending, energy ascending as tiebreak
    idx.sort_by(|&a, &b| {
        points[b]
            .0
            .total_cmp(&points[a].0)
            .then(points[a].1.total_cmp(&points[b].1))
    });
    let mut out = Vec::new();
    let mut best_energy = f64::INFINITY;
    let mut last_pa = f64::INFINITY;
    for &i in &idx {
        let (pa, e) = points[i];
        if e < best_energy {
            // strictly better energy than everything with >= perf/area
            out.push(i);
            best_energy = e;
            last_pa = pa;
        } else if e == best_energy && pa == last_pa {
            // exact duplicates of a frontier point are dominated (keep one)
        }
    }
    out.sort();
    out
}

/// True iff `a` dominates `b` (>= perf/area, <= energy, one strict).
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
}

/// Scalar quality of a point set: the 2-D hypervolume (area) dominated by
/// the set's Pareto frontier relative to a reference corner, under the
/// frontier convention of this module (maximize the first coordinate,
/// minimize the second).
///
/// `ref_point = (rx, ry)` is the anti-optimal corner: `rx` a lower bound on
/// the first coordinate, `ry` an upper bound on the second.  Points that do
/// not strictly improve on the corner (or carry a NaN) contribute nothing;
/// the union-of-rectangles area is computed over the frontier only, so
/// inserting a dominated point can never change the result.  This is the
/// optimizer's convergence currency (`crate::opt`): guided-search quality
/// is measured as recovered hypervolume versus the exhaustive sweep.
pub fn hypervolume(points: &[(f64, f64)], ref_point: (f64, f64)) -> f64 {
    let (rx, ry) = ref_point;
    let pts: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|&(x, y)| !x.is_nan() && !y.is_nan() && x > rx && y < ry)
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    let mut front: Vec<(f64, f64)> =
        pareto_frontier(&pts).into_iter().map(|i| pts[i]).collect();
    // Sweep strips right-to-left: sorted by the maximize-axis descending,
    // each frontier point adds the strip between the previous (worse)
    // minimize-axis level and its own.
    front.sort_by(|a, b| b.0.total_cmp(&a.0));
    let mut hv = 0.0;
    let mut prev_y = ry;
    for (x, y) in front {
        if y < prev_y {
            hv += (x - rx) * (prev_y - y);
            prev_y = y;
        }
    }
    hv
}

/// One (perf/area, energy) frontier entry with an arbitrary payload (a grid
/// index, a full `DsePoint`, ...).
#[derive(Debug, Clone)]
pub struct FrontierEntry<T> {
    pub perf_per_area: f64,
    pub energy: f64,
    pub payload: T,
}

/// Streaming Pareto frontier: fold points in one at a time, keeping only the
/// undominated set — the memory the sweep engine retains is O(frontier)
/// instead of O(grid).
///
/// Matches [`pareto_frontier`] batch semantics exactly: weakly-dominated
/// points (including exact duplicates of a member) are rejected, and among
/// exact duplicates the first-seen point is the one kept.  Entries stay in
/// insertion order, so pushing in grid order yields payloads in grid order.
/// Points with a NaN coordinate are rejected outright (a degenerate
/// prediction must not poison — or panic — the frontier).
#[derive(Debug, Clone, Default)]
pub struct IncrementalFrontier<T> {
    entries: Vec<FrontierEntry<T>>,
}

impl<T> IncrementalFrontier<T> {
    pub fn new() -> IncrementalFrontier<T> {
        IncrementalFrontier { entries: Vec::new() }
    }

    /// Offer one point; returns true iff it joined the frontier (possibly
    /// evicting now-dominated members).
    pub fn push(&mut self, perf_per_area: f64, energy: f64, payload: T) -> bool {
        if perf_per_area.is_nan() || energy.is_nan() {
            return false;
        }
        // Rejected if any member weakly dominates it (>= on both axes).
        if self
            .entries
            .iter()
            .any(|q| q.perf_per_area >= perf_per_area && q.energy <= energy)
        {
            return false;
        }
        // Evict members the new point weakly dominates.
        self.entries
            .retain(|q| !(perf_per_area >= q.perf_per_area && energy <= q.energy));
        self.entries.push(FrontierEntry { perf_per_area, energy, payload });
        true
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Members in insertion order.
    pub fn entries(&self) -> &[FrontierEntry<T>] {
        &self.entries
    }

    pub fn into_entries(self) -> Vec<FrontierEntry<T>> {
        self.entries
    }

    /// Hypervolume dominated by the current frontier relative to
    /// `ref_point` (see [`hypervolume`]); since the frontier already equals
    /// the batch frontier of everything pushed, this is the streaming view
    /// of the same scalar.
    pub fn hypervolume(&self, ref_point: (f64, f64)) -> f64 {
        let pts: Vec<(f64, f64)> =
            self.entries.iter().map(|e| (e.perf_per_area, e.energy)).collect();
        hypervolume(&pts, ref_point)
    }
}

/// One frontier entry in N-objective minimized space (every coordinate:
/// smaller is better), with an arbitrary payload.
#[derive(Debug, Clone)]
pub struct FrontierNdEntry<T> {
    pub objs: Vec<f64>,
    pub payload: T,
}

/// True iff `a` weakly dominates `b` in minimized space (`a <= b` on every
/// axis).  Equal points weakly dominate each other.
fn weakly_dominates_min(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y)
}

/// Streaming Pareto frontier over N **minimized** objectives — the
/// 3-objective optimizer's archive.  Semantics mirror
/// [`IncrementalFrontier`]: weakly-dominated points (including exact
/// duplicates of a member) are rejected, pushing a point evicts members it
/// weakly dominates, entries stay in insertion order, and NaN coordinates
/// are rejected outright.  The 2-objective engine path keeps the original
/// (maximize, minimize) archive so its hypervolume numbers are untouched.
#[derive(Debug, Clone)]
pub struct IncrementalFrontierNd<T> {
    dim: usize,
    entries: Vec<FrontierNdEntry<T>>,
}

impl<T> IncrementalFrontierNd<T> {
    pub fn new(dim: usize) -> IncrementalFrontierNd<T> {
        assert!(dim >= 1, "frontier dimension must be >= 1");
        IncrementalFrontierNd { dim, entries: Vec::new() }
    }

    /// Offer one minimized point; returns true iff it joined the frontier
    /// (possibly evicting now-dominated members).
    pub fn push(&mut self, objs: &[f64], payload: T) -> bool {
        debug_assert_eq!(objs.len(), self.dim);
        if objs.len() != self.dim || objs.iter().any(|v| v.is_nan()) {
            return false;
        }
        if self.entries.iter().any(|q| weakly_dominates_min(&q.objs, objs)) {
            return false;
        }
        self.entries.retain(|q| !weakly_dominates_min(objs, &q.objs));
        self.entries.push(FrontierNdEntry { objs: objs.to_vec(), payload });
        true
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Members in insertion order.
    pub fn entries(&self) -> &[FrontierNdEntry<T>] {
        &self.entries
    }

    pub fn into_entries(self) -> Vec<FrontierNdEntry<T>> {
        self.entries
    }

    /// Hypervolume dominated by the current frontier relative to the
    /// anti-optimal corner `ref_point` (see [`hypervolume_min`]).
    pub fn hypervolume(&self, ref_point: &[f64]) -> f64 {
        let pts: Vec<Vec<f64>> = self.entries.iter().map(|e| e.objs.clone()).collect();
        hypervolume_min(&pts, ref_point)
    }
}

/// Hypervolume of a point set in N-objective **minimized** space: the
/// volume of the region dominated by the set's Pareto frontier and bounded
/// by the anti-optimal corner `ref_point` (an upper bound on every
/// coordinate).  Points that do not strictly improve on the corner on
/// every axis — or carry a NaN — contribute nothing, and dominated points
/// never change the result.  Computed by recursive sweep-slicing over the
/// last axis (exact for any N; the optimizer uses N = 3).
pub fn hypervolume_min(points: &[Vec<f64>], ref_point: &[f64]) -> f64 {
    let dim = ref_point.len();
    let pts: Vec<Vec<f64>> = points
        .iter()
        .filter(|p| {
            p.len() == dim && p.iter().zip(ref_point).all(|(v, r)| !v.is_nan() && v < r)
        })
        .cloned()
        .collect();
    hv_min_rec(pts, ref_point)
}

fn hv_min_rec(mut pts: Vec<Vec<f64>>, r: &[f64]) -> f64 {
    if pts.is_empty() {
        return 0.0;
    }
    match r.len() {
        0 => 0.0,
        1 => {
            let best = pts.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min);
            r[0] - best
        }
        dim => {
            // Slice along the last axis: between consecutive distinct
            // levels (and from the last level up to the corner), the
            // dominated cross-section is the (N-1)-D hypervolume of every
            // point at or below the slab floor.
            let k = dim - 1;
            pts.sort_by(|a, b| a[k].total_cmp(&b[k]));
            let mut hv = 0.0;
            let mut i = 0;
            while i < pts.len() {
                let z = pts[i][k];
                let mut j = i + 1;
                while j < pts.len() && pts[j][k] == z {
                    j += 1;
                }
                let z_next = if j < pts.len() { pts[j][k] } else { r[k] };
                if z_next > z {
                    let slice: Vec<Vec<f64>> =
                        pts[..j].iter().map(|p| p[..k].to_vec()).collect();
                    hv += (z_next - z) * hv_min_rec(slice, &r[..k]);
                }
                i = j;
            }
            hv
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::prng::Rng;

    #[test]
    fn simple_frontier() {
        // (pa, energy): point 1 dominates 0; 2 is incomparable to 1.
        let pts = vec![(1.0, 5.0), (2.0, 3.0), (1.5, 1.0)];
        let f = pareto_frontier(&pts);
        assert_eq!(f, vec![1, 2]);
    }

    #[test]
    fn single_and_empty() {
        assert_eq!(pareto_frontier(&[]), Vec::<usize>::new());
        assert_eq!(pareto_frontier(&[(1.0, 1.0)]), vec![0]);
    }

    #[test]
    fn dominated_duplicates_removed() {
        let pts = vec![(2.0, 3.0), (2.0, 3.0), (2.0, 3.0)];
        let f = pareto_frontier(&pts);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn duplicate_frontier_point_keeps_first_occurrence() {
        // two coincident frontier points + a dominated straggler
        let pts = vec![(1.0, 9.0), (2.0, 3.0), (2.0, 3.0)];
        assert_eq!(pareto_frontier(&pts), vec![1]);
    }

    #[test]
    fn dominance_ties_on_one_axis() {
        // equal perf/area: only the lower-energy point survives;
        // equal energy: only the higher-perf/area point survives.
        let pts = vec![(2.0, 3.0), (2.0, 5.0), (3.0, 3.0), (1.0, 1.0)];
        assert_eq!(pareto_frontier(&pts), vec![2, 3]);
    }

    #[test]
    fn incremental_frontier_edge_cases() {
        // empty
        let f: IncrementalFrontier<usize> = IncrementalFrontier::new();
        assert!(f.is_empty());
        assert_eq!(f.entries().len(), 0);
        // single point
        let mut f = IncrementalFrontier::new();
        assert!(f.push(1.0, 1.0, 0usize));
        assert_eq!(f.len(), 1);
        // all-duplicate points: first-seen wins, the rest are rejected
        let mut f = IncrementalFrontier::new();
        assert!(f.push(2.0, 3.0, 10usize));
        assert!(!f.push(2.0, 3.0, 11));
        assert!(!f.push(2.0, 3.0, 12));
        assert_eq!(f.len(), 1);
        assert_eq!(f.entries()[0].payload, 10);
        // dominance tie on one axis evicts the weakly-dominated member
        let mut f = IncrementalFrontier::new();
        f.push(2.0, 3.0, 0usize);
        assert!(f.push(2.0, 2.0, 1)); // same pa, less energy: evicts 0
        assert!(!f.push(2.0, 2.5, 2)); // back between: dominated
        assert_eq!(f.len(), 1);
        assert_eq!(f.entries()[0].payload, 1);
        // NaN never joins (and never panics)
        assert!(!f.push(f64::NAN, 0.0, 9));
        assert!(!f.push(3.0, f64::NAN, 9));
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn nan_points_are_excluded_and_paths_agree() {
        // A degenerate prediction (NaN perf/area, finite energy) must not
        // shadow the genuine frontier — in either extraction path.
        let pts = vec![(f64::NAN, 0.3), (5.0, 0.4), (1.0, f64::NAN)];
        assert_eq!(pareto_frontier(&pts), vec![1]);
        let mut inc = IncrementalFrontier::new();
        for (i, &(pa, e)) in pts.iter().enumerate() {
            inc.push(pa, e, i);
        }
        let inc_idx: Vec<usize> = inc.entries().iter().map(|e| e.payload).collect();
        assert_eq!(inc_idx, vec![1]);
    }

    #[test]
    fn property_incremental_matches_batch_frontier() {
        // Quantized coordinates force duplicates and single-axis ties —
        // exactly the cases where incremental vs batch semantics could
        // drift.  Payload = original index, so membership AND identity of
        // kept duplicates must agree.
        testkit::forall(
            "incremental == batch",
            300,
            23,
            |rng: &mut Rng| {
                let n = 1 + rng.below(50);
                (0..n)
                    .map(|_| (rng.below(8) as f64, rng.below(8) as f64))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let batch = pareto_frontier(pts);
                let mut inc = IncrementalFrontier::new();
                for (i, &(pa, e)) in pts.iter().enumerate() {
                    inc.push(pa, e, i);
                }
                let inc_idx: Vec<usize> =
                    inc.entries().iter().map(|e| e.payload).collect();
                if inc_idx != batch {
                    return Err(format!("incremental {inc_idx:?} != batch {batch:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_frontier_has_no_dominated_member() {
        testkit::forall(
            "no dominated member",
            200,
            11,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 10.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = pareto_frontier(pts);
                for &i in &f {
                    for (j, &q) in pts.iter().enumerate() {
                        if i != j && dominates(q, pts[i]) {
                            return Err(format!("frontier member {i} dominated by {j}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_every_point_dominated_by_some_frontier_member() {
        testkit::forall(
            "coverage",
            200,
            13,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 10.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = pareto_frontier(pts);
                for (j, &q) in pts.iter().enumerate() {
                    let covered = f.iter().any(|&i| i == j || dominates(pts[i], q))
                        // equal points count as covered
                        || f.iter().any(|&i| pts[i] == q);
                    if !covered {
                        return Err(format!("point {j} not covered by frontier"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hypervolume_known_values() {
        // one point: a single rectangle
        assert_eq!(hypervolume(&[(2.0, 1.0)], (0.0, 3.0)), 4.0);
        // staircase of two incomparable points: two strips
        // (3,2) adds (3-0)*(4-2)=6; (1,1) adds (1-0)*(2-1)=1
        let pts = [(3.0, 2.0), (1.0, 1.0)];
        assert_eq!(hypervolume(&pts, (0.0, 4.0)), 7.0);
        // dominated points contribute nothing
        let with_dom = [(3.0, 2.0), (1.0, 1.0), (0.5, 3.9), (2.0, 2.0)];
        assert_eq!(hypervolume(&with_dom, (0.0, 4.0)), 7.0);
        // points outside the reference corner are clipped away entirely
        assert_eq!(hypervolume(&[(0.5, 5.0)], (1.0, 4.0)), 0.0);
        // empty set / NaN-only set
        assert_eq!(hypervolume(&[], (0.0, 1.0)), 0.0);
        assert_eq!(hypervolume(&[(f64::NAN, 0.5)], (0.0, 1.0)), 0.0);
    }

    #[test]
    fn property_hypervolume_dominated_insertion_never_increases() {
        // Inserting a point dominated by an existing member must leave the
        // hypervolume exactly unchanged (the satellite acceptance bound is
        // "never increases"; for a dominated point the area is identical).
        testkit::forall(
            "hv dominated insertion",
            200,
            29,
            |rng: &mut Rng| {
                let n = 1 + rng.below(30);
                let pts: Vec<(f64, f64)> = (0..n)
                    .map(|_| (rng.range_f64(0.1, 10.0), rng.range_f64(0.1, 10.0)))
                    .collect();
                // a point weakly dominated by a random member
                let (x, y) = pts[rng.below(n)];
                let dom = (x - rng.range_f64(0.0, x), y + rng.range_f64(0.0, 2.0));
                (pts, dom)
            },
            |(pts, dom)| {
                let r = (0.0, 13.0);
                let before = hypervolume(pts, r);
                let mut with = pts.clone();
                with.push(*dom);
                let after = hypervolume(&with, r);
                if after > before + 1e-12 {
                    return Err(format!("hv grew on dominated insert: {before} -> {after}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_hypervolume_permutation_invariant() {
        testkit::forall(
            "hv permutation invariance",
            200,
            31,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                let pts: Vec<(f64, f64)> = (0..n)
                    .map(|_| (rng.range_f64(0.0, 8.0), rng.range_f64(0.0, 8.0)))
                    .collect();
                let mut shuffled = pts.clone();
                rng.shuffle(&mut shuffled);
                (pts, shuffled)
            },
            |(pts, shuffled)| {
                let r = (-1.0, 9.0);
                let a = hypervolume(pts, r);
                let b = hypervolume(shuffled, r);
                if (a - b).abs() > 1e-9 * a.abs().max(1.0) {
                    return Err(format!("hv not permutation invariant: {a} vs {b}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_hypervolume_incremental_matches_batch() {
        // The streaming frontier's hypervolume must equal the batch
        // hypervolume of the full point set at every prefix length.
        testkit::forall(
            "hv incremental == batch",
            150,
            37,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (rng.below(12) as f64, rng.below(12) as f64))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let r = (-0.5, 12.5);
                let mut inc = IncrementalFrontier::new();
                for (i, &(x, y)) in pts.iter().enumerate() {
                    inc.push(x, y, i);
                    let batch = hypervolume(&pts[..=i], r);
                    let stream = inc.hypervolume(r);
                    if (batch - stream).abs() > 1e-9 * batch.abs().max(1.0) {
                        return Err(format!(
                            "prefix {}: incremental hv {stream} != batch hv {batch}",
                            i + 1
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nd_frontier_edge_cases_mirror_the_2d_archive() {
        let mut f: IncrementalFrontierNd<usize> = IncrementalFrontierNd::new(3);
        assert!(f.is_empty());
        assert!(f.push(&[2.0, 3.0, 1.0], 0));
        // exact duplicate: first-seen wins
        assert!(!f.push(&[2.0, 3.0, 1.0], 1));
        // weakly dominated (ties on two axes)
        assert!(!f.push(&[2.0, 3.0, 2.0], 2));
        // dominating point evicts
        assert!(f.push(&[1.0, 3.0, 1.0], 3));
        assert_eq!(f.len(), 1);
        assert_eq!(f.entries()[0].payload, 3);
        // incomparable point joins
        assert!(f.push(&[5.0, 1.0, 5.0], 4));
        assert_eq!(f.len(), 2);
        // NaN never joins
        assert!(!f.push(&[f64::NAN, 0.0, 0.0], 5));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn hypervolume_min_known_values() {
        // one 3-D point: a single box to the corner
        assert_eq!(hypervolume_min(&[vec![1.0, 1.0, 1.0]], &[2.0, 3.0, 2.0]), 2.0);
        // two incomparable points with a shared dominated overlap:
        // vol(A) + vol(B) - vol(A ∩ B) = 16 + 16 - 8 = 24
        let pts = vec![vec![0.0, 2.0, 0.0], vec![2.0, 0.0, 0.0]];
        assert_eq!(hypervolume_min(&pts, &[4.0, 4.0, 2.0]), 24.0);
        // dominated point contributes nothing
        let with_dom = vec![vec![0.0, 2.0, 0.0], vec![2.0, 0.0, 0.0], vec![3.0, 3.0, 1.0]];
        assert_eq!(hypervolume_min(&with_dom, &[4.0, 4.0, 2.0]), 24.0);
        // outside the corner on any axis: clipped away
        assert_eq!(hypervolume_min(&[vec![1.0, 1.0, 5.0]], &[2.0, 2.0, 2.0]), 0.0);
        // empty / NaN
        assert_eq!(hypervolume_min(&[], &[1.0, 1.0, 1.0]), 0.0);
        assert_eq!(hypervolume_min(&[vec![f64::NAN, 0.0, 0.0]], &[1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn hypervolume_min_2d_matches_the_mirrored_classic() {
        // In 2-D, minimizing x is the classic convention with x negated.
        testkit::forall(
            "hv_min 2d == mirrored hv",
            200,
            41,
            |rng: &mut Rng| {
                let n = 1 + rng.below(30);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 8.0), rng.range_f64(0.0, 8.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let min_pts: Vec<Vec<f64>> = pts.iter().map(|&(x, y)| vec![x, y]).collect();
                let a = hypervolume_min(&min_pts, &[9.0, 9.0]);
                let mirrored: Vec<(f64, f64)> = pts.iter().map(|&(x, y)| (-x, y)).collect();
                let b = hypervolume(&mirrored, (-9.0, 9.0));
                if (a - b).abs() > 1e-9 * a.abs().max(1.0) {
                    return Err(format!("hv_min {a} != mirrored hv {b}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_hypervolume_min_3d_monotone_and_permutation_invariant() {
        // Adding any point never decreases the dominated volume, and the
        // result is independent of insertion order.
        testkit::forall(
            "hv_min 3d monotone + permutation",
            150,
            43,
            |rng: &mut Rng| {
                let n = 1 + rng.below(25);
                let pts: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..3).map(|_| rng.below(6) as f64).collect())
                    .collect();
                let mut shuffled = pts.clone();
                rng.shuffle(&mut shuffled);
                (pts, shuffled)
            },
            |(pts, shuffled)| {
                let r = [6.5, 6.5, 6.5];
                let full = hypervolume_min(pts, &r);
                let perm = hypervolume_min(shuffled, &r);
                if (full - perm).abs() > 1e-9 * full.abs().max(1.0) {
                    return Err(format!("hv_min not permutation invariant: {full} vs {perm}"));
                }
                let mut prev = 0.0;
                for i in 0..pts.len() {
                    let hv = hypervolume_min(&pts[..=i], &r);
                    if hv + 1e-12 < prev {
                        return Err(format!("hv_min shrank on insert: {prev} -> {hv}"));
                    }
                    prev = hv;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_nd_archive_matches_brute_force_frontier() {
        // The streaming N-D archive must retain exactly the points no
        // other point weakly dominates (first-seen among duplicates).
        testkit::forall(
            "nd archive == brute force",
            200,
            47,
            |rng: &mut Rng| {
                let n = 1 + rng.below(40);
                (0..n)
                    .map(|_| (0..3).map(|_| rng.below(5) as f64).collect::<Vec<f64>>())
                    .collect::<Vec<_>>()
            },
            |pts| {
                let mut inc = IncrementalFrontierNd::new(3);
                for (i, p) in pts.iter().enumerate() {
                    inc.push(p, i);
                }
                let kept: Vec<usize> = inc.entries().iter().map(|e| e.payload).collect();
                // brute force: i survives iff no j (j != i) weakly
                // dominates it, except that the first occurrence of a
                // duplicate group survives its copies.
                let mut expect = Vec::new();
                'outer: for (i, p) in pts.iter().enumerate() {
                    for (j, q) in pts.iter().enumerate() {
                        if i == j || !weakly_dominates_min(q, p) {
                            continue;
                        }
                        // q == p: only an earlier copy displaces i
                        if q == p && j > i {
                            continue;
                        }
                        continue 'outer;
                    }
                    expect.push(i);
                }
                let mut kept_sorted = kept.clone();
                kept_sorted.sort();
                if kept_sorted != expect {
                    return Err(format!("archive {kept_sorted:?} != brute {expect:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_idempotent() {
        testkit::forall(
            "idempotent",
            100,
            17,
            |rng: &mut Rng| {
                let n = 1 + rng.below(30);
                (0..n)
                    .map(|_| (rng.range_f64(0.0, 4.0), rng.range_f64(0.0, 4.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let f = pareto_frontier(pts);
                let sub: Vec<(f64, f64)> = f.iter().map(|&i| pts[i]).collect();
                let f2 = pareto_frontier(&sub);
                if f2.len() != sub.len() {
                    return Err(format!("re-running dropped {} points", sub.len() - f2.len()));
                }
                Ok(())
            },
        );
    }
}
