//! Precision-grid DSE — bit widths as first-class sweep axes.
//!
//! The per-type pipeline (`explorer`) trains one regression model per PE
//! type and sweeps the hardware grid once per type.  This module
//! generalizes that to *arbitrary* precision grids (QADAM / QUIDAM-style
//! co-exploration): a [`PrecisionGrid`] expands CLI-style ranges
//! (`--act-bits 4:16 --wt-bits 2:8`) into validated [`QuantSpec`]s, a
//! single **unified** model is fitted with the bit widths as regression
//! features ([`crate::config::AcceleratorConfig::features_quant`]), and
//! every precision cell streams through the existing chunked
//! [`SweepEngine`] — sharding, incremental Pareto frontiers and top-k
//! reservoirs included.  The historical `ALL_PE_TYPES` sweep is the
//! special case of a 4-entry grid with per-type models.
//!
//! The unified model runs on a `QUANT_NUM_FEATURES`-dimension backend
//! (always the native backend: the AOT XLA artifacts are lowered for the
//! 7-feature per-type protocol).  See `docs/PRECISION.md`.

use std::collections::BTreeMap;

use crate::api::error::QappaError;
use crate::config::{auto_psum, MacKind, PeType, QuantSpec, QUANT_NUM_FEATURES};
use crate::coordinator::explorer::{
    assemble_ratios, best_points, DseOptions, ModelStore, WorkloadSummary,
};
use crate::coordinator::sweep::{NamedWorkload, SweepEngine, TypeSweep};
use crate::model::{fit_ppa, Backend, PpaModel};
use crate::obs;
use crate::obs::trace::phase_with;
use crate::synth::oracle::{synthesize_with_sigma, Ppa};
use crate::util::pool::parallel_map;

/// A validated, order-preserving, deduplicated list of precision cells.
#[derive(Debug, Clone)]
pub struct PrecisionGrid {
    /// Canonicalized precision selectors (presets where specs match).
    pub types: Vec<PeType>,
}

impl PrecisionGrid {
    /// Build from explicit precision selectors; validates every spec
    /// (bit-width range, psum >= operands) and deduplicates while keeping
    /// first-seen order.
    pub fn new(types: Vec<PeType>) -> Result<PrecisionGrid, QappaError> {
        if types.is_empty() {
            return Err(QappaError::Config("precision grid: no precision cells".into()));
        }
        let mut out: Vec<PeType> = Vec::with_capacity(types.len());
        for ty in types {
            let ty = PeType::from_spec(ty.spec());
            ty.spec()
                .validate()
                .map_err(|e| e.context(format!("precision grid cell '{}'", ty.label())))?;
            if !out.contains(&ty) {
                out.push(ty);
            }
        }
        Ok(PrecisionGrid { types: out })
    }

    /// Cross-product of width axes at a fixed MAC kind.  `psum` empty =
    /// automatic accumulator widths ([`auto_psum`]).
    pub fn from_ranges(
        act: &[u32],
        wt: &[u32],
        psum: &[u32],
        mac: MacKind,
    ) -> Result<PrecisionGrid, QappaError> {
        if act.is_empty() {
            return Err(QappaError::Config("precision grid: empty act_bits axis".into()));
        }
        if wt.is_empty() {
            return Err(QappaError::Config("precision grid: empty wt_bits axis".into()));
        }
        let mut types = Vec::with_capacity(act.len() * wt.len() * psum.len().max(1));
        for &a in act {
            for &w in wt {
                if psum.is_empty() {
                    let spec = QuantSpec { act_bits: a, wt_bits: w, psum_bits: auto_psum(a, w, mac), mac };
                    types.push(PeType::from_spec(spec));
                } else {
                    for &p in psum {
                        types.push(PeType::from_spec(QuantSpec {
                            act_bits: a,
                            wt_bits: w,
                            psum_bits: p,
                            mac,
                        }));
                    }
                }
            }
        }
        PrecisionGrid::new(types)
    }

    /// Number of precision cells.
    pub fn len(&self) -> usize {
        self.types.len()
    }

    pub fn is_empty(&self) -> bool {
        self.types.is_empty()
    }
}

/// Parse one bit-width axis from its CLI form: a single value (`8`), an
/// inclusive range (`4:16`), a stepped range (`4:16:4`) or an explicit
/// comma list (`4,8,16`).
///
/// The default range step is 2 bits — `4:16` yields 4, 6, 8, 10, 12, 14,
/// 16 — matching how precision-search papers walk even widths; the upper
/// endpoint is always included.
pub fn parse_bits_axis(s: &str, flag: &str) -> Result<Vec<u32>, QappaError> {
    let err = |m: String| QappaError::Config(m);
    let parse_u32 = |tok: &str| -> Result<u32, QappaError> {
        tok.trim()
            .parse::<u32>()
            .map_err(|_| err(format!("--{flag}: cannot parse '{tok}' as a bit width")))
    };
    if s.contains(',') {
        let mut out = Vec::new();
        for tok in s.split(',').filter(|t| !t.trim().is_empty()) {
            out.push(parse_u32(tok)?);
        }
        if out.is_empty() {
            return Err(err(format!("--{flag}: empty width list '{s}'")));
        }
        return Ok(out);
    }
    let parts: Vec<&str> = s.split(':').collect();
    match parts.as_slice() {
        [one] => Ok(vec![parse_u32(one)?]),
        [lo, hi] | [lo, hi, _] => {
            let lo = parse_u32(lo)?;
            let hi = parse_u32(hi)?;
            let step = if let [_, _, st] = parts.as_slice() { parse_u32(st)? } else { 2 };
            if step == 0 {
                return Err(err(format!("--{flag}: step must be >= 1 in '{s}'")));
            }
            if lo > hi {
                return Err(err(format!("--{flag}: range '{s}' has lo > hi")));
            }
            let mut out = Vec::new();
            let mut v = lo;
            while v < hi {
                out.push(v);
                v += step;
            }
            out.push(hi); // always include the upper endpoint
            Ok(out)
        }
        _ => Err(err(format!("--{flag}: expected N, LO:HI, LO:HI:STEP or a comma list, got '{s}'"))),
    }
}

/// Train the unified cross-precision PPA model: oracle samples drawn
/// across the hardware hull *and* every precision cell, fitted on the
/// quant-extended feature vector so one model predicts any (hardware,
/// precision) pair in the grid.
pub fn train_quant_model(
    backend: &dyn Backend,
    opts: &DseOptions,
    grid: &[PeType],
) -> Result<PpaModel, QappaError> {
    if grid.is_empty() {
        return Err(QappaError::Config("precision grid: no precision cells".into()));
    }
    if backend.d() != QUANT_NUM_FEATURES {
        return Err(QappaError::Backend(format!(
            "unified precision model needs a {QUANT_NUM_FEATURES}-feature backend, \
             got d={} ({}); precision sweeps run the native backend",
            backend.d(),
            backend.name()
        )));
    }
    opts.space.validate()?;
    let t0 = std::time::Instant::now();
    // At least a few dozen samples per cell, spread deterministically.
    let per_cell = (opts.train_per_type / grid.len()).max(48);
    let mut cfgs = Vec::with_capacity(per_cell * grid.len());
    for ty in grid {
        cfgs.extend(opts.space.sample(*ty, per_cell, opts.seed));
    }
    let ppas: Vec<Ppa> =
        parallel_map(&cfgs, opts.workers, |c| synthesize_with_sigma(c, opts.sigma));
    phase_with(|| format!("train/quant/synth({})", cfgs.len()), t0);
    let mut feats = Vec::with_capacity(cfgs.len() * QUANT_NUM_FEATURES);
    let mut targets = Vec::with_capacity(cfgs.len() * 3);
    for (c, p) in cfgs.iter().zip(&ppas) {
        feats.extend_from_slice(&c.features_quant());
        targets.extend_from_slice(&p.as_array());
    }
    let t1 = std::time::Instant::now();
    let model = fit_ppa(backend, &feats, &targets, &opts.cv)
        .map_err(|e| e.context("unified precision model"))?;
    phase_with(|| "train/quant/cv_fit".to_string(), t1);
    obs::registry()
        .histogram("store.train_ms")
        .record_ms(t0.elapsed().as_secs_f64() * 1e3);
    Ok(model)
}

/// Precision-grid DSE over one or more workloads: one unified model, one
/// chunked streaming sweep per precision cell, every workload folded per
/// shard.  One [`SweepEngine`] serves every cell, so its synthesis and
/// layer-cost memos stay warm across the grid — the per-cell
/// `SweepStats` memo counters are cumulative snapshots in sweep order.
/// Returns one [`WorkloadSummary`] per workload whose maps are
/// keyed by the grid's precision cells; ratios are normalized against the
/// INT16 cell when the grid contains it, otherwise against the grid's
/// best predicted perf/area point.
pub fn run_dse_precision(
    backend: &dyn Backend,
    store: &ModelStore,
    workloads: &[NamedWorkload],
    opts: &DseOptions,
    grid: &PrecisionGrid,
) -> Result<Vec<WorkloadSummary>, QappaError> {
    if workloads.is_empty() {
        return Err(QappaError::Workload("run_dse_precision: no workloads given".into()));
    }
    let model = store.get_or_train_quant(backend, opts, &grid.types)?;
    let engine = SweepEngine::new(backend, opts);

    // per_wl[w][cell] = TypeSweep
    let mut per_wl: Vec<BTreeMap<PeType, TypeSweep>> =
        workloads.iter().map(|_| BTreeMap::new()).collect();
    for ty in &grid.types {
        for (w, ts) in engine.sweep_type(&model, *ty, workloads)?.into_iter().enumerate() {
            per_wl[w].insert(*ty, ts);
        }
    }

    let mut out = Vec::with_capacity(workloads.len());
    for (wl, sweeps) in workloads.iter().zip(per_wl) {
        let best = best_points(&sweeps)?;
        let anchor = match best.get(&PeType::Int16) {
            Some((pa, _)) => pa.clone(),
            None => best
                .values()
                .max_by(|a, b| a.0.perf_per_area.total_cmp(&b.0.perf_per_area))
                .expect("non-empty precision grid")
                .0
                .clone(),
        };
        let (ratios, ratios_validated) = assemble_ratios(&wl.layers, opts.sigma, &anchor, &best);
        let mut frontier = BTreeMap::new();
        let mut top_pa = BTreeMap::new();
        let mut top_e = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (ty, ts) in sweeps {
            frontier.insert(ty, ts.frontier_points());
            stats.insert(ty, ts.stats);
            top_pa.insert(ty, ts.top_perf_per_area);
            top_e.insert(ty, ts.top_energy);
        }
        out.push(WorkloadSummary {
            workload: wl.name.clone(),
            frontier,
            top_perf_per_area: top_pa,
            top_energy: top_e,
            anchor,
            ratios,
            ratios_validated,
            stats,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QUANT_NUM_FEATURES;
    use crate::coordinator::space::DesignSpace;
    use crate::dataflow::Layer;
    use crate::model::native::NativeBackend;
    use crate::model::{predict_ppa, CvConfig};

    fn tiny_opts() -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 96,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk: 16,
            topk: 4,
        }
    }

    fn net() -> Vec<Layer> {
        vec![Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)]
    }

    #[test]
    fn parse_bits_axis_forms() {
        assert_eq!(parse_bits_axis("8", "act-bits").unwrap(), vec![8]);
        assert_eq!(parse_bits_axis("4:16", "act-bits").unwrap(), vec![4, 6, 8, 10, 12, 14, 16]);
        assert_eq!(parse_bits_axis("4:16:4", "act-bits").unwrap(), vec![4, 8, 12, 16]);
        // upper endpoint always included, even off-step
        assert_eq!(parse_bits_axis("2:7:2", "wt-bits").unwrap(), vec![2, 4, 6, 7]);
        assert_eq!(parse_bits_axis("4,8,16", "wt-bits").unwrap(), vec![4, 8, 16]);
        for bad in ["", "a:b", "8:4", "4:16:0", "1:2:3:4"] {
            let e = parse_bits_axis(bad, "act-bits").unwrap_err();
            assert_eq!(e.kind(), "config", "{bad}");
            assert!(e.to_string().contains("act-bits"), "{bad}: {e}");
        }
    }

    #[test]
    fn grid_from_ranges_validates_and_canonicalizes() {
        let g = PrecisionGrid::from_ranges(&[8, 16], &[8, 16], &[], MacKind::IntExact).unwrap();
        assert_eq!(g.len(), 4);
        // a16w16 with auto psum (= 32) is canonicalized to the INT16 preset
        assert!(g.types.contains(&PeType::Int16), "{:?}", g.types);
        // invalid widths are rejected with the cell and field named
        let e = PrecisionGrid::from_ranges(&[0], &[8], &[], MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("act_bits"), "{e}");
        let e = PrecisionGrid::from_ranges(&[16], &[8], &[4], MacKind::IntExact).unwrap_err();
        assert!(e.to_string().contains("psum_bits"), "{e}");
        // duplicates collapse, order preserved
        let g2 = PrecisionGrid::new(vec![PeType::Int16, PeType::LightPe1, PeType::Int16]).unwrap();
        assert_eq!(g2.types, vec![PeType::Int16, PeType::LightPe1]);
    }

    #[test]
    fn unified_model_predicts_across_precisions() {
        let backend = NativeBackend::new(QUANT_NUM_FEATURES);
        let opts = tiny_opts();
        let grid =
            PrecisionGrid::from_ranges(&[4, 8, 16], &[4, 8, 16], &[], MacKind::IntExact).unwrap();
        let model = train_quant_model(&backend, &opts, &grid.types).unwrap();
        // holdout across every cell: one model, sane accuracy everywhere
        let mut rel_err = 0.0;
        let mut n = 0usize;
        for ty in &grid.types {
            let cfgs = opts.space.sample(*ty, 24, 999);
            let mut feats = Vec::new();
            for c in &cfgs {
                feats.extend_from_slice(&c.features_quant());
            }
            let preds = predict_ppa(&backend, &model, &feats).unwrap();
            for (c, pred) in cfgs.iter().zip(&preds) {
                let truth = synthesize_with_sigma(c, opts.sigma).as_array();
                for k in 0..3 {
                    rel_err += ((pred[k] - truth[k]) / truth[k]).abs();
                    n += 1;
                }
            }
        }
        rel_err /= n as f64;
        assert!(rel_err < 0.25, "cross-precision holdout rel err {rel_err}");
    }

    #[test]
    fn quant_model_demands_extended_backend() {
        let narrow = NativeBackend::new(7);
        let e = train_quant_model(&narrow, &tiny_opts(), &[PeType::Int16]).unwrap_err();
        assert_eq!(e.kind(), "backend");
        assert!(e.to_string().contains("native"), "{e}");
    }

    #[test]
    fn precision_dse_produces_per_cell_rows_and_monotone_story() {
        let backend = NativeBackend::new(QUANT_NUM_FEATURES);
        let opts = tiny_opts();
        let store = ModelStore::new();
        let grid = PrecisionGrid::from_ranges(&[4, 16], &[4, 16], &[], MacKind::IntExact).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let summaries = run_dse_precision(&backend, &store, &wl, &opts, &grid).unwrap();
        assert_eq!(store.misses(), 1, "one unified model for the whole grid");
        assert_eq!(summaries.len(), 1);
        let s = &summaries[0];
        assert_eq!(s.ratios.len(), grid.len());
        for ty in &grid.types {
            assert!(!s.frontier[ty].is_empty(), "{}", ty.label());
            assert_eq!(s.stats[ty].evaluated, opts.space.len());
            assert!(s.top_perf_per_area[ty].first().is_some());
        }
        // the INT16 cell anchors the ratios at 1.0
        assert!((s.ratios[&PeType::Int16].0 - 1.0).abs() < 1e-9);
        // the 4x4 cell must beat the 16x16 cell on predicted perf/area
        let a4 = PeType::parse("a4w4p8-int").unwrap();
        assert!(
            s.ratios[&a4].0 > s.ratios[&PeType::Int16].0,
            "a4w4 {} <= int16 {}",
            s.ratios[&a4].0,
            s.ratios[&PeType::Int16].0
        );
        // warm repeat: no retraining
        let again = run_dse_precision(&backend, &store, &wl, &opts, &grid).unwrap();
        assert_eq!(store.misses(), 1);
        assert_eq!(again[0].anchor.cfg, s.anchor.cfg);
    }

    #[test]
    fn precision_dse_memo_stays_warm_across_cells() {
        // One engine serves every precision cell, so the synthesis memo
        // keeps warming: the per-cell counters are cumulative snapshots in
        // sweep order, and shared GLB macros make later cells hit.
        let backend = NativeBackend::new(QUANT_NUM_FEATURES);
        let opts = tiny_opts();
        let store = ModelStore::new();
        let grid = PrecisionGrid::from_ranges(&[4, 16], &[4, 16], &[], MacKind::IntExact).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let s = run_dse_precision(&backend, &store, &wl, &opts, &grid)
            .unwrap()
            .remove(0);
        let first = s.stats[&grid.types[0]];
        let last = s.stats[grid.types.last().unwrap()];
        // one synthesis-memo lookup per config per cell, cumulative
        assert_eq!(first.synth_hits + first.synth_misses, opts.space.len() as u64);
        assert_eq!(
            last.synth_hits + last.synth_misses,
            (grid.len() * opts.space.len()) as u64
        );
        // monotone growth and real sharing between cells
        assert!(last.synth_hits >= first.synth_hits);
        assert!(last.synth_misses >= first.synth_misses);
        assert!(last.synth_hits > 0, "no cross-config synth reuse");
        assert!(
            last.synth_misses < last.synth_hits + last.synth_misses,
            "memo never hit across the grid"
        );
    }
}
