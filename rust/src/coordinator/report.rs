//! Figure / table regeneration (paper §4).

use crate::api::error::QappaError;
use crate::api::types::OptimizeResponse;
use crate::config::{PeType, ALL_PE_TYPES};
use crate::coordinator::explorer::{DseOptions, DseResult, WorkloadSummary};
use crate::dataflow::Layer;
use crate::model::{predict_ppa, Backend};
use crate::synth::oracle::synthesize_with_sigma;
use crate::util::stats;
use crate::util::table::{fmt_g, Table};

/// Figure-2 row: model accuracy for one (PE type, metric).
#[derive(Debug, Clone)]
pub struct AccuracyRow {
    pub pe_type: PeType,
    pub metric: &'static str,
    pub r2: f64,
    pub mape: f64,
    pub pearson: f64,
    pub degree: usize,
}

/// Reproduce Figure 2: fit models on a training sample, score them on a
/// fresh holdout against the synthesis oracle.
pub fn fig2_accuracy(
    backend: &dyn Backend,
    opts: &DseOptions,
    holdout_per_type: usize,
) -> Result<Vec<AccuracyRow>, QappaError> {
    let models = crate::coordinator::explorer::train_models(backend, opts)?;
    let metrics = ["power_mw", "fmax_mhz", "area_mm2"];
    let mut rows = Vec::new();
    for ty in ALL_PE_TYPES {
        let cfgs = opts.space.sample(ty, holdout_per_type, opts.seed ^ 0x601d);
        let mut feats = Vec::new();
        for c in &cfgs {
            feats.extend_from_slice(&c.features());
        }
        let preds = predict_ppa(backend, &models[&ty], &feats)?;
        for (k, name) in metrics.iter().enumerate() {
            let actual: Vec<f64> = cfgs
                .iter()
                .map(|c| synthesize_with_sigma(c, opts.sigma).as_array()[k])
                .collect();
            let predicted: Vec<f64> = preds.iter().map(|p| p[k]).collect();
            rows.push(AccuracyRow {
                pe_type: ty,
                metric: name,
                r2: stats::r2(&actual, &predicted),
                mape: stats::mape(&actual, &predicted),
                pearson: stats::pearson(&actual, &predicted),
                degree: models[&ty].degree,
            });
        }
    }
    Ok(rows)
}

/// Render the Figure-2 table.
pub fn fig2_table(rows: &[AccuracyRow]) -> Table {
    let mut t = Table::new(&["pe_type", "metric", "R2", "MAPE_%", "pearson", "degree"]);
    for r in rows {
        t.row(vec![
            r.pe_type.label().to_string(),
            r.metric.to_string(),
            format!("{:.4}", r.r2),
            format!("{:.2}", r.mape),
            format!("{:.4}", r.pearson),
            r.degree.to_string(),
        ]);
    }
    t
}

/// Render a Figure-3/4/5 summary table (ratios vs the best-INT16 anchor).
pub fn dse_summary_table(res: &DseResult) -> Table {
    let mut t = Table::new(&[
        "pe_type",
        "configs",
        "frontier",
        "perf/area_pred",
        "perf/area_true",
        "energy_pred",
        "energy_true",
        "best_cfg",
    ]);
    for ty in ALL_PE_TYPES {
        let pts = &res.points[&ty];
        let (pa, e) = res.ratios[&ty];
        let (pav, ev) = res.ratios_validated[&ty];
        let best = pts
            .iter()
            .max_by(|a, b| a.perf_per_area.total_cmp(&b.perf_per_area))
            .unwrap();
        t.row(vec![
            ty.label().to_string(),
            pts.len().to_string(),
            res.frontier[&ty].len().to_string(),
            format!("{:.2}x", pa),
            format!("{:.2}x", pav),
            format!("{:.2}x", e),
            format!("{:.2}x", ev),
            best.cfg.key(),
        ]);
    }
    t
}

/// Cross-workload summary for `qappa explore --workload a,b,c`: one row
/// per (workload, PE type) with the anchor-normalized ratios (predicted
/// and winner-validated), frontier size and the best config — everything
/// the streaming multi-workload run retains.
pub fn multi_summary_table(summaries: &[WorkloadSummary]) -> Table {
    let mut t = Table::new(&[
        "workload",
        "pe_type",
        "evaluated",
        "frontier",
        "perf/area_pred",
        "perf/area_true",
        "energy_pred",
        "energy_true",
        "best_cfg",
    ]);
    for s in summaries {
        for ty in ALL_PE_TYPES {
            let (pa, e) = s.ratios[&ty];
            let (pav, ev) = s.ratios_validated[&ty];
            let best = s.top_perf_per_area[&ty]
                .first()
                .expect("non-empty reservoir");
            t.row(vec![
                s.workload.clone(),
                ty.label().to_string(),
                s.stats[&ty].evaluated.to_string(),
                s.frontier[&ty].len().to_string(),
                format!("{:.2}x", pa),
                format!("{:.2}x", pav),
                format!("{:.2}x", e),
                format!("{:.2}x", ev),
                best.cfg.key(),
            ]);
        }
    }
    t
}

/// Per-precision summary for `qappa explore --act-bits ... --wt-bits ...`:
/// one row per (workload, precision cell) with the anchor-normalized
/// ratios, frontier size and best config.  The summaries' maps are keyed
/// by the precision grid (see `coordinator::precision`), so the row set
/// follows the grid, not the four presets.
pub fn precision_summary_table(summaries: &[WorkloadSummary]) -> Table {
    let mut t = Table::new(&[
        "workload",
        "precision",
        "act",
        "wt",
        "psum",
        "evaluated",
        "frontier",
        "perf/area_pred",
        "perf/area_true",
        "energy_pred",
        "energy_true",
        "best_cfg",
    ]);
    for s in summaries {
        for (ty, &(pa, e)) in &s.ratios {
            let (pav, ev) = s.ratios_validated[ty];
            let best = s.top_perf_per_area[ty].first().expect("non-empty reservoir");
            let q = ty.spec();
            t.row(vec![
                s.workload.clone(),
                ty.label(),
                q.act_bits.to_string(),
                q.wt_bits.to_string(),
                q.psum_bits.to_string(),
                s.stats[ty].evaluated.to_string(),
                s.frontier[ty].len().to_string(),
                format!("{:.2}x", pa),
                format!("{:.2}x", pav),
                format!("{:.2}x", e),
                format!("{:.2}x", ev),
                best.cfg.key(),
            ]);
        }
    }
    t
}

/// Compact description of a frontier member's precision assignment: the
/// single label for a uniform design, otherwise the distinct labels with
/// their layer counts (`a4w4p8-int x9 + INT16 x19`), first-seen order.
fn precision_cell(labels: &[String]) -> String {
    if labels.is_empty() {
        return "-".to_string();
    }
    let mut counts: Vec<(&str, usize)> = Vec::new();
    for l in labels {
        match counts.iter().position(|(name, _)| *name == l.as_str()) {
            Some(i) => counts[i].1 += 1,
            None => counts.push((l.as_str(), 1)),
        }
    }
    if counts.len() == 1 {
        return counts[0].0.to_string();
    }
    counts
        .iter()
        .map(|(name, n)| format!("{name} x{n}"))
        .collect::<Vec<_>>()
        .join(" + ")
}

/// Frontier report for `qappa optimize`: one row per frontier member,
/// sorted as the response is (first objective ascending), with the raw
/// metrics and the precision assignment.
pub fn opt_frontier_table(resp: &OptimizeResponse) -> Table {
    // Two objective columns always (the historical shape); a third when
    // the run searched three.  An accuracy column appears only when some
    // frontier member carries an estimate, so classic reports stay
    // byte-identical.
    let nobj = resp.objectives.len().max(2);
    let fallback = ["obj0", "obj1", "obj2"];
    let obj_headers: Vec<String> = (0..nobj)
        .map(|k| {
            format!(
                "{}(min)",
                resp.objectives
                    .get(k)
                    .map(String::as_str)
                    .unwrap_or(fallback.get(k).copied().unwrap_or("obj"))
            )
        })
        .collect();
    let with_accuracy = resp.frontier.iter().any(|p| p.accuracy.is_some());
    let mut header: Vec<&str> = vec!["#"];
    header.extend(obj_headers.iter().map(String::as_str));
    header.extend(["thrpt_inf_s", "energy_mJ", "area_mm2", "power_mW"]);
    if with_accuracy {
        header.push("accuracy");
    }
    header.extend(["precision", "config"]);
    let mut t = Table::new(&header);
    for (i, p) in resp.frontier.iter().enumerate() {
        let mut row = vec![(i + 1).to_string()];
        for k in 0..nobj {
            row.push(fmt_g(p.objectives.get(k).copied().unwrap_or(f64::NAN)));
        }
        row.push(format!("{:.2}", p.throughput));
        row.push(format!("{:.4}", p.energy_mj));
        row.push(format!("{:.4}", p.ppa.area_mm2));
        row.push(format!("{:.2}", p.ppa.power_mw));
        if with_accuracy {
            row.push(match p.accuracy {
                Some(a) => format!("{a:.4}"),
                None => "-".to_string(),
            });
        }
        row.push(precision_cell(&p.precision));
        row.push(p.config.key());
        t.row(row);
    }
    t
}

/// Convergence report for `qappa optimize`: the per-generation spend /
/// frontier-size / hypervolume trajectory (hypervolume is measured against
/// the run's fixed reference corner).
pub fn opt_convergence_table(resp: &OptimizeResponse) -> Table {
    // Column count follows the run's objective arity (>= 2, so classic
    // two-objective reports keep their historical shape byte-for-byte).
    let nobj = resp
        .generations
        .iter()
        .map(|g| g.best.len())
        .max()
        .unwrap_or(resp.objectives.len())
        .max(2);
    let best_headers: Vec<String> = (0..nobj).map(|k| format!("best_obj{k}")).collect();
    let mut header = vec!["generation", "evaluated", "frontier", "hypervolume"];
    header.extend(best_headers.iter().map(String::as_str));
    let mut t = Table::new(&header);
    for g in &resp.generations {
        let mut row = vec![
            g.generation.to_string(),
            g.evaluated.to_string(),
            g.frontier.to_string(),
            fmt_g(g.hypervolume),
        ];
        for k in 0..nobj {
            row.push(g.best.get(k).copied().map(fmt_g).unwrap_or_else(|| "-".to_string()));
        }
        t.row(row);
    }
    t
}

/// One engine-counter row (shared by the single- and multi-workload
/// stats tables).
fn stats_row(workload: &str, ty: PeType, st: &crate::coordinator::sweep::SweepStats) -> Vec<String> {
    vec![
        workload.to_string(),
        ty.label().to_string(),
        st.evaluated.to_string(),
        st.shards.to_string(),
        st.frontier_len.to_string(),
        st.peak_resident.to_string(),
    ]
}

const STATS_HEADER: [&str; 6] =
    ["workload", "pe_type", "evaluated", "shards", "frontier", "peak_resident"];

/// Engine counters for a multi-workload run: per (workload, PE type)
/// evaluated points, shard count and the peak resident point set — the
/// streaming-memory guarantee, in a table.
pub fn sweep_stats_table(summaries: &[WorkloadSummary]) -> Table {
    let mut t = Table::new(&STATS_HEADER);
    for s in summaries {
        for ty in ALL_PE_TYPES {
            t.row(stats_row(&s.workload, ty, &s.stats[&ty]));
        }
    }
    t
}

/// Engine counters for a single-workload `DseResult` (`qappa dse --stats`).
pub fn dse_stats_table(res: &DseResult) -> Table {
    let mut t = Table::new(&STATS_HEADER);
    for ty in ALL_PE_TYPES {
        t.row(stats_row(&res.workload, ty, &res.stats[&ty]));
    }
    t
}

/// Per-layer table for `qappa workloads --workload W`: taxonomy kind,
/// shape, and the groups-aware MAC count of every layer.  When any layer
/// carries a per-layer precision override, a `precision` column is
/// appended (mixed-precision networks); transformer workloads (any
/// matmul/attention layer) get `shape` and `KV_KB` columns.  Plain CNN
/// workloads keep the historical column set byte-for-byte.
pub fn workload_table(layers: &[Layer]) -> Table {
    use crate::dataflow::Op;
    let mixed = layers.iter().any(|l| l.quant.is_some());
    let transformer = layers.iter().any(|l| l.is_transformer());
    let mut header =
        vec!["layer", "kind", "c", "k", "hw", "rs", "stride", "groups", "MACs_M"];
    if transformer {
        header.push("shape");
        header.push("KV_KB");
    }
    if mixed {
        header.push("precision");
    }
    let mut t = Table::new(&header);
    for l in layers {
        let mut row = vec![
            l.name.clone(),
            l.kind().to_string(),
            l.c.to_string(),
            l.k.to_string(),
            l.hw.to_string(),
            l.rs.to_string(),
            l.stride.to_string(),
            l.groups.to_string(),
            format!("{:.2}", l.macs() as f64 / 1e6),
        ];
        if transformer {
            row.push(match l.op {
                Op::Matmul { m, k, n } => format!("m{m}xk{k}xn{n}"),
                Op::Attention { heads, head_dim, seq_q, seq_kv } => {
                    format!("h{heads}xd{head_dim}xq{seq_q}xkv{seq_kv}")
                }
                Op::Conv => "-".to_string(),
            });
            // KV cache residency per layer at the layer's activation
            // width (override, else the 16-bit baseline operand).
            let act_bits = l.quant.map(|q| q.act_bits).unwrap_or(16) as u64;
            row.push(match l.kv_elems() {
                0 => "-".to_string(),
                kv => format!("{:.1}", (kv * act_bits) as f64 / 8.0 / 1e3),
            });
        }
        if mixed {
            row.push(match l.quant {
                Some(q) => crate::config::PeType::from_spec(q).label(),
                None => "-".to_string(),
            });
        }
        t.row(row);
    }
    t
}

/// Full scatter (the actual figure series): normalized perf/area and
/// normalized energy per point, per PE type.
pub fn dse_scatter_table(res: &DseResult) -> Table {
    let mut t = Table::new(&[
        "pe_type",
        "norm_perf_per_area",
        "norm_energy",
        "on_frontier",
        "pe_rows",
        "pe_cols",
        "glb_kb",
        "spad_if",
        "spad_w",
        "spad_ps",
        "bw_gbps",
    ]);
    for ty in ALL_PE_TYPES {
        let pts = &res.points[&ty];
        let frontier: std::collections::BTreeSet<usize> =
            res.frontier[&ty].iter().copied().collect();
        for (i, p) in pts.iter().enumerate() {
            t.row(vec![
                ty.label().to_string(),
                fmt_g(p.perf_per_area / res.anchor.perf_per_area),
                fmt_g(p.energy_mj / res.anchor.energy_mj),
                (frontier.contains(&i) as u8).to_string(),
                p.cfg.pe_rows.to_string(),
                p.cfg.pe_cols.to_string(),
                p.cfg.glb_kb.to_string(),
                p.cfg.spad_ifmap_b.to_string(),
                p.cfg.spad_filter_b.to_string(),
                p.cfg.spad_psum_b.to_string(),
                format!("{:.2}", p.cfg.bandwidth_gbps),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::space::DesignSpace;
    use crate::model::native::NativeBackend;
    use crate::model::CvConfig;

    fn opts() -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 192,
            cv: CvConfig { k: 3, degrees: vec![2], lambdas: vec![1e-3], seed: 2 },
            seed: 5,
            workers: 4,
            sigma: 0.02,
            chunk: 1024,
            topk: 8,
        }
    }

    #[test]
    fn fig2_rows_cover_types_and_metrics() {
        let backend = NativeBackend::new(7);
        let rows = fig2_accuracy(&backend, &opts(), 48).unwrap();
        assert_eq!(rows.len(), 4 * 3);
        for r in &rows {
            assert!(r.r2 > 0.8, "{:?} {} R2 {}", r.pe_type, r.metric, r.r2);
            assert!(r.mape < 15.0, "{:?} {} MAPE {}", r.pe_type, r.metric, r.mape);
        }
        let t = fig2_table(&rows);
        assert_eq!(t.len(), 12);
    }

    #[test]
    fn summary_and_scatter_render() {
        let backend = NativeBackend::new(7);
        let layers = vec![crate::dataflow::Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)];
        let res =
            crate::coordinator::explorer::run_dse(&backend, &layers, "t", &opts()).unwrap();
        let summary = dse_summary_table(&res);
        assert_eq!(summary.len(), 4);
        let scatter = dse_scatter_table(&res);
        assert_eq!(scatter.len(), 4 * opts().space.len());
        // CSV round trip sanity
        assert!(scatter.to_csv().lines().count() == scatter.len() + 1);
    }

    #[test]
    fn multi_summary_and_stats_tables_render() {
        let backend = NativeBackend::new(7);
        let store = crate::coordinator::explorer::ModelStore::new();
        let named = vec![
            crate::coordinator::sweep::NamedWorkload::new(
                "a",
                vec![crate::dataflow::Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)],
            ),
            crate::coordinator::sweep::NamedWorkload::new(
                "b",
                vec![crate::dataflow::Layer::conv("d", 3, 8, 32, 32, 3, 1, 1)],
            ),
        ];
        let summaries =
            crate::coordinator::explorer::run_dse_multi(&backend, &store, &named, &opts())
                .unwrap();
        let t = multi_summary_table(&summaries);
        assert_eq!(t.len(), 2 * 4);
        let csv = t.to_csv();
        assert!(csv.contains("a,"), "workload column missing");
        assert!(csv.contains("INT16"));
        let st = sweep_stats_table(&summaries);
        assert_eq!(st.len(), 2 * 4);
        assert!(st.to_csv().contains(&opts().space.len().to_string()));
    }

    #[test]
    fn workload_table_reports_kinds_and_grouped_macs() {
        let layers = crate::workloads::mobilenetv2();
        let t = workload_table(&layers);
        assert_eq!(t.len(), layers.len());
        let csv = t.to_csv();
        assert!(csv.contains("dw"), "depthwise kind missing from table");
        assert!(csv.contains("pw"), "pointwise kind missing from table");
        // no override anywhere -> the historical column set, byte-for-byte
        assert!(!csv.lines().next().unwrap().contains("precision"));
    }

    #[test]
    fn workload_table_shows_precision_column_for_mixed_nets() {
        use crate::config::QuantSpec;
        let mut layers = crate::workloads::mobilenetv1();
        for l in layers.iter_mut().filter(|l| l.is_depthwise()) {
            l.quant = Some(QuantSpec::int(4, 4));
        }
        let t = workload_table(&layers);
        let csv = t.to_csv();
        assert!(csv.lines().next().unwrap().contains("precision"));
        assert!(csv.contains("a4w4p8-int"), "{csv}");
        assert!(csv.contains(",-"), "non-overridden layers show '-'");
        // a pure-CNN net never grows the transformer columns
        assert!(!csv.lines().next().unwrap().contains("shape"));
        assert!(!csv.lines().next().unwrap().contains("KV_KB"));
    }

    #[test]
    fn workload_table_shows_shape_and_kv_for_transformers() {
        use crate::workloads::{shape_for_phase, Phase};
        let layers = shape_for_phase(&crate::workloads::opt_1p3b(), Phase::Decode, 2048);
        let t = workload_table(&layers);
        let csv = t.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("shape") && header.contains("KV_KB"), "{header}");
        assert!(csv.contains("matmul"), "{csv}");
        assert!(csv.contains("attention"), "{csv}");
        // attention rows carry the qkv shape and a nonzero KV footprint;
        // matmul rows show '-' in the KV column
        assert!(csv.contains("q1xkv2048"), "{csv}");
        assert!(csv.contains("m1xk"), "{csv}");
        let attn = csv
            .lines()
            .find(|l| l.contains("attention"))
            .expect("attention row");
        let kv_kb: f64 = attn.split(',').nth(10).unwrap().parse().unwrap();
        assert!(kv_kb > 0.0, "{attn}");

        // rendered (aligned) output sizes the name column to the longest
        // dotted name (blk0.attn.qkv style), so `kind` starts at the same
        // offset on every line
        let rendered = t.render();
        assert!(rendered.contains("blk0.attn.qkv"), "{rendered}");
        let name_w = layers
            .iter()
            .map(|l| l.name.len())
            .max()
            .unwrap()
            .max("layer".len());
        let header_line = rendered.lines().next().unwrap();
        assert_eq!(header_line.find("kind"), Some(name_w + 2), "{header_line}");
    }

    #[test]
    fn opt_tables_render_frontier_and_convergence() {
        use crate::api::types::{OptPoint, OptimizeResponse};
        use crate::config::{AcceleratorConfig, PeType};
        use crate::opt::engine::GenStat;
        use crate::synth::oracle::Ppa;
        let resp = OptimizeResponse {
            workload: "mnv1".into(),
            strategy: "nsga2".into(),
            objectives: vec!["perf/area".into(), "energy".into()],
            evaluated: 96,
            budget: 100,
            ref_point: vec![0.5, 8.0],
            hypervolume: 1.25,
            frontier: vec![
                OptPoint {
                    config: AcceleratorConfig::default_with(PeType::LightPe1),
                    objectives: vec![0.25, 4.0],
                    throughput: 400.0,
                    energy_mj: 4.0,
                    ppa: Ppa { power_mw: 210.0, fmax_mhz: 900.0, area_mm2: 1.5 },
                    precision: vec!["LightPE-1".into(); 3],
                    accuracy: None,
                },
                OptPoint {
                    config: AcceleratorConfig::default_with(PeType::Int16),
                    objectives: vec![0.4, 3.0],
                    throughput: 250.0,
                    energy_mj: 3.0,
                    ppa: Ppa { power_mw: 300.0, fmax_mhz: 800.0, area_mm2: 2.5 },
                    precision: vec!["a4w4p8-int".into(), "INT16".into(), "INT16".into()],
                    accuracy: None,
                },
            ],
            generations: vec![
                GenStat {
                    generation: 0,
                    evaluated: 32,
                    frontier: 4,
                    hypervolume: 0.75,
                    best: vec![0.3, 3.5],
                },
                GenStat {
                    generation: 1,
                    evaluated: 96,
                    frontier: 7,
                    hypervolume: 1.25,
                    best: vec![0.25, 3.0],
                },
            ],
            memo: Default::default(),
        };
        let t = opt_frontier_table(&resp);
        assert_eq!(t.len(), 2);
        let csv = t.to_csv();
        assert!(csv.lines().next().unwrap().contains("perf/area(min)"), "{csv}");
        // accuracy-free runs keep the classic column set
        assert!(!csv.lines().next().unwrap().contains("accuracy"), "{csv}");
        // uniform assignment collapses to one label; mixed shows counts
        assert!(csv.contains("LightPE-1"), "{csv}");
        assert!(csv.contains("a4w4p8-int x1 + INT16 x2"), "{csv}");
        let c = opt_convergence_table(&resp);
        assert_eq!(c.len(), 2);
        assert!(c.to_csv().contains("hypervolume"));
        // empty precision renders a placeholder, not a panic
        assert_eq!(super::precision_cell(&[]), "-");

        // a three-objective accuracy run grows the matching columns
        let mut acc = resp.clone();
        acc.objectives = vec!["latency".into(), "energy".into(), "accuracy".into()];
        for (p, a) in acc.frontier.iter_mut().zip([0.97, 0.95]) {
            p.objectives.push(1.0 - a);
            p.accuracy = Some(a);
        }
        for g in &mut acc.generations {
            g.best.push(0.05);
        }
        let ft = opt_frontier_table(&acc);
        let head = ft.to_csv().lines().next().unwrap().to_string();
        // both the objective column and the estimate column appear
        assert!(head.contains("accuracy(min)"), "{head}");
        assert!(head.matches("accuracy").count() >= 2, "{head}");
        assert!(ft.to_csv().contains("0.9700"), "{}", ft.to_csv());
        let ct = opt_convergence_table(&acc);
        assert!(ct.to_csv().lines().next().unwrap().contains("best_obj2"));
    }

    #[test]
    fn precision_summary_table_has_one_row_per_cell() {
        use crate::config::{MacKind, QUANT_NUM_FEATURES};
        use crate::coordinator::precision::{run_dse_precision, PrecisionGrid};
        let backend = NativeBackend::new(QUANT_NUM_FEATURES);
        let store = crate::coordinator::explorer::ModelStore::new();
        let grid = PrecisionGrid::from_ranges(&[8, 16], &[8], &[], MacKind::IntExact).unwrap();
        let named = vec![crate::coordinator::sweep::NamedWorkload::new(
            "a",
            vec![crate::dataflow::Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)],
        )];
        let mut opts = opts();
        opts.train_per_type = 96;
        let summaries = run_dse_precision(&backend, &store, &named, &opts, &grid).unwrap();
        let t = precision_summary_table(&summaries);
        assert_eq!(t.len(), grid.len());
        let csv = t.to_csv();
        assert!(csv.contains("precision"), "{csv}");
        assert!(csv.contains("a8w8p16-int"), "{csv}");
    }
}
