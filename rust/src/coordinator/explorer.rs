//! The end-to-end DSE pipeline (paper §4).

use std::collections::BTreeMap;

use crate::config::{AcceleratorConfig, PeType, ALL_PE_TYPES};
use crate::coordinator::pareto::pareto_frontier;
use crate::coordinator::space::DesignSpace;
use crate::dataflow::{evaluate_network, Layer};
use crate::model::{fit_ppa, predict_ppa, Backend, CvConfig, PpaModel};
use crate::synth::oracle::{energy_params, synthesize_with_sigma, Ppa, JITTER_SIGMA};
use crate::util::pool::{default_workers, parallel_map};

/// Options for one DSE run.
#[derive(Debug, Clone)]
pub struct DseOptions {
    pub space: DesignSpace,
    /// Training configs sampled (and "synthesized") per PE type.
    pub train_per_type: usize,
    pub cv: CvConfig,
    pub seed: u64,
    pub workers: usize,
    /// Synthesis jitter sigma (ablation hook).
    pub sigma: f64,
}

impl Default for DseOptions {
    fn default() -> DseOptions {
        DseOptions {
            space: DesignSpace::default(),
            train_per_type: 384,
            cv: CvConfig::default(),
            seed: 42,
            workers: default_workers(),
            sigma: JITTER_SIGMA,
        }
    }
}

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub cfg: AcceleratorConfig,
    /// Model-predicted PPA (the DSE currency; ground truth only exists for
    /// the training sample).
    pub ppa: Ppa,
    /// Inferences/s on the workload.
    pub throughput: f64,
    /// Throughput per mm².
    pub perf_per_area: f64,
    /// Energy per inference, mJ (predicted power x modeled latency).
    pub energy_mj: f64,
    pub utilization: f64,
}

/// Result of a DSE run over one workload.
pub struct DseResult {
    pub workload: String,
    pub models: BTreeMap<PeType, PpaModel>,
    pub points: BTreeMap<PeType, Vec<DsePoint>>,
    /// Pareto-frontier indices into `points[ty]`.
    pub frontier: BTreeMap<PeType, Vec<usize>>,
    /// The INT16 anchor: index of the max-perf/area INT16 point.
    pub anchor: DsePoint,
    /// (perf/area ratio, energy-improvement ratio) vs the anchor, per type,
    /// at each type's best point for the respective metric — computed from
    /// the *model-predicted* PPA (what the framework's user sees).
    pub ratios: BTreeMap<PeType, (f64, f64)>,
    /// The same ratios with the winning configs re-synthesized by the
    /// oracle (ground truth). Selecting the best of ~2e4 noisy predictions
    /// is optimistically biased (winner's curse); these are the honest
    /// post-selection numbers EXPERIMENTS.md reports.
    pub ratios_validated: BTreeMap<PeType, (f64, f64)>,
}

/// Train one PPA model per PE type from oracle data.
/// Phase-timing hook: set `QAPPA_TRACE=1` to print per-phase wall times.
fn trace(phase: &str, t0: std::time::Instant) {
    if std::env::var_os("QAPPA_TRACE").is_some() {
        eprintln!("[trace] {phase}: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }
}

pub fn train_models(
    backend: &dyn Backend,
    opts: &DseOptions,
) -> Result<BTreeMap<PeType, PpaModel>, String> {
    let mut models = BTreeMap::new();
    for ty in ALL_PE_TYPES {
        let t0 = std::time::Instant::now();
        let cfgs = opts.space.sample(ty, opts.train_per_type, opts.seed);
        let ppas: Vec<Ppa> = parallel_map(&cfgs, opts.workers, |c| {
            synthesize_with_sigma(c, opts.sigma)
        });
        trace(&format!("train/{}/synth({})", ty.label(), cfgs.len()), t0);
        let mut feats = Vec::with_capacity(cfgs.len() * 7);
        let mut targets = Vec::with_capacity(cfgs.len() * 3);
        for (c, p) in cfgs.iter().zip(&ppas) {
            feats.extend_from_slice(&c.features());
            targets.extend_from_slice(&p.as_array());
        }
        let t1 = std::time::Instant::now();
        let model = fit_ppa(backend, &feats, &targets, &opts.cv)
            .map_err(|e| format!("{}: {e}", ty.label()))?;
        trace(&format!("train/{}/cv_fit", ty.label()), t1);
        models.insert(ty, model);
    }
    Ok(models)
}

/// Evaluate one predicted config on the workload.
fn eval_point(cfg: &AcceleratorConfig, ppa: Ppa, layers: &[Layer]) -> DsePoint {
    // Energy coefficients are structural (jitter-free); the clock the
    // dataflow runs at is the *predicted* fmax, and energy uses the
    // *predicted* power — the regression models drive the DSE.
    let mut ep = energy_params(cfg);
    ep.fmax_mhz = ppa.fmax_mhz.max(1.0);
    let cost = evaluate_network(cfg, &ep, layers);
    let throughput = 1.0 / cost.latency_s.max(1e-12);
    let energy_mj = ppa.power_mw * cost.latency_s; // mW x s = mJ
    DsePoint {
        cfg: *cfg,
        ppa,
        throughput,
        perf_per_area: throughput / ppa.area_mm2.max(1e-9),
        energy_mj,
        utilization: cost.avg_utilization,
    }
}

/// Full pipeline: train models, sweep the space, evaluate the workload,
/// extract frontiers and the paper's ratios.
pub fn run_dse(
    backend: &dyn Backend,
    layers: &[Layer],
    workload: &str,
    opts: &DseOptions,
) -> Result<DseResult, String> {
    let models = train_models(backend, opts)?;

    let mut points = BTreeMap::new();
    for ty in ALL_PE_TYPES {
        let cfgs = opts.space.enumerate(ty);
        let model = &models[&ty];
        // Batched prediction over the whole grid (engine tiles to B=256).
        let mut feats = Vec::with_capacity(cfgs.len() * 7);
        for c in &cfgs {
            feats.extend_from_slice(&c.features());
        }
        let t0 = std::time::Instant::now();
        let preds = predict_ppa(backend, model, &feats)?;
        trace(&format!("sweep/{}/predict({})", ty.label(), preds.len()), t0);
        // Workload evaluation in parallel.
        let items: Vec<(AcceleratorConfig, [f64; 3])> =
            cfgs.into_iter().zip(preds).collect();
        let t1 = std::time::Instant::now();
        let pts: Vec<DsePoint> = parallel_map(&items, opts.workers, |(cfg, ppa)| {
            eval_point(cfg, Ppa::from_array(*ppa), layers)
        });
        trace(&format!("sweep/{}/dataflow({})", ty.label(), pts.len()), t1);
        points.insert(ty, pts);
    }

    // Anchor: best-perf/area INT16 point.
    let int16 = &points[&PeType::Int16];
    let anchor = int16
        .iter()
        .max_by(|a, b| a.perf_per_area.partial_cmp(&b.perf_per_area).unwrap())
        .ok_or("empty INT16 space")?
        .clone();

    // Ground-truth re-evaluation of the anchor for validated ratios.
    let anchor_true = eval_point(
        &anchor.cfg,
        synthesize_with_sigma(&anchor.cfg, opts.sigma),
        layers,
    );

    let mut frontier = BTreeMap::new();
    let mut ratios = BTreeMap::new();
    let mut ratios_validated = BTreeMap::new();
    for ty in ALL_PE_TYPES {
        let pts = &points[&ty];
        let pairs: Vec<(f64, f64)> =
            pts.iter().map(|p| (p.perf_per_area, p.energy_mj)).collect();
        frontier.insert(ty, pareto_frontier(&pairs));
        let best_pa_pt = pts
            .iter()
            .max_by(|a, b| a.perf_per_area.partial_cmp(&b.perf_per_area).unwrap())
            .ok_or("empty space")?;
        let best_e_pt = pts
            .iter()
            .min_by(|a, b| a.energy_mj.partial_cmp(&b.energy_mj).unwrap())
            .ok_or("empty space")?;
        ratios.insert(
            ty,
            (
                best_pa_pt.perf_per_area / anchor.perf_per_area,
                anchor.energy_mj / best_e_pt.energy_mj,
            ),
        );
        // Winner validation: synthesize the chosen configs for real.
        let pa_true = eval_point(
            &best_pa_pt.cfg,
            synthesize_with_sigma(&best_pa_pt.cfg, opts.sigma),
            layers,
        );
        let e_true = eval_point(
            &best_e_pt.cfg,
            synthesize_with_sigma(&best_e_pt.cfg, opts.sigma),
            layers,
        );
        ratios_validated.insert(
            ty,
            (
                pa_true.perf_per_area / anchor_true.perf_per_area,
                anchor_true.energy_mj / e_true.energy_mj,
            ),
        );
    }

    Ok(DseResult {
        workload: workload.to_string(),
        models,
        points,
        frontier,
        anchor,
        ratios,
        ratios_validated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::NativeBackend;
    use crate::workloads;

    fn tiny_opts() -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
        }
    }

    fn small_net() -> Vec<Layer> {
        vec![
            Layer::conv("c1", 3, 16, 32, 32, 3, 1, 1),
            Layer::conv("c2", 16, 32, 16, 16, 3, 1, 1),
            Layer::fc("fc", 512, 10),
        ]
    }

    #[test]
    fn dse_pipeline_runs_native() {
        let backend = NativeBackend::new(7);
        let res = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        for ty in ALL_PE_TYPES {
            let pts = &res.points[&ty];
            assert_eq!(pts.len(), tiny_opts().space.len());
            for p in pts {
                assert!(p.perf_per_area > 0.0, "{ty:?}");
                assert!(p.energy_mj > 0.0);
                assert!(p.ppa.area_mm2 > 0.0);
            }
            assert!(!res.frontier[&ty].is_empty());
        }
        // anchor is an INT16 point with the max perf/area
        let int16 = &res.points[&PeType::Int16];
        let max_pa = int16.iter().map(|p| p.perf_per_area).fold(f64::MIN, f64::max);
        assert!((res.anchor.perf_per_area - max_pa).abs() < 1e-12);
        // INT16's own ratio anchor-relative perf/area is 1.0
        assert!((res.ratios[&PeType::Int16].0 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lightpe_dominates_int16_in_tiny_dse() {
        let backend = NativeBackend::new(7);
        let res = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        let (pa1, e1) = res.ratios[&PeType::LightPe1];
        assert!(pa1 > 1.2, "LightPE-1 perf/area ratio {pa1}");
        assert!(e1 > 1.2, "LightPE-1 energy ratio {e1}");
        let (paf, ef) = res.ratios[&PeType::Fp32];
        assert!(paf < 1.0, "FP32 perf/area ratio {paf}");
        assert!(ef < 1.0, "FP32 energy ratio {ef}");
    }

    #[test]
    fn models_predict_training_oracle_well() {
        let backend = NativeBackend::new(7);
        let opts = tiny_opts();
        let models = train_models(&backend, &opts).unwrap();
        // holdout check on fresh samples
        for ty in ALL_PE_TYPES {
            let cfgs = opts.space.sample(ty, 64, 999);
            let mut feats = Vec::new();
            for c in &cfgs {
                feats.extend_from_slice(&c.features());
            }
            let preds = predict_ppa(&backend, &models[&ty], &feats).unwrap();
            let mut rel_err = 0.0;
            for (c, pred) in cfgs.iter().zip(&preds) {
                let truth = synthesize_with_sigma(c, opts.sigma).as_array();
                for k in 0..3 {
                    rel_err += ((pred[k] - truth[k]) / truth[k]).abs();
                }
            }
            rel_err /= (cfgs.len() * 3) as f64;
            assert!(rel_err < 0.12, "{ty:?} holdout rel err {rel_err}");
        }
    }

    #[test]
    fn dse_deterministic_under_seed() {
        let backend = NativeBackend::new(7);
        let a = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        let b = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        assert_eq!(a.anchor.cfg, b.anchor.cfg);
        for ty in ALL_PE_TYPES {
            assert_eq!(a.frontier[&ty], b.frontier[&ty]);
        }
    }

    #[test]
    fn works_on_real_workloads() {
        let backend = NativeBackend::new(7);
        let mut opts = tiny_opts();
        opts.train_per_type = 48;
        let layers = workloads::vgg16();
        let res = run_dse(&backend, &layers[..4], "vgg16-head", &opts).unwrap();
        assert!(res.ratios[&PeType::LightPe1].0 > 1.0);
    }

    #[test]
    fn works_on_depthwise_workloads() {
        // MobileNetV2 head (stem + first two inverted-residual blocks):
        // the DSE pipeline must evaluate depthwise layers end-to-end and
        // still produce positive, frontier-bearing points for every type.
        let backend = NativeBackend::new(7);
        let mut opts = tiny_opts();
        opts.train_per_type = 48;
        let layers = workloads::mobilenetv2();
        assert!(layers[..6].iter().any(|l| l.is_depthwise()));
        let res = run_dse(&backend, &layers[..6], "mobilenetv2-head", &opts).unwrap();
        for ty in ALL_PE_TYPES {
            for p in &res.points[&ty] {
                assert!(p.throughput > 0.0 && p.energy_mj > 0.0, "{ty:?}");
            }
            assert!(!res.frontier[&ty].is_empty());
        }
    }
}
