//! The end-to-end DSE pipeline (paper §4).
//!
//! `run_dse` is a thin composition of three pieces:
//!
//! * a [`ModelStore`] — PPA models cached by (PE type, space hash, training
//!   recipe), so one training pass is shared across workloads and repeat
//!   runs;
//! * the streaming [`SweepEngine`] (`coordinator::sweep`) — shards of the
//!   lazy space cursor pipelined through predict -> dataflow-eval with an
//!   incremental Pareto frontier and top-k reservoirs;
//! * ratio/validation reporting — the paper's anchor-normalized ratios,
//!   plus the honest post-selection numbers from re-synthesizing winners.
//!
//! [`run_dse_multi`] evaluates many networks in one pass over the grid:
//! each shard is predicted once per PE type and folded into per-workload
//! accumulators.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, PeType, ALL_PE_TYPES};
use crate::coordinator::space::DesignSpace;
use crate::coordinator::sweep::{
    eval_point, NamedWorkload, SweepEngine, SweepStats, TypeSweep,
};
use crate::dataflow::Layer;
use crate::model::{fit_ppa, Backend, CvConfig, PpaModel};
use crate::obs;
use crate::obs::trace::phase_with;
use crate::synth::oracle::{synthesize_with_sigma, Ppa, JITTER_SIGMA};
use crate::util::pool::{default_workers, parallel_map};
use crate::util::prng::hash64;

/// Options for one DSE run.
#[derive(Debug, Clone)]
pub struct DseOptions {
    pub space: DesignSpace,
    /// Training configs sampled (and "synthesized") per PE type.
    pub train_per_type: usize,
    pub cv: CvConfig,
    pub seed: u64,
    pub workers: usize,
    /// Synthesis jitter sigma (ablation hook).
    pub sigma: f64,
    /// Sweep shard size; 0 = whole grid in one shard (eager-equivalent).
    pub chunk: usize,
    /// Reservoir depth for the best-perf/area and best-energy top-k sets.
    pub topk: usize,
}

impl Default for DseOptions {
    fn default() -> DseOptions {
        DseOptions {
            space: DesignSpace::default(),
            train_per_type: 384,
            cv: CvConfig::default(),
            seed: 42,
            workers: default_workers(),
            sigma: JITTER_SIGMA,
            chunk: 1024,
            topk: 8,
        }
    }
}

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub cfg: AcceleratorConfig,
    /// Model-predicted PPA (the DSE currency; ground truth only exists for
    /// the training sample).
    pub ppa: Ppa,
    /// Inferences/s on the workload.
    pub throughput: f64,
    /// Throughput per mm².
    pub perf_per_area: f64,
    /// Energy per inference, mJ (predicted power x modeled latency).
    pub energy_mj: f64,
    pub utilization: f64,
}

/// Result of a DSE run over one workload.
pub struct DseResult {
    pub workload: String,
    pub models: BTreeMap<PeType, PpaModel>,
    pub points: BTreeMap<PeType, Vec<DsePoint>>,
    /// Pareto-frontier indices into `points[ty]`.
    pub frontier: BTreeMap<PeType, Vec<usize>>,
    /// The INT16 anchor: index of the max-perf/area INT16 point.
    pub anchor: DsePoint,
    /// (perf/area ratio, energy-improvement ratio) vs the anchor, per type,
    /// at each type's best point for the respective metric — computed from
    /// the *model-predicted* PPA (what the framework's user sees).
    pub ratios: BTreeMap<PeType, (f64, f64)>,
    /// The same ratios with the winning configs re-synthesized by the
    /// oracle (ground truth). Selecting the best of ~2e4 noisy predictions
    /// is optimistically biased (winner's curse); these are the honest
    /// post-selection numbers EXPERIMENTS.md reports.
    pub ratios_validated: BTreeMap<PeType, (f64, f64)>,
    /// Per-type sweep counters (evaluated points, shards, peak resident).
    pub stats: BTreeMap<PeType, SweepStats>,
}

/// Streaming result of one workload inside a multi-workload run: only the
/// frontier, the reservoirs and the ratio summary are retained —
/// O(frontier + k) points instead of O(grid).
pub struct WorkloadSummary {
    pub workload: String,
    /// Pareto frontier points per type, grid order.
    pub frontier: BTreeMap<PeType, Vec<DsePoint>>,
    /// Best-perf/area reservoir per type, best-first.
    pub top_perf_per_area: BTreeMap<PeType, Vec<DsePoint>>,
    /// Best-energy reservoir per type, best-first.
    pub top_energy: BTreeMap<PeType, Vec<DsePoint>>,
    pub anchor: DsePoint,
    pub ratios: BTreeMap<PeType, (f64, f64)>,
    pub ratios_validated: BTreeMap<PeType, (f64, f64)>,
    pub stats: BTreeMap<PeType, SweepStats>,
}

// ---------------------------------------------------------------------------
// model store
// ---------------------------------------------------------------------------

/// Cache of trained PPA models keyed by (PE type, training recipe hash).
///
/// The hash covers everything that determines the fitted model: the design
/// space ([`DesignSpace::space_hash`]), `train_per_type`, the DSE seed, the
/// jitter sigma, the CV grid, and the backend.  One store shared across
/// workloads / repeat runs means each PE-type model is trained exactly
/// once — hit/miss counters make that assertable.
#[derive(Default)]
pub struct ModelStore {
    entries: Mutex<BTreeMap<(PeType, u64), Arc<PpaModel>>>,
    /// Unified cross-precision models, keyed by (recipe, precision grid)
    /// hash — one model per grid, shared across workloads and repeat runs.
    quant_entries: Mutex<BTreeMap<u64, Arc<PpaModel>>>,
    /// Serializes all training through the store (one pass at a time):
    /// concurrent requests for the same (type, recipe) dedupe — the loser
    /// re-checks the cache under this lock and records a hit instead of
    /// retraining.  Training of *different* keys also queues here; each
    /// pass is internally parallel (oracle fleet), so the lost overlap is
    /// small and the trained-exactly-once invariant stays simple.
    train_lock: Mutex<()>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl ModelStore {
    pub fn new() -> ModelStore {
        ModelStore::default()
    }

    /// One avoided training pass: bump the store counter and the
    /// process-wide `store.cache_hits` metric together.
    fn note_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        obs::registry().counter("store.cache_hits").inc();
    }

    /// One training pass actually run (`store.models_trained`).
    fn note_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        obs::registry().counter("store.models_trained").inc();
    }

    fn recipe_hash(backend: &dyn Backend, opts: &DseOptions) -> u64 {
        let mut s = format!(
            "{:x}|{}|{}|{:x}|{}|{}|{:x}",
            opts.space.space_hash(),
            opts.train_per_type,
            opts.seed,
            opts.sigma.to_bits(),
            backend.name(),
            opts.cv.k,
            opts.cv.seed,
        );
        for d in &opts.cv.degrees {
            s.push_str(&format!("d{d}"));
        }
        for l in &opts.cv.lambdas {
            s.push_str(&format!("l{:x}", l.to_bits()));
        }
        hash64(s.as_bytes())
    }

    /// Return the cached model for `ty`, training it on a miss.  In-flight
    /// training is deduplicated: concurrent callers of the same (type,
    /// recipe) block on one training pass instead of each running their
    /// own, so a warm serving session trains each model exactly once no
    /// matter how many requests race on a cold cache.
    pub fn get_or_train(
        &self,
        backend: &dyn Backend,
        opts: &DseOptions,
        ty: PeType,
    ) -> Result<Arc<PpaModel>, QappaError> {
        let key = (ty, Self::recipe_hash(backend, opts));
        if let Some(m) = self.entries.lock().unwrap().get(&key) {
            self.note_hit();
            return Ok(m.clone());
        }
        let _training = self.train_lock.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(m) = self.entries.lock().unwrap().get(&key) {
            self.note_hit();
            return Ok(m.clone());
        }
        self.note_miss();
        let model = Arc::new(train_one_model(backend, opts, ty)?);
        self.entries.lock().unwrap().insert(key, model.clone());
        Ok(model)
    }

    /// Return the cached unified cross-precision model for a precision
    /// grid, training it on a miss (same recipe hashing, in-flight
    /// deduplication and hit/miss counters as the per-type path).  The
    /// backend must be built for `QUANT_NUM_FEATURES` features.
    pub fn get_or_train_quant(
        &self,
        backend: &dyn Backend,
        opts: &DseOptions,
        grid: &[PeType],
    ) -> Result<Arc<PpaModel>, QappaError> {
        let mut s = format!("{:x}|quant", Self::recipe_hash(backend, opts));
        for ty in grid {
            s.push_str(&ty.label());
            s.push(',');
        }
        let key = hash64(s.as_bytes());
        if let Some(m) = self.quant_entries.lock().unwrap().get(&key) {
            self.note_hit();
            return Ok(m.clone());
        }
        let _training = self.train_lock.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(m) = self.quant_entries.lock().unwrap().get(&key) {
            self.note_hit();
            return Ok(m.clone());
        }
        self.note_miss();
        let model =
            Arc::new(crate::coordinator::precision::train_quant_model(backend, opts, grid)?);
        self.quant_entries.lock().unwrap().insert(key, model.clone());
        Ok(model)
    }

    /// Cache hits so far (a hit = one avoided training pass).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far (= training passes actually run).
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct models resident in the store.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Train the PPA model of one PE type from oracle data.
pub fn train_one_model(
    backend: &dyn Backend,
    opts: &DseOptions,
    ty: PeType,
) -> Result<PpaModel, QappaError> {
    // A degenerate space (empty axis) must fail with the axis named, not
    // panic inside `sample`.
    opts.space.validate()?;
    let t0 = std::time::Instant::now();
    let cfgs = opts.space.sample(ty, opts.train_per_type, opts.seed);
    let ppas: Vec<Ppa> = parallel_map(&cfgs, opts.workers, |c| {
        synthesize_with_sigma(c, opts.sigma)
    });
    phase_with(|| format!("train/{}/synth({})", ty.label(), cfgs.len()), t0);
    let mut feats = Vec::with_capacity(cfgs.len() * 7);
    let mut targets = Vec::with_capacity(cfgs.len() * 3);
    for (c, p) in cfgs.iter().zip(&ppas) {
        feats.extend_from_slice(&c.features());
        targets.extend_from_slice(&p.as_array());
    }
    let t1 = std::time::Instant::now();
    let model = fit_ppa(backend, &feats, &targets, &opts.cv)
        .map_err(|e| e.context(ty.label()))?;
    phase_with(|| format!("train/{}/cv_fit", ty.label()), t1);
    obs::registry()
        .histogram("store.train_ms")
        .record_ms(t0.elapsed().as_secs_f64() * 1e3);
    Ok(model)
}

/// Train one PPA model per PE type from oracle data.
pub fn train_models(
    backend: &dyn Backend,
    opts: &DseOptions,
) -> Result<BTreeMap<PeType, PpaModel>, QappaError> {
    let mut models = BTreeMap::new();
    for ty in ALL_PE_TYPES {
        models.insert(ty, train_one_model(backend, opts, ty)?);
    }
    Ok(models)
}

// ---------------------------------------------------------------------------
// ratio assembly (shared by the eager-compatible and streaming paths)
// ---------------------------------------------------------------------------

/// The paper's anchor-normalized ratios for one workload, from each type's
/// best points: predicted, and validated by re-synthesizing the winners.
/// (Shared with the precision-grid pipeline in `coordinator::precision`.)
pub(crate) fn assemble_ratios(
    layers: &[Layer],
    sigma: f64,
    anchor: &DsePoint,
    best: &BTreeMap<PeType, (DsePoint, DsePoint)>, // (best perf/area, best energy)
) -> (BTreeMap<PeType, (f64, f64)>, BTreeMap<PeType, (f64, f64)>) {
    // Ground-truth re-evaluation of the anchor for validated ratios.
    let anchor_true = eval_point(
        &anchor.cfg,
        synthesize_with_sigma(&anchor.cfg, sigma),
        layers,
    );
    let mut ratios = BTreeMap::new();
    let mut ratios_validated = BTreeMap::new();
    for (&ty, (best_pa_pt, best_e_pt)) in best {
        ratios.insert(
            ty,
            (
                best_pa_pt.perf_per_area / anchor.perf_per_area,
                anchor.energy_mj / best_e_pt.energy_mj,
            ),
        );
        // Winner validation: synthesize the chosen configs for real.
        let pa_true = eval_point(
            &best_pa_pt.cfg,
            synthesize_with_sigma(&best_pa_pt.cfg, sigma),
            layers,
        );
        let e_true = eval_point(
            &best_e_pt.cfg,
            synthesize_with_sigma(&best_e_pt.cfg, sigma),
            layers,
        );
        ratios_validated.insert(
            ty,
            (
                pa_true.perf_per_area / anchor_true.perf_per_area,
                anchor_true.energy_mj / e_true.energy_mj,
            ),
        );
    }
    (ratios, ratios_validated)
}

/// Pull each type's (best perf/area, best energy) points out of its sweep.
pub(crate) fn best_points(
    sweeps: &BTreeMap<PeType, TypeSweep>,
) -> Result<BTreeMap<PeType, (DsePoint, DsePoint)>, QappaError> {
    let mut best = BTreeMap::new();
    for (&ty, ts) in sweeps {
        let pa = ts
            .best_perf_per_area()
            .ok_or_else(|| QappaError::Config(format!("empty {} space", ty.label())))?;
        let e = ts
            .best_energy()
            .ok_or_else(|| QappaError::Config(format!("empty {} space", ty.label())))?;
        best.insert(ty, (pa.clone(), e.clone()));
    }
    Ok(best)
}

// ---------------------------------------------------------------------------
// DSE entry points
// ---------------------------------------------------------------------------

/// Full pipeline: train models, sweep the space, evaluate the workload,
/// extract frontiers and the paper's ratios.
pub fn run_dse(
    backend: &dyn Backend,
    layers: &[Layer],
    workload: &str,
    opts: &DseOptions,
) -> Result<DseResult, QappaError> {
    let store = ModelStore::new();
    run_dse_with_store(backend, &store, layers, workload, opts)
}

/// Like [`run_dse`], sharing a [`ModelStore`] so repeat runs over the same
/// space/recipe skip retraining.
pub fn run_dse_with_store(
    backend: &dyn Backend,
    store: &ModelStore,
    layers: &[Layer],
    workload: &str,
    opts: &DseOptions,
) -> Result<DseResult, QappaError> {
    let named = [NamedWorkload::new(workload, layers.to_vec())];
    let engine = SweepEngine::new(backend, opts).retain_all(true);

    let mut models = BTreeMap::new();
    let mut sweeps = BTreeMap::new();
    for ty in ALL_PE_TYPES {
        let model = store.get_or_train(backend, opts, ty)?;
        let ts = engine.sweep_type(&model, ty, &named)?.remove(0);
        models.insert(ty, (*model).clone());
        sweeps.insert(ty, ts);
    }

    let best = best_points(&sweeps)?;
    let anchor = best
        .get(&PeType::Int16)
        .ok_or_else(|| QappaError::Config("empty INT16 space".into()))?
        .0
        .clone();
    let (ratios, ratios_validated) =
        assemble_ratios(layers, opts.sigma, &anchor, &best);

    let mut points = BTreeMap::new();
    let mut frontier = BTreeMap::new();
    let mut stats = BTreeMap::new();
    for (ty, ts) in sweeps {
        frontier.insert(ty, ts.frontier_indices());
        stats.insert(ty, ts.stats);
        points.insert(ty, ts.points.expect("retain_all sweep keeps points"));
    }

    Ok(DseResult {
        workload: workload.to_string(),
        models,
        points,
        frontier,
        anchor,
        ratios,
        ratios_validated,
        stats,
    })
}

/// Evaluate many workloads in one streaming pass over the grid: each shard
/// is predicted once per PE type and folded into every workload's frontier
/// and reservoirs.  Models come from `store`, so with a fresh store exactly
/// one training pass runs per PE type no matter how many workloads.
pub fn run_dse_multi(
    backend: &dyn Backend,
    store: &ModelStore,
    workloads: &[NamedWorkload],
    opts: &DseOptions,
) -> Result<Vec<WorkloadSummary>, QappaError> {
    if workloads.is_empty() {
        return Err(QappaError::Workload("run_dse_multi: no workloads given".into()));
    }
    let engine = SweepEngine::new(backend, opts);

    // per_wl[w][ty] = TypeSweep
    let mut per_wl: Vec<BTreeMap<PeType, TypeSweep>> =
        workloads.iter().map(|_| BTreeMap::new()).collect();
    for ty in ALL_PE_TYPES {
        let model = store.get_or_train(backend, opts, ty)?;
        for (w, ts) in engine.sweep_type(&model, ty, workloads)?.into_iter().enumerate() {
            per_wl[w].insert(ty, ts);
        }
    }

    let mut out = Vec::with_capacity(workloads.len());
    for (wl, sweeps) in workloads.iter().zip(per_wl) {
        let best = best_points(&sweeps)?;
        let anchor = best
            .get(&PeType::Int16)
            .ok_or_else(|| QappaError::Config("empty INT16 space".into()))?
            .0
            .clone();
        let (ratios, ratios_validated) =
            assemble_ratios(&wl.layers, opts.sigma, &anchor, &best);
        let mut frontier = BTreeMap::new();
        let mut top_pa = BTreeMap::new();
        let mut top_e = BTreeMap::new();
        let mut stats = BTreeMap::new();
        for (ty, ts) in sweeps {
            frontier.insert(ty, ts.frontier_points());
            stats.insert(ty, ts.stats);
            top_pa.insert(ty, ts.top_perf_per_area);
            top_e.insert(ty, ts.top_energy);
        }
        out.push(WorkloadSummary {
            workload: wl.name.clone(),
            frontier,
            top_perf_per_area: top_pa,
            top_energy: top_e,
            anchor,
            ratios,
            ratios_validated,
            stats,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::NativeBackend;
    use crate::workloads;

    fn tiny_opts() -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk: 1024,
            topk: 8,
        }
    }

    fn small_net() -> Vec<Layer> {
        vec![
            Layer::conv("c1", 3, 16, 32, 32, 3, 1, 1),
            Layer::conv("c2", 16, 32, 16, 16, 3, 1, 1),
            Layer::fc("fc", 512, 10),
        ]
    }

    #[test]
    fn dse_pipeline_runs_native() {
        let backend = NativeBackend::new(7);
        let res = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        for ty in ALL_PE_TYPES {
            let pts = &res.points[&ty];
            assert_eq!(pts.len(), tiny_opts().space.len());
            for p in pts {
                assert!(p.perf_per_area > 0.0, "{ty:?}");
                assert!(p.energy_mj > 0.0);
                assert!(p.ppa.area_mm2 > 0.0);
            }
            assert!(!res.frontier[&ty].is_empty());
            assert_eq!(res.stats[&ty].evaluated, tiny_opts().space.len());
        }
        // anchor is an INT16 point with the max perf/area
        let int16 = &res.points[&PeType::Int16];
        let max_pa = int16.iter().map(|p| p.perf_per_area).fold(f64::MIN, f64::max);
        assert!((res.anchor.perf_per_area - max_pa).abs() < 1e-12);
        // INT16's own ratio anchor-relative perf/area is 1.0
        assert!((res.ratios[&PeType::Int16].0 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lightpe_dominates_int16_in_tiny_dse() {
        let backend = NativeBackend::new(7);
        let res = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        let (pa1, e1) = res.ratios[&PeType::LightPe1];
        assert!(pa1 > 1.2, "LightPE-1 perf/area ratio {pa1}");
        assert!(e1 > 1.2, "LightPE-1 energy ratio {e1}");
        let (paf, ef) = res.ratios[&PeType::Fp32];
        assert!(paf < 1.0, "FP32 perf/area ratio {paf}");
        assert!(ef < 1.0, "FP32 energy ratio {ef}");
    }

    #[test]
    fn models_predict_training_oracle_well() {
        let backend = NativeBackend::new(7);
        let opts = tiny_opts();
        let models = train_models(&backend, &opts).unwrap();
        // holdout check on fresh samples
        for ty in ALL_PE_TYPES {
            let cfgs = opts.space.sample(ty, 64, 999);
            let mut feats = Vec::new();
            for c in &cfgs {
                feats.extend_from_slice(&c.features());
            }
            let preds = crate::model::predict_ppa(&backend, &models[&ty], &feats).unwrap();
            let mut rel_err = 0.0;
            for (c, pred) in cfgs.iter().zip(&preds) {
                let truth = synthesize_with_sigma(c, opts.sigma).as_array();
                for k in 0..3 {
                    rel_err += ((pred[k] - truth[k]) / truth[k]).abs();
                }
            }
            rel_err /= (cfgs.len() * 3) as f64;
            assert!(rel_err < 0.12, "{ty:?} holdout rel err {rel_err}");
        }
    }

    #[test]
    fn dse_deterministic_under_seed() {
        let backend = NativeBackend::new(7);
        let a = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        let b = run_dse(&backend, &small_net(), "tiny", &tiny_opts()).unwrap();
        assert_eq!(a.anchor.cfg, b.anchor.cfg);
        for ty in ALL_PE_TYPES {
            assert_eq!(a.frontier[&ty], b.frontier[&ty]);
        }
    }

    #[test]
    fn works_on_real_workloads() {
        let backend = NativeBackend::new(7);
        let mut opts = tiny_opts();
        opts.train_per_type = 48;
        let layers = workloads::vgg16();
        let res = run_dse(&backend, &layers[..4], "vgg16-head", &opts).unwrap();
        assert!(res.ratios[&PeType::LightPe1].0 > 1.0);
    }

    #[test]
    fn works_on_depthwise_workloads() {
        // MobileNetV2 head (stem + first two inverted-residual blocks):
        // the DSE pipeline must evaluate depthwise layers end-to-end and
        // still produce positive, frontier-bearing points for every type.
        let backend = NativeBackend::new(7);
        let mut opts = tiny_opts();
        opts.train_per_type = 48;
        let layers = workloads::mobilenetv2();
        assert!(layers[..6].iter().any(|l| l.is_depthwise()));
        let res = run_dse(&backend, &layers[..6], "mobilenetv2-head", &opts).unwrap();
        for ty in ALL_PE_TYPES {
            for p in &res.points[&ty] {
                assert!(p.throughput > 0.0 && p.energy_mj > 0.0, "{ty:?}");
            }
            assert!(!res.frontier[&ty].is_empty());
        }
    }

    #[test]
    fn eager_and_streaming_chunks_are_bit_identical() {
        // Acceptance: anchor config, frontier membership and ratios must be
        // bit-identical between the eager shim path (one whole-grid shard)
        // and fine-grained streaming shards.
        let backend = NativeBackend::new(7);
        let mut eager = tiny_opts();
        eager.chunk = 0;
        let mut streaming = tiny_opts();
        streaming.chunk = 7;
        let a = run_dse(&backend, &small_net(), "tiny", &eager).unwrap();
        let b = run_dse(&backend, &small_net(), "tiny", &streaming).unwrap();
        assert_eq!(a.anchor.cfg, b.anchor.cfg);
        assert_eq!(a.anchor.perf_per_area, b.anchor.perf_per_area);
        for ty in ALL_PE_TYPES {
            assert_eq!(a.frontier[&ty], b.frontier[&ty], "{ty:?} frontier");
            assert_eq!(a.ratios[&ty], b.ratios[&ty], "{ty:?} ratios");
            assert_eq!(
                a.ratios_validated[&ty], b.ratios_validated[&ty],
                "{ty:?} validated ratios"
            );
            let pa_a: Vec<f64> = a.points[&ty].iter().map(|p| p.perf_per_area).collect();
            let pa_b: Vec<f64> = b.points[&ty].iter().map(|p| p.perf_per_area).collect();
            assert_eq!(pa_a, pa_b, "{ty:?} points");
        }
    }

    #[test]
    fn model_store_trains_once_per_recipe() {
        let backend = NativeBackend::new(7);
        let opts = tiny_opts();
        let store = ModelStore::new();
        let layers = small_net();
        run_dse_with_store(&backend, &store, &layers, "a", &opts).unwrap();
        assert_eq!(store.misses(), 4, "one training pass per PE type");
        assert_eq!(store.hits(), 0);
        // second run over the same recipe: all hits, identical result
        let r2 = run_dse_with_store(&backend, &store, &layers, "b", &opts).unwrap();
        assert_eq!(store.misses(), 4);
        assert_eq!(store.hits(), 4);
        assert_eq!(store.len(), 4);
        // a different recipe (seed) retrains
        let mut opts2 = opts.clone();
        opts2.seed ^= 1;
        run_dse_with_store(&backend, &store, &layers, "c", &opts2).unwrap();
        assert_eq!(store.misses(), 8);
        assert_eq!(r2.workload, "b");
    }

    #[test]
    fn multi_workload_run_shares_one_training_pass() {
        let backend = NativeBackend::new(7);
        let mut opts = tiny_opts();
        opts.chunk = 16;
        let store = ModelStore::new();
        let named = vec![
            NamedWorkload::new("a", small_net()),
            NamedWorkload::new("b", vec![Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)]),
            NamedWorkload::new("c", workloads::mobilenetv2()[..4].to_vec()),
        ];
        let summaries = run_dse_multi(&backend, &store, &named, &opts).unwrap();
        assert_eq!(store.misses(), 4, "each PE-type model trained exactly once");
        assert_eq!(store.hits(), 0);
        assert_eq!(summaries.len(), 3);
        for s in &summaries {
            assert!((s.ratios[&PeType::Int16].0 - 1.0).abs() < 1e-9);
            for ty in ALL_PE_TYPES {
                assert!(!s.frontier[&ty].is_empty());
                assert_eq!(s.stats[&ty].evaluated, opts.space.len());
                assert!(!s.top_perf_per_area[&ty].is_empty());
                assert!(!s.top_energy[&ty].is_empty());
            }
        }
    }

    #[test]
    fn multi_matches_single_workload_results() {
        // The streaming multi-workload path must agree with the retained
        // single-workload path on anchor and ratios.
        let backend = NativeBackend::new(7);
        let mut opts = tiny_opts();
        opts.chunk = 16;
        let layers = small_net();
        let single = run_dse(&backend, &layers, "t", &opts).unwrap();
        let store = ModelStore::new();
        let named = vec![NamedWorkload::new("t", layers)];
        let multi = run_dse_multi(&backend, &store, &named, &opts)
            .unwrap()
            .remove(0);
        assert_eq!(single.anchor.cfg, multi.anchor.cfg);
        for ty in ALL_PE_TYPES {
            assert_eq!(single.ratios[&ty], multi.ratios[&ty]);
            assert_eq!(single.ratios_validated[&ty], multi.ratios_validated[&ty]);
            assert_eq!(
                single.frontier[&ty].len(),
                multi.frontier[&ty].len(),
                "{ty:?} frontier size"
            );
        }
    }
}
