//! Streaming sweep engine: the bounded-memory hot path of the DSE.
//!
//! The eager pipeline materialized the full per-type grid and every
//! evaluated `DsePoint` (O(grid x workloads) resident).  [`SweepEngine`]
//! instead pipelines fixed-size config shards from the lazy space cursor
//! ([`DesignSpace::chunks`]) through predict -> dataflow-eval, folding each
//! shard into an incremental Pareto frontier and two top-k reservoirs
//! (best perf/area, best energy) per workload — a run retains
//! O(frontier + k) points, so paper-scale-and-beyond spaces fit in laptop
//! memory.  Several workloads share one pass over the grid (and one
//! prediction per shard); [`SweepStats`] counts evaluated points and the
//! peak resident set so the bound is checkable, and an optional per-shard
//! progress hook (plus `QAPPA_TRACE=1` phase timing) exposes the pipeline.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, NUM_FEATURES, PeType, QUANT_NUM_FEATURES};
use crate::coordinator::explorer::{DseOptions, DsePoint};
use crate::coordinator::pareto::{FrontierEntry, IncrementalFrontier};
use crate::dataflow::{
    evaluate_network, evaluate_network_prepared, EvalContext, Layer, MemoStats,
    PreparedWorkload,
};
use crate::model::{predict_ppa, Backend, PpaModel};
use crate::obs;
use crate::obs::trace::phase_with;
use crate::synth::oracle::{energy_params, EnergyParams, Ppa};
use crate::util::pool::{parallel_map, workers_for};

/// `QAPPA_LEGACY_EVAL=1` forces the pre-SoA per-point evaluation path —
/// the test oracle the equivalence suite (and a cautious user) compares
/// the hot path against.  Results are bit-identical either way; only
/// speed differs.
pub(crate) fn legacy_eval_env() -> bool {
    std::env::var_os("QAPPA_LEGACY_EVAL").is_some()
}

/// A workload with its display name, as swept by the engine.
///
/// Layers are taken as-is: phase shaping for transformer workloads
/// (prefill vs decode, see [`crate::workloads::shape_for_phase`]) happens
/// upstream in the session layer, so the engine always sweeps a concrete
/// already-shaped layer list.
#[derive(Debug, Clone)]
pub struct NamedWorkload {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl NamedWorkload {
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> NamedWorkload {
        NamedWorkload { name: name.into(), layers }
    }
}

/// Reservoir objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Maximize,
    Minimize,
}

/// Bounded best-k reservoir, kept best-first.
///
/// Tie semantics mirror the eager pipeline's selection exactly (pinned by
/// the eager/streaming identity test): `Maximize` prefers the *latest*
/// point among equal keys (`Iterator::max_by`), `Minimize` the *earliest*
/// (`Iterator::min_by`).  Non-finite keys are rejected — a degenerate
/// prediction cannot claim a slot.
#[derive(Debug, Clone)]
pub struct TopK<T> {
    k: usize,
    objective: Objective,
    entries: Vec<(f64, T)>,
}

impl<T> TopK<T> {
    pub fn new(k: usize, objective: Objective) -> TopK<T> {
        TopK { k, objective, entries: Vec::new() }
    }

    /// Offer one keyed value; returns true iff it took a slot.
    pub fn push(&mut self, key: f64, value: T) -> bool {
        if self.k == 0 || !key.is_finite() {
            return false;
        }
        let pos = match self.objective {
            Objective::Maximize => self.entries.iter().position(|(e, _)| key >= *e),
            Objective::Minimize => self.entries.iter().position(|(e, _)| key < *e),
        }
        .unwrap_or(self.entries.len());
        if pos >= self.k {
            return false;
        }
        self.entries.insert(pos, (key, value));
        self.entries.truncate(self.k);
        true
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn best(&self) -> Option<&T> {
        self.entries.first().map(|(_, v)| v)
    }

    /// Values best-first.
    pub fn into_values(self) -> Vec<T> {
        self.entries.into_iter().map(|(_, v)| v).collect()
    }
}

/// Counters for one (PE type, workload) sweep — the engine's memory-bound
/// guarantee is checkable: `peak_resident` is the largest number of
/// `DsePoint`s (shard in flight + frontier + reservoirs + any retained
/// points) alive at once.
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepStats {
    pub evaluated: usize,
    pub shards: usize,
    pub peak_resident: usize,
    /// Final frontier size.
    pub frontier_len: usize,
    /// Largest mid-sweep frontier (the incremental frontier is not
    /// monotonic: later points can evict whole swaths).
    pub peak_frontier: usize,
    /// Final reservoir occupancy (both reservoirs summed, <= 2 x top-k).
    pub reservoir_len: usize,
    /// Layer-cost memo hits — cumulative over the owning engine's
    /// lifetime at snapshot time (the memo is sweep-wide: one engine
    /// reused across precision cells keeps warming it).
    pub cost_hits: u64,
    /// Layer-cost memo misses (cumulative, see `cost_hits`).
    pub cost_misses: u64,
    /// Synthesis memo (`energy_params`) hits (cumulative).
    pub synth_hits: u64,
    /// Synthesis memo misses (cumulative).
    pub synth_misses: u64,
}

impl SweepStats {
    fn record_memo(&mut self, m: MemoStats) {
        self.cost_hits = m.cost_hits;
        self.cost_misses = m.cost_misses;
        self.synth_hits = m.synth_hits;
        self.synth_misses = m.synth_misses;
    }
}

/// Per-shard progress snapshot handed to the [`SweepEngine::on_shard`] hook.
#[derive(Debug, Clone)]
pub struct ShardProgress {
    pub pe_type: PeType,
    pub workload: String,
    pub shard: usize,
    pub shard_len: usize,
    pub evaluated: usize,
    pub total: usize,
    pub resident: usize,
}

/// Result of sweeping one PE type for one workload.
#[derive(Debug, Clone)]
pub struct TypeSweep {
    pub pe_type: PeType,
    pub workload: String,
    /// Pareto frontier in grid order; payload = (grid index, point).
    pub frontier: Vec<FrontierEntry<(usize, DsePoint)>>,
    /// Best-perf/area reservoir, best-first.
    pub top_perf_per_area: Vec<DsePoint>,
    /// Best-energy reservoir, best-first.
    pub top_energy: Vec<DsePoint>,
    /// Every evaluated point, grid order — only with `retain_all` (the
    /// eager-compatible path); `None` in streaming mode.
    pub points: Option<Vec<DsePoint>>,
    pub stats: SweepStats,
}

impl TypeSweep {
    /// Frontier as grid indices, ascending (the eager `DseResult` shape).
    pub fn frontier_indices(&self) -> Vec<usize> {
        self.frontier.iter().map(|e| e.payload.0).collect()
    }

    /// Frontier as points, grid order.
    pub fn frontier_points(&self) -> Vec<DsePoint> {
        self.frontier.iter().map(|e| e.payload.1.clone()).collect()
    }

    pub fn best_perf_per_area(&self) -> Option<&DsePoint> {
        self.top_perf_per_area.first()
    }

    pub fn best_energy(&self) -> Option<&DsePoint> {
        self.top_energy.first()
    }
}

/// Batch-predict the PPA of a set of configs through `model` — the predict
/// stage of the streaming pipeline, shared by grid shards
/// ([`SweepEngine::sweep_type`]) and the guided optimizer's population
/// batches ([`crate::opt`]).  The feature encoding follows the model: the
/// per-type models are fitted on the 7 base axes, the unified
/// cross-precision model on the quant-extended vector.
pub fn predict_configs(
    backend: &dyn Backend,
    model: &PpaModel,
    cfgs: &[AcceleratorConfig],
) -> Result<Vec<Ppa>, QappaError> {
    if legacy_eval_env() {
        predict_configs_legacy(backend, model, cfgs)
    } else {
        predict_configs_soa(backend, model, cfgs)
    }
}

/// The pre-SoA form: one flat feature slab in input order.  Kept as the
/// equivalence-suite oracle (`QAPPA_LEGACY_EVAL=1` routes here).
pub fn predict_configs_legacy(
    backend: &dyn Backend,
    model: &PpaModel,
    cfgs: &[AcceleratorConfig],
) -> Result<Vec<Ppa>, QappaError> {
    let quant_features = model.x_std.d() == QUANT_NUM_FEATURES;
    let d = if quant_features { QUANT_NUM_FEATURES } else { NUM_FEATURES };
    let mut feats = Vec::with_capacity(cfgs.len() * d);
    for c in cfgs {
        if quant_features {
            feats.extend_from_slice(&c.features_quant());
        } else {
            feats.extend_from_slice(&c.features());
        }
    }
    Ok(predict_ppa(backend, model, &feats)?
        .into_iter()
        .map(Ppa::from_array)
        .collect())
}

/// Structure-of-arrays predict: configs are grouped by shared PE recipe
/// (resolved precision spec), each group predicted as one contiguous batch
/// through the backend's column-wise pass, and results scattered back to
/// input order.  Standardization, prediction and de-standardization are
/// all row-independent, so grouping cannot change any output — results
/// are bit-identical to [`predict_configs_legacy`] (pinned by
/// `tests/integration_soa.rs`).  Grid shards are single-recipe already;
/// the grouping pays off on the optimizer's mixed-recipe populations.
pub fn predict_configs_soa(
    backend: &dyn Backend,
    model: &PpaModel,
    cfgs: &[AcceleratorConfig],
) -> Result<Vec<Ppa>, QappaError> {
    let quant_features = model.x_std.d() == QUANT_NUM_FEATURES;
    let d = if quant_features { QUANT_NUM_FEATURES } else { NUM_FEATURES };
    // Group config indices by PE recipe, first-seen order.
    let mut groups: Vec<(PeType, Vec<usize>)> = Vec::new();
    for (i, c) in cfgs.iter().enumerate() {
        match groups.iter_mut().find(|(t, _)| *t == c.pe_type) {
            Some((_, ix)) => ix.push(i),
            None => groups.push((c.pe_type, vec![i])),
        }
    }
    let mut out = vec![Ppa { power_mw: 0.0, fmax_mhz: 0.0, area_mm2: 0.0 }; cfgs.len()];
    let mut feats = Vec::new();
    for (_, ix) in &groups {
        feats.clear();
        feats.reserve(ix.len() * d);
        for &i in ix {
            if quant_features {
                feats.extend_from_slice(&cfgs[i].features_quant());
            } else {
                feats.extend_from_slice(&cfgs[i].features());
            }
        }
        let preds = predict_ppa(backend, model, &feats)?;
        for (&i, row) in ix.iter().zip(preds) {
            out[i] = Ppa::from_array(row);
        }
    }
    Ok(out)
}

/// Evaluate one predicted config on a workload.
pub fn eval_point(cfg: &AcceleratorConfig, ppa: Ppa, layers: &[Layer]) -> DsePoint {
    // Energy coefficients are structural (jitter-free); the clock the
    // dataflow runs at is the *predicted* fmax, and energy uses the
    // *predicted* power — the regression models drive the DSE.
    let mut ep = energy_params(cfg);
    ep.fmax_mhz = ppa.fmax_mhz.max(1.0);
    let cost = evaluate_network(cfg, &ep, layers);
    let throughput = 1.0 / cost.latency_s.max(1e-12);
    let energy_mj = ppa.power_mw * cost.latency_s; // mW x s = mJ
    DsePoint {
        cfg: *cfg,
        ppa,
        throughput,
        perf_per_area: throughput / ppa.area_mm2.max(1e-9),
        energy_mj,
        utilization: cost.avg_utilization,
    }
}

/// [`eval_point`] with the per-point synthesis and workload-dedup work
/// hoisted out: the caller supplies the memoized `EnergyParams` (identical
/// bits to `energy_params(cfg)`, see [`crate::synth::cache::SynthMemo`])
/// and the pre-deduplicated workload, and the per-layer mapping runs
/// through the sweep-wide layer-cost memo.  Bit-identical to
/// [`eval_point`]; pinned by `tests/integration_soa.rs`.
pub fn eval_point_prepared(
    cfg: &AcceleratorConfig,
    ppa: Ppa,
    mut ep: EnergyParams,
    prep: &PreparedWorkload,
    ctx: &EvalContext,
) -> DsePoint {
    ep.fmax_mhz = ppa.fmax_mhz.max(1.0);
    let cost = evaluate_network_prepared(cfg, &ep, prep, ctx);
    let throughput = 1.0 / cost.latency_s.max(1e-12);
    let energy_mj = ppa.power_mw * cost.latency_s; // mW x s = mJ
    DsePoint {
        cfg: *cfg,
        ppa,
        throughput,
        perf_per_area: throughput / ppa.area_mm2.max(1e-9),
        energy_mj,
        utilization: cost.avg_utilization,
    }
}

/// One sweep accumulator per workload.
struct Acc {
    frontier: IncrementalFrontier<(usize, DsePoint)>,
    top_pa: TopK<DsePoint>,
    top_e: TopK<DsePoint>,
    points: Option<Vec<DsePoint>>,
    stats: SweepStats,
}

/// The streaming sweep engine.  Borrowing the backend and options, it
/// sweeps one PE type at a time; each call pipelines every shard through
/// predict -> dataflow-eval for *all* given workloads, so the per-shard
/// prediction is paid once per type regardless of workload count.
pub struct SweepEngine<'a> {
    backend: &'a dyn Backend,
    opts: &'a DseOptions,
    retain_all: bool,
    /// Per-point legacy evaluation (the pre-SoA oracle).  Defaults to the
    /// `QAPPA_LEGACY_EVAL` env; the builder overrides it for in-process
    /// equivalence tests where env mutation would race.
    legacy: bool,
    /// Sweep-wide memo state: synthesis derivations and layer costs are
    /// cached across shards, workloads and (when one engine is reused,
    /// as the precision DSE does) precision cells.
    ctx: EvalContext,
    progress: Option<Box<dyn Fn(&ShardProgress) + 'a>>,
}

impl<'a> SweepEngine<'a> {
    pub fn new(backend: &'a dyn Backend, opts: &'a DseOptions) -> SweepEngine<'a> {
        SweepEngine {
            backend,
            opts,
            retain_all: false,
            legacy: legacy_eval_env(),
            ctx: EvalContext::new(),
            progress: None,
        }
    }

    /// Keep every evaluated point (the eager-compatible path; memory goes
    /// back to O(grid)).  Off by default.
    pub fn retain_all(mut self, yes: bool) -> SweepEngine<'a> {
        self.retain_all = yes;
        self
    }

    /// Force the legacy per-point evaluation path (the test oracle),
    /// independent of `QAPPA_LEGACY_EVAL`.
    pub fn legacy(mut self, yes: bool) -> SweepEngine<'a> {
        self.legacy = yes;
        self
    }

    /// Snapshot the engine's cumulative memo counters.
    pub fn memo_stats(&self) -> MemoStats {
        self.ctx.stats()
    }

    /// Install a per-shard progress hook.
    pub fn on_shard(mut self, f: impl Fn(&ShardProgress) + 'a) -> SweepEngine<'a> {
        self.progress = Some(Box::new(f));
        self
    }

    /// Sweep the whole grid of one PE type for every workload in one pass.
    /// Returns one [`TypeSweep`] per workload, in input order.
    pub fn sweep_type(
        &self,
        model: &PpaModel,
        ty: PeType,
        workloads: &[NamedWorkload],
    ) -> Result<Vec<TypeSweep>, QappaError> {
        if workloads.is_empty() {
            return Err(QappaError::Workload("sweep_type: no workloads given".into()));
        }
        let opts = self.opts;
        let total = opts.space.len();
        // Anchor/best-point selection reads the reservoir heads, so depth 0
        // would break every run; clamp to 1.
        let topk = opts.topk.max(1);
        let mut accs: Vec<Acc> = workloads
            .iter()
            .map(|_| Acc {
                frontier: IncrementalFrontier::new(),
                top_pa: TopK::new(topk, Objective::Maximize),
                top_e: TopK::new(topk, Objective::Minimize),
                points: if self.retain_all { Some(Vec::with_capacity(total)) } else { None },
                stats: SweepStats::default(),
            })
            .collect();

        // Dedup each workload's repeated layer shapes once per sweep, not
        // once per (config, workload) evaluation — the O(L²) first-seen
        // scan leaves the hot loop.
        let preps: Vec<PreparedWorkload> =
            workloads.iter().map(|wl| PreparedWorkload::new(&wl.layers)).collect();

        // Registry feeds: shard/point counters, per-shard wall time, and
        // (after the pass) the memo-counter deltas this sweep contributed.
        let reg = obs::registry();
        let m_shards = reg.counter("sweep.shards");
        let m_points = reg.counter("sweep.points_evaluated");
        let m_shard_ms = reg.histogram("sweep.shard_ms");
        let memo_before = self.ctx.stats();
        let mut sweep_span = obs::span("sweep.type");
        sweep_span.attr("ty", ty.label()).attr("workloads", workloads.len());

        for (shard_no, (start, shard)) in opts.space.chunks(ty, opts.chunk).enumerate() {
            let shard_t0 = std::time::Instant::now();
            let t0 = std::time::Instant::now();
            let preds = predict_configs(self.backend, model, &shard)?;
            phase_with(
                || format!("sweep/{}/shard{shard_no}/predict({})", ty.label(), shard.len()),
                t0,
            );
            // Fast path: derive the shard's energy coefficients up front
            // through the synthesis memo (one derivation per distinct
            // PE recipe / GLB size, not per config); legacy path derives
            // them per point inside `eval_point`.
            let t0 = std::time::Instant::now();
            let eps: Vec<Option<EnergyParams>> = if self.legacy {
                vec![None; shard.len()]
            } else {
                shard.iter().map(|c| Some(self.ctx.synth.energy_params_with(c))).collect()
            };
            phase_with(
                || format!("sweep/{}/shard{shard_no}/synth({})", ty.label(), shard.len()),
                t0,
            );
            let items: Vec<(AcceleratorConfig, Ppa, Option<EnergyParams>)> = shard
                .into_iter()
                .zip(preds)
                .zip(eps)
                .map(|((cfg, ppa), ep)| (cfg, ppa, ep))
                .collect();
            let workers = workers_for(items.len(), opts.workers, 32);
            for (w, wl) in workloads.iter().enumerate() {
                let t1 = std::time::Instant::now();
                let pts: Vec<DsePoint> = parallel_map(&items, workers, |(cfg, ppa, ep)| {
                    match ep {
                        Some(ep) => eval_point_prepared(cfg, *ppa, *ep, &preps[w], &self.ctx),
                        None => eval_point(cfg, *ppa, &wl.layers),
                    }
                });
                phase_with(
                    || {
                        format!(
                            "sweep/{}/shard{shard_no}/dataflow({}, {})",
                            ty.label(),
                            pts.len(),
                            wl.name
                        )
                    },
                    t1,
                );
                let acc = &mut accs[w];
                for (off, p) in pts.into_iter().enumerate() {
                    let idx = start + off;
                    acc.frontier.push(p.perf_per_area, p.energy_mj, (idx, p.clone()));
                    acc.top_pa.push(p.perf_per_area, p.clone());
                    acc.top_e.push(p.energy_mj, p.clone());
                    if let Some(all) = &mut acc.points {
                        all.push(p);
                    }
                    acc.stats.evaluated += 1;
                }
                acc.stats.shards += 1;
                acc.stats.peak_frontier =
                    acc.stats.peak_frontier.max(acc.frontier.len());
                let resident = items.len()
                    + acc.frontier.len()
                    + acc.top_pa.len()
                    + acc.top_e.len()
                    + acc.points.as_ref().map_or(0, Vec::len);
                acc.stats.peak_resident = acc.stats.peak_resident.max(resident);
                if let Some(hook) = &self.progress {
                    hook(&ShardProgress {
                        pe_type: ty,
                        workload: wl.name.clone(),
                        shard: shard_no,
                        shard_len: items.len(),
                        evaluated: acc.stats.evaluated,
                        total,
                        resident,
                    });
                }
            }
            m_shards.inc();
            m_points.add((items.len() * workloads.len()) as u64);
            m_shard_ms.record_ms(shard_t0.elapsed().as_secs_f64() * 1e3);
        }

        // Memo counters are cumulative per engine; feed only this pass's
        // contribution so registry totals stay additive across sweeps.
        let memo_after = self.ctx.stats();
        reg.counter("sweep.memo.cost_hits")
            .add(memo_after.cost_hits.saturating_sub(memo_before.cost_hits));
        reg.counter("sweep.memo.cost_misses")
            .add(memo_after.cost_misses.saturating_sub(memo_before.cost_misses));
        reg.counter("sweep.memo.synth_hits")
            .add(memo_after.synth_hits.saturating_sub(memo_before.synth_hits));
        reg.counter("sweep.memo.synth_misses")
            .add(memo_after.synth_misses.saturating_sub(memo_before.synth_misses));
        drop(sweep_span);

        Ok(workloads
            .iter()
            .zip(accs)
            .map(|(wl, mut acc)| {
                acc.stats.frontier_len = acc.frontier.len();
                acc.stats.reservoir_len = acc.top_pa.len() + acc.top_e.len();
                acc.stats.record_memo(self.ctx.stats());
                TypeSweep {
                    pe_type: ty,
                    workload: wl.name.clone(),
                    frontier: acc.frontier.into_entries(),
                    top_perf_per_area: acc.top_pa.into_values(),
                    top_energy: acc.top_e.into_values(),
                    points: acc.points,
                    stats: acc.stats,
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PE_TYPES;
    use crate::coordinator::pareto::pareto_frontier;
    use crate::coordinator::space::DesignSpace;
    use crate::coordinator::explorer::{train_models, train_one_model};
    use crate::model::native::NativeBackend;
    use crate::model::CvConfig;

    fn opts_with(chunk: usize, topk: usize) -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk,
            topk,
        }
    }

    fn net() -> Vec<Layer> {
        vec![Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)]
    }

    #[test]
    fn topk_reservoir_orders_and_bounds() {
        let mut t = TopK::new(3, Objective::Maximize);
        for (i, k) in [1.0, 5.0, 3.0, 5.0, 2.0, 9.0].iter().enumerate() {
            t.push(*k, i);
        }
        // best-first; latest among the tied 5.0s (index 3) ranks first
        assert_eq!(t.len(), 3);
        assert_eq!(t.best(), Some(&5));
        assert_eq!(t.clone().into_values(), vec![5, 3, 1]);

        let mut m = TopK::new(2, Objective::Minimize);
        for (i, k) in [4.0, 2.0, 2.0, 7.0].iter().enumerate() {
            m.push(*k, i);
        }
        // earliest among the tied 2.0s (index 1) ranks first
        assert_eq!(m.into_values(), vec![1, 2]);

        let mut z = TopK::new(0, Objective::Maximize);
        assert!(!z.push(1.0, 0));
        let mut nan = TopK::new(2, Objective::Maximize);
        assert!(!nan.push(f64::NAN, 0));
        assert!(nan.is_empty());
    }

    #[test]
    fn topk_tie_rules_match_iterator_selection() {
        // The reservoir's best must be exactly what max_by/min_by picked in
        // the eager pipeline, including tie direction.
        let keys = [3.0, 7.0, 7.0, 1.0, 7.0, 1.0];
        let mut pa = TopK::new(4, Objective::Maximize);
        let mut e = TopK::new(4, Objective::Minimize);
        for (i, &k) in keys.iter().enumerate() {
            pa.push(k, i);
            e.push(k, i);
        }
        let max_by = keys
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        let min_by = keys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(pa.best(), Some(&max_by)); // last 7.0 (index 4)
        assert_eq!(e.best(), Some(&min_by)); // first 1.0 (index 3)
    }

    #[test]
    fn streaming_matches_eager_shim_per_type() {
        let backend = NativeBackend::new(7);
        let eager = opts_with(0, 8); // chunk=0: whole grid in one shard
        let streaming = opts_with(7, 8); // ragged shards
        let models = train_models(&backend, &eager).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        for ty in ALL_PE_TYPES {
            let a = SweepEngine::new(&backend, &eager)
                .retain_all(true)
                .sweep_type(&models[&ty], ty, &wl)
                .unwrap()
                .remove(0);
            let b = SweepEngine::new(&backend, &streaming)
                .retain_all(true)
                .sweep_type(&models[&ty], ty, &wl)
                .unwrap()
                .remove(0);
            // bit-identical points, frontier and reservoirs
            let pa_a: Vec<f64> =
                a.points.as_ref().unwrap().iter().map(|p| p.perf_per_area).collect();
            let pa_b: Vec<f64> =
                b.points.as_ref().unwrap().iter().map(|p| p.perf_per_area).collect();
            assert_eq!(pa_a, pa_b, "{ty:?} point stream diverged");
            assert_eq!(a.frontier_indices(), b.frontier_indices(), "{ty:?}");
            assert_eq!(
                a.best_perf_per_area().unwrap().cfg,
                b.best_perf_per_area().unwrap().cfg
            );
            assert_eq!(a.best_energy().unwrap().cfg, b.best_energy().unwrap().cfg);
            // the incremental frontier equals the batch frontier
            let pairs: Vec<(f64, f64)> = a
                .points
                .as_ref()
                .unwrap()
                .iter()
                .map(|p| (p.perf_per_area, p.energy_mj))
                .collect();
            assert_eq!(a.frontier_indices(), pareto_frontier(&pairs), "{ty:?}");
        }
    }

    #[test]
    fn streaming_bounds_resident_points() {
        // chunk <= 2*topk makes the acceptance bound structural:
        // resident = shard + frontier + reservoirs <= 2*(frontier + topk).
        let backend = NativeBackend::new(7);
        let opts = opts_with(16, 8);
        let models = train_models(&backend, &opts).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let ts = SweepEngine::new(&backend, &opts)
            .sweep_type(&models[&PeType::Int16], PeType::Int16, &wl)
            .unwrap()
            .remove(0);
        assert!(ts.points.is_none());
        assert_eq!(ts.stats.evaluated, opts.space.len());
        assert!(
            ts.stats.peak_resident
                <= 2 * (ts.stats.peak_frontier + ts.stats.reservoir_len),
            "peak {} vs frontier {} + reservoirs {}",
            ts.stats.peak_resident,
            ts.stats.peak_frontier,
            ts.stats.reservoir_len
        );
    }

    #[test]
    fn streaming_sweeps_4x_paper_scale_in_bounded_memory() {
        // 4x the paper-scale grid (76800 configs/type): the streaming
        // engine must complete with peak resident points <= 2 x
        // (frontier + top-k) — the whole point of the refactor.
        let mut space = DesignSpace::default();
        space.rows.extend([32, 40, 48, 64]); // x2
        space.bandwidth_gbps.extend([12.0, 16.0, 24.0]); // x2
        assert_eq!(space.len(), 4 * DesignSpace::default().len());
        let opts = DseOptions {
            space,
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3], seed: 1 },
            seed: 11,
            workers: crate::util::pool::default_workers(),
            sigma: 0.02,
            chunk: 512,
            topk: 256,
        };
        let backend = NativeBackend::new(7);
        let model = train_one_model(&backend, &opts, PeType::Int16).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let ts = SweepEngine::new(&backend, &opts)
            .sweep_type(&model, PeType::Int16, &wl)
            .unwrap()
            .remove(0);
        assert_eq!(ts.stats.evaluated, 76800);
        assert_eq!(ts.stats.shards, 76800usize.div_ceil(512));
        assert!(
            ts.stats.peak_resident
                <= 2 * (ts.stats.peak_frontier + ts.stats.reservoir_len),
            "peak {} vs frontier {} + reservoirs {}",
            ts.stats.peak_resident,
            ts.stats.peak_frontier,
            ts.stats.reservoir_len
        );
        // and the retained set is a sliver of the grid
        assert!(ts.stats.peak_resident * 10 < ts.stats.evaluated);
        assert!(!ts.frontier.is_empty());
    }

    #[test]
    fn fast_path_bit_identical_to_legacy_and_warms_memo() {
        // The SoA/memoized pipeline must be byte-for-byte the old per-point
        // path, including a workload with repeated shapes and a mixed
        // per-layer precision override (the override-hardware branch).
        let backend = NativeBackend::new(7);
        let opts = opts_with(16, 8);
        let models = train_models(&backend, &opts).unwrap();
        let mixed = vec![
            Layer::conv("c0", 8, 16, 16, 16, 3, 1, 1),
            Layer::conv("c1", 8, 16, 16, 16, 3, 1, 1), // repeated shape, dedups
            Layer::dw("dw", 16, 16, 3, 1, 1)
                .with_precision(crate::config::QuantSpec::int(4, 8)),
        ];
        // The second workload shares the conv shape — every config's memo
        // entry from workload 0 is hit again under workload 1.
        let wl = vec![
            NamedWorkload::new("mix", mixed),
            NamedWorkload::new("shared", net()),
        ];
        for ty in ALL_PE_TYPES {
            let fast_engine = SweepEngine::new(&backend, &opts).retain_all(true);
            let fast = fast_engine.sweep_type(&models[&ty], ty, &wl).unwrap();
            let memo = fast_engine.memo_stats();
            let slow = SweepEngine::new(&backend, &opts)
                .retain_all(true)
                .legacy(true)
                .sweep_type(&models[&ty], ty, &wl)
                .unwrap();
            for (f, s) in fast.iter().zip(&slow) {
                let a = f.points.as_ref().unwrap();
                let b = s.points.as_ref().unwrap();
                assert_eq!(a.len(), b.len(), "{ty:?}/{}", f.workload);
                for (p, q) in a.iter().zip(b) {
                    assert_eq!(p.cfg, q.cfg, "{ty:?}");
                    assert_eq!(p.throughput.to_bits(), q.throughput.to_bits(), "{ty:?}");
                    assert_eq!(
                        p.perf_per_area.to_bits(),
                        q.perf_per_area.to_bits(),
                        "{ty:?}"
                    );
                    assert_eq!(p.energy_mj.to_bits(), q.energy_mj.to_bits(), "{ty:?}");
                    assert_eq!(p.utilization.to_bits(), q.utilization.to_bits(), "{ty:?}");
                }
                assert_eq!(f.frontier_indices(), s.frontier_indices(), "{ty:?}");
                // Legacy path records no memo traffic.
                assert_eq!(s.stats.cost_hits + s.stats.cost_misses, 0, "{ty:?}");
            }
            // Memo actually engaged: shared shapes and recipes must hit.
            assert!(memo.cost_hits > 0, "{ty:?}: no layer-cost hits");
            assert!(memo.synth_hits > 0, "{ty:?}: no synth hits");
            assert_eq!(fast[0].stats.cost_hits, memo.cost_hits);
            assert_eq!(fast[0].stats.synth_misses, memo.synth_misses);
        }
    }

    #[test]
    fn shard_hook_sees_every_shard() {
        let backend = NativeBackend::new(7);
        let opts = opts_with(16, 4);
        let models = train_models(&backend, &opts).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let seen = std::cell::RefCell::new(Vec::new());
        let engine = SweepEngine::new(&backend, &opts)
            .on_shard(|p| seen.borrow_mut().push((p.shard, p.evaluated)));
        let ts = engine
            .sweep_type(&models[&PeType::Fp32], PeType::Fp32, &wl)
            .unwrap()
            .remove(0);
        drop(engine); // release the hook's borrow of `seen`
        let seen = seen.into_inner();
        assert_eq!(seen.len(), opts.space.len().div_ceil(16));
        assert_eq!(seen.last().unwrap().1, opts.space.len());
        assert_eq!(ts.stats.shards, seen.len());
    }
}
