//! Streaming sweep engine: the bounded-memory hot path of the DSE.
//!
//! The eager pipeline materialized the full per-type grid and every
//! evaluated `DsePoint` (O(grid x workloads) resident).  [`SweepEngine`]
//! instead pipelines fixed-size config shards from the lazy space cursor
//! ([`DesignSpace::chunks`]) through predict -> dataflow-eval, folding each
//! shard into an incremental Pareto frontier and two top-k reservoirs
//! (best perf/area, best energy) per workload — a run retains
//! O(frontier + k) points, so paper-scale-and-beyond spaces fit in laptop
//! memory.  Several workloads share one pass over the grid (and one
//! prediction per shard); [`SweepStats`] counts evaluated points and the
//! peak resident set so the bound is checkable, and an optional per-shard
//! progress hook (plus `QAPPA_TRACE=1` phase timing) exposes the pipeline.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, NUM_FEATURES, PeType, QUANT_NUM_FEATURES};
use crate::coordinator::explorer::{DseOptions, DsePoint};
use crate::coordinator::pareto::{FrontierEntry, IncrementalFrontier};
use crate::dataflow::{evaluate_network, Layer};
use crate::model::{predict_ppa, Backend, PpaModel};
use crate::synth::oracle::{energy_params, Ppa};
use crate::util::pool::{parallel_map, workers_for};

/// Phase-timing hook: set `QAPPA_TRACE=1` to print per-phase wall times.
pub(crate) fn trace(phase: &str, t0: std::time::Instant) {
    if std::env::var_os("QAPPA_TRACE").is_some() {
        eprintln!("[trace] {phase}: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }
}

/// A workload with its display name, as swept by the engine.
#[derive(Debug, Clone)]
pub struct NamedWorkload {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl NamedWorkload {
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> NamedWorkload {
        NamedWorkload { name: name.into(), layers }
    }
}

/// Reservoir objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Maximize,
    Minimize,
}

/// Bounded best-k reservoir, kept best-first.
///
/// Tie semantics mirror the eager pipeline's selection exactly (pinned by
/// the eager/streaming identity test): `Maximize` prefers the *latest*
/// point among equal keys (`Iterator::max_by`), `Minimize` the *earliest*
/// (`Iterator::min_by`).  Non-finite keys are rejected — a degenerate
/// prediction cannot claim a slot.
#[derive(Debug, Clone)]
pub struct TopK<T> {
    k: usize,
    objective: Objective,
    entries: Vec<(f64, T)>,
}

impl<T> TopK<T> {
    pub fn new(k: usize, objective: Objective) -> TopK<T> {
        TopK { k, objective, entries: Vec::new() }
    }

    /// Offer one keyed value; returns true iff it took a slot.
    pub fn push(&mut self, key: f64, value: T) -> bool {
        if self.k == 0 || !key.is_finite() {
            return false;
        }
        let pos = match self.objective {
            Objective::Maximize => self.entries.iter().position(|(e, _)| key >= *e),
            Objective::Minimize => self.entries.iter().position(|(e, _)| key < *e),
        }
        .unwrap_or(self.entries.len());
        if pos >= self.k {
            return false;
        }
        self.entries.insert(pos, (key, value));
        self.entries.truncate(self.k);
        true
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn best(&self) -> Option<&T> {
        self.entries.first().map(|(_, v)| v)
    }

    /// Values best-first.
    pub fn into_values(self) -> Vec<T> {
        self.entries.into_iter().map(|(_, v)| v).collect()
    }
}

/// Counters for one (PE type, workload) sweep — the engine's memory-bound
/// guarantee is checkable: `peak_resident` is the largest number of
/// `DsePoint`s (shard in flight + frontier + reservoirs + any retained
/// points) alive at once.
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepStats {
    pub evaluated: usize,
    pub shards: usize,
    pub peak_resident: usize,
    /// Final frontier size.
    pub frontier_len: usize,
    /// Largest mid-sweep frontier (the incremental frontier is not
    /// monotonic: later points can evict whole swaths).
    pub peak_frontier: usize,
    /// Final reservoir occupancy (both reservoirs summed, <= 2 x top-k).
    pub reservoir_len: usize,
}

/// Per-shard progress snapshot handed to the [`SweepEngine::on_shard`] hook.
#[derive(Debug, Clone)]
pub struct ShardProgress {
    pub pe_type: PeType,
    pub workload: String,
    pub shard: usize,
    pub shard_len: usize,
    pub evaluated: usize,
    pub total: usize,
    pub resident: usize,
}

/// Result of sweeping one PE type for one workload.
#[derive(Debug, Clone)]
pub struct TypeSweep {
    pub pe_type: PeType,
    pub workload: String,
    /// Pareto frontier in grid order; payload = (grid index, point).
    pub frontier: Vec<FrontierEntry<(usize, DsePoint)>>,
    /// Best-perf/area reservoir, best-first.
    pub top_perf_per_area: Vec<DsePoint>,
    /// Best-energy reservoir, best-first.
    pub top_energy: Vec<DsePoint>,
    /// Every evaluated point, grid order — only with `retain_all` (the
    /// eager-compatible path); `None` in streaming mode.
    pub points: Option<Vec<DsePoint>>,
    pub stats: SweepStats,
}

impl TypeSweep {
    /// Frontier as grid indices, ascending (the eager `DseResult` shape).
    pub fn frontier_indices(&self) -> Vec<usize> {
        self.frontier.iter().map(|e| e.payload.0).collect()
    }

    /// Frontier as points, grid order.
    pub fn frontier_points(&self) -> Vec<DsePoint> {
        self.frontier.iter().map(|e| e.payload.1.clone()).collect()
    }

    pub fn best_perf_per_area(&self) -> Option<&DsePoint> {
        self.top_perf_per_area.first()
    }

    pub fn best_energy(&self) -> Option<&DsePoint> {
        self.top_energy.first()
    }
}

/// Batch-predict the PPA of a set of configs through `model` — the predict
/// stage of the streaming pipeline, shared by grid shards
/// ([`SweepEngine::sweep_type`]) and the guided optimizer's population
/// batches ([`crate::opt`]).  The feature encoding follows the model: the
/// per-type models are fitted on the 7 base axes, the unified
/// cross-precision model on the quant-extended vector.
pub fn predict_configs(
    backend: &dyn Backend,
    model: &PpaModel,
    cfgs: &[AcceleratorConfig],
) -> Result<Vec<Ppa>, QappaError> {
    let quant_features = model.x_std.d() == QUANT_NUM_FEATURES;
    let d = if quant_features { QUANT_NUM_FEATURES } else { NUM_FEATURES };
    let mut feats = Vec::with_capacity(cfgs.len() * d);
    for c in cfgs {
        if quant_features {
            feats.extend_from_slice(&c.features_quant());
        } else {
            feats.extend_from_slice(&c.features());
        }
    }
    Ok(predict_ppa(backend, model, &feats)?
        .into_iter()
        .map(Ppa::from_array)
        .collect())
}

/// Evaluate one predicted config on a workload.
pub fn eval_point(cfg: &AcceleratorConfig, ppa: Ppa, layers: &[Layer]) -> DsePoint {
    // Energy coefficients are structural (jitter-free); the clock the
    // dataflow runs at is the *predicted* fmax, and energy uses the
    // *predicted* power — the regression models drive the DSE.
    let mut ep = energy_params(cfg);
    ep.fmax_mhz = ppa.fmax_mhz.max(1.0);
    let cost = evaluate_network(cfg, &ep, layers);
    let throughput = 1.0 / cost.latency_s.max(1e-12);
    let energy_mj = ppa.power_mw * cost.latency_s; // mW x s = mJ
    DsePoint {
        cfg: *cfg,
        ppa,
        throughput,
        perf_per_area: throughput / ppa.area_mm2.max(1e-9),
        energy_mj,
        utilization: cost.avg_utilization,
    }
}

/// One sweep accumulator per workload.
struct Acc {
    frontier: IncrementalFrontier<(usize, DsePoint)>,
    top_pa: TopK<DsePoint>,
    top_e: TopK<DsePoint>,
    points: Option<Vec<DsePoint>>,
    stats: SweepStats,
}

/// The streaming sweep engine.  Borrowing the backend and options, it
/// sweeps one PE type at a time; each call pipelines every shard through
/// predict -> dataflow-eval for *all* given workloads, so the per-shard
/// prediction is paid once per type regardless of workload count.
pub struct SweepEngine<'a> {
    backend: &'a dyn Backend,
    opts: &'a DseOptions,
    retain_all: bool,
    progress: Option<Box<dyn Fn(&ShardProgress) + 'a>>,
}

impl<'a> SweepEngine<'a> {
    pub fn new(backend: &'a dyn Backend, opts: &'a DseOptions) -> SweepEngine<'a> {
        SweepEngine { backend, opts, retain_all: false, progress: None }
    }

    /// Keep every evaluated point (the eager-compatible path; memory goes
    /// back to O(grid)).  Off by default.
    pub fn retain_all(mut self, yes: bool) -> SweepEngine<'a> {
        self.retain_all = yes;
        self
    }

    /// Install a per-shard progress hook.
    pub fn on_shard(mut self, f: impl Fn(&ShardProgress) + 'a) -> SweepEngine<'a> {
        self.progress = Some(Box::new(f));
        self
    }

    /// Sweep the whole grid of one PE type for every workload in one pass.
    /// Returns one [`TypeSweep`] per workload, in input order.
    pub fn sweep_type(
        &self,
        model: &PpaModel,
        ty: PeType,
        workloads: &[NamedWorkload],
    ) -> Result<Vec<TypeSweep>, QappaError> {
        if workloads.is_empty() {
            return Err(QappaError::Workload("sweep_type: no workloads given".into()));
        }
        let opts = self.opts;
        let total = opts.space.len();
        // Anchor/best-point selection reads the reservoir heads, so depth 0
        // would break every run; clamp to 1.
        let topk = opts.topk.max(1);
        let mut accs: Vec<Acc> = workloads
            .iter()
            .map(|_| Acc {
                frontier: IncrementalFrontier::new(),
                top_pa: TopK::new(topk, Objective::Maximize),
                top_e: TopK::new(topk, Objective::Minimize),
                points: if self.retain_all { Some(Vec::with_capacity(total)) } else { None },
                stats: SweepStats::default(),
            })
            .collect();

        for (shard_no, (start, shard)) in opts.space.chunks(ty, opts.chunk).enumerate() {
            let t0 = std::time::Instant::now();
            let preds = predict_configs(self.backend, model, &shard)?;
            trace(
                &format!("sweep/{}/shard{shard_no}/predict({})", ty.label(), shard.len()),
                t0,
            );
            let items: Vec<(AcceleratorConfig, Ppa)> =
                shard.into_iter().zip(preds).collect();
            let workers = workers_for(items.len(), opts.workers, 32);
            for (w, wl) in workloads.iter().enumerate() {
                let t1 = std::time::Instant::now();
                let pts: Vec<DsePoint> = parallel_map(&items, workers, |(cfg, ppa)| {
                    eval_point(cfg, *ppa, &wl.layers)
                });
                trace(
                    &format!(
                        "sweep/{}/shard{shard_no}/dataflow({}, {})",
                        ty.label(),
                        pts.len(),
                        wl.name
                    ),
                    t1,
                );
                let acc = &mut accs[w];
                for (off, p) in pts.into_iter().enumerate() {
                    let idx = start + off;
                    acc.frontier.push(p.perf_per_area, p.energy_mj, (idx, p.clone()));
                    acc.top_pa.push(p.perf_per_area, p.clone());
                    acc.top_e.push(p.energy_mj, p.clone());
                    if let Some(all) = &mut acc.points {
                        all.push(p);
                    }
                    acc.stats.evaluated += 1;
                }
                acc.stats.shards += 1;
                acc.stats.peak_frontier =
                    acc.stats.peak_frontier.max(acc.frontier.len());
                let resident = items.len()
                    + acc.frontier.len()
                    + acc.top_pa.len()
                    + acc.top_e.len()
                    + acc.points.as_ref().map_or(0, Vec::len);
                acc.stats.peak_resident = acc.stats.peak_resident.max(resident);
                if let Some(hook) = &self.progress {
                    hook(&ShardProgress {
                        pe_type: ty,
                        workload: wl.name.clone(),
                        shard: shard_no,
                        shard_len: items.len(),
                        evaluated: acc.stats.evaluated,
                        total,
                        resident,
                    });
                }
            }
        }

        Ok(workloads
            .iter()
            .zip(accs)
            .map(|(wl, mut acc)| {
                acc.stats.frontier_len = acc.frontier.len();
                acc.stats.reservoir_len = acc.top_pa.len() + acc.top_e.len();
                TypeSweep {
                    pe_type: ty,
                    workload: wl.name.clone(),
                    frontier: acc.frontier.into_entries(),
                    top_perf_per_area: acc.top_pa.into_values(),
                    top_energy: acc.top_e.into_values(),
                    points: acc.points,
                    stats: acc.stats,
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PE_TYPES;
    use crate::coordinator::pareto::pareto_frontier;
    use crate::coordinator::space::DesignSpace;
    use crate::coordinator::explorer::{train_models, train_one_model};
    use crate::model::native::NativeBackend;
    use crate::model::CvConfig;

    fn opts_with(chunk: usize, topk: usize) -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk,
            topk,
        }
    }

    fn net() -> Vec<Layer> {
        vec![Layer::conv("c", 8, 16, 16, 16, 3, 1, 1)]
    }

    #[test]
    fn topk_reservoir_orders_and_bounds() {
        let mut t = TopK::new(3, Objective::Maximize);
        for (i, k) in [1.0, 5.0, 3.0, 5.0, 2.0, 9.0].iter().enumerate() {
            t.push(*k, i);
        }
        // best-first; latest among the tied 5.0s (index 3) ranks first
        assert_eq!(t.len(), 3);
        assert_eq!(t.best(), Some(&5));
        assert_eq!(t.clone().into_values(), vec![5, 3, 1]);

        let mut m = TopK::new(2, Objective::Minimize);
        for (i, k) in [4.0, 2.0, 2.0, 7.0].iter().enumerate() {
            m.push(*k, i);
        }
        // earliest among the tied 2.0s (index 1) ranks first
        assert_eq!(m.into_values(), vec![1, 2]);

        let mut z = TopK::new(0, Objective::Maximize);
        assert!(!z.push(1.0, 0));
        let mut nan = TopK::new(2, Objective::Maximize);
        assert!(!nan.push(f64::NAN, 0));
        assert!(nan.is_empty());
    }

    #[test]
    fn topk_tie_rules_match_iterator_selection() {
        // The reservoir's best must be exactly what max_by/min_by picked in
        // the eager pipeline, including tie direction.
        let keys = [3.0, 7.0, 7.0, 1.0, 7.0, 1.0];
        let mut pa = TopK::new(4, Objective::Maximize);
        let mut e = TopK::new(4, Objective::Minimize);
        for (i, &k) in keys.iter().enumerate() {
            pa.push(k, i);
            e.push(k, i);
        }
        let max_by = keys
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        let min_by = keys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(pa.best(), Some(&max_by)); // last 7.0 (index 4)
        assert_eq!(e.best(), Some(&min_by)); // first 1.0 (index 3)
    }

    #[test]
    fn streaming_matches_eager_shim_per_type() {
        let backend = NativeBackend::new(7);
        let eager = opts_with(0, 8); // chunk=0: whole grid in one shard
        let streaming = opts_with(7, 8); // ragged shards
        let models = train_models(&backend, &eager).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        for ty in ALL_PE_TYPES {
            let a = SweepEngine::new(&backend, &eager)
                .retain_all(true)
                .sweep_type(&models[&ty], ty, &wl)
                .unwrap()
                .remove(0);
            let b = SweepEngine::new(&backend, &streaming)
                .retain_all(true)
                .sweep_type(&models[&ty], ty, &wl)
                .unwrap()
                .remove(0);
            // bit-identical points, frontier and reservoirs
            let pa_a: Vec<f64> =
                a.points.as_ref().unwrap().iter().map(|p| p.perf_per_area).collect();
            let pa_b: Vec<f64> =
                b.points.as_ref().unwrap().iter().map(|p| p.perf_per_area).collect();
            assert_eq!(pa_a, pa_b, "{ty:?} point stream diverged");
            assert_eq!(a.frontier_indices(), b.frontier_indices(), "{ty:?}");
            assert_eq!(
                a.best_perf_per_area().unwrap().cfg,
                b.best_perf_per_area().unwrap().cfg
            );
            assert_eq!(a.best_energy().unwrap().cfg, b.best_energy().unwrap().cfg);
            // the incremental frontier equals the batch frontier
            let pairs: Vec<(f64, f64)> = a
                .points
                .as_ref()
                .unwrap()
                .iter()
                .map(|p| (p.perf_per_area, p.energy_mj))
                .collect();
            assert_eq!(a.frontier_indices(), pareto_frontier(&pairs), "{ty:?}");
        }
    }

    #[test]
    fn streaming_bounds_resident_points() {
        // chunk <= 2*topk makes the acceptance bound structural:
        // resident = shard + frontier + reservoirs <= 2*(frontier + topk).
        let backend = NativeBackend::new(7);
        let opts = opts_with(16, 8);
        let models = train_models(&backend, &opts).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let ts = SweepEngine::new(&backend, &opts)
            .sweep_type(&models[&PeType::Int16], PeType::Int16, &wl)
            .unwrap()
            .remove(0);
        assert!(ts.points.is_none());
        assert_eq!(ts.stats.evaluated, opts.space.len());
        assert!(
            ts.stats.peak_resident
                <= 2 * (ts.stats.peak_frontier + ts.stats.reservoir_len),
            "peak {} vs frontier {} + reservoirs {}",
            ts.stats.peak_resident,
            ts.stats.peak_frontier,
            ts.stats.reservoir_len
        );
    }

    #[test]
    fn streaming_sweeps_4x_paper_scale_in_bounded_memory() {
        // 4x the paper-scale grid (76800 configs/type): the streaming
        // engine must complete with peak resident points <= 2 x
        // (frontier + top-k) — the whole point of the refactor.
        let mut space = DesignSpace::default();
        space.rows.extend([32, 40, 48, 64]); // x2
        space.bandwidth_gbps.extend([12.0, 16.0, 24.0]); // x2
        assert_eq!(space.len(), 4 * DesignSpace::default().len());
        let opts = DseOptions {
            space,
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3], seed: 1 },
            seed: 11,
            workers: crate::util::pool::default_workers(),
            sigma: 0.02,
            chunk: 512,
            topk: 256,
        };
        let backend = NativeBackend::new(7);
        let model = train_one_model(&backend, &opts, PeType::Int16).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let ts = SweepEngine::new(&backend, &opts)
            .sweep_type(&model, PeType::Int16, &wl)
            .unwrap()
            .remove(0);
        assert_eq!(ts.stats.evaluated, 76800);
        assert_eq!(ts.stats.shards, 76800usize.div_ceil(512));
        assert!(
            ts.stats.peak_resident
                <= 2 * (ts.stats.peak_frontier + ts.stats.reservoir_len),
            "peak {} vs frontier {} + reservoirs {}",
            ts.stats.peak_resident,
            ts.stats.peak_frontier,
            ts.stats.reservoir_len
        );
        // and the retained set is a sliver of the grid
        assert!(ts.stats.peak_resident * 10 < ts.stats.evaluated);
        assert!(!ts.frontier.is_empty());
    }

    #[test]
    fn shard_hook_sees_every_shard() {
        let backend = NativeBackend::new(7);
        let opts = opts_with(16, 4);
        let models = train_models(&backend, &opts).unwrap();
        let wl = vec![NamedWorkload::new("t", net())];
        let seen = std::cell::RefCell::new(Vec::new());
        let engine = SweepEngine::new(&backend, &opts)
            .on_shard(|p| seen.borrow_mut().push((p.shard, p.evaluated)));
        let ts = engine
            .sweep_type(&models[&PeType::Fp32], PeType::Fp32, &wl)
            .unwrap()
            .remove(0);
        drop(engine); // release the hook's borrow of `seen`
        let seen = seen.into_inner();
        assert_eq!(seen.len(), opts.space.len().div_ceil(16));
        assert_eq!(seen.last().unwrap().1, opts.space.len());
        assert_eq!(ts.stats.shards, seen.len());
    }
}
