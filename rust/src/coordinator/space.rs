//! Design-space definition: the axes swept in §4 of the paper.
//!
//! The grid is never materialized: [`DesignSpace::nth`] decodes any grid
//! index directly (mixed-radix over the axes, bandwidth fastest-varying),
//! [`DesignSpace::iter`] walks the grid lazily, and
//! [`DesignSpace::chunks`] yields fixed-size config shards for the
//! streaming sweep engine ([`crate::coordinator::sweep`]).  The historical
//! [`DesignSpace::enumerate`] is kept as a thin `iter().collect()` shim
//! for tests and small spaces.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, PeType};
use crate::util::prng::{hash64, Rng};

/// A grid over the accelerator parameters (per PE type), with an optional
/// precision axis.
///
/// When `quants` is empty (the default and every legacy space), the grid
/// spans the seven hardware axes and the PE type passed to
/// [`DesignSpace::nth`] / [`DesignSpace::iter`] / [`DesignSpace::chunks`]
/// applies to every point — the historical per-type sweep.  When `quants`
/// is non-empty it becomes the outermost (slowest-varying) grid axis: each
/// point's precision comes from the axis and the passed PE type is
/// ignored, so one lazy cursor walks `|quants| x |hardware grid|` points
/// and shards of any size stream through the sweep engine exactly like the
/// other axes.  `ALL_PE_TYPES` sweeps are the special case
/// `quants = ALL_PE_TYPES.to_vec()`.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub glb_kb: Vec<u32>,
    pub spad_ifmap_b: Vec<u32>,
    pub spad_filter_b: Vec<u32>,
    pub spad_psum_b: Vec<u32>,
    pub bandwidth_gbps: Vec<f64>,
    /// Optional precision axis (empty = use the per-call PE type).
    pub quants: Vec<PeType>,
}

impl Default for DesignSpace {
    /// The paper-scale sweep: array geometry around Eyeriss (12x14),
    /// Eyeriss-like scratchpads, edge-device GLB sizes and bandwidths.
    fn default() -> DesignSpace {
        DesignSpace {
            rows: vec![8, 12, 16, 24],
            cols: vec![8, 14, 20, 28],
            glb_kb: vec![32, 64, 108, 256, 512],
            spad_ifmap_b: vec![12, 24, 48, 96],
            // down to sizes where the quantization-aware capacity limits
            // bind: 28 B holds 18 LightPE-1 filter planes but only 4 INT16
            // planes of a 3x3 kernel (see dataflow::rs::map_layer)
            spad_filter_b: vec![28, 56, 112, 224, 448],
            spad_psum_b: vec![16, 32, 64, 128],
            bandwidth_gbps: vec![2.0, 4.0, 8.0],
            quants: Vec::new(),
        }
    }
}

impl DesignSpace {
    /// A small space for tests / quickstart (64 points per type).
    pub fn tiny() -> DesignSpace {
        DesignSpace {
            rows: vec![8, 16],
            cols: vec![8, 16],
            glb_kb: vec![64, 256],
            spad_ifmap_b: vec![48],
            spad_filter_b: vec![224, 448],
            spad_psum_b: vec![64],
            bandwidth_gbps: vec![2.0, 8.0],
            quants: Vec::new(),
        }
    }

    /// Copy of this space with a precision axis installed (the quantization
    /// grid of `docs/PRECISION.md`).
    pub fn with_quants(mut self, quants: Vec<PeType>) -> DesignSpace {
        self.quants = quants;
        self
    }

    /// Number of hardware grid points (excluding the precision axis).
    fn base_len(&self) -> usize {
        self.rows.len()
            * self.cols.len()
            * self.glb_kb.len()
            * self.spad_ifmap_b.len()
            * self.spad_filter_b.len()
            * self.spad_psum_b.len()
            * self.bandwidth_gbps.len()
    }

    /// Number of grid points: per PE type when `quants` is empty,
    /// `|quants| x hardware grid` otherwise.
    pub fn len(&self) -> usize {
        self.base_len() * self.quants.len().max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Structural sanity of the axis lists: every hardware axis must be
    /// non-empty (a zero-length axis makes the whole grid empty — and
    /// would make [`DesignSpace::sample`] panic).  Errors name the
    /// offending axis, so a mis-built space fails loudly at the boundary
    /// instead of silently yielding nothing.
    pub fn validate(&self) -> Result<(), QappaError> {
        for (axis, len) in [
            ("rows", self.rows.len()),
            ("cols", self.cols.len()),
            ("glb_kb", self.glb_kb.len()),
            ("spad_ifmap_b", self.spad_ifmap_b.len()),
            ("spad_filter_b", self.spad_filter_b.len()),
            ("spad_psum_b", self.spad_psum_b.len()),
            ("bandwidth_gbps", self.bandwidth_gbps.len()),
        ] {
            if len == 0 {
                return Err(QappaError::Config(format!(
                    "design space: axis '{axis}' is empty (every hardware axis needs \
                     at least one value)"
                )));
            }
        }
        Ok(())
    }

    /// Checked variant of [`DesignSpace::nth`]: a degenerate space (empty
    /// axis) and a past-the-end index both return a structured
    /// [`QappaError`] naming the problem, instead of the iterator-protocol
    /// `None` the lazy cursor uses internally.
    pub fn nth_checked(&self, pe_type: PeType, i: usize) -> Result<AcceleratorConfig, QappaError> {
        self.validate()?;
        self.nth(pe_type, i).ok_or_else(|| {
            QappaError::Config(format!(
                "design space: index {i} out of range (grid has {} points)",
                self.len()
            ))
        })
    }

    /// Decode grid index `i` into its config (row-major over the axes:
    /// precision axis outermost when present, then rows, bandwidth
    /// fastest-varying — the same order the old eager `enumerate`
    /// produced).  O(1); the basis of the lazy cursor.  Returns `None`
    /// past the end (use [`DesignSpace::nth_checked`] for a structured
    /// error instead).
    pub fn nth(&self, pe_type: PeType, i: usize) -> Option<AcceleratorConfig> {
        if i >= self.len() {
            return None;
        }
        let base = self.base_len();
        let (pe_type, mut rem) = if self.quants.is_empty() {
            (pe_type, i)
        } else {
            (self.quants[i / base], i % base)
        };
        let mut digit = |axis_len: usize| -> usize {
            let d = rem % axis_len;
            rem /= axis_len;
            d
        };
        let bw = digit(self.bandwidth_gbps.len());
        let sp = digit(self.spad_psum_b.len());
        let sf = digit(self.spad_filter_b.len());
        let si = digit(self.spad_ifmap_b.len());
        let g = digit(self.glb_kb.len());
        let c = digit(self.cols.len());
        let r = digit(self.rows.len());
        Some(AcceleratorConfig {
            pe_type,
            pe_rows: self.rows[r],
            pe_cols: self.cols[c],
            glb_kb: self.glb_kb[g],
            spad_ifmap_b: self.spad_ifmap_b[si],
            spad_filter_b: self.spad_filter_b[sf],
            spad_psum_b: self.spad_psum_b[sp],
            bandwidth_gbps: self.bandwidth_gbps[bw],
        })
    }

    /// Lazy cursor over the full grid for one PE type.
    pub fn iter(&self, pe_type: PeType) -> SpaceIter<'_> {
        SpaceIter { space: self, pe_type, next: 0, len: self.len() }
    }

    /// Fixed-size config shards for the streaming sweep.  `chunk == 0`
    /// means one shard holding the whole grid (the eager-equivalent path).
    pub fn chunks(&self, pe_type: PeType, chunk: usize) -> SpaceChunks<'_> {
        let len = self.len();
        let chunk = if chunk == 0 { len.max(1) } else { chunk };
        SpaceChunks { space: self, pe_type, next: 0, len, chunk }
    }

    /// Enumerate the full grid for one PE type.  Thin shim over the lazy
    /// cursor, kept for tests and small spaces; large sweeps should stream
    /// through [`DesignSpace::chunks`] instead.
    pub fn enumerate(&self, pe_type: PeType) -> Vec<AcceleratorConfig> {
        self.iter(pe_type).collect()
    }

    /// Stable hash of the axis contents — part of the `ModelStore` cache
    /// key, so model reuse is keyed to the exact space that trained it.
    /// The precision axis only contributes when present, keeping legacy
    /// spaces' hashes (and therefore cache identities) unchanged.
    pub fn space_hash(&self) -> u64 {
        let mut s = String::new();
        for axis in [
            &self.rows,
            &self.cols,
            &self.glb_kb,
            &self.spad_ifmap_b,
            &self.spad_filter_b,
            &self.spad_psum_b,
        ] {
            for v in axis {
                s.push_str(&v.to_string());
                s.push(',');
            }
            s.push(';');
        }
        for v in &self.bandwidth_gbps {
            s.push_str(&format!("{:x},", v.to_bits()));
        }
        if !self.quants.is_empty() {
            s.push('|');
            for q in &self.quants {
                s.push_str(&q.label());
                s.push(',');
            }
        }
        hash64(s.as_bytes())
    }

    /// Sample `n` training configs uniformly from the *continuous* hull of
    /// the grid (better regression coverage than grid points; the oracle
    /// can synthesize any config).
    pub fn sample(&self, pe_type: PeType, n: usize, seed: u64) -> Vec<AcceleratorConfig> {
        let mut rng = Rng::new(seed ^ pe_type.stream_id().wrapping_mul(0x9e37));
        let span_u = |v: &[u32], rng: &mut Rng| -> u32 {
            let lo = *v.iter().min().unwrap();
            let hi = *v.iter().max().unwrap();
            lo + rng.below((hi - lo + 1) as usize) as u32
        };
        let bw_lo = self.bandwidth_gbps.iter().cloned().fold(f64::INFINITY, f64::min);
        let bw_hi = self.bandwidth_gbps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(AcceleratorConfig {
                pe_type,
                pe_rows: span_u(&self.rows, &mut rng),
                pe_cols: span_u(&self.cols, &mut rng),
                glb_kb: span_u(&self.glb_kb, &mut rng),
                spad_ifmap_b: span_u(&self.spad_ifmap_b, &mut rng),
                spad_filter_b: span_u(&self.spad_filter_b, &mut rng),
                spad_psum_b: span_u(&self.spad_psum_b, &mut rng),
                // A single-value bandwidth axis must come back exactly
                // (range_f64's half-open [lo, hi) is degenerate at lo==hi).
                bandwidth_gbps: if bw_lo == bw_hi {
                    bw_lo
                } else {
                    rng.range_f64(bw_lo, bw_hi)
                },
            });
        }
        out
    }
}

/// Lazy grid cursor (see [`DesignSpace::iter`]).  `nth` is O(1), so shards
/// can be dispatched by index without walking the prefix.
#[derive(Debug, Clone)]
pub struct SpaceIter<'a> {
    space: &'a DesignSpace,
    pe_type: PeType,
    next: usize,
    len: usize,
}

impl Iterator for SpaceIter<'_> {
    type Item = AcceleratorConfig;

    fn next(&mut self) -> Option<AcceleratorConfig> {
        if self.next >= self.len {
            return None;
        }
        let cfg = self.space.nth(self.pe_type, self.next);
        self.next += 1;
        cfg
    }

    fn nth(&mut self, n: usize) -> Option<AcceleratorConfig> {
        self.next = self.next.saturating_add(n);
        self.next()
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.len - self.next.min(self.len);
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for SpaceIter<'_> {}

/// Iterator of fixed-size config shards (see [`DesignSpace::chunks`]).
/// Yields `(start_index, configs)` so downstream consumers can recover
/// global grid indices without materializing the prefix.
#[derive(Debug, Clone)]
pub struct SpaceChunks<'a> {
    space: &'a DesignSpace,
    pe_type: PeType,
    next: usize,
    len: usize,
    chunk: usize,
}

impl Iterator for SpaceChunks<'_> {
    type Item = (usize, Vec<AcceleratorConfig>);

    fn next(&mut self) -> Option<(usize, Vec<AcceleratorConfig>)> {
        if self.next >= self.len {
            return None;
        }
        let start = self.next;
        let end = (start + self.chunk).min(self.len);
        let mut shard = Vec::with_capacity(end - start);
        for i in start..end {
            shard.push(self.space.nth(self.pe_type, i).expect("index in range"));
        }
        self.next = end;
        Some((start, shard))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerate_count_matches_len() {
        let s = DesignSpace::default();
        let e = s.enumerate(PeType::Int16);
        assert_eq!(e.len(), s.len());
        // every config valid
        for c in &e {
            c.validate().unwrap();
        }
    }

    #[test]
    fn enumerate_distinct() {
        let s = DesignSpace::tiny();
        let e = s.enumerate(PeType::Fp32);
        let mut keys: Vec<String> = e.iter().map(|c| c.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), e.len());
    }

    #[test]
    fn sample_is_deterministic_and_in_hull() {
        let s = DesignSpace::default();
        let a = s.sample(PeType::LightPe1, 50, 1);
        let b = s.sample(PeType::LightPe1, 50, 1);
        assert_eq!(a, b);
        for c in &a {
            assert!(c.pe_rows >= 8 && c.pe_rows <= 24);
            assert!(c.bandwidth_gbps >= 2.0 && c.bandwidth_gbps <= 8.0);
            c.validate().unwrap();
        }
    }

    #[test]
    fn nth_matches_enumerate_order() {
        let s = DesignSpace::default();
        let e = s.enumerate(PeType::Int16);
        for (i, c) in e.iter().enumerate() {
            assert_eq!(s.nth(PeType::Int16, i).as_ref(), Some(c), "index {i}");
        }
        assert!(s.nth(PeType::Int16, s.len()).is_none());
    }

    #[test]
    fn iter_is_lazy_but_complete() {
        let s = DesignSpace::tiny();
        let it = s.iter(PeType::LightPe2);
        assert_eq!(it.len(), s.len());
        let collected: Vec<_> = it.collect();
        assert_eq!(collected, s.enumerate(PeType::LightPe2));
        // O(1) nth: skipping straight to the tail matches direct decode
        let mut it2 = s.iter(PeType::LightPe2);
        assert_eq!(it2.nth(s.len() - 1), s.nth(PeType::LightPe2, s.len() - 1));
        assert_eq!(it2.next(), None);
    }

    #[test]
    fn chunks_cover_grid_exactly_once() {
        let s = DesignSpace::tiny();
        for chunk in [1, 7, 64, 1000, 0] {
            let mut seen = Vec::new();
            let mut expected_start = 0;
            for (start, shard) in s.chunks(PeType::Fp32, chunk) {
                assert_eq!(start, expected_start);
                assert!(!shard.is_empty());
                if chunk > 0 {
                    assert!(shard.len() <= chunk);
                }
                expected_start += shard.len();
                seen.extend(shard);
            }
            assert_eq!(seen, s.enumerate(PeType::Fp32), "chunk={chunk}");
        }
    }

    #[test]
    fn sample_single_value_bandwidth_axis_is_exact() {
        // Regression: a degenerate bandwidth axis (lo == hi) must sample
        // the axis value exactly, not a [lo, hi) draw.
        let mut s = DesignSpace::tiny();
        s.bandwidth_gbps = vec![4.0];
        let a = s.sample(PeType::Int16, 32, 3);
        for c in &a {
            assert_eq!(c.bandwidth_gbps, 4.0);
            c.validate().unwrap();
        }
        assert_eq!(a, s.sample(PeType::Int16, 32, 3), "still deterministic");
    }

    #[test]
    fn quant_axis_multiplies_grid_and_decodes_outermost() {
        use crate::config::{QuantSpec, ALL_PE_TYPES};
        let base = DesignSpace::tiny();
        let specs = vec![
            PeType::from_spec(QuantSpec::int(4, 4)),
            PeType::Int16,
            PeType::from_spec(QuantSpec::int(8, 8)),
        ];
        let s = DesignSpace::tiny().with_quants(specs.clone());
        assert_eq!(s.len(), 3 * base.len());
        // outermost axis: the first base.len() points carry specs[0], etc.
        for (qi, ty) in specs.iter().enumerate() {
            for off in [0, 1, base.len() - 1] {
                let c = s.nth(PeType::Fp32, qi * base.len() + off).unwrap();
                assert_eq!(c.pe_type, *ty, "q{qi} off{off}");
                // hardware digits match the plain grid at the same offset
                let plain = base.nth(*ty, off).unwrap();
                assert_eq!(c, plain);
                c.validate().unwrap();
            }
        }
        assert!(s.nth(PeType::Fp32, s.len()).is_none());
        // chunks stream across precision boundaries exactly once
        let mut seen = Vec::new();
        for (start, shard) in s.chunks(PeType::Fp32, 7) {
            assert_eq!(start, seen.len());
            seen.extend(shard);
        }
        assert_eq!(seen.len(), s.len());
        assert_eq!(seen, s.iter(PeType::Fp32).collect::<Vec<_>>());
        // the ALL_PE_TYPES sweep is the special case quants = presets
        let all = DesignSpace::tiny().with_quants(ALL_PE_TYPES.to_vec());
        assert_eq!(all.len(), 4 * base.len());
        let mut per_type = Vec::new();
        for ty in ALL_PE_TYPES {
            per_type.extend(base.enumerate(ty));
        }
        assert_eq!(all.iter(PeType::Fp32).collect::<Vec<_>>(), per_type);
    }

    #[test]
    fn quant_axis_contributes_to_space_hash_only_when_present() {
        let plain = DesignSpace::tiny();
        let with = DesignSpace::tiny().with_quants(vec![PeType::Int16]);
        assert_ne!(plain.space_hash(), with.space_hash());
        let with2 = DesignSpace::tiny().with_quants(vec![PeType::LightPe1]);
        assert_ne!(with.space_hash(), with2.space_hash());
    }

    #[test]
    fn space_hash_distinguishes_spaces() {
        let a = DesignSpace::tiny();
        let mut b = DesignSpace::tiny();
        assert_eq!(a.space_hash(), b.space_hash());
        b.glb_kb.push(512);
        assert_ne!(a.space_hash(), b.space_hash());
        let mut c = DesignSpace::tiny();
        c.bandwidth_gbps[0] += 0.5;
        assert_ne!(a.space_hash(), c.space_hash());
    }

    #[test]
    fn nth_checked_errors_past_the_end_with_the_grid_size() {
        let s = DesignSpace::tiny();
        // in range: agrees with the raw decoder
        assert_eq!(s.nth_checked(PeType::Int16, 0).unwrap(), s.nth(PeType::Int16, 0).unwrap());
        let last = s.len() - 1;
        assert_eq!(
            s.nth_checked(PeType::Int16, last).unwrap(),
            s.nth(PeType::Int16, last).unwrap()
        );
        // past the end: structured config error naming index and size
        let e = s.nth_checked(PeType::Int16, s.len()).unwrap_err();
        assert_eq!(e.kind(), "config");
        let msg = e.to_string();
        assert!(msg.contains(&s.len().to_string()), "{msg}");
        assert!(msg.contains("out of range"), "{msg}");
    }

    #[test]
    fn zero_length_axis_is_a_structured_error_not_a_silent_none() {
        let mut s = DesignSpace::tiny();
        s.glb_kb.clear();
        assert!(s.is_empty());
        let e = s.validate().unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("glb_kb"), "{e}");
        // nth_checked reports the degenerate axis, not a bare out-of-range
        let e = s.nth_checked(PeType::Fp32, 0).unwrap_err();
        assert!(e.to_string().contains("glb_kb"), "{e}");
        // every axis is covered by name
        for (clear, name) in [
            (0usize, "rows"),
            (1, "cols"),
            (2, "spad_ifmap_b"),
            (3, "spad_filter_b"),
            (4, "spad_psum_b"),
            (5, "bandwidth_gbps"),
        ] {
            let mut s = DesignSpace::tiny();
            match clear {
                0 => s.rows.clear(),
                1 => s.cols.clear(),
                2 => s.spad_ifmap_b.clear(),
                3 => s.spad_filter_b.clear(),
                4 => s.spad_psum_b.clear(),
                _ => s.bandwidth_gbps.clear(),
            }
            let e = s.validate().unwrap_err();
            assert!(e.to_string().contains(name), "axis {name}: {e}");
        }
        // a healthy space validates
        DesignSpace::default().validate().unwrap();
        DesignSpace::tiny().validate().unwrap();
    }

    #[test]
    fn samples_differ_across_types() {
        let s = DesignSpace::default();
        let a = s.sample(PeType::Int16, 10, 1);
        let b = s.sample(PeType::Fp32, 10, 1);
        assert_ne!(
            a.iter().map(|c| c.pe_rows).collect::<Vec<_>>(),
            b.iter().map(|c| c.pe_rows).collect::<Vec<_>>()
        );
    }
}
