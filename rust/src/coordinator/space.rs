//! Design-space definition: the axes swept in §4 of the paper.

use crate::config::{AcceleratorConfig, PeType};
use crate::util::prng::Rng;

/// A grid over the accelerator parameters (per PE type).
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub glb_kb: Vec<u32>,
    pub spad_ifmap_b: Vec<u32>,
    pub spad_filter_b: Vec<u32>,
    pub spad_psum_b: Vec<u32>,
    pub bandwidth_gbps: Vec<f64>,
}

impl Default for DesignSpace {
    /// The paper-scale sweep: array geometry around Eyeriss (12x14),
    /// Eyeriss-like scratchpads, edge-device GLB sizes and bandwidths.
    fn default() -> DesignSpace {
        DesignSpace {
            rows: vec![8, 12, 16, 24],
            cols: vec![8, 14, 20, 28],
            glb_kb: vec![32, 64, 108, 256, 512],
            spad_ifmap_b: vec![12, 24, 48, 96],
            // down to sizes where the quantization-aware capacity limits
            // bind: 28 B holds 18 LightPE-1 filter planes but only 4 INT16
            // planes of a 3x3 kernel (see dataflow::rs::map_layer)
            spad_filter_b: vec![28, 56, 112, 224, 448],
            spad_psum_b: vec![16, 32, 64, 128],
            bandwidth_gbps: vec![2.0, 4.0, 8.0],
        }
    }
}

impl DesignSpace {
    /// A small space for tests / quickstart (64 points per type).
    pub fn tiny() -> DesignSpace {
        DesignSpace {
            rows: vec![8, 16],
            cols: vec![8, 16],
            glb_kb: vec![64, 256],
            spad_ifmap_b: vec![48],
            spad_filter_b: vec![224, 448],
            spad_psum_b: vec![64],
            bandwidth_gbps: vec![2.0, 8.0],
        }
    }

    /// Number of grid points (per PE type).
    pub fn len(&self) -> usize {
        self.rows.len()
            * self.cols.len()
            * self.glb_kb.len()
            * self.spad_ifmap_b.len()
            * self.spad_filter_b.len()
            * self.spad_psum_b.len()
            * self.bandwidth_gbps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate the full grid for one PE type.
    pub fn enumerate(&self, pe_type: PeType) -> Vec<AcceleratorConfig> {
        let mut out = Vec::with_capacity(self.len());
        for &r in &self.rows {
            for &c in &self.cols {
                for &g in &self.glb_kb {
                    for &si in &self.spad_ifmap_b {
                        for &sf in &self.spad_filter_b {
                            for &sp in &self.spad_psum_b {
                                for &bw in &self.bandwidth_gbps {
                                    out.push(AcceleratorConfig {
                                        pe_type,
                                        pe_rows: r,
                                        pe_cols: c,
                                        glb_kb: g,
                                        spad_ifmap_b: si,
                                        spad_filter_b: sf,
                                        spad_psum_b: sp,
                                        bandwidth_gbps: bw,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Sample `n` training configs uniformly from the *continuous* hull of
    /// the grid (better regression coverage than grid points; the oracle
    /// can synthesize any config).
    pub fn sample(&self, pe_type: PeType, n: usize, seed: u64) -> Vec<AcceleratorConfig> {
        let mut rng = Rng::new(seed ^ (pe_type as u64).wrapping_mul(0x9e37));
        let span_u = |v: &[u32], rng: &mut Rng| -> u32 {
            let lo = *v.iter().min().unwrap();
            let hi = *v.iter().max().unwrap();
            lo + rng.below((hi - lo + 1) as usize) as u32
        };
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(AcceleratorConfig {
                pe_type,
                pe_rows: span_u(&self.rows, &mut rng),
                pe_cols: span_u(&self.cols, &mut rng),
                glb_kb: span_u(&self.glb_kb, &mut rng),
                spad_ifmap_b: span_u(&self.spad_ifmap_b, &mut rng),
                spad_filter_b: span_u(&self.spad_filter_b, &mut rng),
                spad_psum_b: span_u(&self.spad_psum_b, &mut rng),
                bandwidth_gbps: rng.range_f64(
                    self.bandwidth_gbps
                        .iter()
                        .cloned()
                        .fold(f64::INFINITY, f64::min),
                    self.bandwidth_gbps
                        .iter()
                        .cloned()
                        .fold(f64::NEG_INFINITY, f64::max),
                ),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerate_count_matches_len() {
        let s = DesignSpace::default();
        let e = s.enumerate(PeType::Int16);
        assert_eq!(e.len(), s.len());
        // every config valid
        for c in &e {
            c.validate().unwrap();
        }
    }

    #[test]
    fn enumerate_distinct() {
        let s = DesignSpace::tiny();
        let e = s.enumerate(PeType::Fp32);
        let mut keys: Vec<String> = e.iter().map(|c| c.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), e.len());
    }

    #[test]
    fn sample_is_deterministic_and_in_hull() {
        let s = DesignSpace::default();
        let a = s.sample(PeType::LightPe1, 50, 1);
        let b = s.sample(PeType::LightPe1, 50, 1);
        assert_eq!(a, b);
        for c in &a {
            assert!(c.pe_rows >= 8 && c.pe_rows <= 24);
            assert!(c.bandwidth_gbps >= 2.0 && c.bandwidth_gbps <= 8.0);
            c.validate().unwrap();
        }
    }

    #[test]
    fn samples_differ_across_types() {
        let s = DesignSpace::default();
        let a = s.sample(PeType::Int16, 10, 1);
        let b = s.sample(PeType::Fp32, 10, 1);
        assert_ne!(
            a.iter().map(|c| c.pe_rows).collect::<Vec<_>>(),
            b.iter().map(|c| c.pe_rows).collect::<Vec<_>>()
        );
    }
}
