//! The DSE coordinator — QAPPA's workflow engine.
//!
//! Pipeline (one call to [`explorer::run_dse`] / [`explorer::run_dse_multi`]):
//!
//! 1. fetch each PE type's PPA model from the [`explorer::ModelStore`] —
//!    on a miss, sample a training set, run the synthesis-oracle fleet over
//!    it (thread pool) and fit with k-fold CV (degree x lambda), through
//!    either the native backend or the AOT-artifact engine; one training
//!    pass is shared across workloads and repeat runs;
//! 2. stream the design-space grid through the [`sweep::SweepEngine`]:
//!    the lazy [`space::SpaceIter`] cursor yields fixed-size config shards,
//!    each shard is batch-predicted (the framework's raison d'être: the
//!    oracle takes ~ms per config, the model ~µs) and evaluated on every
//!    workload with the row-stationary dataflow model;
//! 3. fold each shard into an incremental Pareto frontier and top-k
//!    reservoirs per (PE type, workload) — a streaming run retains
//!    O(frontier + k) points instead of O(grid);
//! 4. report the paper's normalized ratios, validated by re-synthesizing
//!    the winning configs.

pub mod explorer;
pub mod pareto;
pub mod precision;
pub mod report;
pub mod space;
pub mod sweep;

pub use explorer::{
    run_dse, run_dse_multi, run_dse_with_store, DseOptions, DsePoint, DseResult,
    ModelStore, WorkloadSummary,
};
pub use pareto::{hypervolume, pareto_frontier, IncrementalFrontier};
pub use precision::{parse_bits_axis, run_dse_precision, train_quant_model, PrecisionGrid};
pub use space::DesignSpace;
pub use sweep::{predict_configs, NamedWorkload, SweepEngine, SweepStats};
