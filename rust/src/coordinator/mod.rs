//! The DSE coordinator — QAPPA's workflow engine.
//!
//! Pipeline (one call to [`explorer::run_dse`]):
//!
//! 1. sample a training set per PE type and run the synthesis-oracle fleet
//!    over it (thread pool);
//! 2. fit a PPA model per PE type with k-fold CV (degree x lambda), through
//!    either the native backend or the AOT-artifact engine;
//! 3. predict PPA over the *full* design-space grid (batched through the
//!    runtime engine — this is the framework's raison d'être: the oracle
//!    takes ~ms per config, the model ~µs);
//! 4. evaluate every predicted config on the workload with the
//!    row-stationary dataflow model;
//! 5. extract Pareto frontiers and the paper's normalized ratios.

pub mod explorer;
pub mod pareto;
pub mod report;
pub mod space;

pub use explorer::{run_dse, DseOptions, DsePoint, DseResult};
pub use pareto::pareto_frontier;
pub use space::DesignSpace;
