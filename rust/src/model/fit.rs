//! k-fold cross-validated model selection + the fitted `PpaModel`.
//!
//! Reproduces the paper's §3 methodology: polynomial regression with model
//! selection (degree, here also the ridge lambda) chosen by k-fold CV.
//! Fold membership is expressed as 0/1 weight vectors so the same
//! fixed-shape fit/loss backend calls serve every fold — exactly the
//! protocol the AOT artifacts were lowered for.

use crate::api::error::QappaError;
use crate::model::features::Standardizer;
use crate::model::{Backend, M};
use crate::util::prng::Rng;

/// Cross-validation settings.
#[derive(Debug, Clone)]
pub struct CvConfig {
    pub k: usize,
    pub degrees: Vec<usize>,
    pub lambdas: Vec<f64>,
    pub seed: u64,
}

impl Default for CvConfig {
    fn default() -> CvConfig {
        CvConfig {
            k: 4,
            degrees: vec![1, 2, 3],
            lambdas: vec![1e-4, 1e-3, 1e-2, 1e-1],
            seed: 0x9a99a,
        }
    }
}

/// One CV grid entry for reports.
#[derive(Debug, Clone, Copy)]
pub struct CvEntry {
    pub degree: usize,
    pub lambda: f64,
    /// Mean (over folds and outputs) validation MSE in standardized units.
    pub mse: f64,
}

/// A fitted PPA model for one PE type.
#[derive(Debug, Clone)]
pub struct PpaModel {
    pub degree: usize,
    pub lambda: f64,
    /// `p x M` coefficients in standardized space.
    pub coef: Vec<f32>,
    pub x_std: Standardizer,
    pub y_std: Standardizer,
    pub cv_table: Vec<CvEntry>,
    /// Training rows used.
    pub n_train: usize,
}

/// Fit a PPA model: standardize, CV-select (degree, lambda), refit on all
/// rows.  `features` is n x d raw features, `targets` n x M raw targets.
pub fn fit_ppa(
    backend: &dyn Backend,
    features: &[f64],
    targets: &[f64],
    cv: &CvConfig,
) -> Result<PpaModel, QappaError> {
    let d = backend.d();
    assert_eq!(features.len() % d, 0, "feature shape");
    let n = features.len() / d;
    assert_eq!(targets.len(), n * M, "target shape");
    if n < 2 * cv.k {
        return Err(QappaError::Model(format!(
            "need at least {} rows for {}-fold CV, got {n}",
            2 * cv.k,
            cv.k
        )));
    }

    let x_std = Standardizer::fit(features, d);
    let y_std = Standardizer::fit(targets, M);
    let x: Vec<f32> = x_std.apply_f32(features);
    let y: Vec<f32> = y_std.apply_f32(targets);

    // Shuffled fold assignment.
    let mut fold = vec![0usize; n];
    let mut order: Vec<usize> = (0..n).collect();
    Rng::new(cv.seed).shuffle(&mut order);
    for (slot, &row) in order.iter().enumerate() {
        fold[row] = slot % cv.k;
    }

    let (cv_table, best) = if backend.has_gram_solve() {
        cv_grid_fast(backend, &x, &y, n, &fold, cv)?
    } else {
        cv_grid_plain(backend, &x, &y, n, &fold, cv)?
    };
    let (degree, lambda, _) = best;

    // Final fit on all rows.
    let w = vec![1.0f32; n];
    let coef = backend.fit(&x, &y, &w, n, lambda as f32, degree)?;

    Ok(PpaModel {
        degree,
        lambda,
        coef,
        x_std,
        y_std,
        cv_table,
        n_train: n,
    })
}

type CvOutcome = (Vec<CvEntry>, (usize, f64, f64));

/// Plain CV: one `fit` + one `loss` backend call per (degree, lambda, fold).
fn cv_grid_plain(
    backend: &dyn Backend,
    x: &[f32],
    y: &[f32],
    n: usize,
    fold: &[usize],
    cv: &CvConfig,
) -> Result<CvOutcome, QappaError> {
    let mut cv_table = Vec::new();
    let mut best: Option<(usize, f64, f64)> = None;
    for &degree in &cv.degrees {
        for &lambda in &cv.lambdas {
            let mut total = 0.0;
            for f in 0..cv.k {
                let w_tr: Vec<f32> =
                    fold.iter().map(|&g| if g == f { 0.0 } else { 1.0 }).collect();
                let w_te: Vec<f32> =
                    fold.iter().map(|&g| if g == f { 1.0 } else { 0.0 }).collect();
                let coef = backend.fit(x, y, &w_tr, n, lambda as f32, degree)?;
                let mse = backend.loss(x, y, &w_te, n, &coef, degree)?;
                total += mse.iter().map(|&v| v as f64).sum::<f64>() / M as f64;
            }
            let mse = total / cv.k as f64;
            cv_table.push(CvEntry { degree, lambda, mse });
            if best.map_or(true, |(_, _, b)| mse < b) {
                best = Some((degree, lambda, mse));
            }
        }
    }
    Ok((cv_table, best.ok_or_else(|| QappaError::Model("empty CV grid".into()))?))
}

/// Fast CV via Gram additivity: per degree, one `gram` call per fold; each
/// (lambda, fold) training split is assembled by subtraction and solved by
/// the cheap `solve` call; the held-out MSE is computed from a `predict`
/// over just the fold's rows.  Produces the same table as `cv_grid_plain`
/// to f32 round-off (pinned by a parity test).
fn cv_grid_fast(
    backend: &dyn Backend,
    x: &[f32],
    y: &[f32],
    n: usize,
    fold: &[usize],
    cv: &CvConfig,
) -> Result<CvOutcome, QappaError> {
    let d = backend.d();
    // Rows of each fold (for held-out scoring).
    let mut fold_rows: Vec<Vec<usize>> = vec![Vec::new(); cv.k];
    for (r, &g) in fold.iter().enumerate() {
        fold_rows[g].push(r);
    }
    let mut cv_table = Vec::new();
    let mut best: Option<(usize, f64, f64)> = None;
    for &degree in &cv.degrees {
        // One Gram per fold; totals by accumulation.
        let mut grams = Vec::with_capacity(cv.k);
        for f in 0..cv.k {
            let w_f: Vec<f32> =
                fold.iter().map(|&g| if g == f { 1.0 } else { 0.0 }).collect();
            grams.push(backend.gram(x, y, &w_f, n, degree)?);
        }
        let p2 = grams[0].0.len();
        let pm = grams[0].1.len();
        let mut g_all = vec![0.0f32; p2];
        let mut c_all = vec![0.0f32; pm];
        let mut n_all = 0.0f32;
        for (g, c, ne) in &grams {
            for (a, b) in g_all.iter_mut().zip(g) {
                *a += b;
            }
            for (a, b) in c_all.iter_mut().zip(c) {
                *a += b;
            }
            n_all += ne;
        }
        for &lambda in &cv.lambdas {
            let mut total = 0.0;
            for f in 0..cv.k {
                // training split = all - fold f
                let (gf, cf, nf) = &grams[f];
                let g_tr: Vec<f32> = g_all.iter().zip(gf).map(|(a, b)| a - b).collect();
                let c_tr: Vec<f32> = c_all.iter().zip(cf).map(|(a, b)| a - b).collect();
                let coef = backend.solve(&g_tr, &c_tr, n_all - nf, lambda as f32, degree)?;
                // held-out MSE from a predict over the fold's rows only
                let rows = &fold_rows[f];
                let mut xf = Vec::with_capacity(rows.len() * d);
                for &r in rows {
                    xf.extend_from_slice(&x[r * d..(r + 1) * d]);
                }
                let pred = backend.predict(&xf, rows.len(), &coef, degree)?;
                let mut mse = 0.0f64;
                for (i, &r) in rows.iter().enumerate() {
                    for c in 0..M {
                        let e = (pred[i * M + c] - y[r * M + c]) as f64;
                        mse += e * e;
                    }
                }
                total += mse / (rows.len().max(1) * M) as f64;
            }
            let mse = total / cv.k as f64;
            cv_table.push(CvEntry { degree, lambda, mse });
            if best.map_or(true, |(_, _, b)| mse < b) {
                best = Some((degree, lambda, mse));
            }
        }
    }
    Ok((cv_table, best.ok_or_else(|| QappaError::Model("empty CV grid".into()))?))
}

/// Predict raw-unit PPA for raw feature rows (n x d).
pub fn predict_ppa(
    backend: &dyn Backend,
    model: &PpaModel,
    features: &[f64],
) -> Result<Vec<[f64; M]>, QappaError> {
    let d = backend.d();
    assert_eq!(features.len() % d, 0);
    let n = features.len() / d;
    let x = model.x_std.apply_f32(features);
    let z = backend.predict(&x, n, &model.coef, model.degree)?;
    let mut out = Vec::with_capacity(n);
    for r in 0..n {
        let zrow: Vec<f64> = (0..M).map(|c| z[r * M + c] as f64).collect();
        let raw = model.y_std.invert_row(&zrow);
        out.push([raw[0], raw[1], raw[2]]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::NativeBackend;
    use crate::util::prng::Rng;

    /// Quadratic ground truth with small noise.
    fn dataset(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n * M);
        for _ in 0..n {
            let row: Vec<f64> = (0..d).map(|_| rng.range_f64(1.0, 10.0)).collect();
            // targets: nonlinear but exactly quadratic in features
            let a = 2.0 + row[0] * row[1] + 0.5 * row[0] * row[0];
            let b = 1.0 + 3.0 * row[1] + row[1] * row[1] * 0.1;
            let c = 5.0 + row[0] + row[1];
            y.push(a + 0.001 * rng.gauss());
            y.push(b + 0.001 * rng.gauss());
            y.push(c + 0.001 * rng.gauss());
            x.extend(row);
        }
        (x, y)
    }

    #[test]
    fn cv_selects_quadratic_for_quadratic_truth() {
        let (x, y) = dataset(240, 2, 1);
        let b = NativeBackend::new(2);
        let model = fit_ppa(&b, &x, &y, &CvConfig::default()).unwrap();
        assert_eq!(model.degree, 2, "cv table: {:?}", model.cv_table);
    }

    #[test]
    fn predictions_match_truth_in_raw_units() {
        let (x, y) = dataset(300, 2, 2);
        let b = NativeBackend::new(2);
        let model = fit_ppa(&b, &x, &y, &CvConfig::default()).unwrap();
        let preds = predict_ppa(&b, &model, &x).unwrap();
        let mut worst: f64 = 0.0;
        for (r, p) in preds.iter().enumerate() {
            for c in 0..M {
                let truth = y[r * M + c];
                worst = worst.max(((p[c] - truth) / truth).abs());
            }
        }
        assert!(worst < 0.05, "worst relative error {worst}");
    }

    #[test]
    fn cv_table_covers_grid() {
        let (x, y) = dataset(120, 2, 3);
        let b = NativeBackend::new(2);
        let cv = CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-1], seed: 7 };
        let model = fit_ppa(&b, &x, &y, &cv).unwrap();
        assert_eq!(model.cv_table.len(), 4);
        // the winner must be in the table with the minimal mse
        let min = model
            .cv_table
            .iter()
            .map(|e| e.mse)
            .fold(f64::INFINITY, f64::min);
        let winner = model
            .cv_table
            .iter()
            .find(|e| e.degree == model.degree && e.lambda == model.lambda)
            .unwrap();
        assert!((winner.mse - min).abs() < 1e-15);
    }

    #[test]
    fn too_few_rows_is_error() {
        let b = NativeBackend::new(2);
        let err = fit_ppa(&b, &[1.0, 2.0], &[1.0, 2.0, 3.0], &CvConfig::default());
        assert!(err.is_err());
    }

    #[test]
    fn fast_and_plain_cv_agree() {
        // The Gram-additivity fast path must reproduce the plain CV table
        // (same winners; mse equal to f32 round-off).
        let (x, y) = dataset(200, 2, 9);
        let b = NativeBackend::new(2);
        let cv = CvConfig::default();
        let x_std = Standardizer::fit(&x, 2);
        let y_std = Standardizer::fit(&y, M);
        let xs = x_std.apply_f32(&x);
        let ys = y_std.apply_f32(&y);
        let n = 200;
        let mut fold = vec![0usize; n];
        let mut order: Vec<usize> = (0..n).collect();
        Rng::new(cv.seed).shuffle(&mut order);
        for (slot, &row) in order.iter().enumerate() {
            fold[row] = slot % cv.k;
        }
        let (t_fast, best_fast) = cv_grid_fast(&b, &xs, &ys, n, &fold, &cv).unwrap();
        let (t_plain, best_plain) = cv_grid_plain(&b, &xs, &ys, n, &fold, &cv).unwrap();
        assert_eq!(best_fast.0, best_plain.0, "degree winner");
        assert_eq!(best_fast.1, best_plain.1, "lambda winner");
        for (a, bb) in t_fast.iter().zip(&t_plain) {
            assert!(
                // f32 accumulation-order noise floor near-zero mse
                (a.mse - bb.mse).abs() < 1e-3 * bb.mse.max(1e-6),
                "cv mse {} vs {} at d{} l{}",
                a.mse,
                bb.mse,
                a.degree,
                a.lambda
            );
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let (x, y) = dataset(160, 2, 4);
        let b = NativeBackend::new(2);
        let m1 = fit_ppa(&b, &x, &y, &CvConfig::default()).unwrap();
        let m2 = fit_ppa(&b, &x, &y, &CvConfig::default()).unwrap();
        assert_eq!(m1.degree, m2.degree);
        assert_eq!(m1.coef, m2.coef);
    }
}
