//! Polynomial feature plumbing + standardization.
//!
//! The monomial ordering here MUST match `python/compile/kernels/poly.py`
//! (degree-major, lexicographic combinations-with-replacement); the
//! integration tests cross-check it against `artifacts/manifest.json`.

/// All monomials of total degree 1..=degree over d variables.
pub fn monomial_indices(d: usize, degree: usize) -> Vec<Vec<usize>> {
    assert!(d > 0 && degree >= 1, "bad monomial args d={d} degree={degree}");
    let mut out = Vec::new();
    for k in 1..=degree {
        let mut cur = vec![0usize; k];
        loop {
            out.push(cur.clone());
            // next combination with replacement (non-decreasing tuples)
            let mut i = k;
            loop {
                if i == 0 {
                    break;
                }
                i -= 1;
                if cur[i] < d - 1 {
                    cur[i] += 1;
                    for j in i + 1..k {
                        cur[j] = cur[i];
                    }
                    break;
                }
                if i == 0 {
                    cur.clear();
                    break;
                }
            }
            if cur.is_empty() {
                break;
            }
        }
    }
    out
}

/// P — feature count including the constant column.
pub fn num_features(d: usize, degree: usize) -> usize {
    1 + monomial_indices(d, degree).len()
}

/// Expand one standardized feature row into its P monomials.
pub fn expand_row(x: &[f64], degree: usize, idx: &[Vec<usize>]) -> Vec<f64> {
    let mut out = Vec::with_capacity(1 + idx.len());
    expand_row_into(x, degree, idx, &mut out);
    out
}

/// [`expand_row`] into a caller-owned buffer (cleared first), so batch
/// loops — the Gram accumulation and the hot-path predict — expand
/// thousands of rows without a per-row allocation.  The monomial values
/// are computed by the identical multiply chain, so results are
/// bit-identical to [`expand_row`].
pub fn expand_row_into(x: &[f64], degree: usize, idx: &[Vec<usize>], out: &mut Vec<f64>) {
    out.clear();
    out.reserve(1 + idx.len());
    out.push(1.0);
    for tup in idx {
        let mut v = 1.0;
        for &j in tup {
            v *= x[j];
        }
        out.push(v);
    }
    debug_assert_eq!(out.len(), num_features(x.len(), degree));
}

/// Column-wise standardizer: z = (x - mean) / std.
#[derive(Debug, Clone, PartialEq)]
pub struct Standardizer {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Standardizer {
    /// Fit on rows (n x d, row-major).
    pub fn fit(rows: &[f64], d: usize) -> Standardizer {
        assert!(d > 0 && rows.len() % d == 0, "bad shape");
        let n = rows.len() / d;
        assert!(n > 0, "empty standardizer input");
        let mut mean = vec![0.0; d];
        for row in rows.chunks(d) {
            for (m, v) in mean.iter_mut().zip(row) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0; d];
        for row in rows.chunks(d) {
            for ((v, m), x) in var.iter_mut().zip(&mean).zip(row) {
                *v += (x - m) * (x - m);
            }
        }
        let std = var
            .into_iter()
            .map(|v| {
                let s = (v / n as f64).sqrt();
                if s < 1e-12 {
                    1.0 // constant column: leave centred at 0
                } else {
                    s
                }
            })
            .collect();
        Standardizer { mean, std }
    }

    pub fn d(&self) -> usize {
        self.mean.len()
    }

    pub fn apply_row(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(&self.mean)
            .zip(&self.std)
            .map(|((v, m), s)| (v - m) / s)
            .collect()
    }

    pub fn invert_row(&self, z: &[f64]) -> Vec<f64> {
        z.iter()
            .zip(&self.mean)
            .zip(&self.std)
            .map(|((v, m), s)| v * s + m)
            .collect()
    }

    /// Apply to an n x d row-major slab, producing f32 (the artifact dtype).
    pub fn apply_f32(&self, rows: &[f64]) -> Vec<f32> {
        let d = self.d();
        let mut out = Vec::with_capacity(rows.len());
        for row in rows.chunks(d) {
            for ((v, m), s) in row.iter().zip(&self.mean).zip(&self.std) {
                out.push(((v - m) / s) as f32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p_matches_python_contract() {
        // D=7: P(1)=8, P(2)=36, P(3)=120 — pinned by manifest.json.
        assert_eq!(num_features(7, 1), 8);
        assert_eq!(num_features(7, 2), 36);
        assert_eq!(num_features(7, 3), 120);
    }

    #[test]
    fn monomials_are_degree_major_lex() {
        let idx = monomial_indices(3, 2);
        assert_eq!(
            idx,
            vec![
                vec![0], vec![1], vec![2],
                vec![0, 0], vec![0, 1], vec![0, 2],
                vec![1, 1], vec![1, 2], vec![2, 2],
            ]
        );
    }

    #[test]
    fn monomials_nondecreasing_tuples() {
        for tup in monomial_indices(7, 3) {
            let mut sorted = tup.clone();
            sorted.sort();
            assert_eq!(tup, sorted);
        }
    }

    #[test]
    fn expand_row_values() {
        let idx = monomial_indices(2, 2);
        let f = expand_row(&[2.0, 3.0], 2, &idx);
        // [1, x0, x1, x0², x0x1, x1²]
        assert_eq!(f, vec![1.0, 2.0, 3.0, 4.0, 6.0, 9.0]);
    }

    #[test]
    fn expand_row_into_reuses_buffer_and_matches_expand_row() {
        let idx = monomial_indices(3, 3);
        let mut buf = vec![99.0; 4]; // stale contents must be cleared
        for row in [[0.5, -1.25, 2.0], [3.0, 0.0, -0.5]] {
            expand_row_into(&row, 3, &idx, &mut buf);
            assert_eq!(buf, expand_row(&row, 3, &idx));
        }
    }

    #[test]
    fn standardizer_roundtrip() {
        let rows = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let s = Standardizer::fit(&rows, 2);
        let z = s.apply_row(&[2.5, 25.0]);
        assert!(z[0].abs() < 1e-12 && z[1].abs() < 1e-12); // the mean row
        let back = s.invert_row(&z);
        assert!((back[0] - 2.5).abs() < 1e-12);
        assert!((back[1] - 25.0).abs() < 1e-12);
    }

    #[test]
    fn standardized_columns_have_unit_variance() {
        let mut rows = Vec::new();
        for i in 0..100 {
            rows.push(i as f64);
            rows.push(3.0 * i as f64 + 7.0);
        }
        let s = Standardizer::fit(&rows, 2);
        let z = s.apply_f32(&rows);
        for col in 0..2 {
            let vals: Vec<f64> = z.chunks(2).map(|r| r[col] as f64).collect();
            let mean: f64 = vals.iter().sum::<f64>() / vals.len() as f64;
            let var: f64 =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
            assert!(mean.abs() < 1e-6, "col {col} mean {mean}");
            assert!((var - 1.0).abs() < 1e-4, "col {col} var {var}");
        }
    }

    #[test]
    fn constant_column_safe() {
        let rows = vec![5.0, 1.0, 5.0, 2.0, 5.0, 3.0];
        let s = Standardizer::fit(&rows, 2);
        let z = s.apply_row(&[5.0, 2.0]);
        assert_eq!(z[0], 0.0); // centred, not divided by 0
        assert!(z[0].is_finite() && z[1].is_finite());
    }
}
