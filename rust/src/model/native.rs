//! Pure-Rust weighted polynomial ridge regression.
//!
//! Mirrors the L2 JAX semantics exactly (unpenalized intercept,
//! `n_eff = sum(w)` normalization, `1e-7` diagonal jitter), in f64, so it
//! doubles as the parity oracle for the XLA artifact path and as the
//! fallback backend when `artifacts/` is absent.

use crate::api::error::QappaError;
use crate::model::features::{expand_row, expand_row_into, monomial_indices};
use crate::model::{Backend, M};

/// Dense column-major-free little matrix helper (row-major).
fn cholesky_solve(a: &mut [f64], b: &mut [f64], p: usize, m: usize) -> Result<(), QappaError> {
    // In-place Cholesky A = L L^T (lower in a).
    for j in 0..p {
        let mut diag = a[j * p + j];
        for k in 0..j {
            diag -= a[j * p + k] * a[j * p + k];
        }
        if !(diag > 0.0) {
            // negative OR NaN (NaN fails every comparison)
            return Err(QappaError::Model(format!(
                "matrix not SPD at column {j} (diag {diag})"
            )));
        }
        let d = diag.sqrt();
        a[j * p + j] = d;
        for i in j + 1..p {
            let mut v = a[i * p + j];
            for k in 0..j {
                v -= a[i * p + k] * a[j * p + k];
            }
            a[i * p + j] = v / d;
        }
    }
    // Forward substitution L z = b.
    for col in 0..m {
        for i in 0..p {
            let mut v = b[i * m + col];
            for k in 0..i {
                v -= a[i * p + k] * b[k * m + col];
            }
            b[i * m + col] = v / a[i * p + i];
        }
        // Back substitution L^T x = z.
        for i in (0..p).rev() {
            let mut v = b[i * m + col];
            for k in i + 1..p {
                v -= a[k * p + i] * b[k * m + col];
            }
            b[i * m + col] = v / a[i * p + i];
        }
    }
    Ok(())
}

/// Un-normalized weighted Gram accumulators (upper triangle filled,
/// symmetrized): returns `(G [p*p], C [p*M], n_eff)`.
pub fn gram_f64(
    x: &[f64],
    y: &[f64],
    w: &[f64],
    n: usize,
    d: usize,
    degree: usize,
) -> (Vec<f64>, Vec<f64>, f64) {
    let idx = monomial_indices(d, degree);
    let p = 1 + idx.len();
    let mut gram = vec![0.0; p * p];
    let mut rhs = vec![0.0; p * M];
    let mut n_eff = 0.0;
    let mut f = Vec::new();
    for r in 0..n {
        let wi = w[r];
        if wi == 0.0 {
            continue;
        }
        n_eff += wi;
        expand_row_into(&x[r * d..(r + 1) * d], degree, &idx, &mut f);
        for i in 0..p {
            let fwi = f[i] * wi;
            for j in i..p {
                gram[i * p + j] += fwi * f[j];
            }
            for c in 0..M {
                rhs[i * M + c] += fwi * y[r * M + c];
            }
        }
    }
    for i in 0..p {
        for j in 0..i {
            gram[i * p + j] = gram[j * p + i];
        }
    }
    (gram, rhs, n_eff)
}

/// Ridge solve from accumulated Grams (matches the L2 `solve_fn` exactly:
/// unpenalized intercept, `n_eff` normalization, `1e-7` jitter).
pub fn solve_from_gram_f64(
    g: &[f64],
    c: &[f64],
    n_eff: f64,
    lam: f64,
    p: usize,
) -> Result<Vec<f64>, QappaError> {
    let n_eff = n_eff.max(1.0);
    let mut a: Vec<f64> = g.iter().map(|v| v / n_eff).collect();
    let mut b: Vec<f64> = c.iter().map(|v| v / n_eff).collect();
    for i in 0..p {
        if i > 0 {
            a[i * p + i] += lam;
        }
        a[i * p + i] += 1e-7;
    }
    cholesky_solve(&mut a, &mut b, p, M)?;
    Ok(b)
}

/// Weighted ridge fit on expanded features (f64 core).
pub fn ridge_fit_f64(
    x: &[f64],
    y: &[f64],
    w: &[f64],
    n: usize,
    d: usize,
    lam: f64,
    degree: usize,
) -> Result<Vec<f64>, QappaError> {
    let (g, c, n_eff) = gram_f64(x, y, w, n, d, degree);
    let p = 1 + monomial_indices(d, degree).len();
    solve_from_gram_f64(&g, &c, n_eff, lam, p)
}

/// Prediction on expanded features (f64 core), one row at a time.  Kept
/// as the readable reference implementation and the bit-exactness oracle
/// for [`predict_f64_batch`] (the hot-path form).
pub fn predict_f64(x: &[f64], n: usize, d: usize, coef: &[f64], degree: usize) -> Vec<f64> {
    let idx = monomial_indices(d, degree);
    let p = 1 + idx.len();
    assert_eq!(coef.len(), p * M, "coef shape");
    let mut out = vec![0.0; n * M];
    for r in 0..n {
        let f = expand_row(&x[r * d..(r + 1) * d], degree, &idx);
        for c in 0..M {
            let mut acc = 0.0;
            for i in 0..p {
                acc += f[i] * coef[i * M + c];
            }
            out[r * M + c] = acc;
        }
    }
    out
}

/// Structure-of-arrays prediction: one pass per monomial *column* over the
/// whole batch instead of one feature expansion per row.  No per-row
/// allocation (a single `n`-length scratch column is reused), sequential
/// access to `coef`, and the accumulation for each `(row, target)` output
/// still happens in monomial-index-ascending order with identically
/// computed monomial values — so the result is bit-identical to
/// [`predict_f64`] (pinned by a test below).
pub fn predict_f64_batch(x: &[f64], n: usize, d: usize, coef: &[f64], degree: usize) -> Vec<f64> {
    let idx = monomial_indices(d, degree);
    let p = 1 + idx.len();
    assert_eq!(coef.len(), p * M, "coef shape");
    let mut out = vec![0.0; n * M];
    // Constant column (monomial 0 is the intercept, value 1.0 per row).
    for r in 0..n {
        for c in 0..M {
            out[r * M + c] += coef[c];
        }
    }
    let mut col = vec![0.0; n];
    for (t, tup) in idx.iter().enumerate() {
        let i = t + 1;
        for (r, v) in col.iter_mut().enumerate() {
            let row = &x[r * d..(r + 1) * d];
            let mut m = 1.0;
            for &j in tup {
                m *= row[j];
            }
            *v = m;
        }
        for c in 0..M {
            let k = coef[i * M + c];
            for r in 0..n {
                out[r * M + c] += col[r] * k;
            }
        }
    }
    out
}

/// The native backend (f32 interface shared with the XLA path).
pub struct NativeBackend {
    pub d: usize,
}

impl NativeBackend {
    pub fn new(d: usize) -> NativeBackend {
        NativeBackend { d }
    }
}

fn to_f64(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

impl Backend for NativeBackend {
    fn d(&self) -> usize {
        self.d
    }

    fn fit(
        &self,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        n: usize,
        lam: f32,
        degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        let coef = ridge_fit_f64(
            &to_f64(x),
            &to_f64(y),
            &to_f64(w),
            n,
            self.d,
            lam as f64,
            degree,
        )?;
        Ok(coef.into_iter().map(|v| v as f32).collect())
    }

    fn loss(
        &self,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        n: usize,
        coef: &[f32],
        degree: usize,
    ) -> Result<[f32; M], QappaError> {
        let pred = predict_f64(&to_f64(x), n, self.d, &to_f64(coef), degree);
        let mut mse = [0.0f64; M];
        let mut n_eff = 0.0;
        for r in 0..n {
            let wi = w[r] as f64;
            n_eff += wi;
            for c in 0..M {
                let e = pred[r * M + c] - y[r * M + c] as f64;
                mse[c] += wi * e * e;
            }
        }
        let n_eff = n_eff.max(1.0);
        Ok([
            (mse[0] / n_eff) as f32,
            (mse[1] / n_eff) as f32,
            (mse[2] / n_eff) as f32,
        ])
    }

    fn predict(
        &self,
        x: &[f32],
        n: usize,
        coef: &[f32],
        degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        // Column-wise SoA form; bit-identical to the per-row reference.
        Ok(predict_f64_batch(&to_f64(x), n, self.d, &to_f64(coef), degree)
            .into_iter()
            .map(|v| v as f32)
            .collect())
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn has_gram_solve(&self) -> bool {
        true
    }

    fn gram(
        &self,
        x: &[f32],
        y: &[f32],
        w: &[f32],
        n: usize,
        degree: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32), QappaError> {
        let (g, c, n_eff) = gram_f64(&to_f64(x), &to_f64(y), &to_f64(w), n, self.d, degree);
        Ok((
            g.into_iter().map(|v| v as f32).collect(),
            c.into_iter().map(|v| v as f32).collect(),
            n_eff as f32,
        ))
    }

    fn solve(
        &self,
        g: &[f32],
        c: &[f32],
        n_eff: f32,
        lam: f32,
        degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        let p = crate::model::features::num_features(self.d, degree);
        let out = solve_from_gram_f64(&to_f64(g), &to_f64(c), n_eff as f64, lam as f64, p)?;
        Ok(out.into_iter().map(|v| v as f32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Build a planted polynomial dataset.
    fn planted(n: usize, d: usize, degree: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let idx = monomial_indices(d, degree);
        let p = 1 + idx.len();
        let mut rng = Rng::new(seed);
        let coef: Vec<f64> = (0..p * M).map(|_| rng.gauss()).collect();
        let mut x = Vec::with_capacity(n * d);
        for _ in 0..n * d {
            x.push(rng.range_f64(-1.0, 1.0));
        }
        let y = predict_f64(&x, n, d, &coef, degree);
        (x, y, coef)
    }

    #[test]
    fn recovers_planted_polynomial() {
        let (x, y, coef_true) = planted(400, 4, 2, 1);
        let w = vec![1.0; 400];
        let coef = ridge_fit_f64(&x, &y, &w, 400, 4, 0.0, 2).unwrap();
        for (a, b) in coef.iter().zip(&coef_true) {
            // the 1e-7 stabilization jitter bounds achievable accuracy
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_weight_rows_ignored() {
        let (mut x, mut y, _) = planted(200, 3, 2, 2);
        let mut w = vec![1.0; 200];
        // corrupt the last 50 rows and mask them
        for r in 150..200 {
            w[r] = 0.0;
            for j in 0..3 {
                x[r * 3 + j] = 99.0;
            }
            for c in 0..M {
                y[r * M + c] = -99.0;
            }
        }
        let a = ridge_fit_f64(&x, &y, &w, 200, 3, 0.01, 2).unwrap();
        let b = ridge_fit_f64(&x[..150 * 3], &y[..150 * M], &vec![1.0; 150], 150, 3, 0.01, 2)
            .unwrap();
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn ridge_shrinks_non_intercept() {
        let (x, y, _) = planted(150, 7, 2, 3);
        let w = vec![1.0; 150];
        let small = ridge_fit_f64(&x, &y, &w, 150, 7, 1e-6, 2).unwrap();
        let big = ridge_fit_f64(&x, &y, &w, 150, 7, 10.0, 2).unwrap();
        let norm = |c: &[f64]| c[M..].iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(norm(&big) < norm(&small));
    }

    #[test]
    fn backend_loss_zero_on_training_fit() {
        let (x, y, _) = planted(300, 5, 2, 4);
        let b = NativeBackend::new(5);
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let w = vec![1.0f32; 300];
        let coef = b.fit(&xf, &yf, &w, 300, 0.0, 2).unwrap();
        let mse = b.loss(&xf, &yf, &w, 300, &coef, 2).unwrap();
        for v in mse {
            assert!(v < 1e-6, "mse {v}");
        }
    }

    #[test]
    fn non_spd_is_reported() {
        // n=1 with degree 3 over d=7: wildly underdetermined but jitter
        // keeps it SPD — so force failure via NaN input instead.
        let x = vec![f64::NAN; 7];
        let y = vec![0.0; M];
        let w = vec![1.0];
        assert!(ridge_fit_f64(&x, &y, &w, 1, 7, 0.0, 2).is_err());
    }

    #[test]
    fn predict_shape() {
        let b = NativeBackend::new(7);
        let coef = vec![0.0f32; 36 * M];
        let x = vec![0.5f32; 7 * 9];
        let out = b.predict(&x, 9, &coef, 2).unwrap();
        assert_eq!(out.len(), 9 * M);
    }

    #[test]
    fn batch_predict_bit_identical_to_per_row_reference() {
        let mut rng = Rng::new(77);
        for (n, d, degree) in [(1usize, 7usize, 2usize), (9, 7, 3), (257, 12, 2), (64, 3, 1)] {
            let idx = monomial_indices(d, degree);
            let p = 1 + idx.len();
            let coef: Vec<f64> = (0..p * M).map(|_| rng.gauss()).collect();
            let x: Vec<f64> = (0..n * d).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let row_wise = predict_f64(&x, n, d, &coef, degree);
            let col_wise = predict_f64_batch(&x, n, d, &coef, degree);
            assert_eq!(row_wise.len(), col_wise.len());
            for (i, (a, b)) in row_wise.iter().zip(&col_wise).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "element {i}: {a} vs {b}");
            }
        }
    }
}
