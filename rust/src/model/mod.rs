//! PPA regression models — the rust-side client of the paper's polynomial
//! models.
//!
//! * [`features`] — monomial index sets and feature/target standardization
//!   (mirrors `python/compile/kernels/poly.py`; the order contract lives in
//!   `artifacts/manifest.json`);
//! * [`native`]  — a pure-Rust weighted ridge implementation, used as the
//!   no-artifact fallback and as the baseline the XLA path is
//!   parity-checked against;
//! * [`fit`]     — the k-fold CV driver (degree x lambda model selection)
//!   over an abstract [`Backend`], plus the fitted [`PpaModel`].
//!
//! The [`Backend`] trait is implemented by [`native::NativeBackend`] and by
//! the PJRT-artifact engine (`crate::runtime::XlaBackend`).

pub mod features;
pub mod fit;
pub mod native;

pub use features::{num_features, Standardizer};
pub use fit::{fit_ppa, predict_ppa, CvConfig, PpaModel};

use crate::api::error::QappaError;

/// Number of regression targets: [power_mw, fmax_mhz, area_mm2].
pub const M: usize = 3;

/// Abstract regression backend (native f64 or AOT-compiled XLA artifacts).
///
/// All matrices are row-major `f32` slices; `x` is `n x d` *standardized*
/// features, `y` is `n x M` *standardized* targets, `w` is an `n` weight
/// vector (0 = ignore row), `coef` is `p x M`.
pub trait Backend {
    /// Feature dimension D the backend was built for.
    fn d(&self) -> usize;
    /// Weighted ridge fit; returns `p x M` coefficients.
    fn fit(&self, x: &[f32], y: &[f32], w: &[f32], n: usize, lam: f32, degree: usize)
        -> Result<Vec<f32>, QappaError>;
    /// Weighted per-output MSE of `coef` on the rows selected by `w`.
    fn loss(&self, x: &[f32], y: &[f32], w: &[f32], n: usize, coef: &[f32], degree: usize)
        -> Result<[f32; M], QappaError>;
    /// Batched prediction; returns `n x M`.
    fn predict(&self, x: &[f32], n: usize, coef: &[f32], degree: usize)
        -> Result<Vec<f32>, QappaError>;
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    // ---- optional CV fast path (Gram additivity over folds) ------------

    /// Whether `gram`/`solve` are implemented (enables the k-fold CV fast
    /// path: one Gram per fold, cheap per-lambda solves).
    fn has_gram_solve(&self) -> bool {
        false
    }

    /// Un-normalized accumulators: returns `(G [p*p], C [p*M], n_eff)`.
    fn gram(
        &self,
        _x: &[f32],
        _y: &[f32],
        _w: &[f32],
        _n: usize,
        _degree: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32), QappaError> {
        Err(QappaError::Backend("gram unsupported by this backend".into()))
    }

    /// Ridge solve from accumulators; returns `p x M` coefficients.
    fn solve(
        &self,
        _g: &[f32],
        _c: &[f32],
        _n_eff: f32,
        _lam: f32,
        _degree: usize,
    ) -> Result<Vec<f32>, QappaError> {
        Err(QappaError::Backend("solve unsupported by this backend".into()))
    }
}
