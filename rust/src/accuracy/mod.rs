//! Quantization-sensitivity accuracy modeling — the quality axis of the
//! co-exploration space.
//!
//! QAPPA's optimizer searches hardware × per-layer precision, but PPA alone
//! rewards the degenerate corner: with no accuracy signal, 2-bit weights
//! everywhere always "win".  QADAM (arXiv:2205.13045) frames the payoff as
//! Pareto-optimality across *quality and cost*, and QUIDAM
//! (arXiv:2206.15463) extends the search to choosing the model jointly with
//! the hardware.  This module supplies the quality signal:
//!
//! * [`AccuracyModel`] — a pluggable per-layer quantization-sensitivity
//!   model.  The default is a QAT-emulation-style noise proxy: quantizing
//!   an operand to `b` bits injects noise power `∝ 4^-b` (uniform
//!   quantization SNR halves per bit, i.e. noise power `2^-2b`), each
//!   layer scales that noise by a structural sensitivity, and the
//!   MAC-weighted sum composes into a network-level estimate
//!   `baseline · capacity(width, depth) · exp(-scale · noise)`.
//! * [`SensitivityTable`] — strict-JSON ingestion of *measured* per-layer
//!   sensitivities (e.g. from a real QAT sweep), so the proxy is a
//!   stand-in, not a ceiling.  Parsing mirrors the workload-JSON contract:
//!   unknown fields, non-positive sensitivities and layer-name mismatches
//!   are each rejected with an error naming the offending field.
//!
//! The estimate is monotone (more bits per layer never decreases it),
//! permutation-invariant over layer order (it is a weighted sum), and
//! bounded by `baseline`.  `opt/` consumes it as the `accuracy` maximize
//! objective and the `min-accuracy` hard constraint; model-side genome
//! knobs (channel-width / depth multipliers) feed [`AccuracyModel::
//! estimate_scaled`] through the capacity term.  See `docs/ACCURACY.md`.

use std::collections::BTreeMap;

use crate::api::error::QappaError;
use crate::config::{MacKind, QuantSpec};
use crate::dataflow::Layer;
use crate::util::json::{obj, Json};

/// Default noise→accuracy scale: calibrated so uniform INT4 on a
/// MobileNet-class net loses ~9% relative accuracy while INT8-activation
/// datapaths stay within ~5% — the qualitative ordering reported for
/// LightPE datapaths in the paper's lineage.
pub const DEFAULT_NOISE_SCALE: f64 = 12.0;

/// Capacity exponents for the model-side knobs: accuracy scales as
/// `width^WIDTH_EXP · depth^DEPTH_EXP` (EfficientNet-style diminishing
/// returns; both multipliers live in (0, 1], so capacity ≤ 1).
pub const WIDTH_EXP: f64 = 0.15;
/// See [`WIDTH_EXP`].
pub const DEPTH_EXP: f64 = 0.10;

/// Measured per-layer sensitivity data, as ingested from strict JSON.
///
/// Schema (all other fields rejected):
///
/// ```json
/// {
///   "baseline": 0.709,
///   "noise_scale": 12.0,
///   "sensitivity": { "stem": 1.5, "b1.dw": 2.0, "...": 1.0 }
/// }
/// ```
///
/// `baseline` is the unquantized (float) accuracy in (0, 1]; `noise_scale`
/// is optional (default [`DEFAULT_NOISE_SCALE`]); `sensitivity` maps every
/// workload layer name to a positive relative sensitivity.
#[derive(Debug, Clone, PartialEq)]
pub struct SensitivityTable {
    /// Unquantized reference accuracy, in (0, 1].
    pub baseline: f64,
    /// Noise→accuracy scale (exponent multiplier).
    pub noise_scale: f64,
    /// Per-layer positive sensitivities, keyed by layer name.
    pub sensitivity: BTreeMap<String, f64>,
}

fn terr(msg: String) -> QappaError {
    QappaError::Workload(format!("sensitivity table: {msg}"))
}

impl SensitivityTable {
    /// Strict parse from JSON text: unknown fields, missing required
    /// fields, out-of-range baselines and non-positive sensitivities are
    /// each errors naming the offending field.
    pub fn parse(text: &str) -> Result<SensitivityTable, QappaError> {
        let json = Json::parse(text).map_err(|e| terr(e.to_string()))?;
        SensitivityTable::from_json(&json)
    }

    /// Strict decode from a parsed [`Json`] document.
    pub fn from_json(json: &Json) -> Result<SensitivityTable, QappaError> {
        let top = json.as_obj().ok_or_else(|| terr("root must be an object".into()))?;
        for key in top.keys() {
            if !matches!(key.as_str(), "baseline" | "noise_scale" | "sensitivity") {
                return Err(terr(format!("unknown field \"{key}\"")));
            }
        }
        let baseline = top
            .get("baseline")
            .and_then(Json::as_f64)
            .ok_or_else(|| terr("field \"baseline\" is required and must be a number".into()))?;
        if !(baseline.is_finite() && baseline > 0.0 && baseline <= 1.0) {
            return Err(terr(format!("field \"baseline\" must be in (0, 1], got {baseline}")));
        }
        let noise_scale = match top.get("noise_scale") {
            None => DEFAULT_NOISE_SCALE,
            Some(v) => {
                let s = v
                    .as_f64()
                    .ok_or_else(|| terr("field \"noise_scale\" must be a number".into()))?;
                if !(s.is_finite() && s > 0.0) {
                    return Err(terr(format!(
                        "field \"noise_scale\" must be a positive number, got {s}"
                    )));
                }
                s
            }
        };
        let sens_obj = top
            .get("sensitivity")
            .and_then(Json::as_obj)
            .ok_or_else(|| terr("field \"sensitivity\" is required and must be an object".into()))?;
        if sens_obj.is_empty() {
            return Err(terr("field \"sensitivity\" must not be empty".into()));
        }
        let mut sensitivity = BTreeMap::new();
        for (name, v) in sens_obj {
            let s = v.as_f64().ok_or_else(|| {
                terr(format!("field \"sensitivity.{name}\" must be a number"))
            })?;
            if !(s.is_finite() && s > 0.0) {
                return Err(terr(format!(
                    "field \"sensitivity.{name}\" must be a positive number, got {s}"
                )));
            }
            sensitivity.insert(name.clone(), s);
        }
        Ok(SensitivityTable { baseline, noise_scale, sensitivity })
    }

    /// Compact JSON encoding; [`SensitivityTable::from_json`] round-trips.
    pub fn to_json(&self) -> Json {
        let sens = self
            .sensitivity
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect::<BTreeMap<_, _>>();
        obj(vec![
            ("baseline", Json::Num(self.baseline)),
            ("noise_scale", Json::Num(self.noise_scale)),
            ("sensitivity", Json::Obj(sens)),
        ])
    }

    /// Validate this table against a workload: every workload layer must
    /// have an entry and every entry must name a workload layer.  Errors
    /// name the offending layer/field (mirroring workload-JSON style).
    pub fn validate_for(&self, layers: &[Layer]) -> Result<(), QappaError> {
        for l in layers {
            if !self.sensitivity.contains_key(&l.name) {
                return Err(terr(format!(
                    "workload layer '{}' has no entry in field \"sensitivity\"",
                    l.name
                )));
            }
        }
        for name in self.sensitivity.keys() {
            if !layers.iter().any(|l| &l.name == name) {
                return Err(terr(format!(
                    "field \"sensitivity.{name}\" does not match any workload layer"
                )));
            }
        }
        Ok(())
    }
}

/// Network-level accuracy estimator over per-layer quantization specs.
///
/// Either a structural proxy (sensitivities derived from layer shape) or a
/// wrapper over a validated measured [`SensitivityTable`] — callers never
/// branch on which.
#[derive(Debug, Clone, PartialEq)]
pub struct AccuracyModel {
    baseline: f64,
    noise_scale: f64,
    /// `None` = structural proxy; `Some` = measured per-layer table.
    table: Option<BTreeMap<String, f64>>,
}

impl AccuracyModel {
    /// The structural proxy: baseline 1.0 (accuracy is reported as the
    /// fraction of float accuracy retained) and shape-derived
    /// sensitivities.
    pub fn proxy() -> AccuracyModel {
        AccuracyModel { baseline: 1.0, noise_scale: DEFAULT_NOISE_SCALE, table: None }
    }

    /// Wrap a measured table, validating it covers `layers` exactly.
    pub fn from_table(
        table: SensitivityTable,
        layers: &[Layer],
    ) -> Result<AccuracyModel, QappaError> {
        table.validate_for(layers)?;
        Ok(AccuracyModel {
            baseline: table.baseline,
            noise_scale: table.noise_scale,
            table: Some(table.sensitivity),
        })
    }

    /// Unquantized reference accuracy.
    pub fn baseline(&self) -> f64 {
        self.baseline
    }

    /// True when backed by a measured table rather than the proxy.
    pub fn is_measured(&self) -> bool {
        self.table.is_some()
    }

    /// Structural sensitivity of one layer, independent of its position in
    /// the network (the estimate must be permutation-invariant):
    /// depthwise layers (no channel mixing to absorb noise) are the most
    /// fragile, the RGB stem (`c ≤ 3`) and the classifier head amplify
    /// into few channels, attention is mildly above dense matmuls.
    pub fn proxy_sensitivity(layer: &Layer) -> f64 {
        let mut s = 1.0;
        if layer.is_depthwise() {
            s *= 2.0;
        }
        if layer.c <= 3 {
            s *= 1.5;
        }
        if layer.is_fc() {
            s *= 1.5;
        }
        if matches!(layer.kind(), "attention") {
            s *= 1.25;
        }
        s
    }

    /// Per-layer sensitivity: the measured entry when backed by a table
    /// (scaled model variants use a subset of the base layer names, so
    /// lookups stay covered), else the structural proxy.
    pub fn sensitivity(&self, layer: &Layer) -> f64 {
        match &self.table {
            Some(t) => t.get(&layer.name).copied().unwrap_or_else(|| {
                AccuracyModel::proxy_sensitivity(layer)
            }),
            None => AccuracyModel::proxy_sensitivity(layer),
        }
    }

    /// Quantization noise power injected by one PE spec.  Float datapaths
    /// are the zero-noise reference; integer operands contribute
    /// `4^-bits` each (noise power `2^-2b`); lightweight shift-add MACs
    /// cap the *effective* weight precision at `2·terms + 2` bits (each
    /// signed power-of-two term resolves ~2 bits of the multiplier).
    pub fn spec_noise(spec: &QuantSpec) -> f64 {
        fn q(bits: u32) -> f64 {
            4f64.powi(-(bits.min(512) as i32))
        }
        match spec.mac {
            MacKind::Fp => 0.0,
            MacKind::IntExact => q(spec.act_bits) + q(spec.wt_bits),
            MacKind::Lightweight(n) => {
                q(spec.act_bits) + q(spec.wt_bits.min(2 * n + 2))
            }
        }
    }

    /// Capacity multiplier for the model-side knobs: `width^0.15 ·
    /// depth^0.10` with both multipliers clamped to (0, 1].
    pub fn capacity(width_mult: f64, depth_mult: f64) -> f64 {
        let w = width_mult.clamp(f64::MIN_POSITIVE, 1.0);
        let d = depth_mult.clamp(f64::MIN_POSITIVE, 1.0);
        w.powf(WIDTH_EXP) * d.powf(DEPTH_EXP)
    }

    /// Network-level estimate for a full-size model:
    /// `baseline · exp(-scale · Σᵢ wᵢ·sᵢ·noise(specᵢ))` with MAC-share
    /// weights `wᵢ`.  `specs[i]` is the precision layer `i` runs at.
    pub fn estimate(&self, layers: &[Layer], specs: &[QuantSpec]) -> f64 {
        self.estimate_scaled(layers, specs, 1.0, 1.0)
    }

    /// Network-level estimate with model-side knobs applied: the layer
    /// list is the *scaled variant's* layers and the capacity term prices
    /// the lost width/depth.
    pub fn estimate_scaled(
        &self,
        layers: &[Layer],
        specs: &[QuantSpec],
        width_mult: f64,
        depth_mult: f64,
    ) -> f64 {
        debug_assert_eq!(layers.len(), specs.len());
        let total: f64 = layers.iter().map(|l| l.macs() as f64).sum();
        if total <= 0.0 {
            return self.baseline * AccuracyModel::capacity(width_mult, depth_mult);
        }
        let mut noise = 0.0;
        for (l, spec) in layers.iter().zip(specs) {
            let w = l.macs() as f64 / total;
            noise += w * self.sensitivity(l) * AccuracyModel::spec_noise(spec);
        }
        self.baseline
            * AccuracyModel::capacity(width_mult, depth_mult)
            * (-self.noise_scale * noise).exp()
    }

    /// Materialize this model's per-layer sensitivities for `layers` as a
    /// table — the bridge that lets tests pin proxy == table agreement and
    /// users export the proxy as a starting point for measured data.
    pub fn to_table(&self, layers: &[Layer]) -> SensitivityTable {
        let sensitivity = layers
            .iter()
            .map(|l| (l.name.clone(), self.sensitivity(l)))
            .collect::<BTreeMap<_, _>>();
        SensitivityTable { baseline: self.baseline, noise_scale: self.noise_scale, sensitivity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PeType;
    use crate::workloads;

    fn uniform_specs(layers: &[Layer], spec: QuantSpec) -> Vec<QuantSpec> {
        vec![spec; layers.len()]
    }

    #[test]
    fn float_reference_hits_the_baseline() {
        let net = workloads::mobilenetv1();
        let m = AccuracyModel::proxy();
        let acc = m.estimate(&net, &uniform_specs(&net, PeType::Fp32.spec()));
        assert!((acc - 1.0).abs() < 1e-12, "{acc}");
    }

    #[test]
    fn preset_palette_orders_by_precision() {
        let net = workloads::mobilenetv1();
        let m = AccuracyModel::proxy();
        let acc = |t: PeType| m.estimate(&net, &uniform_specs(&net, t.spec()));
        let (fp, i16_, l2, l1) = (
            acc(PeType::Fp32),
            acc(PeType::Int16),
            acc(PeType::LightPe2),
            acc(PeType::LightPe1),
        );
        assert!(fp >= i16_ && i16_ > l2 && l2 > l1, "{fp} {i16_} {l2} {l1}");
        // INT16 is visually lossless, LightPE-1 (4-bit weights) is not.
        assert!(i16_ > 0.999, "{i16_}");
        assert!(l1 < 0.99, "{l1}");
        assert!(l1 > 0.5, "{l1}");
    }

    #[test]
    fn capacity_penalizes_slimmer_models() {
        assert_eq!(AccuracyModel::capacity(1.0, 1.0), 1.0);
        let slim = AccuracyModel::capacity(0.5, 1.0);
        let shallow = AccuracyModel::capacity(1.0, 0.5);
        assert!(slim < 1.0 && shallow < 1.0);
        assert!(AccuracyModel::capacity(0.5, 0.5) < slim.min(shallow));
    }

    #[test]
    fn table_json_round_trips() {
        let net = workloads::mobilenetv1();
        let t = AccuracyModel::proxy().to_table(&net);
        let text = t.to_json().to_string();
        let back = SensitivityTable::parse(&text).unwrap();
        assert_eq!(back, t);
        back.validate_for(&net).unwrap();
    }

    #[test]
    fn strict_parse_names_the_offending_field() {
        let cases = [
            (r#"{"baseline":0.7,"sensitivity":{"a":1.0},"extra":1}"#, "\"extra\""),
            (r#"{"sensitivity":{"a":1.0}}"#, "\"baseline\""),
            (r#"{"baseline":1.7,"sensitivity":{"a":1.0}}"#, "\"baseline\""),
            (r#"{"baseline":0.7}"#, "\"sensitivity\""),
            (r#"{"baseline":0.7,"sensitivity":{}}"#, "\"sensitivity\""),
            (r#"{"baseline":0.7,"sensitivity":{"a":-1.0}}"#, "\"sensitivity.a\""),
            (r#"{"baseline":0.7,"noise_scale":0,"sensitivity":{"a":1.0}}"#, "\"noise_scale\""),
        ];
        for (text, field) in cases {
            let e = SensitivityTable::parse(text).unwrap_err().to_string();
            assert!(e.contains(field), "expected {field} in: {e}");
        }
    }

    #[test]
    fn validate_for_names_missing_and_unknown_layers() {
        let net = workloads::mobilenetv1();
        let mut t = AccuracyModel::proxy().to_table(&net);
        t.sensitivity.remove("stem");
        let e = t.validate_for(&net).unwrap_err().to_string();
        assert!(e.contains("'stem'"), "{e}");
        let mut t2 = AccuracyModel::proxy().to_table(&net);
        t2.sensitivity.insert("ghost".into(), 1.0);
        let e2 = t2.validate_for(&net).unwrap_err().to_string();
        assert!(e2.contains("sensitivity.ghost"), "{e2}");
    }
}
